"""repro — multiphase sparse/dense dataflows (Garg et al. 2021) as a
JAX/TPU framework.  See README.md / DESIGN.md / EXPERIMENTS.md.

The front door is :func:`repro.compile`: search a model-level dataflow
schedule (or accept one), lower it to executable kernel knobs, and get a
frozen :class:`repro.api.Program` with ``run``/``loss``/``stats`` and a
cacheable ``save``/``load`` JSON artifact.
"""
from .api import Program, compile, trace_count, workload_fingerprint
from .core.hw import LatencyModel

__all__ = [
    "LatencyModel",
    "Program",
    "compile",
    "trace_count",
    "workload_fingerprint",
]
