"""repro — multiphase sparse/dense dataflows (Garg et al. 2021) as a
JAX/TPU framework.  See README.md / DESIGN.md / EXPERIMENTS.md."""
