"""GNN layers in JAX with explicit multiphase execution policies.

Each layer is a two-phase sparse/dense chain (aggregation = SpMM over the
padded-ELL adjacency, combination = GEMM).  The inter-phase dataflow is a
*program structure*:

  * ``seq``        — materialize the full V x F intermediate, then GEMM
                     (paper Seq: intermediate round-trips through memory).
  * ``sp_generic`` — `lax.scan` over row bands; each band's intermediate is
                     produced and consumed inside one scan step (paper
                     SP-Generic at row granularity).
  * ``sp_opt``     — the fused band step keeps the aggregated tile as the
                     immediate GEMM operand (no stacked intermediate at
                     all); on TPU this is the fused Pallas kernel
                     (:mod:`repro.kernels.fused_agg_cmb`), on CPU its jnp
                     body (paper SP-Optimized).
  * ``pp``         — producer/consumer device groups connected by
                     collective_permute (:mod:`repro.gnn.pp`), the paper's
                     Parallel Pipeline at the device level.

All policies compute the same numbers (tested to 1e-5); they differ in
where the intermediate lives — exactly the paper's point.

Phase order is a knob too: ``AC`` computes (A·X)·W, ``CA`` computes
A·(X·W) — same result, different cost (paper Sec. 3.3; AWB-GCN is CA).

Each executable path registers itself in the kernel registry
(:mod:`repro.core.registry`) keyed by the
:class:`~repro.core.schedule.ExecSpec` fields ``(policy, order,
use_pallas)``; :func:`multiphase_matmul` is a thin dispatcher that
normalizes its arguments into an ``ExecSpec`` and looks the path up.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.registry import lookup_kernel, register_kernel
from ..core.schedule import ExecSpec
from ..graphs.csr import CSRGraph

POLICIES = ("seq", "sp_generic", "sp_opt", "pp")


@dataclass(frozen=True)
class EllAdjacency:
    """Device-side padded-ELL adjacency (see CSRGraph.to_ell)."""

    indices: jax.Array  # (V_pad, D) int32
    weights: jax.Array  # (V_pad, D) f32 — zero on padded slots
    n_nodes: int

    @classmethod
    def from_csr(
        cls, g: CSRGraph, block_rows: int = 1, pad_to: int | None = None
    ) -> "EllAdjacency":
        """``pad_to`` fixes the padded-ELL width D (>= the graph's max
        degree): batched serving pads every micro-batch of a bucket to the
        same D so rebinding never changes the device shapes."""
        if pad_to is not None and pad_to < g.max_degree:
            raise ValueError(
                f"pad_to={pad_to} is narrower than the graph's max degree "
                f"{g.max_degree}; neighbor lists would be truncated"
            )
        idx, wts, _ = g.to_ell(block_rows, pad_to=pad_to)
        return cls(jnp.asarray(idx), jnp.asarray(wts), g.n_nodes)

    @classmethod
    def from_schedule(
        cls, g: CSRGraph, schedule, pad_to: int | None = None
    ) -> "EllAdjacency":
        """Build the adjacency with a ModelSchedule's lowered ELL block
        rows, so every layer's band scan walks aligned row groups."""
        return cls.from_csr(
            g, block_rows=schedule.ell_block_rows, pad_to=pad_to
        )

    @property
    def v_pad(self) -> int:
        return self.indices.shape[0]


# ---------------------------------------------------------------------------
# Aggregation (SpMM) primitives
# ---------------------------------------------------------------------------


def aggregate_full(adj: EllAdjacency, x: jax.Array) -> jax.Array:
    """Whole-graph aggregation: out[v] = sum_d w[v,d] * x[idx[v,d]]."""
    gathered = x[adj.indices]  # (V_pad, D, F)
    return jnp.einsum("vd,vdf->vf", adj.weights, gathered)


def aggregate_band(indices: jax.Array, weights: jax.Array, x: jax.Array) -> jax.Array:
    """Aggregation for one row band: indices/weights (B, D)."""
    gathered = x[indices]  # (B, D, F)
    return jnp.einsum("bd,bdf->bf", weights, gathered)


def _band_scan(
    adj: EllAdjacency,
    x: jax.Array,
    band_fn: Callable[[jax.Array], jax.Array],
    band_size: int,
):
    v_pad = adj.v_pad
    n_bands = -(-v_pad // band_size)
    pad = n_bands * band_size - v_pad
    idx = jnp.pad(adj.indices, ((0, pad), (0, 0)))
    wts = jnp.pad(adj.weights, ((0, pad), (0, 0)))
    idx = idx.reshape(n_bands, band_size, -1)
    wts = wts.reshape(n_bands, band_size, -1)

    def step(carry, band):
        i, w = band
        h_band = aggregate_band(i, w, x)
        return carry, band_fn(h_band)

    _, out = jax.lax.scan(step, None, (idx, wts))
    out = out.reshape(n_bands * band_size, -1)
    return out[:v_pad]


# ---------------------------------------------------------------------------
# Registered executable paths (keyed by ExecSpec fields)
# ---------------------------------------------------------------------------


@register_kernel("seq", orders=("AC",))
def _seq_ac(adj, x, w, spec, mesh):
    """Seq/AC: materialize the full aggregated intermediate, then GEMM."""
    return (aggregate_full(adj, x) @ w)[: adj.n_nodes]


@register_kernel("seq", orders=("CA",))
def _seq_ca(adj, x, w, spec, mesh):
    """Seq/CA: dense GEMM first, then whole-graph aggregation."""
    return aggregate_full(adj, x @ w)[: adj.n_nodes]


@register_kernel("seq", pallas=(True,))
def _seq_pallas(adj, x, w, spec, mesh):
    """Seq with the aggregation routed through the Pallas ELL SpMM."""
    from ..kernels.spmm.ops import spmm

    feats = x @ w if spec.order == "CA" else x
    h = spmm(
        adj.indices,
        adj.weights,
        feats,
        block_v=spec.band_size,
        block_f=spec.block_f or 128,
    )
    if spec.order == "CA":
        return h[: adj.n_nodes]
    return (h @ w)[: adj.n_nodes]


@register_kernel("sp_generic", orders=("AC",))
@register_kernel("sp_opt", orders=("AC",))
def _sp_ac(adj, x, w, spec, mesh):
    """SP/AC band scan: each band's intermediate lives inside one scan
    step, and the fused step keeps the aggregated tile as the immediate
    GEMM operand — the jnp body of both SP-Generic and SP-Optimized."""
    return _band_scan(adj, x, lambda h: h @ w, spec.band_size)[: adj.n_nodes]


@register_kernel("sp_generic", orders=("CA",))
@register_kernel("sp_opt", orders=("CA",))
def _sp_ca(adj, x, w, spec, mesh):
    """SP/CA: aggregate the combined features band by band."""
    return _band_scan(adj, x @ w, lambda h: h, spec.band_size)[: adj.n_nodes]


@register_kernel("sp_opt", orders=("AC",), pallas=(True,))
def _sp_opt_fused(adj, x, w, spec, mesh):
    """SP-Optimized/AC on TPU: the fused aggregation+combination kernel."""
    from ..kernels.fused_agg_cmb.ops import fused_agg_cmb

    return fused_agg_cmb(
        adj.indices,
        adj.weights,
        x,
        w,
        band_size=spec.band_size,
        block_f=spec.block_f,
    )[: adj.n_nodes]


@register_kernel("pp")
def _pp(adj, x, w, spec, mesh):
    """Parallel Pipeline: producer/consumer device groups (repro.gnn.pp)."""
    from .pp import pp_multiphase_matmul

    return pp_multiphase_matmul(
        adj, x, w, order=spec.order, mesh=mesh, band_size=spec.band_size
    )


# ---------------------------------------------------------------------------
# Two-phase execution under a multiphase policy
# ---------------------------------------------------------------------------

_SPEC_KNOBS = ("policy", "order", "band_size", "block_f", "use_pallas")


def multiphase_matmul(
    adj: EllAdjacency,
    x: jax.Array,
    w: jax.Array,
    policy: str | None = None,
    order: str | None = None,
    band_size: int | None = None,
    use_pallas: bool | None = None,
    mesh=None,
    block_f: int | None = None,
    spec: ExecSpec | None = None,
) -> jax.Array:
    """Execute aggregation + combination under an inter-phase policy.

    AC: (A @ X) @ W.  CA: A @ (X @ W).

    ``spec`` (a :class:`repro.core.schedule.ExecSpec`, the lowered form of a
    mapper-chosen :class:`~repro.core.schedule.LayerSchedule`) is the single
    source of truth when one is provided: passing an explicit ``policy`` /
    ``order`` / ``band_size`` / ``block_f`` / ``use_pallas`` kwarg that
    disagrees with the spec raises :class:`ValueError` rather than being
    silently ignored.  Without a spec, the string knobs build one
    (defaults: ``sp_opt`` / ``AC`` / band 128), so both entry styles
    dispatch through the same kernel registry.
    """
    if spec is not None:
        given = dict(
            policy=policy,
            order=order,
            band_size=band_size,
            block_f=block_f,
            use_pallas=use_pallas,
        )
        conflicts = {
            k: v
            for k, v in given.items()
            if v is not None and v != getattr(spec, k)
        }
        if conflicts:
            raise ValueError(
                f"multiphase_matmul got an ExecSpec plus conflicting explicit "
                f"kwargs {conflicts}; the spec has "
                f"{ {k: getattr(spec, k) for k in conflicts} } — pass one or "
                f"the other"
            )
    else:
        spec = ExecSpec(
            policy=policy if policy is not None else "sp_opt",
            order=order if order is not None else "AC",
            band_size=band_size if band_size is not None else 128,
            block_f=block_f,
            use_pallas=bool(use_pallas),
        )
    kernel = lookup_kernel(spec.policy, spec.order, spec.use_pallas)
    return kernel(adj, x, w, spec, mesh)


# ---------------------------------------------------------------------------
# Segment-aware readout (batched serving)
# ---------------------------------------------------------------------------

READOUTS = ("sum", "mean", "max")


def segment_readout(
    h: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    reduce: str = "mean",
) -> jax.Array:
    """Per-graph readout over a block-diagonally batched node output.

    ``h`` is (V, F) node output of a batched forward pass and
    ``segment_ids[v]`` the member-graph index of row ``v``; returns the
    (num_segments, F) per-graph reduction.  Pad rows carry an id of
    ``num_segments`` (out of range), which JAX segment ops drop — so the
    batch padding never leaks into the readout.
    """
    if reduce not in READOUTS:
        raise ValueError(
            f"reduce must be one of {READOUTS}, got {reduce!r}"
        )
    if reduce == "max":
        return jax.ops.segment_max(h, segment_ids, num_segments=num_segments)
    s = jax.ops.segment_sum(h, segment_ids, num_segments=num_segments)
    if reduce == "sum":
        return s
    counts = jax.ops.segment_sum(
        jnp.ones(h.shape[0], h.dtype), segment_ids, num_segments=num_segments
    )
    return s / jnp.maximum(counts, 1.0)[:, None]


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------


def gcn_layer(params, adj, x, *, policy=None, order=None, **kw):
    """GCN: relu(Ã X W + b) with the multiphase policy."""
    out = multiphase_matmul(adj, x, params["w"], policy=policy, order=order, **kw)
    return jax.nn.relu(out + params["b"])


def sage_layer(params, adj, x, *, policy=None, order=None, **kw):
    """GraphSAGE with the paper's Sec.-6 decomposition:

        concat(X, A·X) @ W  ==  X @ W_top + (A·X) @ W_bottom

    The GEMM-first form keeps X @ W_top independent of aggregation — the
    extra scheduling freedom the paper highlights.
    """
    self_term = x[: adj.n_nodes] @ params["w_top"]
    agg_term = multiphase_matmul(
        adj, x, params["w_bottom"], policy=policy, order=order, **kw
    )
    return jax.nn.relu(self_term + agg_term + params["b"])


def gin_layer(params, adj, x, *, policy=None, order=None, **kw):
    """GIN: MLP((1 + eps) * x + sum-aggregate(x)).

    The sum aggregation is the same SpMM with unit weights; the first MLP
    matmul plays the combination role, so the multiphase policy applies.
    """
    eps = params["eps"]
    # aggregate-then-combine on the summed representation
    unit_adj = EllAdjacency(adj.indices, (adj.weights > 0).astype(x.dtype), adj.n_nodes)
    agg = multiphase_matmul(unit_adj, x, params["w1"], policy=policy, order=order, **kw)
    self_term = (1.0 + eps) * x[: adj.n_nodes] @ params["w1"]
    h = jax.nn.relu(agg + self_term + params["b1"])
    return jax.nn.relu(h @ params["w2"] + params["b2"])


LAYER_FNS = {"gcn": gcn_layer, "sage": sage_layer, "gin": gin_layer}


def init_layer(kind: str, rng: jax.Array, f_in: int, f_out: int):
    k1, k2, k3 = jax.random.split(rng, 3)
    scale = 1.0 / np.sqrt(f_in)
    if kind == "gcn":
        return {
            "w": jax.random.normal(k1, (f_in, f_out)) * scale,
            "b": jnp.zeros((f_out,)),
        }
    if kind == "sage":
        return {
            "w_top": jax.random.normal(k1, (f_in, f_out)) * scale,
            "w_bottom": jax.random.normal(k2, (f_in, f_out)) * scale,
            "b": jnp.zeros((f_out,)),
        }
    if kind == "gin":
        return {
            "eps": jnp.zeros(()),
            "w1": jax.random.normal(k1, (f_in, f_out)) * scale,
            "b1": jnp.zeros((f_out,)),
            "w2": jax.random.normal(k2, (f_out, f_out)) * (1.0 / np.sqrt(f_out)),
            "b2": jnp.zeros((f_out,)),
        }
    raise KeyError(kind)
