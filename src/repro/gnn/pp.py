"""Parallel-Pipeline (PP) inter-phase dataflow at the device level.

The paper's PP splits the PE array into an aggregation engine and a
combination engine connected by a ping-pong buffer (HyGCN/AWB-GCN style).
The TPU-native analogue implemented here splits the *device mesh* into two
phase groups: group 0 aggregates row band ``i`` while group 1 runs the
combination GEMM on band ``i-1``; the intermediate band is handed off with
``collective_permute`` (the "NoC connecting Agg and Cmb units", Table 2).

This is the honest mapping of the paper's spatial phase partitioning onto
jax-native constructs — no torch.distributed emulation, just shard_map +
lax collectives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6 promotes shard_map to the top level (check_vma arg)
    _shard_map = jax.shard_map
    _SHARD_MAP_KW = {"check_vma": False}
except AttributeError:  # jax 0.4/0.5: experimental home, check_rep arg
    from jax.experimental.shard_map import shard_map as _shard_map

    _SHARD_MAP_KW = {"check_rep": False}


def mesh_devices(
    mesh: jax.sharding.Mesh | None = None,
    devices: list | None = None,
) -> list:
    """Flatten a placement target into an ordered device list.

    Accepts a :class:`jax.sharding.Mesh` (any axis shape — placement is
    over the flattened device grid), an explicit device list, or neither
    (all local devices).  The serving scheduler and the PP path share this
    so "the mesh" means the same devices in both.
    """
    if mesh is not None and devices is not None:
        raise ValueError("pass mesh= or devices=, not both")
    if mesh is not None:
        return list(mesh.devices.flat)
    if devices is not None:
        return list(devices)
    return list(jax.devices())


def pp_multiphase_matmul(
    adj,
    x: jax.Array,
    w: jax.Array,
    order: str = "AC",
    mesh: jax.sharding.Mesh | None = None,
    band_size: int = 128,
    phase_axis: str = "phase",
) -> jax.Array:
    """(A @ X) @ W (AC) or A @ (X @ W) (CA) on a two-group phase mesh.

    Falls back to the SP-Generic band scan when no multi-device mesh is
    available (the CPU test container has one device; the PP structure is
    exercised with ``--xla_force_host_platform_device_count`` in
    tests/test_gnn_pp.py and examples/gnn_parallel_pipeline.py).
    """
    if mesh is None or mesh.devices.size < 2:
        from .layers import multiphase_matmul

        return multiphase_matmul(adj, x, w, policy="sp_generic", order=order)

    if order == "CA":
        # combination first is a single dense GEMM; pipeline the aggregation
        # of its output bands instead (AWB-GCN direction).  sp_generic/CA is
        # exactly that band scan — routing through the AC path with an
        # identity W would pay a pointless O(V*G^2) GEMM per band.
        from .layers import multiphase_matmul

        return multiphase_matmul(
            adj, x, w, policy="sp_generic", order="CA", band_size=band_size
        )

    v_pad = adj.v_pad
    n_bands = -(-v_pad // band_size)
    pad = n_bands * band_size - v_pad
    idx = jnp.pad(adj.indices, ((0, pad), (0, 0))).reshape(n_bands, band_size, -1)
    wts = jnp.pad(adj.weights, ((0, pad), (0, 0))).reshape(n_bands, band_size, -1)

    def pipelined(idx, wts, x, w):
        p = jax.lax.axis_index(phase_axis)
        f_in, g_out = w.shape

        def agg(band_i):
            g = x[idx[band_i]]  # (B, D, F)
            return jnp.einsum("bd,bdf->bf", wts[band_i], g)

        def step(carry, band_i):
            prev_band = carry  # intermediate band produced last step
            # producer group computes band i; consumer sees zeros
            h = jnp.where(p == 0, agg(band_i), jnp.zeros((band_size, f_in), x.dtype))
            # hand off through the pipeline "NoC"
            h_next = jax.lax.ppermute(h, phase_axis, perm=[(0, 1)])
            # consumer group combines the band received in the *previous*
            # step (one-deep ping-pong buffer)
            out = jnp.where(
                p == 1, prev_band @ w, jnp.zeros((band_size, g_out), x.dtype)
            )
            return h_next, out

        carry0 = jnp.zeros((band_size, f_in), x.dtype)
        carry, outs = jax.lax.scan(step, carry0, jnp.arange(n_bands))
        # drain: the last band is still in the consumer's buffer
        last = jnp.where(p == 1, carry @ w, jnp.zeros((band_size, g_out), x.dtype))
        outs = jnp.concatenate([outs[1:], last[None]], axis=0)
        # only the consumer group holds real outputs; share them
        outs = jax.lax.psum(outs, phase_axis)
        return outs.reshape(n_bands * band_size, g_out)

    shard = _shard_map(
        pipelined,
        mesh=mesh,
        in_specs=(P(), P(), P(), P()),
        out_specs=P(),
        **_SHARD_MAP_KW,
    )
    return shard(idx, wts, x, w)[: adj.n_nodes]
