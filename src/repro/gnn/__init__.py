from .layers import (
    EllAdjacency,
    LAYER_FNS,
    POLICIES,
    aggregate_full,
    gcn_layer,
    gin_layer,
    init_layer,
    multiphase_matmul,
    sage_layer,
    segment_readout,
)
from .model import (
    GNNConfig,
    forward_layers,
    gnn_forward,
    gnn_loss,
    init_gnn,
    make_node_classification_task,
    masked_xent_loss,
)
