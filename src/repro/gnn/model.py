"""Multi-layer GNN models with per-layer multiphase dataflow policies."""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.csr import CSRGraph
from .layers import LAYER_FNS, EllAdjacency, init_layer


@dataclass(frozen=True)
class GNNConfig:
    kind: str = "gcn"  # gcn | sage | gin
    f_in: int = 128
    hidden: int = 16  # Kipf-standard hidden width
    n_classes: int = 8
    n_layers: int = 2
    policy: str = "sp_opt"  # inter-phase dataflow policy
    order: str = "AC"  # phase order
    band_size: int = 128

    @property
    def dims(self) -> list[tuple[int, int]]:
        ds = []
        f = self.f_in
        for i in range(self.n_layers):
            out = self.n_classes if i == self.n_layers - 1 else self.hidden
            ds.append((f, out))
            f = out
        return ds


def init_gnn(cfg: GNNConfig, rng: jax.Array):
    keys = jax.random.split(rng, cfg.n_layers)
    return [init_layer(cfg.kind, k, fi, fo) for k, (fi, fo) in zip(keys, cfg.dims)]


def gnn_forward(cfg: GNNConfig, params, adj: EllAdjacency, x: jax.Array, mesh=None):
    fn = LAYER_FNS[cfg.kind]
    h = x
    for layer in params:
        h = fn(
            layer,
            adj,
            h,
            policy=cfg.policy,
            order=cfg.order,
            band_size=cfg.band_size,
            mesh=mesh,
        )
    return h  # logits (V, n_classes)


def gnn_loss(cfg: GNNConfig, params, adj, x, labels, mask):
    logits = gnn_forward(cfg, params, adj, x)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def make_node_classification_task(
    g: CSRGraph, f_in: int, n_classes: int, seed: int = 0
):
    """Seeded synthetic node-classification task over a CSR graph."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(g.n_nodes, f_in)).astype(np.float32)
    labels = rng.integers(0, n_classes, size=g.n_nodes).astype(np.int32)
    mask = (rng.random(g.n_nodes) < 0.3).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(labels), jnp.asarray(mask)
