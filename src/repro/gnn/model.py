"""Multi-layer GNN models with per-layer multiphase dataflow schedules.

The execution path runs off the model-level schedule IR
(:class:`repro.core.schedule.ModelSchedule`): ``gnn_forward`` lowers each
layer's :class:`~repro.core.schedule.LayerSchedule` to its executable knobs
and dispatches :func:`repro.gnn.layers.multiphase_matmul` with them.

.. deprecated::
    Configuring execution through the ``GNNConfig.policy`` / ``order`` /
    ``band_size`` string knobs is deprecated.  They remain as a thin
    compatibility shim that constructs a homogeneous default schedule
    (:meth:`ModelSchedule.from_policies`) and emits a one-time
    :class:`DeprecationWarning`; new code should compile a
    :class:`repro.api.Program` with :func:`repro.compile` (or pass an
    explicit ``ModelSchedule``), so string-configured and mapper-searched
    models share one code path.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..core.schedule import ModelSchedule
from ..graphs.csr import CSRGraph
from .layers import LAYER_FNS, EllAdjacency, init_layer, segment_readout

#: set True after the first string-policy shim warning (reset by tests).
_POLICY_SHIM_WARNED = False


def _warn_policy_shim() -> None:
    """One-time DeprecationWarning for the string-policy execution path."""
    global _POLICY_SHIM_WARNED
    if not _POLICY_SHIM_WARNED:
        _POLICY_SHIM_WARNED = True
        warnings.warn(
            "executing from GNNConfig.policy/order/band_size string knobs is "
            "deprecated; compile a Program with repro.compile(...) or pass an "
            "explicit ModelSchedule (schedule=...) instead",
            DeprecationWarning,
            stacklevel=3,
        )


@dataclass(frozen=True)
class GNNConfig:
    kind: str = "gcn"  # gcn | sage | gin
    f_in: int = 128
    hidden: int = 16  # Kipf-standard hidden width
    n_classes: int = 8
    n_layers: int = 2
    policy: str = "sp_opt"  # deprecated shim; see module docstring
    order: str = "AC"  # phase order
    band_size: int = 128
    use_pallas: bool = False  # route kernels through Pallas when lowering

    @property
    def dims(self) -> list[tuple[int, int]]:
        ds = []
        f = self.f_in
        for i in range(self.n_layers):
            out = self.n_classes if i == self.n_layers - 1 else self.hidden
            ds.append((f, out))
            f = out
        return ds

    def default_schedule(self) -> ModelSchedule:
        """The homogeneous ModelSchedule the (deprecated) string knobs
        stand for; prefer :func:`repro.compile` for new code."""
        return ModelSchedule.from_policies(
            self.policy, self.order, self.dims, band_size=self.band_size
        )


def init_gnn(cfg: GNNConfig, rng: jax.Array):
    keys = jax.random.split(rng, cfg.n_layers)
    return [init_layer(cfg.kind, k, fi, fo) for k, (fi, fo) in zip(keys, cfg.dims)]


def forward_layers(kind: str, params, adj: EllAdjacency, x: jax.Array,
                   specs, mesh=None, segment_ids=None, num_segments=None,
                   readout: str = "mean") -> jax.Array:
    """Run the layer stack under per-layer ExecSpecs (the single forward
    loop shared by ``gnn_forward`` and ``repro.api.Program.run``).

    With ``segment_ids`` / ``num_segments`` (a block-diagonally batched
    graph, see :mod:`repro.graphs.batching`), the per-node logits are
    reduced per member graph with :func:`repro.gnn.layers.segment_readout`
    and the result is (num_segments, f_out) — per-graph outputs, not one
    fused logit matrix.
    """
    fn = LAYER_FNS[kind]
    h = x
    for layer, spec in zip(params, specs):
        h = fn(layer, adj, h, spec=spec, mesh=mesh)
    if segment_ids is not None:
        if num_segments is None:
            raise ValueError("segment_ids needs num_segments")
        h = segment_readout(h, segment_ids, num_segments, reduce=readout)
    return h


def masked_xent_loss(logits: jax.Array, labels, mask):
    """Masked softmax cross-entropy shared by ``gnn_loss`` and
    ``Program.loss``."""
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def gnn_forward(
    cfg: GNNConfig,
    params,
    adj: EllAdjacency,
    x: jax.Array,
    mesh=None,
    schedule: ModelSchedule | None = None,
):
    """Forward pass under a model-level schedule.

    ``schedule`` defaults to the homogeneous schedule constructed from the
    config's string knobs (the **deprecated** shim path — it warns once);
    pass a mapper-searched :class:`~repro.core.schedule.ModelSchedule`
    (``search_model`` -> ``lower``), or better, compile a
    :class:`repro.api.Program` with :func:`repro.compile`, to run each
    layer under its own dataflow.
    """
    if schedule is None:
        _warn_policy_shim()
        schedule = cfg.default_schedule()
    if schedule.n_layers != len(params):
        raise ValueError(
            f"schedule has {schedule.n_layers} layers but params have "
            f"{len(params)}"
        )
    return forward_layers(
        cfg.kind, params, adj, x,
        schedule.lower(use_pallas=cfg.use_pallas), mesh=mesh,
    )  # logits (V, n_classes)


def gnn_loss(cfg: GNNConfig, params, adj, x, labels, mask, schedule=None):
    logits = gnn_forward(cfg, params, adj, x, schedule=schedule)
    return masked_xent_loss(logits, labels, mask)


def make_node_classification_task(
    g: CSRGraph, f_in: int, n_classes: int, seed: int = 0
):
    """Seeded synthetic node-classification task over a CSR graph."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(g.n_nodes, f_in)).astype(np.float32)
    labels = rng.integers(0, n_classes, size=g.n_nodes).astype(np.int32)
    mask = (rng.random(g.n_nodes) < 0.3).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(labels), jnp.asarray(mask)
