"""Multi-layer GNN models with per-layer multiphase dataflow schedules.

The execution path runs off the model-level schedule IR
(:class:`repro.core.schedule.ModelSchedule`): ``gnn_forward`` lowers each
layer's :class:`~repro.core.schedule.LayerSchedule` to its executable knobs
and dispatches :func:`repro.gnn.layers.multiphase_matmul` with them.  The
legacy string knobs (``GNNConfig.policy`` / ``order`` / ``band_size``) are
kept as a thin compatibility shim that constructs a homogeneous default
schedule (:meth:`ModelSchedule.from_policies`), so string-configured and
mapper-searched models share one code path.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..core.schedule import ModelSchedule
from ..graphs.csr import CSRGraph
from .layers import LAYER_FNS, EllAdjacency, init_layer


@dataclass(frozen=True)
class GNNConfig:
    kind: str = "gcn"  # gcn | sage | gin
    f_in: int = 128
    hidden: int = 16  # Kipf-standard hidden width
    n_classes: int = 8
    n_layers: int = 2
    policy: str = "sp_opt"  # inter-phase dataflow policy (shim; see module doc)
    order: str = "AC"  # phase order
    band_size: int = 128
    use_pallas: bool = False  # route kernels through Pallas when lowering

    @property
    def dims(self) -> list[tuple[int, int]]:
        ds = []
        f = self.f_in
        for i in range(self.n_layers):
            out = self.n_classes if i == self.n_layers - 1 else self.hidden
            ds.append((f, out))
            f = out
        return ds

    def default_schedule(self) -> ModelSchedule:
        """The homogeneous ModelSchedule the string knobs stand for."""
        return ModelSchedule.from_policies(
            self.policy, self.order, self.dims, band_size=self.band_size
        )


def init_gnn(cfg: GNNConfig, rng: jax.Array):
    keys = jax.random.split(rng, cfg.n_layers)
    return [init_layer(cfg.kind, k, fi, fo) for k, (fi, fo) in zip(keys, cfg.dims)]


def gnn_forward(
    cfg: GNNConfig,
    params,
    adj: EllAdjacency,
    x: jax.Array,
    mesh=None,
    schedule: ModelSchedule | None = None,
):
    """Forward pass under a model-level schedule.

    ``schedule`` defaults to the homogeneous schedule constructed from the
    config's string knobs; pass a mapper-searched
    :class:`~repro.core.schedule.ModelSchedule` (``search_model`` ->
    ``lower``) to run each layer under its own dataflow.
    """
    if schedule is None:
        schedule = cfg.default_schedule()
    if schedule.n_layers != len(params):
        raise ValueError(
            f"schedule has {schedule.n_layers} layers but params have "
            f"{len(params)}"
        )
    fn = LAYER_FNS[cfg.kind]
    h = x
    for layer, spec in zip(params, schedule.lower(use_pallas=cfg.use_pallas)):
        h = fn(layer, adj, h, spec=spec, mesh=mesh)
    return h  # logits (V, n_classes)


def gnn_loss(cfg: GNNConfig, params, adj, x, labels, mask, schedule=None):
    logits = gnn_forward(cfg, params, adj, x, schedule=schedule)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def make_node_classification_task(
    g: CSRGraph, f_in: int, n_classes: int, seed: int = 0
):
    """Seeded synthetic node-classification task over a CSR graph."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(g.n_nodes, f_in)).astype(np.float32)
    labels = rng.integers(0, n_classes, size=g.n_nodes).astype(np.int32)
    mask = (rng.random(g.n_nodes) < 0.3).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(labels), jnp.asarray(mask)
