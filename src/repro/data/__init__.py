from .pipeline import GraphStream, LMDataPipeline
