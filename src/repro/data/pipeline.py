"""Deterministic, checkpointable synthetic data pipelines.

Every batch is a pure function of (seed, step), so the entire pipeline
state is two integers: resuming from a checkpoint replays the exact token
stream (tested in tests/test_train_integration.py), and no host state can
be lost on preemption — the property that makes the fault-tolerance story
exact rather than approximate.

The synthetic LM stream is a mixture of Zipf-distributed unigrams and
shifted-copy spans, which gives a learnable (loss-reducing) signal without
any external corpus (the container is offline).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ArchConfig


@dataclass
class LMDataPipeline:
    cfg: ArchConfig
    batch: int
    seq: int
    seed: int = 0
    step: int = 0

    def state_dict(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def load_state_dict(self, state: dict) -> None:
        self.seed = int(state["seed"])
        self.step = int(state["step"])

    def peek(self, step: int | None = None) -> dict:
        """Batch for an arbitrary step (pure function — no state change)."""
        step = self.step if step is None else step
        rng = np.random.default_rng((self.seed, step))
        v = self.cfg.vocab
        # Zipf-ish unigram distribution
        ranks = np.arange(1, v + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(v, size=(self.batch, self.seq + 1), p=probs)
        # inject copy spans: tokens repeat 8 positions later (learnable)
        span = self.seq // 4
        if span > 8:
            start = rng.integers(0, self.seq - span - 8)
            toks[:, start + 8 : start + 8 + span] = toks[:, start : start + span]
        toks = toks.astype(np.int32)
        inputs_tok = toks[:, :-1]
        labels = toks[:, 1:]
        if self.cfg.embedded_inputs:
            # stub frontend: embed with a fixed random table (seeded)
            table_rng = np.random.default_rng(self.seed + 7)
            table = table_rng.normal(size=(64, self.cfg.d_model)).astype(np.float32) * 0.05
            inputs = table[inputs_tok % 64]
            inputs = jnp.asarray(inputs, jnp.dtype(self.cfg.dtype))
        else:
            inputs = jnp.asarray(inputs_tok)
        return {"inputs": inputs, "labels": jnp.asarray(labels)}

    def __next__(self) -> dict:
        b = self.peek()
        self.step += 1
        return b

    def __iter__(self):
        return self


@dataclass
class GraphStream:
    """Seeded stream of graph-classification batches (GNN training)."""

    dataset: str
    f_in: int
    n_classes: int
    seed: int = 0
    step: int = 0

    def state_dict(self):
        return {"seed": self.seed, "step": self.step}

    def load_state_dict(self, state):
        self.seed = int(state["seed"])
        self.step = int(state["step"])

    def __next__(self):
        from ..graphs.datasets import load_dataset
        from ..gnn.model import make_node_classification_task

        g, spec = load_dataset(self.dataset, seed=self.seed + self.step)
        x, labels, mask = make_node_classification_task(
            g, self.f_in, self.n_classes, seed=self.seed + self.step
        )
        self.step += 1
        return g, x, labels, mask
