"""``repro.compile()``: one compiler-style front-end over the whole stack.

The paper's thesis is that a *mapper* should pick intra- and inter-phase
dataflows per workload and hand an optimized mapping to a flexible
accelerator.  This module is the stable compilation boundary that composes
every piece the repo already has:

    search (``repro.core.mapper.search_model``)
      -> lower (``ModelSchedule.lower`` -> per-layer ``ExecSpec``)
        -> execute (the kernel registry behind ``repro.gnn``)

behind a single entry point::

    import repro
    program = repro.compile(workloads, graph=g, objective="cycles")
    logits  = program.run(params, x)       # runs the searched schedule
    program.save("model.program.json")     # cacheable compiled artifact

A :class:`Program` is a frozen artifact: the searched
:class:`~repro.core.schedule.ModelSchedule`, the
:class:`~repro.core.hw.AcceleratorConfig` it was priced on, the predicted
:class:`~repro.core.simulator.ModelStats`, and a fingerprint of the
workloads it was compiled for.  ``save``/``load`` round-trip all of that
through byte-stable JSON so serving paths can cache compiled programs and
skip the mapper entirely.
"""
from __future__ import annotations

import json
import os
import zlib
from dataclasses import asdict, dataclass, field, replace
from pathlib import Path
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .core.cost_model import GNNLayerWorkload
from .core.hw import AcceleratorConfig, DEFAULT_ACCEL, HWGrid, LatencyModel
from .core.mapper import TABLE5_NAMES, search_model, search_model_codesign
from .core.registry import get_objective
from .core.schedule import ModelSchedule, TransitionSpec
from .core.simulator import (
    ModelStats,
    RunStats,
    TransitionStats,
    simulate_model,
)
from .gnn.layers import LAYER_FNS, EllAdjacency, init_layer
from .gnn.model import GNNConfig, forward_layers, masked_xent_loss
from .graphs.csr import CSRGraph

#: Artifact schema version.  Bump the suffix whenever the JSON layout of
#: :meth:`Program.to_json` changes incompatibly (new required field,
#: changed schedule encoding, ...).  ``Program.from_json`` rejects any
#: other format string with a ``ValueError`` — deliberately, so a loader
#: can *choose* its forward-compat policy: direct callers see the error,
#: while :class:`repro.runtime.store.ProgramStore` treats it as a cache
#: miss and recompiles, which is how a version bump invalidates every
#: persisted store entry without ever crashing a serving process.
PROGRAM_FORMAT = "repro.program/v1"

#: total number of XLA traces taken by Program executables, process-wide.
#: ``Program.run`` routes through shape-keyed jitted executables, so a
#: second run on a same-shape input (or a same-shape rebind) must leave
#: this counter unchanged — tests and the serving engine assert exactly
#: that.
_TRACE_COUNT = 0


def _note_trace() -> None:
    global _TRACE_COUNT
    _TRACE_COUNT += 1


def trace_count() -> int:
    """Process-wide count of XLA traces taken by ``Program.run``."""
    return _TRACE_COUNT


def workload_fingerprint(workloads: Sequence[GNNLayerWorkload]) -> dict:
    """A compact identity for the graph + layer shapes a Program was
    compiled for: cache keys for compiled artifacts.  The degree vector is
    hashed with crc32 (stable across processes, unlike ``hash``)."""
    first = workloads[0]
    return {
        "v": first.v,
        "e": first.e,
        "nnz_crc32": int(zlib.crc32(np.ascontiguousarray(first.nnz).tobytes())),
        "dims": [[wl.f_in, wl.g_out] for wl in workloads],
    }


# ---------------------------------------------------------------------------
# (De)serialization helpers for the costed stats
# ---------------------------------------------------------------------------


def _stats_to_dict(stats: ModelStats) -> dict:
    return {
        "layers": [asdict(s) for s in stats.layers],
        "transitions": [
            {
                "spec": t.spec.to_dict(),
                "gb_accesses": t.gb_accesses,
                "cycles": t.cycles,
                "energy_pj": t.energy_pj,
            }
            for t in stats.transitions
        ],
    }


def _stats_from_dict(d: dict) -> ModelStats:
    return ModelStats(
        layers=[RunStats(**s) for s in d["layers"]],
        transitions=[
            TransitionStats(
                spec=TransitionSpec.from_dict(t["spec"]),
                gb_accesses=t["gb_accesses"],
                cycles=t["cycles"],
                energy_pj=t["energy_pj"],
            )
            for t in d["transitions"]
        ],
    )


# ---------------------------------------------------------------------------
# Program
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Program:
    """A compiled multiphase GNN: schedule + hardware + predicted cost.

    Frozen artifact of :func:`repro.compile`.  ``run``/``loss`` execute the
    searched schedule through the kernel registry; ``save``/``load``
    round-trip the artifact through byte-stable JSON (schedule, hw,
    predicted stats, workload fingerprint) so a serving path can cache the
    compilation and never re-run the mapper.
    """

    schedule: ModelSchedule
    hw: AcceleratorConfig = DEFAULT_ACCEL
    kind: str = "gcn"  # gcn | sage | gin
    objective: str = "cycles"
    use_pallas: bool = False
    fingerprint: dict = field(default_factory=dict)
    stats: ModelStats | None = field(default=None, compare=False, repr=False)
    #: runtime adjacency binding (set by compile(graph=...) / bind()); not
    #: part of the artifact and never serialized.
    adj: EllAdjacency | None = field(default=None, compare=False, repr=False)
    #: the hw x objective sweep behind a co-searched Program (one
    #: (AcceleratorConfig, objective value) pair per HWGrid point, in grid
    #: order, inf = infeasible); informational, never serialized.
    codesign: list | None = field(default=None, compare=False, repr=False)
    #: shape-keyed jitted executables.  ``bind`` shares this dict across
    #: rebound copies, so serving a stream of same-shape graphs compiles
    #: once and re-traces never (see ``trace_count``).
    _exec_cache: dict = field(
        default_factory=dict, init=False, compare=False, repr=False
    )

    def __post_init__(self):
        if self.kind not in LAYER_FNS:
            raise ValueError(
                f"kind must be one of {tuple(sorted(LAYER_FNS))}, got "
                f"{self.kind!r}"
            )
        get_objective(self.objective)

    # -- views --------------------------------------------------------------
    @property
    def n_layers(self) -> int:
        return self.schedule.n_layers

    @property
    def dims(self) -> list[tuple[int, int]]:
        """(f_in, f_out) per layer, straight off the schedule."""
        return [(l.f_in, l.f_out) for l in self.schedule.layers]

    @property
    def specs(self):
        """The lowered per-layer :class:`ExecSpec` knobs."""
        return self.schedule.lower(use_pallas=self.use_pallas)

    # -- runtime binding ----------------------------------------------------
    def bind(self, graph: CSRGraph, pad_degree: int | None = None) -> "Program":
        """Bind a concrete graph: builds the padded-ELL adjacency with the
        schedule's row grouping.  Returns a new Program (self is frozen).

        ``pad_degree`` fixes the padded-ELL width (the serving engine pads
        every micro-batch of a bucket to the same width).  The rebound
        Program shares this Program's executable cache: rebinding a
        same-shape graph reuses the compiled executable, zero re-tracing.
        """
        bound = replace(
            self,
            adj=EllAdjacency.from_schedule(
                graph, self.schedule, pad_to=pad_degree
            ),
        )
        object.__setattr__(bound, "_exec_cache", self._exec_cache)
        return bound

    def degraded(self, use_pallas: bool = False) -> "Program":
        """A tier-twin of this Program with the kernel family switched
        (``use_pallas``) but the schedule, hardware, stats and adjacency
        binding unchanged — the serving engine's degradation ladder steps
        from the Pallas tier to the jnp registry fallback through this
        without re-running the mapper.  Returns ``self`` when already on
        the requested tier; the twin gets its own executable cache
        (different kernels trace different programs).
        """
        if bool(use_pallas) == self.use_pallas:
            return self
        return replace(self, use_pallas=bool(use_pallas))

    def _require_adj(self) -> EllAdjacency:
        if self.adj is None:
            raise ValueError(
                "Program has no graph bound; compile with graph=... or call "
                "program.bind(graph) before run()/loss()"
            )
        return self.adj

    # -- execution ----------------------------------------------------------
    def init(self, rng: jax.Array):
        """Initialize layer parameters matching the schedule's shapes."""
        keys = jax.random.split(rng, self.n_layers)
        return [
            init_layer(self.kind, k, fi, fo)
            for k, (fi, fo) in zip(keys, self.dims)
        ]

    def _executable(
        self,
        n_nodes: int,
        mesh,
        donate: bool,
        readout: str | None,
        num_segments: int | None,
    ):
        """The shape-keyed jitted forward.  jit's own cache handles the
        per-(array shape, dtype) keying; this dict keys the static closure
        knobs.  ``donate`` donates the feature buffer (serving streams
        never reuse it), a no-op on backends without donation."""
        key = (n_nodes, mesh, donate, readout, num_segments)
        exe = self._exec_cache.get(key)
        if exe is None:
            kind, specs = self.kind, self.specs

            def fwd(params, indices, weights, x, segment_ids):
                _note_trace()
                adj = EllAdjacency(indices, weights, n_nodes)
                return forward_layers(
                    kind, params, adj, x, specs, mesh=mesh,
                    segment_ids=segment_ids if readout is not None else None,
                    num_segments=num_segments,
                    readout=readout or "mean",
                )

            exe = jax.jit(fwd, donate_argnums=(3,) if donate else ())
            self._exec_cache[key] = exe
        return exe

    def run(
        self,
        params,
        x: jax.Array,
        mesh=None,
        *,
        segment_ids=None,
        num_segments: int | None = None,
        readout: str | None = None,
        donate: bool = False,
    ) -> jax.Array:
        """Forward pass under the compiled schedule.

        Returns per-node logits of shape (V, f_out of the last layer) — or,
        with ``segment_ids`` / ``num_segments`` (a batched graph from
        :mod:`repro.graphs.batching`), the (num_segments, f_out) per-graph
        ``readout`` (sum | mean | max, default mean).  Any of the three
        batching kwargs without ``segment_ids`` is an error — there is no
        per-graph readout of an unbatched run.

        Executables are cached per input shape: the second call on a
        same-shape input (including a same-shape :meth:`bind`) performs
        zero re-tracing (see :func:`repro.api.trace_count`).
        """
        adj = self._require_adj()
        if len(params) != self.n_layers:
            raise ValueError(
                f"program has {self.n_layers} layers but params have "
                f"{len(params)}"
            )
        batched = segment_ids is not None
        if batched and num_segments is None:
            raise ValueError("segment_ids needs num_segments")
        if not batched and (num_segments is not None or readout is not None):
            raise ValueError(
                "num_segments/readout need segment_ids (a batched graph)"
            )
        exe = self._executable(
            adj.n_nodes,
            mesh,
            donate,
            (readout or "mean") if batched else None,
            num_segments,
        )
        if not batched:
            segment_ids = jnp.zeros(0, dtype=jnp.int32)  # unused placeholder
        return exe(
            params, adj.indices, adj.weights, x, jnp.asarray(segment_ids)
        )

    def prime(
        self,
        params,
        mesh=None,
        *,
        segment_ids=None,
        num_segments: int | None = None,
        readout: str | None = None,
        donate: bool = False,
    ) -> int:
        """Warm the executable cache for one input shape, off the request
        path: runs :meth:`run` on a zeros feature array of the bound
        graph's shape (same static knobs, so the jitted executable is the
        exact one a later same-shape request will hit) and returns how
        many new XLA traces it took — 0 when the shape was already warm.

        The serving engine's :meth:`~repro.runtime.engine.InferenceEngine.
        precompile` walks the expected bucket grid through this hook at
        startup, so the first *request* of a revived process re-traces
        nothing (see :func:`trace_count`).
        """
        adj = self._require_adj()
        x = jnp.zeros((adj.n_nodes, self.dims[0][0]), jnp.float32)
        before = _TRACE_COUNT
        out = self.run(
            params,
            x,
            mesh,
            segment_ids=segment_ids,
            num_segments=num_segments,
            readout=readout,
            donate=donate,
        )
        jax.block_until_ready(out)
        return _TRACE_COUNT - before

    def loss(self, params, x, labels, mask, mesh=None):
        """Masked softmax cross-entropy over :meth:`run`'s logits."""
        return masked_xent_loss(self.run(params, x, mesh=mesh), labels, mask)

    @property
    def schedule_digest(self) -> str:
        """Stable identity of the compiled schedule content (see
        :meth:`ModelSchedule.digest`) — the key under which the serving
        engine attributes measured wall-clock observations."""
        return self.schedule.digest()

    def _train_executable(self, n_nodes: int, mesh, lr: float):
        """Shape-keyed jitted SGD step, cached alongside the forward
        executables (same sharing semantics as :meth:`_executable`)."""
        key = ("train", n_nodes, mesh, lr)
        exe = self._exec_cache.get(key)
        if exe is None:
            kind, specs = self.kind, self.specs

            def step(params, indices, weights, x, labels, mask):
                _note_trace()
                adj = EllAdjacency(indices, weights, n_nodes)

                def loss_fn(p):
                    h = forward_layers(kind, p, adj, x, specs, mesh=mesh)
                    return masked_xent_loss(h, labels, mask)

                l, grads = jax.value_and_grad(loss_fn)(params)
                new = jax.tree_util.tree_map(
                    lambda a, g: a - lr * g, params, grads
                )
                return l, new

            exe = jax.jit(step)
            self._exec_cache[key] = exe
        return exe

    def train_step(self, params, x, labels, mask, *, lr: float = 0.05, mesh=None):
        """One fused SGD step (loss, grad, parameter update) under the
        compiled schedule; returns ``(loss, new_params)``.

        The step executable lives in the Program's shared cache keyed by
        ``(shape, lr, mesh)``: later epochs — and same-shape rebinds — take
        zero new XLA traces (``examples/train_gnn_dataflow.py`` asserts
        exactly that via :func:`trace_count`).
        """
        adj = self._require_adj()
        exe = self._train_executable(adj.n_nodes, mesh, float(lr))
        return exe(params, adj.indices, adj.weights, x, labels, mask)

    # -- artifact -----------------------------------------------------------
    def to_json(self) -> str:
        """Canonical (sorted-keys, 2-space indent) JSON artifact; stable
        bytes across save/load/save."""
        payload = {
            "format": PROGRAM_FORMAT,
            "kind": self.kind,
            "objective": self.objective,
            "use_pallas": self.use_pallas,
            "fingerprint": self.fingerprint,
            "hw": asdict(self.hw),
            "schedule": json.loads(self.schedule.to_json(indent=None)),
            "stats": None if self.stats is None else _stats_to_dict(self.stats),
        }
        return json.dumps(payload, indent=2, sort_keys=True) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "Program":
        d = json.loads(text)
        if d.get("format") != PROGRAM_FORMAT:
            raise ValueError(
                f"not a {PROGRAM_FORMAT} artifact "
                f"(format={d.get('format')!r})"
            )
        stats = None if d["stats"] is None else _stats_from_dict(d["stats"])
        return cls(
            schedule=ModelSchedule.from_json(json.dumps(d["schedule"])),
            hw=AcceleratorConfig.from_dict(d["hw"]),
            kind=d["kind"],
            objective=d["objective"],
            use_pallas=d["use_pallas"],
            fingerprint=d["fingerprint"],
            stats=stats,
        )

    def save(self, path) -> Path:
        """Write the artifact atomically; returns the path.

        The JSON lands in a temp file in the same directory and is moved
        into place with ``os.replace``, so a crash (or injected failure)
        mid-write can never leave a truncated artifact at ``path`` — a
        reader sees either the previous complete artifact or the new one.
        """
        p = Path(path)
        tmp = p.with_name(p.name + f".tmp.{os.getpid()}")
        try:
            tmp.write_text(self.to_json())
            os.replace(tmp, p)
        finally:
            tmp.unlink(missing_ok=True)
        return p

    @classmethod
    def load(cls, path, graph: CSRGraph | None = None) -> "Program":
        """Load a saved artifact; with ``graph``, also bind the adjacency
        (after checking the graph against the compiled fingerprint)."""
        prog = cls.from_json(Path(path).read_text())
        if graph is not None:
            fp = prog.fingerprint
            if fp:
                crc = int(
                    zlib.crc32(np.ascontiguousarray(graph.nnz).tobytes())
                )
                if graph.n_nodes != fp["v"]:
                    raise ValueError(
                        f"graph does not match the program's compiled "
                        f"fingerprint: V={graph.n_nodes} vs compiled "
                        f"V={fp['v']}"
                    )
                if crc != fp["nnz_crc32"]:
                    raise ValueError(
                        f"graph does not match the program's compiled "
                        f"fingerprint: same V={fp['v']} but the degree "
                        f"vector differs (nnz crc32 {crc} vs "
                        f"{fp['nnz_crc32']})"
                    )
            prog = prog.bind(graph)
        return prog

    def __str__(self) -> str:
        head = (
            f"Program(kind={self.kind}, objective={self.objective}, "
            f"layers={self.n_layers}"
        )
        if self.stats is not None:
            head += (
                f", predicted {self.stats.cycles:.0f} cycles / "
                f"{self.stats.energy_pj / 1e6:.1f} uJ"
            )
        return head + ")\n" + str(self.schedule)


# ---------------------------------------------------------------------------
# compile()
# ---------------------------------------------------------------------------


def _resolve_workloads(
    target, graph: CSRGraph | None
) -> tuple[list[GNNLayerWorkload], GNNConfig | None]:
    """``target`` is either a GNNConfig (needs a graph for the degree
    vector) or an explicit per-layer workload sequence."""
    if isinstance(target, GNNConfig):
        if graph is None:
            raise ValueError(
                "compiling from a GNNConfig needs graph=... (the workload's "
                "degree vector comes from the graph)"
            )
        wls = [
            GNNLayerWorkload(graph.nnz, fi, fo, name=f"layer{i}")
            for i, (fi, fo) in enumerate(target.dims)
        ]
        return wls, target
    wls = list(target)
    if not wls:
        raise ValueError("need at least one layer workload")
    for wl in wls:
        if not isinstance(wl, GNNLayerWorkload):
            raise TypeError(
                f"compile() takes a GNNConfig or a sequence of "
                f"GNNLayerWorkload, got {type(wl).__name__}"
            )
    return wls, None


def _select_hw(
    objs: list[float], costs, hw_selection: str
) -> int:
    """Pick the winning grid point of a co-search.

    ``"objective"`` minimizes the objective outright (ties: cheapest
    hw-cost proxy); ``"objective_x_cost"`` minimizes objective x
    (n_pes x gb_bandwidth) — the provisioning-aware knee of the joint
    Pareto curve.
    """
    objs = np.asarray(objs, dtype=np.float64)
    costs = np.asarray(costs, dtype=np.float64)
    if not np.isfinite(objs).any():
        raise RuntimeError("no hardware grid point admits a legal mapping")
    if hw_selection == "objective":
        key = np.where(np.isfinite(objs), costs, np.inf)
        order = np.lexsort((key, objs))
    elif hw_selection == "objective_x_cost":
        prod = objs * costs
        order = np.lexsort((objs, prod))
    else:
        raise ValueError(
            f"hw_selection must be 'objective' or 'objective_x_cost', "
            f"got {hw_selection!r}"
        )
    return int(order[0])


def _reprice_schedule(schedule, hw, stats):
    """An explicit schedule handed to compile() may record the hw (and
    stats, down to the per-layer RunStats) it was originally searched on —
    or none at all; after re-pricing, every recorded quantity must agree
    with the chosen config."""
    if schedule.hw == hw and schedule.stats is stats:
        return schedule  # fresh from the search on this very hw
    return replace(
        schedule,
        hw=hw,
        stats=stats,
        layers=tuple(
            replace(l, stats=s)
            for l, s in zip(schedule.layers, stats.layers)
        ),
    )


def compile(
    target,
    graph: CSRGraph | None = None,
    hw: AcceleratorConfig | HWGrid = DEFAULT_ACCEL,
    *,
    objective: str = "cycles",
    schedule: ModelSchedule | None = None,
    kind: str | None = None,
    use_pallas: bool | None = None,
    names: tuple[str, ...] = TABLE5_NAMES,
    pe_splits: tuple[float, ...] = (0.25, 0.5, 0.75),
    top_k: int = 4,
    hw_selection: str = "objective",
    latency_model: LatencyModel | None = None,
) -> Program:
    """Search -> lower -> package: the one entry point over the mapper.

    ``target`` is either a :class:`~repro.gnn.GNNConfig` (layer shapes from
    its ``dims``; degree vector from ``graph``) or an explicit sequence of
    :class:`~repro.core.cost_model.GNNLayerWorkload`.  Unless a
    ``schedule`` is passed, the model-level mapper
    (:func:`~repro.core.mapper.search_model`) picks one dataflow per layer
    by dynamic programming over inter-layer transition costs; an explicit
    ``schedule`` skips the search (it is validated against the workload
    shapes and priced with :func:`simulate_model` if it carries no stats).

    ``hw`` may be an :class:`~repro.core.hw.HWGrid`: compile then runs the
    hardware x dataflow co-search (:func:`search_model_codesign` — the
    model-level DP re-prices transition costs at every grid point, sharing
    tile caches), picks the winner per ``hw_selection`` and freezes the
    chosen :class:`AcceleratorConfig` into the Program and its artifact;
    the full sweep stays inspectable on ``program.codesign``.  With an
    explicit ``schedule``, the grid re-prices that schedule at every point
    and picks the hardware the same way.

    Returns a frozen :class:`Program`; with ``graph`` given, the program is
    already bound and ``program.run(params, x)`` executes immediately.

    ``latency_model`` installs a fitted :class:`LatencyModel` (see
    :mod:`repro.core.calibrate`) into the pricing config before any search
    or re-pricing runs, so candidate ranking uses calibrated cycles.  When
    omitted, the ``REPRO_LATENCY_MODEL`` environment variable may point at
    a fitted artifact; otherwise the identity (paper-constant) model is
    used.
    """
    get_objective(objective)
    if latency_model is None:
        latency_model = LatencyModel.from_env()
    if latency_model is not None:
        if isinstance(hw, HWGrid):
            hw = replace(hw, base=replace(hw.base, latency=latency_model))
        else:
            hw = replace(hw, latency=latency_model)
    if hw_selection not in ("objective", "objective_x_cost"):
        # fail before any (expensive) search runs
        raise ValueError(
            f"hw_selection must be 'objective' or 'objective_x_cost', "
            f"got {hw_selection!r}"
        )
    workloads, cfg = _resolve_workloads(target, graph)
    if kind is None:
        kind = cfg.kind if cfg is not None else "gcn"
    if use_pallas is None:
        use_pallas = cfg.use_pallas if cfg is not None else False

    if schedule is not None:
        want = [(wl.f_in, wl.g_out) for wl in workloads]
        have = [(l.f_in, l.f_out) for l in schedule.layers]
        if want != have:
            raise ValueError(
                f"schedule layer shapes {have} do not match the workload "
                f"shapes {want}"
            )

    codesign_log = None
    if isinstance(hw, HWGrid):
        grid = hw
        if schedule is None:
            schedules = search_model_codesign(
                workloads,
                grid,
                objective=objective,
                names=names,
                pe_splits=pe_splits,
                top_k=top_k,
            )
            objs = [
                float("inf") if s is None else s.stats.objective(objective)
                for s in schedules
            ]
            i = _select_hw(objs, grid.hw_cost(), hw_selection)
            schedule = schedules[i]
            stats = schedule.stats
        else:
            stats_per = []
            for cfg_i in grid.configs():
                try:
                    stats_per.append(
                        simulate_model(schedule.dataflows, workloads, cfg_i)
                    )
                except ValueError:  # e.g. PE budget violated at this point
                    stats_per.append(None)
            objs = [
                float("inf") if s is None else s.objective(objective)
                for s in stats_per
            ]
            i = _select_hw(objs, grid.hw_cost(), hw_selection)
            stats = stats_per[i]
        codesign_log = list(zip(grid.configs(), objs))
        hw = grid.configs()[i]
        schedule = _reprice_schedule(schedule, hw, stats)
    elif schedule is None:
        schedule = search_model(
            workloads,
            hw,
            objective=objective,
            names=names,
            pe_splits=pe_splits,
            top_k=top_k,
        )
        stats = schedule.stats  # priced by the search on this hw
    else:
        # an explicit schedule may carry stats (and a recorded hw) from a
        # *different* config; always re-price on the given one so the
        # artifact's hw, schedule.hw and predicted stats agree.
        stats = simulate_model(schedule.dataflows, workloads, hw)
        schedule = _reprice_schedule(schedule, hw, stats)

    prog = Program(
        schedule=schedule,
        hw=hw,
        kind=kind,
        objective=objective,
        use_pallas=use_pallas,
        fingerprint=workload_fingerprint(workloads),
        stats=stats,
        codesign=codesign_log,
    )
    return prog.bind(graph) if graph is not None else prog
