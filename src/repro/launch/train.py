"""Training launcher.

CPU smoke / single host:
    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
        --steps 30 --batch 4 --seq 64 --checkpoint-dir /tmp/ckpt

Production invocation (TPU pod; identical code path — the mesh grows):
    python -m repro.launch.train --arch granite-8b --steps 100000 \
        --batch 256 --seq 4096 --model-parallel 16 \
        --checkpoint-dir gs://.../ckpt --grad-compression int8

Features: deterministic resumable data stream, atomic checkpoints +
auto-resume, retrying step runner with straggler monitor, optional
int8 error-feedback gradient compression on the DP all-reduce, ZeRO-1
sharded optimizer state (on multi-device meshes).
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.checkpointer import Checkpointer
from ..configs import ARCH_IDS, get_config
from ..data.pipeline import LMDataPipeline
from ..models import (
    init_params,
    lm_loss,
    param_shardings,
    production_rules,
    use_sharding,
)
from ..models.sharding import ShardingRules
from ..optim import adamw, compress_grads, decompress_grads, init_error_feedback
from ..optim.schedule import warmup_cosine
from ..runtime.fault_tolerance import ResilientRunner, StragglerMonitor
from .mesh import make_mesh_for

log = logging.getLogger("repro.train")


def build_trainer(cfg, mesh, rules, lr=3e-4, total_steps=10_000,
                  grad_compression: str | None = None):
    init_opt, update = adamw(lr=warmup_cosine(lr, min(100, total_steps // 10 + 1), total_steps))

    def loss_fn(p, batch):
        return lm_loss(cfg, p, batch)

    def step_fn(params, opt, ef, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if grad_compression == "int8":
            q, ef = compress_grads(grads, ef)
            grads = decompress_grads(q)
        params, opt = update(grads, opt, params)
        return loss, params, opt, ef

    return init_opt, jax.jit(step_fn, donate_argnums=(0, 1, 2))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", help="smoke-scale config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--grad-compression", choices=["int8"], default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    n_dev = len(jax.devices())
    mesh = make_mesh_for(n_dev, args.model_parallel) if n_dev > 1 else None
    rules = (
        ShardingRules(batch=("data",), heads="model", d_ff="model",
                      experts="model", vocab="model")
        if mesh is not None
        else None
    )

    data = LMDataPipeline(cfg, args.batch, args.seq, seed=args.seed)
    ckpt = Checkpointer(args.checkpoint_dir) if args.checkpoint_dir else None

    with use_sharding(mesh, rules):
        params = init_params(cfg, jax.random.PRNGKey(args.seed))
        if mesh is not None:
            shardings = param_shardings(params, mesh, rules)
            params = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), params, shardings
            )
        init_opt, step_fn = build_trainer(
            cfg, mesh, rules, lr=args.lr, total_steps=args.steps,
            grad_compression=args.grad_compression,
        )
        opt = init_opt(params)
        ef = init_error_feedback(params) if args.grad_compression else None

        start_step = 0
        if ckpt and ckpt.latest_step() is not None:
            state = ckpt.restore({"params": params, "opt": opt, "data": data.state_dict()})
            params, opt = state["params"], state["opt"]
            data.load_state_dict(state["data"])
            start_step = data.step
            log.info("resumed from step %d", start_step)

        def run_step(state, batch):
            params, opt, ef = state
            loss, params, opt, ef = step_fn(params, opt, ef, batch)
            return (params, opt, ef), {"loss": float(loss)}

        def save(step, state):
            if ckpt:
                params, opt, ef = state
                data.step = step
                ckpt.save(step, {"params": params, "opt": opt, "data": data.state_dict()})

        def restore():
            state = ckpt.restore({"params": params, "opt": opt, "data": data.state_dict()})
            data.load_state_dict(state["data"])
            return data.step, (state["params"], state["opt"], ef)

        runner = ResilientRunner(
            step_fn=run_step,
            save_fn=save,
            restore_fn=restore if ckpt else (lambda: (_ for _ in ()).throw(RuntimeError("no ckpt"))),
            checkpoint_every=args.checkpoint_every,
            monitor=StragglerMonitor(),
        )

        t0 = time.time()
        state, metrics = runner.run(
            (params, opt, ef), lambda s: data.peek(s), start_step, args.steps - start_step
        )
        dt = time.time() - t0
        losses = [m["loss"] for m in metrics]
        if losses:
            log.info(
                "steps=%d first_loss=%.4f last_loss=%.4f wall=%.1fs (%.2f s/step)",
                len(losses), losses[0], losses[-1], dt, dt / max(len(losses), 1),
            )
            print(f"FINAL loss={losses[-1]:.4f} first={losses[0]:.4f} steps={len(losses)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
