"""Batched serving driver: prefill a batch of prompts, decode new tokens.

CPU smoke:
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
        --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..models import decode_step, forward, init_cache, init_params, make_inputs
from ..models.transformer import prefill


def generate(cfg, params, prompts, new_tokens: int, greedy: bool = True, rng=None):
    """prompts: (B, S) tokens (or (B, S, d) embeddings for stub frontends).
    Returns (B, new_tokens) sampled token ids and per-step latencies."""
    b = prompts.shape[0]
    s = prompts.shape[1]
    total = s + new_tokens
    logits, _ = forward(cfg, params, prompts)
    cache = init_cache(cfg, b, total)
    # replay the prompt through decode steps to build the cache
    for t in range(s):
        tok = prompts[:, t : t + 1]
        _, cache = jax.jit(
            lambda c, tk, i: decode_step(cfg, params, c, tk, i),
            static_argnums=(),
        )(cache, tok, t)
    next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)

    dstep = jax.jit(lambda c, tk, i: decode_step(cfg, params, c, tk, i))
    out_tokens = []
    lat = []
    rng = rng or jax.random.PRNGKey(0)
    for i in range(new_tokens):
        t0 = time.perf_counter()
        if cfg.embedded_inputs:
            # stub frontends decode in embedding space with a fixed table
            table = jax.random.normal(jax.random.PRNGKey(7), (64, cfg.d_model)) * 0.05
            tok_in = table[next_tok[:, 0] % 64][:, None].astype(jnp.dtype(cfg.dtype))
        else:
            tok_in = next_tok
        logits, cache = dstep(cache, tok_in, s + i)
        if greedy:
            next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        else:
            rng, k = jax.random.split(rng)
            next_tok = jax.random.categorical(k, logits[:, -1])[:, None].astype(jnp.int32)
        out_tokens.append(next_tok)
        lat.append(time.perf_counter() - t0)
    return jnp.concatenate(out_tokens, axis=1), lat


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    if cfg.embedded_inputs:
        prompts = make_inputs(cfg, args.batch, args.prompt_len, seed=args.seed)
    else:
        prompts = make_inputs(cfg, args.batch, args.prompt_len, seed=args.seed)
    toks, lat = generate(cfg, params, prompts, args.new_tokens)
    print(f"generated {toks.shape} tokens; sample row: {np.asarray(toks[0])[:12]}")
    print(
        f"decode latency: first={lat[0]*1e3:.1f}ms "
        f"steady={np.median(lat[1:])*1e3 if len(lat) > 1 else 0:.1f}ms"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
