"""Production mesh construction (assignment spec).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to build the 16x16 (single-pod) and 2x16x16 (two-pod) meshes on
CPU placeholder devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_mesh_for(devices: int, model_parallel: int = 1) -> jax.sharding.Mesh:
    """Elastic helper: (data, model) mesh over an arbitrary device count
    (used by the trainer and the elastic-restore tests)."""
    assert devices % model_parallel == 0, (devices, model_parallel)
    return jax.make_mesh(
        (devices // model_parallel, model_parallel),
        ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
