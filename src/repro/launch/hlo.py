"""HLO-text analysis: collective-communication byte accounting.

``compiled.cost_analysis()`` reports FLOPs and memory bytes but not
collective traffic, so we parse the (per-device) HLO module text and sum
the operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op (assignment §Roofline).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
# match " = <shape(s)> <opcode>(" with optional -start/-done suffixes
_OP_RE = re.compile(
    r"=\s+(?P<result>.*?)\s+(?P<op>"
    + "|".join(_COLLECTIVES)
    + r")(?:-start|-done)?\((?P<args>.*)$"
)
# replica_groups=[G,P]<=[N] — P participants per group
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def shape_bytes(text: str) -> int:
    """Total bytes of every dtype[dims] shape literal in `text`."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: dict[str, int] = field(default_factory=dict)  # operand bytes
    link_bytes_by_op: dict[str, int] = field(default_factory=dict)  # wire traffic
    count_by_op: dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_link_bytes(self) -> int:
        return sum(self.link_bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Per-collective byte accounting over a (per-device) HLO module.

    Post-optimization HLO prints operands without shapes, so sizes are
    derived from the *result* shape plus the replica-group participant
    count P (``replica_groups=[G,P]``):

      operand bytes:  all-gather = result/P; reduce-scatter = result*P;
                      all-reduce / all-to-all / permute = result.
      link bytes (ring-algorithm wire traffic per device):
                      all-gather & reduce-scatter = operand*(P-1);
                      all-reduce = 2*operand*(P-1)/P;
                      all-to-all = operand*(P-1)/P; permute = operand.

    ``-done`` ops are skipped (the matching ``-start`` already counted).
    Loop bodies are counted once — the dry-run scales by trip counts.
    """
    stats = CollectiveStats(defaultdict(int), defaultdict(int), defaultdict(int))
    for line in hlo_text.splitlines():
        if "-done(" in line or " = " not in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        result = shape_bytes(m.group("result"))
        gm = _GROUP_RE.search(line)
        p = int(gm.group(2)) if gm else 1
        if op == "all-gather":
            operand = result // max(p, 1)
            link = operand * (p - 1)
        elif op == "reduce-scatter":
            operand = result * p
            link = result * (p - 1)
        elif op == "all-reduce":
            operand = result
            link = int(2 * operand * (p - 1) / max(p, 1))
        elif op == "all-to-all":
            operand = result
            link = int(operand * (p - 1) / max(p, 1))
        else:  # collective-permute
            operand = result
            link = operand
        stats.bytes_by_op[op] += operand
        stats.link_bytes_by_op[op] += link
        stats.count_by_op[op] += 1
    stats.bytes_by_op = dict(stats.bytes_by_op)
    stats.link_bytes_by_op = dict(stats.link_bytes_by_op)
    stats.count_by_op = dict(stats.count_by_op)
    return stats


_WHILE_TRIP_RE = re.compile(
    r"trip_count[\"']?\s*[:=]\s*[\{\"']*n?[\"']?\s*[:=]?\s*[\"']?(\d+)"
)


def while_trip_counts(hlo_text: str) -> list[int]:
    """Trip counts of while loops (scanned layers) from backend_config
    annotations, e.g. ``backend_config={"known_trip_count":{"n":"30"}}``."""
    out = []
    for line in hlo_text.splitlines():
        if "while(" not in line:
            continue
        m = _WHILE_TRIP_RE.search(line)
        if m:
            out.append(int(m.group(1)))
    return out


# ---------------------------------------------------------------------------
# Execution-count-aware accounting (collectives inside scanned layers run
# trip_count times per step; the gradient all-reduce runs once)
# ---------------------------------------------------------------------------

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(")
_WHILE_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_WHILE_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    """Computation name -> instruction lines.  Header lines look like
    ``%region_0.1_spmd (param: (...)) -> (...) {`` (ENTRY-prefixed for
    main); instruction lines are indented."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not line.startswith(" ") and "{" in line and "->" in line:
            m = _COMP_RE.match(stripped.removeprefix("ENTRY ").strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is not None and stripped and stripped != "}":
            comps[cur].append(stripped)
    return comps


def execution_counts(hlo_text: str) -> dict[str, int]:
    """Execution multiplier per computation: product of enclosing while
    trip counts (nested scans multiply).  Computations not reached from a
    while body have multiplier 1."""
    comps = _split_computations(hlo_text)
    # while ops: (parent_comp, body_comp, trips)
    edges: list[tuple[str, str, int]] = []
    for parent, lines in comps.items():
        for line in lines:
            if "while(" not in line:
                continue
            bm = _WHILE_BODY_RE.search(line)
            tm = _WHILE_TRIP_RE.search(line)
            trips = int(tm.group(1)) if tm else 1
            if bm:
                edges.append((parent, bm.group(1), trips))
                cm = _WHILE_COND_RE.search(line)
                if cm:
                    edges.append((parent, cm.group(1), trips))
    mult = {name: 1 for name in comps}
    # propagate multipliers down the while-nesting DAG (few levels deep)
    for _ in range(8):
        changed = False
        for parent, body, trips in edges:
            want = mult.get(parent, 1) * trips
            if mult.get(body, 1) < want:
                mult[body] = want
                changed = True
        if not changed:
            break
    return mult


def collective_bytes_scaled(hlo_text: str) -> CollectiveStats:
    """Like :func:`collective_bytes` but weighting each collective by its
    computation's execution count (scan trip products)."""
    comps = _split_computations(hlo_text)
    mult = execution_counts(hlo_text)
    stats = CollectiveStats(defaultdict(int), defaultdict(int), defaultdict(int))
    for comp, lines in comps.items():
        m_c = mult.get(comp, 1)
        for line in lines:
            if "-done(" in line or " = " not in line:
                continue
            m = _OP_RE.search(line)
            if not m:
                continue
            op = m.group("op")
            result = shape_bytes(m.group("result"))
            gm = _GROUP_RE.search(line)
            p = int(gm.group(2)) if gm else 1
            if op == "all-gather":
                operand = result // max(p, 1)
                link = operand * (p - 1)
            elif op == "reduce-scatter":
                operand = result * p
                link = result * (p - 1)
            elif op == "all-reduce":
                operand = result
                link = int(2 * operand * (p - 1) / max(p, 1))
            elif op == "all-to-all":
                operand = result
                link = int(operand * (p - 1) / max(p, 1))
            else:
                operand = result
                link = operand
            stats.bytes_by_op[op] += operand * m_c
            stats.link_bytes_by_op[op] += link * m_c
            stats.count_by_op[op] += m_c
    stats.bytes_by_op = dict(stats.bytes_by_op)
    stats.link_bytes_by_op = dict(stats.link_bytes_by_op)
    stats.count_by_op = dict(stats.count_by_op)
    return stats
