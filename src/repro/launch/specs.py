"""ShapeDtypeStruct stand-ins for every model input (dry-run, no
allocation) plus the in/out sharding assignments for each step kind."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.shapes import ShapeSuite
from ..models import (
    ShardingRules,
    init_cache,
    init_params,
    param_shardings,
)
from ..models.config import ArchConfig
from ..optim import adamw


def batch_specs(cfg: ArchConfig, shape: ShapeSuite) -> dict:
    """Training/prefill batch as ShapeDtypeStructs."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.embedded_inputs:
        inputs = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.dtype(cfg.dtype))
    else:
        inputs = jax.ShapeDtypeStruct((b, s), jnp.int32)
    out = {"inputs": inputs}
    if shape.kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return out


def decode_specs(cfg: ArchConfig, shape: ShapeSuite) -> dict:
    """Decode step inputs: one token + the full KV/state cache."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.embedded_inputs:
        tokens = jax.ShapeDtypeStruct((b, 1, cfg.d_model), jnp.dtype(cfg.dtype))
    else:
        tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
    index = jax.ShapeDtypeStruct((), jnp.int32)
    return {"tokens": tokens, "cache": cache, "index": index}


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def abstract_opt_state(cfg: ArchConfig, params_abs):
    init_opt, _ = adamw()
    return jax.eval_shape(init_opt, params_abs)


# ---------------------------------------------------------------------------
# Sharding assignments
# ---------------------------------------------------------------------------


def _dp(rules: ShardingRules):
    return rules.batch


def batch_shardings(cfg, shape, mesh, rules: ShardingRules):
    dp = _dp(rules)
    if cfg.embedded_inputs:
        inp = NamedSharding(mesh, P(dp, None, None))
    else:
        inp = NamedSharding(mesh, P(dp, None))
    out = {"inputs": inp}
    if shape.kind == "train":
        out["labels"] = NamedSharding(mesh, P(dp, None))
    return out


def _zero1_spec(spec: P, shape: tuple, mesh, rules: ShardingRules) -> P:
    """ZeRO-1: additionally shard optimizer-state leaves over the data
    axes on the first dimension that is unsharded and divisible."""
    dp = _dp(rules)
    if dp is None:
        return spec
    dp_axes = dp if isinstance(dp, tuple) else (dp,)
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    specs = list(spec) + [None] * (len(shape) - len(spec))
    for i, (dim, s) in enumerate(zip(shape, specs)):
        if s is None and dim % dp_size == 0 and dim >= dp_size:
            specs[i] = dp
            return P(*specs)
    return spec


def opt_shardings(cfg, params_abs, opt_abs, mesh, rules: ShardingRules):
    """Optimizer-state shardings: params' TP sharding + ZeRO-1 over DP."""
    pshard = param_shardings(params_abs, mesh, rules)

    def zero1(ns: NamedSharding, leaf):
        return NamedSharding(mesh, _zero1_spec(ns.spec, leaf.shape, mesh, rules))

    m_shard = jax.tree_util.tree_map(zero1, pshard, params_abs)
    from ..optim import AdamWState

    return AdamWState(
        step=NamedSharding(mesh, P()),
        m=m_shard,
        v=m_shard,
    )


def cache_shardings(cfg, cache_abs, mesh, rules: ShardingRules):
    """KV/state cache shardings.

    KV caches (stacked (L, B, S, H, D)): batch over the DP axes; the
    sequence dim over the model axis (flash-decoding style partial
    attention — kv heads may be fewer than the model-axis size, sequence
    always divides it).  Recurrent states (B, ...): batch over DP only.
    """
    dp = _dp(rules)
    model = rules.heads

    def one(leaf):
        shp = leaf.shape
        dp_axes = dp if isinstance(dp, tuple) else ((dp,) if dp else ())
        dp_size = 1
        for a in dp_axes:
            dp_size *= mesh.shape[a]
        if len(shp) == 5:  # stacked KV: (L, B, S, H, D)
            spec = [None] * 5
            if dp and shp[1] % dp_size == 0:
                spec[1] = dp
            if model and shp[2] % mesh.shape[model] == 0 and shp[2] >= mesh.shape[model]:
                spec[2] = model
            return NamedSharding(mesh, P(*spec))
        if len(shp) >= 2:  # stacked recurrent state: (L, B, ...)
            spec = [None] * len(shp)
            if dp and shp[1] % dp_size == 0:
                spec[1] = dp
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(one, cache_abs)
