"""Roofline-term derivation from the compiled dry-run artifact.

Per (arch x shape x mesh) cell (assignment §Roofline):

    compute term    = FLOPs / (chips x peak_FLOP/s)
    memory term     = HBM bytes / (chips x HBM_bw)
    collective term = collective bytes / (chips x link_bw)

``compiled.cost_analysis()`` / ``compiled.as_text()`` describe ONE
device's partitioned module, so the chip count cancels inside each term.

Because XLA cost analysis counts scan (while) bodies once (see
repro.launch.analytic), the compute term uses exact ANALYTIC FLOPs; the
HLO numbers, scaled by the scan trip count, are kept as a cross-check and
as the memory/collective sources (memory additionally floored by the
analytic parameter/optimizer/cache traffic).
"""
from __future__ import annotations

from ..core.hw import TPU_V5E
from ..models.config import ArchConfig
from .analytic import cell_flops, cell_hbm_floor_bytes


def model_flops(cfg: ArchConfig, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) canonical model FLOPs."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def _scan_scale(result: dict, cfg: ArchConfig) -> float:
    """Trip-count multiplier for once-counted while bodies (layer scan)."""
    trips = [t for t in result.get("while_trip_counts", []) if t > 1]
    if not trips:
        return 1.0
    reps = max(cfg.n_layers // len(cfg.block_pattern), 1)
    return float(reps) if reps in trips else float(max(trips))


def roofline_report(cfg: ArchConfig, shape, result: dict) -> dict:
    chips = result["n_chips"]
    model_shards = 16  # the "model" mesh axis of both production meshes
    scale = _scan_scale(result, cfg)

    flops_global = cell_flops(cfg, shape)
    flops_dev = flops_global / chips
    hlo_flops_scaled = result["cost"]["flops_per_device"] * scale

    # memory: analytic HBM traffic model (params/opt/cache/activations);
    # raw HLO bytes (entry-level, scan bodies once) kept for reference
    bytes_dev = cell_hbm_floor_bytes(cfg, shape, chips, model_shards)
    # collectives are already execution-count weighted by the HLO parser
    coll_dev = result["collectives"].get(
        "link_bytes_per_device", result["collectives"]["total_bytes_per_device"]
    )

    t_compute = flops_dev / TPU_V5E.peak_bf16_flops
    t_memory = bytes_dev / TPU_V5E.hbm_bandwidth
    t_collective = coll_dev / TPU_V5E.ici_link_bandwidth

    terms = {"compute": t_compute, "memory": t_memory, "collective": t_collective}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(cfg, shape)

    return {
        "scan_scale_applied": scale,
        "compute_term_s": t_compute,
        "memory_term_s": t_memory,
        "collective_term_s": t_collective,
        "dominant_term": dominant,
        "bound_s": bound,
        "analytic_flops_global": flops_global,
        "hlo_flops_scaled_global": hlo_flops_scaled * chips,
        "model_flops_global": mf,
        "useful_flops_ratio": mf / max(flops_global, 1.0),
        "hbm_bytes_per_device": bytes_dev,
        "collective_link_bytes_per_device": coll_dev,
        # fraction of the compute roofline achieved if the dominant term
        # set the runtime — the score the perf loop pushes up
        "roofline_fraction": t_compute / max(bound, 1e-30),
    }


def format_table(results: list[dict]) -> str:
    rows = []
    hdr = (
        f"{'arch':24s} {'shape':12s} {'mesh':10s} {'compute_s':>11s} "
        f"{'memory_s':>11s} {'collect_s':>11s} {'bound':>10s} "
        f"{'RF':>6s} {'useful':>7s}"
    )
    rows.append(hdr)
    rows.append("-" * len(hdr))
    for r in results:
        if r.get("skipped"):
            rows.append(f"{r['arch']:24s} {r['shape']:12s} SKIP ({r['reason']})")
            continue
        rf = r["roofline"]
        rows.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:10s} "
            f"{rf['compute_term_s']:11.5f} {rf['memory_term_s']:11.5f} "
            f"{rf['collective_term_s']:11.5f} {rf['dominant_term']:>10s} "
            f"{rf['roofline_fraction']:6.2f} {rf['useful_flops_ratio']:7.2f}"
        )
    return "\n".join(rows)
