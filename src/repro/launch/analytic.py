"""Analytic FLOP/byte accounting per (arch x shape) cell.

XLA's ``cost_analysis`` counts while-loop bodies once (verified in
tests/test_dryrun.py::test_cost_analysis_counts_scan_body_once), and our
models scan over layers, so raw HLO numbers underestimate by the trip
count.  The roofline therefore uses:

  * compute term — ANALYTIC FLOPs (exactly derivable: we know every GEMM,
    attention-score and recurrence op in the model), cross-checked against
    trip-count-scaled HLO FLOPs;
  * memory term — max(scaled HLO bytes, an analytic HBM floor of
    parameter + optimizer + cache + activation traffic);
  * collective term — per-layer HLO link bytes x layer trip count.
"""
from __future__ import annotations

from ..configs.shapes import ShapeSuite
from ..models.config import ArchConfig

BF16 = 2
F32 = 4


def _attn_proj_macs(cfg: ArchConfig) -> float:
    hd = cfg.head_dim
    return cfg.d_model * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + (
        cfg.n_heads * hd * cfg.d_model
    )


def layer_macs_per_token(cfg: ArchConfig, kind: str, ctx: float) -> float:
    """Forward MACs per token for one block of the given kind.

    ``ctx`` is the average attended context length (S/2 for causal
    training, min(window, S) for local attention, the cache length for
    decode)."""
    d, hd, h = cfg.d_model, cfg.head_dim, cfg.n_heads
    if kind in ("attn", "local", "moe"):
        macs = _attn_proj_macs(cfg)
        macs += 2.0 * h * hd * ctx  # QK^T + PV
        if kind == "moe":
            macs += d * cfg.moe.n_experts  # router
            macs += 3.0 * d * cfg.d_ff * cfg.moe.top_k  # active experts
        else:
            macs += 3.0 * d * cfg.d_ff
        return macs
    if kind == "rglru":
        r = cfg.rnn_width
        macs = 3.0 * d * r  # in / gate / out projections
        macs += cfg.conv_width * r + 2.0 * r * r  # conv + gate matrices
        macs += 3.0 * d * cfg.d_ff
        return macs
    if kind == "mlstm":
        # qkv (3d^2) + output gate (d^2) + out proj (d^2) + state ops
        chunk = 256.0
        state = 3.0 * h * hd * hd  # C update + C q + n ops
        intra = h * hd * min(ctx, chunk)  # chunkwise scores+pv average
        return 5.0 * d * d + state + intra
    if kind == "slstm":
        return 4.0 * d * d + 4.0 * d * hd + d * d  # W + block-diag R + out
    raise KeyError(kind)


def cell_flops(cfg: ArchConfig, shape: ShapeSuite) -> float:
    """Total analytic FLOPs (global, all chips) for one step of the cell."""
    s, b = shape.seq_len, shape.global_batch
    if shape.kind == "decode":
        tokens = float(b)
        full_ctx = float(s)
    else:
        tokens = float(b) * s
        full_ctx = s / 2.0  # causal average

    macs = 0.0
    for kind in cfg.layer_kinds:
        ctx = full_ctx
        if kind == "local":
            ctx = min(float(cfg.window), full_ctx)
        macs += layer_macs_per_token(cfg, kind, ctx)
    macs += float(cfg.d_model) * cfg.vocab  # logits head
    fwd_flops = 2.0 * macs * tokens
    if shape.kind == "train":
        # fwd + bwd(2x) + remat recompute (~1x fwd) = 4x forward
        mult = 4.0 if cfg.remat else 3.0
        return fwd_flops * mult
    return fwd_flops


def cell_hbm_floor_bytes(cfg: ArchConfig, shape: ShapeSuite, n_chips: int,
                         model_shards: int) -> float:
    """Per-device HBM traffic floor for one step."""
    n = float(cfg.param_count())
    s, b = shape.seq_len, shape.global_batch
    p_dev = n / model_shards  # TP-sharded params, replicated across DP
    if shape.kind == "train":
        # params r/w (bf16), grads r/w (bf16), adam m/v r/w (f32, ZeRO-1)
        opt_dev = n / n_chips
        traffic = p_dev * (2 * BF16) + p_dev * (2 * BF16) + opt_dev * (4 * F32)
        # activations: ~8 d-wide tensors per layer saved + reread
        tok_dev = b * s / max(n_chips / model_shards, 1)
        traffic += tok_dev * cfg.d_model * cfg.n_layers * 2 * BF16 * 2
        return traffic
    if shape.kind == "prefill":
        tok_dev = b * s / max(n_chips / model_shards, 1)
        return p_dev * BF16 + tok_dev * cfg.d_model * cfg.n_layers * 2 * BF16
    # decode: all params + the whole KV cache are read for one token
    cache = 0.0
    for kind in cfg.layer_kinds:
        if kind in ("attn", "moe"):
            cache += 2.0 * b * s * cfg.n_kv_heads * cfg.head_dim * BF16
        elif kind == "local":
            cache += 2.0 * b * min(cfg.window, s) * cfg.n_kv_heads * cfg.head_dim * BF16
        elif kind == "rglru":
            cache += b * cfg.rnn_width * (cfg.conv_width + 1) * BF16
        elif kind == "mlstm":
            cache += b * cfg.n_heads * cfg.head_dim**2 * F32
        elif kind == "slstm":
            cache += 4.0 * b * cfg.d_model * F32
    return p_dev * BF16 + cache / n_chips
