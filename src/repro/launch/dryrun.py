import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and extract the roofline terms.

The two lines above MUST stay the first statements in this module — jax
locks the device count at first init, and the dry-run (and only the
dry-run) needs 512 placeholder CPU devices for the 16x16 and 2x16x16
meshes.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all   # every applicable cell

Each cell writes experiments/dryrun/<arch>__<shape>__<mesh>.json with
memory analysis, cost analysis, collective bytes, and the three roofline
terms (assignment §Roofline).
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, SHAPES, applicable, get_config
from ..models import forward, lm_loss, decode_step, param_shardings, production_rules, use_sharding
from ..models.sharding import tuned_rules
from ..optim import adamw
from ..optim.schedule import warmup_cosine
from .hlo import collective_bytes_scaled, while_trip_counts
from .mesh import make_production_mesh
from .roofline import roofline_report
from .specs import (
    abstract_opt_state,
    abstract_params,
    batch_shardings,
    batch_specs,
    cache_shardings,
    decode_specs,
    opt_shardings,
)

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def grad_accum_steps(cfg, shape, mesh, rules=None) -> int:
    """Microbatching so per-device live activations stay within ~6 GB.

    Standard production practice: the global batch is split into
    microbatches scanned inside the step, gradients accumulated — trades
    one more traversal of the weights for a bounded activation footprint.
    With sequence parallelism the saved residuals are seq-sharded over the
    model axis, so far fewer microbatches are needed (each microbatch
    re-gathers the weights — §Perf iteration L2).
    """
    dp = mesh.devices.size // 16  # model axis is 16 on both meshes
    tok_dev = shape.global_batch * shape.seq_len / max(dp, 1)
    act_bytes = tok_dev * cfg.d_model * cfg.n_layers * 2 * 2  # carries, bf16
    if rules is not None and rules.sequence:
        act_bytes /= mesh.shape[rules.sequence]
    # the f32 logits + log-softmax of one microbatch are often the peak
    vocab_dev = cfg.vocab / (16 if cfg.vocab % 16 == 0 else 1)
    logit_bytes = tok_dev * vocab_dev * 6  # f32 logits + softmax temps
    accum = 1
    # microbatches must still cover the data axis (>= 1 sequence/device)
    max_accum = max(shape.global_batch // dp, 1)
    # 1.5 GB live-activation target: gathered f32 buffers (2-4 alive
    # during remat-backward) plus carries must stay well under HBM
    while max(act_bytes, logit_bytes) / accum > 1.5e9 and accum < max_accum:
        accum *= 2
    return accum


def accumulated_grads(cfg, params, batch, accum: int):
    """Mean loss + grads over `accum` microbatches via lax.scan."""
    if accum <= 1:
        return jax.value_and_grad(lambda p: lm_loss(cfg, p, batch))(params)

    def split(x):
        return x.reshape(accum, x.shape[0] // accum, *x.shape[1:])

    micro = jax.tree_util.tree_map(split, batch)
    zero_grads = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )

    def step(carry, mb):
        loss_sum, gacc = carry
        l, g = jax.value_and_grad(lambda p: lm_loss(cfg, p, mb))(params)
        gacc = jax.tree_util.tree_map(
            lambda a, b: a + b.astype(jnp.float32), gacc, g
        )
        return (loss_sum + l, gacc), None

    (loss, grads), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), zero_grads), micro
    )
    grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
    return loss / accum, grads


def build_step(cfg, shape, mesh, rules):
    """Returns (fn, arg_structs, in_shardings, donate) for the cell."""
    params_abs = abstract_params(cfg)
    pshard = param_shardings(params_abs, mesh, rules)

    if shape.kind == "train":
        opt_abs = abstract_opt_state(cfg, params_abs)
        oshard = opt_shardings(cfg, params_abs, opt_abs, mesh, rules)
        bshard = batch_shardings(cfg, shape, mesh, rules)
        init_opt, update = adamw(lr=warmup_cosine(3e-4, 100, 10_000))
        accum = grad_accum_steps(cfg, shape, mesh, rules)

        def train_step(params, opt, batch):
            loss, grads = accumulated_grads(cfg, params, batch, accum)
            params, opt = update(grads, opt, params)
            return loss, params, opt

        args = (params_abs, opt_abs, batch_specs(cfg, shape))
        shardings = (pshard, oshard, bshard)
        return train_step, args, shardings, (0, 1)

    if shape.kind == "prefill":

        def prefill_step(params, batch):
            logits, _ = forward(cfg, params, batch["inputs"])
            return logits

        args = (params_abs, batch_specs(cfg, shape))
        shardings = (pshard, batch_shardings(cfg, shape, mesh, rules))
        return prefill_step, args, shardings, ()

    # decode
    specs = decode_specs(cfg, shape)
    cshard = cache_shardings(cfg, specs["cache"], mesh, rules)
    from jax.sharding import NamedSharding, PartitionSpec as P

    # batch=1 shapes (long_500k) cannot shard over the data axes
    dp_axes = rules.batch if isinstance(rules.batch, tuple) else (rules.batch,)
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    dp = rules.batch if shape.global_batch % dp_size == 0 else None
    tok_spec = (
        NamedSharding(mesh, P(dp, None, None))
        if cfg.embedded_inputs
        else NamedSharding(mesh, P(dp, None))
    )

    def serve_step(params, cache, tokens, index):
        return decode_step(cfg, params, cache, tokens, index)

    args = (params_abs, specs["cache"], specs["tokens"], specs["index"])
    shardings = (pshard, cshard, tok_spec, NamedSharding(mesh, P()))
    return serve_step, args, shardings, (1,)


def run_cell(arch: str, shape_name: str, multi_pod: bool, save: bool = True,
             tuned: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "long_500k requires sub-quadratic attention"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = tuned_rules(arch, multi_pod) if tuned else production_rules(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    with use_sharding(mesh, rules):
        fn, args, shardings, donate = build_step(cfg, shape, mesh, rules)
        with jax.set_mesh(mesh):
            jitted = jax.jit(
                fn, in_shardings=shardings, donate_argnums=donate or None
            )
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_bytes_scaled(hlo)  # execution-count weighted
    trips = while_trip_counts(hlo)

    mesh_name = ("pod2x16x16" if multi_pod else "pod16x16") + ("-tuned" if tuned else "")
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_chips": n_chips,
        "kind": shape.kind,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "cost": {
            "flops_per_device": cost.get("flops", 0.0),
            "bytes_per_device": cost.get("bytes accessed", 0.0),
        },
        "collectives": {
            "bytes_by_op": coll.bytes_by_op,
            "link_bytes_by_op": coll.link_bytes_by_op,
            "count_by_op": coll.count_by_op,
            "total_bytes_per_device": coll.total_bytes,
            "link_bytes_per_device": coll.total_link_bytes,
        },
        "while_trip_counts": trips,
    }
    result["roofline"] = roofline_report(cfg, shape, result)
    if save:
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        path = OUT_DIR / f"{arch}__{shape_name}__{mesh_name}.json"
        path.write_text(json.dumps(result, indent=2))
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--tuned", action="store_true",
                    help="hillclimbed sharding rules (§Perf) instead of baseline")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                for mp in ((False, True) if args.both_meshes else (args.multi_pod,)):
                    cells.append((arch, shape, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        meshes = (False, True) if args.both_meshes else (args.multi_pod,)
        cells = [(args.arch, args.shape, mp) for mp in meshes]

    failures = 0
    for arch, shape, mp in cells:
        tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
        try:
            r = run_cell(arch, shape, mp, tuned=args.tuned)
            if r.get("skipped"):
                print(f"SKIP {tag}: {r['reason']}", flush=True)
                continue
            rf = r["roofline"]
            print(
                f"OK   {tag}: compile={r['compile_s']}s "
                f"flops/dev={r['cost']['flops_per_device']:.3e} "
                f"coll={r['collectives']['total_bytes_per_device']:.3e}B "
                f"bound={rf['dominant_term']}",
                flush=True,
            )
        except Exception as e:
            failures += 1
            print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
