from .mesh import make_mesh_for, make_production_mesh
