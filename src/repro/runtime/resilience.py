"""Resilience primitives shared by the serving and training runtimes.

The paper's own framing is that *flexibility between execution strategies
is an asset* (Sec. 7, value of flexibility): the stack already carries
several interchangeable paths per phase — Pallas kernels with jnp registry
fallbacks, mapper-searched schedules with a safe default.  This module
turns that flexibility into explicit fault-handling machinery:

* an **error taxonomy** (:class:`ServingError` and friends) so every
  per-request failure carries a typed cause and a stable ``code`` that
  surfaces on :class:`~repro.runtime.engine.Result` and in
  ``EngineStats.errors``;
* **request statuses** — ``ok`` / ``rejected`` / ``failed`` / ``degraded``
  — the engine's per-request contract (``submit()`` never raises for a
  per-request cause; it returns a non-``ok`` status instead);
* a :class:`RetryPolicy` with bounded exponential backoff — the retry core
  :class:`~repro.runtime.fault_tolerance.ResilientRunner` (training) and
  :class:`~repro.runtime.engine.InferenceEngine` (serving) both use;
* the **degradation ladder** (:class:`Tier` / :func:`default_ladder`):
  searched schedule + Pallas -> searched schedule + jnp -> default
  schedule, walked tier by tier when the preferred path faults;
* :func:`validate_request` — the engine-boundary validation that
  quarantines malformed graphs and poisoned features *before* they can
  join a micro-batch and take healthy neighbors down with them.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import TYPE_CHECKING, Callable

import numpy as np

if TYPE_CHECKING:  # pragma: no cover - annotation-only import
    from .engine import Request

# ---------------------------------------------------------------------------
# Request statuses
# ---------------------------------------------------------------------------

#: served on the preferred execution tier; output is authoritative.
STATUS_OK = "ok"
#: never admitted (validation / admission control); safe to resubmit after
#: fixing the cause (or after ``retry_after_s`` for load shedding).
STATUS_REJECTED = "rejected"
#: admitted but produced no trustworthy output (kernel fault at every
#: tier, non-finite output, missed deadline).
STATUS_FAILED = "failed"
#: served correctly, but on a lower tier of the degradation ladder.
STATUS_DEGRADED = "degraded"

STATUSES = (STATUS_OK, STATUS_REJECTED, STATUS_FAILED, STATUS_DEGRADED)


# ---------------------------------------------------------------------------
# Error taxonomy
# ---------------------------------------------------------------------------


class ServingError(Exception):
    """Base of the serving error taxonomy; ``code`` is the stable
    machine-readable cause recorded on ``Result.error_type``."""

    code = "serving_error"
    #: the status a request carrying this error ends in.
    status = STATUS_FAILED


class InvalidRequest(ServingError):
    """Malformed request: broken CSR invariants, wrong feature dtype or
    shape, non-finite features.  Caught at the engine boundary."""

    code = "invalid_request"
    status = STATUS_REJECTED


class OversizedGraph(ServingError):
    """Graph exceeds the bucket policy's explicit size caps; rejected with
    a clear error instead of silently compiling a one-off giant bucket."""

    code = "oversized_graph"
    status = STATUS_REJECTED


class EngineOverloaded(ServingError):
    """Admission control shed this request; ``retry_after_s`` is the
    engine's backpressure hint."""

    code = "engine_overloaded"
    status = STATUS_REJECTED

    def __init__(self, message: str, retry_after_s: float | None = None):
        super().__init__(message)
        self.retry_after_s = retry_after_s


class KernelFault(ServingError):
    """An execution-path failure (Pallas/XLA kernel raise, compile
    failure, or an injected fault) that survived every retry and tier."""

    code = "kernel_fault"


class NumericalFault(ServingError):
    """Non-finite values detected in a computed output; the result is
    marked failed instead of returned silently."""

    code = "numerical_fault"


class DeadlineExceeded(ServingError):
    """The request's deadline expired before its micro-batch assembled."""

    code = "deadline_exceeded"


def backlog_retry_after(
    queue_depth: int, batch_wall_s: float, max_graphs: int
) -> float:
    """Backpressure hint for a shed request: the wall time the current
    backlog needs to drain.  ``queue_depth`` graphs form
    ``ceil(queue_depth / max_graphs)`` micro-batches (at least one), each
    costing about the recent median ``batch_wall_s`` — so a client that
    waits this long retries into a queue that has actually moved, instead
    of re-colliding after one request's latency."""
    n_batches = max(1, -(-max(0, queue_depth) // max(1, max_graphs)))
    return float(batch_wall_s) * n_batches


def as_serving_error(exc: BaseException) -> ServingError:
    """Wrap an arbitrary execution failure into the taxonomy (already-typed
    errors pass through)."""
    if isinstance(exc, ServingError):
        return exc
    err = KernelFault(f"{type(exc).__name__}: {exc}")
    err.__cause__ = exc
    return err


# ---------------------------------------------------------------------------
# Retry policy (shared by serving and training)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff.

    ``delay(i)`` is the sleep before the ``i``-th retry (0-based):
    ``backoff_s * multiplier**i`` capped at ``max_backoff_s``.  A
    ``backoff_s`` of 0 (the default — right for deterministic CPU tests)
    never sleeps.
    """

    max_retries: int = 2
    backoff_s: float = 0.0
    multiplier: float = 2.0
    max_backoff_s: float = 1.0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")

    @property
    def max_attempts(self) -> int:
        return self.max_retries + 1

    def delay(self, retry_index: int) -> float:
        if self.backoff_s <= 0:
            return 0.0
        return min(
            self.backoff_s * self.multiplier ** max(retry_index, 0),
            self.max_backoff_s,
        )

    def sleep_for(self, retry_index: int, sleep: Callable[[float], None] = time.sleep):
        d = self.delay(retry_index)
        if d > 0:
            sleep(d)


def run_with_retry(fn: Callable[[], "object"], policy: RetryPolicy,
                   sleep: Callable[[float], None] = time.sleep):
    """Call ``fn`` under ``policy``; returns ``(value, n_retries)`` or
    re-raises the last failure once retries are exhausted."""
    last: BaseException | None = None
    for attempt in range(policy.max_attempts):
        try:
            return fn(), attempt
        except Exception as e:  # noqa: BLE001 — any fault is retryable here
            last = e
            if attempt < policy.max_retries:
                policy.sleep_for(attempt, sleep=sleep)
    assert last is not None
    raise last


# ---------------------------------------------------------------------------
# Degradation ladder
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Tier:
    """One rung of the degradation ladder.

    ``use_pallas`` picks the kernel family; ``searched`` picks between the
    mapper-searched schedule and the safe default
    (``ModelSchedule.from_policies("sp_opt", "AC", dims)``) that needs no
    mapper and no Pallas toolchain.
    """

    name: str
    use_pallas: bool
    searched: bool


def default_ladder(use_pallas: bool) -> tuple[Tier, ...]:
    """The engine's ladder, preferred tier first.

    With Pallas enabled: searched+Pallas -> searched+jnp -> default+jnp.
    Without: searched+jnp -> default+jnp.  Every downgrade is recorded on
    the per-request :class:`~repro.runtime.engine.Result` and counted in
    ``EngineStats``.
    """
    tiers = []
    if use_pallas:
        tiers.append(Tier("pallas+searched", use_pallas=True, searched=True))
    tiers.append(Tier("jnp+searched", use_pallas=False, searched=True))
    tiers.append(Tier("jnp+default", use_pallas=False, searched=False))
    return tuple(tiers)


# ---------------------------------------------------------------------------
# Engine-boundary request validation
# ---------------------------------------------------------------------------


def validate_request(req: "Request", f_in: int) -> None:
    """Reject a malformed request before it can join a micro-batch.

    Raises :class:`InvalidRequest` (message naming the request id) when the
    features are not 2-D float32 of shape ``(n_nodes, f_in)`` or carry
    non-finite values (a float64 ``x`` would otherwise silently downcast
    into the batch buffer; a NaN block would poison every neighbor's
    aggregation), or when the CSR invariants are broken: ``row_ptr``
    monotone from 0 to ``nnz``, ``col_idx`` in ``[0, n_nodes)``,
    ``values`` matching ``col_idx`` and finite.
    """
    g, x, rid = req.graph, req.x, req.rid

    def bad(msg: str) -> None:
        raise InvalidRequest(f"request {rid}: {msg}")

    if getattr(x, "ndim", None) != 2:
        bad(f"features must be a 2-D array, got ndim={getattr(x, 'ndim', None)}")
    if x.dtype != np.float32:
        bad(
            f"features must be float32, got {x.dtype} (mixed-precision "
            f"features would silently change the whole batch's numerics)"
        )
    if x.shape != (g.n_nodes, f_in):
        bad(
            f"features {x.shape} do not match (n_nodes={g.n_nodes}, "
            f"f_in={f_in})"
        )
    if not np.isfinite(x).all():
        bad("features contain non-finite values")

    rp = np.asarray(g.row_ptr)
    ci = np.asarray(g.col_idx)
    vals = np.asarray(g.values)
    if rp.ndim != 1 or rp.shape[0] != g.n_nodes + 1:
        bad(
            f"row_ptr has length {rp.shape[0] if rp.ndim == 1 else rp.shape} "
            f"for n_nodes={g.n_nodes} (want n_nodes + 1)"
        )
    if rp.shape[0] and rp[0] != 0:
        bad(f"row_ptr must start at 0, got {rp[0]}")
    if (np.diff(rp) < 0).any():
        bad("row_ptr must be monotonically non-decreasing")
    if rp.shape[0] and rp[-1] != ci.shape[0]:
        bad(
            f"row_ptr[-1]={int(rp[-1])} does not match the number of stored "
            f"edges {ci.shape[0]}"
        )
    if vals.shape[0] != ci.shape[0]:
        bad(
            f"values ({vals.shape[0]}) and col_idx ({ci.shape[0]}) lengths "
            f"disagree"
        )
    if ci.shape[0] and ((ci < 0).any() or (ci >= g.n_nodes).any()):
        bad(
            f"col indices out of range [0, {g.n_nodes}): "
            f"min={int(ci.min())}, max={int(ci.max())}"
        )
    if vals.shape[0] and not np.isfinite(vals).all():
        bad("adjacency values contain non-finite entries")
