"""Persistent program store: compiled serving artifacts that survive the
process.

The serving engine's :class:`~repro.runtime.engine.ProgramCache` amortizes
mapper search and XLA tracing *within* a process; every restart used to
pay all of it again (cold p99 913 ms vs 11 ms p50 in
``experiments/benchmarks/serve_gnn.json``).  The paper's premise is that
the expensive part — exploring the sparse/dense dataflow design-space —
is per workload *shape*, not per request, so the searched schedule should
outlive the process.  This module is that persistence layer:

* :class:`ProgramStore` — a directory of :class:`~repro.api.Program`
  JSON artifacts keyed by ``(layer dims, bucket shape, kind, objective,
  tier, hw)``.  ``Program.save``/``load`` is already byte-stable JSON
  with a workload fingerprint, so the store is artifacts plus a versioned
  index.  Loads are **corruption-tolerant by construction**: the artifact
  path is derived from the key digest (the index is informational), and a
  truncated / garbage / wrong-format artifact is a counted cache miss,
  never a crash — the engine just recompiles and :meth:`put` repairs the
  entry atomically.
* :func:`enable_persistent_compilation_cache` — wires JAX's persistent
  compilation cache so the XLA executables behind ``Program.run`` also
  survive restarts: a revived process still re-traces (tracing is a
  Python-process affair) but the XLA compile behind each trace becomes a
  disk hit.  :meth:`InferenceEngine.precompile
  <repro.runtime.engine.InferenceEngine.precompile>` moves those traces
  off the request path at startup.
* The recorded :class:`~repro.graphs.batching.TrafficProfile` is
  serialized alongside the artifacts (:meth:`ProgramStore.save_profile`)
  so a revived engine knows which bucket shapes to warm, hottest first.

Store layout::

    <root>/
      index.json              # versioned key -> file listing (informational)
      <digest>.program.json   # one Program artifact per key
      traffic.json            # TrafficProfile (bucket heat across lives)
      jax-cache/              # XLA persistent compilation cache (opt-in)
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
from dataclasses import asdict
from pathlib import Path
from typing import Iterator

from ..api import Program
from ..graphs.batching import TrafficProfile

STORE_FORMAT = "repro.store/v1"

#: environment override for the XLA persistent compilation cache location
#: (see :func:`enable_persistent_compilation_cache`).
JAX_CACHE_ENV = "REPRO_JAX_CACHE_DIR"

_INDEX = "index.json"
_PROFILE = "traffic.json"
_SUFFIX = ".program.json"
_LATENCY = "latency_model.json"

#: LatencyModel collection file schema version.
LATENCY_STORE_FORMAT = "repro.latency-store/v1"


def store_key(
    dims,
    bucket: tuple[int, int],
    v_total: int,
    *,
    kind: str,
    objective: str,
    use_pallas: bool,
    searched: bool = True,
    hw=None,
) -> dict:
    """The canonical store key for one compiled serving artifact.

    ``dims`` + ``bucket`` are the workload fingerprint at serving
    granularity: every micro-batch of a bucket presents the same padded
    shapes, so one artifact serves them all (``v_total`` distinguishes
    slot-count variants of the bucket — their executables differ).
    ``hw`` is an :class:`~repro.core.hw.AcceleratorConfig` (or ``None``
    for "any").
    """
    return {
        "dims": [[int(fi), int(fo)] for fi, fo in dims],
        "bucket": [int(bucket[0]), int(bucket[1])],
        "v_total": int(v_total),
        "kind": str(kind),
        "objective": str(objective),
        "use_pallas": bool(use_pallas),
        "searched": bool(searched),
        "hw": None if hw is None else {k: v for k, v in sorted(asdict(hw).items())},
    }


def key_digest(key: dict) -> str:
    """Stable content digest of a store key (the artifact's filename)."""
    blob = json.dumps(key, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:20]


def _atomic_write_text(path: Path, text: str) -> None:
    tmp = path.with_name(path.name + f".tmp.{os.getpid()}")
    try:
        tmp.write_text(text)
        os.replace(tmp, path)
    finally:
        tmp.unlink(missing_ok=True)


class ProgramStore:
    """On-disk cache of compiled :class:`~repro.api.Program` artifacts.

    ``get`` returns ``None`` on any miss — absent, truncated, garbage,
    wrong artifact format, or key mismatch — and counts the cause
    (``hits`` / ``misses`` / ``corrupt``); it never raises for a bad
    artifact, because a store must degrade to a recompile, not take the
    serving process down.  ``put`` writes atomically (temp file +
    ``os.replace``) so a crash mid-write can't strand a truncated entry.

    The index file is a versioned, human-readable listing (key -> file);
    it is *not* load-bearing: artifact paths derive from the key digest,
    so a corrupt or missing index only costs :meth:`keys` its listing
    until the next :meth:`put` rewrites it.

    One store instance may back several per-device engines at once (the
    async front-end shares it across workers), so counters, index updates
    and profile writes are serialized by a lock; the artifact files
    themselves were already safe under concurrency (atomic writes, derived
    paths).
    """

    def __init__(self, root, *, jax_cache: bool = False):
        self.root = Path(root).expanduser()
        self.root.mkdir(parents=True, exist_ok=True)
        self.hits = 0
        self.misses = 0
        self.corrupt = 0  # artifacts that existed but failed to load
        self._lock = threading.Lock()
        self._index: dict[str, dict] = self._load_index()
        if jax_cache:
            # co-locate the XLA cache with the store unless the operator
            # pointed REPRO_JAX_CACHE_DIR somewhere else (CI does, so the
            # two caches can be restored independently)
            enable_persistent_compilation_cache(
                None if os.environ.get(JAX_CACHE_ENV)
                else self.root / "jax-cache"
            )

    # -- index ---------------------------------------------------------------
    def _load_index(self) -> dict[str, dict]:
        path = self.root / _INDEX
        try:
            d = json.loads(path.read_text())
            if d.get("format") != STORE_FORMAT:
                raise ValueError(f"index format {d.get('format')!r}")
            return dict(d["entries"])
        except FileNotFoundError:
            return {}
        except Exception:
            # a bad index is cosmetic: rebuild the listing from the
            # artifacts actually on disk (their keys are in the payloads)
            entries: dict[str, dict] = {}
            for p in sorted(self.root.glob(f"*{_SUFFIX}")):
                entries[p.name[: -len(_SUFFIX)]] = {"file": p.name}
            return entries

    def _save_index(self) -> None:
        payload = {"format": STORE_FORMAT, "entries": self._index}
        _atomic_write_text(
            self.root / _INDEX,
            json.dumps(payload, indent=2, sort_keys=True) + "\n",
        )

    # -- artifacts -----------------------------------------------------------
    def path_for(self, key: dict) -> Path:
        return self.root / f"{key_digest(key)}{_SUFFIX}"

    def __len__(self) -> int:
        return sum(1 for _ in self.root.glob(f"*{_SUFFIX}"))

    def __contains__(self, key: dict) -> bool:
        return self.path_for(key).exists()

    def keys(self) -> Iterator[dict]:
        """The indexed keys (informational listing)."""
        for entry in self._index.values():
            if "key" in entry:
                yield entry["key"]

    def get(self, key: dict) -> Program | None:
        """Load the artifact for ``key``, or ``None`` (miss) — never
        raises for a bad artifact."""
        path = self.path_for(key)
        if not path.exists():
            with self._lock:
                self.misses += 1
            return None
        try:
            prog = Program.from_json(path.read_text())
        except Exception:
            # truncated write, garbage bytes, or a PROGRAM_FORMAT bump:
            # all of them degrade to a recompile
            with self._lock:
                self.corrupt += 1
                self.misses += 1
            return None
        with self._lock:
            self.hits += 1
        return prog

    def put(self, key: dict, program: Program) -> Path:
        """Persist ``program`` under ``key`` (atomic), update the index."""
        digest = key_digest(key)
        path = self.root / f"{digest}{_SUFFIX}"
        program.save(path)  # Program.save is atomic
        with self._lock:
            self._index[digest] = {"file": path.name, "key": key}
            self._save_index()
        return path

    # -- traffic profile -----------------------------------------------------
    @property
    def profile_path(self) -> Path:
        return self.root / _PROFILE

    def save_profile(self, profile: TrafficProfile) -> Path:
        with self._lock:
            return profile.save(self.profile_path)

    def load_profile(self) -> TrafficProfile | None:
        """The persisted bucket-heat profile, or ``None`` when absent or
        unreadable (same corruption tolerance as :meth:`get`)."""
        try:
            return TrafficProfile.load(self.profile_path)
        except FileNotFoundError:
            return None
        except Exception:
            with self._lock:
                self.corrupt += 1
            return None

    # -- fitted latency models ----------------------------------------------
    @property
    def latency_path(self) -> Path:
        return self.root / _LATENCY

    def save_latency_model(self, model) -> Path:
        """Persist a fitted :class:`~repro.core.hw.LatencyModel` beside
        the program artifacts, keyed by the backend fingerprint it was
        measured on (one file holds all backends; saving merges)."""
        from ..core.hw import LatencyModel

        if not isinstance(model, LatencyModel):
            raise TypeError(f"expected a LatencyModel, got {type(model).__name__}")
        if not model.backend:
            raise ValueError(
                "refusing to store a LatencyModel with no backend "
                "fingerprint — fit it via repro.core.calibrate"
            )
        with self._lock:
            models = self._load_latency_models()
            entry = json.loads(model.to_json())
            entry.pop("format")
            models[model.backend] = entry
            payload = {"format": LATENCY_STORE_FORMAT, "models": models}
            _atomic_write_text(
                self.latency_path,
                json.dumps(payload, indent=2, sort_keys=True) + "\n",
            )
        return self.latency_path

    def _load_latency_models(self) -> dict:
        try:
            d = json.loads(self.latency_path.read_text())
            if d.get("format") != LATENCY_STORE_FORMAT:
                raise ValueError(f"latency store format {d.get('format')!r}")
            return dict(d["models"])
        except FileNotFoundError:
            return {}
        except Exception:
            self.corrupt += 1
            return {}

    def load_latency_model(self, backend: str):
        """The fitted model for ``backend`` (a
        :func:`~repro.core.calibrate.backend_fingerprint` string), or
        ``None`` when absent/unreadable — same corruption tolerance as
        :meth:`get`."""
        from ..core.hw import LatencyModel

        with self._lock:
            entry = self._load_latency_models().get(backend)
        if entry is None:
            return None
        try:
            return LatencyModel(**entry)
        except Exception:
            with self._lock:
                self.corrupt += 1
            return None

    def stats(self) -> dict:
        return {
            "n_artifacts": len(self),
            "hits": self.hits,
            "misses": self.misses,
            "corrupt": self.corrupt,
        }


def enable_persistent_compilation_cache(cache_dir=None) -> Path:
    """Point JAX's persistent compilation cache at ``cache_dir`` so the
    XLA executables behind every jitted ``Program.run`` survive restarts.

    Resolution order: explicit ``cache_dir`` argument, the
    ``REPRO_JAX_CACHE_DIR`` environment variable, then
    ``~/.cache/repro/jax-cache``.  The min-compile-time threshold is
    dropped to zero because serving executables on small bucket shapes
    compile fast but add up across a fleet of buckets — exactly the
    entries the default 1 s threshold would skip.  Returns the directory.
    """
    import jax

    d = Path(
        cache_dir
        or os.environ.get(JAX_CACHE_ENV)
        or Path.home() / ".cache" / "repro" / "jax-cache"
    ).expanduser()
    d.mkdir(parents=True, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", str(d))
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    return d
