"""Async continuous-batching front-end with multi-device bucket placement.

:class:`~repro.runtime.engine.InferenceEngine.submit` is synchronous and
single-device: requests only batch within one call, every bucket executes
serially on one device, and a request's latency is set by whoever it
happened to arrive with.  The paper's core claim is that spatial
accelerators win by running distinct phase dataflows *concurrently* on
partitioned compute; for a serving workload the analogous axis is
graph-level parallelism across independent inputs — distinct padding
buckets are independent compiled programs, so they can run on distinct
devices of a mesh at the same time.  This module is that front-end:

* :class:`AsyncEngine` — an arrival queue with a **batching window** per
  bucket: a window flushes when it holds ``policy.max_graphs`` graphs or
  when ``window_ms`` expires, whichever comes first.  ``submit_async``
  returns a :class:`concurrent.futures.Future` per request, so latency is
  measured per request (enqueue -> result), not per submit-chunk.
* :class:`BucketPlacer` — schedules buckets over the devices of a
  :class:`jax.sharding.Mesh` (or an explicit device list): distinct
  buckets land on distinct devices while devices remain (least-loaded by
  recorded heat), and buckets hotter than a fair device share get up to
  ``replicas`` replicas, driven by the same
  :class:`~repro.graphs.batching.TrafficProfile` heat the engine already
  records.
* **Overlapped transfers** — the flush path assembles the block-diagonal
  batch and stages its feature block onto the target device with
  :func:`jax.device_put` *before* the group reaches the device worker, so
  the host->device copy overlaps the previous batch's compute.

Contracts carried over:

* PR 6 (resilience): admission runs **before** queueing — a malformed,
  oversized or shed request resolves its future immediately with a typed
  ``rejected`` :class:`~repro.runtime.engine.Result` and never occupies a
  window slot.  Per-request deadlines are enforced at the batching window
  (:meth:`InferenceEngine.serve_group`), and the per-device engines keep
  the full ladder + solo-retry quarantine, so a poisoned request still
  fails alone with a typed status.  No code path raises for a per-request
  cause.
* PR 7 (zero cold start): every per-device engine's LRU sits on the one
  shared :class:`~repro.runtime.store.ProgramStore` (artifacts compiled on
  any device serve all of them — they are keyed by shape, not device),
  and :meth:`AsyncEngine.precompile` warms **each device's assigned
  buckets** on that device's own worker thread.

Execution model: one worker thread per device.  JAX traces/compiles hold
the GIL, but ``block_until_ready`` releases it during device execution,
so on a multi-core host the per-device streams overlap; on a single-core
container the win is continuous batching itself (requests arriving while
a batch runs form the next batch instead of serializing per call).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Sequence

import jax
import numpy as np

from ..gnn.pp import mesh_devices
from ..graphs.batching import TrafficProfile, assemble
from .engine import (
    EngineStats,
    InferenceEngine,
    PrecompileReport,
    Request,
    Result,
)
from .resilience import (
    STATUS_DEGRADED,
    STATUS_FAILED,
    STATUS_OK,
    STATUS_REJECTED,
    EngineOverloaded,
    OversizedGraph,
    ServingError,
    backlog_retry_after,
    validate_request,
)


@dataclass
class AsyncEngineStats:
    """The async front-end's serving report.

    ``p50_ms`` / ``p99_ms`` are per-request enqueue -> result wall times
    across every device (front-end rejections included), so they are
    directly comparable to the sync engine's.  ``per_device`` holds each
    worker engine's own :class:`~repro.runtime.engine.EngineStats`;
    ``placement`` records which devices each bucket was assigned to.
    """

    n_requests: int = 0
    n_devices: int = 0
    wall_s: float = 0.0
    graphs_per_sec: float = 0.0
    p50_ms: float = 0.0
    p99_ms: float = 0.0
    n_ok: int = 0
    n_rejected: int = 0
    n_failed: int = 0
    n_degraded: int = 0
    n_flushes_full: int = 0  # windows flushed because they filled
    n_flushes_deadline: int = 0  # windows flushed by the window_ms clock
    max_inflight: int = 0  # high-water mark of queued+running graphs
    errors: dict = field(default_factory=dict)
    placement: dict = field(default_factory=dict)  # "VxD" -> [device labels]
    per_device: dict = field(default_factory=dict)  # label -> EngineStats dict

    def as_dict(self) -> dict:
        from dataclasses import asdict

        return asdict(self)


@dataclass
class AsyncPrecompileReport:
    """Per-device precompile roll-up: each worker warmed its *assigned*
    buckets (placer plan over the persisted profile) on its own thread."""

    n_shapes: int = 0
    n_store_hits: int = 0
    n_compiled: int = 0
    n_searches: int = 0
    n_traces: int = 0
    wall_s: float = 0.0
    per_device: dict = field(default_factory=dict)  # label -> PrecompileReport

    def as_dict(self) -> dict:
        from dataclasses import asdict

        return asdict(self)


class BucketPlacer:
    """Bucket -> device assignment over a mesh, driven by traffic heat.

    Distinct buckets go to distinct devices while free devices remain:
    a new bucket is assigned to the device carrying the least cumulative
    heat (request count), so the first ``n_devices`` buckets spread one
    per device.  A bucket whose heat share exceeds a fair device share
    (``1 / n_devices``) is *hot* and gets additional replicas — up to
    ``replicas`` — on the least-loaded devices that don't already serve
    it.  Dispatch picks the assigned replica with the fewest outstanding
    graphs.

    The placer is deliberately greedy and incremental: assignments only
    grow (a bucket never migrates), so per-device executable caches stay
    warm and placement is deterministic for a given arrival order.  Not
    thread-safe by itself — the :class:`AsyncEngine` serializes calls
    under its own lock.
    """

    def __init__(
        self, n_devices: int, *, replicas: int = 1, min_heat: int = 32
    ):
        if n_devices < 1:
            raise ValueError(f"need at least one device, got {n_devices}")
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        self.n_devices = n_devices
        self.replicas = min(replicas, n_devices)
        #: minimum absolute heat before a bucket can widen — a bucket's
        #: first few arrivals dominate any share computation, so expansion
        #: waits for a statistically meaningful sample
        self.min_heat = min_heat
        #: bucket -> ordered device indices serving it (first = home)
        self.assignment: dict[tuple[int, int], list[int]] = {}
        #: cumulative request heat per bucket / per device
        self.heat: dict[tuple[int, int], int] = {}
        self.device_heat: list[int] = [0] * n_devices
        #: outstanding (queued or running) graphs per device
        self.outstanding: list[int] = [0] * n_devices

    def _least_loaded(self, exclude: Sequence[int] = ()) -> int:
        """Device with the least heat (ties -> lowest index) not excluded."""
        best = None
        for d in range(self.n_devices):
            if d in exclude:
                continue
            if best is None or self.device_heat[d] < self.device_heat[best]:
                best = d
        assert best is not None
        return best

    def record(self, bucket: tuple[int, int], n: int = 1) -> None:
        """Account ``n`` arrivals to ``bucket``: assign it on first sight,
        and widen hot buckets up to ``replicas`` devices."""
        self.heat[bucket] = self.heat.get(bucket, 0) + n
        homes = self.assignment.get(bucket)
        if homes is None:
            homes = [self._least_loaded()]
            self.assignment[bucket] = homes
        self.device_heat[homes[0]] += n
        if (
            self.replicas > 1
            and len(homes) < self.replicas
            and self.heat[bucket] >= self.min_heat
        ):
            total = sum(self.heat.values())
            if total > 0 and self.heat[bucket] / total > 1.0 / self.n_devices:
                extra = self._least_loaded(exclude=homes)
                if extra not in homes:
                    homes.append(extra)

    def plan(self, profile: TrafficProfile) -> None:
        """Seed the assignment from a recorded profile, hottest bucket
        first — the startup twin of :meth:`record`, so ``precompile`` can
        warm each device's buckets before traffic arrives."""
        for bucket, n in profile.heat():
            self.record(bucket, n)

    def pick(self, bucket: tuple[int, int], n_graphs: int) -> int:
        """The device index to dispatch this flush to: the bucket's
        assigned replica with the fewest outstanding graphs.  Registers
        the ``n_graphs`` as outstanding (release with :meth:`done`)."""
        homes = self.assignment.get(bucket)
        if homes is None:  # dispatch before record (defensive)
            self.record(bucket, 0)
            homes = self.assignment[bucket]
        d = min(homes, key=lambda i: (self.outstanding[i], homes.index(i)))
        self.outstanding[d] += n_graphs
        return d

    def done(self, device: int, n_graphs: int) -> None:
        self.outstanding[device] = max(0, self.outstanding[device] - n_graphs)

    def buckets_for(self, device: int) -> set[tuple[int, int]]:
        """Every bucket assigned (home or replica) to ``device``."""
        return {b for b, homes in self.assignment.items() if device in homes}


class _Window:
    """One open batching window: same-bucket requests waiting to flush."""

    __slots__ = ("bucket", "requests", "arrivals", "futures", "deadline")

    def __init__(self, bucket: tuple[int, int], deadline: float):
        self.bucket = bucket
        self.requests: list[Request] = []
        self.arrivals: list[float] = []
        self.futures: list[Future] = []
        self.deadline = deadline  # perf_counter time to force-flush


class _DeviceWorker(threading.Thread):
    """One device's serving loop: owns a per-device
    :class:`InferenceEngine` (its own LRU + executable caches, the shared
    store underneath) and drains dispatched groups in FIFO order under
    ``jax.default_device`` so every trace, transfer and execution lands on
    its device."""

    def __init__(self, index: int, device, engine: InferenceEngine, owner):
        super().__init__(name=f"repro-worker-{index}", daemon=True)
        self.index = index
        self.device = device
        self.engine = engine
        self.owner = owner
        self.inbox: "list" = []
        self.cv = threading.Condition()

    def dispatch(self, item) -> None:
        with self.cv:
            self.inbox.append(item)
            self.cv.notify()

    def run(self) -> None:
        with jax.default_device(self.device):
            if self.engine.params is not None:
                # commit the params once; every batch then reads them
                # device-locally instead of re-transferring
                self.engine.params = jax.device_put(
                    self.engine.params, self.device
                )
            while True:
                with self.cv:
                    while not self.inbox:
                        self.cv.wait()
                    item = self.inbox.pop(0)
                if item is None:
                    return
                kind, payload, fut = item
                try:
                    if kind == "group":
                        reqs, arrivals, pre = payload
                        out = self.engine.serve_group(
                            reqs, arrivals, pre=pre
                        )
                    else:  # "call": run an arbitrary thunk on this device
                        out = payload()
                    fut.set_result(out)
                except BaseException as e:  # noqa: BLE001 — worker survives
                    fut.set_exception(e)


class AsyncEngine:
    """Continuous-batching serving front-end over a device mesh.

    ::

        engine = AsyncEngine(dims, params, mesh=mesh, window_ms=10)
        engine.start()
        futs = [engine.submit_async(r) for r in requests]
        results = [f.result() for f in futs]
        engine.close()

    ``submit_async`` admits the request (PR 6 boundary checks + a
    ``max_queue_graphs`` backlog cap with a queue-depth-proportional
    ``retry_after_s``), then parks it in its bucket's batching window.
    The window flushes to a device when it fills to ``policy.max_graphs``
    or its ``window_ms`` deadline expires — so under load p99 tracks the
    window, not the batch that happened to contain the request.

    Every per-device engine is constructed with ``donate=False`` (staged
    feature buffers must survive ladder retries) and the shared ``store``;
    everything else mirrors the sync :class:`InferenceEngine` kwargs.
    """

    def __init__(
        self,
        dims: Sequence[tuple[int, int]],
        params=None,
        *,
        mesh: "jax.sharding.Mesh | None" = None,
        devices: Sequence | None = None,
        window_ms: float = 10.0,
        replicas: int = 1,
        max_queue_graphs: int | None = None,
        **engine_kwargs,
    ):
        self.devices = mesh_devices(mesh, list(devices) if devices else None)
        if not self.devices:
            raise ValueError("no devices to place buckets on")
        self.window_s = float(window_ms) / 1e3
        self.max_queue_graphs = max_queue_graphs
        engine_kwargs.pop("donate", None)
        # admission is the front-end's job — per-engine shedding would
        # double-count a stream that is already capped at the queue
        engine_kwargs.pop("max_inflight_graphs", None)
        self.workers: list[_DeviceWorker] = []
        for i, dev in enumerate(self.devices):
            eng = InferenceEngine(
                dims,
                params,
                donate=False,
                device_label=str(dev),
                **engine_kwargs,
            )
            self.workers.append(_DeviceWorker(i, dev, eng, self))
        e0 = self.workers[0].engine
        self.policy = e0.policy
        self.f_in = e0.f_in
        self.store = e0.store
        self.placer = BucketPlacer(len(self.devices), replicas=replicas)
        #: merged bucket heat across devices (persisted to the store on
        #: close; worker engines never save their partial profiles)
        self.profile: TrafficProfile = e0.profile
        for w in self.workers[1:]:
            w.engine.profile = TrafficProfile()  # don't double-seed heat
        self._lock = threading.Lock()
        self._windows: dict[tuple[int, int], _Window] = {}
        self._inflight = 0  # graphs admitted but not yet resolved
        self._max_inflight = 0
        self._rid = 0
        self._n_requests = 0
        self._n_flushes_full = 0
        self._n_flushes_deadline = 0
        self._fe_latencies: list[float] = []  # front-end rejections
        self._fe_status = {s: 0 for s in
                           (STATUS_OK, STATUS_REJECTED, STATUS_FAILED,
                            STATUS_DEGRADED)}
        self._fe_errors: dict[str, int] = {}
        self._wall_t0: float | None = None
        self._wall_t1: float = 0.0
        self._started = False
        self._closed = False
        self._flusher = threading.Thread(
            target=self._flush_loop, name="repro-flusher", daemon=True
        )
        self._flush_cv = threading.Condition(self._lock)

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "AsyncEngine":
        if self._started:
            return self
        self._started = True
        for w in self.workers:
            w.start()
        self._flusher.start()
        return self

    def close(self) -> None:
        """Flush every open window, drain the workers, persist the merged
        traffic profile.  Idempotent."""
        if self._closed or not self._started:
            self._closed = True
            return
        self._closed = True
        final: list[tuple[int, list]] = []
        with self._lock:
            for bucket in list(self._windows):
                flushed = self._flush_locked(bucket, "deadline")
                if flushed is not None:
                    final.append(flushed)
            self._flush_cv.notify_all()
        for widx, wins in final:
            self._stage_and_dispatch(widx, wins)
        self._flusher.join(timeout=10.0)
        # sentinel after all groups: workers drain FIFO then exit
        for w in self.workers:
            w.dispatch(None)
        for w in self.workers:
            w.join(timeout=30.0)
        self._persist_profile()

    def __enter__(self) -> "AsyncEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def _persist_profile(self) -> None:
        if self.store is not None:
            merged = self.profile
            for w in self.workers[1:]:
                merged = merged.merge(w.engine.profile)
            self.profile = merged
            for w in self.workers[1:]:
                w.engine.profile = TrafficProfile()
            self.store.save_profile(merged)

    # -- admission (PR 6: before queueing) -----------------------------------
    def _admission_error(self, req: Request) -> ServingError | None:
        try:
            validate_request(req, self.f_in)
            reason = self.workers[0].engine.oversized_reason(req.graph)
            if reason is not None:
                raise OversizedGraph(f"request {req.rid}: {reason}")
            if (
                self.max_queue_graphs is not None
                and self._inflight >= self.max_queue_graphs
            ):
                hint = backlog_retry_after(
                    self._inflight,
                    self._median_batch_wall(),
                    self.policy.max_graphs,
                )
                raise EngineOverloaded(
                    f"request {req.rid}: queue at max_queue_graphs="
                    f"{self.max_queue_graphs}; retry after {hint:.3f}s",
                    retry_after_s=hint,
                )
        except ServingError as e:
            return e
        return None

    def _median_batch_wall(self) -> float:
        walls: list[float] = []
        for w in self.workers:
            walls.extend(w.engine._batch_walls[-50:])
        if not walls:
            return 0.05
        return float(np.median(walls))

    # -- enqueue -------------------------------------------------------------
    def submit_async(self, req: Request) -> "Future[Result]":
        """Admit ``req`` and park it in its bucket's batching window.

        Returns a future resolving to this request's
        :class:`~repro.runtime.engine.Result`.  Admission failures resolve
        immediately (typed ``rejected`` result, never an exception) —
        nothing inadmissible ever occupies a window slot.
        """
        if not self._started or self._closed:
            raise RuntimeError("AsyncEngine is not running (call start())")
        fut: "Future[Result]" = Future()
        t_arrival = time.perf_counter()
        flush_now: tuple[int, list] | None = None
        part_widx: int | None = None
        with self._lock:
            if self._wall_t0 is None:
                self._wall_t0 = t_arrival
            self._n_requests += 1
            err = self._admission_error(req)
            if (
                err is not None
                and isinstance(err, OversizedGraph)
                and self.workers[0].engine.partition_oversized
            ):
                # beyond-capacity single graph: route to the partitioned
                # lane on the least-loaded device instead of rejecting
                res = None
                self._inflight += 1
                self._max_inflight = max(self._max_inflight, self._inflight)
                part_widx = min(
                    range(len(self.workers)),
                    key=lambda i: self.placer.outstanding[i],
                )
                self.placer.outstanding[part_widx] += 1
            elif err is not None:
                lat = time.perf_counter() - t_arrival
                res = Result(
                    rid=req.rid,
                    output=None,
                    bucket=None,
                    latency_s=lat,
                    status=err.status,
                    error=str(err),
                    error_type=err.code,
                    retry_after_s=getattr(err, "retry_after_s", None),
                )
                self._fe_status[err.status] += 1
                self._fe_errors[err.code] = self._fe_errors.get(err.code, 0) + 1
                self._fe_latencies.append(lat)
                self._wall_t1 = time.perf_counter()
            else:
                res = None
                bucket = self.policy.bucket_of(req.graph)
                self.placer.record(bucket)
                self._inflight += 1
                self._max_inflight = max(self._max_inflight, self._inflight)
                win = self._windows.get(bucket)
                if win is None:
                    win = _Window(bucket, t_arrival + self.window_s)
                    self._windows[bucket] = win
                    self._flush_cv.notify()  # new earliest deadline maybe
                win.requests.append(req)
                win.arrivals.append(t_arrival)
                win.futures.append(fut)
                if len(win.requests) >= self.policy.max_graphs:
                    flush_now = self._flush_locked(bucket, "full")
        if res is not None:
            fut.set_result(res)  # outside the lock
        elif part_widx is not None:
            worker = self.workers[part_widx]
            done: "Future[Result]" = Future()
            done.add_done_callback(
                self._make_partition_resolver(part_widx, fut)
            )
            worker.dispatch((
                "call",
                lambda e=worker.engine, r=req, t=t_arrival:
                    e.serve_partitioned(r, t),
                done,
            ))
        elif flush_now is not None:
            self._stage_and_dispatch(*flush_now)
        return fut

    def submit(self, requests: Sequence[Request]) -> list[Result]:
        """Synchronous convenience: enqueue everything, wait for all."""
        futs = [self.submit_async(r) for r in requests]
        return [f.result() for f in futs]

    def make_request(self, graph, x, **kw) -> Request:
        """A :class:`Request` with a fresh front-end-assigned rid."""
        with self._lock:
            rid = self._rid
            self._rid += 1
        return Request(graph=graph, x=x, rid=rid, **kw)

    # -- flush ---------------------------------------------------------------
    def _flush_locked(self, bucket: tuple[int, int], reason: str):
        """Pop the bucket's window (lock held) and pick its device; the
        caller stages + dispatches outside the lock."""
        win = self._windows.pop(bucket, None)
        if win is None or not win.requests:
            return None
        widx = self.placer.pick(bucket, len(win.requests))
        if reason == "full":
            self._n_flushes_full += 1
        else:
            self._n_flushes_deadline += 1
        return widx, [win]

    def _stage_and_dispatch(self, widx: int, wins: list) -> None:
        """Assemble + stage each flushed window onto its device, then hand
        it to the worker.  Runs on the enqueueing/flusher thread so the
        host->device transfer overlaps the device's current batch."""
        worker = self.workers[widx]
        for win in wins:
            pre = None
            if len(win.requests) <= self.policy.max_graphs:
                try:
                    batch = assemble(
                        [r.graph for r in win.requests], self.policy
                    )
                    x_np = batch.batch_features([r.x for r in win.requests])
                    # place (don't commit) the feature block on the target
                    # device: committed-ness is part of the jit dispatch
                    # key, and precompile's prime warms the uncommitted
                    # variant — a committed device_put here would pay a
                    # fresh XLA compile per shape despite the warm cache
                    with jax.default_device(worker.device):
                        pre = (batch, jax.numpy.asarray(x_np))
                except Exception:
                    pre = None  # fall back to in-engine assembly
            done: "Future[list[Result]]" = Future()
            done.add_done_callback(
                self._make_resolver(widx, win.futures, len(win.requests))
            )
            worker.dispatch(
                ("group", (win.requests, win.arrivals, pre), done)
            )

    def _make_resolver(self, widx: int, futures: list, n: int):
        def _resolve(done: "Future") -> None:
            exc = done.exception()
            results = None if exc is not None else done.result()
            with self._lock:
                self._inflight -= n
                self.placer.done(widx, n)
                self._wall_t1 = time.perf_counter()
            if exc is not None:
                # engine misconfiguration (serve_group's only raise path);
                # surface it on every waiting future
                for f in futures:
                    f.set_exception(exc)
                return
            for f, r in zip(futures, results):
                f.set_result(r)

        return _resolve

    def _make_partition_resolver(self, widx: int, fut: "Future"):
        def _resolve(done: "Future") -> None:
            exc = done.exception()
            with self._lock:
                self._inflight -= 1
                self.placer.done(widx, 1)
                self._wall_t1 = time.perf_counter()
            if exc is not None:
                fut.set_exception(exc)
            else:
                fut.set_result(done.result())

        return _resolve

    def _flush_loop(self) -> None:
        """Deadline clock: sleep until the earliest open window expires,
        flush everything due, repeat."""
        while True:
            with self._lock:
                if self._closed and not self._windows:
                    return
                now = time.perf_counter()
                due: list[tuple[int, list]] = []
                next_deadline = None
                for bucket in list(self._windows):
                    win = self._windows[bucket]
                    if win.deadline <= now:
                        flushed = self._flush_locked(bucket, "deadline")
                        if flushed is not None:
                            due.append(flushed)
                    elif (
                        next_deadline is None or win.deadline < next_deadline
                    ):
                        next_deadline = win.deadline
                if not due:
                    timeout = (
                        None if next_deadline is None
                        else max(0.0, next_deadline - now)
                    )
                    self._flush_cv.wait(timeout=timeout)
                    continue
            for widx, wins in due:
                self._stage_and_dispatch(widx, wins)

    # -- startup warmth (PR 7) -----------------------------------------------
    def precompile(
        self,
        profile: TrafficProfile | None = None,
        *,
        max_shapes: int | None = None,
    ) -> AsyncPrecompileReport:
        """Warm each device's *assigned* buckets on its own worker thread.

        The placer is seeded from the (persisted) profile, then every
        worker precompiles the profile subset its device was assigned —
        so a revived multi-device engine takes all of its XLA traces off
        the request path, and no device wastes startup warming a bucket
        it will never be handed.
        """
        if not self._started:
            raise RuntimeError("call start() before precompile()")
        if profile is None and self.store is not None:
            profile = self.store.load_profile()
        if profile is None:
            profile = self.profile
        with self._lock:
            self.placer.plan(profile)
            subsets = [
                profile.subset(self.placer.buckets_for(i))
                for i in range(len(self.workers))
            ]
        t0 = time.perf_counter()
        futs: list[Future] = []
        for w, sub in zip(self.workers, subsets):
            fut: Future = Future()
            futs.append(fut)
            w.dispatch((
                "call",
                (lambda e=w.engine, s=sub: e.precompile(
                    s, max_shapes=max_shapes
                )),
                fut,
            ))
        rep = AsyncPrecompileReport()
        for w, fut in zip(self.workers, futs):
            r: PrecompileReport = fut.result()
            rep.n_shapes += r.n_shapes
            rep.n_store_hits += r.n_store_hits
            rep.n_compiled += r.n_compiled
            rep.n_searches += r.n_searches
            rep.n_traces += r.n_traces
            rep.per_device[str(w.device)] = r.as_dict()
        rep.wall_s = time.perf_counter() - t0
        return rep

    # -- reporting -----------------------------------------------------------
    def placement(self) -> dict[str, list[str]]:
        """Bucket -> device labels, for inspection and tests."""
        with self._lock:
            return {
                f"{v}x{d}": [str(self.devices[i]) for i in homes]
                for (v, d), homes in sorted(self.placer.assignment.items())
            }

    def stats(self) -> AsyncEngineStats:
        """Merged per-request report across every device worker."""
        with self._lock:
            lat = list(self._fe_latencies)
            status = dict(self._fe_status)
            errors = dict(self._fe_errors)
            n_requests = self._n_requests
            wall = (
                (self._wall_t1 - self._wall_t0)
                if self._wall_t0 is not None else 0.0
            )
            n_full = self._n_flushes_full
            n_deadline = self._n_flushes_deadline
            max_inflight = self._max_inflight
        per_device: dict[str, EngineStats] = {}
        n_served = 0
        for w in self.workers:
            s = w.engine.stats()
            per_device[str(w.device)] = s
            lat.extend(w.engine._latencies)
            status[STATUS_OK] += s.n_ok
            status[STATUS_REJECTED] += s.n_rejected
            status[STATUS_FAILED] += s.n_failed
            status[STATUS_DEGRADED] += s.n_degraded
            n_served += s.n_ok + s.n_degraded
            for code, n in s.errors.items():
                errors[code] = errors.get(code, 0) + n
        lat_ms = np.asarray(lat, dtype=np.float64) * 1e3
        return AsyncEngineStats(
            n_requests=n_requests,
            n_devices=len(self.devices),
            wall_s=wall,
            graphs_per_sec=n_served / wall if wall > 0 else 0.0,
            p50_ms=float(np.percentile(lat_ms, 50)) if len(lat_ms) else 0.0,
            p99_ms=float(np.percentile(lat_ms, 99)) if len(lat_ms) else 0.0,
            n_ok=status[STATUS_OK],
            n_rejected=status[STATUS_REJECTED],
            n_failed=status[STATUS_FAILED],
            n_degraded=status[STATUS_DEGRADED],
            n_flushes_full=n_full,
            n_flushes_deadline=n_deadline,
            max_inflight=max_inflight,
            errors=errors,
            placement=self.placement(),
            per_device={k: v.as_dict() for k, v in per_device.items()},
        )
