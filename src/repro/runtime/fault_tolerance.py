"""Fault-tolerant step runner: retry, restore, straggler mitigation.

At 1000+ node scale, node failure is routine: the runner treats every
step as retryable, restores from the last atomic checkpoint after a
failure (the deterministic data pipeline replays the exact stream), and
monitors per-step latency for stragglers.

On CPU this is exercised by fault-injection tests
(tests/test_fault_tolerance.py): steps that raise are retried, and a
simulated preemption mid-run resumes to bit-identical parameters.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable

from .resilience import RetryPolicy

log = logging.getLogger("repro.runtime")


@dataclass
class StragglerMonitor:
    """Flags steps slower than ``threshold`` x the running median.

    On a real cluster the mitigation hook would trigger data re-balancing
    or hot-spare swap-in; here it records and logs (the decision logic is
    what we can test without hardware).
    """

    threshold: float = 3.0
    window: int = 50
    times: list[float] = field(default_factory=list)
    flagged: list[int] = field(default_factory=list)
    on_straggler: Callable[[int, float, float], None] | None = None

    def record(self, step: int, seconds: float) -> bool:
        self.times.append(seconds)
        hist = self.times[-self.window :]
        if len(hist) < 8:
            return False
        med = sorted(hist)[len(hist) // 2]
        if seconds > self.threshold * med:
            self.flagged.append(step)
            log.warning("straggler step %d: %.3fs vs median %.3fs", step, seconds, med)
            if self.on_straggler:
                self.on_straggler(step, seconds, med)
            return True
        return False


@dataclass
class ResilientRunner:
    """Runs a step function with retry + checkpoint/restore semantics.

    step_fn(state, batch) -> (state, metrics).  ``state`` is an opaque
    pytree; save_fn/restore_fn bind it to a Checkpointer.
    """

    step_fn: Callable[[Any, Any], tuple[Any, dict]]
    save_fn: Callable[[int, Any], None]
    restore_fn: Callable[[], tuple[int, Any]]  # -> (step, state)
    checkpoint_every: int = 50
    max_retries: int = 3
    backoff_s: float = 0.0
    monitor: StragglerMonitor = field(default_factory=StragglerMonitor)

    @property
    def retry_policy(self) -> RetryPolicy:
        """The shared retry/backoff core (same machinery the serving
        engine's degradation ladder uses)."""
        return RetryPolicy(max_retries=self.max_retries,
                           backoff_s=self.backoff_s)

    def run(self, state, batches, start_step: int = 0, num_steps: int = 100):
        """Iterate ``batches`` (indexable by step) for num_steps.

        ``metrics_log`` holds exactly one entry per *surviving* step: when
        a restore rolls ``step`` back, entries for the steps about to be
        replayed are truncated, so a replayed step never appears twice.
        """
        policy = self.retry_policy
        step = start_step
        metrics_log: list[dict] = []
        while step < start_step + num_steps:
            batch = batches(step)
            retries = 0
            while True:
                t0 = time.monotonic()
                try:
                    state, metrics = self.step_fn(state, batch)
                    break
                except Exception as e:  # noqa: BLE001 — any step fault
                    retries += 1
                    log.warning("step %d failed (%s), retry %d", step, e, retries)
                    if retries > self.max_retries:
                        log.error("step %d exhausted retries; restoring", step)
                        step, state = self.restore_fn()
                        retries = 0
                        batch = batches(step)
                        # drop metrics for the steps we are about to
                        # replay, so each step is logged exactly once —
                        # and don't sleep a backoff on the restore itself
                        del metrics_log[max(step - start_step, 0):]
                        continue
                    policy.sleep_for(retries - 1)
            self.monitor.record(step, time.monotonic() - t0)
            metrics_log.append({"step": step, **metrics})
            step += 1
            if step % self.checkpoint_every == 0:
                self.save_fn(step, state)
        self.save_fn(step, state)
        return state, metrics_log
