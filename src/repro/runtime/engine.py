"""Serving runtime: a request stream -> bucketized batches -> compiled Programs.

The executable stack below this module is single-graph: ``repro.compile``
searches + lowers one :class:`~repro.api.Program` per graph, and every
distinct input shape costs a fresh XLA compile.  Real GNN serving traffic
is the opposite shape — many small graphs, few distinct sizes (the paper
batches 64/32 graphs per inference, Sec. 5.1.2).  The
:class:`InferenceEngine` turns the stream into batched device work:

1. **Admit**: every request is validated at the boundary
   (:func:`repro.runtime.resilience.validate_request` — CSR invariants,
   float32 features) and checked against the policy's oversized-graph caps
   and the ``max_inflight_graphs`` load-shedding limit.  A request that
   fails admission returns a typed ``rejected`` :class:`Result`; it never
   joins a batch, so it cannot poison healthy neighbors.
2. **Route**: every admitted request's graph maps to a pow2 padding bucket
   (:class:`repro.graphs.batching.BucketPolicy`).
3. **Assemble**: up to ``max_graphs`` same-bucket graphs become one
   block-diagonal micro-batch with per-graph segment ids
   (:func:`repro.graphs.batching.assemble`), padded so every batch of a
   bucket presents identical device shapes.  Per-request deadlines are
   enforced here: an expired request fails with ``DeadlineExceeded``
   instead of occupying a slot.
4. **Compile-or-load**: one Program per (workload fingerprint, bucket,
   tier, hw) key through an LRU cache — the mapper search and the XLA
   compile are paid once per bucket, not once per request.  With a
   persistent :class:`~repro.runtime.store.ProgramStore` attached they
   are paid once per bucket *ever*: fresh compiles persist to disk,
   restarts load instead of searching, and
   :meth:`InferenceEngine.precompile` replays the recorded
   :class:`~repro.graphs.batching.TrafficProfile` at startup so even the
   XLA traces happen off the request path (zero-cold-start serving).
5. **Execute with fault isolation**: each micro-batch walks the
   degradation ladder (:func:`repro.runtime.resilience.default_ladder` —
   searched+Pallas -> searched+jnp -> default schedule) with bounded
   retries per tier; non-finite outputs raise instead of returning
   silently.  A multi-graph batch that faults at every tier is re-run
   request by request (**solo-retry quarantine**), so one poisoned request
   fails alone with a typed status while its neighbors still return
   bit-identical outputs.  ``submit()`` never raises for a per-request
   cause.

The engine reports graphs/sec, p50/p99 request latency and the full
resilience ledger — per-status counts, retries, downgrades, straggler
batches, and an error-taxonomy histogram (:meth:`InferenceEngine.stats`);
``benchmarks/serve_gnn.py`` holds the throughput evidence (and, under
``--chaos``, the fault-isolation evidence) against naive per-graph
compile+run.
"""
from __future__ import annotations

import json
import time
import warnings
from collections import OrderedDict
from dataclasses import asdict, dataclass, field, replace as dc_replace
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..api import Program, compile as _compile, trace_count
from ..core.cost_model import GNNLayerWorkload
from ..core.hw import AcceleratorConfig, DEFAULT_ACCEL, DEFAULT_LATENCY, LatencyModel
from ..core.schedule import ModelSchedule
from ..kernels.common import measure_wall
from ..graphs.batching import (
    BucketPolicy,
    GraphBatch,
    TrafficProfile,
    assemble,
    bucketize,
    next_pow2,
)
from ..graphs.csr import CSRGraph, block_diagonal, from_edges
from .fault_tolerance import StragglerMonitor
from .faults import FaultInjector
from .store import ProgramStore, store_key
from .resilience import (
    STATUS_DEGRADED,
    STATUS_FAILED,
    STATUS_OK,
    STATUS_REJECTED,
    DeadlineExceeded,
    EngineOverloaded,
    NumericalFault,
    OversizedGraph,
    RetryPolicy,
    ServingError,
    Tier,
    as_serving_error,
    backlog_retry_after,
    default_ladder,
    validate_request,
)


@dataclass(frozen=True)
class Request:
    """One inference request: a graph and its node features.

    ``deadline_s`` is an optional per-request latency budget, measured
    from ``submit()`` entry; a request whose deadline has already expired
    when its micro-batch assembles fails with ``DeadlineExceeded`` instead
    of occupying batch slots.
    """

    graph: CSRGraph
    x: np.ndarray  # (n_nodes, f_in) float32
    rid: int = 0
    deadline_s: float | None = None


@dataclass(frozen=True)
class Result:
    """Per-request output plus serving metadata.

    ``status`` is the per-request verdict (see
    :mod:`repro.runtime.resilience`): ``ok`` / ``degraded`` carry an
    ``output`` (the ``readout`` vector ``(f_out,)`` — or the
    ``(n_nodes, f_out)`` node logits when the engine runs with
    ``readout=None``); ``rejected`` / ``failed`` carry ``None`` plus the
    typed cause in ``error_type`` (taxonomy code) and ``error`` (message).
    """

    rid: int
    output: np.ndarray | None
    bucket: tuple[int, int] | None
    latency_s: float  # this request's enqueue -> result wall time
    status: str = STATUS_OK
    error: str | None = None
    error_type: str | None = None
    tier: str | None = None  # execution tier that produced the output
    n_retries: int = 0
    retry_after_s: float | None = None  # backpressure hint on shed load
    #: which device served this request (the engine's ``device_label``;
    #: the async front-end sets one per worker).  ``None`` = default.
    device: str | None = None
    #: partitioned-lane telemetry: how many partitions served this
    #: request (0 = the normal batched path), the partitioned wall
    #: clock, and the planner's chosen plan kind
    #: (``row_stream`` / ``feature_chunk`` / ``pp_shard``).
    n_partitions: int = 0
    partition_wall_s: float = 0.0
    plan: str | None = None

    @property
    def ok(self) -> bool:
        """True when ``output`` is a served answer (ok or degraded)."""
        return self.status in (STATUS_OK, STATUS_DEGRADED)


@dataclass
class EngineStats:
    """Aggregate serving report: throughput, latency percentiles, and the
    resilience ledger (statuses, retries, downgrades, stragglers).

    ``p50_ms`` / ``p99_ms`` are **per-request** enqueue -> result wall
    times (a request that waits behind earlier micro-batches of the same
    ``submit`` call — or in the async front-end's arrival queue — is
    charged that wait), not per-micro-batch wall; ``batch_p50_ms`` is the
    per-micro-batch median for comparison."""

    n_requests: int
    n_batches: int
    n_buckets: int
    wall_s: float
    graphs_per_sec: float
    p50_ms: float
    p99_ms: float
    #: ``search_s + trace_s`` — kept as the historical aggregate so older
    #: dashboards/benchmark JSON keep a comparable column.
    compile_s: float
    search_s: float  # mapper search + Program packaging (cold buckets)
    trace_s: float  # wall of executions that took new XLA traces/compiles
    cache_hits: int
    cache_misses: int
    cache_evictions: int
    n_searches: int = 0  # mapper searches actually run (store hits skip them)
    store_hits: int = 0  # programs loaded from the persistent store
    store_misses: int = 0
    store_corrupt: int = 0  # artifacts that existed but failed to load
    n_ok: int = 0
    n_rejected: int = 0
    n_failed: int = 0
    n_degraded: int = 0
    n_retries: int = 0  # execution attempts repeated after a fault
    n_downgrades: int = 0  # micro-batches that left their preferred tier
    n_solo_retries: int = 0  # quarantine re-runs of single requests
    n_stragglers: int = 0  # micro-batches flagged by the StragglerMonitor
    errors: dict = field(default_factory=dict)  # taxonomy code -> count
    batch_p50_ms: float = 0.0  # median micro-batch wall (drain-rate probe)
    n_partitioned: int = 0  # oversized requests served via a partition plan
    partition_wall_s: float = 0.0  # wall spent inside the partitioned lane
    partition_plans: dict = field(default_factory=dict)  # plan kind -> count

    def as_dict(self) -> dict:
        return asdict(self)


@dataclass
class PrecompileReport:
    """What :meth:`InferenceEngine.precompile` did at startup: how many
    bucket shapes it warmed, how many Programs came from the persistent
    store vs fresh compiles (and how many of those ran the mapper), how
    many XLA traces it took off the request path, and the wall clock."""

    n_shapes: int = 0
    n_store_hits: int = 0
    n_compiled: int = 0  # store misses compiled in-process
    n_searches: int = 0  # mapper searches among the compiles
    n_traces: int = 0  # XLA traces taken while warming
    wall_s: float = 0.0

    def as_dict(self) -> dict:
        return asdict(self)


@dataclass
class RerankReport:
    """What :meth:`InferenceEngine.rerank_topk` did: how many hot buckets
    it re-ranked, how many candidate schedules it measured, which buckets
    swapped to a measured-faster schedule (``swaps`` maps ``"VxD"`` to the
    incumbent/winner digests and walls), and how many XLA traces the whole
    pass took — all off the request path."""

    n_buckets: int = 0
    n_candidates: int = 0  # candidate schedules compiled and measured
    n_swapped: int = 0  # buckets whose pinned schedule changed
    n_traces: int = 0  # XLA traces taken while measuring + re-priming
    wall_s: float = 0.0
    swaps: dict = field(default_factory=dict)  # "VxD" -> swap detail

    def as_dict(self) -> dict:
        return asdict(self)


class ProgramCache:
    """LRU over compiled Programs, keyed by (fingerprint, bucket, hw)."""

    def __init__(self, capacity: int = 32):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._programs: OrderedDict[tuple, Program] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._programs)

    def get(self, key: tuple) -> Program | None:
        prog = self._programs.get(key)
        if prog is None:
            self.misses += 1
            return None
        self._programs.move_to_end(key)
        self.hits += 1
        return prog

    def peek(self, key: tuple) -> Program | None:
        """Non-counting lookup (used to derive tier twins)."""
        return self._programs.get(key)

    def put(self, key: tuple, prog: Program) -> None:
        self._programs[key] = prog
        self._programs.move_to_end(key)
        while len(self._programs) > self.capacity:
            self._programs.popitem(last=False)
            self.evictions += 1


def _chunks(seq: list, size: int):
    for i in range(0, len(seq), size):
        yield seq[i : i + size]


class InferenceEngine:
    """Bucketized multi-graph serving over an LRU of compiled Programs.

    One engine serves one model (``dims`` layer shapes + ``params``) under
    one objective on one accelerator config.  ``schedule`` pins an
    explicit :class:`~repro.core.schedule.ModelSchedule` for every bucket;
    by default each bucket's first micro-batch runs the model-level mapper
    search once and the LRU amortizes it over the stream.

    ``readout`` is the per-graph reduction (``"mean"``/``"sum"``/``"max"``)
    — or ``None`` to return per-graph node logits instead.

    Resilience knobs:

    * ``retry`` — bounded backoff per ladder tier
      (:class:`~repro.runtime.resilience.RetryPolicy`);
    * ``ladder`` — explicit degradation tiers (default:
      :func:`~repro.runtime.resilience.default_ladder` of ``use_pallas``);
    * ``max_inflight_graphs`` — admission-control cap per ``submit`` call;
      excess requests are shed with ``rejected`` + ``retry_after_s``;
    * ``fault_injector`` — a
      :class:`~repro.runtime.faults.FaultInjector` consulted at the
      compile and run boundaries (chaos testing);
    * ``check_numerics`` — treat non-finite outputs as faults (retried,
      then ``failed``) instead of returning them silently;
    * ``monitor`` — per-micro-batch latency
      :class:`~repro.runtime.fault_tolerance.StragglerMonitor`;
    * ``store`` — a persistent
      :class:`~repro.runtime.store.ProgramStore` backing the LRU:
      compiled Programs and the traffic profile survive the process, and
      :meth:`precompile` warms the recorded bucket grid at startup.
    """

    def __init__(
        self,
        dims: Sequence[tuple[int, int]],
        params=None,
        *,
        kind: str = "gcn",
        objective: str = "cycles",
        hw: AcceleratorConfig = DEFAULT_ACCEL,
        policy: BucketPolicy = BucketPolicy(),
        schedule: ModelSchedule | None = None,
        cache_capacity: int = 32,
        use_pallas: bool = False,
        readout: str | None = "mean",
        retry: RetryPolicy = RetryPolicy(max_retries=2, backoff_s=0.0),
        ladder: Sequence[Tier] | None = None,
        max_inflight_graphs: int | None = None,
        fault_injector: FaultInjector | None = None,
        check_numerics: bool = True,
        monitor: StragglerMonitor | None = None,
        store: ProgramStore | None = None,
        donate: bool = True,
        device_label: str | None = None,
        partition_oversized: bool = False,
        max_partitions: int = 256,
    ):
        self.dims = [(int(fi), int(fo)) for fi, fo in dims]
        if not self.dims:
            raise ValueError("engine needs at least one layer shape")
        self.params = params
        self.kind = kind
        self.objective = objective
        self.hw = hw
        self.policy = policy
        self.schedule = schedule
        self.use_pallas = use_pallas
        self.readout = readout
        self.retry = retry
        self.ladder = (
            tuple(ladder) if ladder is not None else default_ladder(use_pallas)
        )
        if not self.ladder:
            raise ValueError("the degradation ladder needs at least one tier")
        self.max_inflight_graphs = max_inflight_graphs
        self.injector = fault_injector
        self.check_numerics = check_numerics
        #: donate feature buffers to the executables.  The async front-end
        #: turns this off: it stages features onto the target device ahead
        #: of dispatch, and a donated pre-staged buffer could not survive a
        #: ladder retry.  The flag is part of the executable cache key, so
        #: an engine must pick one mode and keep it (precompile honors it).
        self.donate = donate
        #: stamped on every Result this engine produces (the async
        #: front-end labels each per-device engine with its jax device).
        self.device_label = device_label
        #: serve oversized admissions through a planner-chosen partition
        #: (:func:`repro.graphs.partition.plan_partition`) instead of a
        #: typed rejection.  Off by default: the PR 6 rejection contract
        #: stays intact unless a deployment opts in.
        self.partition_oversized = partition_oversized
        self.max_partitions = max_partitions
        self.monitor = monitor if monitor is not None else StragglerMonitor()
        self.cache = ProgramCache(cache_capacity)
        #: optional persistent backing for the program cache: a miss here
        #: consults the store before compiling, and every fresh compile is
        #: persisted, so a restarted engine loads instead of searching.
        self.store = store
        #: recorded bucket traffic.  Seeded from the store's persisted
        #: profile (bucket heat survives the process) and re-persisted
        #: after every ``submit``; ``precompile()`` replays it at startup.
        self.profile: TrafficProfile = TrafficProfile()
        if store is not None:
            prior = store.load_profile()
            if prior is not None:
                self.profile = prior
        # a fitted latency model calibrates every schedule this engine
        # searches.  When the caller left ``hw.latency`` at the identity
        # default, resolve one: the ``REPRO_LATENCY_MODEL`` env override
        # first, then the store's fitted model for the running jax
        # backend (written by ``repro.core.calibrate.calibrate``).  An
        # explicit non-default ``hw.latency`` always wins.
        if self.hw.latency == DEFAULT_LATENCY:
            lm = LatencyModel.from_env()
            if lm is None and store is not None:
                from ..core.calibrate import backend_fingerprint

                lm = store.load_latency_model(backend_fingerprint())
            if lm is not None:
                self.hw = dc_replace(self.hw, latency=lm)
        #: searched schedules keyed by (v_bucket, d_bucket): the mapper
        #: runs once per bucket; slot-count variants of the bucket (partial
        #: tail batches) reuse the schedule and only pay their XLA compile.
        self._schedules: dict[tuple[int, int], ModelSchedule] = {}
        # accumulators behind stats()
        self._latencies: list[float] = []  # per-request enqueue -> result
        self._batch_walls: list[float] = []  # per-micro-batch wall times
        self._buckets_seen: set[tuple[int, int]] = set()
        self._n_requests = 0
        self._n_batches = 0
        self._wall_s = 0.0
        self._search_s = 0.0  # mapper search + Program packaging
        self._trace_s = 0.0  # wall of executions that took new XLA traces
        self._n_searches = 0  # mapper searches actually run
        self._status_counts = {s: 0 for s in
                               (STATUS_OK, STATUS_REJECTED, STATUS_FAILED,
                                STATUS_DEGRADED)}
        self._errors: dict[str, int] = {}
        self._n_retries = 0
        self._n_downgrades = 0
        self._n_solo_retries = 0
        #: per-bucket micro-batch sequence numbers (fault-injection plans
        #: target (bucket, batch_index); solo-retry batches get their own)
        self._batch_seq: dict[tuple[int, int], int] = {}
        #: partition plans keyed by the graph's nominal bucket — planning
        #: (a few mapper searches) is paid once per oversized shape class
        self._plans: dict[tuple[int, int], "PartitionPlan"] = {}
        self._n_partitioned = 0
        self._partition_wall_s = 0.0
        self._partition_plans: dict[str, int] = {}

    @property
    def f_in(self) -> int:
        return self.dims[0][0]

    def init(self, rng: jax.Array):
        """Initialize (and adopt) model parameters for the served dims."""
        keys = jax.random.split(rng, len(self.dims))
        from ..gnn.layers import init_layer

        self.params = [
            init_layer(self.kind, k, fi, fo)
            for k, (fi, fo) in zip(keys, self.dims)
        ]
        return self.params

    # -- program cache -------------------------------------------------------
    def _shape_key(
        self, v_bucket: int, v_total: int, d_bucket: int, tier: Tier
    ) -> tuple:
        return (
            tuple(self.dims),
            self.kind,
            self.objective,
            (tier.use_pallas, tier.searched),
            # v_bucket AND v_total: buckets whose v_bucket * slots products
            # coincide (e.g. 32x2 and 64x1) must not share a Program
            (v_bucket, v_total, d_bucket),
            # canonical JSON string: asdict(hw) nests the latency-model
            # mapping, which is not hashable as a tuple of items
            json.dumps(asdict(self.hw), sort_keys=True),
        )

    def _cache_key(self, batch: GraphBatch, tier: Tier) -> tuple:
        return self._shape_key(
            batch.v_bucket, batch.v_total, batch.d_bucket, tier
        )

    def _store_key(self, batch: GraphBatch, tier: Tier) -> dict:
        """The persistent twin of :meth:`_cache_key` (see
        :func:`repro.runtime.store.store_key`)."""
        return store_key(
            self.dims,
            (batch.v_bucket, batch.d_bucket),
            batch.v_total,
            kind=self.kind,
            objective=self.objective,
            use_pallas=tier.use_pallas,
            searched=tier.searched,
            hw=self.hw,
        )

    def _default_schedule(self) -> ModelSchedule:
        """The ladder's last rung: a fixed sp_opt/AC schedule that needs
        no mapper search and no Pallas toolchain."""
        return ModelSchedule.from_policies("sp_opt", "AC", self.dims)

    def _program_for(self, batch: GraphBatch, tier: Tier) -> Program:
        """Compile — or load — the bucket's Program for one ladder tier.

        Resolution order on a memory-cache miss: the persistent
        :class:`~repro.runtime.store.ProgramStore` (a restarted engine
        loads the searched schedule instead of re-running the mapper; a
        corrupt artifact is a counted miss, never a crash), then the
        cached Pallas twin via :meth:`Program.degraded`, then a fresh
        compile — which is persisted back to the store atomically.  The
        mapper searches on the bucket's first micro-batch; later batches
        of the bucket reuse the schedule *and* the jitted executables
        (the Program's exec cache is shared across ``bind``).
        """
        key = self._cache_key(batch, tier)
        prog = self.cache.get(key)
        if prog is None:
            if self.injector is not None:
                self.injector.on_compile((batch.v_bucket, batch.d_bucket))
            bucket = (batch.v_bucket, batch.d_bucket)
            skey = None
            if self.store is not None:
                skey = self._store_key(batch, tier)
                prog = self.store.get(skey)
            if prog is None:
                t0 = time.perf_counter()
                twin = None
                if tier.searched and not tier.use_pallas:
                    pallas_tier = Tier("pallas+searched", True, True)
                    twin = self.cache.peek(
                        self._cache_key(batch, pallas_tier)
                    )
                if twin is not None:
                    prog = twin.degraded(use_pallas=False)
                else:
                    wls = [
                        GNNLayerWorkload(
                            batch.graph.nnz, fi, fo, name=f"layer{i}"
                        )
                        for i, (fi, fo) in enumerate(self.dims)
                    ]
                    if tier.searched:
                        sched = self.schedule or self._schedules.get(bucket)
                    else:
                        sched = self._default_schedule()
                    if tier.searched and sched is None:
                        self._n_searches += 1
                    prog = _compile(
                        wls,
                        hw=self.hw,
                        objective=self.objective,
                        schedule=sched,
                        kind=self.kind,
                        use_pallas=tier.use_pallas,
                    )
                self._search_s += time.perf_counter() - t0
                if skey is not None:
                    self.store.put(skey, prog)
            if tier.searched:
                self._schedules.setdefault(bucket, prog.schedule)
            self.cache.put(key, prog)
        return prog

    # -- ahead-of-time warmup ------------------------------------------------
    def _synthetic_batch(
        self, v_bucket: int, d_bucket: int, slots: int
    ) -> GraphBatch:
        """A stand-in micro-batch with the bucket's exact device shapes:
        ``slots`` member graphs of ``v_bucket`` nodes each (rings, or
        isolated self-loops when the degree bucket is too narrow for a
        ring), so binding at ``pad_degree=d_bucket`` and reading out over
        ``slots`` segments warms precisely the executable a real batch of
        this shape will request.  Only shapes matter here — the adjacency
        values never reach a served answer."""
        if d_bucket >= 3 and v_bucket >= 3:
            src = np.arange(v_bucket)
            dst = (src + 1) % v_bucket
            member = from_edges(
                v_bucket, np.concatenate([src, dst]), np.concatenate([dst, src])
            )
        else:
            member = from_edges(
                v_bucket, np.zeros(0, np.int64), np.zeros(0, np.int64)
            )
        batched = block_diagonal([member] * slots)
        segment_ids = np.repeat(
            np.arange(slots, dtype=np.int32), v_bucket
        )
        return GraphBatch(
            graph=batched,
            segment_ids=segment_ids,
            sizes=np.full(slots, v_bucket, dtype=np.int64),
            v_bucket=v_bucket,
            d_bucket=d_bucket,
        )

    def precompile(
        self,
        profile: TrafficProfile | None = None,
        *,
        max_shapes: int | None = None,
    ) -> PrecompileReport:
        """Warm the expected bucket grid ahead of traffic, hottest first.

        For every ``((v_bucket, d_bucket), slots)`` shape the
        :class:`~repro.graphs.batching.TrafficProfile` recorded (argument,
        else the store's persisted profile, else this engine's own), the
        preferred ladder tier's Program is compiled-or-loaded through the
        store-backed cache and its executable traced on a synthetic batch
        via :meth:`Program.prime <repro.api.Program.prime>` — so a revived
        engine pays mapper search *zero* times (store hits) and takes
        every XLA trace here, off the request path: the first real request
        of a warm shape re-traces nothing (``repro.trace_count()`` delta
        of 0) and runs at warm-path latency.  ``max_shapes`` bounds
        startup work to the hottest shapes.
        """
        if self.params is None:
            raise ValueError(
                "engine has no params; pass params= or call engine.init(rng)"
            )
        if profile is None and self.store is not None:
            profile = self.store.load_profile()
        if profile is None:
            profile = self.profile
        rep = PrecompileReport()
        t0 = time.perf_counter()
        shapes = profile.hot_shapes()
        if max_shapes is not None:
            shapes = shapes[:max_shapes]
        tier = self.ladder[0]
        hits0 = self.store.hits if self.store is not None else 0
        searches0 = self._n_searches
        misses0 = self.cache.misses
        with warnings.catch_warnings():
            warnings.filterwarnings("ignore", message="Some donated buffers")
            for (v_bucket, d_bucket), slots in shapes:
                batch = self._synthetic_batch(v_bucket, d_bucket, slots)
                self._buckets_seen.add((v_bucket, d_bucket))
                prog = self._program_for(batch, tier)
                bound = prog.bind(batch.graph, pad_degree=batch.d_bucket)
                t_run = time.perf_counter()
                # prime with this engine's donate flag: the jit-executable
                # cache keys on it, so a donate=False (async) engine must
                # warm donate=False executables or the first real request
                # would re-trace
                if self.readout is None:
                    n_new = bound.prime(self.params, donate=self.donate)
                else:
                    n_new = bound.prime(
                        self.params,
                        segment_ids=jnp.asarray(batch.segment_ids),
                        num_segments=batch.slots,
                        readout=self.readout,
                        donate=self.donate,
                    )
                if n_new:
                    self._trace_s += time.perf_counter() - t_run
                rep.n_shapes += 1
                rep.n_traces += n_new
        rep.n_store_hits = (
            (self.store.hits - hits0) if self.store is not None else 0
        )
        rep.n_searches = self._n_searches - searches0
        # shapes already warm in the memory cache cost neither a store
        # load nor a compile, so count compiles off the cache-miss delta
        rep.n_compiled = (self.cache.misses - misses0) - rep.n_store_hits
        rep.wall_s = time.perf_counter() - t0
        return rep

    # -- measured re-ranking -------------------------------------------------
    def rerank_topk(
        self,
        *,
        top_k: int = 4,
        max_shapes: int | None = None,
        min_improvement: float = 0.03,
        warmup: int = 1,
        iters: int = 5,
    ) -> RerankReport:
        """Re-rank every hot bucket's schedule by *measured* wall time.

        The mapper search behind each bucket minimizes the analytic cost
        model; a calibrated :class:`~repro.core.hw.LatencyModel` narrows
        the model<->hardware gap but cannot close it per schedule.  This
        pass closes the loop with actual measurements, entirely off the
        request path:

        1. for each hot bucket (hottest first, bounded by ``max_shapes``),
           take the mapper's analytic top-k
           (:func:`~repro.core.mapper.search_model_topk`) plus the
           incumbent schedule;
        2. compile each candidate with a *pinned* schedule (no search)
           and measure it on a synthetic batch of the bucket's hottest
           slot count via :func:`~repro.kernels.common.measure_wall`
           (``donate=False`` so the measurement buffer survives repeat
           runs); every measurement lands in the profile's observation
           ledger (:meth:`TrafficProfile.record_wall
           <repro.graphs.batching.TrafficProfile.record_wall>`);
        3. when the best candidate beats the incumbent by more than
           ``min_improvement`` (hysteresis against timer noise), hot-swap
           the bucket: pin the winner in the per-bucket schedule map,
           overwrite the memory-cache entry *and* the store artifact for
           every recorded slot variant, and re-prime the serving
           executables with this engine's own ``donate`` mode — so the
           next real request of the bucket re-traces nothing
           (``repro.trace_count()`` delta of 0 on the request path).
        """
        if self.params is None:
            raise ValueError(
                "engine has no params; pass params= or call engine.init(rng)"
            )
        from ..core.mapper import search_model_topk

        rep = RerankReport()
        t0 = time.perf_counter()
        traces0 = trace_count()
        tier = self.ladder[0]
        shapes = self.profile.hot_shapes()
        if max_shapes is not None:
            shapes = shapes[:max_shapes]
        # the hottest slot variant of each bucket carries the measurement
        # (hot_shapes is hottest-first); the other variants only get
        # re-primed when the bucket swaps
        hot_slots: dict[tuple[int, int], int] = {}
        variants: dict[tuple[int, int], list[int]] = {}
        for bucket, slots in shapes:
            hot_slots.setdefault(bucket, slots)
            variants.setdefault(bucket, []).append(slots)

        with warnings.catch_warnings():
            warnings.filterwarnings("ignore", message="Some donated buffers")
            for bucket, slots in hot_slots.items():
                rep.n_buckets += 1
                v_bucket, d_bucket = bucket
                batch = self._synthetic_batch(v_bucket, d_bucket, slots)
                incumbent = self._program_for(batch, tier)
                wls = [
                    GNNLayerWorkload(batch.graph.nnz, fi, fo, name=f"layer{i}")
                    for i, (fi, fo) in enumerate(self.dims)
                ]
                x = jnp.zeros((batch.graph.n_nodes, self.f_in), jnp.float32)
                seg = jnp.asarray(batch.segment_ids)

                def measure(prog: Program) -> float:
                    bound = prog.bind(batch.graph, pad_degree=batch.d_bucket)

                    def run():
                        if self.readout is None:
                            return bound.run(self.params, x, donate=False)
                        return bound.run(
                            self.params,
                            x,
                            segment_ids=seg,
                            num_segments=batch.slots,
                            readout=self.readout,
                            donate=False,
                        )

                    wall = measure_wall(run, warmup=warmup, iters=iters)
                    self.profile.record_wall(
                        bucket, batch.slots, prog.schedule_digest, wall
                    )
                    return wall

                walls: dict[str, tuple[float, Program]] = {
                    incumbent.schedule_digest: (measure(incumbent), incumbent)
                }
                for cand in search_model_topk(
                    wls, hw=self.hw, objective=self.objective, top_k=top_k
                ):
                    dig = cand.digest()
                    if dig in walls:
                        continue
                    prog = _compile(
                        wls,
                        hw=self.hw,
                        objective=self.objective,
                        schedule=cand,
                        kind=self.kind,
                        use_pallas=tier.use_pallas,
                    )
                    rep.n_candidates += 1
                    walls[dig] = (measure(prog), prog)
                best_dig, (best_wall, best_prog) = min(
                    walls.items(), key=lambda kv: kv[1][0]
                )
                inc_wall = walls[incumbent.schedule_digest][0]
                if (
                    best_dig == incumbent.schedule_digest
                    or best_wall >= inc_wall * (1.0 - min_improvement)
                ):
                    continue
                rep.n_swapped += 1
                self._schedules[bucket] = best_prog.schedule
                rep.swaps[f"{v_bucket}x{d_bucket}"] = {
                    "from": incumbent.schedule_digest,
                    "to": best_dig,
                    "incumbent_wall_s": inc_wall,
                    "winner_wall_s": best_wall,
                    "improvement": 1.0 - best_wall / inc_wall,
                }
                for sv in variants[bucket]:
                    vb = self._synthetic_batch(v_bucket, d_bucket, sv)
                    self.cache.put(self._cache_key(vb, tier), best_prog)
                    if self.store is not None:
                        self.store.put(self._store_key(vb, tier), best_prog)
                    bound = best_prog.bind(vb.graph, pad_degree=vb.d_bucket)
                    if self.readout is None:
                        bound.prime(self.params, donate=self.donate)
                    else:
                        bound.prime(
                            self.params,
                            segment_ids=jnp.asarray(vb.segment_ids),
                            num_segments=vb.slots,
                            readout=self.readout,
                            donate=self.donate,
                        )
        if self.store is not None:
            self.store.save_profile(self.profile)
        rep.n_traces = trace_count() - traces0
        rep.wall_s = time.perf_counter() - t0
        return rep

    # -- admission -----------------------------------------------------------
    def median_batch_wall(self) -> float:
        """Recent median micro-batch wall time (the engine's drain rate);
        a conservative 50 ms before the first batch completes."""
        if not self._batch_walls:
            return 0.05
        return float(np.median(self._batch_walls[-50:]))

    def _retry_after_hint(self, queue_depth: int) -> float:
        """Backpressure hint for shed load, proportional to the backlog:
        the number of micro-batches the queued graphs represent times the
        recent median batch wall — not just one request's latency — so
        shed clients back off long enough for the queue to actually drain."""
        return backlog_retry_after(
            queue_depth, self.median_batch_wall(), self.policy.max_graphs
        )

    def oversized_reason(self, graph: CSRGraph) -> str | None:
        """Why ``graph`` exceeds this engine's admission limits, or
        ``None`` — the policy caps plus the simulator's footprint check
        against ``hw.gb_capacity_bytes`` (the widest served layer sets
        the staged-intermediate width)."""
        f_max = max(max(fi, fo) for fi, fo in self.dims)
        return self.policy.oversized_reason(graph, f=f_max, hw=self.hw)

    def _admission_error(
        self, req: Request, inflight_units: int
    ) -> ServingError | None:
        """Validity, size and load checks for one request.
        ``inflight_units`` is the work already admitted this call in
        batch-slot units (a partitioned giant counts ``n_partitions``)."""
        try:
            validate_request(req, self.f_in)
            reason = self.oversized_reason(req.graph)
            if reason is not None:
                raise OversizedGraph(f"request {req.rid}: {reason}")
            if (
                self.max_inflight_graphs is not None
                and inflight_units >= self.max_inflight_graphs
            ):
                hint = self._retry_after_hint(inflight_units)
                raise EngineOverloaded(
                    f"request {req.rid}: engine at max_inflight_graphs="
                    f"{self.max_inflight_graphs}; retry after {hint:.3f}s",
                    retry_after_s=hint,
                )
        except ServingError as e:
            return e
        return None

    # -- bookkeeping ---------------------------------------------------------
    def _record(self, results: list, pos: int, res: Result,
                err: ServingError | None = None) -> None:
        if self.device_label is not None and res.device is None:
            res = dc_replace(res, device=self.device_label)
        results[pos] = res
        self._status_counts[res.status] += 1
        if err is not None:
            self._errors[err.code] = self._errors.get(err.code, 0) + 1

    # -- serving -------------------------------------------------------------
    def submit(self, requests: Sequence[Request]) -> list[Result]:
        """Serve a slice of the stream: admit -> route -> assemble -> run.

        Requests are grouped by bucket and chunked into
        ``policy.max_graphs``-sized micro-batches; every request's latency
        is its own enqueue -> result wall time (bucket-cold compiles and
        time spent waiting behind earlier micro-batches of this call
        included, so the p99 reflects what the *request* experienced, not
        what its micro-batch cost).

        Never raises for a per-request cause: malformed, oversized, shed,
        expired or faulted requests come back as typed non-``ok``
        :class:`Result`\\ s while their healthy neighbors are served
        normally.  (A missing ``params`` is an engine misconfiguration and
        still raises.)
        """
        if self.params is None:
            raise ValueError(
                "engine has no params; pass params= or call engine.init(rng)"
            )
        t_submit = time.perf_counter()
        t_arrival = [t_submit] * len(requests)
        self._n_requests += len(requests)
        results: list[Result | None] = [None] * len(requests)

        admitted: list[int] = []
        partitioned: list[int] = []
        # admission charges *work units*, not request count: a normal
        # request is one batch slot, but an oversized request fans out
        # into plan.n_partitions device launches — charging only 1 would
        # let one giant blow straight through max_inflight_graphs
        inflight_units = 0
        for pos, req in enumerate(requests):
            err = self._admission_error(req, inflight_units)
            if err is None:
                admitted.append(pos)
                inflight_units += 1
            elif self.partition_oversized and isinstance(err, OversizedGraph):
                try:
                    units = self._plan_for(req.graph).n_partitions
                except ValueError:
                    # unplannable: admit with one unit; the partitioned
                    # lane fails it with the typed OversizedGraph cause
                    units = 1
                if (
                    self.max_inflight_graphs is not None
                    and inflight_units > 0
                    and inflight_units + units > self.max_inflight_graphs
                ):
                    # over the cap *and* not first in line — shed it with
                    # a hint sized to its real backlog contribution.  An
                    # empty engine always admits one giant (units may
                    # exceed the cap outright; progress beats starvation).
                    hint = self._retry_after_hint(inflight_units + units)
                    err = EngineOverloaded(
                        f"request {req.rid}: {units} partition units would "
                        f"exceed max_inflight_graphs="
                        f"{self.max_inflight_graphs} "
                        f"({inflight_units} units in flight); "
                        f"retry after {hint:.3f}s",
                        retry_after_s=hint,
                    )
                else:
                    partitioned.append(pos)
                    inflight_units += units
                    continue
                self._record(
                    results,
                    pos,
                    Result(
                        rid=req.rid,
                        output=None,
                        bucket=None,
                        latency_s=time.perf_counter() - t_submit,
                        status=err.status,
                        error=str(err),
                        error_type=err.code,
                        retry_after_s=err.retry_after_s,
                    ),
                    err,
                )
            else:
                self._record(
                    results,
                    pos,
                    Result(
                        rid=req.rid,
                        output=None,
                        bucket=None,
                        latency_s=time.perf_counter() - t_submit,
                        status=err.status,
                        error=str(err),
                        error_type=err.code,
                        retry_after_s=getattr(err, "retry_after_s", None),
                    ),
                    err,
                )

        if admitted:
            routed = bucketize(
                [requests[i].graph for i in admitted], self.policy
            )
            with warnings.catch_warnings():
                # buffer donation is advisory; CPU warns it off
                warnings.filterwarnings(
                    "ignore", message="Some donated buffers"
                )
                for bucket_key, local_idxs in routed.items():
                    self._buckets_seen.add(bucket_key)
                    self.profile.record_request(bucket_key, len(local_idxs))
                    idxs = [admitted[j] for j in local_idxs]
                    for chunk in _chunks(idxs, self.policy.max_graphs):
                        live = self._enforce_deadlines(
                            requests, chunk, bucket_key, t_arrival, results
                        )
                        if live:
                            self._serve_batch(
                                requests, live, bucket_key, results,
                                t_arrival=t_arrival,
                            )
        if partitioned:
            with warnings.catch_warnings():
                warnings.filterwarnings(
                    "ignore", message="Some donated buffers"
                )
                for pos in partitioned:
                    self._serve_partitioned(
                        requests, pos, results, t_arrival[pos]
                    )
        self._wall_s += time.perf_counter() - t_submit
        if self.store is not None:
            self.store.save_profile(self.profile)
        return results  # type: ignore[return-value]

    def serve_group(
        self,
        requests: Sequence[Request],
        t_arrival: Sequence[float] | None = None,
        *,
        pre: tuple[GraphBatch, "jax.Array"] | None = None,
    ) -> list[Result]:
        """Serve one *pre-admitted*, same-bucket group of requests — the
        async front-end's batching-window flush path.

        The caller owns admission (the PR 6 contract puts it **before**
        queueing, so nothing malformed, oversized or shed ever reaches a
        window); this path re-checks nothing.  Per-request deadlines are
        enforced here, at the window, against each request's own
        ``t_arrival`` (its enqueue time, ``time.perf_counter()`` clock) —
        as are the reported latencies, so a request's latency is its
        queue wait plus its micro-batch, never the whole flush chunk.

        ``pre`` is an optionally pre-assembled ``(GraphBatch, features)``
        pair whose features the front-end already staged onto this
        engine's device (``jax.device_put`` ahead of dispatch, so the
        host->device transfer overlaps queueing).  It is used only when
        every request in the group is still live — a deadline drop
        changes the batch composition and falls back to re-assembly.

        Same fault contract as :meth:`submit`: never raises for a
        per-request cause.
        """
        if self.params is None:
            raise ValueError(
                "engine has no params; pass params= or call engine.init(rng)"
            )
        if not requests:
            return []
        t0 = time.perf_counter()
        if t_arrival is None:
            t_arrival = [t0] * len(requests)
        bucket_key = self.policy.bucket_of(requests[0].graph)
        self._n_requests += len(requests)
        self._buckets_seen.add(bucket_key)
        self.profile.record_request(bucket_key, len(requests))
        results: list[Result | None] = [None] * len(requests)
        idxs = list(range(len(requests)))
        with warnings.catch_warnings():
            warnings.filterwarnings("ignore", message="Some donated buffers")
            for chunk in _chunks(idxs, self.policy.max_graphs):
                live = self._enforce_deadlines(
                    requests, chunk, bucket_key, t_arrival, results
                )
                if live:
                    self._serve_batch(
                        requests, live, bucket_key, results,
                        t_arrival=t_arrival,
                        pre=pre if live == idxs else None,
                    )
        self._wall_s += time.perf_counter() - t0
        return results  # type: ignore[return-value]

    # -- partitioned lane ----------------------------------------------------
    def serve_partitioned(
        self, req: Request, t_arrival: float | None = None
    ) -> Result:
        """Serve one oversized request through the partitioned lane.

        The async front-end dispatches these as standalone worker items
        (they never join a batching window); same fault contract as
        :meth:`submit` — a planning or execution failure comes back as a
        typed non-``ok`` :class:`Result`, never an exception.
        """
        if self.params is None:
            raise ValueError(
                "engine has no params; pass params= or call engine.init(rng)"
            )
        t0 = time.perf_counter()
        results: list[Result | None] = [None]
        with warnings.catch_warnings():
            warnings.filterwarnings("ignore", message="Some donated buffers")
            self._serve_partitioned(
                [req], 0, results, t_arrival if t_arrival is not None else t0
            )
        self._n_requests += 1
        self._wall_s += time.perf_counter() - t0
        if self.store is not None:
            self.store.save_profile(self.profile)
        return results[0]

    def _plan_for(self, graph: CSRGraph):
        """The cached partition plan for this graph's shape class."""
        key = self.policy.bucket_of(graph)
        plan = self._plans.get(key)
        if plan is None:
            from ..graphs.partition import plan_partition

            # a device-pinned worker engine (async front-end) must not
            # claim the whole mesh for a pp shard
            n_devices = 1 if self.device_label is not None else len(jax.devices())
            t0 = time.perf_counter()
            plan = plan_partition(
                graph,
                self.dims,
                self.hw,
                objective=self.objective,
                n_devices=n_devices,
                allow_monolithic=False,
                max_partitions=self.max_partitions,
                max_block_rows=self.policy.max_nodes,
            )
            self._search_s += time.perf_counter() - t0
            self._plans[key] = plan
        return plan

    def _serve_partitioned(
        self, requests, pos: int, results: list, t_arr: float
    ) -> None:
        """Plan and execute one oversized request; records the Result."""
        req = requests[pos]
        t0 = time.perf_counter()
        dl = req.deadline_s
        if dl is not None and (t0 - t_arr) > dl:
            err = DeadlineExceeded(
                f"request {req.rid}: deadline {dl:.3f}s expired "
                f"({t0 - t_arr:.3f}s elapsed) before partitioned execution"
            )
            self._record(
                results, pos,
                Result(
                    rid=req.rid, output=None, bucket=None,
                    latency_s=t0 - t_arr, status=STATUS_FAILED,
                    error=str(err), error_type=err.code,
                ),
                err,
            )
            return
        bucket_key = self.policy.bucket_of(req.graph)
        try:
            plan = self._plan_for(req.graph)
        except ValueError as e:
            err = OversizedGraph(f"request {req.rid}: {e}")
            self._record(
                results, pos,
                Result(
                    rid=req.rid, output=None, bucket=bucket_key,
                    latency_s=time.perf_counter() - t_arr,
                    status=err.status, error=str(err), error_type=err.code,
                ),
                err,
            )
            return

        out, n_parts, tier_idx, n_retries, err = (
            self._execute_partitioned_ladder(req, plan)
        )
        wall = time.perf_counter() - t0
        lat = time.perf_counter() - t_arr
        self._latencies.append(lat)
        self._n_partitioned += 1
        self._partition_wall_s += wall
        self._partition_plans[plan.kind] = (
            self._partition_plans.get(plan.kind, 0) + 1
        )
        if err is not None:
            self._record(
                results, pos,
                Result(
                    rid=req.rid, output=None, bucket=bucket_key,
                    latency_s=lat, status=err.status, error=str(err),
                    error_type=err.code, n_retries=n_retries,
                    n_partitions=n_parts, partition_wall_s=wall,
                    plan=plan.kind,
                ),
                err,
            )
            return
        if tier_idx > 0:
            self._n_downgrades += 1
        tier = self.ladder[tier_idx]
        self._record(
            results, pos,
            Result(
                rid=req.rid, output=out, bucket=bucket_key, latency_s=lat,
                status=STATUS_DEGRADED if tier_idx > 0 else STATUS_OK,
                tier=tier.name, n_retries=n_retries,
                n_partitions=n_parts, partition_wall_s=wall, plan=plan.kind,
            ),
        )

    def _execute_partitioned_ladder(self, req: Request, plan):
        """Walk the degradation ladder around the whole partition loop
        (the PR 6 retry/downgrade contract, per oversized request)."""
        last: BaseException | None = None
        n_retries = 0
        n_parts = plan.n_partitions
        for tier_idx, tier in enumerate(self.ladder):
            for attempt in range(self.retry.max_attempts):
                try:
                    out, n_parts = self._execute_partitioned(req, plan, tier)
                    return out, n_parts, tier_idx, n_retries, None
                except Exception as e:  # noqa: BLE001 — isolate any fault
                    last = e
                    if attempt < self.retry.max_retries:
                        n_retries += 1
                        self._n_retries += 1
                        self.retry.sleep_for(attempt)
        assert last is not None
        return (
            None, n_parts, len(self.ladder) - 1, n_retries,
            as_serving_error(last),
        )

    def _execute_partitioned(self, req: Request, plan, tier: Tier):
        """Execute one oversized request under its plan on one tier.

        ``row_stream`` streams halo closures through store-backed
        Programs: all partitions share one (closure-bucket) Program, each
        is bound and launched without blocking — JAX's async dispatch
        double-buffers the next partition's host-side halo gather against
        the device compute — and the per-partition ``[:n_own]`` node
        slices stitch back bit-identically to the whole-graph forward.
        Returns ``(output, n_partitions)``.
        """
        g = req.graph
        x_full = np.asarray(req.x)
        if plan.kind == "row_stream":
            from ..graphs.partition import extract_row_partitions

            parts = extract_row_partitions(g, plan.block_rows, plan.n_hops)
            d_bucket = self.policy.degree_bucket(g.max_degree)
            v_max = max(p.graph.n_nodes for p in parts)
            sub_policy = BucketPolicy(
                min_nodes=next_pow2(v_max), min_degree=d_bucket, max_graphs=1
            )
            prog = None
            pending = []
            traces_before = trace_count()
            t_run = time.perf_counter()
            for part in parts:
                batch = assemble([part.graph], sub_policy)
                if prog is None:
                    self._buckets_seen.add((batch.v_bucket, batch.d_bucket))
                    self.profile.record_request(
                        (batch.v_bucket, batch.d_bucket), 1
                    )
                    prog = self._program_for(batch, tier)
                self.profile.record_batch(
                    (batch.v_bucket, batch.d_bucket), batch.slots
                )
                bound = prog.bind(batch.graph, pad_degree=batch.d_bucket)
                x_in = jnp.asarray(batch.batch_features([x_full[part.nodes]]))
                # enqueue without blocking: the device crunches this
                # partition while the host gathers the next one's halo
                pending.append(
                    (bound.run(self.params, x_in, donate=False), part.n_own)
                )
            slices = [
                np.asarray(jax.block_until_ready(o))[:n_own]
                for o, n_own in pending
            ]
            if trace_count() > traces_before:
                self._trace_s += time.perf_counter() - t_run
            h = np.concatenate(slices, axis=0)
            n_parts = len(parts)
        elif plan.kind == "feature_chunk":
            from ..graphs.partition import feature_chunk_forward

            h = feature_chunk_forward(
                g, x_full, self.params, kind=self.kind, chunk_f=plan.chunk_f
            )
            n_parts = plan.n_partitions
        elif plan.kind == "pp_shard":
            from ..graphs.partition import pp_shard_forward

            h = pp_shard_forward(
                g, x_full, self.params, kind=self.kind,
                n_devices=plan.n_partitions,
            )
            n_parts = plan.n_partitions
        else:
            raise ValueError(f"unexpected partition plan kind {plan.kind!r}")
        if self.check_numerics and not np.isfinite(h).all():
            raise NumericalFault(
                f"non-finite values in partitioned output of request "
                f"{req.rid} (plan {plan.kind}, tier {tier.name})"
            )
        if self.readout is None:
            return h, n_parts
        from ..gnn.layers import segment_readout

        seg = jnp.zeros(h.shape[0], dtype=jnp.int32)
        out = np.asarray(
            jax.block_until_ready(
                segment_readout(jnp.asarray(h), seg, 1, reduce=self.readout)
            )
        )
        return out[0], n_parts

    def _enforce_deadlines(
        self, requests, chunk, bucket_key, t_arrival, results
    ) -> list[int]:
        """Deadline check at batch-assembly time: expired requests fail
        with ``DeadlineExceeded`` and free their batch slots."""
        live = []
        for i in chunk:
            dl = requests[i].deadline_s
            elapsed = time.perf_counter() - t_arrival[i]
            if dl is not None and elapsed > dl:
                err = DeadlineExceeded(
                    f"request {requests[i].rid}: deadline {dl:.3f}s expired "
                    f"({elapsed:.3f}s elapsed) before batch assembly"
                )
                self._record(
                    results,
                    i,
                    Result(
                        rid=requests[i].rid,
                        output=None,
                        bucket=bucket_key,
                        latency_s=elapsed,
                        status=STATUS_FAILED,
                        error=str(err),
                        error_type=err.code,
                    ),
                    err,
                )
            else:
                live.append(i)
        return live

    def _serve_batch(
        self,
        requests: Sequence[Request],
        idxs: list[int],
        bucket_key: tuple[int, int],
        results: list,
        *,
        t_arrival: Sequence[float],
        solo: bool = False,
        pre: tuple[GraphBatch, "jax.Array"] | None = None,
    ) -> None:
        """Assemble and execute one micro-batch down the ladder; on a
        whole-batch fault, quarantine by re-running each member solo.

        ``pre`` skips assembly: the front-end already built the batch and
        staged its features on this engine's device (quarantine solo
        re-runs always re-assemble — their composition differs)."""
        t0 = time.perf_counter()
        if pre is not None:
            batch, x_in = pre
        else:
            batch = assemble([requests[i].graph for i in idxs], self.policy)
            x_in = batch.batch_features([requests[i].x for i in idxs])
        self.profile.record_batch(bucket_key, batch.slots)
        rids = [requests[i].rid for i in idxs]
        batch_index = self._batch_seq.get(bucket_key, 0)
        self._batch_seq[bucket_key] = batch_index + 1

        outs, tier_idx, n_retries, err = self._execute_ladder(
            batch, x_in, rids, bucket_key, batch_index
        )
        dt = time.perf_counter() - t0
        t_done = time.perf_counter()
        self._n_batches += 1
        self._batch_walls.append(dt)
        if solo:
            self._n_solo_retries += 1
        self.monitor.record(self._n_batches, dt)

        if err is not None:
            if len(idxs) > 1:
                # the batch is poisoned but we don't know by whom: re-run
                # every member alone so the poison fails solo and healthy
                # neighbors still get served (bit-identical outputs — the
                # block-diagonal batch computes each graph independently)
                for i in idxs:
                    self._serve_batch(
                        requests, [i], bucket_key, results,
                        t_arrival=t_arrival, solo=True,
                    )
                return
            lat = t_done - t_arrival[idxs[0]]
            self._latencies.append(lat)
            self._record(
                results,
                idxs[0],
                Result(
                    rid=rids[0],
                    output=None,
                    bucket=bucket_key,
                    latency_s=lat,
                    status=err.status,
                    error=str(err),
                    error_type=err.code,
                    n_retries=n_retries,
                ),
                err,
            )
            return

        tier = self.ladder[tier_idx]
        if tier_idx > 0:
            self._n_downgrades += 1
        status = STATUS_DEGRADED if tier_idx > 0 else STATUS_OK
        for i, o in zip(idxs, outs):
            lat = t_done - t_arrival[i]
            self._latencies.append(lat)
            self._record(
                results,
                i,
                Result(
                    rid=requests[i].rid,
                    output=o,
                    bucket=bucket_key,
                    latency_s=lat,
                    status=status,
                    tier=tier.name,
                    n_retries=n_retries,
                ),
            )

    def _execute_ladder(
        self,
        batch: GraphBatch,
        x_in,
        rids: list[int],
        bucket_key: tuple[int, int],
        batch_index: int,
    ):
        """Walk the degradation ladder with bounded retries per tier.

        ``x_in`` is the assembled feature block: a host ``np.ndarray`` on
        the sync path, or a ``jax.Array`` the front-end already staged on
        this engine's device (never donated — retries and other ladder
        tiers must be able to reuse it).

        Returns ``(outputs, tier_index, n_retries, error)`` — ``error`` is
        ``None`` on success, the (taxonomy-wrapped) last failure when every
        tier is exhausted.
        """
        last: BaseException | None = None
        n_retries = 0
        for tier_idx, tier in enumerate(self.ladder):
            for attempt in range(self.retry.max_attempts):
                try:
                    outs = self._attempt(
                        batch, x_in, rids, bucket_key, batch_index, tier
                    )
                    return outs, tier_idx, n_retries, None
                except Exception as e:  # noqa: BLE001 — isolate any fault
                    last = e
                    if attempt < self.retry.max_retries:
                        n_retries += 1
                        self._n_retries += 1
                        self.retry.sleep_for(attempt)
            # tier exhausted: fall through to the next rung of the ladder
        assert last is not None
        return None, len(self.ladder) - 1, n_retries, as_serving_error(last)

    def _attempt(
        self,
        batch: GraphBatch,
        x_in,
        rids: list[int],
        bucket_key: tuple[int, int],
        batch_index: int,
        tier: Tier,
    ) -> list[np.ndarray]:
        """One execution attempt on one tier (the unit of retry)."""
        prog = self._program_for(batch, tier)
        bound = prog.bind(batch.graph, pad_degree=batch.d_bucket)
        corrupt = None
        if self.injector is not None:
            corrupt = self.injector.on_run(
                bucket_key, batch_index, rids, tier.name
            )
        staged = isinstance(x_in, jax.Array)
        x = x_in if staged else jnp.asarray(x_in)
        # a staged buffer must survive retries and lower ladder tiers;
        # donating it would leave the next attempt with a dead buffer
        donate = self.donate and not staged
        traces_before = trace_count()
        t_run = time.perf_counter()
        if self.readout is None:
            out = bound.run(self.params, x, donate=donate)
        else:
            # readout over the padded slot count, not n_graphs: the
            # executable shape then depends only on the bucket, so tail
            # batches at any fill level reuse it (pad segments are sliced
            # off below)
            out = bound.run(
                self.params,
                x,
                segment_ids=jnp.asarray(batch.segment_ids),
                num_segments=batch.slots,
                readout=self.readout,
                donate=donate,
            )
        arr = np.asarray(jax.block_until_ready(out))
        wall = time.perf_counter() - t_run
        traced = trace_count() > traces_before
        if traced:
            # first execution on a cold shape: this wall is dominated by
            # the XLA trace + compile (or the persistent-cache load), so
            # attribute it to trace_s — that is exactly what precompile()
            # and the compilation cache save a revived engine.
            self._trace_s += wall
        if corrupt == "nan":
            arr = self.injector.corrupt_output(arr)
        if self.check_numerics and not np.isfinite(arr).all():
            raise NumericalFault(
                f"non-finite values in the output of bucket {bucket_key} "
                f"batch {batch_index} (tier {tier.name}, rids {rids})"
            )
        if not traced and corrupt is None:
            # clean warm run: fold the measured wall into the traffic
            # profile's observation ledger keyed by the schedule that
            # produced it — the feedback half of the predicted<->measured
            # loop that rerank_topk() re-scores candidates against.
            self.profile.record_wall(
                bucket_key, batch.slots, prog.schedule_digest, wall
            )
        if self.readout is None:
            return batch.split_nodes(arr)
        return list(arr[: batch.n_graphs])

    def stats(self) -> EngineStats:
        """The serving report over everything submitted so far."""
        lat_ms = np.asarray(self._latencies, dtype=np.float64) * 1e3
        n = len(self._latencies)
        return EngineStats(
            n_requests=self._n_requests,
            n_batches=self._n_batches,
            n_buckets=len(self._buckets_seen),
            wall_s=self._wall_s,
            graphs_per_sec=n / self._wall_s if self._wall_s > 0 else 0.0,
            p50_ms=float(np.percentile(lat_ms, 50)) if n else 0.0,
            p99_ms=float(np.percentile(lat_ms, 99)) if n else 0.0,
            batch_p50_ms=(
                float(np.median(self._batch_walls)) * 1e3
                if self._batch_walls else 0.0
            ),
            compile_s=self._search_s + self._trace_s,
            search_s=self._search_s,
            trace_s=self._trace_s,
            cache_hits=self.cache.hits,
            cache_misses=self.cache.misses,
            cache_evictions=self.cache.evictions,
            n_searches=self._n_searches,
            store_hits=self.store.hits if self.store is not None else 0,
            store_misses=self.store.misses if self.store is not None else 0,
            store_corrupt=self.store.corrupt if self.store is not None else 0,
            n_ok=self._status_counts[STATUS_OK],
            n_rejected=self._status_counts[STATUS_REJECTED],
            n_failed=self._status_counts[STATUS_FAILED],
            n_degraded=self._status_counts[STATUS_DEGRADED],
            n_retries=self._n_retries,
            n_downgrades=self._n_downgrades,
            n_solo_retries=self._n_solo_retries,
            n_stragglers=len(self.monitor.flagged),
            errors=dict(self._errors),
            n_partitioned=self._n_partitioned,
            partition_wall_s=self._partition_wall_s,
            partition_plans=dict(self._partition_plans),
        )
