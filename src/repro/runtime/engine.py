"""Serving runtime: a request stream -> bucketized batches -> compiled Programs.

The executable stack below this module is single-graph: ``repro.compile``
searches + lowers one :class:`~repro.api.Program` per graph, and every
distinct input shape costs a fresh XLA compile.  Real GNN serving traffic
is the opposite shape — many small graphs, few distinct sizes (the paper
batches 64/32 graphs per inference, Sec. 5.1.2).  The
:class:`InferenceEngine` turns the stream into batched device work:

1. **Route**: every request's graph maps to a pow2 padding bucket
   (:class:`repro.graphs.batching.BucketPolicy`).
2. **Assemble**: up to ``max_graphs`` same-bucket graphs become one
   block-diagonal micro-batch with per-graph segment ids
   (:func:`repro.graphs.batching.assemble`), padded so every batch of a
   bucket presents identical device shapes.
3. **Compile-or-load**: one Program per (workload fingerprint, bucket, hw)
   key through an LRU cache — the mapper search and the XLA compile are
   paid once per bucket, not once per request.
4. **Execute**: ``Program.run`` with segment readout through shape-keyed
   jitted executables with donated feature buffers; zero re-tracing after
   the first batch of a bucket (``repro.trace_count`` asserts it).

The engine reports graphs/sec and p50/p99 request latency
(:meth:`InferenceEngine.stats`); ``benchmarks/serve_gnn.py`` holds the
throughput evidence against naive per-graph compile+run.
"""
from __future__ import annotations

import time
import warnings
from collections import OrderedDict
from dataclasses import asdict, dataclass, field
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..api import Program, compile as _compile
from ..core.cost_model import GNNLayerWorkload
from ..core.hw import AcceleratorConfig, DEFAULT_ACCEL
from ..core.schedule import ModelSchedule
from ..graphs.batching import BucketPolicy, GraphBatch, assemble, bucketize
from ..graphs.csr import CSRGraph


@dataclass(frozen=True)
class Request:
    """One inference request: a graph and its node features."""

    graph: CSRGraph
    x: np.ndarray  # (n_nodes, f_in) float32
    rid: int = 0


@dataclass(frozen=True)
class Result:
    """Per-request output: the ``readout`` vector (f_out,) — or the
    (n_nodes, f_out) node logits when the engine runs with
    ``readout=None`` — plus serving metadata."""

    rid: int
    output: np.ndarray
    bucket: tuple[int, int]
    latency_s: float  # wall time of this request's micro-batch


@dataclass
class EngineStats:
    """Aggregate serving report (graphs/sec + latency percentiles)."""

    n_requests: int
    n_batches: int
    n_buckets: int
    wall_s: float
    graphs_per_sec: float
    p50_ms: float
    p99_ms: float
    compile_s: float  # mapper search + Program packaging (cold buckets)
    cache_hits: int
    cache_misses: int
    cache_evictions: int

    def as_dict(self) -> dict:
        return asdict(self)


class ProgramCache:
    """LRU over compiled Programs, keyed by (fingerprint, bucket, hw)."""

    def __init__(self, capacity: int = 32):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._programs: OrderedDict[tuple, Program] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._programs)

    def get(self, key: tuple) -> Program | None:
        prog = self._programs.get(key)
        if prog is None:
            self.misses += 1
            return None
        self._programs.move_to_end(key)
        self.hits += 1
        return prog

    def put(self, key: tuple, prog: Program) -> None:
        self._programs[key] = prog
        self._programs.move_to_end(key)
        while len(self._programs) > self.capacity:
            self._programs.popitem(last=False)
            self.evictions += 1


def _chunks(seq: list, size: int):
    for i in range(0, len(seq), size):
        yield seq[i : i + size]


class InferenceEngine:
    """Bucketized multi-graph serving over an LRU of compiled Programs.

    One engine serves one model (``dims`` layer shapes + ``params``) under
    one objective on one accelerator config.  ``schedule`` pins an
    explicit :class:`~repro.core.schedule.ModelSchedule` for every bucket;
    by default each bucket's first micro-batch runs the model-level mapper
    search once and the LRU amortizes it over the stream.

    ``readout`` is the per-graph reduction (``"mean"``/``"sum"``/``"max"``)
    — or ``None`` to return per-graph node logits instead.
    """

    def __init__(
        self,
        dims: Sequence[tuple[int, int]],
        params=None,
        *,
        kind: str = "gcn",
        objective: str = "cycles",
        hw: AcceleratorConfig = DEFAULT_ACCEL,
        policy: BucketPolicy = BucketPolicy(),
        schedule: ModelSchedule | None = None,
        cache_capacity: int = 32,
        use_pallas: bool = False,
        readout: str | None = "mean",
    ):
        self.dims = [(int(fi), int(fo)) for fi, fo in dims]
        if not self.dims:
            raise ValueError("engine needs at least one layer shape")
        self.params = params
        self.kind = kind
        self.objective = objective
        self.hw = hw
        self.policy = policy
        self.schedule = schedule
        self.use_pallas = use_pallas
        self.readout = readout
        self.cache = ProgramCache(cache_capacity)
        #: searched schedules keyed by (v_bucket, d_bucket): the mapper
        #: runs once per bucket; slot-count variants of the bucket (partial
        #: tail batches) reuse the schedule and only pay their XLA compile.
        self._schedules: dict[tuple[int, int], ModelSchedule] = {}
        # accumulators behind stats()
        self._latencies: list[float] = []
        self._buckets_seen: set[tuple[int, int]] = set()
        self._n_batches = 0
        self._wall_s = 0.0
        self._compile_s = 0.0

    @property
    def f_in(self) -> int:
        return self.dims[0][0]

    def init(self, rng: jax.Array):
        """Initialize (and adopt) model parameters for the served dims."""
        keys = jax.random.split(rng, len(self.dims))
        from ..gnn.layers import init_layer

        self.params = [
            init_layer(self.kind, k, fi, fo)
            for k, (fi, fo) in zip(keys, self.dims)
        ]
        return self.params

    # -- program cache -------------------------------------------------------
    def _cache_key(self, batch: GraphBatch) -> tuple:
        return (
            tuple(self.dims),
            self.kind,
            self.objective,
            self.use_pallas,
            # v_bucket AND v_total: buckets whose v_bucket * slots products
            # coincide (e.g. 32x2 and 64x1) must not share a Program
            (batch.v_bucket, batch.v_total, batch.d_bucket),
            tuple(sorted(asdict(self.hw).items())),
        )

    def _program_for(self, batch: GraphBatch) -> Program:
        """Compile (or load) the bucket's Program.  The mapper searches on
        the bucket's first micro-batch; later batches of the bucket reuse
        the schedule *and* the jitted executables (the Program's exec
        cache is shared across ``bind``)."""
        key = self._cache_key(batch)
        prog = self.cache.get(key)
        if prog is None:
            t0 = time.perf_counter()
            bucket = (batch.v_bucket, batch.d_bucket)
            wls = [
                GNNLayerWorkload(batch.graph.nnz, fi, fo, name=f"layer{i}")
                for i, (fi, fo) in enumerate(self.dims)
            ]
            prog = _compile(
                wls,
                hw=self.hw,
                objective=self.objective,
                schedule=self.schedule or self._schedules.get(bucket),
                kind=self.kind,
                use_pallas=self.use_pallas,
            )
            self._schedules.setdefault(bucket, prog.schedule)
            self._compile_s += time.perf_counter() - t0
            self.cache.put(key, prog)
        return prog

    # -- serving -------------------------------------------------------------
    def submit(self, requests: Sequence[Request]) -> list[Result]:
        """Serve a slice of the stream: route -> assemble -> run.

        Requests are grouped by bucket and chunked into
        ``policy.max_graphs``-sized micro-batches; every request's latency
        is its micro-batch's wall time (bucket-cold compiles included, so
        the p99 reflects real cold-start behavior).
        """
        if self.params is None:
            raise ValueError(
                "engine has no params; pass params= or call engine.init(rng)"
            )
        t_submit = time.perf_counter()
        for req in requests:
            if req.x.shape != (req.graph.n_nodes, self.f_in):
                raise ValueError(
                    f"request {req.rid}: features {req.x.shape} do not match "
                    f"(n_nodes={req.graph.n_nodes}, f_in={self.f_in})"
                )
        routed = bucketize([r.graph for r in requests], self.policy)

        results: list[Result | None] = [None] * len(requests)
        with warnings.catch_warnings():
            # buffer donation is advisory; CPU warns it off
            warnings.filterwarnings("ignore", message="Some donated buffers")
            for bucket_key, idxs in routed.items():
                self._buckets_seen.add(bucket_key)
                for chunk in _chunks(idxs, self.policy.max_graphs):
                    t0 = time.perf_counter()
                    batch = assemble(
                        [requests[i].graph for i in chunk], self.policy
                    )
                    prog = self._program_for(batch)
                    bound = prog.bind(batch.graph, pad_degree=batch.d_bucket)
                    x = jnp.asarray(
                        batch.batch_features([requests[i].x for i in chunk])
                    )
                    if self.readout is None:
                        out = bound.run(self.params, x, donate=True)
                        outs = batch.split_nodes(
                            np.asarray(jax.block_until_ready(out))
                        )
                    else:
                        # readout over the padded slot count, not n_graphs:
                        # the executable shape then depends only on the
                        # bucket, so tail batches at any fill level reuse
                        # it (pad segments are sliced off below)
                        out = bound.run(
                            self.params,
                            x,
                            segment_ids=jnp.asarray(batch.segment_ids),
                            num_segments=batch.slots,
                            readout=self.readout,
                            donate=True,
                        )
                        out = np.asarray(jax.block_until_ready(out))
                        outs = list(out[: batch.n_graphs])
                    dt = time.perf_counter() - t0
                    self._n_batches += 1
                    for i, o in zip(chunk, outs):
                        results[i] = Result(
                            rid=requests[i].rid,
                            output=o,
                            bucket=bucket_key,
                            latency_s=dt,
                        )
                        self._latencies.append(dt)
        self._wall_s += time.perf_counter() - t_submit
        return results  # type: ignore[return-value]

    def stats(self) -> EngineStats:
        """The serving report over everything submitted so far."""
        lat_ms = np.asarray(self._latencies, dtype=np.float64) * 1e3
        n = len(self._latencies)
        return EngineStats(
            n_requests=n,
            n_batches=self._n_batches,
            n_buckets=len(self._buckets_seen),
            wall_s=self._wall_s,
            graphs_per_sec=n / self._wall_s if self._wall_s > 0 else 0.0,
            p50_ms=float(np.percentile(lat_ms, 50)) if n else 0.0,
            p99_ms=float(np.percentile(lat_ms, 99)) if n else 0.0,
            compile_s=self._compile_s,
            cache_hits=self.cache.hits,
            cache_misses=self.cache.misses,
            cache_evictions=self.cache.evictions,
        )
