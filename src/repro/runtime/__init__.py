from .engine import (
    EngineStats,
    InferenceEngine,
    ProgramCache,
    Request,
    Result,
)
from .fault_tolerance import ResilientRunner, StragglerMonitor
from .faults import COMPILE, FaultInjector, FaultRule, InjectionEvent, kill_pallas
from .resilience import (
    STATUS_DEGRADED,
    STATUS_FAILED,
    STATUS_OK,
    STATUS_REJECTED,
    STATUSES,
    DeadlineExceeded,
    EngineOverloaded,
    InvalidRequest,
    KernelFault,
    NumericalFault,
    OversizedGraph,
    RetryPolicy,
    ServingError,
    Tier,
    default_ladder,
    validate_request,
)
