from .fault_tolerance import ResilientRunner, StragglerMonitor
