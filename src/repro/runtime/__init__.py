from .engine import (
    EngineStats,
    InferenceEngine,
    ProgramCache,
    Request,
    Result,
)
from .fault_tolerance import ResilientRunner, StragglerMonitor
