from .engine import (
    EngineStats,
    InferenceEngine,
    PrecompileReport,
    ProgramCache,
    Request,
    Result,
)
from .fault_tolerance import ResilientRunner, StragglerMonitor
from .scheduler import (
    AsyncEngine,
    AsyncEngineStats,
    AsyncPrecompileReport,
    BucketPlacer,
)
from .store import (
    ProgramStore,
    enable_persistent_compilation_cache,
    key_digest,
    store_key,
)
from .faults import COMPILE, FaultInjector, FaultRule, InjectionEvent, kill_pallas
from .resilience import (
    STATUS_DEGRADED,
    STATUS_FAILED,
    STATUS_OK,
    STATUS_REJECTED,
    STATUSES,
    DeadlineExceeded,
    EngineOverloaded,
    InvalidRequest,
    KernelFault,
    NumericalFault,
    OversizedGraph,
    RetryPolicy,
    ServingError,
    Tier,
    default_ladder,
    validate_request,
)
