"""Deterministic fault injection for the serving runtime.

The resilience layer is only trustworthy if it is *driven*: this module is
the chaos harness behind ``tests/test_resilience.py`` and the
``benchmarks/serve_gnn.py --chaos`` lane.  A seeded
:class:`FaultInjector` hooks the engine's execution boundaries:

* **run boundary** — before/after each micro-batch execution the engine
  consults :meth:`FaultInjector.on_run`, which can raise an injected
  :class:`~repro.runtime.resilience.KernelFault`, sleep a latency spike
  (flagged by the engine's
  :class:`~repro.runtime.fault_tolerance.StragglerMonitor`), or order the
  output corrupted with NaNs (caught by the engine's numerics check);
* **compile boundary** — :meth:`FaultInjector.on_compile` fires on a
  bucket's program-cache miss;
* **kernel-registry dispatch** — :func:`kill_pallas` (or any
  :func:`repro.core.registry.push_kernel_hook` wrapper) replaces resolved
  kernels at trace time, e.g. simulating the Pallas toolchain going down
  mid-stream so new buckets must degrade to the jnp tier.  Programs whose
  executables are already traced keep running — exactly how a live serving
  process experiences a backend outage.

Faults are **deterministic**: targeted rules (:class:`FaultRule`) match on
request id, bucket, micro-batch index, or tier and fire a bounded number
of times; probabilistic mixes draw from a seeded generator.  Every
injection is recorded in :attr:`FaultInjector.log` so tests and the chaos
benchmark can assert exactly what the engine survived.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from ..core.registry import pop_kernel_hook, push_kernel_hook
from .resilience import KernelFault

FAULT_KINDS = ("exception", "nan", "latency")


@dataclass
class FaultRule:
    """One targeted fault.  Unset match fields are wildcards.

    ``rid`` matches when the request is a member of the executing
    micro-batch — the way to poison *one request* so that its batch faults
    and the engine's solo-retry quarantine has to isolate it.
    ``max_fires=None`` makes the rule sticky (fires on every match,
    retries included); ``max_fires=1`` injects a transient fault that a
    single retry clears.
    """

    kind: str
    rid: int | None = None
    bucket: tuple[int, int] | None = None
    batch_index: int | None = None
    tier: str | None = None
    max_fires: int | None = None
    latency_s: float = 0.05
    fires: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"kind must be one of {FAULT_KINDS}, got {self.kind!r}"
            )

    def matches(
        self,
        bucket: tuple[int, int],
        batch_index: int,
        rids: Sequence[int],
        tier: str | None,
    ) -> bool:
        if self.max_fires is not None and self.fires >= self.max_fires:
            return False
        if self.rid is not None and self.rid not in rids:
            return False
        if self.bucket is not None and self.bucket != bucket:
            return False
        if self.batch_index is not None and self.batch_index != batch_index:
            return False
        if self.tier is not None and tier is not None and self.tier != tier:
            return False
        return True


@dataclass(frozen=True)
class InjectionEvent:
    """One recorded injection (for assertions and the chaos report)."""

    boundary: str  # "run" | "compile" | "dispatch"
    kind: str
    bucket: tuple[int, int] | None
    batch_index: int | None
    tier: str | None


class FaultInjector:
    """Seeded fault source the engine consults at its boundaries.

    ``rules`` are targeted faults checked first (in order; the first match
    fires).  The ``p_*`` knobs add a probabilistic background mix drawn
    from ``numpy.random.default_rng(seed)`` — deterministic for a fixed
    seed and call sequence.  ``sleep`` is injectable so latency-spike
    tests need not actually wait.
    """

    def __init__(
        self,
        seed: int = 0,
        *,
        rules: Sequence[FaultRule] = (),
        p_exception: float = 0.0,
        p_nan: float = 0.0,
        p_latency: float = 0.0,
        latency_s: float = 0.05,
        nan_fraction: float = 0.25,
        sleep: Callable[[float], None] = time.sleep,
    ):
        for name, p in (("p_exception", p_exception), ("p_nan", p_nan),
                        ("p_latency", p_latency)):
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")
        if p_exception + p_nan + p_latency > 1.0:
            raise ValueError("fault probabilities must sum to <= 1")
        self.rng = np.random.default_rng(seed)
        self.rules = list(rules)
        self.p_exception = p_exception
        self.p_nan = p_nan
        self.p_latency = p_latency
        self.latency_s = latency_s
        self.nan_fraction = nan_fraction
        self.sleep = sleep
        self.log: list[InjectionEvent] = []

    # -- matching ------------------------------------------------------------
    def _targeted(self, bucket, batch_index, rids, tier) -> FaultRule | None:
        for rule in self.rules:
            if rule.matches(bucket, batch_index, rids, tier):
                rule.fires += 1
                return rule
        return None

    def _drawn(self) -> str | None:
        if self.p_exception + self.p_nan + self.p_latency <= 0.0:
            return None
        r = float(self.rng.random())
        if r < self.p_exception:
            return "exception"
        if r < self.p_exception + self.p_nan:
            return "nan"
        if r < self.p_exception + self.p_nan + self.p_latency:
            return "latency"
        return None

    # -- engine-facing hooks -------------------------------------------------
    def on_compile(self, bucket: tuple[int, int]) -> None:
        """Compile-boundary hook: a matching ``exception`` rule with
        ``batch_index=COMPILE`` (-1) fails the bucket's compilation."""
        rule = self._targeted(bucket, COMPILE, (), None)
        if rule is not None and rule.kind == "exception":
            self.log.append(
                InjectionEvent("compile", "exception", bucket, COMPILE, None)
            )
            raise KernelFault(
                f"injected compile fault for bucket {bucket}"
            )

    def on_run(
        self,
        bucket: tuple[int, int],
        batch_index: int,
        rids: Sequence[int],
        tier: str | None = None,
    ) -> str | None:
        """Run-boundary hook, called once per execution attempt.

        Raises :class:`KernelFault` for an ``exception`` fault, sleeps (and
        returns ``"latency"``) for a latency spike, or returns ``"nan"``
        when the caller must corrupt this attempt's output.  Returns
        ``None`` when no fault fires.
        """
        rule = self._targeted(bucket, batch_index, rids, tier)
        kind = rule.kind if rule is not None else self._drawn()
        if kind is None:
            return None
        self.log.append(InjectionEvent("run", kind, bucket, batch_index, tier))
        if kind == "exception":
            raise KernelFault(
                f"injected kernel fault (bucket={bucket}, "
                f"batch={batch_index}, tier={tier})"
            )
        if kind == "latency":
            self.sleep(rule.latency_s if rule is not None else self.latency_s)
            return "latency"
        return "nan"

    def corrupt_output(self, out: np.ndarray) -> np.ndarray:
        """Smear NaNs over a deterministic stride of the output buffer —
        what a misbehaving kernel's partial write looks like."""
        out = np.array(out, copy=True)
        flat = out.reshape(-1)
        stride = max(int(1 / max(self.nan_fraction, 1e-6)), 1)
        flat[::stride] = np.nan
        return out

    # -- reporting -----------------------------------------------------------
    def counts(self) -> dict[str, int]:
        """Injection totals by kind (for the chaos report)."""
        out: dict[str, int] = {}
        for ev in self.log:
            out[ev.kind] = out.get(ev.kind, 0) + 1
        return out


#: sentinel ``batch_index`` for compile-boundary rules.
COMPILE = -1


@contextmanager
def kill_pallas(message: str = "injected: pallas backend down"):
    """Registry-dispatch hook: every kernel resolved for a
    ``use_pallas=True`` request raises :class:`KernelFault` at trace time.

    New buckets compiled inside this context cannot trace their Pallas
    tier, so the engine degrades them down the ladder; executables traced
    *before* the kill keep serving — a live backend outage, not a process
    restart.
    """

    def hook(key, impl):
        policy, order, use_pallas = key
        if not use_pallas:
            return impl

        def dead(*args, **kwargs):
            raise KernelFault(
                f"{message} (policy={policy!r}, order={order!r})"
            )

        return dead

    push_kernel_hook(hook)
    try:
        yield
    finally:
        pop_kernel_hook(hook)
