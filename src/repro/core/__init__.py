from .taxonomy import (
    Binding,
    GNNDataflow,
    Granularity,
    InterPhase,
    IntraPhaseDataflow,
    Loop,
    PhaseOrder,
    enumerate_dataflows,
    intra,
    named_dataflow,
)
from .hw import AcceleratorConfig, TPUChipConfig, DEFAULT_ACCEL, TPU_V5E
from .cost_model import (
    GNNLayerWorkload,
    PhaseCost,
    aggregation_cost,
    combination_cost,
    pipelined_elements,
    table3_buffering,
)
from .simulator import RunStats, simulate, simulate_model
from .mapper import MappingResult, TABLE5_NAMES, optimize_tiles, search_dataflows
from .taxonomy import DataflowSkeleton, SkeletonPhase, Cons, named_skeleton, SKELETONS
