from .taxonomy import (
    Binding,
    GNNDataflow,
    Granularity,
    InterPhase,
    IntraPhaseDataflow,
    Loop,
    PhaseOrder,
    enumerate_dataflows,
    intra,
    named_dataflow,
)
from .hw import AcceleratorConfig, HWGrid, TPUChipConfig, DEFAULT_ACCEL, TPU_V5E
from .registry import (
    Objective,
    get_objective,
    kernel_policies,
    lookup_kernel,
    objective_names,
    objective_value,
    register_kernel,
    register_objective,
    unregister_objective,
)
from .cost_model import (
    BandStats,
    GNNLayerWorkload,
    PhaseCost,
    TileStats,
    aggregation_cost,
    combination_cost,
    pipelined_elements,
    table3_buffering,
)
from .schedule import (
    ExecSpec,
    LayerSchedule,
    ModelSchedule,
    TransitionSpec,
    default_dataflow,
    policy_of,
    transition_spec,
)
from .simulator import (
    BatchStats,
    ModelStats,
    RunStats,
    TransitionStats,
    simulate,
    simulate_batch,
    simulate_model,
    transition_cost,
    validate_workload_chain,
)
from .mapper import (
    CodesignPoint,
    CodesignResult,
    FlexibilityReport,
    MappingResult,
    TABLE5_NAMES,
    flexibility_value,
    optimize_tiles,
    optimize_tiles_topk,
    search_codesign,
    search_dataflows,
    search_model,
    search_model_codesign,
    sweep_pe_splits,
)
from .taxonomy import DataflowSkeleton, SkeletonPhase, Cons, named_skeleton, SKELETONS
from .taxonomy import input_walk, output_walk, parse_dataflow
