from .taxonomy import (
    Binding,
    GNNDataflow,
    Granularity,
    InterPhase,
    IntraPhaseDataflow,
    Loop,
    PhaseOrder,
    enumerate_dataflows,
    intra,
    named_dataflow,
)
from .hw import AcceleratorConfig, TPUChipConfig, DEFAULT_ACCEL, TPU_V5E
from .cost_model import (
    BandStats,
    GNNLayerWorkload,
    PhaseCost,
    TileStats,
    aggregation_cost,
    combination_cost,
    pipelined_elements,
    table3_buffering,
)
from .simulator import BatchStats, RunStats, simulate, simulate_batch, simulate_model
from .mapper import (
    MappingResult,
    TABLE5_NAMES,
    optimize_tiles,
    optimize_tiles_topk,
    search_dataflows,
)
from .taxonomy import DataflowSkeleton, SkeletonPhase, Cons, named_skeleton, SKELETONS
