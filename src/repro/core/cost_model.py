"""Analytical per-phase cost model (paper Sec. 4, Tables 1-3).

The model is a single-level "Timeloop-lite": each PE's register file holds
one tile per operand; a tile is (re)fetched from the Global Buffer whenever
any loop at or above the operand's innermost *effective* relevant loop
increments (degenerate trip-count-1 loops grant free reuse and are dropped
from the nest).  Spatially-mapped dimensions multicast tiles across lanes,
so spatial unrolling never multiplies GB traffic — exactly the paper's
Table 1 semantics (e.g. ``{GsFs}Vt`` keeps weights stationary, ``{VsGs}Ft``
keeps outputs stationary and streams both inputs).

Aggregation is ragged: vertex tiles run in lockstep, so a tile's neighbor
trip count is ``ceil(max_nnz_in_tile / T_N)`` — this is how "evil rows"
(paper Sec. 5.2.1, AWB-GCN) show up as both load imbalance and padded
occupancy.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .hw import AcceleratorConfig
from .taxonomy import (
    Binding,
    GNNDataflow,
    IntraPhaseDataflow,
    InterPhase,
    PhaseOrder,
)


@dataclass(frozen=True)
class GNNLayerWorkload:
    """One GCN layer: AX W (AC) or A (XW) (CA) over a CSR graph."""

    nnz: np.ndarray  # per-vertex neighbor count (self-loops included)
    f_in: int
    g_out: int
    name: str = ""

    @property
    def v(self) -> int:
        return int(len(self.nnz))

    @property
    def e(self) -> int:
        return int(self.nnz.sum())

    def macs(self, order: PhaseOrder) -> tuple[int, int]:
        """(aggregation MACs, combination MACs)."""
        cmb = self.v * self.f_in * self.g_out
        agg = self.e * (self.f_in if order == PhaseOrder.AC else self.g_out)
        return agg, cmb


@dataclass
class PhaseCost:
    """Cost of one phase of one layer."""

    cycles: float
    macs: float
    # GB traffic in elements, keyed by logical operand:
    #   agg: adj / inp / out (+psum) ; cmb: inp / wt / out (+psum)
    gb_reads: dict[str, float] = field(default_factory=dict)
    gb_writes: dict[str, float] = field(default_factory=dict)
    rf_accesses: float = 0.0
    spatial_util: float = 0.0  # busy-lane fraction of the PE budget

    @property
    def gb_total(self) -> float:
        return sum(self.gb_reads.values()) + sum(self.gb_writes.values())


def _tiles_of(nnz: np.ndarray, t_v: int) -> np.ndarray:
    """Max nnz per consecutive vertex tile of size t_v."""
    v = len(nnz)
    n_tiles = -(-v // t_v)
    padded = np.full(n_tiles * t_v, 0, dtype=np.int64)
    padded[:v] = nnz
    return padded.reshape(n_tiles, t_v).max(axis=1)


def _ceil(a, b):
    return -(-a // b) if isinstance(a, (int, np.integer)) else np.ceil(a / b)


@dataclass
class BandStats:
    """Per-chunk producer-side trip counts for one PP chunking of a workload.

    ``band`` holds the sum of aggregation N-trips inside each pipeline chunk
    (a band of consecutive vertex tiles).  The sorted copy + prefix sums let
    the batch engine evaluate ``sum(max(alpha * band, gamma))`` — the
    two-stage-pipeline overlap term — in O(log n_chunks) per candidate via
    ``searchsorted`` instead of O(n_chunks).
    """

    band: np.ndarray  # (n_chunks,) float64 per-chunk ntrip sums
    sorted_all: np.ndarray  # band sorted ascending
    prefix_all: np.ndarray  # (n_chunks + 1,) cumulative sums of sorted_all
    sorted_tail: np.ndarray  # band[1:] sorted ascending
    prefix_tail: np.ndarray  # (n_chunks,) cumulative sums of sorted_tail

    @property
    def n_chunks(self) -> int:
        return len(self.band)

    @property
    def first(self) -> float:
        return float(self.band[0])

    @property
    def total(self) -> float:
        return float(self.prefix_all[-1])

    def sum_max_all(self, alpha: np.ndarray, gamma: np.ndarray) -> np.ndarray:
        """Vectorized ``sum_j max(alpha * band_j, gamma)`` over all chunks."""
        return self._sum_max(self.sorted_all, self.prefix_all, alpha, gamma)

    def sum_max_tail(self, alpha: np.ndarray, gamma: np.ndarray) -> np.ndarray:
        """Vectorized ``sum_{j>=1} max(alpha * band_j, gamma)``."""
        return self._sum_max(self.sorted_tail, self.prefix_tail, alpha, gamma)

    @staticmethod
    def _sum_max(srt, prefix, alpha, gamma):
        alpha = np.asarray(alpha, dtype=np.float64)
        gamma = np.asarray(gamma, dtype=np.float64)
        with np.errstate(divide="ignore", invalid="ignore"):
            thr = np.where(alpha > 0, gamma / np.maximum(alpha, 1e-300), np.inf)
        k = np.searchsorted(srt, thr, side="right")
        total = prefix[-1]
        return alpha * (total - prefix[k]) + gamma * k


class TileStats:
    """Per-workload memo of every tile-derived quantity the cost model and
    simulator need, so a mapper sweep never redoes O(V) numpy work per
    candidate.

    ``tile_max(t_v)`` — the per-vertex-tile max nnz array — is built by
    hierarchical doubling: ``tile_max(2k)`` is the pairwise max of
    consecutive entries of ``tile_max(k)`` (tile boundaries are consecutive,
    so halves always align; zero-padding is harmless under ``max``).  The
    whole power-of-two ladder therefore costs O(V log V) once per workload
    instead of O(V) per candidate tiling.
    """

    def __init__(self, nnz: np.ndarray):
        self.nnz = np.ascontiguousarray(np.asarray(nnz, dtype=np.int64))
        self._tile_max: dict[int, np.ndarray] = {}
        self._sum_ntrips: dict[tuple[int, int], float] = {}
        self._ntrips: dict[tuple[int, int], np.ndarray] = {}
        self._bands: dict[tuple[int, int, int], BandStats] = {}

    def tile_max(self, t_v: int) -> np.ndarray:
        """Max nnz per consecutive vertex tile of size ``t_v`` (cached)."""
        arr = self._tile_max.get(t_v)
        if arr is None:
            if t_v == 1:
                arr = self.nnz
            elif t_v % 2 == 0:
                half = self.tile_max(t_v // 2)
                if len(half) % 2:
                    half = np.append(half, 0)
                arr = half.reshape(-1, 2).max(axis=1)
            else:
                arr = _tiles_of(self.nnz, t_v)
            self._tile_max[t_v] = arr
        return arr

    def n_vtiles(self, t_v: int) -> int:
        return len(self.tile_max(t_v))

    def ntrips(self, t_v: int, t_n: int) -> np.ndarray:
        """Per-vertex-tile neighbor trip counts ``max(1, ceil(max_nnz/t_n))``."""
        key = (t_v, t_n)
        arr = self._ntrips.get(key)
        if arr is None:
            tm = self.tile_max(t_v)
            arr = np.maximum(1, -(-tm // t_n)).astype(np.float64)
            self._ntrips[key] = arr
        return arr

    def sum_ntrips(self, t_v: int, t_n: int) -> float:
        key = (t_v, t_n)
        val = self._sum_ntrips.get(key)
        if val is None:
            val = float(self.ntrips(*key).sum())
            self._sum_ntrips[key] = val
        return val

    def band_stats(self, t_v: int, t_n: int, vtiles_per_chunk: int) -> BandStats:
        """Per-chunk ntrip sums for bands of ``vtiles_per_chunk`` consecutive
        vertex tiles (the PP row/element chunking), with sorted prefix sums."""
        key = (t_v, t_n, vtiles_per_chunk)
        bs = self._bands.get(key)
        if bs is None:
            nt = self.ntrips(t_v, t_n)
            n_chunks = -(-len(nt) // vtiles_per_chunk)
            pad = n_chunks * vtiles_per_chunk - len(nt)
            if pad:
                nt = np.pad(nt, (0, pad))
            band = nt.reshape(n_chunks, vtiles_per_chunk).sum(axis=1)
            sorted_all = np.sort(band)
            sorted_tail = np.sort(band[1:])
            bs = BandStats(
                band=band,
                sorted_all=sorted_all,
                prefix_all=np.concatenate(([0.0], np.cumsum(sorted_all))),
                sorted_tail=sorted_tail,
                prefix_tail=np.concatenate(([0.0], np.cumsum(sorted_tail))),
            )
            self._bands[key] = bs
        return bs


def _loads(
    order: tuple[str, ...],
    trips: dict[str, float],
    relevant: tuple[str, ...],
) -> float:
    """Tile loads for an operand = product of trips of all loops at or above
    its innermost effective relevant loop (trip-1 loops dropped)."""
    eff = [d for d in order if trips[d] > 1]
    rel_pos = [i for i, d in enumerate(eff) if d in relevant]
    if not rel_pos:
        return 1.0
    j = max(rel_pos)
    out = 1.0
    for d in eff[: j + 1]:
        out *= trips[d]
    return out


def aggregation_cost(
    df: IntraPhaseDataflow,
    nnz: np.ndarray,
    feat_extent: int,
    hw: AcceleratorConfig,
    pe_budget: int | None = None,
    row_slice: slice | None = None,
    stats: "TileStats | None" = None,
) -> PhaseCost:
    """Cost of the aggregation phase (SpMM) under an intra-phase dataflow.

    ``feat_extent`` is F for AC and G for CA.  ``row_slice`` restricts the
    evaluation to a band of vertices (used for PP/SP chunk accounting).
    ``stats`` is an optional :class:`TileStats` cache for the *full* nnz
    array (ignored when ``row_slice`` is given).
    """
    pe_budget = pe_budget or hw.n_pes
    if df.spatial_footprint > pe_budget:
        raise ValueError(
            f"agg footprint {df.spatial_footprint} > PE budget {pe_budget}"
        )
    if row_slice is not None:
        nnz = nnz[row_slice]
        stats = None
    v = len(nnz)
    e = float(nnz.sum())
    if v == 0 or e == 0:
        return PhaseCost(cycles=0.0, macs=0.0)

    t_v, t_n, t_f = df.tile("V"), df.tile("N"), df.tile("F")
    order = df.order
    pos = {d: i for i, d in enumerate(order)}

    if stats is not None:
        ntrips = stats.ntrips(t_v, t_n)
        n_vtiles = stats.n_vtiles(t_v)
    else:
        tile_max = _tiles_of(nnz, t_v)  # (n_vtiles,)
        ntrips = np.maximum(1, -(-tile_max // t_n)).astype(np.float64)
        n_vtiles = len(tile_max)
    f_trips = float(_ceil(feat_extent, t_f))
    sum_ntrips = float(ntrips.sum())

    cycles = f_trips * sum_ntrips
    macs = e * feat_extent

    # ---- GB traffic -------------------------------------------------------
    reads: dict[str, float] = {}
    writes: dict[str, float] = {}
    # adjacency (CSR indices): re-read per F pass only if the F loop is
    # outside the N loop.
    adj_factor = f_trips if pos["F"] < pos["N"] else 1.0
    reads["adj"] = e * adj_factor
    # gathered neighbor features: irregular, no cross-vertex reuse.
    reads["inp"] = e * feat_extent
    # intermediate output (V x feat): partial-sum spills occur when the N
    # loop sits above an effective relevant loop of the output.
    spill = (pos["N"] < pos["F"] and f_trips > 1) or (
        pos["N"] < pos["V"] and n_vtiles > 1
    )
    out_elems = float(v * feat_extent)
    if spill:
        visits = float((ntrips * f_trips).sum()) * t_v * t_f
        writes["out"] = out_elems
        writes["psum"] = max(0.0, visits - out_elems)
        reads["psum"] = max(0.0, visits - out_elems)
    else:
        writes["out"] = out_elems

    # ---- RF ---------------------------------------------------------------
    # two operand reads per MAC; temporal reduction adds an accumulator
    # read+write per MAC (paper Table 1: "temporal reduction within each PE")
    rf = 2.0 * macs
    if df.binding("N") == Binding.TEMPORAL:
        rf += 2.0 * macs
    else:
        rf += macs / max(t_n, 1)  # adder-tree root writes

    # busy-lane fraction: real MACs over (lanes x busy cycles)
    util = macs / max(cycles * df.spatial_footprint, 1.0)
    return PhaseCost(
        cycles=cycles,
        macs=macs,
        gb_reads=reads,
        gb_writes=writes,
        rf_accesses=rf,
        spatial_util=min(util, 1.0),
    )


def combination_cost(
    df: IntraPhaseDataflow,
    v: int,
    g: int,
    f: int,
    hw: AcceleratorConfig,
    pe_budget: int | None = None,
) -> PhaseCost:
    """Cost of the combination phase (dense GEMM, V x F x G)."""
    pe_budget = pe_budget or hw.n_pes
    if df.spatial_footprint > pe_budget:
        raise ValueError(
            f"cmb footprint {df.spatial_footprint} > PE budget {pe_budget}"
        )
    if v == 0:
        return PhaseCost(cycles=0.0, macs=0.0)
    t_v, t_g, t_f = df.tile("V"), df.tile("G"), df.tile("F")
    order = df.order
    trips = {
        "V": float(_ceil(v, t_v)),
        "G": float(_ceil(g, t_g)),
        "F": float(_ceil(f, t_f)),
    }
    cycles = trips["V"] * trips["G"] * trips["F"]
    macs = float(v) * g * f

    reads: dict[str, float] = {}
    writes: dict[str, float] = {}
    reads["inp"] = _loads(order, trips, ("V", "F")) * t_v * t_f
    reads["wt"] = _loads(order, trips, ("F", "G")) * t_f * t_g
    pos = {d: i for i, d in enumerate(order)}
    eff = [d for d in order if trips[d] > 1]
    # output spills: reduction (F) loop above an effective relevant loop
    spill = ("F" in eff) and (
        (pos["F"] < pos["V"] and trips["V"] > 1)
        or (pos["F"] < pos["G"] and trips["G"] > 1)
    )
    out_elems = float(v) * g
    if spill:
        visits = _loads(order, {**trips}, ("V", "G"))
        # ensure the reduction factor is counted (loops above j included)
        visits = max(visits, trips["V"] * trips["G"] * trips["F"])
        vol = visits * t_v * t_g
        writes["out"] = out_elems
        writes["psum"] = max(0.0, vol - out_elems)
        reads["psum"] = max(0.0, vol - out_elems)
    else:
        writes["out"] = out_elems

    rf = 2.0 * macs
    if df.binding("F") == Binding.TEMPORAL:
        rf += 2.0 * macs
    else:
        rf += macs / max(t_f, 1)

    util = macs / max(cycles * df.spatial_footprint, 1.0)
    return PhaseCost(
        cycles=cycles,
        macs=macs,
        gb_reads=reads,
        gb_writes=writes,
        rf_accesses=rf,
        spatial_util=min(util, 1.0),
    )


# ---------------------------------------------------------------------------
# Table 3 closed forms (for validation against the simulator)
# ---------------------------------------------------------------------------


def table3_buffering(df: GNNDataflow, wl: GNNLayerWorkload) -> float:
    """Intermediate buffering requirement in elements (paper Table 3)."""
    feat = wl.f_in if df.order == PhaseOrder.AC else wl.g_out
    if df.inter == InterPhase.SEQ:
        return float(wl.v * feat)
    if df.inter == InterPhase.SP and df.is_sp_optimized:
        return 0.0
    pel = pipelined_elements(df, wl)
    return 2.0 * pel if df.inter == InterPhase.PP else pel


def pipelined_elements(df: GNNDataflow, wl: GNNLayerWorkload) -> float:
    """Pel — elements of the intermediate matrix in flight (Sec. 4.4)."""
    feat = wl.f_in if df.order == PhaseOrder.AC else wl.g_out
    gran = df.granularity
    if df.order == PhaseOrder.AC:
        rows_first, cols_first = df.agg.tile("V"), df.agg.tile("F")
        rows_second, cols_second = df.cmb.tile("V"), df.cmb.tile("F")
    else:
        rows_first, cols_first = df.cmb.tile("V"), df.cmb.tile("G")
        # The intermediate X.W is V x G; the aggregation phase consumes a
        # band of it per *output vertex* tile, so its row granularity is the
        # aggregation V tile (not N, which indexes gathered neighbors).
        rows_second, cols_second = df.agg.tile("V"), df.agg.tile("F")
    t_v = max(rows_first, rows_second)
    t_f = max(cols_first, cols_second)
    if gran.value == "element":
        return float(t_v * t_f)
    if gran.value == "row":
        return float(t_v * feat)
    if gran.value == "column":
        return float(wl.v * t_f)
    return float(wl.v * feat)
