"""Hardware abstraction for the spatial-accelerator model (paper Sec. 2.2).

Also carries the TPU-v5e constants used by the roofline analysis in
:mod:`repro.launch.roofline` so every hardware number lives in one place,
plus :class:`HWGrid` — the broadcastable hardware axis the co-design search
(:func:`repro.core.mapper.search_codesign`) and the batched simulator
(:func:`repro.core.simulator.simulate_batch`) sweep — and
:class:`LatencyModel`, the fittable latency constants the calibration
harness (:mod:`repro.core.calibrate`) anchors to measured wall-clock.
"""
from __future__ import annotations

import itertools
import json
import os
from dataclasses import asdict, dataclass, field, replace
from pathlib import Path

import numpy as np

#: LatencyModel artifact schema version (same bump discipline as
#: ``repro.api.PROGRAM_FORMAT``).
LATENCY_FORMAT = "repro.latency/v1"

#: environment override: path to a fitted :class:`LatencyModel` JSON file
#: that ``repro.compile`` and the serving engine load when no explicit
#: model is passed.
LATENCY_MODEL_ENV = "REPRO_LATENCY_MODEL"


@dataclass(frozen=True)
class LatencyModel:
    """Fittable latency constants over the analytic cycle model.

    The simulator's closed forms predict *relative* cost from first
    principles; this parameter set anchors them to a measured backend the
    way the empirical GEMM performance models do (per-direction effective
    bandwidth, a compute ``overhead_factor``, a per-transfer ``C_setup``):

    ``cycles_calibrated = overhead(family) * cycles_analytic(bw_eff,
    dram_bw) + c_setup``, and ``wall_s = cycle_time_s * cycles_calibrated``.

    The default instance is the **identity**: every multiplier is 1.0 and
    every additive term 0.0, so an uncalibrated
    :class:`AcceleratorConfig` reproduces the paper-constant simulator
    outputs bit-for-bit (pinned by ``tests/test_calibrate.py``).  A fitted
    instance (see :func:`repro.core.calibrate.fit_latency_model`) records
    the backend fingerprint it was measured on plus its residual error.
    """

    #: per-policy-family compute-overhead multipliers on the analytic
    #: cycle count (the GEMM model's ``overhead_factor``, one per
    #: executable kernel family).
    overhead_seq: float = 1.0
    overhead_sp_generic: float = 1.0
    overhead_sp_opt: float = 1.0
    overhead_pp: float = 1.0
    #: measured effective GB<->PE bandwidth in elements/cycle (the GEMM
    #: model's ``BW``).  ``None`` = the nominal ``gb_bandwidth``.  On an
    #: :class:`HWGrid` sweep the ratio ``bw_eff / base.gb_bandwidth``
    #: derates every grid point's bandwidth column.
    bw_eff: float | None = None
    #: per-kernel-dispatch setup overhead in cycles (the GEMM model's
    #: ``C_setup``), charged once per simulated layer.
    c_setup: float = 0.0
    #: DRAM spill bandwidth in elements/cycle: when the staged
    #: intermediate exceeds ``gb_capacity_bytes`` the serialized hand-off
    #: moves at this rate instead of the GB bandwidth.  ``None`` keeps the
    #: pre-calibration behavior (spills change energy only).
    dram_bw: float | None = None
    #: seconds per calibrated cycle.  0.0 = uncalibrated: the model ranks
    #: but cannot predict wall-clock.
    cycle_time_s: float = 0.0
    #: backend fingerprint the fit was measured on ("" = uncalibrated).
    backend: str = ""
    #: median relative wall-clock error of the fit over its grid.
    fit_error_median: float = 0.0

    OVERHEAD_FAMILIES = ("seq", "sp_generic", "sp_opt", "pp")

    @property
    def calibrated(self) -> bool:
        return self.cycle_time_s > 0.0

    def overhead(self, family: str) -> float:
        """Compute-overhead multiplier for one kernel policy family
        (``seq`` / ``sp_generic`` / ``sp_opt`` / ``pp``)."""
        try:
            return float(getattr(self, f"overhead_{family}"))
        except AttributeError:
            raise ValueError(
                f"unknown policy family {family!r}; expected one of "
                f"{self.OVERHEAD_FAMILIES}"
            ) from None

    def effective_bw(self, gb_bandwidth: float) -> float:
        """The GB bandwidth the latency terms should use."""
        return float(gb_bandwidth) if self.bw_eff is None else float(self.bw_eff)

    def calibrate_cycles(self, cycles, family: str):
        """Analytic -> calibrated cycles (scalar or array; identity by
        default)."""
        return cycles * self.overhead(family) + self.c_setup

    def wall_s(self, cycles) -> float:
        """Predicted wall seconds for already-calibrated cycles."""
        if not self.calibrated:
            raise ValueError(
                "LatencyModel is uncalibrated (cycle_time_s == 0); run "
                "repro.core.calibrate.calibrate() or load a fitted model"
            )
        return float(cycles) * self.cycle_time_s

    # -- artifact -------------------------------------------------------------
    def to_json(self) -> str:
        """Canonical (sorted-keys) JSON; byte-stable across round-trips."""
        payload = {"format": LATENCY_FORMAT, **asdict(self)}
        return json.dumps(payload, indent=2, sort_keys=True) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "LatencyModel":
        d = json.loads(text)
        if d.get("format") != LATENCY_FORMAT:
            raise ValueError(
                f"not a {LATENCY_FORMAT} artifact (format={d.get('format')!r})"
            )
        d.pop("format")
        return cls(**d)

    def save(self, path) -> Path:
        """Atomic write (temp file + ``os.replace``), same contract as
        ``Program.save``."""
        p = Path(path)
        tmp = p.with_name(p.name + f".tmp.{os.getpid()}")
        try:
            tmp.write_text(self.to_json())
            os.replace(tmp, p)
        finally:
            tmp.unlink(missing_ok=True)
        return p

    @classmethod
    def load(cls, path) -> "LatencyModel":
        return cls.from_json(Path(path).read_text())

    @classmethod
    def from_env(cls) -> "LatencyModel | None":
        """The model pointed at by ``REPRO_LATENCY_MODEL``, or ``None``
        when the variable is unset (a set-but-unreadable path raises —
        a misconfigured deployment should fail loudly, not silently
        serve uncalibrated)."""
        path = os.environ.get(LATENCY_MODEL_ENV)
        if not path:
            return None
        return cls.load(path)


DEFAULT_LATENCY = LatencyModel()


@dataclass(frozen=True)
class AcceleratorConfig:
    """A templated flexible spatial accelerator (MAERI/SIGMA-like).

    The paper's substrate: a flat array of ``n_pes`` MAC units with private
    RFs, a shared Global Buffer, and configurable distribution/reduction
    networks.  ``gb_bandwidth`` is the number of elements that can be moved
    between the Global Buffer and the PE array per cycle (paper Fig. 13
    sweeps this).
    """

    n_pes: int = 512
    gb_bandwidth: int = 512  # elements / cycle, distribution + reduction
    gb_capacity_bytes: int | None = None  # None = sufficient (paper Sec 5.1.2)
    bytes_per_elem: int = 4
    # Energy constants from Dally et al. (paper Sec 5.2.2)
    gb_energy_pj: float = 1.046  # per access, 1 MB bank
    rf_energy_pj: float = 0.053  # per access, per-PE register file
    gb_bank_bytes: int = 1 << 20  # reference bank size for energy scaling
    # Scaling exponent for access energy vs buffer capacity (CACTI-like
    # sqrt scaling; the paper only states that smaller intermediate buffers
    # cost less per access — we make that concrete and document it).
    buffer_energy_exponent: float = 0.5
    dram_energy_pj: float = 100.0  # only used when gb_capacity is exceeded
    #: fittable latency constants (identity by default — see LatencyModel)
    latency: LatencyModel = DEFAULT_LATENCY

    @classmethod
    def from_dict(cls, d: dict) -> "AcceleratorConfig":
        """Rebuild from an ``asdict()`` payload.

        Tolerates artifacts written before the ``latency`` field existed
        (pre-calibration Programs/schedules keep loading) and converts a
        nested latency mapping back into a :class:`LatencyModel`.
        """
        d = dict(d)
        lat = d.pop("latency", None)
        if lat is None:
            lat = DEFAULT_LATENCY
        elif not isinstance(lat, LatencyModel):
            lat = LatencyModel(**lat)
        return cls(latency=lat, **d)

    def buffer_access_energy(self, capacity_bytes):
        """Energy per access for a buffer of the given capacity (pJ).

        Accepts a scalar or a numpy array of capacities (the batched
        simulator prices whole candidate grids through this one method, so
        the exponent/clamp can never drift between the scalar and
        vectorized paths).  Scalar in, ``float`` out; array in, array out.
        """
        cap = np.asarray(capacity_bytes, dtype=np.float64)
        ratio = np.where(cap > 0, cap / self.gb_bank_bytes, 1.0)
        e = np.minimum(
            np.maximum(
                self.gb_energy_pj * ratio**self.buffer_energy_exponent,
                self.rf_energy_pj,
            ),
            self.dram_energy_pj,
        )
        out = np.where(cap <= 0, self.rf_energy_pj, e)
        return float(out) if np.ndim(capacity_bytes) == 0 else out


DEFAULT_ACCEL = AcceleratorConfig()


def _axis(value, name: str) -> tuple:
    """Coerce a scalar / iterable axis spec to a non-empty tuple."""
    if value is None or isinstance(value, (int, float)):
        return (value,)
    out = tuple(value)
    if not out:
        raise ValueError(f"HWGrid axis {name!r} must not be empty")
    return out


@dataclass(frozen=True)
class HWGrid:
    """A broadcastable grid of accelerator configurations.

    The cartesian product of the three searchable hardware axes the paper's
    case studies sweep — PE count (Fig. 12's allocation study runs on top of
    it), Global-Buffer bandwidth (Fig. 13) and GB capacity — over a shared
    ``base`` config carrying the energy constants.  Points are enumerated in
    C order (``n_pes`` major, ``gb_capacity_bytes`` minor); ``configs()``
    materializes one frozen :class:`AcceleratorConfig` per point and
    ``columns()`` exposes the per-point arrays the batched simulator
    broadcasts against the dataflow axis.
    """

    n_pes: tuple[int, ...] = (DEFAULT_ACCEL.n_pes,)
    gb_bandwidth: tuple[int, ...] = (DEFAULT_ACCEL.gb_bandwidth,)
    gb_capacity_bytes: tuple[int | None, ...] = (None,)
    base: AcceleratorConfig = DEFAULT_ACCEL

    def __post_init__(self):
        # axes are integral (AcceleratorConfig's fields are ints): coercing
        # here keeps columns() and configs() pricing the same values
        def ints(values, name):
            out = []
            for v in _axis(values, name):
                if v != int(v):
                    raise ValueError(f"{name} must be integral, got {v}")
                out.append(int(v))
            return tuple(out)

        object.__setattr__(self, "n_pes", ints(self.n_pes, "n_pes"))
        object.__setattr__(
            self, "gb_bandwidth", ints(self.gb_bandwidth, "gb_bandwidth")
        )
        object.__setattr__(
            self,
            "gb_capacity_bytes",
            tuple(
                None if c is None else int(c)
                for c in _axis(self.gb_capacity_bytes, "gb_capacity_bytes")
            ),
        )
        for p in self.n_pes:
            if p < 1:
                raise ValueError(f"n_pes must be >= 1, got {p}")
        for b in self.gb_bandwidth:
            if b <= 0:
                raise ValueError(f"gb_bandwidth must be > 0, got {b}")

    def __len__(self) -> int:
        return (
            len(self.n_pes) * len(self.gb_bandwidth) * len(self.gb_capacity_bytes)
        )

    def __iter__(self):
        return iter(self.configs())

    def points(self) -> list[tuple[int, int, int | None]]:
        """(n_pes, gb_bandwidth, gb_capacity_bytes) per grid point."""
        return list(
            itertools.product(self.n_pes, self.gb_bandwidth, self.gb_capacity_bytes)
        )

    def configs(self) -> list[AcceleratorConfig]:
        """One frozen :class:`AcceleratorConfig` per grid point."""
        return [
            replace(self.base, n_pes=int(p), gb_bandwidth=int(b), gb_capacity_bytes=c)
            for p, b, c in self.points()
        ]

    def columns(self) -> dict[str, np.ndarray]:
        """Per-point arrays: ``n_pes`` (int64), ``gb_bw`` (float64) and
        ``gb_cap`` (float64, ``inf`` where capacity is unconstrained) — the
        hardware columns :func:`~repro.core.simulator.simulate_batch`
        broadcasts against the candidate axis."""
        pts = self.points()
        return {
            "n_pes": np.array([p for p, _, _ in pts], dtype=np.int64),
            "gb_bw": np.array([float(b) for _, b, _ in pts], dtype=np.float64),
            "gb_cap": np.array(
                [np.inf if c is None else float(c) for _, _, c in pts],
                dtype=np.float64,
            ),
        }

    def hw_cost(self) -> np.ndarray:
        """Provisioning-cost proxy per point: ``n_pes * gb_bandwidth``
        (compute lanes x interconnect wires, the two quantities the paper's
        case studies trade against dataflow choice)."""
        pts = self.points()
        return np.array([float(p) * float(b) for p, b, _ in pts], dtype=np.float64)


#: TPU v5e single-chip constants for the roofline model (assignment spec).
@dataclass(frozen=True)
class TPUChipConfig:
    name: str = "tpu-v5e"
    peak_bf16_flops: float = 197e12  # FLOP/s per chip
    hbm_bandwidth: float = 819e9  # bytes/s
    ici_link_bandwidth: float = 50e9  # bytes/s per link
    hbm_capacity: float = 16e9  # bytes
    vmem_bytes: int = 128 * 1024 * 1024 // 8  # 16 MiB


TPU_V5E = TPUChipConfig()
