"""Hardware abstraction for the spatial-accelerator model (paper Sec. 2.2).

Also carries the TPU-v5e constants used by the roofline analysis in
:mod:`repro.launch.roofline` so every hardware number lives in one place.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class AcceleratorConfig:
    """A templated flexible spatial accelerator (MAERI/SIGMA-like).

    The paper's substrate: a flat array of ``n_pes`` MAC units with private
    RFs, a shared Global Buffer, and configurable distribution/reduction
    networks.  ``gb_bandwidth`` is the number of elements that can be moved
    between the Global Buffer and the PE array per cycle (paper Fig. 13
    sweeps this).
    """

    n_pes: int = 512
    gb_bandwidth: int = 512  # elements / cycle, distribution + reduction
    gb_capacity_bytes: int | None = None  # None = sufficient (paper Sec 5.1.2)
    bytes_per_elem: int = 4
    # Energy constants from Dally et al. (paper Sec 5.2.2)
    gb_energy_pj: float = 1.046  # per access, 1 MB bank
    rf_energy_pj: float = 0.053  # per access, per-PE register file
    gb_bank_bytes: int = 1 << 20  # reference bank size for energy scaling
    # Scaling exponent for access energy vs buffer capacity (CACTI-like
    # sqrt scaling; the paper only states that smaller intermediate buffers
    # cost less per access — we make that concrete and document it).
    buffer_energy_exponent: float = 0.5
    dram_energy_pj: float = 100.0  # only used when gb_capacity is exceeded

    def buffer_access_energy(self, capacity_bytes: int) -> float:
        """Energy per access for a buffer of the given capacity (pJ)."""
        if capacity_bytes <= 0:
            return self.rf_energy_pj
        ratio = (capacity_bytes / self.gb_bank_bytes) ** self.buffer_energy_exponent
        return float(
            min(
                max(self.gb_energy_pj * ratio, self.rf_energy_pj),
                self.dram_energy_pj,
            )
        )


#: TPU v5e single-chip constants for the roofline model (assignment spec).
@dataclass(frozen=True)
class TPUChipConfig:
    name: str = "tpu-v5e"
    peak_bf16_flops: float = 197e12  # FLOP/s per chip
    hbm_bandwidth: float = 819e9  # bytes/s
    ici_link_bandwidth: float = 50e9  # bytes/s per link
    hbm_capacity: float = 16e9  # bytes
    vmem_bytes: int = 128 * 1024 * 1024 // 8  # 16 MiB


DEFAULT_ACCEL = AcceleratorConfig()
TPU_V5E = TPUChipConfig()
