"""Kernel calibration: fit :class:`LatencyModel` to measured wall-clock.

The simulator's closed forms (Sec 5 of the paper) predict *relative* cost
from first principles, but every constant in them is a paper constant that
has never been checked against this backend (ROADMAP open item 2).  The
communication-requirements line of work argues such models must be anchored
to measured constants to be predictive, and the empirical GEMM performance
models show the recipe: microbenchmark a grid, least-squares a handful of
bandwidth/overhead constants, report residual error.

This module is that recipe for the multiphase GNN kernels:

1. :func:`measure_grid` microbenchmarks the registered kernel families
   (``seq`` / ``sp_generic`` / ``sp_opt``, jnp fallbacks or Pallas) across
   a policy x phase-order x graph-size grid of synthetic workloads, timing
   each compiled :class:`~repro.api.Program` with
   :func:`~repro.kernels.common.measure_wall` and pricing the same
   schedule with the *identity* (uncalibrated) analytic model.
2. :func:`fit_latency_model` solves a relative-error weighted least
   squares for per-family overheads + per-dispatch setup, grid-searching
   the effective-bandwidth axis, and reports per-point relative error.
   The fit is pure and deterministic: same points in, same model out.
3. :func:`calibrate` composes the two and (optionally) persists the
   fitted model beside a :class:`~repro.runtime.store.ProgramStore`,
   keyed by :func:`backend_fingerprint`, where ``repro.compile`` and the
   serving engine pick it up automatically.

The ``pp`` family executes through the ``sp_generic`` band scan on a
single-device host (see :mod:`repro.gnn.pp`), so its overhead is tied to
the ``sp_generic`` fit unless pp observations are supplied.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from .cost_model import GNNLayerWorkload
from .hw import AcceleratorConfig, DEFAULT_ACCEL, LatencyModel
from .schedule import ModelSchedule
from .simulator import simulate_model

#: default calibration grid: every single-device-executable policy family
#: x both phase orders x a ladder of synthetic graph sizes (v, avg_degree).
CAL_POLICIES = ("seq", "sp_generic", "sp_opt")
CAL_ORDERS = ("AC", "CA")
CAL_SIZES = ((256, 8), (1024, 8), (2048, 16))
CAL_SIZES_FAST = ((256, 8), (1024, 8))
#: (f_in, f_out) of the single calibration layer.
CAL_DIMS = (32, 32)
#: effective-bandwidth grid (multipliers on the nominal ``gb_bandwidth``)
#: the fit searches over.
CAL_BW_MULTS = (0.25, 0.5, 1.0, 2.0, 4.0)


def backend_fingerprint() -> str:
    """Identity of the measured backend: fitted models only transfer to
    the platform they were measured on, so stored models are keyed by
    this string."""
    import jax

    dev = jax.devices()[0]
    kind = str(getattr(dev, "device_kind", "unknown")).replace(" ", "_")
    return f"{jax.default_backend()}:{kind}:jax-{jax.__version__}"


def _synthetic_graph(v: int, degree: int, seed: int):
    """Deterministic random graph with ~``degree`` average in-degree and
    no isolated nodes (a ring underlay guarantees connectivity)."""
    from ..graphs.csr import from_edges

    rng = np.random.default_rng(seed)
    m = v * degree
    src = rng.integers(0, v, size=m)
    dst = rng.integers(0, v, size=m)
    ring = np.arange(v)
    return from_edges(
        v,
        np.concatenate([src, ring]),
        np.concatenate([dst, (ring + 1) % v]),
    )


@dataclass(frozen=True)
class CalibrationPoint:
    """One (kernel config, workload) microbenchmark observation."""

    policy: str  # fitted family: seq | sp_generic | sp_opt | pp
    order: str  # AC | CA
    v: int
    degree: int
    f_in: int
    f_out: int
    use_pallas: bool
    cycles: float  # analytic cycles under the identity LatencyModel
    measured_s: float  # measured wall seconds (measure_wall median)
    #: analytic cycles re-priced at each effective-bandwidth multiplier,
    #: as (multiplier, cycles) pairs — the fit's bw_eff search axis.
    cycles_by_bw: tuple[tuple[float, float], ...] = ()

    def cycles_at(self, bw_mult: float) -> float:
        for m, c in self.cycles_by_bw:
            if m == bw_mult:
                return c
        if bw_mult == 1.0:
            return self.cycles
        raise KeyError(f"no cycles recorded at bw multiplier {bw_mult}")


@dataclass(frozen=True)
class FitReport:
    """A fitted model plus the evidence behind it."""

    model: LatencyModel
    n_points: int
    error_median: float
    error_max: float
    bw_mult: float  # winning effective-bandwidth multiplier
    #: per-family diagnostics: family -> {n, overhead, error_median}
    per_family: dict
    #: per-point relative errors, in measure_grid order
    errors: tuple[float, ...] = ()

    def to_dict(self) -> dict:
        from dataclasses import asdict

        return {
            "model": asdict(self.model),
            "n_points": self.n_points,
            "error_median": self.error_median,
            "error_max": self.error_max,
            "bw_mult": self.bw_mult,
            "per_family": self.per_family,
            "errors": list(self.errors),
        }


def measure_grid(
    *,
    policies: tuple[str, ...] = CAL_POLICIES,
    orders: tuple[str, ...] = CAL_ORDERS,
    sizes: tuple[tuple[int, int], ...] = CAL_SIZES,
    dims: tuple[int, int] = CAL_DIMS,
    hw: AcceleratorConfig = DEFAULT_ACCEL,
    use_pallas: bool = False,
    bw_mults: tuple[float, ...] = CAL_BW_MULTS,
    warmup: int = 1,
    iters: int = 5,
    seed: int = 0,
) -> list[CalibrationPoint]:
    """Microbenchmark the kernel grid; returns one point per cell.

    Every point compiles a homogeneous schedule
    (:meth:`ModelSchedule.from_policies`) for a synthetic workload, runs
    it through the real kernel registry and times it with
    :func:`measure_wall`; the identity-model analytic cycles for the same
    schedule ride along, plus a ladder of re-pricings across ``bw_mults``
    so the fit can search the effective-bandwidth axis without
    re-simulating.
    """
    import jax
    import jax.numpy as jnp

    from ..api import compile as _compile
    from ..kernels.common import measure_wall

    f_in, f_out = dims
    identity = LatencyModel()
    hw0 = replace(hw, latency=identity)
    points: list[CalibrationPoint] = []
    for si, (v, degree) in enumerate(sizes):
        g = _synthetic_graph(v, degree, seed + si)
        wl = GNNLayerWorkload(g.nnz, f_in, f_out, name="cal")
        rng = np.random.default_rng(seed + 1000 + si)
        x = jnp.asarray(
            rng.standard_normal((g.n_nodes, f_in)), dtype=jnp.float32
        )
        for policy in policies:
            for order in orders:
                sched = ModelSchedule.from_policies(
                    policy, order, [(f_in, f_out)], v=v
                )
                prog = _compile(
                    [wl],
                    graph=g,
                    hw=hw0,
                    schedule=sched,
                    use_pallas=use_pallas,
                    latency_model=identity,
                )
                params = prog.init(jax.random.PRNGKey(seed))
                measured = measure_wall(
                    lambda: prog.run(params, x), warmup=warmup, iters=iters
                )
                ladder = tuple(
                    (
                        float(m),
                        float(
                            simulate_model(
                                sched.dataflows,
                                [wl],
                                replace(
                                    hw0,
                                    latency=LatencyModel(
                                        bw_eff=float(m) * hw0.gb_bandwidth
                                    ),
                                ),
                            ).cycles
                        ),
                    )
                    for m in bw_mults
                )
                points.append(
                    CalibrationPoint(
                        policy=policy,
                        order=order,
                        v=v,
                        degree=degree,
                        f_in=f_in,
                        f_out=f_out,
                        use_pallas=use_pallas,
                        cycles=float(prog.stats.cycles),
                        measured_s=float(measured),
                        cycles_by_bw=ladder,
                    )
                )
    return points


def _solve(points, families, bw_mult):
    """Relative-error weighted least squares at one bandwidth multiplier.

    Model: measured_i ~ a_{family(i)} * cycles_i + b, rows weighted by
    1/measured_i so the residual is (pred - meas) / meas.  Returns
    (a per family, b, per-point relative errors).
    """
    fam_idx = {f: j for j, f in enumerate(families)}
    n, k = len(points), len(families)
    X = np.zeros((n, k + 1))
    y = np.ones(n)
    cyc = np.array([p.cycles_at(bw_mult) for p in points])
    meas = np.array([p.measured_s for p in points])
    for i, p in enumerate(points):
        X[i, fam_idx[p.policy]] = cyc[i] / meas[i]
        X[i, k] = 1.0 / meas[i]
    coef, *_ = np.linalg.lstsq(X, y, rcond=None)
    a, b = coef[:k], float(coef[k])
    if b < 0.0:
        # negative setup is unphysical; refit through the origin
        coef, *_ = np.linalg.lstsq(X[:, :k], y, rcond=None)
        a, b = coef, 0.0
    for j, f in enumerate(families):
        if a[j] <= 0.0:
            # degenerate family (e.g. constant cycles across its points):
            # fall back to the robust per-family ratio
            sel = np.array([p.policy == f for p in points])
            a[j] = float(np.median(meas[sel] / cyc[sel]))
    pred = a[[fam_idx[p.policy] for p in points]] * cyc + b
    errors = np.abs(pred - meas) / meas
    return a, b, errors


def fit_latency_model(
    points: list[CalibrationPoint],
    hw: AcceleratorConfig = DEFAULT_ACCEL,
    backend: str = "",
) -> FitReport:
    """Fit a :class:`LatencyModel` to measured points (pure + deterministic).

    Grid-searches the effective-bandwidth multipliers the points carry,
    solving the per-family overhead + setup least squares at each, and
    keeps the multiplier with the lowest median relative error (ties go
    to the multiplier closest to 1.0).  ``cycle_time_s`` is normalized so
    the smallest family overhead is exactly 1.0.
    """
    if not points:
        raise ValueError("cannot fit a LatencyModel to zero points")
    families = sorted({p.policy for p in points})
    mults = sorted(
        {m for p in points for m, _ in p.cycles_by_bw} or {1.0}
    )
    best = None
    for mult in mults:
        a, b, errors = _solve(points, families, mult)
        med = float(np.median(errors))
        key = (med, abs(np.log2(mult)))
        if best is None or key < best[0]:
            best = (key, mult, a, b, errors)
    _, bw_mult, a, b, errors = best

    fam_idx = {f: j for j, f in enumerate(families)}
    cycle_time = float(np.min(a))
    overheads = {f: float(a[fam_idx[f]] / cycle_time) for f in families}
    # families without observations: pp executes the sp_generic fallback
    # on single-device hosts; anything else stays neutral at the mean
    mean_ov = float(np.mean(list(overheads.values())))
    full = {}
    for f in LatencyModel.OVERHEAD_FAMILIES:
        if f in overheads:
            full[f] = overheads[f]
        elif f == "pp" and "sp_generic" in overheads:
            full[f] = overheads["sp_generic"]
        else:
            full[f] = mean_ov
    med = float(np.median(errors))
    model = LatencyModel(
        overhead_seq=full["seq"],
        overhead_sp_generic=full["sp_generic"],
        overhead_sp_opt=full["sp_opt"],
        overhead_pp=full["pp"],
        bw_eff=(
            None
            if bw_mult == 1.0
            else float(bw_mult) * float(hw.gb_bandwidth)
        ),
        c_setup=float(b / cycle_time),
        cycle_time_s=cycle_time,
        backend=backend,
        fit_error_median=med,
    )
    per_family = {
        f: {
            "n": int(sum(p.policy == f for p in points)),
            "overhead": overheads[f],
            "error_median": float(
                np.median([e for p, e in zip(points, errors) if p.policy == f])
            ),
        }
        for f in families
    }
    return FitReport(
        model=model,
        n_points=len(points),
        error_median=med,
        error_max=float(np.max(errors)),
        bw_mult=float(bw_mult),
        per_family=per_family,
        errors=tuple(float(e) for e in errors),
    )


def calibrate(
    *,
    hw: AcceleratorConfig = DEFAULT_ACCEL,
    fast: bool = False,
    use_pallas: bool = False,
    store=None,
    seed: int = 0,
    warmup: int = 1,
    iters: int = 5,
) -> FitReport:
    """Measure the kernel grid, fit the model, optionally persist it.

    ``fast`` shrinks the grid for smoke runs (CI's ``calibrate --fast``
    lane).  With ``store`` (a :class:`~repro.runtime.store.ProgramStore`),
    the fitted model is saved beside the program artifacts keyed by
    :func:`backend_fingerprint`, where the engine and ``repro.compile``
    auto-load it.
    """
    points = measure_grid(
        sizes=CAL_SIZES_FAST if fast else CAL_SIZES,
        hw=hw,
        use_pallas=use_pallas,
        seed=seed,
        warmup=warmup,
        iters=max(1, iters // 2) if fast else iters,
    )
    report = fit_latency_model(points, hw=hw, backend=backend_fingerprint())
    if store is not None:
        store.save_latency_model(report.model)
    return report
