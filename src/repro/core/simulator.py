"""Tile-level simulator for multiphase GNN dataflows (paper Sec. 5.1.1).

Composes the per-phase cost model (:mod:`repro.core.cost_model`) under the
four inter-phase strategies of the paper (Seq / SP-Generic / SP-Optimized /
PP at element/row/column granularity), producing runtime, energy breakdown
and buffering statistics — the quantities behind the paper's Figures 9-13
and Table 3.

Pipeline-parallel (PP) runtime follows Sec. 4.3: the accelerator's PEs are
split between the phases (``pe_split``), the intermediate matrix is chunked
at the dataflow's granularity and the two phases advance in a two-stage
pipeline whose per-chunk latency is the max of the two phases — so
unstructured sparsity shows up directly as pipeline bubbles (the paper's
Collab case).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .cost_model import (
    GNNLayerWorkload,
    PhaseCost,
    aggregation_cost,
    combination_cost,
    pipelined_elements,
    table3_buffering,
    _ceil,
    _tiles_of,
)
from .hw import AcceleratorConfig, DEFAULT_ACCEL
from .taxonomy import GNNDataflow, InterPhase, PhaseOrder, Granularity


@dataclass
class RunStats:
    """Simulated execution statistics for one GNN layer."""

    dataflow: str
    cycles: float
    energy_pj: float
    energy_breakdown: dict[str, float]
    gb_accesses: dict[str, float]  # element counts per logical operand
    rf_accesses: float
    buffering_elems: float
    macs: float
    pe_utilization: float
    stall_factor: float
    agg_cycles: float
    cmb_cycles: float

    @property
    def gb_total(self) -> float:
        return sum(self.gb_accesses.values())


def _merge(into: dict[str, float], src: dict[str, float], rename: dict[str, str]):
    for k, val in src.items():
        key = rename.get(k, k)
        into[key] = into.get(key, 0.0) + val


def _phase_costs(
    df: GNNDataflow,
    wl: GNNLayerWorkload,
    hw: AcceleratorConfig,
    pe_agg: int,
    pe_cmb: int,
):
    """Evaluate both phases.  Returns (agg, cmb, first_traffic,
    second_traffic) where each traffic dict uses canonical operand labels
    (adj/inp/wt/out/psum_rd/psum_wr/int_rd/int_wr): the intermediate matrix
    is written by the first phase and read by the second."""
    feat = wl.f_in if df.order == PhaseOrder.AC else wl.g_out
    agg = aggregation_cost(df.agg, wl.nnz, feat, hw, pe_budget=pe_agg)
    cmb = combination_cost(df.cmb, wl.v, wl.g_out, wl.f_in, hw, pe_budget=pe_cmb)
    first_c, second_c = (agg, cmb) if df.order == PhaseOrder.AC else (cmb, agg)
    first: dict[str, float] = {}
    second: dict[str, float] = {}
    if df.order == PhaseOrder.AC:
        _merge(first, agg.gb_reads, {"adj": "adj", "inp": "inp", "psum": "psum_rd"})
        _merge(first, agg.gb_writes, {"out": "int_wr", "psum": "psum_wr"})
        _merge(second, cmb.gb_reads, {"inp": "int_rd", "wt": "wt", "psum": "psum_rd"})
        _merge(second, cmb.gb_writes, {"out": "out", "psum": "psum_wr"})
    else:
        _merge(first, cmb.gb_reads, {"inp": "inp", "wt": "wt", "psum": "psum_rd"})
        _merge(first, cmb.gb_writes, {"out": "int_wr", "psum": "psum_wr"})
        _merge(second, agg.gb_reads, {"adj": "adj", "inp": "int_rd", "psum": "psum_rd"})
        _merge(second, agg.gb_writes, {"out": "out", "psum": "psum_wr"})
    return agg, cmb, first_c, second_c, first, second


def _pp_chunk_times(
    df: GNNDataflow,
    wl: GNNLayerWorkload,
    hw: AcceleratorConfig,
    pe_agg: int,
    pe_cmb: int,
    agg_total: float,
    cmb_total: float,
) -> tuple[np.ndarray, np.ndarray]:
    """Per-chunk (producer, consumer) cycle arrays at the dataflow's
    pipelining granularity.  Exact row-band accounting for AC (captures
    evil-row bubbles); proportional chunking for CA (documented
    approximation — AWB-GCN's column granularity is uniform per column,
    where proportional is exact)."""
    gran = df.granularity
    feat = wl.f_in if df.order == PhaseOrder.AC else wl.g_out

    if df.order == PhaseOrder.CA:
        if gran == Granularity.ROW:
            n_chunks = int(_ceil(wl.v, max(df.cmb.tile("V"), df.agg.tile("N"))))
        elif gran == Granularity.COLUMN:
            n_chunks = int(_ceil(wl.g_out, max(df.cmb.tile("G"), df.agg.tile("F"))))
        else:
            n_v = int(_ceil(wl.v, max(df.cmb.tile("V"), df.agg.tile("N"))))
            n_f = int(_ceil(wl.g_out, max(df.cmb.tile("G"), df.agg.tile("F"))))
            n_chunks = n_v * n_f
        n_chunks = max(n_chunks, 1)
        first = np.full(n_chunks, cmb_total / n_chunks)
        second = np.full(n_chunks, agg_total / n_chunks)
        return first, second

    # ---- AC: exact row/element/column band accounting ---------------------
    t_v_a, t_n, t_f_a = df.agg.tile("V"), df.agg.tile("N"), df.agg.tile("F")
    t_v_c, t_g, t_f_c = df.cmb.tile("V"), df.cmb.tile("G"), df.cmb.tile("F")
    tile_max = _tiles_of(wl.nnz, t_v_a)
    ntrips = np.maximum(1, -(-tile_max // t_n)).astype(np.float64)
    g_trips = float(_ceil(wl.g_out, t_g))

    if gran == Granularity.ROW:
        rows = max(t_v_a, t_v_c)
        vtiles_per_chunk = max(1, rows // t_v_a)
        n_chunks = int(_ceil(len(ntrips), vtiles_per_chunk))
        pad = n_chunks * vtiles_per_chunk - len(ntrips)
        nt = np.pad(ntrips, (0, pad))
        band = nt.reshape(n_chunks, vtiles_per_chunk).sum(axis=1)
        f_trips_a = float(_ceil(feat, t_f_a))
        a = band * f_trips_a
        c = np.full(
            n_chunks,
            _ceil(rows, t_v_c) * g_trips * _ceil(wl.f_in, t_f_c),
        )
        return a, c

    if gran == Granularity.COLUMN:
        cols = max(t_f_a, t_f_c)
        n_chunks = int(_ceil(feat, cols))
        a = np.full(n_chunks, float(ntrips.sum()) * _ceil(cols, t_f_a))
        c = np.full(
            n_chunks,
            _ceil(wl.v, t_v_c) * g_trips * _ceil(cols, t_f_c),
        )
        return a, c

    # ELEMENT: grid of (row band x column band) chunks, row-major.
    rows = max(t_v_a, t_v_c)
    cols = max(t_f_a, t_f_c)
    vtiles_per_chunk = max(1, rows // t_v_a)
    n_vchunks = int(_ceil(len(ntrips), vtiles_per_chunk))
    pad = n_vchunks * vtiles_per_chunk - len(ntrips)
    nt = np.pad(ntrips, (0, pad))
    band = nt.reshape(n_vchunks, vtiles_per_chunk).sum(axis=1)
    n_fchunks = int(_ceil(feat, cols))
    a = np.repeat(band, n_fchunks) * _ceil(cols, t_f_a)
    c_per = _ceil(rows, t_v_c) * g_trips * _ceil(cols, t_f_c)
    c = np.full(n_vchunks * n_fchunks, float(c_per))
    return a, c


def simulate(
    df: GNNDataflow,
    wl: GNNLayerWorkload,
    hw: AcceleratorConfig = DEFAULT_ACCEL,
) -> RunStats:
    """Simulate one GNN layer under a complete dataflow description."""
    df.validate()
    if df.inter == InterPhase.PP:
        pe_first = max(1, int(round(hw.n_pes * df.pe_split)))
        pe_second = max(1, hw.n_pes - pe_first)
        if df.order == PhaseOrder.AC:
            pe_agg, pe_cmb = pe_first, pe_second
        else:
            pe_agg, pe_cmb = pe_second, pe_first
    else:
        pe_agg = pe_cmb = hw.n_pes

    agg, cmb, first_c, second_c, first_t, second_t = _phase_costs(
        df, wl, hw, pe_agg, pe_cmb
    )
    feat = wl.f_in if df.order == PhaseOrder.AC else wl.g_out
    int_elems = float(wl.v * feat)
    bytes_per = hw.bytes_per_elem
    sp_opt = df.inter == InterPhase.SP and df.is_sp_optimized

    # ---- intermediate traffic billing -------------------------------------
    # Seq / SP-Generic: intermediate goes through the Global Buffer (and
    # consumes its bandwidth).  PP: dedicated ping-pong buffer + NoC — GB
    # bandwidth is NOT consumed, energy scales with the (small) buffer.
    # SP-Optimized: intermediate never leaves the PEs.
    int_energy_per_access = hw.gb_energy_pj
    buffering = table3_buffering(df, wl)
    int_uses_gb_bw = df.inter in (InterPhase.SEQ, InterPhase.SP)
    if sp_opt:
        first_t.pop("int_wr", None)
        second_t.pop("int_rd", None)
        int_energy_per_access = 0.0
        int_uses_gb_bw = False
    elif df.inter == InterPhase.PP:
        int_energy_per_access = hw.buffer_access_energy(int(buffering * bytes_per))
    elif df.inter == InterPhase.SEQ and hw.gb_capacity_bytes is not None:
        if int_elems * bytes_per > hw.gb_capacity_bytes:
            int_energy_per_access = hw.dram_energy_pj

    # ---- runtime -----------------------------------------------------------
    def gb_traffic(t: dict[str, float]) -> float:
        tot = 0.0
        for k, v_ in t.items():
            if k.startswith("int") and not int_uses_gb_bw:
                continue
            tot += v_
        return tot

    bw = float(hw.gb_bandwidth)
    # operand traffic (excluding the intermediate) overlaps with compute and
    # shows up as a bandwidth stall; the intermediate hand-off is serialized
    # at the phase boundary for Seq/SP-Generic — this is exactly Table 3's
    # `t_load` that SP-Optimized saves.
    int_wr = first_t.get("int_wr", 0.0) if int_uses_gb_bw else 0.0
    int_rd = second_t.get("int_rd", 0.0) if int_uses_gb_bw else 0.0
    traf_1 = gb_traffic(first_t) - int_wr
    traf_2 = gb_traffic(second_t) - int_rd
    stall_1 = max(1.0, traf_1 / max(bw * first_c.cycles, 1e-9))
    stall_2 = max(1.0, traf_2 / max(bw * second_c.cycles, 1e-9))

    if df.inter == InterPhase.SEQ or (df.inter == InterPhase.SP and not sp_opt):
        t_xfer = (int_wr + int_rd) / bw
        cycles = stall_1 * first_c.cycles + stall_2 * second_c.cycles + t_xfer
        stall = cycles / max(first_c.cycles + second_c.cycles, 1e-9)
    elif sp_opt:
        # the fused dataflow never moves the intermediate at all
        cycles = stall_1 * first_c.cycles + stall_2 * second_c.cycles
        stall = cycles / max(first_c.cycles + second_c.cycles, 1e-9)
    else:  # PP
        a_ck, b_ck = _pp_chunk_times(
            df, wl, hw, pe_agg, pe_cmb, first_c.cycles, second_c.cycles
        )
        n = len(a_ck)
        if n == 1:
            nostall = float(a_ck[0] + b_ck[0])
        else:
            overlap = np.maximum(a_ck[1:], b_ck[:-1]).sum()
            nostall = float(a_ck[0] + overlap + b_ck[-1])
        # Both phases pull operands from the GB *concurrently* during the
        # overlapped window, so their instantaneous demands add — this is
        # why PP suffers most when bandwidth shrinks (paper Fig. 13).
        d1 = traf_1 / max(float(a_ck.sum()), 1e-9)
        d2 = traf_2 / max(float(b_ck.sum()), 1e-9)
        stall = max(1.0, (d1 + d2) / bw)
        cycles = nostall * stall

    # ---- energy ------------------------------------------------------------
    breakdown: dict[str, float] = {}
    gb_acc: dict[str, float] = {}
    for t in (first_t, second_t):
        for k, v_ in t.items():
            if k.startswith("int"):
                e, label = int_energy_per_access, "int"
            elif k.startswith("psum"):
                e, label = hw.gb_energy_pj, "psum"
            else:
                e, label = hw.gb_energy_pj, k
            breakdown[f"gb_{label}"] = breakdown.get(f"gb_{label}", 0.0) + v_ * e
            gb_acc[label] = gb_acc.get(label, 0.0) + v_
    rf_total = agg.rf_accesses + cmb.rf_accesses
    breakdown["rf"] = rf_total * hw.rf_energy_pj
    energy = sum(breakdown.values())

    macs = agg.macs + cmb.macs
    util = macs / max(cycles * hw.n_pes, 1e-9)
    return RunStats(
        dataflow=str(df),
        cycles=float(cycles),
        energy_pj=float(energy),
        energy_breakdown=breakdown,
        gb_accesses=gb_acc,
        rf_accesses=float(rf_total),
        buffering_elems=float(buffering),
        macs=float(macs),
        pe_utilization=float(min(util, 1.0)),
        stall_factor=float(stall),
        agg_cycles=float(agg.cycles),
        cmb_cycles=float(cmb.cycles),
    )


def simulate_model(
    dataflows: list[GNNDataflow],
    workloads: list[GNNLayerWorkload],
    hw: AcceleratorConfig = DEFAULT_ACCEL,
) -> list[RunStats]:
    """Simulate a multi-layer GNN: one dataflow per layer (or one reused)."""
    if len(dataflows) == 1:
        dataflows = dataflows * len(workloads)
    if len(dataflows) != len(workloads):
        raise ValueError("need one dataflow (shared) or one per layer")
    return [simulate(d, w, hw) for d, w in zip(dataflows, workloads)]
