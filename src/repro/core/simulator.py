"""Tile-level simulator for multiphase GNN dataflows (paper Sec. 5.1.1).

Composes the per-phase cost model (:mod:`repro.core.cost_model`) under the
four inter-phase strategies of the paper (Seq / SP-Generic / SP-Optimized /
PP at element/row/column granularity), producing runtime, energy breakdown
and buffering statistics — the quantities behind the paper's Figures 9-13
and Table 3.

Pipeline-parallel (PP) runtime follows Sec. 4.3: the accelerator's PEs are
split between the phases (``pe_split``), the intermediate matrix is chunked
at the dataflow's granularity and the two phases advance in a two-stage
pipeline whose per-chunk latency is the max of the two phases — so
unstructured sparsity shows up directly as pipeline bubbles (the paper's
Collab case).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING

import numpy as np

from .cost_model import (
    GNNLayerWorkload,
    PhaseCost,
    TileStats,
    aggregation_cost,
    combination_cost,
    pipelined_elements,
    table3_buffering,
    _ceil,
    _tiles_of,
)
from .hw import AcceleratorConfig, DEFAULT_ACCEL, HWGrid
from .registry import get_objective, objective_names, objective_value
from .taxonomy import (
    Binding,
    GNNDataflow,
    InterPhase,
    PhaseOrder,
    Granularity,
    classify_granularity,
)

if TYPE_CHECKING:
    from .schedule import TransitionSpec


@dataclass
class RunStats:
    """Simulated execution statistics for one GNN layer."""

    dataflow: str
    cycles: float
    energy_pj: float
    energy_breakdown: dict[str, float]
    gb_accesses: dict[str, float]  # element counts per logical operand
    rf_accesses: float
    buffering_elems: float
    macs: float
    pe_utilization: float
    stall_factor: float
    agg_cycles: float
    cmb_cycles: float

    @property
    def gb_total(self) -> float:
        return sum(self.gb_accesses.values())


def _merge(into: dict[str, float], src: dict[str, float], rename: dict[str, str]):
    for k, val in src.items():
        key = rename.get(k, k)
        into[key] = into.get(key, 0.0) + val


def _phase_costs(
    df: GNNDataflow,
    wl: GNNLayerWorkload,
    hw: AcceleratorConfig,
    pe_agg: int,
    pe_cmb: int,
):
    """Evaluate both phases.  Returns (agg, cmb, first_traffic,
    second_traffic) where each traffic dict uses canonical operand labels
    (adj/inp/wt/out/psum_rd/psum_wr/int_rd/int_wr): the intermediate matrix
    is written by the first phase and read by the second."""
    feat = wl.f_in if df.order == PhaseOrder.AC else wl.g_out
    agg = aggregation_cost(df.agg, wl.nnz, feat, hw, pe_budget=pe_agg)
    cmb = combination_cost(df.cmb, wl.v, wl.g_out, wl.f_in, hw, pe_budget=pe_cmb)
    first_c, second_c = (agg, cmb) if df.order == PhaseOrder.AC else (cmb, agg)
    first: dict[str, float] = {}
    second: dict[str, float] = {}
    if df.order == PhaseOrder.AC:
        _merge(first, agg.gb_reads, {"adj": "adj", "inp": "inp", "psum": "psum_rd"})
        _merge(first, agg.gb_writes, {"out": "int_wr", "psum": "psum_wr"})
        _merge(second, cmb.gb_reads, {"inp": "int_rd", "wt": "wt", "psum": "psum_rd"})
        _merge(second, cmb.gb_writes, {"out": "out", "psum": "psum_wr"})
    else:
        _merge(first, cmb.gb_reads, {"inp": "inp", "wt": "wt", "psum": "psum_rd"})
        _merge(first, cmb.gb_writes, {"out": "int_wr", "psum": "psum_wr"})
        _merge(second, agg.gb_reads, {"adj": "adj", "inp": "int_rd", "psum": "psum_rd"})
        _merge(second, agg.gb_writes, {"out": "out", "psum": "psum_wr"})
    return agg, cmb, first_c, second_c, first, second


def _pp_chunk_times(
    df: GNNDataflow,
    wl: GNNLayerWorkload,
    hw: AcceleratorConfig,
    pe_agg: int,
    pe_cmb: int,
    first_total: float,
    second_total: float,
) -> tuple[np.ndarray, np.ndarray]:
    """Per-chunk (producer, consumer) cycle arrays at the dataflow's
    pipelining granularity.  Exact row-band accounting for AC (captures
    evil-row bubbles); proportional chunking for CA (documented
    approximation — AWB-GCN's column granularity is uniform per column,
    where proportional is exact)."""
    gran = df.granularity
    feat = wl.f_in if df.order == PhaseOrder.AC else wl.g_out

    if df.order == PhaseOrder.CA:
        if gran == Granularity.ROW:
            n_chunks = int(_ceil(wl.v, max(df.cmb.tile("V"), df.agg.tile("N"))))
        elif gran == Granularity.COLUMN:
            n_chunks = int(_ceil(wl.g_out, max(df.cmb.tile("G"), df.agg.tile("F"))))
        else:
            n_v = int(_ceil(wl.v, max(df.cmb.tile("V"), df.agg.tile("N"))))
            n_f = int(_ceil(wl.g_out, max(df.cmb.tile("G"), df.agg.tile("F"))))
            n_chunks = n_v * n_f
        n_chunks = max(n_chunks, 1)
        first = np.full(n_chunks, first_total / n_chunks)
        second = np.full(n_chunks, second_total / n_chunks)
        return first, second

    # ---- AC: exact row/element/column band accounting ---------------------
    t_v_a, t_n, t_f_a = df.agg.tile("V"), df.agg.tile("N"), df.agg.tile("F")
    t_v_c, t_g, t_f_c = df.cmb.tile("V"), df.cmb.tile("G"), df.cmb.tile("F")
    tile_max = _tiles_of(wl.nnz, t_v_a)
    ntrips = np.maximum(1, -(-tile_max // t_n)).astype(np.float64)
    g_trips = float(_ceil(wl.g_out, t_g))

    if gran == Granularity.ROW:
        rows = max(t_v_a, t_v_c)
        vtiles_per_chunk = max(1, rows // t_v_a)
        n_chunks = int(_ceil(len(ntrips), vtiles_per_chunk))
        pad = n_chunks * vtiles_per_chunk - len(ntrips)
        nt = np.pad(ntrips, (0, pad))
        band = nt.reshape(n_chunks, vtiles_per_chunk).sum(axis=1)
        f_trips_a = float(_ceil(feat, t_f_a))
        a = band * f_trips_a
        c = np.full(
            n_chunks,
            _ceil(rows, t_v_c) * g_trips * _ceil(wl.f_in, t_f_c),
        )
        return a, c

    if gran == Granularity.COLUMN:
        cols = max(t_f_a, t_f_c)
        n_chunks = int(_ceil(feat, cols))
        a = np.full(n_chunks, float(ntrips.sum()) * _ceil(cols, t_f_a))
        c = np.full(
            n_chunks,
            _ceil(wl.v, t_v_c) * g_trips * _ceil(cols, t_f_c),
        )
        return a, c

    # ELEMENT: grid of (row band x column band) chunks, row-major.
    rows = max(t_v_a, t_v_c)
    cols = max(t_f_a, t_f_c)
    vtiles_per_chunk = max(1, rows // t_v_a)
    n_vchunks = int(_ceil(len(ntrips), vtiles_per_chunk))
    pad = n_vchunks * vtiles_per_chunk - len(ntrips)
    nt = np.pad(ntrips, (0, pad))
    band = nt.reshape(n_vchunks, vtiles_per_chunk).sum(axis=1)
    n_fchunks = int(_ceil(feat, cols))
    a = np.repeat(band, n_fchunks) * _ceil(cols, t_f_a)
    c_per = _ceil(rows, t_v_c) * g_trips * _ceil(cols, t_f_c)
    c = np.full(n_vchunks * n_fchunks, float(c_per))
    return a, c


def simulate(
    df: GNNDataflow,
    wl: GNNLayerWorkload,
    hw: AcceleratorConfig = DEFAULT_ACCEL,
) -> RunStats:
    """Simulate one GNN layer under a complete dataflow description."""
    df.validate()
    if df.inter == InterPhase.PP:
        pe_first = max(1, int(round(hw.n_pes * df.pe_split)))
        pe_second = max(1, hw.n_pes - pe_first)
        if df.order == PhaseOrder.AC:
            pe_agg, pe_cmb = pe_first, pe_second
        else:
            pe_agg, pe_cmb = pe_second, pe_first
    else:
        pe_agg = pe_cmb = hw.n_pes

    agg, cmb, first_c, second_c, first_t, second_t = _phase_costs(
        df, wl, hw, pe_agg, pe_cmb
    )
    feat = wl.f_in if df.order == PhaseOrder.AC else wl.g_out
    bytes_per = hw.bytes_per_elem
    sp_opt = df.inter == InterPhase.SP and df.is_sp_optimized

    # ---- intermediate traffic billing -------------------------------------
    # Seq / SP-Generic: intermediate goes through the Global Buffer (and
    # consumes its bandwidth).  PP: dedicated ping-pong buffer + NoC — GB
    # bandwidth is NOT consumed, energy scales with the (small) buffer.
    # SP-Optimized: intermediate never leaves the PEs.
    int_energy_per_access = hw.gb_energy_pj
    buffering = table3_buffering(df, wl)
    int_uses_gb_bw = df.inter in (InterPhase.SEQ, InterPhase.SP)
    if sp_opt:
        first_t.pop("int_wr", None)
        second_t.pop("int_rd", None)
        int_energy_per_access = 0.0
        int_uses_gb_bw = False
    elif df.inter == InterPhase.PP:
        int_energy_per_access = hw.buffer_access_energy(int(buffering * bytes_per))
    # Capacity check: the *live* intermediate footprint is the whole V x F
    # matrix only for Seq (staged in full between the phases); the pipelined
    # strategies keep just the chunk in flight (Table 3's buffering) — every
    # non-fused path spills to DRAM pricing when its own footprint exceeds
    # the GB capacity.
    spilled = (
        not sp_opt
        and hw.gb_capacity_bytes is not None
        and buffering * bytes_per > hw.gb_capacity_bytes
    )
    if spilled:
        int_energy_per_access = hw.dram_energy_pj

    # ---- runtime -----------------------------------------------------------
    def gb_traffic(t: dict[str, float]) -> float:
        tot = 0.0
        for k, v_ in t.items():
            if k.startswith("int") and not int_uses_gb_bw:
                continue
            tot += v_
        return tot

    lm = hw.latency
    bw = lm.effective_bw(hw.gb_bandwidth)
    # operand traffic (excluding the intermediate) overlaps with compute and
    # shows up as a bandwidth stall; the intermediate hand-off is serialized
    # at the phase boundary for Seq/SP-Generic — this is exactly Table 3's
    # `t_load` that SP-Optimized saves.
    int_wr = first_t.get("int_wr", 0.0) if int_uses_gb_bw else 0.0
    int_rd = second_t.get("int_rd", 0.0) if int_uses_gb_bw else 0.0
    traf_1 = gb_traffic(first_t) - int_wr
    traf_2 = gb_traffic(second_t) - int_rd
    stall_1 = max(1.0, traf_1 / max(bw * first_c.cycles, 1e-9))
    stall_2 = max(1.0, traf_2 / max(bw * second_c.cycles, 1e-9))

    if df.inter == InterPhase.SEQ or (df.inter == InterPhase.SP and not sp_opt):
        # a spilled intermediate hands off through DRAM: when the fitted
        # model carries a measured spill bandwidth, the serialized
        # transfer moves at that rate instead of the GB rate.
        bw_int = lm.dram_bw if (spilled and lm.dram_bw is not None) else bw
        t_xfer = (int_wr + int_rd) / bw_int
        cycles = stall_1 * first_c.cycles + stall_2 * second_c.cycles + t_xfer
        stall = cycles / max(first_c.cycles + second_c.cycles, 1e-9)
    elif sp_opt:
        # the fused dataflow never moves the intermediate at all
        cycles = stall_1 * first_c.cycles + stall_2 * second_c.cycles
        stall = cycles / max(first_c.cycles + second_c.cycles, 1e-9)
    else:  # PP
        a_ck, b_ck = _pp_chunk_times(
            df, wl, hw, pe_agg, pe_cmb, first_c.cycles, second_c.cycles
        )
        n = len(a_ck)
        if n == 1:
            nostall = float(a_ck[0] + b_ck[0])
        else:
            overlap = np.maximum(a_ck[1:], b_ck[:-1]).sum()
            nostall = float(a_ck[0] + overlap + b_ck[-1])
        # Both phases pull operands from the GB *concurrently* during the
        # overlapped window, so their instantaneous demands add — this is
        # why PP suffers most when bandwidth shrinks (paper Fig. 13).
        d1 = traf_1 / max(float(a_ck.sum()), 1e-9)
        d2 = traf_2 / max(float(b_ck.sum()), 1e-9)
        stall = max(1.0, (d1 + d2) / bw)
        cycles = nostall * stall

    # calibrated-model correction: per-family overhead multiplier plus
    # per-dispatch setup, mirroring the empirical GEMM model's
    # `overhead_factor` / `C_setup`.  Identity at the uncalibrated default
    # (`x * 1.0 + 0.0` is bit-exact), pinned by tests/test_calibrate.py.
    if df.inter == InterPhase.SEQ:
        family = "seq"
    elif df.inter == InterPhase.PP:
        family = "pp"
    else:
        family = "sp_opt" if sp_opt else "sp_generic"
    cycles = lm.calibrate_cycles(cycles, family)

    # ---- energy ------------------------------------------------------------
    breakdown: dict[str, float] = {}
    gb_acc: dict[str, float] = {}
    for t in (first_t, second_t):
        for k, v_ in t.items():
            if k.startswith("int"):
                e, label = int_energy_per_access, "int"
            elif k.startswith("psum"):
                e, label = hw.gb_energy_pj, "psum"
            else:
                e, label = hw.gb_energy_pj, k
            breakdown[f"gb_{label}"] = breakdown.get(f"gb_{label}", 0.0) + v_ * e
            gb_acc[label] = gb_acc.get(label, 0.0) + v_
    rf_total = agg.rf_accesses + cmb.rf_accesses
    breakdown["rf"] = rf_total * hw.rf_energy_pj
    energy = sum(breakdown.values())

    macs = agg.macs + cmb.macs
    util = macs / max(cycles * hw.n_pes, 1e-9)
    return RunStats(
        dataflow=str(df),
        cycles=float(cycles),
        energy_pj=float(energy),
        energy_breakdown=breakdown,
        gb_accesses=gb_acc,
        rf_accesses=float(rf_total),
        buffering_elems=float(buffering),
        macs=float(macs),
        pe_utilization=float(min(util, 1.0)),
        stall_factor=float(stall),
        agg_cycles=float(agg.cycles),
        cmb_cycles=float(cmb.cycles),
    )


# ---------------------------------------------------------------------------
# Batched, cache-backed simulation
# ---------------------------------------------------------------------------
#
# The mapper sweeps thousands of candidate tilings per skeleton; every
# quantity in `simulate` above is a closed-form scalar once the workload's
# tile ladder (`TileStats`) is known, so a whole candidate grid can be
# evaluated as numpy array ops.  `_eval_candidates` is the vectorized mirror
# of `simulate` — the scalar path stays the reference oracle, and
# `tests/test_mapper.py` pins the two to within 1e-6 relative tolerance.

#: Candidate tile-size columns understood by the batch evaluator.
TILE_COLUMNS = ("t_v_a", "t_n", "t_f_a", "t_v_c", "t_g", "t_f_c")


@dataclass(frozen=True)
class _GroupSpec:
    """Structural (non-tile) description shared by a batch of candidates."""

    inter: InterPhase
    order: PhaseOrder
    agg_order: tuple[str, ...]
    cmb_order: tuple[str, ...]

    @property
    def granularity(self) -> Granularity:
        return classify_granularity(self.order, self.agg_order, self.cmb_order)


@dataclass
class BatchStats:
    """Vectorized simulation results for a batch of candidate dataflows.

    Arrays are aligned with the candidate order passed to
    :func:`simulate_batch`.  ``legal`` is False where the candidate violates
    its PE budget (or is not pipelineable) — the scalar path raises
    ``ValueError`` there instead.

    When :func:`simulate_batch` is handed an :class:`~repro.core.hw.HWGrid`
    the arrays are 2-D, shaped ``(n_dataflows, len(grid))`` with the grid's
    point order along the second axis (``grid`` records which one).
    """

    cycles: np.ndarray
    energy_pj: np.ndarray
    legal: np.ndarray
    agg_cycles: np.ndarray
    cmb_cycles: np.ndarray
    macs: np.ndarray
    dataflows: list[GNNDataflow] | None = None
    grid: HWGrid | None = None

    def __len__(self) -> int:
        return len(self.cycles)

    def objective(self, name: str) -> np.ndarray:
        """Objective values for the whole batch (see the objective
        registry, :mod:`repro.core.registry`); unknown names raise
        ``ValueError`` listing the valid ones."""
        return objective_value(name, self.cycles, self.energy_pj)

    def masked_objective(self, name: str) -> np.ndarray:
        """Objective with illegal candidates forced to +inf."""
        obj = np.array(self.objective(name), dtype=np.float64)
        obj[~self.legal] = np.inf
        return obj


def _unique_map(cols: list[np.ndarray], fn) -> np.ndarray:
    """``fn(*key) -> float`` evaluated once per unique key row, broadcast
    back to the full candidate length."""
    stacked = np.stack([np.asarray(c, dtype=np.int64) for c in cols], axis=1)
    uniq, inv = np.unique(stacked, axis=0, return_inverse=True)
    vals = np.fromiter(
        (fn(*row) for row in uniq), dtype=np.float64, count=len(uniq)
    )
    return vals[inv]


def _pp_closed_form(
    spec: _GroupSpec,
    c: dict[str, np.ndarray],
    wl: GNNLayerWorkload,
    ts: TileStats,
    sum_nt: np.ndarray,
    first_cycles: np.ndarray,
    second_cycles: np.ndarray,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Closed-form (nostall_cycles, sum_producer, sum_consumer) for the PP
    two-stage pipeline — the vectorized mirror of `_pp_chunk_times` plus the
    `a[0] + sum(max(a[1:], b[:-1])) + b[-1]` recurrence of `simulate`.

    The consumer chunk time is a per-candidate constant, so the overlap term
    reduces to ``sum(max(alpha * band, gamma))`` over the cached per-band
    ntrip sums, answered in O(log n_chunks) via sorted prefix sums.
    """
    v, f_in, g_out = wl.v, wl.f_in, wl.g_out
    feat = f_in if spec.order == PhaseOrder.AC else g_out
    gran = spec.granularity
    t_v_a, t_n, t_f_a = c["t_v_a"], c["t_n"], c["t_f_a"]
    t_v_c, t_g, t_f_c = c["t_v_c"], c["t_g"], c["t_f_c"]

    if spec.order == PhaseOrder.CA:
        # proportional chunking (documented approximation, as in the scalar
        # path): both chunk times are constants.
        if gran == Granularity.ROW:
            n_chunks = -(-v // np.maximum(t_v_c, t_n))
        elif gran == Granularity.COLUMN:
            n_chunks = -(-g_out // np.maximum(t_g, t_f_a))
        else:
            n_chunks = (-(-v // np.maximum(t_v_c, t_n))) * (
                -(-g_out // np.maximum(t_g, t_f_a))
            )
        n_chunks = np.maximum(n_chunks, 1).astype(np.float64)
        a_per = first_cycles / n_chunks
        b_per = second_cycles / n_chunks
        nostall = np.where(
            n_chunks == 1,
            a_per + b_per,
            a_per + (n_chunks - 1) * np.maximum(a_per, b_per) + b_per,
        )
        return nostall, n_chunks * a_per, n_chunks * b_per

    g_trips = (-(-g_out // t_g)).astype(np.float64)

    if gran == Granularity.COLUMN:
        cols = np.maximum(t_f_a, t_f_c)
        n_chunks = (-(-feat // cols)).astype(np.float64)
        a_per = sum_nt * (-(-cols // t_f_a))
        gamma = (-(-v // t_v_c)) * g_trips * (-(-cols // t_f_c))
        nostall = np.where(
            n_chunks == 1,
            a_per + gamma,
            a_per + (n_chunks - 1) * np.maximum(a_per, gamma) + gamma,
        )
        return nostall, n_chunks * a_per, n_chunks * gamma

    rows = np.maximum(t_v_a, t_v_c)
    vpc = np.maximum(1, rows // t_v_a)
    n = len(t_v_a)
    nostall = np.empty(n, dtype=np.float64)
    sum_a = np.empty(n, dtype=np.float64)
    sum_b = np.empty(n, dtype=np.float64)
    keys = np.stack([t_v_a, t_n, vpc], axis=1)
    uniq, inv = np.unique(keys, axis=0, return_inverse=True)

    if gran == Granularity.ROW:
        alpha = (-(-feat // t_f_a)).astype(np.float64)
        gamma = (-(-rows // t_v_c)) * g_trips * (-(-f_in // t_f_c))
        for u, row in enumerate(uniq):
            idx = np.flatnonzero(inv == u)
            bs = ts.band_stats(int(row[0]), int(row[1]), int(row[2]))
            al, ga = alpha[idx], gamma[idx]
            nostall[idx] = al * bs.first + bs.sum_max_tail(al, ga) + ga
            sum_a[idx] = al * bs.total
            sum_b[idx] = bs.n_chunks * ga
        return nostall, sum_a, sum_b

    # ELEMENT: a row-major (row band x column band) chunk grid; the column
    # bands repeat each row band's trip sum n_fchunks times.
    cols = np.maximum(t_f_a, t_f_c)
    n_f = (-(-feat // cols)).astype(np.float64)
    alpha = (-(-cols // t_f_a)).astype(np.float64)
    gamma = (-(-rows // t_v_c)) * g_trips * (-(-cols // t_f_c))
    for u, row in enumerate(uniq):
        idx = np.flatnonzero(inv == u)
        bs = ts.band_stats(int(row[0]), int(row[1]), int(row[2]))
        al, ga, nf = alpha[idx], gamma[idx], n_f[idx]
        s_all = bs.sum_max_all(al, ga)
        overlap = nf * s_all - np.maximum(al * bs.first, ga)
        nostall[idx] = al * bs.first + overlap + ga
        sum_a[idx] = nf * al * bs.total
        sum_b[idx] = bs.n_chunks * nf * ga
    return nostall, sum_a, sum_b


def _eval_candidates(
    spec: _GroupSpec,
    cand: dict[str, np.ndarray],
    wl: GNNLayerWorkload,
    hw: AcceleratorConfig,
    ts: TileStats,
) -> dict[str, np.ndarray]:
    """Evaluate a structural group of candidates (shared loop orders /
    inter-phase strategy, varying tile sizes + PE split) in one vectorized
    pass.  Mirrors `simulate` + the per-phase cost model term by term.

    ``cand`` columns: the six ``TILE_COLUMNS`` plus ``pe_split`` (float),
    ``agg_n_temporal`` / ``cmb_f_temporal`` (reduction-loop bindings) and
    ``sp_opt`` (bool).  The hardware axis is broadcastable: optional
    ``n_pes`` (int64), ``gb_bw`` (float64) and ``gb_cap`` (float64, ``inf``
    = unconstrained) columns override the scalar ``hw`` values per
    candidate, so one call can price a dataflow x hardware grid (``hw``
    still supplies the shared energy constants).  Requires a non-empty
    workload (V > 0, E > 0).
    """
    t_v_a = np.asarray(cand["t_v_a"], dtype=np.int64)
    t_n = np.asarray(cand["t_n"], dtype=np.int64)
    t_f_a = np.asarray(cand["t_f_a"], dtype=np.int64)
    t_v_c = np.asarray(cand["t_v_c"], dtype=np.int64)
    t_g = np.asarray(cand["t_g"], dtype=np.int64)
    t_f_c = np.asarray(cand["t_f_c"], dtype=np.int64)
    split = np.asarray(cand["pe_split"], dtype=np.float64)
    n = len(t_v_a)

    # hardware columns (scalar fallbacks broadcast against the candidates)
    if "n_pes" in cand:
        n_pes = np.asarray(cand["n_pes"], dtype=np.int64)
    else:
        n_pes = hw.n_pes
    lm = hw.latency
    if "gb_bw" in cand:
        bw = np.asarray(cand["gb_bw"], dtype=np.float64)
        if lm.bw_eff is not None:
            # hardware-grid sweep: derate every point's nominal bandwidth
            # by the measured/nominal ratio of the base config
            bw = bw * (float(lm.bw_eff) / float(hw.gb_bandwidth))
    else:
        bw = lm.effective_bw(hw.gb_bandwidth)
    if "gb_cap" in cand:
        gb_cap = np.asarray(cand["gb_cap"], dtype=np.float64)
    else:
        gb_cap = np.inf if hw.gb_capacity_bytes is None else float(hw.gb_capacity_bytes)

    v = wl.v
    e = float(wl.nnz.sum())
    f_in, g_out = wl.f_in, wl.g_out
    ac = spec.order == PhaseOrder.AC
    feat = f_in if ac else g_out

    # ---- PE budgets + legality -------------------------------------------
    fp_a = t_v_a * t_n * t_f_a
    fp_c = t_v_c * t_g * t_f_c
    if spec.inter == InterPhase.PP:
        pe_first = np.maximum(1, np.rint(n_pes * split).astype(np.int64))
        pe_second = np.maximum(1, n_pes - pe_first)
        pe_agg, pe_cmb = (pe_first, pe_second) if ac else (pe_second, pe_first)
    else:
        pe_agg = pe_cmb = np.broadcast_to(
            np.asarray(n_pes, dtype=np.int64), (n,)
        )
    legal = (fp_a <= pe_agg) & (fp_c <= pe_cmb)
    if spec.inter in (InterPhase.SP, InterPhase.PP):
        if spec.granularity == Granularity.NONE:
            legal = np.zeros(n, dtype=bool)

    # ---- aggregation phase (cache-backed) --------------------------------
    apos = {d: i for i, d in enumerate(spec.agg_order)}
    f_trips_a = -(-feat // t_f_a)
    sum_nt = _unique_map(
        [t_v_a, t_n], lambda a, b: ts.sum_ntrips(int(a), int(b))
    )
    n_vt = _unique_map([t_v_a], lambda a: float(ts.n_vtiles(int(a))))
    cycles_a = f_trips_a * sum_nt
    macs_a = e * feat
    adj = e * f_trips_a.astype(np.float64) if apos["F"] < apos["N"] else np.full(n, e)
    inp_a = e * feat
    spill_a = np.zeros(n, dtype=bool)
    if apos["N"] < apos["F"]:
        spill_a |= f_trips_a > 1
    if apos["N"] < apos["V"]:
        spill_a |= n_vt > 1
    out_elems_a = float(v * feat)
    visits_a = f_trips_a * sum_nt * t_v_a * t_f_a
    psum_a = np.where(spill_a, np.maximum(0.0, visits_a - out_elems_a), 0.0)
    rf_a = 2.0 * macs_a + np.where(
        np.asarray(cand["agg_n_temporal"], dtype=bool),
        2.0 * macs_a,
        macs_a / np.maximum(t_n, 1),
    )

    # ---- combination phase -----------------------------------------------
    cpos = {d: i for i, d in enumerate(spec.cmb_order)}
    trips = {"V": -(-v // t_v_c), "G": -(-g_out // t_g), "F": -(-f_in // t_f_c)}
    tripsf = {d: t.astype(np.float64) for d, t in trips.items()}
    cycles_c = tripsf["V"] * tripsf["G"] * tripsf["F"]
    macs_c = float(v) * g_out * f_in

    def loads(relevant: tuple[str, ...]) -> np.ndarray:
        # innermost effective relevant loop position; trip-1 loops above it
        # contribute a factor of 1, so the product can run over all loops
        j = np.full(n, -1, dtype=np.int64)
        for d in relevant:
            j = np.maximum(j, np.where(trips[d] > 1, cpos[d], -1))
        out = np.ones(n, dtype=np.float64)
        for d in spec.cmb_order:
            out *= np.where(cpos[d] <= j, tripsf[d], 1.0)
        return out

    inp_c = loads(("V", "F")) * t_v_c * t_f_c
    wt_c = loads(("F", "G")) * t_f_c * t_g
    spill_c = np.zeros(n, dtype=bool)
    if cpos["F"] < cpos["V"]:
        spill_c |= trips["V"] > 1
    if cpos["F"] < cpos["G"]:
        spill_c |= trips["G"] > 1
    spill_c &= trips["F"] > 1
    vol_c = np.maximum(loads(("V", "G")), cycles_c) * t_v_c * t_g
    out_elems_c = float(v) * g_out
    psum_c = np.where(spill_c, np.maximum(0.0, vol_c - out_elems_c), 0.0)
    rf_c = 2.0 * macs_c + np.where(
        np.asarray(cand["cmb_f_temporal"], dtype=bool),
        2.0 * macs_c,
        macs_c / np.maximum(t_f_c, 1),
    )

    # ---- canonical traffic (int_* excluded from GB bandwidth as in the
    # scalar path: it is either serialized at the phase boundary or moved
    # through the PP ping-pong buffer) -------------------------------------
    if ac:
        first_cycles, second_cycles = cycles_a, cycles_c
        first_nonint = adj + inp_a + 2.0 * psum_a
        int_wr = np.full(n, out_elems_a)
        second_nonint = wt_c + out_elems_c + 2.0 * psum_c
        int_rd = inp_c
    else:
        first_cycles, second_cycles = cycles_c, cycles_a
        first_nonint = inp_c + wt_c + 2.0 * psum_c
        int_wr = np.full(n, out_elems_c)
        second_nonint = adj + out_elems_a + 2.0 * psum_a
        int_rd = np.full(n, inp_a)

    # ---- intermediate buffering + per-access energy ----------------------
    sp_opt = np.asarray(cand["sp_opt"], dtype=bool)
    if ac:
        rows_f, cols_f, rows_s, cols_s = t_v_a, t_f_a, t_v_c, t_f_c
    else:
        rows_f, cols_f, rows_s, cols_s = t_v_c, t_g, t_v_a, t_f_a
    t_vmax = np.maximum(rows_f, rows_s)
    t_fmax = np.maximum(cols_f, cols_s)
    gran = spec.granularity
    if gran == Granularity.ELEMENT:
        pel = (t_vmax * t_fmax).astype(np.float64)
    elif gran == Granularity.ROW:
        pel = t_vmax * float(feat)
    elif gran == Granularity.COLUMN:
        pel = float(v) * t_fmax
    else:
        pel = np.full(n, float(v * feat))

    bytes_per = hw.bytes_per_elem
    if spec.inter == InterPhase.PP:
        buffering = 2.0 * pel
        int_e = hw.buffer_access_energy(buffering * bytes_per)
    elif spec.inter == InterPhase.SEQ:
        # Seq stages the whole V x feat intermediate between the phases
        buffering = np.full(n, float(v) * feat)
        int_e = np.full(n, hw.gb_energy_pj)
    else:  # SP: optimized variants never move the intermediate
        buffering = np.where(sp_opt, 0.0, pel)
        int_e = np.where(sp_opt, 0.0, hw.gb_energy_pj)
    # capacity spill: each strategy's own live footprint (mirrors `simulate`)
    spilled = buffering * bytes_per > gb_cap
    int_e = np.where(spilled, hw.dram_energy_pj, int_e)

    # ---- runtime ---------------------------------------------------------
    stall_1 = np.maximum(1.0, first_nonint / np.maximum(bw * first_cycles, 1e-9))
    stall_2 = np.maximum(1.0, second_nonint / np.maximum(bw * second_cycles, 1e-9))

    if spec.inter in (InterPhase.SEQ, InterPhase.SP):
        base = stall_1 * first_cycles + stall_2 * second_cycles
        # spilled intermediates hand off at the measured DRAM rate when the
        # fitted model carries one (mirrors `simulate`)
        bw_int = np.where(spilled, float(lm.dram_bw), bw) if lm.dram_bw is not None else bw
        t_xfer = (int_wr + int_rd) / bw_int
        if spec.inter == InterPhase.SEQ:
            cycles = base + t_xfer
        else:
            cycles = base + np.where(sp_opt, 0.0, t_xfer)
    else:
        nostall, sum_a, sum_b = _pp_closed_form(
            spec, cand, wl, ts, sum_nt, first_cycles, second_cycles
        )
        d1 = first_nonint / np.maximum(sum_a, 1e-9)
        d2 = second_nonint / np.maximum(sum_b, 1e-9)
        cycles = nostall * np.maximum(1.0, (d1 + d2) / bw)

    # calibrated-model correction, term-for-term with `simulate`
    if spec.inter == InterPhase.SEQ:
        ov = lm.overhead_seq
    elif spec.inter == InterPhase.PP:
        ov = lm.overhead_pp
    else:
        ov = np.where(sp_opt, lm.overhead_sp_opt, lm.overhead_sp_generic)
    cycles = cycles * ov + lm.c_setup

    # ---- energy ----------------------------------------------------------
    int_traffic = np.where(sp_opt, 0.0, int_wr + int_rd)
    energy = (
        hw.gb_energy_pj * (first_nonint + second_nonint)
        + int_e * int_traffic
        + (rf_a + rf_c) * hw.rf_energy_pj
    )

    return {
        "cycles": cycles.astype(np.float64),
        "energy_pj": energy.astype(np.float64),
        "legal": legal,
        "agg_cycles": cycles_a.astype(np.float64),
        "cmb_cycles": cycles_c.astype(np.float64),
        "macs": np.full(n, macs_a + macs_c, dtype=np.float64),
    }


def simulate_batch(
    dataflows: list[GNNDataflow],
    wl: GNNLayerWorkload,
    hw: AcceleratorConfig | HWGrid = DEFAULT_ACCEL,
    tile_stats: TileStats | None = None,
) -> BatchStats:
    """Vectorized counterpart of :func:`simulate` for a list of candidates.

    Candidates are grouped by loop-order structure and each group is
    evaluated as numpy array ops over closed-form scalars memoized in a
    per-workload :class:`TileStats` cache.  Candidates that violate their PE
    budget (or are not pipelineable) come back with ``legal=False`` instead
    of raising, so a whole mapper grid can be scored in one call.

    ``hw`` may be an :class:`~repro.core.hw.HWGrid`: every candidate is
    then priced at every grid point in the same vectorized pass (the
    hardware columns broadcast against the dataflow axis) and the returned
    arrays are 2-D, ``(len(dataflows), len(hw))`` — pinned to 1e-6 oracle
    parity with scalar :func:`simulate` at every grid point by
    ``tests/test_codesign.py``.
    """
    grid = hw if isinstance(hw, HWGrid) else None
    base = grid.base if grid is not None else hw
    hw_cols = grid.columns() if grid is not None else None
    n_hw = len(grid) if grid is not None else None

    ts = tile_stats if tile_stats is not None else TileStats(wl.nnz)
    n = len(dataflows)
    shape = (n,) if n_hw is None else (n, n_hw)
    out = {
        "cycles": np.zeros(shape),
        "energy_pj": np.zeros(shape),
        "legal": np.zeros(shape, dtype=bool),
        "agg_cycles": np.zeros(shape),
        "cmb_cycles": np.zeros(shape),
        "macs": np.zeros(shape),
    }
    groups: dict[tuple, list[int]] = {}
    for i, df in enumerate(dataflows):
        key = (df.inter, df.order, df.agg.order, df.cmb.order)
        groups.setdefault(key, []).append(i)
    for key, idxs in groups.items():
        spec = _GroupSpec(*key)
        dfs = [dataflows[i] for i in idxs]
        cand = {
            "t_v_a": np.array([d.agg.tile("V") for d in dfs], dtype=np.int64),
            "t_n": np.array([d.agg.tile("N") for d in dfs], dtype=np.int64),
            "t_f_a": np.array([d.agg.tile("F") for d in dfs], dtype=np.int64),
            "t_v_c": np.array([d.cmb.tile("V") for d in dfs], dtype=np.int64),
            "t_g": np.array([d.cmb.tile("G") for d in dfs], dtype=np.int64),
            "t_f_c": np.array([d.cmb.tile("F") for d in dfs], dtype=np.int64),
            "pe_split": np.array([d.pe_split for d in dfs], dtype=np.float64),
            "agg_n_temporal": np.array(
                [d.agg.binding("N") == Binding.TEMPORAL for d in dfs], dtype=bool
            ),
            "cmb_f_temporal": np.array(
                [d.cmb.binding("F") == Binding.TEMPORAL for d in dfs], dtype=bool
            ),
            "sp_opt": np.array(
                [d.inter == InterPhase.SP and d.is_sp_optimized for d in dfs],
                dtype=bool,
            ),
        }
        if n_hw is not None:
            cand = expand_hw_columns(cand, hw_cols)
        res = _eval_candidates(spec, cand, wl, base, ts)
        ix = np.asarray(idxs)
        for k in out:
            out[k][ix] = res[k] if n_hw is None else res[k].reshape(-1, n_hw)
    return BatchStats(dataflows=list(dataflows), grid=grid, **out)


def expand_hw_columns(
    cand: dict[str, np.ndarray], hw_cols: dict[str, np.ndarray]
) -> dict[str, np.ndarray]:
    """Cross a candidate-column dict with per-hw-point columns: candidates
    repeat along the (minor) hardware axis, hardware points tile along the
    candidate axis — flattened row-major so a ``reshape(k, n_hw)`` recovers
    the (candidate, hw point) grid."""
    k = len(next(iter(cand.values())))
    n_hw = len(next(iter(hw_cols.values())))
    out = {key: np.repeat(col, n_hw) for key, col in cand.items()}
    for key, col in hw_cols.items():
        out[key] = np.tile(col, k)
    return out


# ---------------------------------------------------------------------------
# Model-level simulation: per-layer stats + inter-layer transition costs
# ---------------------------------------------------------------------------


@dataclass
class TransitionStats:
    """Cost of one layer boundary (see :mod:`repro.core.schedule`).

    When the producer's output walk disagrees with the consumer's input
    walk, the V x F intermediate is re-materialized through the GB (or
    DRAM, when it does not fit): one read + one write per element,
    serialized between the layers.
    """

    spec: "TransitionSpec"
    gb_accesses: float  # element accesses charged for the re-layout
    cycles: float
    energy_pj: float

    @property
    def relayout(self) -> bool:
        return self.spec.relayout

    def objective(self, name: str) -> float:
        """Additive objective contribution (model-level DP uses this)."""
        obj = get_objective(name)
        if not obj.additive:
            raise ValueError(
                f"transition costs only support additive objectives "
                f"{objective_names(additive_only=True)}, got {name!r}"
            )
        return obj.fn(self.cycles, self.energy_pj)


def transition_cost(
    prev: GNNDataflow,
    nxt: GNNDataflow,
    v: int,
    f: int,
    hw: AcceleratorConfig = DEFAULT_ACCEL,
) -> TransitionStats:
    """Price the hand-off of the V x F intermediate between two layers.

    Matching walks are free — the consumer streams the producer's output
    exactly as written, and the write/read traffic is already billed inside
    each layer's :func:`simulate`.  Mismatched walks re-lay-out the matrix:
    ``2 * V * F`` extra GB accesses (DRAM-priced when the matrix exceeds
    the GB capacity), serialized at the boundary at the GB bandwidth.
    """
    from .schedule import transition_spec  # local: schedule imports taxonomy only

    spec = transition_spec(prev, nxt, v=v, f=f)
    if not spec.relayout:
        return TransitionStats(spec, 0.0, 0.0, 0.0)
    elems = float(spec.elements)
    accesses = 2.0 * elems
    lm = hw.latency
    bw = lm.effective_bw(hw.gb_bandwidth)
    e_per = hw.gb_energy_pj
    spilled = (
        hw.gb_capacity_bytes is not None
        and elems * hw.bytes_per_elem > hw.gb_capacity_bytes
    )
    if spilled:
        e_per = hw.dram_energy_pj
        if lm.dram_bw is not None:
            bw = lm.dram_bw
    return TransitionStats(
        spec,
        gb_accesses=accesses,
        cycles=accesses / bw,
        energy_pj=accesses * e_per,
    )


# ---------------------------------------------------------------------------
# Partitioned execution: footprint + inter-partition communication costs
# ---------------------------------------------------------------------------


def intermediate_footprint_bytes(
    v: int, f: int, hw: AcceleratorConfig = DEFAULT_ACCEL
) -> int:
    """Bytes of the staged V x F intermediate for non-fused strategies.

    This is the quantity the spill model in :func:`simulate` compares
    against ``gb_capacity_bytes`` for Seq-family buffering, and what
    admission control / the partition planner use to agree on what
    "oversized" means for a graph."""
    return int(v) * int(f) * int(hw.bytes_per_elem)


PARTITION_KINDS = ("monolithic", "feature_chunk", "row_stream", "pp_shard")


@dataclass(frozen=True)
class PartitionCommStats:
    """Inter-partition traffic for one partitioned-execution plan.

    Mirrors :class:`TransitionStats`: an additive cost layered on top of
    the per-layer :func:`simulate` numbers, so the scalar/vector parity
    of the per-strategy paths is untouched.  Pricing follows the
    communication-requirements model (arXiv:2103.10515): every element
    crossing a partition boundary is one read at the producer plus one
    write at the consumer, serialized at the GB bandwidth; traffic whose
    working set cannot be GB-resident is DRAM-priced (arXiv:2404.15510's
    off-chip halo gathers).
    """

    kind: str  # one of PARTITION_KINDS
    n_partitions: int
    elems: float  # elements crossing partition boundaries
    gb_accesses: float  # accesses billed at GB energy
    dram_accesses: float  # accesses billed at DRAM energy
    cycles: float
    energy_pj: float

    def objective(self, name: str) -> float:
        """Additive objective contribution (plan ranking uses this)."""
        obj = get_objective(name)
        if not obj.additive:
            raise ValueError(
                f"partition comm costs only support additive objectives "
                f"{objective_names(additive_only=True)}, got {name!r}"
            )
        return obj.fn(self.cycles, self.energy_pj)


def partition_comm_cost(
    kind: str,
    n_partitions: int,
    *,
    v: int,
    f: int,
    hw: AcceleratorConfig = DEFAULT_ACCEL,
    halo_elems: int = 0,
) -> PartitionCommStats:
    """Price the inter-partition traffic of one execution plan.

    - ``monolithic``: zero — any spill traffic is already priced inside
      each layer's :func:`simulate` (the PR-4 footprint/spill model).
    - ``row_stream``: the halo features gathered per node block come from
      DRAM (the full feature matrix cannot be GB-resident, which is why
      we partitioned): ``2 * halo_elems`` DRAM accesses.
    - ``feature_chunk``: the V x F intermediate round-trips through DRAM
      once per chunk boundary pass: ``2 * v * f`` DRAM accesses.
    - ``pp_shard``: the intermediate crosses the device mesh once per
      boundary, GB/NoC-priced: ``2 * v * f`` GB accesses.
    """
    if kind not in PARTITION_KINDS:
        raise ValueError(f"unknown partition kind {kind!r}; expected {PARTITION_KINDS}")
    if n_partitions < 1:
        raise ValueError(f"n_partitions must be >= 1, got {n_partitions}")
    if kind == "monolithic" or n_partitions == 1:
        return PartitionCommStats(kind, n_partitions, 0.0, 0.0, 0.0, 0.0, 0.0)
    if kind == "row_stream":
        elems = float(halo_elems)
        gb_acc, dram_acc = 0.0, 2.0 * elems
    elif kind == "feature_chunk":
        elems = float(v) * float(f)
        gb_acc, dram_acc = 0.0, 2.0 * elems
    else:  # pp_shard
        elems = float(v) * float(f)
        gb_acc, dram_acc = 2.0 * elems, 0.0
    energy = gb_acc * hw.gb_energy_pj + dram_acc * hw.dram_energy_pj
    lm = hw.latency
    bw = lm.effective_bw(hw.gb_bandwidth)
    dram_bw = bw if lm.dram_bw is None else float(lm.dram_bw)
    return PartitionCommStats(
        kind,
        n_partitions,
        elems=elems,
        gb_accesses=gb_acc,
        dram_accesses=dram_acc,
        cycles=gb_acc / bw + dram_acc / dram_bw,
        energy_pj=energy,
    )


@dataclass
class ModelStats:
    """End-to-end statistics for a multi-layer GNN schedule."""

    layers: list[RunStats]
    transitions: list[TransitionStats]

    def __post_init__(self):
        if len(self.transitions) != max(len(self.layers) - 1, 0):
            raise ValueError(
                f"{len(self.layers)} layers need {len(self.layers) - 1} "
                f"transitions, got {len(self.transitions)}"
            )

    @property
    def layer_cycles(self) -> float:
        return sum(s.cycles for s in self.layers)

    @property
    def transition_cycles(self) -> float:
        return sum(t.cycles for t in self.transitions)

    @property
    def cycles(self) -> float:
        return self.layer_cycles + self.transition_cycles

    @property
    def layer_energy_pj(self) -> float:
        return sum(s.energy_pj for s in self.layers)

    @property
    def transition_energy_pj(self) -> float:
        return sum(t.energy_pj for t in self.transitions)

    @property
    def energy_pj(self) -> float:
        return self.layer_energy_pj + self.transition_energy_pj

    @property
    def n_relayouts(self) -> int:
        return sum(t.relayout for t in self.transitions)

    def objective(self, name: str) -> float:
        """End-to-end objective (resolved via the objective registry)."""
        return objective_value(name, self.cycles, self.energy_pj)


def validate_workload_chain(workloads: list[GNNLayerWorkload]) -> None:
    """Each layer must consume the feature width the previous one produced."""
    for i in range(1, len(workloads)):
        prev, cur = workloads[i - 1], workloads[i]
        if cur.f_in != prev.g_out:
            raise ValueError(
                f"workload {i} ({cur.name or 'unnamed'}) has f_in={cur.f_in} "
                f"but workload {i - 1} ({prev.name or 'unnamed'}) produces "
                f"g_out={prev.g_out}"
            )


def simulate_model(
    dataflows: list[GNNDataflow],
    workloads: list[GNNLayerWorkload],
    hw: AcceleratorConfig = DEFAULT_ACCEL,
) -> ModelStats:
    """Simulate a multi-layer GNN: one dataflow per layer (or one reused).

    Returns :class:`ModelStats` — per-layer :class:`RunStats` plus the
    inter-layer :class:`TransitionStats` (re-layout traffic charged when
    consecutive layers disagree on how the intermediate is walked) and the
    end-to-end cycle/energy totals.
    """
    if not workloads:
        raise ValueError("need at least one layer workload")
    if len(dataflows) == 1:
        dataflows = dataflows * len(workloads)
    if len(dataflows) != len(workloads):
        raise ValueError(
            f"got {len(dataflows)} dataflows for {len(workloads)} layer "
            "workloads; pass exactly 1 (shared across layers) or one per layer"
        )
    validate_workload_chain(workloads)
    layers = [simulate(d, w, hw) for d, w in zip(dataflows, workloads)]
    transitions = [
        transition_cost(
            dataflows[i],
            dataflows[i + 1],
            v=workloads[i + 1].v,
            f=workloads[i + 1].f_in,
            hw=hw,
        )
        for i in range(len(workloads) - 1)
    ]
    return ModelStats(layers, transitions)
