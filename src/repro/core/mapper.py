"""Mapping optimizer over the multiphase dataflow space.

The paper (Sec. 6, "Mapping Optimizer") leaves automatic search as future
work; we implement it here on top of the taxonomy + simulator: take a
dataflow *skeleton* (loop orders + the paper's s/t/x binding constraints),
search power-of-two tile sizes and PP PE splits under the PE budget, and
rank by cycles / energy / EDP.

The search runs on the batched, cache-backed engine
(:func:`repro.core.simulator.simulate_batch`): the whole
(agg_tiling x cmb_tiling x pe_split) grid is scored as numpy array ops over
a per-workload :class:`~repro.core.cost_model.TileStats` cache, dominated
candidates are pruned from the (cycles, energy) Pareto front, and only the
returned top-k mappings are re-simulated through the scalar
:func:`~repro.core.simulator.simulate` oracle.  ``engine="scalar"`` keeps
the original one-candidate-at-a-time loop for cross-checking.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, replace

import numpy as np

from .cost_model import GNNLayerWorkload, TileStats
from .hw import AcceleratorConfig, DEFAULT_ACCEL, HWGrid
from .registry import get_objective, objective_names, objective_value
from .schedule import LayerSchedule, ModelSchedule
from .simulator import (
    BatchStats,
    ModelStats,
    RunStats,
    _GroupSpec,
    _eval_candidates,
    expand_hw_columns,
    simulate,
    simulate_batch,
    simulate_model,
    transition_cost,
    validate_workload_chain,
)
from .taxonomy import (
    Cons,
    DataflowSkeleton,
    GNNDataflow,
    Granularity,
    InterPhase,
    PhaseOrder,
    SKELETONS,
    SkeletonPhase,
    named_skeleton,
)


def _pow2_up_to(extent: int, cap: int) -> list[int]:
    """Tile-size candidates: powers of two plus 3*2^k (so non-power-of-two
    PE partitions like 384 = 3*128 can be filled exactly)."""
    lim = min(max(extent, 1) * 2 - 1, cap)
    out, t = [1], 2
    while t <= lim:
        out.append(t)
        if 3 * t // 2 <= lim and 3 * t // 2 not in out:
            out.append(3 * t // 2)
        t *= 2
    return sorted(out)


def _dim_candidates(
    phase: SkeletonPhase, dim: str, extent: int, budget: int
) -> list[int]:
    fx = phase.fixed_tile(dim)
    if fx:
        return [min(fx, budget)]
    c = phase.constraint(dim)
    full = _pow2_up_to(extent, budget)
    if c == Cons.T:
        return [1]
    if c == Cons.X:
        return full
    if c == Cons.S:
        return [t for t in full if t > 1] or [1]
    if c == Cons.S_HIGH:
        hi = [t for t in full if t >= max(2, budget // 8)]
        return hi or [t for t in full if t > 1][-1:] or [1]
    if c == Cons.S_LOW:
        return [t for t in full if t <= 8]
    if c == Cons.S_FULL:
        return [budget]  # the rigid-substrate case: all PEs on this dim
    raise AssertionError(c)


def _phase_tiling_grid(
    phase: SkeletonPhase,
    extents: dict[str, int],
    budget: int,
    min_fill: float = 0.25,
) -> np.ndarray:
    """(k, 3) int64 tile grid, columns aligned with ``phase.order``, in the
    itertools.product enumeration order.  Keeps tilings whose spatial
    footprint fits the PE budget, preferring ones that fill at least
    ``min_fill`` of it."""
    cands = [
        np.asarray(_dim_candidates(phase, d, extents[d], budget), dtype=np.int64)
        for d in phase.order
    ]
    mesh = np.meshgrid(*cands, indexing="ij")
    grid = np.stack([m.ravel() for m in mesh], axis=1)
    fp = grid.prod(axis=1)
    fits = fp <= budget
    filled = fits & (fp >= max(1, int(budget * min_fill)))
    return grid[filled if filled.any() else fits]


def _phase_tilings(
    phase: SkeletonPhase,
    extents: dict[str, int],
    budget: int,
    min_fill: float = 0.25,
) -> list[dict[str, int]]:
    """Dict view of :func:`_phase_tiling_grid` (kept for tests/callers)."""
    grid = _phase_tiling_grid(phase, extents, budget, min_fill)
    dims = list(phase.order)
    return [dict(zip(dims, map(int, row))) for row in grid]


@dataclass
class MappingResult:
    dataflow: GNNDataflow
    stats: RunStats
    skeleton: str = ""

    def objective(self, name: str) -> float:
        """Objective value (resolved via the objective registry; unknown
        names raise ``ValueError`` listing the valid ones)."""
        return objective_value(name, self.stats.cycles, self.stats.energy_pj)


# ---------------------------------------------------------------------------
# Candidate grid construction (arrays, no dataflow objects)
# ---------------------------------------------------------------------------


def _candidate_grid(
    skeleton: DataflowSkeleton,
    wl: GNNLayerWorkload,
    hw: AcceleratorConfig,
    pe_splits: tuple[float, ...],
    max_evals: int,
) -> dict[str, np.ndarray]:
    """All candidate (agg_tiling, cmb_tiling, pe_split) triples as column
    arrays, in the legacy scalar-search enumeration order (splits outer,
    agg x cmb pairs inner, linspace-subsampled per split to ``max_evals``)."""
    feat = wl.f_in if skeleton.order == PhaseOrder.AC else wl.g_out
    agg_ext = {"V": wl.v, "N": max(int(wl.nnz.max()), 1), "F": feat}
    cmb_ext = {"V": wl.v, "G": wl.g_out, "F": wl.f_in}
    splits = pe_splits if skeleton.inter == InterPhase.PP else (0.5,)
    a_ix = {d: skeleton.agg.order.index(d) for d in ("V", "N", "F")}
    c_ix = {d: skeleton.cmb.order.index(d) for d in ("V", "G", "F")}

    chunks: list[np.ndarray] = []  # (k, 7): 6 tile columns + split
    for split in splits:
        if skeleton.inter == InterPhase.PP:
            pe_first = max(1, int(round(hw.n_pes * split)))
            pe_second = max(1, hw.n_pes - pe_first)
            if skeleton.order == PhaseOrder.AC:
                b_agg, b_cmb = pe_first, pe_second
            else:
                b_agg, b_cmb = pe_second, pe_first
        else:
            b_agg = b_cmb = hw.n_pes

        agg_grid = _phase_tiling_grid(skeleton.agg, agg_ext, b_agg)
        if skeleton.sp_optimized:
            # SP-Optimized: temporal reduction (T_N = 1), combination tiles
            # tied to the aggregation tiles, T_G = 1.
            ag = agg_grid[agg_grid[:, a_ix["N"]] == 1]
            ag = ag[ag[:, a_ix["V"]] * ag[:, a_ix["F"]] <= b_cmb]
            at = ag
            ct = np.ones((len(ag), 3), dtype=np.int64)
            ct[:, c_ix["V"]] = ag[:, a_ix["V"]]
            ct[:, c_ix["F"]] = ag[:, a_ix["F"]]
        else:
            cmb_grid = _phase_tiling_grid(skeleton.cmb, cmb_ext, b_cmb)
            ka, kc = len(agg_grid), len(cmb_grid)
            at = agg_grid[np.repeat(np.arange(ka), kc)]
            ct = cmb_grid[np.tile(np.arange(kc), ka)]
        if len(at) > max_evals:
            idx = np.linspace(0, len(at) - 1, max_evals).astype(int)
            at, ct = at[idx], ct[idx]
        if len(at) == 0:
            continue
        cols = np.empty((len(at), 7), dtype=np.float64)
        cols[:, 0] = at[:, a_ix["V"]]
        cols[:, 1] = at[:, a_ix["N"]]
        cols[:, 2] = at[:, a_ix["F"]]
        cols[:, 3] = ct[:, c_ix["V"]]
        cols[:, 4] = ct[:, c_ix["G"]]
        cols[:, 5] = ct[:, c_ix["F"]]
        cols[:, 6] = split
        chunks.append(cols)

    if not chunks:
        return {}
    all_cols = np.concatenate(chunks, axis=0)
    cand = {
        "t_v_a": all_cols[:, 0].astype(np.int64),
        "t_n": all_cols[:, 1].astype(np.int64),
        "t_f_a": all_cols[:, 2].astype(np.int64),
        "t_v_c": all_cols[:, 3].astype(np.int64),
        "t_g": all_cols[:, 4].astype(np.int64),
        "t_f_c": all_cols[:, 5].astype(np.int64),
        "pe_split": all_cols[:, 6],
    }
    # Skeleton-concretized loops are temporal exactly when the tile is 1
    # (`SkeletonPhase.to_intra`), so bindings follow from the tile columns.
    cand["agg_n_temporal"] = cand["t_n"] == 1
    cand["cmb_f_temporal"] = cand["t_f_c"] == 1
    cand["sp_opt"] = _sp_opt_flags(skeleton, cand)
    return cand


def _sp_opt_flags(skeleton: DataflowSkeleton, cand: dict[str, np.ndarray]) -> np.ndarray:
    """Per-candidate `GNNDataflow.is_sp_optimized` from the tile columns."""
    n = len(cand["t_v_a"])
    if skeleton.inter != InterPhase.SP:
        return np.zeros(n, dtype=bool)
    spec = _GroupSpec(
        skeleton.inter, skeleton.order, skeleton.agg.order, skeleton.cmb.order
    )
    if spec.granularity != Granularity.ELEMENT:
        return np.zeros(n, dtype=bool)
    if skeleton.order == PhaseOrder.AC:
        return (
            (cand["t_n"] == 1)
            & (cand["t_g"] == 1)
            & (cand["t_v_a"] == cand["t_v_c"])
            & (cand["t_f_a"] == cand["t_f_c"])
        )
    return (
        (cand["t_v_a"] == 1)
        & (cand["t_f_c"] == 1)
        & (cand["t_n"] == cand["t_v_c"])
        & (cand["t_f_a"] == cand["t_g"])
    )


def _pareto_mask(cycles: np.ndarray, energy: np.ndarray, legal: np.ndarray) -> np.ndarray:
    """True where a legal candidate is not strictly dominated in
    (cycles, energy) — i.e. no other legal candidate is <= on both axes and
    < on at least one."""
    keep = np.zeros(len(cycles), dtype=bool)
    idx = np.flatnonzero(legal)
    if len(idx) == 0:
        return keep
    c, en = cycles[idx], energy[idx]
    order = np.lexsort((en, c))
    c_s, e_s = c[order], en[order]
    new_c = np.concatenate(([True], c_s[1:] > c_s[:-1]))
    starts = np.flatnonzero(new_c)
    gid = np.cumsum(new_c) - 1
    gmin = np.minimum.reduceat(e_s, starts)
    prev = np.concatenate(([np.inf], np.minimum.accumulate(gmin)[:-1]))
    keep_s = (e_s == gmin[gid]) & (e_s < prev[gid])
    keep[idx[order[keep_s]]] = True
    return keep


def _concretize_at(
    skeleton: DataflowSkeleton, cand: dict[str, np.ndarray], i: int
) -> GNNDataflow:
    at = {
        "V": int(cand["t_v_a"][i]),
        "N": int(cand["t_n"][i]),
        "F": int(cand["t_f_a"][i]),
    }
    ct = {
        "V": int(cand["t_v_c"][i]),
        "G": int(cand["t_g"][i]),
        "F": int(cand["t_f_c"][i]),
    }
    return skeleton.concretize(at, ct, pe_split=float(cand["pe_split"][i]))


# ---------------------------------------------------------------------------
# Search
# ---------------------------------------------------------------------------


def optimize_tiles_topk(
    skeleton: DataflowSkeleton,
    wl: GNNLayerWorkload,
    hw: AcceleratorConfig = DEFAULT_ACCEL,
    objective: str = "edp",
    pe_splits: tuple[float, ...] = (0.5,),
    max_evals: int = 4096,
    top_k: int = 1,
    tile_stats: TileStats | None = None,
) -> list[MappingResult]:
    """Search tile sizes (and PP PE splits) for a dataflow skeleton; return
    up to ``top_k`` mappings, best-``objective`` first.

    The grid is scored by the batched engine, then dominance-pruned: the
    ``top_k`` are drawn from the (cycles, energy) Pareto front — a mapping
    strictly dominated by another candidate is never returned, even if its
    objective value ranks among the k best — extending past the front only
    when it holds fewer than ``top_k`` points.  Returned mappings carry full
    :class:`RunStats` from the scalar ``simulate`` oracle.  ``top_k=1``
    always yields the global objective optimum (the front contains it).
    """
    cand = _candidate_grid(skeleton, wl, hw, pe_splits, max_evals)
    if not cand or len(cand["t_v_a"]) == 0:
        raise RuntimeError(f"no legal tiling found for {skeleton.name}")
    ts = tile_stats if tile_stats is not None else TileStats(wl.nnz)
    spec = _GroupSpec(
        skeleton.inter, skeleton.order, skeleton.agg.order, skeleton.cmb.order
    )
    res = _eval_candidates(spec, cand, wl, hw, ts)
    batch = BatchStats(
        cycles=res["cycles"],
        energy_pj=res["energy_pj"],
        legal=res["legal"],
        agg_cycles=res["agg_cycles"],
        cmb_cycles=res["cmb_cycles"],
        macs=res["macs"],
    )
    obj = batch.masked_objective(objective)
    if not np.isfinite(obj).any():
        raise RuntimeError(f"no legal tiling found for {skeleton.name}")

    keep = _pareto_mask(batch.cycles, batch.energy_pj, batch.legal)
    front = np.flatnonzero(keep)
    ranked = front[np.argsort(obj[front], kind="stable")]
    if len(ranked) < top_k:
        # Pareto front smaller than top_k: extend with the next-best
        # dominated candidates, then restore overall objective order.
        rest = np.flatnonzero(batch.legal & ~keep)
        rest = rest[np.argsort(obj[rest], kind="stable")]
        ranked = np.concatenate([ranked, rest])
    chosen = ranked[:top_k]
    chosen = chosen[np.argsort(obj[chosen], kind="stable")]
    out = []
    for i in chosen:
        df = _concretize_at(skeleton, cand, int(i))
        out.append(MappingResult(df, simulate(df, wl, hw), skeleton=skeleton.name))
    return out


def optimize_tiles(
    skeleton: DataflowSkeleton,
    wl: GNNLayerWorkload,
    hw: AcceleratorConfig = DEFAULT_ACCEL,
    objective: str = "edp",
    pe_splits: tuple[float, ...] = (0.5,),
    max_evals: int = 4096,
    tile_stats: TileStats | None = None,
    engine: str = "batch",
) -> MappingResult:
    """Best mapping for a dataflow skeleton (see :func:`optimize_tiles_topk`).

    ``engine="scalar"`` runs the original per-candidate loop over the scalar
    simulator — the reference oracle the batch engine is validated against.
    """
    if engine == "scalar":
        return _optimize_tiles_scalar(
            skeleton, wl, hw, objective, pe_splits, max_evals
        )
    if engine != "batch":
        raise ValueError(f"unknown engine {engine!r}; use 'batch' or 'scalar'")
    return optimize_tiles_topk(
        skeleton,
        wl,
        hw,
        objective=objective,
        pe_splits=pe_splits,
        max_evals=max_evals,
        top_k=1,
        tile_stats=tile_stats,
    )[0]


def _optimize_tiles_scalar(
    skeleton: DataflowSkeleton,
    wl: GNNLayerWorkload,
    hw: AcceleratorConfig,
    objective: str,
    pe_splits: tuple[float, ...],
    max_evals: int,
) -> MappingResult:
    """Reference search: one scalar `simulate` per candidate."""
    agg_ext = {
        "V": wl.v,
        "N": max(int(wl.nnz.max()), 1),
        "F": wl.f_in if skeleton.order == PhaseOrder.AC else wl.g_out,
    }
    cmb_ext = {"V": wl.v, "G": wl.g_out, "F": wl.f_in}
    splits = pe_splits if skeleton.inter == InterPhase.PP else (0.5,)

    best: MappingResult | None = None
    for split in splits:
        if skeleton.inter == InterPhase.PP:
            pe_first = max(1, int(round(hw.n_pes * split)))
            pe_second = max(1, hw.n_pes - pe_first)
            if skeleton.order == PhaseOrder.AC:
                b_agg, b_cmb = pe_first, pe_second
            else:
                b_agg, b_cmb = pe_second, pe_first
        else:
            b_agg = b_cmb = hw.n_pes

        agg_tilings = _phase_tilings(skeleton.agg, agg_ext, b_agg)
        if skeleton.sp_optimized:
            pairs = []
            for at in agg_tilings:
                if at.get("N", 1) != 1:
                    continue  # SP-Optimized: temporal reduction (T_N = 1)
                ct = {"V": at["V"], "F": at["F"], "G": 1}
                if at["V"] * at["F"] <= b_cmb:
                    pairs.append((at, ct))
        else:
            cmb_tilings = _phase_tilings(skeleton.cmb, cmb_ext, b_cmb)
            pairs = list(itertools.product(agg_tilings, cmb_tilings))
        if len(pairs) > max_evals:
            idx = np.linspace(0, len(pairs) - 1, max_evals).astype(int)
            pairs = [pairs[i] for i in idx]
        for at, ct in pairs:
            df = skeleton.concretize(at, ct, pe_split=split)
            try:
                stats = simulate(df, wl, hw)
            except ValueError:
                continue
            res = MappingResult(df, stats, skeleton=skeleton.name)
            if best is None or res.objective(objective) < best.objective(objective):
                best = res
    if best is None:
        raise RuntimeError(f"no legal tiling found for {skeleton.name}")
    return best


def sweep_pe_splits(
    skeleton: DataflowSkeleton,
    wl: GNNLayerWorkload,
    hw: AcceleratorConfig = DEFAULT_ACCEL,
    objective: str = "cycles",
    pe_splits: tuple[float, ...] = (0.25, 0.5, 0.75),
    max_evals: int = 4096,
    tile_stats: TileStats | None = None,
) -> dict[float, MappingResult]:
    """Best mapping *per PP PE split* from one batched evaluation of the
    whole (tiling x split) grid — the engine behind the paper's Fig. 12
    load-balancing study.  Splits with no legal tiling are omitted; non-PP
    skeletons collapse to the single ``0.5`` entry (their phases share all
    PEs)."""
    get_objective(objective)
    cand = _candidate_grid(skeleton, wl, hw, tuple(pe_splits), max_evals)
    if not cand or len(cand["t_v_a"]) == 0:
        raise RuntimeError(f"no legal tiling found for {skeleton.name}")
    ts = tile_stats if tile_stats is not None else TileStats(wl.nnz)
    spec = _GroupSpec(
        skeleton.inter, skeleton.order, skeleton.agg.order, skeleton.cmb.order
    )
    res = _eval_candidates(spec, cand, wl, hw, ts)
    obj = objective_value(objective, res["cycles"], res["energy_pj"])
    obj = np.asarray(obj, dtype=np.float64)
    obj[~res["legal"]] = np.inf
    out: dict[float, MappingResult] = {}
    for s in np.unique(cand["pe_split"]):
        rows = np.flatnonzero(cand["pe_split"] == s)
        if len(rows) == 0 or not np.isfinite(obj[rows]).any():
            continue
        i = int(rows[np.argmin(obj[rows])])
        df = _concretize_at(skeleton, cand, i)
        out[float(s)] = MappingResult(
            df, simulate(df, wl, hw), skeleton=skeleton.name
        )
    if not out:
        raise RuntimeError(f"no legal tiling found for {skeleton.name}")
    return out


#: The paper's Table 5 evaluation set.
TABLE5_NAMES = (
    "Seq-Nt",
    "Seq-Ns",
    "SP-FsNt-Fs",
    "SP-VsNt-Vs",
    "High-Vs-SP",
    "PP-Nt-Vt/sl",
    "PP-Ns-Vt/sl",
    "PP-Nt-Vsh",
    "PP-Ns-Vsh",
)


def search_dataflows(
    wl: GNNLayerWorkload,
    hw: AcceleratorConfig = DEFAULT_ACCEL,
    objective: str = "edp",
    names: tuple[str, ...] = TABLE5_NAMES,
    pe_splits: tuple[float, ...] = (0.25, 0.5, 0.75),
    top_k: int = 1,
    tile_stats: TileStats | None = None,
) -> list[MappingResult]:
    """Rank dataflow skeletons (default: the paper's Table 5 set) for a
    workload.  Returns up to ``top_k`` Pareto-optimal mappings per skeleton
    (see :func:`optimize_tiles_topk`), sorted by the objective — this is the
    workload-adaptive dataflow choice the paper argues flexible accelerators
    enable.  The :class:`TileStats` cache is shared across all skeletons, so
    the whole sweep costs one O(V log V) ladder build plus numpy grid
    math."""
    get_objective(objective)  # fail fast on unknown names, listing valid ones
    ts = tile_stats if tile_stats is not None else TileStats(wl.nnz)
    out: list[MappingResult] = []
    for n in names:
        try:
            out.extend(
                optimize_tiles_topk(
                    named_skeleton(n),
                    wl,
                    hw,
                    objective=objective,
                    pe_splits=pe_splits,
                    top_k=top_k,
                    tile_stats=ts,
                )
            )
        except (RuntimeError, ValueError):
            continue
    out.sort(key=lambda r: r.objective(objective))
    return out


def search_execution_plans(
    g,
    dims,
    hw: AcceleratorConfig = DEFAULT_ACCEL,
    objective: str = "edp",
    **kwargs,
):
    """Rank whole-graph execution plans — monolithic vs partitioned.

    Extends :func:`search_dataflows` above the single-layer level: each
    candidate's per-layer compute is priced by ``search_dataflows`` and
    its inter-partition traffic by
    :func:`repro.core.simulator.partition_comm_cost`, so beyond-capacity
    graphs can be ranked against (spill-priced) monolithic execution on
    the same objective scale.  Returns a
    :class:`repro.graphs.partition.PartitionPlan`; see
    :func:`repro.graphs.partition.plan_partition` for the knobs.
    """
    from ..graphs.partition import plan_partition  # local: graphs imports core

    return plan_partition(g, dims, hw, objective=objective, **kwargs)


# ---------------------------------------------------------------------------
# Model-level search: DP over per-layer candidates with transition costs
# ---------------------------------------------------------------------------


def _tile_stats_cache(caches: dict[int, TileStats] | None = None):
    """Per-graph :class:`TileStats` memo shared by the multi-workload
    searches: one ladder per distinct degree vector, keyed by ``id(nnz)``
    (layers of one model alias the same array).  Returns a ``ts_for(wl)``
    lookup; pass an existing dict to share ladders across calls (the
    hw-grid sweeps do)."""
    store = caches if caches is not None else {}

    def ts_for(wl: GNNLayerWorkload) -> TileStats:
        key = id(wl.nnz)
        if key not in store:
            store[key] = TileStats(wl.nnz)
        return store[key]

    return ts_for


def _dp_assign(
    layer_dfs: list[list[GNNDataflow]],
    layer_obj: list[np.ndarray],
    workloads: list[GNNLayerWorkload],
    hw: AcceleratorConfig,
    objective: str,
) -> tuple[list[int], float]:
    """Exact dynamic program over per-layer candidate dataflows.

    ``layer_obj[i][j]`` is layer *i* candidate *j*'s additive objective;
    edges between consecutive layers are priced by
    :func:`~repro.core.simulator.transition_cost`.  Returns the chosen
    candidate index per layer and the end-to-end objective — equal to
    brute-force enumeration over the same candidate lists
    (``tests/test_schedule.py`` pins this).
    """
    prev_cost = np.asarray(layer_obj[0], dtype=np.float64)
    back: list[np.ndarray] = []
    for i in range(1, len(layer_dfs)):
        cur = np.asarray(layer_obj[i], dtype=np.float64)
        trans = np.empty((len(prev_cost), len(cur)), dtype=np.float64)
        for j, a in enumerate(layer_dfs[i - 1]):
            for k, b in enumerate(layer_dfs[i]):
                trans[j, k] = transition_cost(
                    a, b, v=workloads[i].v, f=workloads[i].f_in, hw=hw
                ).objective(objective)
        tot = prev_cost[:, None] + trans
        arg = tot.argmin(axis=0)
        back.append(arg)
        prev_cost = tot[arg, np.arange(len(cur))] + cur
    end = int(prev_cost.argmin())
    total = float(prev_cost[end])
    idx = [end]
    for arg in reversed(back):
        idx.append(int(arg[idx[-1]]))
    return idx[::-1], total


def search_model(
    workloads: list[GNNLayerWorkload],
    hw: AcceleratorConfig = DEFAULT_ACCEL,
    objective: str = "cycles",
    names: tuple[str, ...] = TABLE5_NAMES,
    pe_splits: tuple[float, ...] = (0.25, 0.5, 0.75),
    top_k: int = 4,
    shared_dataflow: bool = False,
    tile_stats_caches: dict[int, TileStats] | None = None,
) -> ModelSchedule:
    """End-to-end mapper for a multi-layer GNN (paper Sec. 4.4 composed).

    Per layer, the batched Table-5 sweep (:func:`search_dataflows`, sharing
    one :class:`TileStats` cache per distinct graph) yields up to
    ``top_k`` Pareto candidates per skeleton; a dynamic program then picks
    one candidate per layer minimizing ``sum(layer objective) +
    sum(transition objective)`` where mismatched inter-layer walks charge
    the re-layout of the V x F intermediate.

    ``shared_dataflow=True`` reproduces the homogeneous baseline: the
    single concrete dataflow (drawn from the same candidate pool) that
    minimizes the end-to-end objective when reused for every layer.  The
    heterogeneous DP also sees that winner as a candidate in every layer,
    so its result is never worse than the homogeneous one.

    ``objective`` must be additive across layers: "cycles" or "energy".
    Returns a :class:`ModelSchedule` whose layers carry per-layer
    ``RunStats`` and whose ``stats`` is the end-to-end
    :class:`~repro.core.simulator.ModelStats`; the schedule records the
    ``hw`` it was priced on.  ``tile_stats_caches`` (an ``id(nnz) ->
    TileStats`` dict) lets a hardware-grid sweep share the tile ladders
    across hw points.
    """
    if not get_objective(objective).additive:
        raise ValueError(
            f"model-level objective must be additive "
            f"({', '.join(objective_names(additive_only=True))}), "
            f"got {objective!r}"
        )
    if not workloads:
        raise ValueError("need at least one layer workload")
    validate_workload_chain(workloads)

    ts_for = _tile_stats_cache(tile_stats_caches)

    per_layer = [
        search_dataflows(
            wl,
            hw,
            objective=objective,
            names=names,
            pe_splits=pe_splits,
            top_k=top_k,
            tile_stats=ts_for(wl),
        )
        for wl in workloads
    ]
    for i, cands in enumerate(per_layer):
        if not cands:
            raise RuntimeError(f"no legal mapping found for layer {i}")

    # ---- homogeneous baseline: one concrete dataflow reused everywhere ----
    # scored on the batch engine (one vectorized pass per layer over the
    # whole candidate pool), with the self-transition charged when a
    # dataflow's own output walk disagrees with its input walk; only the
    # winner is re-simulated through the scalar oracle.
    pool: list[GNNDataflow] = []
    for cands in per_layer:
        for r in cands:
            if r.dataflow not in pool:
                pool.append(r.dataflow)
    totals = np.zeros(len(pool), dtype=np.float64)
    for wl in workloads:
        batch = simulate_batch(pool, wl, hw, tile_stats=ts_for(wl))
        totals += batch.masked_objective(objective)
    for k, df in enumerate(pool):
        if not np.isfinite(totals[k]):
            continue
        totals[k] += sum(
            transition_cost(
                df, df, v=workloads[i].v, f=workloads[i].f_in, hw=hw
            ).objective(objective)
            for i in range(1, len(workloads))
        )
    if not np.isfinite(totals).any():
        raise RuntimeError("no candidate dataflow is legal across all layers")
    best_shared = pool[int(np.argmin(totals))]
    best_shared_stats = simulate_model([best_shared], list(workloads), hw)
    shared_schedule = ModelSchedule(
        tuple(
            LayerSchedule(best_shared, wl.f_in, wl.g_out, name=wl.name, stats=st)
            for wl, st in zip(workloads, best_shared_stats.layers)
        ),
        tuple(t.spec for t in best_shared_stats.transitions),
        objective=objective,
        stats=best_shared_stats,
        hw=hw,
    )

    if shared_dataflow:
        return shared_schedule

    layer_dfs = [[r.dataflow for r in cands] for cands in per_layer]
    layer_obj = [
        np.array([r.objective(objective) for r in cands], dtype=np.float64)
        for cands in per_layer
    ]
    # guarantee DP <= homogeneous: the shared winner is a path in the DP
    for i, wl in enumerate(workloads):
        if best_shared not in layer_dfs[i]:
            layer_dfs[i].append(best_shared)
            layer_obj[i] = np.append(
                layer_obj[i],
                best_shared_stats.layers[i].cycles
                if objective == "cycles"
                else best_shared_stats.layers[i].energy_pj,
            )
    idx, _ = _dp_assign(layer_dfs, layer_obj, list(workloads), hw, objective)
    chosen = [layer_dfs[i][j] for i, j in enumerate(idx)]
    stats = simulate_model(chosen, list(workloads), hw)

    layers = tuple(
        LayerSchedule(df, wl.f_in, wl.g_out, name=wl.name, stats=st)
        for df, wl, st in zip(chosen, workloads, stats.layers)
    )
    transitions = tuple(t.spec for t in stats.transitions)
    return ModelSchedule(
        layers,
        transitions,
        objective=objective,
        stats=stats,
        shared_baseline=shared_schedule,
        hw=hw,
    )


def search_model_topk(
    workloads: list[GNNLayerWorkload],
    hw: AcceleratorConfig = DEFAULT_ACCEL,
    objective: str = "cycles",
    names: tuple[str, ...] = TABLE5_NAMES,
    pe_splits: tuple[float, ...] = (0.25, 0.5, 0.75),
    top_k: int = 4,
    tile_stats_caches: dict[int, TileStats] | None = None,
) -> list[ModelSchedule]:
    """Ranked candidate schedules for measured re-ranking.

    The analytic winner alone is what :func:`search_model` returns; the
    serving engine's execution-feedback loop (Bao-style) instead wants the
    model's *top-k* so it can time each candidate on the real backend and
    keep the measured best.  Returns up to ``top_k`` schedules, analytic
    best first: the DP winner, the homogeneous shared baseline, and the
    best homogeneous schedule per distinct *executable policy family*
    (``seq`` / ``sp_generic`` / ``sp_opt`` / ``pp``) from the per-layer
    candidate pool — family diversity is what gives measurement something
    meaningful to choose between, since same-family tilings lower to the
    same kernels.  Deduplicated by :meth:`ModelSchedule.digest`; every
    candidate carries its own priced stats on ``hw``.
    """
    caches = tile_stats_caches if tile_stats_caches is not None else {}
    winner = search_model(
        workloads,
        hw,
        objective=objective,
        names=names,
        pe_splits=pe_splits,
        top_k=top_k,
        tile_stats_caches=caches,
    )
    candidates: list[ModelSchedule] = [winner]
    if winner.shared_baseline is not None:
        candidates.append(winner.shared_baseline)

    # homogeneous candidates from the same per-layer pool the DP saw
    ts_for = _tile_stats_cache(caches)
    pool: list[GNNDataflow] = []
    for wl in workloads:
        for r in search_dataflows(
            wl,
            hw,
            objective=objective,
            names=names,
            pe_splits=pe_splits,
            top_k=top_k,
            tile_stats=ts_for(wl),
        ):
            if r.dataflow not in pool:
                pool.append(r.dataflow)
    by_family: dict[str, ModelSchedule] = {}
    for df in pool:
        try:
            stats = simulate_model([df], list(workloads), hw)
        except ValueError:  # illegal on some layer of this model
            continue
        sched = ModelSchedule(
            tuple(
                LayerSchedule(df, wl.f_in, wl.g_out, name=wl.name, stats=st)
                for wl, st in zip(workloads, stats.layers)
            ),
            tuple(t.spec for t in stats.transitions),
            objective=objective,
            stats=stats,
            hw=hw,
        )
        fam = sched.layers[0].lower().policy
        cur = by_family.get(fam)
        if cur is None or stats.objective(objective) < cur.stats.objective(
            objective
        ):
            by_family[fam] = sched
    candidates.extend(by_family.values())

    seen: set[str] = set()
    unique: list[ModelSchedule] = []
    for s in candidates:
        dig = s.digest()
        if dig not in seen:
            seen.add(dig)
            unique.append(s)
    unique.sort(key=lambda s: s.stats.objective(objective))
    return unique[: max(1, int(top_k))]


# ---------------------------------------------------------------------------
# Hardware co-design: dataflow x hardware grid search + value of flexibility
# ---------------------------------------------------------------------------


@dataclass
class CodesignPoint:
    """One hardware grid point of a :func:`search_codesign` sweep."""

    hw: AcceleratorConfig
    hw_cost: float  # n_pes x gb_bandwidth provisioning proxy
    objective_total: float  # sum of per-workload best objectives (inf = infeasible)
    dataflows: list[GNNDataflow | None]  # per-workload winner
    on_frontier: bool = False
    #: scalar-oracle pricing of the winners; filled for frontier points only
    mappings: list[MappingResult] | None = None

    @property
    def feasible(self) -> bool:
        return bool(np.isfinite(self.objective_total))


@dataclass
class CodesignResult:
    """Joint (hardware, dataflow) search result over an :class:`HWGrid`."""

    objective: str
    grid: HWGrid
    points: list[CodesignPoint]

    @property
    def frontier(self) -> list[CodesignPoint]:
        """The joint Pareto frontier (objective vs hw-cost), cheapest-hw
        first — the paper's "what does flexibility buy at each provisioning
        level" curve."""
        return sorted(
            (p for p in self.points if p.on_frontier), key=lambda p: p.hw_cost
        )

    @property
    def best(self) -> CodesignPoint:
        """The feasible point with the best objective (ties: cheaper hw)."""
        feas = [p for p in self.points if p.feasible]
        if not feas:
            raise RuntimeError("no feasible hardware point in the grid")
        return min(feas, key=lambda p: (p.objective_total, p.hw_cost))


def _grid_best_per_point(
    wl: GNNLayerWorkload,
    grid: HWGrid,
    objective: str,
    names: tuple[str, ...],
    pe_splits: tuple[float, ...],
    max_evals: int,
    ts: TileStats,
) -> tuple[np.ndarray, list[GNNDataflow | None]]:
    """Best (objective value, concrete dataflow) per hw grid point for one
    workload.  Hw points sharing an ``n_pes`` also share their candidate
    tiling grids (the PE budget is what shapes them), so the sweep costs one
    vectorized ``_eval_candidates`` per (skeleton, distinct n_pes) — the
    bandwidth / capacity axes ride along as broadcast columns."""
    cols = grid.columns()
    n_hw = len(grid)
    best_obj = np.full(n_hw, np.inf)
    winners: list[tuple[DataflowSkeleton, dict, int] | None] = [None] * n_hw
    for npes in np.unique(cols["n_pes"]):
        sel = np.flatnonzero(cols["n_pes"] == npes)
        budget_hw = replace(grid.base, n_pes=int(npes))
        sub_cols = {k: c[sel] for k, c in cols.items()}
        for name in names:
            skeleton = named_skeleton(name)
            cand = _candidate_grid(skeleton, wl, budget_hw, pe_splits, max_evals)
            if not cand or len(cand["t_v_a"]) == 0:
                continue
            spec = _GroupSpec(
                skeleton.inter,
                skeleton.order,
                skeleton.agg.order,
                skeleton.cmb.order,
            )
            res = _eval_candidates(
                spec, expand_hw_columns(cand, sub_cols), wl, grid.base, ts
            )
            obj = np.asarray(
                objective_value(objective, res["cycles"], res["energy_pj"]),
                dtype=np.float64,
            )
            obj[~res["legal"]] = np.inf
            obj = obj.reshape(-1, len(sel))
            arg = np.argmin(obj, axis=0)
            val = obj[arg, np.arange(len(sel))]
            for j, h in enumerate(sel):
                if val[j] < best_obj[h]:
                    best_obj[h] = val[j]
                    winners[h] = (skeleton, cand, int(arg[j]))
    dataflows = [
        _concretize_at(w[0], w[1], w[2]) if w is not None else None
        for w in winners
    ]
    return best_obj, dataflows


def search_codesign(
    workloads: list[GNNLayerWorkload],
    hw_grid: HWGrid,
    objective: str = "edp",
    names: tuple[str, ...] = TABLE5_NAMES,
    pe_splits: tuple[float, ...] = (0.25, 0.5, 0.75),
    max_evals: int = 4096,
    price_frontier: bool = True,
) -> CodesignResult:
    """Joint hardware x dataflow search: price the whole (dataflow x tiling
    x hw grid) space in vectorized passes and return every grid point with
    its per-workload best mapping, marking the (objective, hw-cost) Pareto
    frontier.

    Each hw point's objective is the *suite total* — the sum over
    ``workloads`` of the best objective a flexible accelerator of that
    provisioning reaches (dataflow re-chosen per workload, the paper's
    flexibility premise; :func:`flexibility_value` prices the premise
    itself).  ``hw_cost`` is the ``n_pes x gb_bandwidth`` proxy from
    :meth:`HWGrid.hw_cost`.  Frontier points get their winners re-priced
    through the scalar :func:`~repro.core.simulator.simulate` oracle
    (``price_frontier=False`` skips that for large grids).
    """
    get_objective(objective)
    if not workloads:
        raise ValueError("need at least one workload")
    if not isinstance(hw_grid, HWGrid):
        raise TypeError(
            f"hw_grid must be an HWGrid, got {type(hw_grid).__name__} "
            "(wrap a single AcceleratorConfig's axes: HWGrid(n_pes=..., ...))"
        )

    ts_for = _tile_stats_cache()

    per_wl = [
        _grid_best_per_point(
            wl, hw_grid, objective, names, pe_splits, max_evals, ts_for(wl)
        )
        for wl in workloads
    ]
    totals = np.sum([obj for obj, _ in per_wl], axis=0)
    hw_cost = hw_grid.hw_cost()
    frontier = _pareto_mask(totals, hw_cost, np.isfinite(totals))

    points = []
    for h, cfg in enumerate(hw_grid.configs()):
        dfs = [per_wl[w][1][h] for w in range(len(workloads))]
        pt = CodesignPoint(
            hw=cfg,
            hw_cost=float(hw_cost[h]),
            objective_total=float(totals[h]),
            dataflows=dfs,
            on_frontier=bool(frontier[h]),
        )
        if pt.on_frontier and price_frontier:
            pt.mappings = [
                MappingResult(df, simulate(df, wl, cfg))
                for df, wl in zip(dfs, workloads)
            ]
        points.append(pt)
    return CodesignResult(objective=objective, grid=hw_grid, points=points)


@dataclass
class FlexibilityReport:
    """The paper's "value of flexibility", made quantitative: how much a
    workload-adaptive (flexible) accelerator beats the best *single fixed
    dataflow* across a workload suite on the same hardware."""

    objective: str
    hw: AcceleratorConfig
    #: flexible accelerator: best dataflow re-chosen per workload
    per_workload: list[MappingResult]
    #: rigid accelerator: the one dataflow minimizing the suite total,
    #: priced on every workload
    fixed: list[MappingResult]

    @property
    def fixed_dataflow(self) -> GNNDataflow:
        return self.fixed[0].dataflow

    @property
    def flexible_total(self) -> float:
        return sum(r.objective(self.objective) for r in self.per_workload)

    @property
    def fixed_total(self) -> float:
        return sum(r.objective(self.objective) for r in self.fixed)

    @property
    def value(self) -> float:
        """fixed / flexible objective ratio; >= 1.0 up to the 1e-6
        scalar/batch oracle-parity tolerance (both sides are picked by
        batch scores over the same candidate pool, then re-priced through
        the scalar oracle), > 1.0 exactly when no single dataflow is best
        for every workload."""
        return self.fixed_total / max(self.flexible_total, 1e-300)

    @property
    def win_pct(self) -> float:
        return (self.value - 1.0) * 100.0


def flexibility_value(
    workloads: list[GNNLayerWorkload],
    hw: AcceleratorConfig = DEFAULT_ACCEL,
    objective: str = "edp",
    names: tuple[str, ...] = TABLE5_NAMES,
    pe_splits: tuple[float, ...] = (0.25, 0.5, 0.75),
    top_k: int = 4,
) -> FlexibilityReport:
    """Quantify the value of dataflow flexibility on a workload suite.

    Runs the per-workload Table-5 search, pools every candidate the
    searches surfaced, and scores the whole pool on every workload with one
    :func:`~repro.core.simulator.simulate_batch` call per workload (shared
    :class:`TileStats`).  The *flexible* cost re-picks the pool's best per
    workload; the *fixed* cost forces the single pool dataflow with the
    best suite total everywhere — both sides drawn from the same pool, so
    ``value >= 1`` by construction and the gap is exactly what hardware
    flexibility buys (cf. VersaGNN's motivation, arXiv:2105.01280).
    """
    get_objective(objective)
    if not workloads:
        raise ValueError("need at least one workload")

    ts_for = _tile_stats_cache()

    per_search = [
        search_dataflows(
            wl,
            hw,
            objective=objective,
            names=names,
            pe_splits=pe_splits,
            top_k=top_k,
            tile_stats=ts_for(wl),
        )
        for wl in workloads
    ]
    for i, res in enumerate(per_search):
        if not res:
            raise RuntimeError(
                f"no legal mapping found for workload {i} "
                f"({workloads[i].name or 'unnamed'})"
            )
    pool: list[GNNDataflow] = []
    for res in per_search:
        for r in res:
            if r.dataflow not in pool:
                pool.append(r.dataflow)

    score = np.empty((len(pool), len(workloads)), dtype=np.float64)
    for w, wl in enumerate(workloads):
        batch = simulate_batch(pool, wl, hw, tile_stats=ts_for(wl))
        score[:, w] = batch.masked_objective(objective)

    flex_idx = np.argmin(score, axis=0)  # per-workload pool winner
    totals = score.sum(axis=1)  # inf wherever illegal on any workload
    if not np.isfinite(totals).any():
        raise RuntimeError("no pool dataflow is legal across the whole suite")
    fixed_idx = int(np.argmin(totals))

    per_workload = [
        MappingResult(pool[int(i)], simulate(pool[int(i)], wl, hw))
        for i, wl in zip(flex_idx, workloads)
    ]
    fixed = [
        MappingResult(pool[fixed_idx], simulate(pool[fixed_idx], wl, hw))
        for wl in workloads
    ]
    return FlexibilityReport(
        objective=objective, hw=hw, per_workload=per_workload, fixed=fixed
    )


def search_model_codesign(
    workloads: list[GNNLayerWorkload],
    hw_grid: HWGrid,
    objective: str = "cycles",
    names: tuple[str, ...] = TABLE5_NAMES,
    pe_splits: tuple[float, ...] = (0.25, 0.5, 0.75),
    top_k: int = 4,
) -> list[ModelSchedule | None]:
    """:func:`search_model` at every point of a hardware grid, sharing the
    per-graph :class:`TileStats` ladders across points.  Transition costs
    are re-priced inside each point's DP on that point's bandwidth /
    capacity, so the chosen schedule can change shape with the hardware
    (e.g. relayouts become affordable at high bandwidth).  One
    :class:`ModelSchedule` per grid point, in grid order, each recording
    its ``hw`` — ``None`` where the point admits no legal mapping."""
    if not isinstance(hw_grid, HWGrid):
        raise TypeError(
            f"hw_grid must be an HWGrid, got {type(hw_grid).__name__}"
        )
    caches: dict[int, TileStats] = {}
    out: list[ModelSchedule | None] = []
    for cfg in hw_grid.configs():
        try:
            out.append(
                search_model(
                    workloads,
                    cfg,
                    objective=objective,
                    names=names,
                    pe_splits=pe_splits,
                    top_k=top_k,
                    tile_stats_caches=caches,
                )
            )
        except RuntimeError:
            out.append(None)
    return out
