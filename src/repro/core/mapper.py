"""Mapping optimizer over the multiphase dataflow space.

The paper (Sec. 6, "Mapping Optimizer") leaves automatic search as future
work; we implement it here on top of the taxonomy + simulator: take a
dataflow *skeleton* (loop orders + the paper's s/t/x binding constraints),
search power-of-two tile sizes and PP PE splits under the PE budget, and
rank by cycles / energy / EDP.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass

import numpy as np

from .cost_model import GNNLayerWorkload
from .hw import AcceleratorConfig, DEFAULT_ACCEL
from .simulator import RunStats, simulate
from .taxonomy import (
    Cons,
    DataflowSkeleton,
    GNNDataflow,
    InterPhase,
    PhaseOrder,
    SKELETONS,
    SkeletonPhase,
    named_skeleton,
)


def _pow2_up_to(extent: int, cap: int) -> list[int]:
    """Tile-size candidates: powers of two plus 3*2^k (so non-power-of-two
    PE partitions like 384 = 3*128 can be filled exactly)."""
    lim = min(max(extent, 1) * 2 - 1, cap)
    out, t = [1], 2
    while t <= lim:
        out.append(t)
        if 3 * t // 2 <= lim and 3 * t // 2 not in out:
            out.append(3 * t // 2)
        t *= 2
    return sorted(out)


def _dim_candidates(
    phase: SkeletonPhase, dim: str, extent: int, budget: int
) -> list[int]:
    fx = phase.fixed_tile(dim)
    if fx:
        return [min(fx, budget)]
    c = phase.constraint(dim)
    full = _pow2_up_to(extent, budget)
    if c == Cons.T:
        return [1]
    if c == Cons.X:
        return full
    if c == Cons.S:
        return [t for t in full if t > 1] or [1]
    if c == Cons.S_HIGH:
        hi = [t for t in full if t >= max(2, budget // 8)]
        return hi or [t for t in full if t > 1][-1:] or [1]
    if c == Cons.S_LOW:
        return [t for t in full if t <= 8]
    if c == Cons.S_FULL:
        return [budget]  # the rigid-substrate case: all PEs on this dim
    raise AssertionError(c)


def _phase_tilings(
    phase: SkeletonPhase,
    extents: dict[str, int],
    budget: int,
    min_fill: float = 0.25,
) -> list[dict[str, int]]:
    """Tilings whose spatial footprint fits the PE budget, preferring ones
    that fill at least ``min_fill`` of it."""
    dims = list(phase.order)
    cands = {d: _dim_candidates(phase, d, extents[d], budget) for d in dims}
    out, loose = [], []
    for combo in itertools.product(*(cands[d] for d in dims)):
        fp = int(np.prod(combo))
        if fp > budget:
            continue
        t = dict(zip(dims, combo))
        loose.append(t)
        if fp >= max(1, int(budget * min_fill)):
            out.append(t)
    return out or loose


@dataclass
class MappingResult:
    dataflow: GNNDataflow
    stats: RunStats
    skeleton: str = ""

    def objective(self, name: str) -> float:
        if name == "cycles":
            return self.stats.cycles
        if name == "energy":
            return self.stats.energy_pj
        if name == "edp":
            return self.stats.cycles * self.stats.energy_pj
        raise KeyError(name)


def optimize_tiles(
    skeleton: DataflowSkeleton,
    wl: GNNLayerWorkload,
    hw: AcceleratorConfig = DEFAULT_ACCEL,
    objective: str = "edp",
    pe_splits: tuple[float, ...] = (0.5,),
    max_evals: int = 4096,
) -> MappingResult:
    """Search tile sizes (and PP PE splits) for a dataflow skeleton."""
    feat = wl.f_in if skeleton.order == PhaseOrder.AC else wl.g_out
    agg_ext = {"V": wl.v, "N": max(int(wl.nnz.max()), 1), "F": feat}
    cmb_ext = {"V": wl.v, "G": wl.g_out, "F": wl.f_in}
    splits = pe_splits if skeleton.inter == InterPhase.PP else (0.5,)

    best: MappingResult | None = None
    for split in splits:
        if skeleton.inter == InterPhase.PP:
            pe_first = max(1, int(round(hw.n_pes * split)))
            pe_second = max(1, hw.n_pes - pe_first)
            if skeleton.order == PhaseOrder.AC:
                b_agg, b_cmb = pe_first, pe_second
            else:
                b_agg, b_cmb = pe_second, pe_first
        else:
            b_agg = b_cmb = hw.n_pes

        agg_tilings = _phase_tilings(skeleton.agg, agg_ext, b_agg)
        if skeleton.sp_optimized:
            pairs = []
            for at in agg_tilings:
                if at.get("N", 1) != 1:
                    continue  # SP-Optimized: temporal reduction (T_N = 1)
                ct = {"V": at["V"], "F": at["F"], "G": 1}
                if at["V"] * at["F"] <= b_cmb:
                    pairs.append((at, ct))
        else:
            cmb_tilings = _phase_tilings(skeleton.cmb, cmb_ext, b_cmb)
            pairs = list(itertools.product(agg_tilings, cmb_tilings))
        if len(pairs) > max_evals:
            idx = np.linspace(0, len(pairs) - 1, max_evals).astype(int)
            pairs = [pairs[i] for i in idx]
        for at, ct in pairs:
            df = skeleton.concretize(at, ct, pe_split=split)
            try:
                stats = simulate(df, wl, hw)
            except ValueError:
                continue
            res = MappingResult(df, stats, skeleton=skeleton.name)
            if best is None or res.objective(objective) < best.objective(objective):
                best = res
    if best is None:
        raise RuntimeError(f"no legal tiling found for {skeleton.name}")
    return best


#: The paper's Table 5 evaluation set.
TABLE5_NAMES = (
    "Seq-Nt",
    "Seq-Ns",
    "SP-FsNt-Fs",
    "SP-VsNt-Vs",
    "High-Vs-SP",
    "PP-Nt-Vt/sl",
    "PP-Ns-Vt/sl",
    "PP-Nt-Vsh",
    "PP-Ns-Vsh",
)


def search_dataflows(
    wl: GNNLayerWorkload,
    hw: AcceleratorConfig = DEFAULT_ACCEL,
    objective: str = "edp",
    names: tuple[str, ...] = TABLE5_NAMES,
    pe_splits: tuple[float, ...] = (0.25, 0.5, 0.75),
) -> list[MappingResult]:
    """Rank dataflow skeletons (default: the paper's Table 5 set) for a
    workload.  Returns results sorted by the objective — this is the
    workload-adaptive dataflow choice the paper argues flexible
    accelerators enable."""
    out = []
    for n in names:
        try:
            out.append(
                optimize_tiles(
                    named_skeleton(n), wl, hw, objective=objective, pe_splits=pe_splits
                )
            )
        except (RuntimeError, ValueError):
            continue
    out.sort(key=lambda r: r.objective(objective))
    return out
