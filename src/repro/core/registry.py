"""Extension registries shared by the search and execution stacks.

Two registries back the :func:`repro.compile` front-end:

* **Objectives** — named scalar figures of merit over ``(cycles, energy)``.
  Every ``.objective(name)`` method (``MappingResult``, ``BatchStats``,
  ``ModelStats``, ``TransitionStats``) and every ``objective=`` search
  argument resolves names here, so an unknown objective raises *one*
  consistent :class:`ValueError` listing the valid names, and a new
  objective (say, a custom EDAP) becomes searchable everywhere with a
  single :func:`register_objective` call.

* **Kernels** — executable inter-phase paths keyed by the
  :class:`~repro.core.schedule.ExecSpec` fields ``(policy, order,
  use_pallas)``.  The JAX/Pallas implementations in
  :mod:`repro.gnn.layers` register themselves at import time and
  ``multiphase_matmul`` becomes a thin dispatcher; a Pallas-less key falls
  back to the jnp implementation of the same ``(policy, order)``, which is
  exactly the CPU-fallback semantics the string-dispatch code used to
  hand-roll.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Iterable


# ---------------------------------------------------------------------------
# Objectives
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Objective:
    """A named figure of merit computed from (cycles, energy_pj).

    ``fn`` must accept scalars *and* numpy arrays (the batch engine calls it
    on whole candidate grids).  ``additive`` marks objectives that sum
    across layers/transitions — the model-level DP requires one.
    """

    name: str
    fn: Callable[[Any, Any], Any]
    additive: bool = False
    description: str = ""

    def __call__(self, cycles, energy_pj):
        return self.fn(cycles, energy_pj)


_OBJECTIVES: dict[str, Objective] = {}


def register_objective(
    name: str,
    fn: Callable[[Any, Any], Any],
    *,
    additive: bool = False,
    description: str = "",
    replace: bool = False,
) -> Objective:
    """Register ``fn(cycles, energy_pj) -> value`` under ``name``."""
    if name in _OBJECTIVES and not replace:
        raise ValueError(
            f"objective {name!r} is already registered; pass replace=True "
            f"to overwrite"
        )
    obj = Objective(name, fn, additive=additive, description=description)
    _OBJECTIVES[name] = obj
    return obj


def unregister_objective(name: str) -> None:
    _OBJECTIVES.pop(name, None)


def objective_names(additive_only: bool = False) -> tuple[str, ...]:
    return tuple(
        sorted(
            n for n, o in _OBJECTIVES.items() if o.additive or not additive_only
        )
    )


def get_objective(name: str) -> Objective:
    """Resolve an objective name, or raise the one canonical error."""
    try:
        return _OBJECTIVES[name]
    except KeyError:
        raise ValueError(
            f"unknown objective {name!r}; valid objectives: "
            f"{', '.join(objective_names())}"
        ) from None


def objective_value(name: str, cycles, energy_pj):
    """``get_objective(name).fn(cycles, energy_pj)`` in one call."""
    return get_objective(name).fn(cycles, energy_pj)


register_objective(
    "cycles", lambda c, e: c, additive=True, description="runtime in cycles"
)
register_objective(
    "energy", lambda c, e: e, additive=True, description="energy in pJ"
)
register_objective(
    "edp",
    lambda c, e: c * e,
    additive=False,
    description="energy-delay product (cycles * pJ)",
)


# ---------------------------------------------------------------------------
# Kernels
# ---------------------------------------------------------------------------

#: (policy, order, use_pallas) -> callable(adj, x, w, spec, mesh)
_KERNELS: dict[tuple[str, str, bool], Callable] = {}

#: dispatch wrappers applied (in push order) to every kernel resolved by
#: :func:`lookup_kernel`.  A hook is ``fn(requested_key, impl) -> impl``:
#: it sees the *requested* ``(policy, order, use_pallas)`` key — even when
#: the Pallas->jnp fallback resolved a different entry — and may return a
#: substitute.  This is the seam the fault-injection harness
#: (:mod:`repro.runtime.faults`) uses to simulate an execution backend
#: going down; hooks fire at dispatch (trace) time, so already-jitted
#: executables are unaffected, exactly like a live backend outage.
_KERNEL_HOOKS: list[Callable] = []

ORDERS = ("AC", "CA")


def push_kernel_hook(hook: Callable) -> Callable:
    """Install a dispatch wrapper (see ``_KERNEL_HOOKS``); returns it so
    callers can :func:`pop_kernel_hook` it later."""
    _KERNEL_HOOKS.append(hook)
    return hook


def pop_kernel_hook(hook: Callable) -> None:
    """Remove a previously pushed dispatch wrapper (no-op if absent)."""
    try:
        _KERNEL_HOOKS.remove(hook)
    except ValueError:
        pass


def register_kernel(
    policy: str,
    orders: Iterable[str] = ORDERS,
    pallas: Iterable[bool] = (False,),
):
    """Decorator: register an executable path for ``policy`` under each
    ``(order, use_pallas)`` combination.  Implementations take
    ``(adj, x, w, spec, mesh)`` where ``spec`` is the lowered
    :class:`~repro.core.schedule.ExecSpec`."""

    def deco(fn: Callable) -> Callable:
        for order in orders:
            if order not in ORDERS:
                raise ValueError(f"order must be one of {ORDERS}, got {order!r}")
            for p in pallas:
                key = (policy, order, bool(p))
                if key in _KERNELS:
                    raise ValueError(f"kernel already registered for {key}")
                _KERNELS[key] = fn
        return fn

    return deco


def kernel_policies() -> tuple[str, ...]:
    return tuple(sorted({k[0] for k in _KERNELS}))


def lookup_kernel(policy: str, order: str, use_pallas: bool = False) -> Callable:
    """Resolve the executable path for an ``ExecSpec``.

    A missing Pallas variant falls back to the jnp path of the same
    ``(policy, order)`` — e.g. ``sp_generic`` has no Pallas kernel, and
    ``sp_opt``'s fused kernel only covers the AC order.  Installed
    dispatch hooks (:func:`push_kernel_hook`) wrap the resolved kernel,
    keyed by the *requested* tuple.
    """
    requested = (policy, order, bool(use_pallas))
    for key in (requested, (policy, order, False)):
        impl = _KERNELS.get(key)
        if impl is not None:
            for hook in _KERNEL_HOOKS:
                impl = hook(requested, impl)
            return impl
    if policy not in kernel_policies():
        raise ValueError(
            f"policy must be one of {kernel_policies()}, got {policy!r}"
        )
    raise ValueError(
        f"order must be one of {ORDERS}, got {order!r} (policy {policy!r})"
    )
