"""GNN multiphase dataflow taxonomy (paper Tables 1 and 2).

This module encodes the paper's complete dataflow description template::

    <Inter><order>(<AggIntra>, <CmbIntra>)

 * ``Inter``    — SEQ | SP | PP  (SP-Optimized is a *subset* of SP, per
                  paper Sec. 4.2: "we can select a subset of intra-phase
                  dataflows ...").
 * ``order``    — AC (aggregation->combination) | CA.
 * ``*Intra``   — a permutation of the phase's three loop dimensions, each
                  bound spatially or temporally, each with a tile size
                  ``T_dim`` (T_dim == 1 for temporal dims).

Aggregation loops over dims (V, N, F): vertices, neighbors (reduction),
features.  Combination loops over (V, G, F): vertices, out-features,
in-features (reduction).  For CA order the aggregation's ``F`` extent binds
to ``G`` (the intermediate X·W is V x G).

``enumerate_dataflows`` reproduces the paper's count of **6,656** loop-order
x parallelism x phase-order choices across the three inter-phase classes
(Seq: unconstrained; SP/PP: constrained to the pipelineable patterns of
Table 2 rows 4-9).  Tile sizes multiply this into the trillions and are
handled by :mod:`repro.core.mapper`.
"""
from __future__ import annotations

import enum
import itertools
import re
from dataclasses import dataclass, field, replace
from typing import Iterable, Sequence

AGG_DIMS = ("V", "N", "F")  # N is the reduction dim of aggregation (SpMM)
CMB_DIMS = ("V", "G", "F")  # F is the reduction dim of combination (GEMM)
AGG_REDUCTION = "N"
CMB_REDUCTION = "F"


class Binding(str, enum.Enum):
    SPATIAL = "s"
    TEMPORAL = "t"


class InterPhase(str, enum.Enum):
    SEQ = "Seq"
    SP = "SP"
    PP = "PP"


class PhaseOrder(str, enum.Enum):
    AC = "AC"  # aggregation then combination (e.g. GraphSAGE, HyGCN)
    CA = "CA"  # combination then aggregation (e.g. AWB-GCN)


class Granularity(str, enum.Enum):
    """Pipelining granularity of the intermediate matrix (paper Sec. 4.4)."""

    ELEMENT = "element"
    ROW = "row"
    COLUMN = "column"
    NONE = "none"  # Seq has no pipelining granularity


@dataclass(frozen=True)
class Loop:
    """One loop level: a dimension, its binding and its tile size.

    ``tile`` is T_dim — the number of elements of the dimension mapped in
    parallel across PEs when spatial.  Temporal dims have tile == 1.
    """

    dim: str
    binding: Binding
    tile: int = 1

    def __post_init__(self):
        if self.binding == Binding.TEMPORAL and self.tile != 1:
            raise ValueError(
                f"temporal loop {self.dim} must have tile 1, got {self.tile}"
            )
        if self.tile < 1:
            raise ValueError(f"tile size must be >= 1, got {self.tile}")

    @property
    def spatial(self) -> bool:
        return self.binding == Binding.SPATIAL

    def __str__(self) -> str:  # e.g. "Vs(8)" or "Nt"
        t = f"({self.tile})" if self.spatial and self.tile > 1 else ""
        return f"{self.dim}{self.binding.value}{t}"


@dataclass(frozen=True)
class IntraPhaseDataflow:
    """Loop nest for a single phase, outermost loop first."""

    loops: tuple[Loop, ...]
    phase: str = "agg"  # "agg" | "cmb"

    def __post_init__(self):
        dims = tuple(l.dim for l in self.loops)
        expected = AGG_DIMS if self.phase == "agg" else CMB_DIMS
        if sorted(dims) != sorted(expected):
            raise ValueError(
                f"{self.phase} dataflow must permute {expected}, got {dims}"
            )

    # -- helpers ----------------------------------------------------------
    @property
    def order(self) -> tuple[str, ...]:
        return tuple(l.dim for l in self.loops)

    def loop(self, dim: str) -> Loop:
        for l in self.loops:
            if l.dim == dim:
                return l
        raise KeyError(dim)

    def tile(self, dim: str) -> int:
        return self.loop(dim).tile

    def binding(self, dim: str) -> Binding:
        return self.loop(dim).binding

    @property
    def reduction_dim(self) -> str:
        return AGG_REDUCTION if self.phase == "agg" else CMB_REDUCTION

    @property
    def spatial_footprint(self) -> int:
        """Number of PE lanes this intra-phase mapping occupies."""
        out = 1
        for l in self.loops:
            out *= l.tile
        return out

    @property
    def temporal_reduction(self) -> bool:
        return self.binding(self.reduction_dim) == Binding.TEMPORAL

    def with_tiles(self, **tiles: int) -> "IntraPhaseDataflow":
        new = []
        for l in self.loops:
            if l.dim in tiles:
                t = tiles[l.dim]
                b = Binding.SPATIAL if t > 1 else l.binding
                # setting tile 1 on a spatial loop leaves it spatial with T=1
                new.append(Loop(l.dim, b if t > 1 else l.binding, t))
            else:
                new.append(l)
        return replace(self, loops=tuple(new))

    def __str__(self) -> str:
        return "".join(str(l) for l in self.loops)


def intra(spec: str, phase: str, **tiles: int) -> IntraPhaseDataflow:
    """Parse a compact spec like ``"VtFsNt"`` into an IntraPhaseDataflow.

    ``tiles`` provides T_dim for spatial dims, e.g. ``intra("VsFsNt", "agg",
    V=16, F=32)``.
    """
    if len(spec) != 6:
        raise ValueError(f"spec must be 6 chars like 'VtFsNt', got {spec!r}")
    loops = []
    for i in range(0, 6, 2):
        dim, b = spec[i], spec[i + 1]
        binding = Binding(b)
        tile = tiles.get(dim, 1)
        if binding == Binding.TEMPORAL:
            tile = 1
        loops.append(Loop(dim, binding, tile))
    return IntraPhaseDataflow(tuple(loops), phase=phase)


# ---------------------------------------------------------------------------
# Complete dataflow
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GNNDataflow:
    """Complete description: <Inter><order>(<AggIntra>, <CmbIntra>)."""

    inter: InterPhase
    order: PhaseOrder
    agg: IntraPhaseDataflow
    cmb: IntraPhaseDataflow
    # PP only: fraction of PEs given to the *first* phase of `order`.
    pe_split: float = 0.5

    def __post_init__(self):
        if self.agg.phase != "agg" or self.cmb.phase != "cmb":
            raise ValueError("agg/cmb intra dataflows swapped")
        if self.inter == InterPhase.PP and not 0.0 < self.pe_split < 1.0:
            raise ValueError("pe_split must be in (0, 1)")

    # -- classification ----------------------------------------------------
    @property
    def first(self) -> IntraPhaseDataflow:
        return self.agg if self.order == PhaseOrder.AC else self.cmb

    @property
    def second(self) -> IntraPhaseDataflow:
        return self.cmb if self.order == PhaseOrder.AC else self.agg

    @property
    def granularity(self) -> Granularity:
        return classify_granularity(self.order, self.agg.order, self.cmb.order)

    @property
    def is_pipelineable(self) -> bool:
        return self.granularity != Granularity.NONE

    @property
    def is_sp_optimized(self) -> bool:
        """Paper Table 2 row 2 — the SP subset whose intermediate stays in
        the PEs.  Requires: element granularity loop orders, temporal
        reduction in the first phase (T_N = 1 for AC), matching tiles for
        the shared dims, and a temporal inner loop in the second phase."""
        if self.inter != InterPhase.SP:
            return False
        if self.granularity != Granularity.ELEMENT:
            return False
        if self.order == PhaseOrder.AC:
            shared = ("V", "F")
            if self.agg.binding("N") != Binding.TEMPORAL:
                return False
            if self.cmb.binding("G") != Binding.TEMPORAL:
                return False
            return all(self.agg.tile(d) == self.cmb.tile(d) for d in shared)
        else:
            # CA - {N_x F_x} V_t , {V_x G_x} F_t  (intermediate is V x G,
            # shared dims map agg.N<->cmb.V and agg.F<->cmb.G)
            if self.agg.binding("V") != Binding.TEMPORAL:
                return False
            if self.cmb.binding("F") != Binding.TEMPORAL:
                return False
            return (
                self.agg.tile("N") == self.cmb.tile("V")
                and self.agg.tile("F") == self.cmb.tile("G")
            )

    def validate(self, n_pes: int | None = None) -> None:
        """Raise ValueError if the dataflow is illegal (paper Table 2)."""
        if self.inter in (InterPhase.SP, InterPhase.PP):
            if not self.is_pipelineable:
                raise ValueError(
                    f"{self} is not pipelineable: loop orders "
                    f"({'/'.join(self.agg.order)}, {'/'.join(self.cmb.order)}) "
                    "admit no element/row/column granularity (Table 2 rows 4-9)"
                )
        if n_pes is not None:
            if self.inter == InterPhase.PP:
                pe_first = max(1, int(n_pes * self.pe_split))
                pe_second = max(1, n_pes - pe_first)
                budgets = (
                    (self.first, pe_first),
                    (self.second, pe_second),
                )
            else:
                budgets = ((self.agg, n_pes), (self.cmb, n_pes))
            for df, budget in budgets:
                if df.spatial_footprint > budget:
                    raise ValueError(
                        f"{df} spatial footprint {df.spatial_footprint} "
                        f"exceeds PE budget {budget}"
                    )

    def __str__(self) -> str:
        name = self.inter.value
        if self.is_sp_optimized:
            name = "SPopt"
        return f"{name}_{self.order.value}({self.agg}, {self.cmb})"

    def to_string(self) -> str:
        """Canonical, parseable template notation (paper Sec. 4.1):

            <Inter>[<pe_split>]_<order>(<AggIntra>, <CmbIntra>)

        Unlike ``str(df)`` this never renames SP to "SPopt" (the subset
        membership is derived, not stored), always prints spatial tile
        sizes, and carries the PP PE split so
        ``parse_dataflow(df.to_string()) == df`` holds exactly.
        """
        def loops(ph: IntraPhaseDataflow) -> str:
            out = []
            for l in ph.loops:
                t = f"({l.tile})" if l.spatial else ""
                out.append(f"{l.dim}{l.binding.value}{t}")
            return "".join(out)

        split = f"[{self.pe_split!r}]" if self.inter == InterPhase.PP else ""
        return (
            f"{self.inter.value}{split}_{self.order.value}"
            f"({loops(self.agg)}, {loops(self.cmb)})"
        )


# ---------------------------------------------------------------------------
# Granularity classification (paper Sec 4.4, Table 2 rows 4-9)
# ---------------------------------------------------------------------------


def classify_granularity(
    order: PhaseOrder,
    agg_order: Sequence[str],
    cmb_order: Sequence[str],
) -> Granularity:
    """Classify the pipelining granularity admitted by a loop-order pair.

    The intermediate matrix is V x F for AC (rows indexed by V, columns by
    the feature dim) and V x G for CA.  A pair is pipelineable iff producer
    and consumer walk the intermediate in a compatible order:

      * ELEMENT — both phases' outer two loops are the intermediate's two
        index dims, in the same order (Table 2 rows 4, 7).
      * ROW     — both phases' outermost loop is the intermediate's row dim
        (rows 5, 8), excluding pairs already classified ELEMENT.
      * COLUMN  — both outermost loops are the intermediate's column dim
        (rows 6, 9), excluding ELEMENT pairs.
    """
    agg_order = tuple(agg_order)
    cmb_order = tuple(cmb_order)
    if order == PhaseOrder.AC:
        # intermediate (AX) is V x F: agg indexes it (V, F); cmb (V, F).
        first_ix = {"row": "V", "col": "F", "dims": ("V", "F")}
        first, second = agg_order, cmb_order
        second_ix = {"row": "V", "col": "F", "dims": ("V", "F")}
    else:
        # intermediate (XW) is V x G: cmb indexes it (V, G); agg consumes it
        # as its "input feature" matrix indexed by (N [gathered rows], F=G).
        first_ix = {"row": "V", "col": "G", "dims": ("V", "G")}
        first, second = cmb_order, agg_order
        second_ix = {"row": "N", "col": "F", "dims": ("N", "F")}

    def outer2(o, ix):
        return tuple(d for d in o if d in ix["dims"])[:2]

    f2 = outer2(first, first_ix)
    s2 = outer2(second, second_ix)
    # map second phase's intermediate dims onto (row, col) labels
    def lab(d, ix):
        return "row" if d == ix["row"] else "col"

    f_lab = tuple(lab(d, first_ix) for d in f2)
    s_lab = tuple(lab(d, second_ix) for d in s2)

    # ELEMENT: outer two loops of both phases are the intermediate dims in
    # the same (row/col) order — i.e. the third (non-intermediate) dim is
    # innermost in both phases (Table 2 rows 4, 7).
    f_elem = first[0] in first_ix["dims"] and first[1] in first_ix["dims"]
    s_elem = second[0] in second_ix["dims"] and second[1] in second_ix["dims"]
    if f_elem and s_elem and f_lab == s_lab:
        return Granularity.ELEMENT
    # ROW / COLUMN: outermost loops of both phases walk the same axis of the
    # intermediate (rows 5-6, 8-9); ELEMENT pairs were already consumed.
    if first[0] == first_ix["row"] and second[0] == second_ix["row"]:
        return Granularity.ROW
    if first[0] == first_ix["col"] and second[0] == second_ix["col"]:
        return Granularity.COLUMN
    return Granularity.NONE


# ---------------------------------------------------------------------------
# Template-notation parsing (inverse of GNNDataflow.to_string)
# ---------------------------------------------------------------------------

_DF_RE = re.compile(
    r"^(?P<inter>Seq|SPopt|SP|PP)"
    r"(?:\[(?P<split>[0-9.eE+-]+)\])?"
    r"_(?P<order>AC|CA)"
    r"\((?P<agg>[^,]+),\s*(?P<cmb>.+)\)$"
)
_LOOP_RE = re.compile(r"([VNFG])([st])(?:\((\d+)\))?")


def _parse_intra(spec: str, phase: str) -> IntraPhaseDataflow:
    loops, consumed = [], 0
    for m in _LOOP_RE.finditer(spec):
        if m.start() != consumed:
            raise ValueError(f"malformed intra-phase spec {spec!r}")
        consumed = m.end()
        dim, b, tile = m.group(1), Binding(m.group(2)), m.group(3)
        loops.append(Loop(dim, b, int(tile) if tile else 1))
    if consumed != len(spec) or len(loops) != 3:
        raise ValueError(f"malformed intra-phase spec {spec!r}")
    return IntraPhaseDataflow(tuple(loops), phase=phase)


def parse_dataflow(text: str) -> GNNDataflow:
    """Parse the paper's ``<Inter><order>(<AggIntra>, <CmbIntra>)`` template.

    Inverse of :meth:`GNNDataflow.to_string`; also accepts the "SPopt"
    prefix that ``str(df)`` prints for SP-Optimized instances (membership is
    re-derived from the loop structure, not stored).  A ``[pe_split]``
    bracket after the inter-phase class carries the PP PE allocation.
    """
    m = _DF_RE.match(text.strip())
    if m is None:
        raise ValueError(f"cannot parse dataflow template {text!r}")
    inter = InterPhase.SP if m["inter"] == "SPopt" else InterPhase(m["inter"])
    kwargs = {}
    if m["split"] is not None:
        kwargs["pe_split"] = float(m["split"])
    return GNNDataflow(
        inter,
        PhaseOrder(m["order"]),
        _parse_intra(m["agg"].strip(), "agg"),
        _parse_intra(m["cmb"].strip(), "cmb"),
        **kwargs,
    )


# ---------------------------------------------------------------------------
# Layer-boundary walk orders (model-level transition costing, Sec. 4.4)
# ---------------------------------------------------------------------------


def output_walk(df: GNNDataflow) -> str:
    """Major order ("row" | "column") in which a layer's final V x F_out
    output matrix is produced.

    The output is written by the *second* phase of the phase order: the
    combination (V x G) for AC, the aggregation (V x F) for CA.  For
    pipelined dataflows (SP/PP) the walk follows the pipelining granularity;
    for Seq it is the loop order of the producing phase.
    """
    second = df.second
    col = "G" if second.phase == "cmb" else "F"
    gran = df.granularity
    if df.inter in (InterPhase.SP, InterPhase.PP) and gran != Granularity.NONE:
        # element granularity walks the chunk grid row-major (see
        # simulator._pp_chunk_times)
        return "column" if gran == Granularity.COLUMN else "row"
    order = second.order
    return "row" if order.index("V") < order.index(col) else "column"


def input_walk(df: GNNDataflow) -> str:
    """Major order ("row" | "column") in which a layer streams its input
    feature matrix X (V x F_in) out of the Global Buffer.

    AC consumes X in the aggregation phase: neighbor *rows* are gathered by
    N (row-major access), except when the F loop is outermost — then the
    whole matrix is swept one column block at a time.  CA consumes X in the
    combination GEMM as a dense (V, F) operand, column-major when F is
    outer to V.
    """
    first = df.first
    if first.phase == "cmb":
        return "row" if first.order.index("V") < first.order.index("F") else "column"
    return "column" if first.order[0] == "F" else "row"


# ---------------------------------------------------------------------------
# Enumeration (paper: 6,656 choices)
# ---------------------------------------------------------------------------


def _all_intra(phase: str) -> list[IntraPhaseDataflow]:
    dims = AGG_DIMS if phase == "agg" else CMB_DIMS
    out = []
    for perm in itertools.permutations(dims):
        for bindings in itertools.product(Binding, repeat=3):
            loops = tuple(Loop(d, b, 1) for d, b in zip(perm, bindings))
            out.append(IntraPhaseDataflow(loops, phase=phase))
    return out


def enumerate_dataflows(
    inter_phases: Iterable[InterPhase] = tuple(InterPhase),
    orders: Iterable[PhaseOrder] = tuple(PhaseOrder),
) -> list[GNNDataflow]:
    """Enumerate the loop-order x parallelism x phase-order design space.

    Tile sizes are left at 1 (they are a separate, continuous axis of the
    map space).  With all three inter-phase classes and both phase orders
    this yields exactly 6,656 dataflows: 48x48x2 = 4,608 Seq + 1,024 SP +
    1,024 PP (the pipelineable loop-order pairs of Table 2 rows 4-9).
    """
    aggs = _all_intra("agg")
    cmbs = _all_intra("cmb")
    out: list[GNNDataflow] = []
    for ip in inter_phases:
        for order in orders:
            for a, c in itertools.product(aggs, cmbs):
                df = GNNDataflow(ip, order, a, c)
                if ip in (InterPhase.SP, InterPhase.PP) and not df.is_pipelineable:
                    continue
                out.append(df)
    return out


# ---------------------------------------------------------------------------
# Skeletons: dataflows with free ("x") dims, for the mapping optimizer
# ---------------------------------------------------------------------------


class Cons(str, enum.Enum):
    """Binding constraint for one dim of a dataflow skeleton.

    Mirrors the paper's subscripts: ``t``/``s`` are forced, ``x`` is free
    (the mapper chooses), ``s_high``/``s_low`` are the paper's Vsh / Vt/sl
    annotations (necessarily-spatial with a large / small tile).
    """

    T = "t"
    S = "s"
    X = "x"
    S_HIGH = "sh"
    S_LOW = "sl"
    S_FULL = "sf"  # the whole PE budget on this one dim (rigid substrate)


@dataclass(frozen=True)
class SkeletonPhase:
    order: tuple[str, ...]
    cons: tuple[Cons, Cons, Cons]  # aligned with `order`
    fixed: tuple[int, ...] = (0, 0, 0)  # 0 = not fixed, else exact tile

    def constraint(self, dim: str) -> Cons:
        return self.cons[self.order.index(dim)]

    def fixed_tile(self, dim: str) -> int:
        return self.fixed[self.order.index(dim)]

    def to_intra(self, phase: str, tiles: dict[str, int]) -> IntraPhaseDataflow:
        loops = []
        for d, c in zip(self.order, self.cons):
            t = tiles.get(d, 1)
            if c == Cons.T:
                loops.append(Loop(d, Binding.TEMPORAL, 1))
            else:
                loops.append(Loop(d, Binding.SPATIAL if t > 1 else Binding.TEMPORAL, max(t, 1)))
        return IntraPhaseDataflow(tuple(loops), phase=phase)


@dataclass(frozen=True)
class DataflowSkeleton:
    """A Table-5 style dataflow family: loop orders + binding constraints.

    The mapper (:mod:`repro.core.mapper`) binds tile sizes, producing a
    concrete :class:`GNNDataflow`.
    """

    name: str
    inter: InterPhase
    order: PhaseOrder
    agg: SkeletonPhase
    cmb: SkeletonPhase
    sp_optimized: bool = False  # tie T_V/T_F across phases, T_N = 1

    def concretize(
        self,
        agg_tiles: dict[str, int],
        cmb_tiles: dict[str, int],
        pe_split: float = 0.5,
    ) -> GNNDataflow:
        return GNNDataflow(
            self.inter,
            self.order,
            self.agg.to_intra("agg", agg_tiles),
            self.cmb.to_intra("cmb", cmb_tiles),
            pe_split=pe_split,
        )


def _sk(order: str, cons: str, fixed: tuple[int, int, int] = (0, 0, 0)) -> SkeletonPhase:
    dims = tuple(order)
    cmap = {
        "t": Cons.T,
        "s": Cons.S,
        "x": Cons.X,
        "h": Cons.S_HIGH,
        "l": Cons.S_LOW,
        "f": Cons.S_FULL,
    }
    return SkeletonPhase(dims, tuple(cmap[c] for c in cons), fixed)


#: Table 5 dataflow configurations (+ HyGCN / AWB-GCN / EnGN), as skeletons.
SKELETONS: dict[str, DataflowSkeleton] = {
    # Seq_AC(VxFxNt, VxGxFx) — temporal aggregation
    "Seq-Nt": DataflowSkeleton(
        "Seq-Nt", InterPhase.SEQ, PhaseOrder.AC, _sk("VFN", "xxt"), _sk("VGF", "xxx")
    ),
    # Seq_AC(VxFxNs, VxGxFx) — spatial aggregation
    "Seq-Ns": DataflowSkeleton(
        "Seq-Ns", InterPhase.SEQ, PhaseOrder.AC, _sk("VFN", "xxs"), _sk("VGF", "xxx")
    ),
    # SP_AC(VxFsNt, VxFsGx) — SP-optimized, high T_F
    "SP-FsNt-Fs": DataflowSkeleton(
        "SP-FsNt-Fs", InterPhase.SP, PhaseOrder.AC,
        _sk("VFN", "xht"), _sk("VFG", "xht"), sp_optimized=True,
    ),
    # SP_AC(VsFxNt, VsFxGx) — SP-optimized, high T_V
    "SP-VsNt-Vs": DataflowSkeleton(
        "SP-VsNt-Vs", InterPhase.SP, PhaseOrder.AC,
        _sk("VFN", "hxt"), _sk("VFG", "hxt"), sp_optimized=True,
    ),
    # High-Vs-SP — the rigid-substrate degenerate SP-opt: T_F = T_N = 1,
    # all parallelism on V (paper Sec. 5.4)
    "High-Vs-SP": DataflowSkeleton(
        "High-Vs-SP", InterPhase.SP, PhaseOrder.AC,
        _sk("VFN", "ftt"), _sk("VFG", "ftt"), sp_optimized=True,
    ),
    # PP_AC(VxFxNt, VxGxFx) — row granularity, few rows pipelined
    "PP-Nt-Vt/sl": DataflowSkeleton(
        "PP-Nt-Vt/sl", InterPhase.PP, PhaseOrder.AC,
        _sk("VFN", "xxt"), _sk("VGF", "lxx"),
    ),
    "PP-Ns-Vt/sl": DataflowSkeleton(
        "PP-Ns-Vt/sl", InterPhase.PP, PhaseOrder.AC,
        _sk("VFN", "xxs"), _sk("VGF", "lxx"),
    ),
    # PP_AC(VxFxNt, VsGxFx) — row granularity, many rows pipelined
    "PP-Nt-Vsh": DataflowSkeleton(
        "PP-Nt-Vsh", InterPhase.PP, PhaseOrder.AC,
        _sk("VFN", "xxt"), _sk("VGF", "hxx"),
    ),
    "PP-Ns-Vsh": DataflowSkeleton(
        "PP-Ns-Vsh", InterPhase.PP, PhaseOrder.AC,
        _sk("VFN", "xxs"), _sk("VGF", "hxx"),
    ),
    # HyGCN: PP_AC(VxFsNt, VsGsFt)
    "HyGCN": DataflowSkeleton(
        "HyGCN", InterPhase.PP, PhaseOrder.AC,
        _sk("VFN", "xst"), _sk("VGF", "sst"),
    ),
    # AWB-GCN: PP_CA(FsNtVs, GtFtVs)
    "AWB-GCN": DataflowSkeleton(
        "AWB-GCN", InterPhase.PP, PhaseOrder.CA,
        _sk("FNV", "sts"), _sk("GFV", "tts"),
    ),
    # EnGN: SP-Optimized instance
    "EnGN": DataflowSkeleton(
        "EnGN", InterPhase.SP, PhaseOrder.AC,
        _sk("VFN", "sst"), _sk("VFG", "sst"), sp_optimized=True,
    ),
}


def named_skeleton(name: str) -> DataflowSkeleton:
    if name not in SKELETONS:
        raise KeyError(f"unknown skeleton {name!r}; have {sorted(SKELETONS)}")
    return SKELETONS[name]


# ---------------------------------------------------------------------------
# Named dataflows from the paper (Table 5 + known accelerators)
# ---------------------------------------------------------------------------


def named_dataflow(name: str, **tiles) -> GNNDataflow:
    """Table 5 configurations plus HyGCN / AWB-GCN / EnGN dataflows.

    ``tiles`` keys: T_V_AGG, T_N, T_F_AGG, T_V_CMB, T_G, T_F_CMB.
    """
    tv_a = tiles.get("T_V_AGG", 1)
    tn = tiles.get("T_N", 1)
    tf_a = tiles.get("T_F_AGG", 1)
    tv_c = tiles.get("T_V_CMB", 1)
    tg = tiles.get("T_G", 1)
    tf_c = tiles.get("T_F_CMB", 1)

    def a(spec):
        return intra(spec, "agg", V=tv_a, N=tn, F=tf_a)

    def c(spec):
        return intra(spec, "cmb", V=tv_c, G=tg, F=tf_c)

    def s(d, t):  # binding char from tile size
        return "s" if t > 1 else d

    catalog = {
        # -- Table 5 ---------------------------------------------------------
        "Seq-Nt": lambda: GNNDataflow(
            InterPhase.SEQ, PhaseOrder.AC,
            a(f"V{'s' if tv_a>1 else 't'}F{'s' if tf_a>1 else 't'}Nt"),
            c(f"V{'s' if tv_c>1 else 't'}G{'s' if tg>1 else 't'}F{'s' if tf_c>1 else 't'}"),
        ),
        "Seq-Ns": lambda: GNNDataflow(
            InterPhase.SEQ, PhaseOrder.AC,
            a(f"V{'s' if tv_a>1 else 't'}F{'s' if tf_a>1 else 't'}Ns"),
            c(f"V{'s' if tv_c>1 else 't'}G{'s' if tg>1 else 't'}F{'s' if tf_c>1 else 't'}"),
        ),
        "SP-FsNt-Fs": lambda: GNNDataflow(  # SP-opt, high T_F
            InterPhase.SP, PhaseOrder.AC,
            a(f"V{'s' if tv_a>1 else 't'}FsNt"),
            c(f"V{'s' if tv_c>1 else 't'}FsGt"),
        ),
        "SP-VsNt-Vs": lambda: GNNDataflow(  # SP-opt, high T_V
            InterPhase.SP, PhaseOrder.AC,
            a(f"VsF{'s' if tf_a>1 else 't'}Nt"),
            c(f"VsF{'s' if tf_c>1 else 't'}Gt"),
        ),
        "High-Vs-SP": lambda: GNNDataflow(  # SP-opt degenerate: T_F=T_N=1
            InterPhase.SP, PhaseOrder.AC,
            a("VsFtNt"),
            c("VsFtGt"),
        ),
        "PP-Nt-Vt/sl": lambda: GNNDataflow(  # row granularity, low rows
            InterPhase.PP, PhaseOrder.AC,
            a(f"V{'s' if tv_a>1 else 't'}F{'s' if tf_a>1 else 't'}Nt"),
            c(f"V{'s' if tv_c>1 else 't'}G{'s' if tg>1 else 't'}F{'s' if tf_c>1 else 't'}"),
            pe_split=tiles.get("pe_split", 0.5),
        ),
        "PP-Ns-Vt/sl": lambda: GNNDataflow(
            InterPhase.PP, PhaseOrder.AC,
            a(f"V{'s' if tv_a>1 else 't'}F{'s' if tf_a>1 else 't'}Ns"),
            c(f"V{'s' if tv_c>1 else 't'}G{'s' if tg>1 else 't'}F{'s' if tf_c>1 else 't'}"),
            pe_split=tiles.get("pe_split", 0.5),
        ),
        "PP-Nt-Vsh": lambda: GNNDataflow(  # high granularity (many rows)
            InterPhase.PP, PhaseOrder.AC,
            a(f"V{'s' if tv_a>1 else 't'}F{'s' if tf_a>1 else 't'}Nt"),
            c(f"VsG{'s' if tg>1 else 't'}F{'s' if tf_c>1 else 't'}"),
            pe_split=tiles.get("pe_split", 0.5),
        ),
        "PP-Ns-Vsh": lambda: GNNDataflow(
            InterPhase.PP, PhaseOrder.AC,
            a(f"V{'s' if tv_a>1 else 't'}F{'s' if tf_a>1 else 't'}Ns"),
            c(f"VsG{'s' if tg>1 else 't'}F{'s' if tf_c>1 else 't'}"),
            pe_split=tiles.get("pe_split", 0.5),
        ),
        # -- published accelerators -----------------------------------------
        # HyGCN: PP_AC(VxFsNt, VsGsFt)
        "HyGCN": lambda: GNNDataflow(
            InterPhase.PP, PhaseOrder.AC,
            a(f"V{'s' if tv_a>1 else 't'}FsNt"),
            c("VsGsFt"),
            pe_split=tiles.get("pe_split", 0.5),
        ),
        # AWB-GCN: PP_CA(FsNtVs, GtFtVs)
        "AWB-GCN": lambda: GNNDataflow(
            InterPhase.PP, PhaseOrder.CA,
            a("FsNtVs"),
            c("GtFtVs"),
            pe_split=tiles.get("pe_split", 0.5),
        ),
        # EnGN: SP-Optimized instance
        "EnGN": lambda: GNNDataflow(
            InterPhase.SP, PhaseOrder.AC,
            a("VsFsNt"),
            c("VsFsGt"),
        ),
    }
    if name not in catalog:
        raise KeyError(f"unknown dataflow {name!r}; have {sorted(catalog)}")
    return catalog[name]()
