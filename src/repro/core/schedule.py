"""Model-level schedule IR: from taxonomy dataflows to executable knobs.

The paper's design-space is per *layer*; its case studies compose
heterogeneous dataflows across a multi-layer GNN (feature widths shrink
layer by layer, so the optimal dataflow changes — Sec. 4.4 / Sec. 5).  This
module is the bridge that makes the taxonomy :class:`GNNDataflow` the
single source of truth from search to execution:

* :class:`LayerSchedule` — one concrete dataflow bound to a layer's
  (f_in, f_out) shape, with :meth:`LayerSchedule.lower` deriving the
  executable knobs (:class:`ExecSpec`): the ``repro.gnn`` policy string,
  the row-band size of the scan, the ELL block rows, and the Pallas
  grid/block shapes consumed by ``kernels/*/ops.py``.
* :class:`ModelSchedule` — per-layer schedules plus the inter-layer
  :class:`TransitionSpec` descriptors (does the producer's output walk
  match the consumer's input walk, and how many elements re-lay-out if
  not).  JSON round-trips through the taxonomy's template notation
  (:meth:`GNNDataflow.to_string` / :func:`~repro.core.taxonomy.parse_dataflow`).

The costed counterpart lives in :mod:`repro.core.simulator`
(``ModelStats`` / ``transition_cost``); the search entry point is
``repro.core.mapper.search_model``.
"""
from __future__ import annotations

import json
import zlib
from dataclasses import asdict, dataclass, field
from typing import TYPE_CHECKING, Sequence

from .hw import AcceleratorConfig
from .taxonomy import (
    GNNDataflow,
    InterPhase,
    PhaseOrder,
    input_walk,
    intra,
    output_walk,
    parse_dataflow,
)

if TYPE_CHECKING:  # costed types only annotate; no runtime import cycle
    from .simulator import ModelStats, RunStats


# ---------------------------------------------------------------------------
# Executable knobs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ExecSpec:
    """Executable knobs for one layer, consumed by :mod:`repro.gnn`.

    ``band_size`` doubles as the Pallas row-block (``block_v``) of the
    SpMM / fused kernels; ``block_f`` is their feature-block;
    ``ell_block_rows`` groups rows when building the padded-ELL adjacency.
    """

    policy: str  # seq | sp_generic | sp_opt | pp
    order: str  # AC | CA
    band_size: int
    block_f: int | None = None  # None = the kernel's own default
    ell_block_rows: int = 1
    use_pallas: bool = False


def policy_of(df: GNNDataflow) -> str:
    """The ``repro.gnn`` execution policy a dataflow lowers to."""
    if df.inter == InterPhase.SEQ:
        return "seq"
    if df.inter == InterPhase.SP:
        return "sp_opt" if df.is_sp_optimized else "sp_generic"
    return "pp"


def _pipeline_rows(df: GNNDataflow) -> int:
    """Row extent of the intermediate chunk in flight (Sec. 4.4)."""
    if df.order == PhaseOrder.AC:
        return max(df.agg.tile("V"), df.cmb.tile("V"))
    return max(df.cmb.tile("V"), df.agg.tile("N"))


def _pipeline_cols(df: GNNDataflow) -> int:
    """Column extent of the intermediate chunk in flight."""
    if df.order == PhaseOrder.AC:
        return max(df.agg.tile("F"), df.cmb.tile("F"))
    return max(df.cmb.tile("G"), df.agg.tile("F"))


# ---------------------------------------------------------------------------
# Per-layer schedule
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerSchedule:
    """One concrete dataflow bound to a layer's (f_in, f_out) shape."""

    dataflow: GNNDataflow
    f_in: int
    f_out: int
    name: str = ""
    #: RunStats from the mapper's scalar oracle, when searched (not part of
    #: identity — two schedules with the same dataflow/shape are equal).
    stats: "RunStats | None" = field(default=None, compare=False, repr=False)

    def lower(self, use_pallas: bool = False, default_band: int = 128) -> ExecSpec:
        """Derive the executable knobs from the dataflow's structure.

        The scan band is the pipelined row chunk (``max`` of the two
        phases' row tiles — exactly the simulator's chunking); dataflows
        whose row dims are temporal fall back to ``default_band``.  Blocks
        are clamped to >= 8 rows so the Pallas tiles stay legal.
        """
        df = self.dataflow
        rows = _pipeline_rows(df)
        cols = _pipeline_cols(df)
        band = max(8, rows if rows > 1 else default_band)
        block_f = max(8, cols if cols > 1 else default_band)
        return ExecSpec(
            policy=policy_of(df),
            order=df.order.value,
            band_size=band,
            block_f=block_f,
            ell_block_rows=band,
            use_pallas=use_pallas,
        )

    def to_dict(self) -> dict:
        return {
            "dataflow": self.dataflow.to_string(),
            "f_in": self.f_in,
            "f_out": self.f_out,
            "name": self.name,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "LayerSchedule":
        return cls(
            parse_dataflow(d["dataflow"]),
            int(d["f_in"]),
            int(d["f_out"]),
            name=d.get("name", ""),
        )


# ---------------------------------------------------------------------------
# Inter-layer transitions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TransitionSpec:
    """Structural descriptor of one layer boundary.

    ``relayout`` is True when the producer's output walk disagrees with the
    consumer's input walk — the V x F intermediate must then be
    re-materialized through the GB/DRAM in the other major order before the
    next layer can stream it (the cost is priced by
    :func:`repro.core.simulator.transition_cost`).
    """

    producer_walk: str  # row | column
    consumer_walk: str  # row | column
    producer_granularity: str  # element | row | column | none
    relayout: bool
    elements: int  # V x F_in of the consuming layer (0 when shape unknown)

    def to_dict(self) -> dict:
        return {
            "producer_walk": self.producer_walk,
            "consumer_walk": self.consumer_walk,
            "producer_granularity": self.producer_granularity,
            "relayout": self.relayout,
            "elements": self.elements,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "TransitionSpec":
        return cls(
            d["producer_walk"],
            d["consumer_walk"],
            d["producer_granularity"],
            bool(d["relayout"]),
            int(d["elements"]),
        )


def transition_spec(
    prev: GNNDataflow, nxt: GNNDataflow, v: int = 0, f: int = 0
) -> TransitionSpec:
    """Classify the boundary between two consecutive layers' dataflows.

    ``v`` / ``f`` are the shape of the inter-layer feature matrix (the
    producing layer's output = the consuming layer's input); ``elements``
    is 0 when they are unknown.
    """
    prod = output_walk(prev)
    cons = input_walk(nxt)
    return TransitionSpec(
        producer_walk=prod,
        consumer_walk=cons,
        producer_granularity=prev.granularity.value,
        relayout=prod != cons,
        elements=int(v) * int(f),
    )


# ---------------------------------------------------------------------------
# Model schedule
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelSchedule:
    """Per-layer schedules + inter-layer transition descriptors."""

    layers: tuple[LayerSchedule, ...]
    transitions: tuple[TransitionSpec, ...] = ()
    objective: str = "cycles"
    #: end-to-end ModelStats from the simulator, when searched.
    stats: "ModelStats | None" = field(default=None, compare=False, repr=False)
    #: the best homogeneous shared-dataflow schedule from the same search
    #: (attached by `search_model`, so callers never pay a second sweep).
    shared_baseline: "ModelSchedule | None" = field(
        default=None, compare=False, repr=False
    )
    #: the AcceleratorConfig the schedule was searched / priced on (set by
    #: `search_model`; the hw x dataflow co-search compares schedules by
    #: it).  Serialized when present; not part of schedule identity.
    hw: AcceleratorConfig | None = field(default=None, compare=False, repr=False)

    def __post_init__(self):
        if not self.layers:
            raise ValueError("ModelSchedule needs at least one layer")
        if len(self.transitions) != len(self.layers) - 1:
            raise ValueError(
                f"{len(self.layers)} layers need {len(self.layers) - 1} "
                f"transitions, got {len(self.transitions)}"
            )
        for i in range(1, len(self.layers)):
            prev, cur = self.layers[i - 1], self.layers[i]
            if prev.f_out != cur.f_in:
                raise ValueError(
                    f"layer {i} consumes f_in={cur.f_in} but layer {i - 1} "
                    f"produces f_out={prev.f_out}"
                )

    # -- views --------------------------------------------------------------
    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def dataflows(self) -> list[GNNDataflow]:
        return [l.dataflow for l in self.layers]

    @property
    def is_heterogeneous(self) -> bool:
        return len({l.dataflow for l in self.layers}) > 1

    @property
    def n_relayouts(self) -> int:
        return sum(t.relayout for t in self.transitions)

    # -- lowering -----------------------------------------------------------
    def lower(self, use_pallas: bool = False) -> list[ExecSpec]:
        """Executable knobs for every layer, in order."""
        return [l.lower(use_pallas=use_pallas) for l in self.layers]

    @property
    def ell_block_rows(self) -> int:
        """Row grouping for the (shared) padded-ELL adjacency: the largest
        per-layer requirement, so every layer's band scan stays aligned."""
        return max(l.lower().ell_block_rows for l in self.layers)

    # -- construction -------------------------------------------------------
    @classmethod
    def from_dataflows(
        cls,
        dataflows: Sequence[GNNDataflow],
        dims: Sequence[tuple[int, int]],
        v: int = 0,
        objective: str = "cycles",
        names: Sequence[str] | None = None,
    ) -> "ModelSchedule":
        """Build a schedule from per-layer dataflows + (f_in, f_out) dims."""
        if len(dataflows) != len(dims):
            raise ValueError(
                f"{len(dataflows)} dataflows vs {len(dims)} layer dims"
            )
        names = list(names or [""] * len(dims))
        layers = tuple(
            LayerSchedule(df, fi, fo, name=n)
            for df, (fi, fo), n in zip(dataflows, dims, names)
        )
        transitions = tuple(
            transition_spec(
                dataflows[i], dataflows[i + 1], v=v, f=dims[i + 1][0]
            )
            for i in range(len(dataflows) - 1)
        )
        return cls(layers, transitions, objective=objective)

    @classmethod
    def from_policies(
        cls,
        policy: str,
        order: str,
        dims: Sequence[tuple[int, int]],
        band_size: int = 128,
        v: int = 0,
    ) -> "ModelSchedule":
        """Compatibility shim: the legacy string knobs as a ModelSchedule.

        This is what ``repro.gnn`` builds internally when handed bare
        ``policy`` / ``order`` strings, so the executable path always runs
        off a schedule.
        """
        df = default_dataflow(policy, order=order, band_size=band_size)
        return cls.from_dataflows([df] * len(dims), dims, v=v)

    def digest(self) -> str:
        """Stable 8-hex identity of the schedule *content* (layers +
        transitions + objective, hw excluded so repricing on a different
        or recalibrated config does not change identity).  This is the
        key the serving engine's measured-latency ledger and re-ranker
        use to attribute wall-clock observations to a schedule."""
        payload = {
            "objective": self.objective,
            "layers": [l.to_dict() for l in self.layers],
            "transitions": [t.to_dict() for t in self.transitions],
        }
        data = json.dumps(payload, sort_keys=True).encode()
        return f"{zlib.crc32(data) & 0xFFFFFFFF:08x}"

    # -- (de)serialization ---------------------------------------------------
    def to_json(self, indent: int | None = 2) -> str:
        payload = {
            "objective": self.objective,
            "layers": [l.to_dict() for l in self.layers],
            "transitions": [t.to_dict() for t in self.transitions],
        }
        if self.hw is not None:
            payload["hw"] = asdict(self.hw)
        return json.dumps(payload, indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "ModelSchedule":
        d = json.loads(text)
        return cls(
            tuple(LayerSchedule.from_dict(l) for l in d["layers"]),
            tuple(TransitionSpec.from_dict(t) for t in d.get("transitions", [])),
            objective=d.get("objective", "cycles"),
            hw=AcceleratorConfig.from_dict(d["hw"]) if "hw" in d else None,
        )

    def __str__(self) -> str:
        rows = [
            f"  [{i}] {l.f_in:>4d}->{l.f_out:<4d} {l.dataflow.to_string()}"
            for i, l in enumerate(self.layers)
        ]
        for i, t in enumerate(self.transitions):
            mark = "relayout" if t.relayout else "aligned"
            rows.insert(
                2 * i + 1,
                f"   |-- {t.producer_walk}->{t.consumer_walk} ({mark})",
            )
        return "ModelSchedule(\n" + "\n".join(rows) + "\n)"


# ---------------------------------------------------------------------------
# Default dataflows for the legacy string policies
# ---------------------------------------------------------------------------


def default_dataflow(
    policy: str, order: str = "AC", band_size: int = 128
) -> GNNDataflow:
    """A canonical taxonomy dataflow matching a ``repro.gnn`` policy string.

    Row tiles are bound to ``band_size`` so :meth:`LayerSchedule.lower`
    round-trips the band the executable scan actually uses.
    """
    band = max(int(band_size), 1)
    po = PhaseOrder(order)
    ac = po == PhaseOrder.AC

    if policy == "seq":
        agg = intra("VsFtNt", "agg", V=band)
        cmb = intra("VsGtFt", "cmb", V=band)
        return GNNDataflow(InterPhase.SEQ, po, agg, cmb)
    if policy in ("sp_generic", "pp"):
        ip = InterPhase.SP if policy == "sp_generic" else InterPhase.PP
        if ac:
            agg = intra("VsFtNt", "agg", V=band)
            cmb = intra("VsGtFt", "cmb", V=band)
        else:
            # NsVtFt (not NsFtVt) keeps the pair at ROW granularity — the
            # element-granularity variant would classify as SP-Optimized.
            agg = intra("NsVtFt", "agg", N=band)
            cmb = intra("VsGtFt", "cmb", V=band)
        return GNNDataflow(ip, po, agg, cmb)
    if policy == "sp_opt":
        if ac:
            agg = intra("VsFsNt", "agg", V=band)
            cmb = intra("VsFsGt", "cmb", V=band)
        else:
            agg = intra("NsFsVt", "agg", N=band)
            cmb = intra("VsGsFt", "cmb", V=band)
        df = GNNDataflow(InterPhase.SP, po, agg, cmb)
        assert df.is_sp_optimized, df
        return df
    raise ValueError(
        f"unknown policy {policy!r}; expected seq|sp_generic|sp_opt|pp"
    )
