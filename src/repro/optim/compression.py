"""Error-feedback int8 gradient compression for the DP all-reduce.

A distributed-optimization trick for 1000+ node scale: quantize each
gradient leaf to int8 with a per-leaf scale before the data-parallel
all-reduce, keep the quantization residual locally and add it back the
next step (error feedback makes the compression unbiased over time).

Used inside shard_map by the launcher (repro.launch.train) when
``--grad-compression int8`` is set; the pure functions here are also unit
tested on CPU.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any  # pytree congruent with grads


def init_error_feedback(grads_like) -> EFState:
    return EFState(
        jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_like
        )
    )


def quantize_int8(x: jax.Array):
    """Symmetric per-tensor int8 quantization -> (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads, ef: EFState):
    """Quantize grads+residual; returns (quantized pytree of (q, scale),
    new residual)."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        return (q, s), gf - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    qtree = treedef.unflatten([p[0] for p in pairs])
    res = treedef.unflatten([p[1] for p in pairs])
    return qtree, EFState(res)


def decompress_grads(qtree):
    def is_pair(x):
        return isinstance(x, tuple) and len(x) == 2 and hasattr(x[0], "dtype")

    return jax.tree_util.tree_map(
        lambda p: dequantize_int8(*p), qtree, is_leaf=is_pair
    )
