"""AdamW with decoupled weight decay, pytree-native (no optax dependency).

State is a pytree congruent with params (m, v per leaf), so it shards
exactly like the parameters (ZeRO-1 style sharding is applied by the
launcher via param_shardings on the state leaves).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw(
    lr: float | Callable[[jax.Array], jax.Array] = 1e-3,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip_norm: float | None = 1.0,
):
    """Returns (init_fn, update_fn)."""

    def init(params) -> AdamWState:
        zeros = lambda p: jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), p
        )
        return AdamWState(jnp.zeros((), jnp.int32), zeros(params), zeros(params))

    def update(grads, state: AdamWState, params):
        step = state.step + 1
        lr_t = lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)

        if grad_clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, grad_clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

        b1t = 1.0 - b1 ** step.astype(jnp.float32)
        b2t = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m_new = b1 * m + (1.0 - b1) * gf
            v_new = b2 * v + (1.0 - b2) * gf * gf
            mh = m_new / b1t
            vh = v_new / b2t
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m_new, v_new

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, AdamWState(step, new_m, new_v)

    return init, update


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def sgd(lr: float = 0.1):
    def init(params):
        return AdamWState(jnp.zeros((), jnp.int32), None, None)

    def update(grads, state, params):
        new_p = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(
                p.dtype
            ),
            params,
            grads,
        )
        return new_p, AdamWState(state.step + 1, None, None)

    return init, update
