from .adamw import AdamWState, adamw, global_norm, sgd
from .schedule import constant, warmup_cosine
from .compression import (
    EFState,
    compress_grads,
    decompress_grads,
    dequantize_int8,
    init_error_feedback,
    quantize_int8,
)
