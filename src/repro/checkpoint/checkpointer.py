"""Sharded, atomic, async checkpointing with elastic restore.

Layout:  <dir>/step_<n>/{manifest.json, arrays/<leaf-id>.npy}
Writes go to a temp directory and are atomically renamed, so a preemption
mid-save can never corrupt the latest checkpoint (the fault-tolerance
contract).  ``keep`` old checkpoints are retained.

Restore takes optional target shardings, so a checkpoint written on one
mesh can be loaded onto another (elastic re-scaling — tested in
tests/test_checkpoint.py with different host-device counts).
"""
from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path
        )
        out.append((key, leaf))
    return out


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3, async_save: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._queue: queue.Queue | None = None
        self._worker = None
        self._error: Exception | None = None
        if async_save:
            self._queue = queue.Queue(maxsize=2)
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # -- save ----------------------------------------------------------------
    def save(self, step: int, state: dict) -> None:
        """state: pytree dict (params/opt/data/step...).  Async if enabled."""
        host_state = jax.tree_util.tree_map(
            lambda x: np.asarray(x) if hasattr(x, "shape") else x, state
        )
        if self._queue is not None:
            if self._error:
                raise self._error
            self._queue.put((step, host_state))
        else:
            self._write(step, host_state)

    def wait(self) -> None:
        if self._queue is not None:
            self._queue.join()
            if self._error:
                raise self._error

    def _drain(self):
        while True:
            step, state = self._queue.get()
            try:
                self._write(step, state)
            except Exception as e:  # surfaced on next save()/wait()
                self._error = e
            finally:
                self._queue.task_done()

    def _write(self, step: int, state: dict) -> None:
        final = self.dir / f"step_{step}"
        tmp = self.dir / f".tmp_step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        (tmp / "arrays").mkdir(parents=True)
        manifest = {"step": step, "leaves": []}
        for i, (key, leaf) in enumerate(_leaf_paths(state)):
            if leaf is None:
                manifest["leaves"].append({"key": key, "none": True})
                continue
            arr = np.asarray(leaf)
            fname = f"{i:05d}.npy"
            np.save(tmp / "arrays" / fname, arr, allow_pickle=False)
            manifest["leaves"].append(
                {"key": key, "file": fname, "dtype": str(arr.dtype), "shape": list(arr.shape)}
            )
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            m = _STEP_RE.match(p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like, step: int | None = None, shardings=None):
        """Rebuild the pytree ``like`` from disk.  ``shardings`` (a pytree of
        NamedSharding or None) re-shards onto the current mesh — the elastic
        path: a checkpoint from N hosts restores onto M."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        root = self.dir / f"step_{step}"
        manifest = json.loads((root / "manifest.json").read_text())
        by_key = {l["key"]: l for l in manifest["leaves"]}

        expect = _leaf_paths(like)
        shard_leaves = (
            [s for _, s in _leaf_paths(shardings)] if shardings is not None else [None] * len(expect)
        )
        leaves = []
        for (key, leaf_like), shd in zip(expect, shard_leaves):
            entry = by_key.get(key)
            if entry is None:
                raise KeyError(f"checkpoint at step {step} missing leaf {key!r}")
            if entry.get("none"):
                leaves.append(None)
                continue
            arr = np.load(root / "arrays" / entry["file"])
            if hasattr(leaf_like, "dtype"):
                arr = arr.astype(leaf_like.dtype)
            if shd is not None:
                leaves.append(jax.device_put(arr, shd))
            else:
                leaves.append(jax.numpy.asarray(arr))
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, leaves)
