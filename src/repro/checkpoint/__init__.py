from .checkpointer import Checkpointer
