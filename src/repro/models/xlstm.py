"""xLSTM blocks (arXiv:2405.04517): chunkwise-parallel mLSTM + sLSTM.

mLSTM is a matrix-memory linear recurrence with exponential gating:

    m_t = max(log f_t + m_{t-1}, i_t)
    C_t = e^{log f_t + m_{t-1} - m_t} C_{t-1} + e^{i_t - m_t} v_t k_t^T
    n_t = e^{log f_t + m_{t-1} - m_t} n_{t-1} + e^{i_t - m_t} k_t
    h_t = (C_t q_t) / max(|n_t . q_t|, e^{-m_t})

Training uses the **chunkwise-parallel form**: within a chunk of length L
the contribution of steps s<=t is an attention-like masked GEMM (all the
b_t log-decay terms cancel into a per-row stabilizer), and only the
(C, n, m) state crosses chunk boundaries via lax.scan.  In the paper's
taxonomy this is SP-Generic pipelining of a two-phase chain (intra-chunk
GEMMs produce a tile the inter-chunk recurrence consumes) — see DESIGN.md.
Decode is the O(1) recurrence.

sLSTM keeps scalar memories with a *nonlinear* recurrent connection
(block-diagonal R acting on h_{t-1}), so it is inherently sequential:
lax.scan over time.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ArchConfig
from .sharding import shard


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(cfg: ArchConfig, rng: jax.Array) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(rng, 4)
    s = 1.0 / np.sqrt(d)
    return {
        "lstm_qkv": (jax.random.normal(ks[0], (d, 3 * d)) * s).astype(_dt(cfg)),
        "lstm_out": (jax.random.normal(ks[1], (d, d)) * s).astype(_dt(cfg)),
        "w_if": (jax.random.normal(ks[2], (d, 2 * h)) * s).astype(_dt(cfg)),
        "b_i": jnp.zeros((h,), _dt(cfg)),
        # forget bias > 0 so f ~ sigmoid(3) ~ 0.95 at init
        "b_f": jnp.full((h,), 3.0, _dt(cfg)),
        "w_o": (jax.random.normal(ks[3], (d, d)) * s).astype(_dt(cfg)),
    }


class MLSTMState(NamedTuple):
    c: jax.Array  # (B, H, Dv, Dk)
    n: jax.Array  # (B, H, Dk)
    m: jax.Array  # (B, H)

    @classmethod
    def zeros(cls, cfg: ArchConfig, batch: int):
        h, hd = cfg.n_heads, cfg.head_dim
        return cls(
            jnp.zeros((batch, h, hd, hd), jnp.float32),
            jnp.zeros((batch, h, hd), jnp.float32),
            jnp.full((batch, h), -1e30, jnp.float32),
        )


def _mlstm_qkv_gates(cfg: ArchConfig, p: dict, x: jax.Array):
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    qkv = x @ p["lstm_qkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, s, h, hd).astype(jnp.float32)
    k = k.reshape(b, s, h, hd).astype(jnp.float32) / np.sqrt(hd)
    v = v.reshape(b, s, h, hd).astype(jnp.float32)
    gif = (x @ p["w_if"]).reshape(b, s, 2, h).astype(jnp.float32)
    i_raw = gif[:, :, 0] + p["b_i"].astype(jnp.float32)
    f_raw = gif[:, :, 1] + p["b_f"].astype(jnp.float32)
    log_f = -jax.nn.softplus(-f_raw)  # log sigmoid
    o = jax.nn.sigmoid((x @ p["w_o"]).astype(jnp.float32))
    return q, k, v, i_raw, log_f, o


def _mlstm_chunk(q, k, v, i_raw, log_f, state: MLSTMState):
    """One chunk (B, L, H, ...) given incoming state; returns (h, state)."""
    b_, l, h, hd = q.shape
    # per-position cumulative decay within the chunk
    b_cum = jnp.cumsum(log_f, axis=1)  # (B, L, H)
    a = i_raw - b_cum  # a_s = i_s - b_s
    run_max = jax.lax.cummax(a, axis=1)  # M_t
    mbar = jnp.maximum(state.m[:, None], run_max)  # (B, L, H)
    m_t = b_cum + mbar  # true stabilizer (for the denominator floor)

    # intra-chunk masked attention-like term:
    # weight[t, s] = exp(a_s - mbar_t) for s <= t (the b_t decay cancels
    # into the row stabilizer mbar_t — that is what makes the chunk a GEMM)
    scores = jnp.einsum("blhd,bshd->bhls", q, k)  # (B, H, L, L)
    a_s = a.transpose(0, 2, 1)[:, :, None, :]  # (B, H, 1, L)
    mb_t = mbar.transpose(0, 2, 1)[:, :, :, None]  # (B, H, L, 1)
    w = jnp.exp(a_s - mb_t)
    mask = jnp.tril(jnp.ones((l, l), bool))
    w = jnp.where(mask[None, None], w, 0.0)
    sw = scores * w
    intra_num = jnp.einsum("bhls,bshd->blhd", sw, v)
    intra_den = sw.sum(axis=-1).transpose(0, 2, 1)  # (B, L, H)

    # inter-chunk (incoming state) term
    scale_in = jnp.exp(state.m[:, None] - mbar)  # (B, L, H)
    inter_num = jnp.einsum("blhd,bhed->blhe", q, state.c) * scale_in[..., None]
    inter_den = jnp.einsum("blhd,bhd->blh", q, state.n) * scale_in

    num = intra_num + inter_num
    den = intra_den + inter_den
    floor = jnp.exp(-m_t)
    h_out = num / jnp.maximum(jnp.abs(den), floor)[..., None]

    # state update
    big_b = b_cum[:, -1]  # (B, H)
    mbar_l = mbar[:, -1]
    m_out = big_b + mbar_l
    decay_state = jnp.exp(state.m - mbar_l)  # (B, H)
    wk = jnp.exp(a - mbar_l[:, None])  # (B, L, H)
    c_out = state.c * decay_state[..., None, None] + jnp.einsum(
        "bshd,bshe,bsh->bhde", v, k, wk
    )
    n_out = state.n * decay_state[..., None] + jnp.einsum("bshd,bsh->bhd", k, wk)
    return h_out, MLSTMState(c_out, n_out, m_out)


def mlstm_block(cfg: ArchConfig, p: dict, x: jax.Array, chunk: int = 256) -> jax.Array:
    """Full-sequence mLSTM (training/prefill) via chunkwise scan."""
    b, s, d = x.shape
    h_heads, hd = cfg.n_heads, cfg.head_dim
    q, k, v, i_raw, log_f, o = _mlstm_qkv_gates(cfg, p, x)
    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (q, k, v))
        i_raw = jnp.pad(i_raw, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))

    def split(t):  # (B, S, ...) -> (n, B, L, ...)
        return t.reshape(b, n_chunks, chunk, *t.shape[2:]).transpose(
            1, 0, 2, *range(3, t.ndim + 1)
        )

    qs, ks_, vs, is_, fs = map(split, (q, k, v, i_raw, log_f))

    def step(state, xs):
        qc, kc, vc, ic, fc = xs
        h_out, state = _mlstm_chunk(qc, kc, vc, ic, fc, state)
        return state, h_out

    state0 = MLSTMState.zeros(cfg, b)
    _, hs = jax.lax.scan(step, state0, (qs, ks_, vs, is_, fs))
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * chunk, h_heads, hd)
    hs = hs[:, :s]
    out = (o.reshape(b, s, d) * hs.reshape(b, s, d).astype(jnp.float32)).astype(x.dtype)
    return shard(out @ p["lstm_out"], "batch", "sequence", None)


def mlstm_decode(
    cfg: ArchConfig, p: dict, x: jax.Array, state: MLSTMState
) -> tuple[jax.Array, MLSTMState]:
    """One-token decode: the O(1) recurrence.  x: (B, 1, d)."""
    b = x.shape[0]
    q, k, v, i_raw, log_f, o = _mlstm_qkv_gates(cfg, p, x)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]  # (B, H, D)
    i_raw, log_f = i_raw[:, 0], log_f[:, 0]  # (B, H)
    m_new = jnp.maximum(log_f + state.m, i_raw)
    decay = jnp.exp(log_f + state.m - m_new)
    inp = jnp.exp(i_raw - m_new)
    c = state.c * decay[..., None, None] + jnp.einsum("bhd,bhe->bhde", v, k) * inp[..., None, None]
    n = state.n * decay[..., None] + k * inp[..., None]
    num = jnp.einsum("bhde,bhe->bhd", c, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)), jnp.exp(-m_new))
    h = num / den[..., None]
    out = (o[:, 0] * h.reshape(b, -1)).astype(x.dtype)[:, None]
    return shard(out @ p["lstm_out"], "batch", None, None), MLSTMState(c, n, m_new)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(cfg: ArchConfig, rng: jax.Array) -> dict:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(rng, 3)
    s = 1.0 / np.sqrt(d)
    return {
        "lstm_w": (jax.random.normal(ks[0], (d, 4 * d)) * s).astype(_dt(cfg)),
        # block-diagonal recurrent weights, one block per head
        "lstm_r": (
            jax.random.normal(ks[1], (h, hd, 4 * hd)) * (1.0 / np.sqrt(hd)) * 0.5
        ).astype(_dt(cfg)),
        "lstm_out": (jax.random.normal(ks[2], (d, d)) * s).astype(_dt(cfg)),
        "bias": jnp.concatenate(
            [jnp.zeros((d,)), jnp.full((d,), 3.0), jnp.zeros((2 * d,))]
        ).astype(_dt(cfg)),
    }


class SLSTMState(NamedTuple):
    c: jax.Array  # (B, D)
    n: jax.Array  # (B, D)
    h: jax.Array  # (B, D)
    m: jax.Array  # (B, D)

    @classmethod
    def zeros(cls, cfg: ArchConfig, batch: int):
        d = cfg.d_model
        z = lambda: jnp.zeros((batch, d), jnp.float32)
        return cls(z(), z(), z(), jnp.full((batch, d), -1e30, jnp.float32))


def _slstm_step(cfg: ArchConfig, p: dict, wx_t: jax.Array, state: SLSTMState):
    """wx_t: (B, 4D) precomputed input projection for this step."""
    b = wx_t.shape[0]
    h_heads, hd = cfg.n_heads, cfg.head_dim
    h_prev = state.h.reshape(b, h_heads, hd)
    rh = jnp.einsum("bhd,hde->bhe", h_prev, p["lstm_r"].astype(jnp.float32))
    rh = rh.reshape(b, h_heads, 4, hd).transpose(0, 2, 1, 3).reshape(b, 4 * cfg.d_model)
    pre = wx_t.astype(jnp.float32) + rh + p["bias"].astype(jnp.float32)
    i_raw, f_raw, z_raw, o_raw = jnp.split(pre, 4, axis=-1)
    log_f = -jax.nn.softplus(-f_raw)
    m_new = jnp.maximum(log_f + state.m, i_raw)
    decay = jnp.exp(log_f + state.m - m_new)
    inp = jnp.exp(i_raw - m_new)
    c = decay * state.c + inp * jnp.tanh(z_raw)
    n = decay * state.n + inp
    h = jax.nn.sigmoid(o_raw) * c / jnp.maximum(n, jnp.exp(-m_new))
    return SLSTMState(c, n, h, m_new)


def slstm_block(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    """Full-sequence sLSTM: sequential lax.scan over time.

    The recurrence is pinned to batch-only sharding: any model-axis
    sharding on the carry would put a collective inside the 4096-step
    loop (measured: an 825 GB/step all-reduce storm — §Perf X1)."""
    b, s, d = x.shape
    wx = shard(x @ p["lstm_w"], "batch", None, None)  # (B, S, 4D)

    def step(state, wx_t):
        state = _slstm_step(cfg, p, wx_t, state)
        state = SLSTMState(*(shard(t, "batch", None) for t in state))
        return state, state.h

    state0 = SLSTMState.zeros(cfg, b)
    _, hs = jax.lax.scan(step, state0, wx.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2).astype(x.dtype)  # (B, S, D)
    return shard(hs @ p["lstm_out"], "batch", "sequence", None)


def slstm_decode(
    cfg: ArchConfig, p: dict, x: jax.Array, state: SLSTMState
) -> tuple[jax.Array, SLSTMState]:
    wx = (x @ p["lstm_w"])[:, 0]
    state = _slstm_step(cfg, p, wx, state)
    out = state.h.astype(x.dtype)[:, None] @ p["lstm_out"]
    return shard(out, "batch", None, None), state
