"""Attention: the LM-scale instance of the paper's multiphase taxonomy.

QKᵀ -> softmax -> PV is a dependent GEMM-GEMM chain.  ``attn_policy``
selects the inter-phase dataflow:

  * ``seq``    — materialize the (S x S) score matrix (paper Seq; only
                 viable at smoke scale — at 32k prefill the intermediate is
                 the whole point of not doing this).
  * ``sp_opt`` — chunked online-softmax: score tiles are produced and
                 consumed in registers/VMEM, never stored (paper
                 SP-Optimized == flash attention).  On TPU the Pallas
                 kernel (:mod:`repro.kernels.flash_attention`) implements
                 the same schedule; the lax.scan form below is what the
                 dry-run lowers.

Supports GQA (n_kv_heads < n_heads, grouped einsums — no KV repetition),
sliding-window (local) attention, and single-token decode against a
(possibly ring-buffered) KV cache.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ArchConfig
from .layers import rope
from .sharding import shard

NEG_INF = -1e30


def init_attention(cfg: ArchConfig, rng: jax.Array) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    s = 1.0 / np.sqrt(d)
    dt = jnp.dtype(cfg.dtype)
    return {
        "wq": (jax.random.normal(k1, (d, cfg.n_heads * hd)) * s).astype(dt),
        "wk": (jax.random.normal(k2, (d, cfg.n_kv_heads * hd)) * s).astype(dt),
        "wv": (jax.random.normal(k3, (d, cfg.n_kv_heads * hd)) * s).astype(dt),
        "wo": (
            jax.random.normal(k4, (cfg.n_heads * hd, d)) * (1.0 / np.sqrt(d))
        ).astype(dt),
    }


def _tp_size() -> int:
    from .sharding import current_mesh, current_rules

    rules, mesh = current_rules(), current_mesh()
    if rules is None or mesh is None or rules.heads is None:
        return 1
    ax = rules.heads
    axes = ax if isinstance(ax, tuple) else (ax,)
    size = 1
    for a in axes:
        size *= int(mesh.shape[a])
    return size


def _lcm(a: int, b: int) -> int:
    import math

    return a * b // math.gcd(a, b)


def head_alignment(cfg: ArchConfig, ts: int | None = None):
    """TP head alignment: (kv_rep, g_new, aligned?).

    When the tensor-parallel size does not divide the head counts, pad the
    per-KV query groups and *replicate* KV heads so both head dims divide
    the TP axis.  Replication preserves semantics exactly (each real query
    head still attends its original KV head; padded query slots have zero
    wq columns and zero wo rows, so they contribute nothing).  Applied
    only when the FLOP overhead is <= 2x (tiny archs like smollm keep
    attention unsharded instead — the MLP still gets TP).
    """
    ts = _tp_size() if ts is None else ts
    hkv = cfg.n_kv_heads
    g = cfg.n_heads // hkv
    if ts <= 1 or (hkv % ts == 0 and cfg.n_heads % ts == 0):
        return 1, g, ts > 1
    rep = _lcm(hkv, ts) // hkv
    g_new = -(-g // rep)
    overhead = (hkv * rep * g_new) / (hkv * g)
    if overhead > 2.0:
        return 1, g, False
    return rep, g_new, True


def aligned_kv_heads(cfg: ArchConfig, ts: int | None = None) -> int:
    rep, _, _ = head_alignment(cfg, ts)
    return cfg.n_kv_heads * rep


def _align_weights(cfg: ArchConfig, p: dict):
    """Runtime-padded projection weights for TP alignment (zero-cost when
    already aligned)."""
    rep, g_new, _ = head_alignment(cfg)
    hd, hkv = cfg.head_dim, cfg.n_kv_heads
    g = cfg.n_heads // hkv
    if rep == 1 and g_new == g:
        return p["wq"], p["wk"], p["wv"], p["wo"]
    d = p["wq"].shape[0]
    gp = rep * g_new
    wq = p["wq"].reshape(d, hkv, g, hd)
    wq = jnp.pad(wq, ((0, 0), (0, 0), (0, gp - g), (0, 0)))
    wq = wq.reshape(d, hkv * rep, g_new, hd).reshape(d, -1)
    wk = jnp.repeat(p["wk"].reshape(d, hkv, hd), rep, axis=1).reshape(d, -1)
    wv = jnp.repeat(p["wv"].reshape(d, hkv, hd), rep, axis=1).reshape(d, -1)
    wo = p["wo"].reshape(hkv, g, hd, d)
    wo = jnp.pad(wo, ((0, 0), (0, gp - g), (0, 0), (0, 0)))
    wo = wo.reshape(hkv * rep, g_new, hd, d).reshape(-1, d)
    return wq, wk, wv, wo


def _project_qkv(cfg: ArchConfig, p: dict, x: jax.Array, positions: jax.Array):
    b, s, _ = x.shape
    hd = cfg.head_dim
    rep, g_new, _ = head_alignment(cfg)
    hkv = cfg.n_kv_heads * rep
    hq = hkv * g_new
    wq, wk, wv, _ = _align_weights(cfg, p)
    q = shard((x @ wq).reshape(b, s, hq, hd), "batch", None, "heads", None)
    k = shard((x @ wk).reshape(b, s, hkv, hd), "batch", None, "heads", None)
    v = shard((x @ wv).reshape(b, s, hkv, hd), "batch", None, "heads", None)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _group(q: jax.Array, n_kv: int) -> jax.Array:
    """(B, S, H, D) -> (B, S, Hkv, G, D) for grouped-query einsums."""
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def _attend_seq(q, k, v, q_pos, k_pos, window: int) -> jax.Array:
    """Materialized-score attention (the Seq baseline)."""
    qg = _group(q, k.shape[2]).astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    scores = scores / np.sqrt(q.shape[-1])
    mask = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    b, s = q.shape[:2]
    return out.reshape(b, s, -1, q.shape[-1]).astype(q.dtype)


def _attend_chunked(q, k, v, q_pos, k_pos, window: int, chunk: int) -> jax.Array:
    """SP-Optimized: lax.scan over KV chunks with online softmax.

    The (bq x chunk) score tile is phase-1 output and phase-2 input inside
    one scan step — element-granularity pipelining with matched tiles.
    """
    b, sq, h, hd = q.shape
    n_kv = k.shape[2]
    sk = k.shape[1]
    chunk = min(chunk, sk)
    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    k_pos = jnp.pad(k_pos, (0, pad), constant_values=np.iinfo(np.int32).max)
    kc = k.reshape(b, n_chunks, chunk, n_kv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, n_kv, hd).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(n_chunks, chunk)

    qg = _group(q, n_kv).astype(jnp.float32) / np.sqrt(hd)

    def step(carry, xs):
        acc, m_prev, l_prev = carry
        k_blk, v_blk, kp = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_blk.astype(jnp.float32))
        mask = kp[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= kp[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + p.sum(axis=-1)
        upd = jnp.einsum("bhgqk,bkhd->bhgqd", p, v_blk.astype(jnp.float32))
        acc = acc * alpha[..., None] + upd
        return (acc, m_new, l_new), None

    g = h // n_kv
    acc0 = jnp.zeros((b, n_kv, g, sq, hd), jnp.float32)
    m0 = jnp.full((b, n_kv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, n_kv, g, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def attention(
    cfg: ArchConfig,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    window: int = 0,
) -> jax.Array:
    """Full-sequence (training / prefill) attention."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, positions)
    pos1 = positions[0] if positions.ndim > 1 else positions
    if cfg.attn_policy == "seq":
        out = _attend_seq(q, k, v, pos1, pos1, window)
    else:
        out = _attend_chunked(q, k, v, pos1, pos1, window, cfg.attn_chunk)
    out = out.reshape(b, s, -1)
    _, _, _, wo = _align_weights(cfg, p)
    return shard(out @ wo, "batch", "sequence", None)


# ---------------------------------------------------------------------------
# Decode with KV cache
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_cache, Hkv, D) — ring buffer when windowed
    v: jax.Array

    @classmethod
    def zeros(cls, cfg: ArchConfig, batch: int, length: int, window: int = 0):
        size = min(length, window) if window > 0 else length
        hd = cfg.head_dim
        dt = jnp.dtype(cfg.dtype)
        # TP-aligned KV head count (replicated KV under tensor parallelism)
        shape = (batch, size, aligned_kv_heads(cfg), hd)
        return cls(jnp.zeros(shape, dt), jnp.zeros(shape, dt))


def decode_attention(
    cfg: ArchConfig,
    p: dict,
    x: jax.Array,  # (B, 1, d)
    cache: KVCache,
    cur_index: jax.Array,  # scalar int32: absolute position of this token
    *,
    window: int = 0,
) -> tuple[jax.Array, KVCache]:
    """One-token decode against the cache; returns (out, new_cache)."""
    b = x.shape[0]
    positions = jnp.full((b, 1), cur_index, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(cfg, p, x, positions)
    size = cache.k.shape[1]
    slot = cur_index % size if window > 0 else cur_index
    k = jax.lax.dynamic_update_slice(cache.k, k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new, (0, slot, 0, 0))

    # absolute positions held by each cache slot
    slots = jnp.arange(size, dtype=jnp.int32)
    if window > 0:
        # ring buffer: slot s holds the most recent position p with
        # p % size == s and p <= cur_index
        delta = (slot - slots) % size
        k_pos = cur_index - delta
    else:
        k_pos = slots
    valid = (k_pos <= cur_index) & (k_pos >= 0)
    if window > 0:
        valid &= k_pos > cur_index - window
    k_pos = jnp.where(valid, k_pos, np.iinfo(np.int32).max)

    qg = _group(q, k.shape[2]).astype(jnp.float32) / np.sqrt(cfg.head_dim)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    s = jnp.where(
        (k_pos[None, :] <= cur_index)[None, None, None], s, NEG_INF
    )
    pattn = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", pattn, v.astype(jnp.float32))
    out = out.reshape(b, 1, -1).astype(x.dtype)
    _, _, _, wo = _align_weights(cfg, p)
    return shard(out @ wo, "batch", None, None), KVCache(k, v)
