"""Model assembly: block pattern -> scanned layer stacks -> LM steps.

Layers are stacked per *pattern position* and walked with ``lax.scan`` so
the HLO stays one-block-sized regardless of depth (60-layer 34B models
lower in seconds; this is also what makes the 512-device dry-run
tractable).  Heterogeneous patterns (RecurrentGemma's rglru/rglru/local,
xLSTM's 7 mLSTM : 1 sLSTM) scan over super-blocks; the remainder layers
(pattern not dividing n_layers) run unscanned.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .attention import KVCache, attention, decode_attention, init_attention
from .config import ArchConfig
from .layers import (
    embed_tokens,
    init_embeddings,
    init_mlp,
    init_norm_scale,
    logits_head,
    mlp,
    norm,
)
from .moe import init_moe, moe_ffn
from .rglru import RGLRUState, init_rglru, rglru_block, rglru_decode
from .sharding import shard
from .xlstm import (
    MLSTMState,
    SLSTMState,
    init_mlstm,
    init_slstm,
    mlstm_block,
    mlstm_decode,
    slstm_block,
    slstm_decode,
)

# ---------------------------------------------------------------------------
# Per-kind block init / apply / decode
# ---------------------------------------------------------------------------


def init_block(cfg: ArchConfig, kind: str, rng: jax.Array) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    p: dict[str, Any] = {"ln1": init_norm_scale(cfg)}
    if kind in ("attn", "local", "moe"):
        p["attn"] = init_attention(cfg, k1)
        p["ln2"] = init_norm_scale(cfg)
        if kind == "moe":
            p["moe"] = init_moe(cfg, k2)
        else:
            p["mlp"] = init_mlp(cfg, k2)
    elif kind == "rglru":
        p["rg"] = init_rglru(cfg, k1)
        p["ln2"] = init_norm_scale(cfg)
        p["mlp"] = init_mlp(cfg, k2)
    elif kind == "mlstm":
        p["mlstm"] = init_mlstm(cfg, k1)
    elif kind == "slstm":
        p["slstm"] = init_slstm(cfg, k1)
    else:
        raise KeyError(kind)
    return p


def apply_block(cfg: ArchConfig, kind: str, p: dict, x, positions):
    """Full-sequence block application. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = norm(cfg, x, p["ln1"])
    if kind in ("attn", "local", "moe"):
        win = cfg.window if kind == "local" else 0
        x = x + attention(cfg, p["attn"], h, positions, window=win)
        h2 = norm(cfg, x, p["ln2"])
        if kind == "moe":
            ff, aux = moe_ffn(cfg, p["moe"], h2)
            x = x + ff
        else:
            x = x + mlp(cfg, p["mlp"], h2)
    elif kind == "rglru":
        x = x + rglru_block(cfg, p["rg"], h)
        x = x + mlp(cfg, p["mlp"], norm(cfg, x, p["ln2"]))
    elif kind == "mlstm":
        x = x + mlstm_block(cfg, p["mlstm"], h)
    elif kind == "slstm":
        x = x + slstm_block(cfg, p["slstm"], h)
    return x, aux


def init_block_state(cfg: ArchConfig, kind: str, batch: int, max_len: int):
    if kind in ("attn", "moe"):
        return KVCache.zeros(cfg, batch, max_len)
    if kind == "local":
        return KVCache.zeros(cfg, batch, max_len, window=cfg.window)
    if kind == "rglru":
        return RGLRUState.zeros(cfg, batch)
    if kind == "mlstm":
        return MLSTMState.zeros(cfg, batch)
    if kind == "slstm":
        return SLSTMState.zeros(cfg, batch)
    raise KeyError(kind)


def decode_block(cfg: ArchConfig, kind: str, p: dict, x, state, index):
    """One-token block application. Returns (x, new_state)."""
    h = norm(cfg, x, p["ln1"])
    if kind in ("attn", "local", "moe"):
        win = cfg.window if kind == "local" else 0
        a, state = decode_attention(cfg, p["attn"], h, state, index, window=win)
        x = x + a
        h2 = norm(cfg, x, p["ln2"])
        if kind == "moe":
            ff, _ = moe_ffn(cfg, p["moe"], h2)
            x = x + ff
        else:
            x = x + mlp(cfg, p["mlp"], h2)
    elif kind == "rglru":
        r, state = rglru_decode(cfg, p["rg"], h, state)
        x = x + r
        x = x + mlp(cfg, p["mlp"], norm(cfg, x, p["ln2"]))
    elif kind == "mlstm":
        m, state = mlstm_decode(cfg, p["mlstm"], h, state)
        x = x + m
    elif kind == "slstm":
        s, state = slstm_decode(cfg, p["slstm"], h, state)
        x = x + s
    return x, state


# ---------------------------------------------------------------------------
# Whole-model parameters: scanned groups + remainder
# ---------------------------------------------------------------------------


def _layer_plan(cfg: ArchConfig) -> tuple[int, tuple[str, ...], tuple[str, ...]]:
    """(#scanned super-blocks, pattern, remainder kinds)."""
    pat = cfg.block_pattern
    reps = cfg.n_layers // len(pat)
    rem = cfg.layer_kinds[reps * len(pat) :]
    return reps, pat, rem


def init_params(cfg: ArchConfig, rng: jax.Array) -> dict:
    reps, pat, rem = _layer_plan(cfg)
    k_embed, k_layers, k_rem = jax.random.split(rng, 3)
    scanned = []
    for pos, kind in enumerate(pat):
        keys = jax.random.split(jax.random.fold_in(k_layers, pos), max(reps, 1))
        stacks = [init_block(cfg, kind, k) for k in keys[:reps]]
        if reps:
            scanned.append(jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *stacks))
        else:
            scanned.append(None)
    remainder = [
        init_block(cfg, kind, jax.random.fold_in(k_rem, i))
        for i, kind in enumerate(rem)
    ]
    return {
        "embeddings": init_embeddings(cfg, k_embed),
        "final_norm": init_norm_scale(cfg),
        "scanned": scanned,
        "remainder": remainder,
    }


def forward(cfg: ArchConfig, params: dict, inputs: jax.Array, positions=None):
    """Training/prefill forward.  ``inputs``: (B, S) int tokens, or
    (B, S, d) embeddings for the VLM/audio stub frontends.
    Returns (logits, aux_loss)."""
    if cfg.embedded_inputs:
        h = inputs.astype(jnp.dtype(cfg.dtype))
        b, s = inputs.shape[:2]
    else:
        h = embed_tokens(cfg, params["embeddings"], inputs)
        b, s = inputs.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h = shard(h, "batch", "sequence", None)
    aux_total = jnp.zeros((), jnp.float32)

    reps, pat, rem = _layer_plan(cfg)
    if reps:

        def superblock(carry, stacked_p):
            x, aux = carry
            for pos, kind in enumerate(pat):
                x, a = apply_block(cfg, kind, stacked_p[pos], x, positions)
                aux = aux + a
            return (x, aux), None

        body = jax.checkpoint(superblock) if cfg.remat else superblock
        (h, aux_total), _ = jax.lax.scan(
            body, (h, aux_total), params["scanned"]
        )
    for kind, p in zip(rem, params["remainder"]):
        h, a = apply_block(cfg, kind, p, h, positions)
        aux_total = aux_total + a

    h = norm(cfg, h, params["final_norm"])
    logits = logits_head(cfg, params["embeddings"], h)
    return logits, aux_total


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    """Decode-state pytree matching the scanned/remainder structure."""
    reps, pat, rem = _layer_plan(cfg)
    scanned = []
    for kind in pat:
        states = [init_block_state(cfg, kind, batch, max_len) for _ in range(reps)]
        scanned.append(
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states) if reps else None
        )
    remainder = [init_block_state(cfg, kind, batch, max_len) for kind in rem]
    return {"scanned": scanned, "remainder": remainder}


def decode_step(cfg: ArchConfig, params: dict, cache, tokens: jax.Array, index):
    """One decode step for the whole model.

    ``tokens``: (B, 1) ints (or (B, 1, d) embeddings); ``index``: scalar
    position.  Returns (logits (B, 1, vocab), new_cache)."""
    if cfg.embedded_inputs:
        h = tokens.astype(jnp.dtype(cfg.dtype))
    else:
        h = embed_tokens(cfg, params["embeddings"], tokens)
    h = shard(h, "batch", None, None)
    reps, pat, rem = _layer_plan(cfg)

    new_scanned = []
    if reps:

        def superblock(x, xs):
            stacked_p, stacked_s = xs
            new_states = []
            for pos, kind in enumerate(pat):
                x, ns = decode_block(cfg, kind, stacked_p[pos], x, stacked_s[pos], index)
                new_states.append(ns)
            return x, tuple(new_states)

        h, states_out = jax.lax.scan(
            superblock, h, (params["scanned"], tuple(cache["scanned"]))
        )
        new_scanned = list(states_out)
    new_rem = []
    for kind, p, st in zip(rem, params["remainder"], cache["remainder"]):
        h, ns = decode_block(cfg, kind, p, h, st, index)
        new_rem.append(ns)

    h = norm(cfg, h, params["final_norm"])
    logits = logits_head(cfg, params["embeddings"], h)
    return logits, {"scanned": new_scanned, "remainder": new_rem}


def prefill(cfg: ArchConfig, params: dict, inputs: jax.Array):
    """Prefill: token-by-token is wasteful, so run the full forward and
    additionally build the decode cache by replaying each block's KV/state
    path.  Used by the serving example at smoke scale; the 32k dry-run cell
    lowers :func:`forward` (the compute-dominant part)."""
    if cfg.embedded_inputs:
        b, s = inputs.shape[:2]
    else:
        b, s = inputs.shape
    logits, _ = forward(cfg, params, inputs)
    cache = init_cache(cfg, b, s)
    # replay decode steps to populate the cache exactly
    def one(i, carry):
        cache, = carry
        tok = jax.lax.dynamic_slice_in_dim(inputs, i, 1, axis=1)
        _, cache = decode_step(cfg, params, cache, tok, i)
        return (cache,)

    (cache,) = jax.lax.fori_loop(0, s, one, (cache,))
    return logits, cache


# ---------------------------------------------------------------------------
# Loss / steps
# ---------------------------------------------------------------------------


def lm_loss(cfg: ArchConfig, params, batch) -> jax.Array:
    """Causal LM loss.  batch: {"inputs": (B,S) or (B,S,d), "labels": (B,S)}."""
    logits, aux = forward(cfg, params, batch["inputs"])
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean() + aux


def count_params(params) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
