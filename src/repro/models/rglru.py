"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

The block: x -> {input branch (linear -> causal conv -> RG-LRU), gate
branch (linear -> GeLU)} -> elementwise product -> output linear.

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    a_t = a^(c * r_t),  a = sigmoid(lambda)  (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training runs the linear recurrence with an associative scan (parallel in
S — the reason the 500k-token shape is lowerable at all); decode is an O(1)
state update.  The recurrence is a single-phase computation — the paper's
inter-phase taxonomy does not apply to it (DESIGN.md §Arch-applicability);
the surrounding local-attention layers use the SP-optimized chunked path.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ArchConfig
from .sharding import shard

_C = 8.0


def init_rglru(cfg: ArchConfig, rng: jax.Array) -> dict:
    d, r = cfg.d_model, cfg.rnn_width
    ks = jax.random.split(rng, 6)
    s = 1.0 / np.sqrt(d)
    dt = jnp.dtype(cfg.dtype)
    return {
        "rg_in": (jax.random.normal(ks[0], (d, r)) * s).astype(dt),
        "rg_gate": (jax.random.normal(ks[1], (d, r)) * s).astype(dt),
        "rg_out": (jax.random.normal(ks[2], (r, d)) * (1.0 / np.sqrt(r))).astype(dt),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv_width, r)) * 0.1).astype(dt),
        "w_a": (jax.random.normal(ks[4], (r, r)) * (1.0 / np.sqrt(r)) * 0.1).astype(dt),
        "w_x": (jax.random.normal(ks[5], (r, r)) * (1.0 / np.sqrt(r)) * 0.1).astype(dt),
        "b_a": jnp.zeros((r,), dt),
        "b_x": jnp.zeros((r,), dt),
        # lambda init so that a = sigmoid(lambda) ~ 0.9..0.999
        "lam": jnp.asarray(np.linspace(2.2, 6.9, r), dt),
    }


class RGLRUState(NamedTuple):
    h: jax.Array  # (B, R) recurrent state
    conv: jax.Array  # (B, W-1, R) causal-conv tail

    @classmethod
    def zeros(cls, cfg: ArchConfig, batch: int):
        r = cfg.rnn_width
        dt = jnp.dtype(cfg.dtype)
        return cls(
            jnp.zeros((batch, r), dt),
            jnp.zeros((batch, cfg.conv_width - 1, r), dt),
        )


def _gates(p: dict, u: jax.Array):
    """u: (..., R) post-conv activations -> (a_t, gated input)."""
    uf = u.astype(jnp.float32)
    r_t = jax.nn.sigmoid(uf @ p["w_a"].astype(jnp.float32) + p["b_a"].astype(jnp.float32))
    i_t = jax.nn.sigmoid(uf @ p["w_x"].astype(jnp.float32) + p["b_x"].astype(jnp.float32))
    log_a = -_C * r_t * jax.nn.softplus(-p["lam"].astype(jnp.float32))
    a_t = jnp.exp(log_a)
    b_t = jnp.sqrt(jnp.maximum(1.0 - a_t**2, 1e-12)) * (i_t * uf)
    return a_t, b_t


def _causal_conv(p: dict, x: jax.Array, tail: jax.Array | None = None):
    """Depthwise causal conv, width W.  x: (B, S, R)."""
    w = p["conv_w"].astype(jnp.float32)  # (W, R)
    width = w.shape[0]
    xf = x.astype(jnp.float32)
    if tail is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), jnp.float32)
    else:
        pad = tail.astype(jnp.float32)
    xp = jnp.concatenate([pad, xf], axis=1)  # (B, S+W-1, R)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(width))
    return out.astype(x.dtype), xp[:, -(width - 1) :].astype(x.dtype)


def rglru_block(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    """Full-sequence forward (training / prefill), associative scan over S."""
    u = shard(x @ p["rg_in"], "batch", None, "d_ff")
    g = shard(x @ p["rg_gate"], "batch", None, "d_ff")
    u, _ = _causal_conv(p, u)
    a_t, b_t = _gates(p, u)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a_t, b_t), axis=1)
    out = jax.nn.gelu(g.astype(jnp.float32)) * h
    return shard(out.astype(x.dtype) @ p["rg_out"], "batch", "sequence", None)


def rglru_decode(
    cfg: ArchConfig, p: dict, x: jax.Array, state: RGLRUState
) -> tuple[jax.Array, RGLRUState]:
    """One-token decode. x: (B, 1, d)."""
    u = x @ p["rg_in"]  # (B, 1, R)
    g = x @ p["rg_gate"]
    u, tail = _causal_conv(p, u, state.conv)
    a_t, b_t = _gates(p, u[:, 0])
    h = a_t * state.h.astype(jnp.float32) + b_t
    out = jax.nn.gelu(g[:, 0].astype(jnp.float32)) * h
    out = (out.astype(x.dtype) @ p["rg_out"])[:, None]
    return out, RGLRUState(h.astype(state.h.dtype), tail)
