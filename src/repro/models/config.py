"""Architecture configuration for the LM substrate.

One :class:`ArchConfig` describes any of the assigned architectures (dense
llama-family, VLM/audio backbones, MoE, RG-LRU hybrid, xLSTM).  The block
pattern is a repeating unit of block kinds:

  * ``attn``   — full causal self-attention + MLP
  * ``local``  — sliding-window attention + MLP
  * ``moe``    — attention + mixture-of-experts FFN
  * ``rglru``  — RG-LRU recurrent block + MLP (Griffin/RecurrentGemma)
  * ``mlstm``  — matrix-memory xLSTM block (no FFN)
  * ``slstm``  — scalar-memory xLSTM block (no FFN)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 32
    top_k: int = 8
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | vlm | audio | moe | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    block_pattern: tuple[str, ...] = ("attn",)
    window: int = 2048  # local-attention window (hybrid archs)
    moe: MoEConfig | None = None
    norm: str = "rmsnorm"  # rmsnorm | nonparam_ln | layernorm
    act: str = "silu"  # silu (SwiGLU) | gelu (GeGLU)
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    embedded_inputs: bool = False  # vlm/audio stubs feed embeddings directly
    d_rnn: int = 0  # RG-LRU recurrence width (0 -> d_model)
    conv_width: int = 4
    dtype: str = "float32"  # compute dtype ("bfloat16" for the dry-run)
    remat: bool = True
    attn_chunk: int = 512  # SP-optimized chunked-attention KV block
    # Multiphase policy for the attention GEMM-GEMM chain: "sp_opt" is the
    # paper's fused dataflow (chunked online softmax); "seq" materializes
    # the S x S score matrix (only feasible for small smoke shapes).
    attn_policy: str = "sp_opt"

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def rnn_width(self) -> int:
        return self.d_rnn or self.d_model

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        """Per-layer block kinds, tiling the pattern over n_layers."""
        reps = -(-self.n_layers // len(self.block_pattern))
        return (self.block_pattern * reps)[: self.n_layers]

    @property
    def is_subquadratic(self) -> bool:
        """True when no block attends over the full sequence (long_500k
        eligibility)."""
        return all(k not in ("attn", "moe") for k in self.block_pattern)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline FLOPs)."""
        d, hd = self.d_model, self.head_dim
        total = 0
        if not self.embedded_inputs:
            total += self.vocab * d  # input embedding
        total += self.vocab * d if not self.tie_embeddings else 0  # head
        for kind in self.layer_kinds:
            if kind in ("attn", "local", "moe"):
                attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
                attn += (self.n_heads * hd) * d
                total += attn
                if kind == "moe":
                    e = self.moe.n_experts
                    total += d * e  # router
                    total += e * (3 * d * self.d_ff)  # gated experts
                else:
                    total += 3 * d * self.d_ff  # SwiGLU/GeGLU
            elif kind == "rglru":
                r = self.rnn_width
                total += 2 * d * r + r * d  # in/gate/out projections
                total += self.conv_width * r + 3 * r  # conv + gates
                total += 3 * d * self.d_ff
            elif kind in ("mlstm", "slstm"):
                total += 4 * d * d + 3 * d  # qkv/out + gates (approx)
            total += 2 * d  # norms
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top_k of n_experts)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        e, k = self.moe.n_experts, self.moe.top_k
        expert_params = sum(
            3 * self.d_model * self.d_ff * e
            for kind in self.layer_kinds
            if kind == "moe"
        )
        return full - expert_params + expert_params * k // e

    def reduced(self, **overrides) -> "ArchConfig":
        """Smoke-test scale config of the same family (assignment: small
        layers/width/experts/tables, one forward step on CPU)."""
        small = dict(
            n_layers=max(2, len(self.block_pattern)),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, 4 * self.n_kv_heads // self.n_heads),
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            window=16,
            d_rnn=64 if self.d_rnn else 0,
            attn_chunk=32,
            dtype="float32",
        )
        if self.moe is not None:
            small["moe"] = MoEConfig(n_experts=4, top_k=2)
        small.update(overrides)
        return dataclasses.replace(self, **small)

    def with_(self, **overrides) -> "ArchConfig":
        return dataclasses.replace(self, **overrides)
