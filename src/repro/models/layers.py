"""Shared LM layers: norms, rotary embeddings, gated MLPs, heads."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ArchConfig
from .sharding import shard


def rmsnorm(x: jax.Array, scale: jax.Array | None, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        out = out * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def nonparam_layernorm(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """OLMo-style non-parametric LayerNorm (no scale/bias)."""
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def norm(cfg: ArchConfig, x: jax.Array, scale: jax.Array | None) -> jax.Array:
    if cfg.norm == "nonparam_ln":
        return nonparam_layernorm(x)
    return rmsnorm(x, scale)


def init_norm_scale(cfg: ArchConfig) -> jax.Array | None:
    if cfg.norm == "nonparam_ln":
        return jnp.zeros((1,), _dtype(cfg))  # placeholder leaf (unused)
    return jnp.zeros((cfg.d_model,), _dtype(cfg))


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) * 2.0 / d))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    sin = jnp.sin(ang)[..., None, :]  # broadcast over heads
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def init_mlp(cfg: ArchConfig, rng: jax.Array) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(rng, 3)
    s_in = 1.0 / np.sqrt(d)
    s_out = 1.0 / np.sqrt(ff)
    dt = _dtype(cfg)
    return {
        "w_gate": (jax.random.normal(k1, (d, ff)) * s_in).astype(dt),
        "w_up": (jax.random.normal(k2, (d, ff)) * s_in).astype(dt),
        "w_down": (jax.random.normal(k3, (ff, d)) * s_out).astype(dt),
    }


def mlp(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    g = shard(x @ p["w_gate"], "batch", None, "d_ff")
    u = shard(x @ p["w_up"], "batch", None, "d_ff")
    h = act(g) * u
    # sequence-parallel residual stream: reduce-scatter instead of
    # all-reduce when rules.sequence is set (Megatron-SP)
    return shard(h @ p["w_down"], "batch", "sequence", None)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def init_embeddings(cfg: ArchConfig, rng: jax.Array) -> dict:
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(rng)
    out = {}
    if not cfg.embedded_inputs:
        out["embed"] = (
            jax.random.normal(k1, (cfg.vocab, cfg.d_model)) * 0.02
        ).astype(dt)
    if not cfg.tie_embeddings or cfg.embedded_inputs:
        out["lm_head"] = (
            jax.random.normal(k2, (cfg.d_model, cfg.vocab))
            * (1.0 / np.sqrt(cfg.d_model))
        ).astype(dt)
    return out


def embed_tokens(cfg: ArchConfig, params: dict, tokens: jax.Array) -> jax.Array:
    h = params["embed"][tokens]
    return shard(h, "batch", "sequence", None)


def logits_head(cfg: ArchConfig, params: dict, h: jax.Array) -> jax.Array:
    w = params.get("lm_head")
    if w is None:  # tied
        w = params["embed"].T
    return shard(h @ w, "batch", None, "vocab")
