"""Logical-axis sharding rules (MaxText-style) for the LM substrate.

Model code annotates activations/params with *logical* axis names; the
rules map them to mesh axes.  With no mesh active every annotation is a
no-op, so the same model code runs the CPU smoke tests and the 512-chip
dry-run unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ShardingRules:
    """Logical axis -> mesh axis (or tuple of mesh axes, or None)."""

    batch: tuple[str, ...] | str | None = None  # e.g. ("pod", "data")
    sequence: str | None = None  # sequence parallelism (long context)
    heads: str | None = None  # TP over attention heads
    d_ff: str | None = None  # TP over MLP hidden
    experts: str | None = None  # EP over MoE experts
    vocab: str | None = None  # TP over vocab/logits
    d_model: str | None = None  # rarely sharded (all-gather heavy)

    def spec(self, *logical: str | None) -> P:
        out = []
        for ax in logical:
            out.append(getattr(self, ax) if ax else None)
        return P(*out)


#: Production rules for the (pod, data, model) / (data, model) meshes.
def production_rules(multi_pod: bool = False) -> ShardingRules:
    dp = ("pod", "data") if multi_pod else ("data",)
    return ShardingRules(
        batch=dp,
        sequence=None,
        heads="model",
        d_ff="model",
        experts="model",
        vocab="model",
    )


def tuned_rules(arch: str, multi_pod: bool = False) -> ShardingRules:
    """Beyond-baseline sharding strategies from the §Perf hillclimb.

    * default: baseline TP + Megatron-style sequence parallelism (the
      residual stream shards on seq over the model axis; per-layer
      all-reduces become reduce-scatter/all-gather pairs).
    (A pure-DP variant for xlstm-1.3b was hypothesized and REFUTED —
    replicated-parameter gradient all-reduces and per-timestep backward
    saves made it 6x worse; see EXPERIMENTS.md §Perf X2.  The effective
    fix was pinning the sLSTM recurrence to batch-only sharding inside
    the model itself.)
    """
    dp = ("pod", "data") if multi_pod else ("data",)
    base = production_rules(multi_pod)
    from dataclasses import replace

    return replace(base, sequence="model")


_STATE = threading.local()


def current_rules() -> ShardingRules | None:
    return getattr(_STATE, "rules", None)


def current_mesh() -> jax.sharding.Mesh | None:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def use_sharding(mesh: jax.sharding.Mesh | None, rules: ShardingRules | None):
    prev = (current_mesh(), current_rules())
    _STATE.mesh, _STATE.rules = mesh, rules
    try:
        yield
    finally:
        _STATE.mesh, _STATE.rules = prev


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """Annotate an activation with logical axes; no-op without a mesh.
    Axes the mesh does not divide are dropped (e.g. 56 q-heads on a 16-way
    model axis) rather than forcing GSPMD padding churn."""
    rules, mesh = current_rules(), current_mesh()
    if rules is None or mesh is None:
        return x
    spec = _divisible(rules.spec(*logical), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter shardings by tree-path pattern
# ---------------------------------------------------------------------------

_PARAM_RULES: tuple[tuple[str, tuple[str | None, ...]], ...] = (
    # name-fragment -> logical axes per dim (matched right-aligned);
    # first match wins, so lm_head must precede the "embed" fragment
    ("lm_head", (None, "vocab")),
    ("embed", ("vocab", None)),
    ("wq", (None, "heads")),
    ("wk", (None, "heads")),
    ("wv", (None, "heads")),
    ("wo", ("heads", None)),
    ("w_gate", (None, "d_ff")),
    ("w_up", (None, "d_ff")),
    ("w_down", ("d_ff", None)),
    ("router", (None, "experts")),
    # expert weights shard over the expert (EP) axis only — d_ff is small
    # per expert and the EP axis already consumes the mesh's model axis
    ("experts_gate", ("experts", None, None)),
    ("experts_up", ("experts", None, None)),
    ("experts_down", ("experts", None, None)),
    ("rg_in", (None, "d_ff")),
    ("rg_gate", (None, "d_ff")),
    ("rg_out", ("d_ff", None)),
    ("lstm_qkv", (None, "heads")),
    ("lstm_out", ("heads", None)),
)


def spec_for_param(path: str, ndim: int, rules: ShardingRules) -> P:
    for frag, logical in _PARAM_RULES:
        if frag in path:
            axes = [None] * ndim
            # right-align the logical axes onto the trailing dims
            lg = logical[-ndim:] if ndim <= len(logical) else logical
            axes[-len(lg):] = [getattr(rules, a) if a else None for a in lg]
            # stacked-layer leading dim stays unsharded
            return P(*axes)
    return P()  # replicate (norms, biases, gates)


def _divisible(spec: P, shape: tuple, mesh: jax.sharding.Mesh) -> P:
    """Drop sharding on dims the mesh axis does not divide (e.g. the
    49,155-row granite-moe vocab on a 16-way axis -> replicate)."""
    out = []
    for i, ax in enumerate(spec):
        if ax is None:
            out.append(None)
            continue
        size = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            size *= mesh.shape[a]
        out.append(ax if (i < len(shape) and shape[i] % size == 0) else None)
    return P(*out)


def param_shardings(params, mesh: jax.sharding.Mesh, rules: ShardingRules):
    """NamedSharding pytree for a parameter pytree."""

    def one(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        spec = spec_for_param(pstr, leaf.ndim, rules)
        return NamedSharding(mesh, _divisible(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, params)
