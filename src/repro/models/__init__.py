from .config import ArchConfig, MoEConfig
from .transformer import (
    count_params,
    decode_step,
    forward,
    init_cache,
    init_params,
    lm_loss,
    prefill,
)
from .sharding import (
    ShardingRules,
    param_shardings,
    production_rules,
    shard,
    use_sharding,
)
from .stubs import make_inputs, synthetic_embeddings, synthetic_tokens
