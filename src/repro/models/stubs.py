"""Modality-frontend stubs for the VLM/audio backbones.

Per the assignment, ``[vlm]``/``[audio]`` entries specify the transformer
BACKBONE only; the modality frontend is a STUB whose ``input_specs()``
provides precomputed frame/patch embeddings.  These helpers generate
seeded synthetic embeddings for the smoke tests and examples, and shape
structs for the dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ArchConfig


def synthetic_embeddings(cfg: ArchConfig, batch: int, seq: int, seed: int = 0):
    """Stand-in for the vision tower / EnCodec encoder output."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(batch, seq, cfg.d_model)).astype(np.float32) * 0.02
    return jnp.asarray(x, dtype=jnp.dtype(cfg.dtype))


def synthetic_tokens(cfg: ArchConfig, batch: int, seq: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab, size=(batch, seq)), dtype=jnp.int32)


def make_inputs(cfg: ArchConfig, batch: int, seq: int, seed: int = 0):
    if cfg.embedded_inputs:
        return synthetic_embeddings(cfg, batch, seq, seed)
    return synthetic_tokens(cfg, batch, seq, seed)
