"""Mixture-of-Experts FFN — the paper's sparse/dense multiphase chain at LM
scale.

Dispatch (sparse scatter by router choice) -> expert GEMMs (dense) ->
combine (sparse gather + weighted sum) is structurally SpMM -> GEMM ->
SpMM.  Three execution paths, mirroring the taxonomy:

  * ``dense``  — every expert processes every token, outputs masked
                 (the Seq baseline/oracle; E/k x FLOP overhead — smoke
                 shapes only).
  * ``ragged`` — sort tokens by expert + ``lax.ragged_dot`` grouped GEMM
                 (SP-Optimized flavor: no capacity padding, no drops;
                 single-device fast path).
  * ``ep``     — explicit expert parallelism for the production mesh:
                 activations are replicated across the TP/EP ("model")
                 axis, so each shard locally dispatches into a capacity
                 buffer for *its own* experts, runs the batched expert
                 GEMM, combines, and one all-reduce over the model axis
                 merges the per-shard contributions (Mixtral-style EP;
                 the all-reduce is the same collective the dense TP MLP
                 pays).  Implemented as a shard_map island so every
                 collective is explicit for the roofline analysis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .config import ArchConfig
from .sharding import current_mesh, current_rules, shard


def init_moe(cfg: ArchConfig, rng: jax.Array) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(ff)
    dt = jnp.dtype(cfg.dtype)
    return {
        "router": (jax.random.normal(k1, (d, e)) * s_in).astype(jnp.float32),
        "experts_gate": (jax.random.normal(k2, (e, d, ff)) * s_in).astype(dt),
        "experts_up": (jax.random.normal(k3, (e, d, ff)) * s_in).astype(dt),
        "experts_down": (jax.random.normal(k4, (e, ff, d)) * s_out).astype(dt),
    }


def _route(cfg: ArchConfig, p: dict, x2d: jax.Array):
    """Router: returns (probs (T,k), ids (T,k), aux_loss)."""
    logits = x2d.astype(jnp.float32) @ p["router"]  # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    probs, ids = jax.lax.top_k(gates, cfg.moe.top_k)
    probs = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss
    e = cfg.moe.n_experts
    me = gates.mean(axis=0)  # mean router prob per expert
    ce = jnp.zeros((e,)).at[ids.reshape(-1)].add(1.0) / ids.size  # assignment frac
    aux = e * jnp.sum(me * ce) * cfg.moe.router_aux_weight
    return probs, ids, aux


def _expert_ffn(cfg: ArchConfig, p: dict, h: jax.Array) -> jax.Array:
    """Batched gated FFN over an (E, C, d) buffer."""
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    g = jnp.einsum("ecd,edf->ecf", h, p["experts_gate"])
    u = jnp.einsum("ecd,edf->ecf", h, p["experts_up"])
    return jnp.einsum("ecf,efd->ecd", act(g) * u, p["experts_down"])


# ---------------------------------------------------------------------------
# Seq baseline: dense (all experts on all tokens)
# ---------------------------------------------------------------------------


def moe_dense(cfg: ArchConfig, p: dict, x: jax.Array):
    b, s, d = x.shape
    x2d = x.reshape(-1, d)
    probs, ids, aux = _route(cfg, p, x2d)
    e = cfg.moe.n_experts
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    g = jnp.einsum("td,edf->etf", x2d, p["experts_gate"])
    u = jnp.einsum("td,edf->etf", x2d, p["experts_up"])
    y = jnp.einsum("etf,efd->etd", act(g) * u, p["experts_down"])  # (E, T, d)
    mask = jax.nn.one_hot(ids, e, dtype=y.dtype) * probs[..., None].astype(y.dtype)
    comb = mask.sum(axis=1)  # (T, E)
    out = jnp.einsum("te,etd->td", comb, y)
    return out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Sort + ragged grouped GEMM (no drops)
# ---------------------------------------------------------------------------


def moe_ragged(cfg: ArchConfig, p: dict, x: jax.Array):
    b, s, d = x.shape
    k = cfg.moe.top_k
    e = cfg.moe.n_experts
    x2d = x.reshape(-1, d)
    t = x2d.shape[0]
    probs, ids, aux = _route(cfg, p, x2d)
    flat_e = ids.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    tok = order // k  # source token per sorted slot
    xs = x2d[tok]  # (T*k, d) gathered, expert-sorted
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    g = jax.lax.ragged_dot(xs, p["experts_gate"], counts)
    u = jax.lax.ragged_dot(xs, p["experts_up"], counts)
    y = jax.lax.ragged_dot(act(g) * u, p["experts_down"], counts)  # (T*k, d)
    w = probs.reshape(-1)[order].astype(y.dtype)
    out = jnp.zeros((t, d), y.dtype).at[tok].add(y * w[:, None])
    return out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Expert-parallel (production path)
# ---------------------------------------------------------------------------


def _local_capacity(cfg: ArchConfig, tokens_local: int) -> int:
    c = tokens_local * cfg.moe.top_k * cfg.moe.capacity_factor / cfg.moe.n_experts
    return max(8, int(-(-c // 8) * 8))  # round up to 8


def moe_ep(cfg: ArchConfig, p: dict, x: jax.Array, mesh, rules):
    """Expert-parallel MoE over the mesh's "experts" axis (shard_map)."""
    model_axis = rules.experts
    dp_axes = rules.batch if isinstance(rules.batch, tuple) else (rules.batch,)
    dp_axes = tuple(a for a in dp_axes if a)
    n_shards = int(mesh.shape[model_axis])
    e = cfg.moe.n_experts
    # pad to a shardable expert count with never-routed dummy experts
    # (e.g. 40 experts on a 16-way axis -> 48 virtual, 8 idle)
    e_loc = -(-e // n_shards)
    e_pad = e_loc * n_shards
    if e_pad != e:
        p = dict(p)
        for name in ("experts_gate", "experts_up", "experts_down"):
            w = p[name]
            p[name] = jnp.concatenate(
                [w, jnp.zeros((e_pad - e, *w.shape[1:]), w.dtype)], axis=0
            )
    b, s, d = x.shape

    seq_axis = rules.sequence  # Megatron-SP: tokens arrive seq-sharded

    def local(x_loc, router, w_gate, w_up, w_down):
        # x_loc: (B_loc, S, d) — replicated over the model axis, or
        # seq-sharded under sequence parallelism (gathered here in bf16,
        # results reduce-scattered back — half the boundary traffic of the
        # replicated all-reduce)
        if seq_axis:
            x_loc = jax.lax.all_gather(x_loc, seq_axis, axis=1, tiled=True)
        bl = x_loc.shape[0]
        x2d = x_loc.reshape(-1, d)
        t_loc = x2d.shape[0]
        probs, ids, aux = _route(cfg, {"router": router}, x2d)
        shard_id = jax.lax.axis_index(model_axis)
        lo = shard_id * e_loc
        cap = _local_capacity(cfg, t_loc)
        # dispatch only the (token, k) pairs owned by this shard's experts
        flat_e = ids.reshape(-1)
        flat_p = probs.reshape(-1)
        mine = (flat_e >= lo) & (flat_e < lo + e_loc)
        local_e = jnp.where(mine, flat_e - lo, e_loc)  # e_loc = drop row
        # position within each local expert (stable order)
        order = jnp.argsort(jnp.where(mine, local_e, e_loc + 1), stable=True)
        sorted_e = local_e[order]
        one = jnp.ones_like(sorted_e)
        pos = jnp.cumsum(one) - 1
        start = jnp.zeros((e_loc + 2,), jnp.int32).at[sorted_e + 1].add(one)
        start = jnp.cumsum(start)[:-1]
        pos_in_e = pos - start[sorted_e]
        keep = (sorted_e < e_loc) & (pos_in_e < cap)
        dest = jnp.where(keep, sorted_e * cap + pos_in_e, e_loc * cap)
        tok = order // cfg.moe.top_k
        buf = jnp.zeros((e_loc * cap + 1, d), x2d.dtype)
        buf = buf.at[dest].set(jnp.where(keep[:, None], x2d[tok], 0.0))
        h = buf[: e_loc * cap].reshape(e_loc, cap, d)
        y = _expert_ffn(cfg, {"experts_gate": w_gate, "experts_up": w_up,
                              "experts_down": w_down}, h)
        y_flat = jnp.concatenate(
            [y.reshape(e_loc * cap, d), jnp.zeros((1, d), y.dtype)], axis=0
        )
        gathered = y_flat[dest] * jnp.where(keep, flat_p[order], 0.0)[:, None].astype(y.dtype)
        out = jnp.zeros((t_loc, d), y.dtype).at[tok].add(gathered)
        # merge expert contributions across the model axis (same collective
        # a TP MLP would pay); under SP, reduce-scatter back to seq shards
        out = out.reshape(bl, s, d)
        if seq_axis:
            out = jax.lax.psum_scatter(out, seq_axis, scatter_dimension=1,
                                       tiled=True)
        else:
            out = jax.lax.psum(out, model_axis)
        aux = jax.lax.pmean(aux, (model_axis, *dp_axes))
        return out, aux

    x_spec = P(dp_axes if dp_axes else None, rules.sequence, None)
    w_spec = P(model_axis, None, None)  # each shard holds its local experts
    out, aux = jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(x_spec, P(None, None), w_spec, w_spec, w_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, p["router"],
      p["experts_gate"], p["experts_up"], p["experts_down"])
    return out, aux


def moe_ffn(cfg: ArchConfig, p: dict, x: jax.Array, policy: str = "auto"):
    """Dispatch to the right MoE path.  "auto": EP when a mesh is active,
    ragged grouped-GEMM otherwise."""
    mesh, rules = current_mesh(), current_rules()
    if policy == "auto":
        policy = "ep" if (mesh is not None and rules is not None and rules.experts) else "ragged"
    if policy == "dense":
        return moe_dense(cfg, p, x)
    if policy == "ragged":
        return moe_ragged(cfg, p, x)
    if policy == "ep":
        return moe_ep(cfg, p, x, mesh, rules)
    raise ValueError(policy)
