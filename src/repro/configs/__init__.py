from .registry import ARCH_IDS, all_configs, get_config
from .shapes import SHAPES, ShapeSuite, applicable
