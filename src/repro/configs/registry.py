"""Architecture registry: ``--arch <id>`` lookup for all assigned configs."""
from __future__ import annotations

import importlib

from ..models.config import ArchConfig

ARCH_IDS = (
    "granite-8b",
    "olmo-1b",
    "tinyllama-1.1b",
    "smollm-135m",
    "llava-next-34b",
    "musicgen-large",
    "granite-moe-1b-a400m",
    "granite-moe-3b-a800m",
    "recurrentgemma-2b",
    "xlstm-1.3b",
)

_MODULES = {
    "granite-8b": "granite_8b",
    "olmo-1b": "olmo_1b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "smollm-135m": "smollm_135m",
    "llava-next-34b": "llava_next_34b",
    "musicgen-large": "musicgen_large",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "xlstm-1.3b": "xlstm_1_3b",
}


def get_config(arch: str) -> ArchConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
