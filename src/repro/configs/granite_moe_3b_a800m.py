"""granite-moe-3b-a800m — MoE, 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base family; hf].

32L d_model=1536 24H (GQA kv=8) d_ff=512/expert vocab=49155.
40 experts do not divide the 16-way model axis; EP pads to 48 virtual
experts (8 idle) — see repro.models.moe.
"""
from ..models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    block_pattern=("moe",),
    moe=MoEConfig(n_experts=40, top_k=8),
    dtype="bfloat16",
)
