"""recurrentgemma-2b — RG-LRU + local attention hybrid, pattern 1:2
[arXiv:2402.19427; hf].

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000, window 2048.
Pattern: (rglru, rglru, local) — two recurrent blocks per local-attention
block (Griffin).  Sub-quadratic: runs the long_500k shape.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    block_pattern=("rglru", "rglru", "local"),
    window=2048,
    d_rnn=2560,
    act="gelu",
    dtype="bfloat16",
)
