"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

48L d_model=2048 32H (GQA kv=32 = MHA) d_ff=8192 vocab=2048.
The EnCodec frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, S, d_model); the backbone predicts codebook tokens.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    block_pattern=("attn",),
    embedded_inputs=True,
    act="gelu",
    dtype="bfloat16",
)
