"""The paper's own workload: 2-layer GCN over the Table-4 datasets.

This is the 11th selectable config — the GNN the dataflow taxonomy was
built for.  It parameterizes repro.gnn rather than the LM substrate.
"""
from ..gnn.model import GNNConfig

# Kipf-standard hidden width; per-dataset f_in/n_classes are bound by the
# dataset loader at run time.
CONFIG = GNNConfig(kind="gcn", hidden=16, n_layers=2, policy="sp_opt", order="AC")
