"""The assigned input-shape suites (one set, shared by all LM archs).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of seq_len), NOT ``train_step``.  ``long_500k`` requires
sub-quadratic attention: it runs only for the SSM/hybrid archs
(recurrentgemma-2b, xlstm-1.3b) and is skipped for pure full-attention
archs (documented in DESIGN.md / EXPERIMENTS.md).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeSuite:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSuite("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSuite("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSuite("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSuite("long_500k", 524_288, 1, "decode"),
}


def applicable(arch_cfg, shape: ShapeSuite) -> bool:
    """long_500k only for sub-quadratic archs (dense 512k KV decode is a
    memory-capacity non-starter; assignment says skip + document)."""
    if shape.name == "long_500k":
        return arch_cfg.is_subquadratic
    return True
