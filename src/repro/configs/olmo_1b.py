"""olmo-1b — dense, non-parametric LayerNorm [arXiv:2402.00838; hf].

16L d_model=2048 16H (GQA kv=16 = MHA) d_ff=8192 vocab=50304.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=50304,
    block_pattern=("attn",),
    norm="nonparam_ln",  # OLMo's non-parametric LN
    act="silu",
    dtype="bfloat16",
)
