"""llava-next-34b — VLM backbone, anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
Per the assignment, the modality frontend is a STUB: input_specs()
provides precomputed patch embeddings (B, S, d_model); only the
transformer backbone is modeled (see repro.models.stubs).
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    block_pattern=("attn",),
    embedded_inputs=True,  # patch embeddings precomputed by the stub
    dtype="bfloat16",
)
