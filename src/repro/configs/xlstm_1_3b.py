"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

48L d_model=2048 4H d_ff=0 vocab=50304.  xLSTM[7:1]: seven mLSTM blocks
per sLSTM block (48 = 6 super-blocks).  No FFN (d_ff = 0): the xLSTM
blocks carry the capacity.  Sub-quadratic: runs the long_500k shape.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    dtype="bfloat16",
)
