"""Spill-model-driven partitioning of beyond-capacity graphs.

A request whose staged V x F intermediate exceeds ``gb_capacity_bytes``
(or the serving admission caps) cannot be served as one monolithic
Program.  This module *chooses* an execution plan for it, pricing each
candidate with the same simulator the mapper uses:

- ``row_stream``   — stream L-hop halo closures of node blocks through
  the existing kernels, gathering halo features between blocks
  (NeuraChip-style decoupled aggregation, arXiv:2404.15510).  Own rows
  come first in every closure, so stitching the per-block ``[:n_own]``
  slices back together is bit-identical to the whole-graph forward.
- ``feature_chunk`` — keep all rows but materialize the intermediate one
  feature-column chunk at a time (columns of ``A @ X`` are independent;
  XLA may reassociate the narrow-chunk reduction, so this path matches
  to <= 1 ulp rather than bitwise).
- ``pp_shard``     — hand the whole graph to the device-level
  pipeline-parallel path (:mod:`repro.gnn.pp`) when a multi-device mesh
  is available.

Each candidate's per-layer compute is priced by
:func:`repro.core.mapper.search_dataflows` on a representative partition
workload, and its inter-partition traffic by
:func:`repro.core.simulator.partition_comm_cost` — the additive
communication term of Guirado et al. (arXiv:2103.10515) — so partitioned
plans rank on the same objective scale as monolithic ones.
"""
from __future__ import annotations

import math
from dataclasses import asdict, dataclass, field

import numpy as np

from ..core.cost_model import GNNLayerWorkload
from ..core.hw import AcceleratorConfig, DEFAULT_ACCEL
from ..core.registry import objective_value, register_kernel
from ..core.simulator import (
    PartitionCommStats,
    intermediate_footprint_bytes,
    partition_comm_cost,
)
from .batching import next_pow2
from .csr import CSRGraph

__all__ = [
    "Partition",
    "PlanCandidate",
    "PartitionPlan",
    "extract_row_partitions",
    "plan_partition",
    "row_stream_forward",
    "feature_chunk_forward",
    "pp_shard_forward",
]

#: Skeletons used to price a partition that fits in the global buffer.
FIT_NAMES = ("Seq-Nt", "SP-FsNt-Fs", "PP-Nt-Vt/sl")
#: Skeletons used to price a beyond-capacity monolithic run: only the
#: Seq family honestly stages the full V x F intermediate (Table 3);
#: pipelined/fused strategies assume a GB/RF-resident working set that a
#: beyond-capacity request cannot provide.
SPILL_NAMES = ("Seq-Nt", "Seq-Ns")
#: Skeletons for the device-level pipeline-parallel shard.
PP_NAMES = ("PP-Nt-Vt/sl", "PP-Ns-Vt/sl")

_MIN_BLOCK_ROWS = 32


# ---------------------------------------------------------------------------
# Row partitions: L-hop halo closures with own rows first
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Partition:
    """One node block plus its L-hop halo closure.

    ``nodes`` maps local row ids to global ids; the first ``n_own`` rows
    are the block's own nodes (in global order), the rest the halo.
    ``graph`` is the closure's locally-remapped CSR: rings ``0..L-1``
    keep their real adjacency, the outermost ring carries a zero-weight
    self-loop (feature-only halo — correct because ring ``r`` only needs
    valid values through layer ``L - r``).
    """

    graph: CSRGraph
    nodes: np.ndarray  # (n_sub,) local -> global node ids
    n_own: int

    @property
    def n_halo(self) -> int:
        return len(self.nodes) - self.n_own


def _rows_cols(g: CSRGraph, rows: np.ndarray) -> np.ndarray:
    """All column indices of the given rows, vectorized."""
    starts = g.row_ptr[rows].astype(np.int64)
    counts = (g.row_ptr[rows + 1] - g.row_ptr[rows]).astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, dtype=g.col_idx.dtype)
    cum = np.cumsum(counts) - counts
    flat = np.repeat(starts - cum, counts) + np.arange(total, dtype=np.int64)
    return g.col_idx[flat]


def _closure_rings(
    g: CSRGraph, start: int, stop: int, n_hops: int
) -> tuple[list[np.ndarray], bool]:
    """BFS rings 0..n_hops around rows [start, stop); ring 0 first.

    The second return is ``closed``: True when the closure is
    neighbor-closed (BFS ran dry before ``n_hops``), in which case every
    ring keeps its real adjacency; otherwise the outermost ring is a
    frontier at exactly ``n_hops`` and becomes feature-only dummy rows.
    """
    seen = np.zeros(g.n_nodes, dtype=bool)
    ring0 = np.arange(start, stop, dtype=np.int64)
    seen[ring0] = True
    rings = [ring0]
    for _ in range(n_hops):
        nbrs = _rows_cols(g, rings[-1])
        fresh = np.unique(nbrs[~seen[nbrs]])
        if fresh.size == 0:
            return rings, True
        seen[fresh] = True
        rings.append(fresh.astype(np.int64))
    return rings, len(rings) == 1


def _interior(rings: list[np.ndarray], closed: bool) -> np.ndarray:
    """Rows that keep real adjacency (the rest carry zero self-loops)."""
    if closed or len(rings) == 1:
        return np.concatenate(rings)
    return np.concatenate(rings[:-1])


def _closure_partition(
    g: CSRGraph, rings: list[np.ndarray], closed: bool
) -> Partition:
    """Build the locally-remapped closure CSR for one set of BFS rings."""
    nodes = np.concatenate(rings)
    n_sub = len(nodes)
    lid = np.full(g.n_nodes, -1, dtype=np.int64)
    lid[nodes] = np.arange(n_sub)
    interior = _interior(rings, closed)
    n_int = len(interior)

    counts = np.ones(n_sub, dtype=np.int64)  # outer ring: 1 self-loop slot
    counts[:n_int] = g.nnz[interior]
    row_ptr = np.zeros(n_sub + 1, dtype=np.int64)
    np.cumsum(counts, out=row_ptr[1:])
    col = np.empty(row_ptr[-1], dtype=np.int32)
    val = np.zeros(row_ptr[-1], dtype=g.values.dtype)
    cols_int = _rows_cols(g, interior)
    vals_int = _row_values(g, interior)
    fill = np.repeat(row_ptr[:n_int], counts[:n_int]) + _within_row_offsets(
        counts[:n_int]
    )
    col[fill] = lid[cols_int].astype(np.int32)
    val[fill] = vals_int
    # outer-ring dummy rows: zero-weight self-loops (feature carriers only)
    col[row_ptr[n_int:-1]] = np.arange(n_int, n_sub, dtype=np.int32)
    return Partition(
        graph=CSRGraph(
            row_ptr=row_ptr.astype(np.int64),
            col_idx=col,
            values=val,
            n_nodes=n_sub,
        ),
        nodes=nodes,
        n_own=len(rings[0]),
    )


def _within_row_offsets(counts: np.ndarray) -> np.ndarray:
    total = int(counts.sum())
    cum = np.cumsum(counts) - counts
    return np.arange(total, dtype=np.int64) - np.repeat(cum, counts)


def _row_values(g: CSRGraph, rows: np.ndarray) -> np.ndarray:
    starts = g.row_ptr[rows].astype(np.int64)
    counts = (g.row_ptr[rows + 1] - g.row_ptr[rows]).astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, dtype=g.values.dtype)
    cum = np.cumsum(counts) - counts
    flat = np.repeat(starts - cum, counts) + np.arange(total, dtype=np.int64)
    return g.values[flat]


def extract_row_partitions(
    g: CSRGraph, block_rows: int, n_hops: int
) -> list[Partition]:
    """Split ``g`` into row blocks of ``block_rows`` with L-hop closures.

    Every global row lands in exactly one partition's own block, in
    order, so concatenating the per-partition ``[:n_own]`` outputs
    reconstructs the whole-graph node ordering exactly.
    """
    if block_rows < 1:
        raise ValueError(f"block_rows must be >= 1, got {block_rows}")
    if n_hops < 1:
        raise ValueError(f"n_hops must be >= 1, got {n_hops}")
    parts = []
    for s in range(0, g.n_nodes, block_rows):
        e = min(s + block_rows, g.n_nodes)
        rings, closed = _closure_rings(g, s, e, n_hops)
        parts.append(_closure_partition(g, rings, closed))
    return parts


# ---------------------------------------------------------------------------
# Plan selection
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PlanCandidate:
    """One priced execution-plan candidate (kept for evidence/telemetry)."""

    kind: str
    n_partitions: int
    feasible: bool
    layer_cycles: float = 0.0
    layer_energy_pj: float = 0.0
    comm_cycles: float = 0.0
    comm_energy_pj: float = 0.0
    objective_value: float = float("inf")
    note: str = ""

    def as_dict(self) -> dict:
        return asdict(self)


@dataclass(frozen=True)
class PartitionPlan:
    """The chosen plan plus the full ranked candidate list."""

    kind: str  # monolithic | row_stream | feature_chunk | pp_shard
    objective: str
    objective_value: float
    n_partitions: int
    block_rows: int = 0  # row_stream: own rows per block
    chunk_f: int = 0  # feature_chunk: feature columns per chunk
    n_hops: int = 0  # row_stream: halo depth (== model layers)
    halo_nodes: int = 0  # row_stream: total halo nodes across blocks
    footprint_bytes: int = 0  # monolithic V x f_max intermediate
    candidates: tuple[PlanCandidate, ...] = ()

    def as_dict(self) -> dict:
        d = asdict(self)
        d["candidates"] = [c.as_dict() for c in self.candidates]
        return d


def _row_stream_geometry(
    g: CSRGraph,
    f_max: int,
    hw: AcceleratorConfig,
    n_hops: int,
    max_partitions: int,
    max_block_rows: int | None,
):
    """Pick block_rows so every padded closure's features stay GB-resident.

    Returns ``(block_rows, n_parts, closure_max, halo_nodes, rep_nnz)``
    or ``None`` when no feasible block size exists.  ``rep_nnz`` is the
    largest closure's per-row nnz vector (used as the pricing workload).
    """
    cap = hw.gb_capacity_bytes
    if cap is not None:
        block = cap // (f_max * hw.bytes_per_elem)
    else:
        block = max_block_rows if max_block_rows is not None else g.n_nodes
    if max_block_rows is not None:
        block = min(block, max_block_rows)
    block = 1 << max(int(block).bit_length() - 1, 0)  # round down to pow2
    while block >= _MIN_BLOCK_ROWS:
        n_parts = math.ceil(g.n_nodes / block)
        if n_parts > max_partitions:
            return None  # shrinking further only adds partitions
        closure_max, halo_nodes, rep_nnz = 0, 0, None
        ok = True
        for s in range(0, g.n_nodes, block):
            rings, closed = _closure_rings(g, s, min(s + block, g.n_nodes), n_hops)
            n_sub = sum(len(r) for r in rings)
            halo_nodes += n_sub - len(rings[0])
            if n_sub > closure_max:
                closure_max = n_sub
                interior = _interior(rings, closed)
                rep_nnz = np.concatenate(
                    [g.nnz[interior], np.ones(n_sub - len(interior), dtype=np.int64)]
                )
            if (
                cap is not None
                and next_pow2(n_sub) * f_max * hw.bytes_per_elem > cap
            ):
                ok = False
                break
        if ok and n_parts > 1:
            return block, n_parts, closure_max, halo_nodes, rep_nnz
        block //= 2
    return None


def _layer_cost(
    workloads, hw, objective, names, mult=1.0
) -> tuple[float, float] | None:
    """Total (cycles, energy_pj) of the best mapping per layer, or None
    when no skeleton in ``names`` yields a legal tiling."""
    from ..core.mapper import search_dataflows

    cyc = en = 0.0
    for wl in workloads:
        res = search_dataflows(
            wl, hw=hw, objective=objective, names=names, pe_splits=(0.5,), top_k=1
        )
        if not res:
            return None
        cyc += res[0].stats.cycles * mult
        en += res[0].stats.energy_pj * mult
    return cyc, en


def plan_partition(
    g: CSRGraph,
    dims,
    hw: AcceleratorConfig = DEFAULT_ACCEL,
    *,
    objective: str = "edp",
    n_devices: int = 1,
    allow_monolithic: bool = True,
    max_partitions: int = 256,
    max_block_rows: int | None = None,
) -> PartitionPlan:
    """Choose an execution plan for ``g`` under ``hw``'s capacity.

    ``dims`` is the model's per-layer ``(f_in, f_out)`` list.  An
    :class:`~repro.core.hw.HWGrid` collapses to its base config for
    planning.  ``max_block_rows`` caps row-stream blocks (the engine
    passes its admission ``max_nodes`` so partitions stay admissible).
    Raises ``ValueError`` when no candidate is feasible.
    """
    base = getattr(hw, "base", hw)
    dims = [tuple(d) for d in dims]
    if not dims:
        raise ValueError("dims must name at least one layer")
    f_max = max(max(fi, fo) for fi, fo in dims)
    f_in0 = dims[0][0]
    f_inter = sum(fi for fi, _ in dims)  # intermediate widths crossing cuts
    cap = base.gb_capacity_bytes
    v = g.n_nodes
    n_hops = len(dims)
    footprint = intermediate_footprint_bytes(v, f_max, base)
    fits = cap is None or footprint <= cap

    candidates: list[PlanCandidate] = []
    chosen_geo: dict[str, tuple] = {}

    def add(kind, n_parts, lc, comm: PartitionCommStats, note=""):
        if lc is None:
            candidates.append(
                PlanCandidate(kind, n_parts, False, note=note or "no legal tiling")
            )
            return
        cyc, en = lc
        candidates.append(
            PlanCandidate(
                kind,
                n_parts,
                True,
                layer_cycles=cyc,
                layer_energy_pj=en,
                comm_cycles=comm.cycles,
                comm_energy_pj=comm.energy_pj,
                objective_value=objective_value(
                    objective, cyc + comm.cycles, en + comm.energy_pj
                ),
                note=note,
            )
        )

    mono_wls = [
        GNNLayerWorkload(g.nnz, fi, fo, name=f"mono-l{i}")
        for i, (fi, fo) in enumerate(dims)
    ]
    if allow_monolithic:
        add(
            "monolithic",
            1,
            _layer_cost(mono_wls, base, objective, FIT_NAMES if fits else SPILL_NAMES),
            partition_comm_cost("monolithic", 1, v=v, f=f_max, hw=base),
            note="fits" if fits else "spills: priced on Seq family",
        )

    geo = _row_stream_geometry(g, f_max, base, n_hops, max_partitions, max_block_rows)
    if geo is None:
        candidates.append(
            PlanCandidate(
                "row_stream", 0, False, note="no block size keeps closures GB-resident"
            )
        )
    else:
        block, n_parts, closure_max, halo_nodes, rep_nnz = geo
        chosen_geo["row_stream"] = geo
        wls = [
            GNNLayerWorkload(rep_nnz, fi, fo, name=f"rs-l{i}")
            for i, (fi, fo) in enumerate(dims)
        ]
        add(
            "row_stream",
            n_parts,
            _layer_cost(wls, base, objective, FIT_NAMES, mult=n_parts),
            partition_comm_cost(
                "row_stream",
                n_parts,
                v=v,
                f=f_in0,
                hw=base,
                halo_elems=halo_nodes * f_in0,
            ),
            note=f"block_rows={block} closure_max={closure_max}",
        )

    if cap is None:
        candidates.append(
            PlanCandidate("feature_chunk", 0, False, note="no capacity to chunk against")
        )
    else:
        chunk_f = min(cap // (v * base.bytes_per_elem), f_max)
        n_chunks = math.ceil(f_max / chunk_f) if chunk_f >= 1 else 0
        if chunk_f < 1 or n_chunks > max_partitions:
            candidates.append(
                PlanCandidate(
                    "feature_chunk", 0, False, note="graph too tall to chunk columns"
                )
            )
        else:
            chosen_geo["feature_chunk"] = (int(chunk_f), n_chunks)
            # work is conserved across chunks and each chunk's intermediate
            # is GB-resident, so compute is priced spill-free on the full
            # workload; the chunk-boundary round-trips are the comm term.
            add(
                "feature_chunk",
                n_chunks,
                _layer_cost(mono_wls, base, objective, FIT_NAMES),
                partition_comm_cost(
                    "feature_chunk", n_chunks, v=v, f=f_inter, hw=base
                ),
                note=f"chunk_f={int(chunk_f)}",
            )

    if n_devices >= 2:
        add(
            "pp_shard",
            n_devices,
            _layer_cost(mono_wls, base, objective, PP_NAMES),
            partition_comm_cost("pp_shard", n_devices, v=v, f=f_inter, hw=base),
            note=f"{n_devices}-device phase mesh",
        )
    else:
        candidates.append(
            PlanCandidate("pp_shard", 0, False, note="needs >= 2 devices")
        )

    ranked = tuple(
        sorted(candidates, key=lambda c: (not c.feasible, c.objective_value))
    )
    best = ranked[0]
    if not best.feasible:
        raise ValueError(
            f"no feasible execution plan for V={v} under "
            f"gb_capacity_bytes={cap}: "
            + "; ".join(f"{c.kind}: {c.note}" for c in ranked)
        )
    plan = PartitionPlan(
        kind=best.kind,
        objective=objective,
        objective_value=best.objective_value,
        n_partitions=best.n_partitions,
        n_hops=n_hops if best.kind == "row_stream" else 0,
        footprint_bytes=footprint,
        candidates=ranked,
    )
    if best.kind == "row_stream":
        block, n_parts, _closure_max, halo_nodes, _ = chosen_geo["row_stream"]
        plan = PartitionPlan(
            **{
                **asdict(plan),
                "block_rows": int(block),
                "halo_nodes": int(halo_nodes),
                "candidates": ranked,
            }
        )
    elif best.kind == "feature_chunk":
        chunk_f, _ = chosen_geo["feature_chunk"]
        plan = PartitionPlan(
            **{**asdict(plan), "chunk_f": int(chunk_f), "candidates": ranked}
        )
    return plan


# ---------------------------------------------------------------------------
# Execution paths (functional; the engine drives row_stream through
# Programs, these are the reference/standalone implementations)
# ---------------------------------------------------------------------------


@register_kernel("feature_chunk", orders=("AC",))
def _feature_chunk_ac(adj, x, w, spec, mesh=None):
    """Seq/AC with the V x F intermediate built one column chunk at a
    time.  Columns of ``A @ X`` are independent per-row reductions, so
    the chunked concat matches the monolithic aggregate to <= 1 ulp
    (XLA may pick a different reduction strategy for narrow chunks)."""
    import jax.numpy as jnp

    from ..gnn.layers import aggregate_full

    fc = spec.block_f or x.shape[1]
    cols = [aggregate_full(adj, x[:, c : c + fc]) for c in range(0, x.shape[1], fc)]
    return (jnp.concatenate(cols, axis=1) @ w)[: adj.n_nodes]


@register_kernel("feature_chunk", orders=("CA",))
def _feature_chunk_ca(adj, x, w, spec, mesh=None):
    import jax.numpy as jnp

    from ..gnn.layers import aggregate_full

    fc = spec.block_f or w.shape[1]
    cols = [
        aggregate_full(adj, x @ w[:, c : c + fc]) for c in range(0, w.shape[1], fc)
    ]
    return jnp.concatenate(cols, axis=1)[: adj.n_nodes]


def _specs(policy, order, band_size, n_layers, block_f=None):
    from ..core.schedule import ExecSpec

    return [ExecSpec(policy, order, band_size, block_f, 1, False)] * n_layers


def row_stream_forward(
    g: CSRGraph,
    x,
    params,
    *,
    kind: str = "gcn",
    policy: str = "sp_opt",
    order: str = "AC",
    band_size: int = 128,
    block_rows: int,
    n_hops: int | None = None,
    readout: str | None = None,
):
    """Whole-model forward via row-streamed halo closures (reference
    implementation; bit-identical to the monolithic forward)."""
    import jax.numpy as jnp

    from ..gnn.layers import EllAdjacency, segment_readout
    from ..gnn.model import forward_layers

    x = np.asarray(x)
    hops = n_hops if n_hops is not None else len(params)
    specs = _specs(policy, order, band_size, len(params))
    pad = g.max_degree  # same ELL width as the whole-graph adjacency
    outs = []
    for part in extract_row_partitions(g, block_rows, hops):
        adj = EllAdjacency.from_csr(part.graph, pad_to=pad)
        h = forward_layers(kind, params, adj, jnp.asarray(x[part.nodes]), specs)
        outs.append(np.asarray(h)[: part.n_own])
    h = np.concatenate(outs, axis=0)
    if readout is None:
        return h
    seg = jnp.zeros(h.shape[0], dtype=jnp.int32)
    return np.asarray(segment_readout(jnp.asarray(h), seg, 1, reduce=readout))[0]


def feature_chunk_forward(
    g: CSRGraph,
    x,
    params,
    *,
    kind: str = "gcn",
    order: str = "AC",
    chunk_f: int,
    band_size: int = 128,
    readout: str | None = None,
):
    """Whole-model forward with chunked feature columns."""
    import jax.numpy as jnp

    from ..gnn.layers import EllAdjacency, segment_readout
    from ..gnn.model import forward_layers

    adj = EllAdjacency.from_csr(g)
    specs = _specs("feature_chunk", order, band_size, len(params), block_f=chunk_f)
    h = np.asarray(forward_layers(kind, params, adj, jnp.asarray(x), specs))
    if readout is None:
        return h
    seg = jnp.zeros(h.shape[0], dtype=jnp.int32)
    return np.asarray(segment_readout(jnp.asarray(h), seg, 1, reduce=readout))[0]


def pp_shard_forward(
    g: CSRGraph,
    x,
    params,
    *,
    kind: str = "gcn",
    order: str = "AC",
    band_size: int = 128,
    n_devices: int | None = None,
    readout: str | None = None,
):
    """Whole-model forward on the device-level pipeline-parallel mesh.

    Falls back to the SP-Generic band scan below two devices (see
    :func:`repro.gnn.pp.pp_multiphase_matmul`); cross-device hand-off
    matches the single-device path to float tolerance, not bitwise.
    """
    import jax
    import jax.numpy as jnp

    from ..gnn.layers import EllAdjacency, segment_readout
    from ..gnn.model import forward_layers

    devs = jax.devices()
    n = min(n_devices or len(devs), len(devs))
    mesh = None
    if n >= 2:
        mesh = jax.sharding.Mesh(np.array(devs[:n]), ("phase",))
    adj = EllAdjacency.from_csr(g)
    specs = _specs("pp", order, band_size, len(params))
    h = np.asarray(
        forward_layers(kind, params, adj, jnp.asarray(x), specs, mesh=mesh)
    )
    if readout is None:
        return h
    seg = jnp.zeros(h.shape[0], dtype=jnp.int32)
    return np.asarray(segment_readout(jnp.asarray(h), seg, 1, reduce=readout))[0]
