"""Bucketized multi-graph batching: many graphs, few compiled shapes.

Real GNN serving traffic is a stream of small graphs (the paper batches
64/32 graphs per inference, Sec. 5.1.2); a JAX/XLA execution path pays a
fresh compile for every distinct input shape.  This module is the bridge
between the two facts:

* :class:`BucketPolicy` — a pow2 padding-bucket router.  Every graph maps
  to a ``(node_bucket, degree_bucket)`` key; graphs sharing a key batch
  together and pad to the *same* device shapes, so a whole request stream
  funnels into a handful of compiled executables.
* :func:`assemble` — block-diagonal micro-batch assembly
  (:func:`repro.graphs.csr.block_diagonal` under the hood) that pads the
  batch with isolated self-loop nodes up to the bucket shape and carries
  per-graph **segment ids**, so node features, labels, and per-graph
  readout survive batching (pad rows get segment id ``n_graphs``, which
  JAX segment ops drop as out-of-range).

The serving loop on top lives in :mod:`repro.runtime.engine`.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from pathlib import Path
from typing import Sequence

import numpy as np

from .csr import CSRGraph, block_diagonal

TRAFFIC_FORMAT = "repro.traffic/v1"


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (1 for n <= 1)."""
    return 1 << max(int(n) - 1, 0).bit_length()


@dataclass(frozen=True)
class BucketPolicy:
    """Pow2 padding buckets over (node count, max degree).

    ``min_nodes`` / ``min_degree`` floor the buckets so tiny graphs don't
    fragment the cache into near-empty shapes; ``max_graphs`` caps the
    micro-batch (the paper's 64-graph batches).  Slot counts of partial
    batches round up to a power of two too, so a bucket contributes at
    most ``log2(max_graphs) + 1`` distinct device shapes.

    ``max_nodes`` / ``max_degree`` are the explicit oversized-graph caps:
    a graph beyond either would otherwise silently compile a one-off giant
    bucket (its own mapper search + XLA trace that nothing else ever
    reuses).  With a cap set, :meth:`oversized_reason` names the violated
    limit and the serving engine rejects the request with a typed
    ``OversizedGraph`` error instead.  ``None`` (the default) keeps the
    pre-cap behavior: any size is admitted.
    """

    min_nodes: int = 32
    min_degree: int = 8
    max_graphs: int = 64
    max_nodes: int | None = None
    max_degree: int | None = None

    def oversized_reason(
        self,
        g: CSRGraph,
        *,
        f: int | None = None,
        hw=None,
    ) -> str | None:
        """Why ``g`` exceeds the admission caps, or ``None`` if it fits.

        With ``f`` (the model's widest layer dimension) and ``hw`` (an
        :class:`~repro.core.hw.AcceleratorConfig`), the check also prices
        the bucketed graph's staged V x f intermediate against
        ``gb_capacity_bytes`` — the same footprint the simulator's spill
        model charges DRAM energy for — so admission and the partition
        planner agree on what "oversized" means.
        """
        if self.max_nodes is not None and g.n_nodes > self.max_nodes:
            return (
                f"graph has {g.n_nodes} nodes, over the policy cap "
                f"max_nodes={self.max_nodes}"
            )
        if self.max_degree is not None and g.max_degree > self.max_degree:
            return (
                f"graph has max degree {g.max_degree}, over the policy cap "
                f"max_degree={self.max_degree}"
            )
        if f is not None and hw is not None and hw.gb_capacity_bytes is not None:
            from ..core.simulator import intermediate_footprint_bytes

            fb = intermediate_footprint_bytes(self.node_bucket(g.n_nodes), f, hw)
            if fb > hw.gb_capacity_bytes:
                return (
                    f"staged intermediate is {fb} bytes "
                    f"({self.node_bucket(g.n_nodes)} bucketed nodes x {f} "
                    f"features), over gb_capacity_bytes="
                    f"{hw.gb_capacity_bytes}"
                )
        return None

    def node_bucket(self, n_nodes: int) -> int:
        return max(self.min_nodes, next_pow2(n_nodes))

    def degree_bucket(self, max_degree: int) -> int:
        return max(self.min_degree, next_pow2(max_degree))

    def bucket_of(self, g: CSRGraph) -> tuple[int, int]:
        """The (node_bucket, degree_bucket) routing key for one graph."""
        return self.node_bucket(g.n_nodes), self.degree_bucket(g.max_degree)

    def slot_count(self, n_graphs: int) -> int:
        """Padded graph-slot count of a micro-batch (pow2, <= max_graphs)."""
        if n_graphs > self.max_graphs:
            raise ValueError(
                f"micro-batch of {n_graphs} graphs exceeds max_graphs="
                f"{self.max_graphs}"
            )
        return min(next_pow2(n_graphs), self.max_graphs)


def _pad_graph(n_pad: int) -> CSRGraph:
    """``n_pad`` isolated self-loop rows (weight 0, so they contribute
    nothing even before the segment readout drops them)."""
    return CSRGraph(
        row_ptr=np.arange(n_pad + 1, dtype=np.int64),
        col_idx=np.arange(n_pad, dtype=np.int32),
        values=np.zeros(n_pad, dtype=np.float32),
        n_nodes=n_pad,
    )


@dataclass(frozen=True)
class GraphBatch:
    """One assembled micro-batch: block-diagonal graph + segment ids.

    ``graph`` has exactly ``v_total = node_bucket * slots`` rows (member
    graphs first, then isolated zero-weight pad rows), so every batch from
    the same bucket presents identical device shapes.  ``segment_ids[i]``
    is the member-graph index of row ``i``; pad rows carry ``n_graphs``
    (out of range for ``num_segments=n_graphs``, hence dropped by
    ``jax.ops.segment_sum``/``segment_max``).
    """

    graph: CSRGraph
    segment_ids: np.ndarray  # (v_total,) int32
    sizes: np.ndarray  # (n_graphs,) int64 real node counts
    v_bucket: int  # node bucket each member padded into
    d_bucket: int  # padded-ELL width every member fits in

    @property
    def n_graphs(self) -> int:
        return int(len(self.sizes))

    @property
    def slots(self) -> int:
        """Padded graph-slot count (pow2).  Readout over ``slots`` segments
        keeps the executable shape fixed across batch fill levels; rows
        n_graphs..slots-1 of the result are pad segments to slice off."""
        return self.v_total // self.v_bucket

    @property
    def v_total(self) -> int:
        return self.graph.n_nodes

    @property
    def n_pad(self) -> int:
        return self.v_total - int(self.sizes.sum())

    @property
    def offsets(self) -> np.ndarray:
        """Start row of each member graph in the batched node dimension."""
        return np.concatenate([[0], np.cumsum(self.sizes)[:-1]]).astype(np.int64)

    def batch_features(self, xs: Sequence[np.ndarray]) -> np.ndarray:
        """Stack per-graph node features into the batched (v_total, F)
        array (zeros on pad rows)."""
        if len(xs) != self.n_graphs:
            raise ValueError(
                f"batch holds {self.n_graphs} graphs but got {len(xs)} "
                f"feature arrays"
            )
        for x, n in zip(xs, self.sizes):
            if x.shape[0] != n:
                raise ValueError(
                    f"feature array has {x.shape[0]} rows for a "
                    f"{n}-node graph"
                )
        f = xs[0].shape[1]
        out = np.zeros((self.v_total, f), dtype=np.float32)
        out[: int(self.sizes.sum())] = np.concatenate(xs, axis=0)
        return out

    def split_nodes(self, out: np.ndarray) -> list[np.ndarray]:
        """Slice a batched per-node output back into per-graph arrays
        (pad rows discarded)."""
        out = np.asarray(out)
        return [
            out[o : o + n]
            for o, n in zip(self.offsets, self.sizes)
        ]


def assemble(
    graphs: Sequence[CSRGraph], policy: BucketPolicy = BucketPolicy()
) -> GraphBatch:
    """Block-diagonal micro-batch assembly, padded to the bucket shape.

    All members must route to the same :meth:`BucketPolicy.bucket_of` key
    (that is the router's job); the assembled batch then has exactly
    ``node_bucket * slot_count`` rows and every neighbor list fits in
    ``degree_bucket`` padded-ELL slots.
    """
    if not graphs:
        raise ValueError("assemble() needs at least one graph")
    keys = {policy.bucket_of(g) for g in graphs}
    if len(keys) > 1:
        raise ValueError(
            f"graphs route to different buckets {sorted(keys)}; the router "
            f"must group a micro-batch into one bucket"
        )
    ((v_bucket, d_bucket),) = keys
    slots = policy.slot_count(len(graphs))
    v_total = v_bucket * slots
    sizes = np.array([g.n_nodes for g in graphs], dtype=np.int64)
    n_pad = v_total - int(sizes.sum())
    assert n_pad >= 0, "bucket arithmetic cannot under-allocate"
    members = list(graphs) + ([_pad_graph(n_pad)] if n_pad else [])
    batched = block_diagonal(members)
    segment_ids = np.full(v_total, len(graphs), dtype=np.int32)
    off = 0
    for i, n in enumerate(sizes):
        segment_ids[off : off + n] = i
        off += int(n)
    return GraphBatch(
        graph=batched,
        segment_ids=segment_ids,
        sizes=sizes,
        v_bucket=v_bucket,
        d_bucket=d_bucket,
    )


@dataclass
class TrafficProfile:
    """Recorded per-bucket traffic: what a serving process actually saw.

    Two ledgers, both additive counters:

    * ``requests[(v_bucket, d_bucket)]`` — how many requests routed to the
      bucket (its *heat*: the precompile priority order);
    * ``batches[(v_bucket, d_bucket, slots)]`` — how many micro-batches
      ran at each padded slot count.  The executable shape depends on
      ``(v_bucket * slots, d_bucket)``, so these triples are exactly the
      shapes a revived engine must warm to serve its first request
      trace-free (:meth:`~repro.runtime.engine.InferenceEngine.precompile`).

    The profile is serialized alongside the program store
    (:meth:`repro.runtime.store.ProgramStore.save_profile`) so bucket heat
    survives the process; :meth:`merge` folds one life's traffic into the
    last one's.
    """

    requests: dict[tuple[int, int], int] = field(default_factory=dict)
    batches: dict[tuple[int, int, int], int] = field(default_factory=dict)
    #: measured batch wall-clock per schedule:
    #: ``(v_bucket, d_bucket, slots, schedule_digest) -> (count,
    #: total_wall_s)`` — the execution-feedback ledger the engine's
    #: measured re-ranking (:meth:`InferenceEngine.rerank_topk`) scores
    #: candidate schedules with.
    observed: dict[tuple[int, int, int, str], tuple[int, float]] = field(
        default_factory=dict
    )

    def record_request(self, bucket: tuple[int, int], n: int = 1) -> None:
        key = (int(bucket[0]), int(bucket[1]))
        self.requests[key] = self.requests.get(key, 0) + int(n)

    def record_batch(self, bucket: tuple[int, int], slots: int) -> None:
        key = (int(bucket[0]), int(bucket[1]), int(slots))
        self.batches[key] = self.batches.get(key, 0) + 1

    def record_wall(
        self,
        bucket: tuple[int, int],
        slots: int,
        schedule_digest: str,
        wall_s: float,
    ) -> None:
        """Fold one measured batch wall time into the observation ledger."""
        key = (int(bucket[0]), int(bucket[1]), int(slots), str(schedule_digest))
        n, tot = self.observed.get(key, (0, 0.0))
        self.observed[key] = (n + 1, tot + float(wall_s))

    def mean_wall(
        self, bucket: tuple[int, int], slots: int, schedule_digest: str
    ) -> float | None:
        """Mean observed wall seconds for a (shape, schedule), or ``None``
        when never observed."""
        key = (int(bucket[0]), int(bucket[1]), int(slots), str(schedule_digest))
        entry = self.observed.get(key)
        if entry is None or entry[0] == 0:
            return None
        return entry[1] / entry[0]

    @property
    def n_requests(self) -> int:
        return sum(self.requests.values())

    def merge(self, other: "TrafficProfile") -> "TrafficProfile":
        """A new profile with all ledgers summed (self is unchanged)."""
        out = TrafficProfile(
            dict(self.requests), dict(self.batches), dict(self.observed)
        )
        for k, n in other.requests.items():
            out.requests[k] = out.requests.get(k, 0) + n
        for k, n in other.batches.items():
            out.batches[k] = out.batches.get(k, 0) + n
        for k, (n, tot) in other.observed.items():
            n0, tot0 = out.observed.get(k, (0, 0.0))
            out.observed[k] = (n0 + n, tot0 + tot)
        return out

    def heat(self) -> list[tuple[tuple[int, int], int]]:
        """Buckets with their request counts, hottest first (ties break on
        the smaller bucket, so placement is deterministic).  This is the
        placer's input: the hottest buckets get replicas first."""
        return sorted(self.requests.items(), key=lambda kv: (-kv[1], kv[0]))

    def subset(
        self, buckets: "set[tuple[int, int]] | Sequence[tuple[int, int]]"
    ) -> "TrafficProfile":
        """A new profile restricted to ``buckets`` — what one device of a
        placement should precompile (its assigned buckets only, with their
        recorded slot variants intact)."""
        keep = {(int(v), int(d)) for v, d in buckets}
        return TrafficProfile(
            requests={b: n for b, n in self.requests.items() if b in keep},
            batches={
                k: n for k, n in self.batches.items() if k[:2] in keep
            },
            observed={
                k: v for k, v in self.observed.items() if k[:2] in keep
            },
        )

    def hot_shapes(self) -> list[tuple[tuple[int, int], int]]:
        """Every recorded ``((v_bucket, d_bucket), slots)`` shape, hottest
        first: buckets by request count (descending), slot variants of a
        bucket by batch count (descending); ties break on the smaller
        shape so warmup cost stays deterministic."""
        heat = lambda b: self.requests.get(b, 0)  # noqa: E731
        shapes = sorted(
            self.batches.items(),
            key=lambda kv: (-heat(kv[0][:2]), -kv[1], kv[0]),
        )
        return [((v, d), s) for (v, d, s), _ in shapes]

    # -- artifact ------------------------------------------------------------
    def to_json(self) -> str:
        payload = {
            "format": TRAFFIC_FORMAT,
            "requests": {
                f"{v}x{d}": n for (v, d), n in sorted(self.requests.items())
            },
            "batches": {
                f"{v}x{d}x{s}": n
                for (v, d, s), n in sorted(self.batches.items())
            },
            "observed": {
                f"{v}x{d}x{s}:{dig}": [n, tot]
                for (v, d, s, dig), (n, tot) in sorted(self.observed.items())
            },
        }
        return json.dumps(payload, indent=2, sort_keys=True) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "TrafficProfile":
        d = json.loads(text)
        if d.get("format") != TRAFFIC_FORMAT:
            raise ValueError(
                f"not a {TRAFFIC_FORMAT} artifact (format={d.get('format')!r})"
            )
        parse = lambda k: tuple(int(p) for p in k.split("x"))  # noqa: E731

        def parse_obs(k: str) -> tuple:
            shape, dig = k.rsplit(":", 1)
            return (*parse(shape), dig)

        return cls(
            requests={parse(k): int(n) for k, n in d["requests"].items()},
            batches={parse(k): int(n) for k, n in d["batches"].items()},
            # absent in pre-calibration profiles (back-compat)
            observed={
                parse_obs(k): (int(v[0]), float(v[1]))
                for k, v in d.get("observed", {}).items()
            },
        )

    def save(self, path) -> Path:
        """Atomic write (temp file + ``os.replace``), same contract as
        :meth:`repro.api.Program.save`."""
        p = Path(path)
        tmp = p.with_name(p.name + f".tmp.{os.getpid()}")
        try:
            tmp.write_text(self.to_json())
            os.replace(tmp, p)
        finally:
            tmp.unlink(missing_ok=True)
        return p

    @classmethod
    def load(cls, path) -> "TrafficProfile":
        return cls.from_json(Path(path).read_text())


def bucketize(
    graphs: Sequence[CSRGraph], policy: BucketPolicy = BucketPolicy()
) -> dict[tuple[int, int], list[int]]:
    """Route a stream: bucket key -> indices into ``graphs``, in arrival
    order.  The engine chunks each bucket's list into ``max_graphs``-sized
    micro-batches for :func:`assemble`."""
    routed: dict[tuple[int, int], list[int]] = {}
    for i, g in enumerate(graphs):
        routed.setdefault(policy.bucket_of(g), []).append(i)
    return routed
