"""Synthetic stand-ins for the paper's datasets (Table 4).

The container is offline, so each dataset is generated with a seeded RNG to
match Table 4's published statistics (#graphs, avg nodes, avg edges,
#features) and — more importantly for the dataflow study — the *degree
structure* that drives the paper's observations:

  * Mutag / Proteins (LEF): small sparse molecules, near-uniform low degree
    ("no evil rows", paper Sec. 5.2.1).
  * Imdb-bin / Collab (HE): dense ego-/collaboration networks (high E/V).
  * Reddit-bin / Citeseer / Cora (HF): high-feature graphs with skewed
    (power-law-ish) degree distributions — the source of "evil rows".

Graph-classification sets are batched block-diagonally (64 graphs; 32 for
Reddit-bin) exactly as in the paper's methodology (Sec. 5.1.2).
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass

import numpy as np

from .csr import CSRGraph, block_diagonal, from_edges


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_graphs: int  # graphs in one evaluated batch (1 = node classification)
    avg_nodes: float
    avg_edges: float
    n_features: int
    category: str  # HE / HF / LEF (paper Sec 5.1.2)
    kind: str  # "molecule" | "ego" | "collab" | "thread" | "citation"


TABLE4 = {
    "mutag": DatasetSpec("mutag", 64, 17.93, 19.79, 28, "LEF", "molecule"),
    "proteins": DatasetSpec("proteins", 64, 39.06, 72.82, 29, "LEF", "molecule"),
    "imdb-bin": DatasetSpec("imdb-bin", 64, 19.77, 96.53, 136, "HE", "ego"),
    "collab": DatasetSpec("collab", 64, 74.49, 2457.78, 492, "HE", "collab"),
    "reddit-bin": DatasetSpec("reddit-bin", 32, 429.63, 497.75, 3782, "HF", "thread"),
    "citeseer": DatasetSpec("citeseer", 1, 3327, 9464, 3703, "HF", "citation"),
    "cora": DatasetSpec("cora", 1, 2708, 10858, 1433, "HF", "citation"),
}


def _molecule(rng: np.random.Generator, n: int, m: int) -> tuple:
    """Sparse near-chain molecule: ring + random chords, degree ~2-4."""
    n = max(n, 3)
    src = np.arange(n)
    dst = (src + 1) % n
    extra = max(m - n, 0)
    es = rng.integers(0, n, size=extra)
    ed = rng.integers(0, n, size=extra)
    src = np.concatenate([src, es])
    dst = np.concatenate([dst, ed])
    return n, np.concatenate([src, dst]), np.concatenate([dst, src])


def _ego(rng: np.random.Generator, n: int, m: int) -> tuple:
    """IMDB-style ego-net: dense core (actors of one movie form cliques)."""
    n = max(n, 4)
    # partition into 1-3 cliques covering all nodes
    k = int(rng.integers(1, 4))
    cuts = np.sort(rng.choice(np.arange(1, n), size=k - 1, replace=False)) if k > 1 else np.array([], int)
    bounds = np.concatenate([[0], cuts, [n]])
    src, dst = [], []
    for a, b in zip(bounds[:-1], bounds[1:]):
        idx = np.arange(a, b)
        if len(idx) < 2:
            continue
        s, d = np.meshgrid(idx, idx)
        keep = s != d
        src.append(s[keep])
        dst.append(d[keep])
    if not src:
        return _molecule(rng, n, m)
    return n, np.concatenate(src), np.concatenate(dst)


def _collab(rng: np.random.Generator, n: int, m: int) -> tuple:
    """Collaboration net: overlapping dense groups → very high degree."""
    n = max(n, 8)
    target = m
    src, dst = [], []
    total = 0
    while total < target:
        size = int(rng.integers(max(4, n // 8), max(6, n // 2)))
        idx = rng.choice(n, size=min(size, n), replace=False)
        s, d = np.meshgrid(idx, idx)
        keep = s != d
        src.append(s[keep])
        dst.append(d[keep])
        total += keep.sum()
    return n, np.concatenate(src), np.concatenate(dst)


def _thread(rng: np.random.Generator, n: int, m: int) -> tuple:
    """Reddit-thread style: a few huge hubs (evil rows) + shallow replies."""
    n = max(n, 10)
    hubs = max(1, n // 150)
    hub_ids = rng.choice(n, size=hubs, replace=False)
    # most nodes attach to a hub; some chain replies
    others = np.setdiff1d(np.arange(n), hub_ids)
    parent_hub = rng.choice(hub_ids, size=len(others))
    src = [others, parent_hub]
    dst = [parent_hub, others]
    extra = max(m - len(others), 0)
    es = rng.integers(0, n, size=extra)
    ed = np.maximum(es - rng.integers(1, 5, size=extra), 0)
    src.append(es)
    dst.append(ed)
    src.append(ed)
    dst.append(es)
    return n, np.concatenate(src), np.concatenate(dst)


def _citation(rng: np.random.Generator, n: int, m: int) -> tuple:
    """Preferential attachment: power-law in-degree (citation hubs)."""
    deg_m = max(1, int(round(m / n / 2)))
    src_l, dst_l = [], []
    deg = np.ones(n, dtype=np.float64)
    seed = deg_m + 1
    order = rng.permutation(n)
    for i in range(seed, n):
        p = deg[order[:i]] / deg[order[:i]].sum()
        targets = rng.choice(order[:i], size=min(deg_m, i), replace=False, p=p)
        for t in targets:
            src_l.append(order[i])
            dst_l.append(t)
            deg[t] += 1
            deg[order[i]] += 1
    src = np.array(src_l)
    dst = np.array(dst_l)
    return n, np.concatenate([src, dst]), np.concatenate([dst, src])


_GENERATORS = {
    "molecule": _molecule,
    "ego": _ego,
    "collab": _collab,
    "thread": _thread,
    "citation": _citation,
}


def make_graph(spec: DatasetSpec, rng: np.random.Generator) -> CSRGraph:
    n = max(3, int(round(rng.normal(spec.avg_nodes, spec.avg_nodes * 0.25))))
    scale = n / spec.avg_nodes
    m = max(2, int(round(spec.avg_edges * scale)))
    n, src, dst = _GENERATORS[spec.kind](rng, n, m)
    return from_edges(n, src, dst)


def load_dataset(name: str, seed: int = 0) -> tuple[CSRGraph, DatasetSpec]:
    """One evaluation batch per paper Sec. 5.1.2 (block-diagonal for
    graph-classification datasets, the full graph for node classification)."""
    spec = TABLE4[name]
    # zlib.crc32 (not hash()) keeps graphs stable across processes —
    # str hashing is PYTHONHASHSEED-salted, which made the committed
    # benchmark evidence irreproducible run to run.
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % (2**31))
    if spec.n_graphs == 1:
        n, src, dst = _GENERATORS[spec.kind](rng, int(spec.avg_nodes), int(spec.avg_edges))
        return from_edges(n, src, dst), spec
    graphs = [make_graph(spec, rng) for _ in range(spec.n_graphs)]
    return block_diagonal(graphs), spec


def all_datasets(seed: int = 0):
    for name in TABLE4:
        yield name, *load_dataset(name, seed)
