"""CSR / padded-ELL graph structures.

The paper assumes CSR adjacency (Sec. 2.1, Fig. 3b).  On TPU, truly random
CSR walks do not vectorize, so the JAX execution path uses a padded
row-block layout (ELL): rows grouped into blocks, neighbor lists padded to
the block's max degree.  The padding waste *is* the paper's lockstep /
evil-row cost, so the same structure feeds both the simulator (exact nnz
array) and the JAX/Pallas kernels (padded indices + mask).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class CSRGraph:
    """Adjacency in CSR with self-loops; values are normalized (GCN Ã)."""

    row_ptr: np.ndarray  # (V+1,) int32
    col_idx: np.ndarray  # (E,) int32
    values: np.ndarray  # (E,) float32 — Ã = D^-1/2 (A+I) D^-1/2 weights
    n_nodes: int

    @property
    def n_edges(self) -> int:
        return int(len(self.col_idx))

    @property
    def nnz(self) -> np.ndarray:
        return np.diff(self.row_ptr).astype(np.int64)

    @property
    def avg_degree(self) -> float:
        return self.n_edges / max(self.n_nodes, 1)

    @property
    def max_degree(self) -> int:
        return int(self.nnz.max()) if self.n_nodes else 0

    def validate(self) -> None:
        assert self.row_ptr[0] == 0 and self.row_ptr[-1] == self.n_edges
        assert (np.diff(self.row_ptr) >= 0).all()
        assert (self.col_idx >= 0).all() and (self.col_idx < self.n_nodes).all()

    # -- conversions ---------------------------------------------------------
    def to_dense(self) -> np.ndarray:
        a = np.zeros((self.n_nodes, self.n_nodes), dtype=np.float32)
        for v in range(self.n_nodes):
            s, e = self.row_ptr[v], self.row_ptr[v + 1]
            a[v, self.col_idx[s:e]] = self.values[s:e]
        return a

    def to_ell(self, block_rows: int = 1, pad_to: int | None = None):
        """Padded neighbor lists: returns (indices, weights, mask) of shape
        (V_pad, D) where D = max degree over each `block_rows` row block,
        rounded up to the global max (single buffer).  Padded slots point at
        row 0 with weight 0, so gather+weighted-sum stays correct."""
        v = self.n_nodes
        d = pad_to or max(self.max_degree, 1)
        v_pad = -(-v // block_rows) * block_rows
        idx = np.zeros((v_pad, d), dtype=np.int32)
        wts = np.zeros((v_pad, d), dtype=np.float32)
        msk = np.zeros((v_pad, d), dtype=bool)
        for r in range(v):
            s, e = self.row_ptr[r], self.row_ptr[r + 1]
            k = min(e - s, d)
            idx[r, :k] = self.col_idx[s : s + k]
            wts[r, :k] = self.values[s : s + k]
            msk[r, :k] = True
        return idx, wts, msk


def from_edges(
    n_nodes: int,
    src: np.ndarray,
    dst: np.ndarray,
    add_self_loops: bool = True,
    normalize: bool = True,
) -> CSRGraph:
    """Build a CSR graph (GCN-normalized) from an edge list."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if add_self_loops:
        loops = np.arange(n_nodes, dtype=np.int64)
        src = np.concatenate([src, loops])
        dst = np.concatenate([dst, loops])
    # dedupe
    keys = src * n_nodes + dst
    keys = np.unique(keys)
    src, dst = keys // n_nodes, keys % n_nodes

    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    counts = np.bincount(src, minlength=n_nodes)
    row_ptr = np.zeros(n_nodes + 1, dtype=np.int32)
    np.cumsum(counts, out=row_ptr[1:])
    if normalize:
        deg = np.maximum(counts, 1).astype(np.float32)
        dinv = 1.0 / np.sqrt(deg)
        values = dinv[src] * dinv[dst]
    else:
        values = np.ones(len(src), dtype=np.float32)
    return CSRGraph(row_ptr, dst.astype(np.int32), values.astype(np.float32), n_nodes)


def block_diagonal(graphs: list[CSRGraph]) -> CSRGraph:
    """Batch graphs into one block-diagonal CSR (paper batches 64/32 graphs)."""
    offs = 0
    ptrs = [np.zeros(1, dtype=np.int64)]
    cols, vals = [], []
    for g in graphs:
        ptrs.append(g.row_ptr[1:].astype(np.int64) + ptrs[-1][-1])
        cols.append(g.col_idx.astype(np.int64) + offs)
        vals.append(g.values)
        offs += g.n_nodes
    return CSRGraph(
        np.concatenate(ptrs).astype(np.int64),
        np.concatenate(cols).astype(np.int32),
        np.concatenate(vals).astype(np.float32),
        offs,
    )
