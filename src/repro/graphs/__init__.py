from .csr import CSRGraph, from_edges, block_diagonal
from .batching import (
    BucketPolicy,
    GraphBatch,
    TrafficProfile,
    assemble,
    bucketize,
    next_pow2,
)
from .datasets import TABLE4, DatasetSpec, load_dataset, all_datasets
from .partition import (
    Partition,
    PartitionPlan,
    PlanCandidate,
    extract_row_partitions,
    plan_partition,
)
