from .csr import CSRGraph, from_edges, block_diagonal
from .datasets import TABLE4, DatasetSpec, load_dataset, all_datasets
