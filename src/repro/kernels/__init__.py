"""Pallas TPU kernels for the performance-critical phases.

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jitted
wrapper, interpret=True off-TPU), ref.py (pure-jnp oracle).
"""
