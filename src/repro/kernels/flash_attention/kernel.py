"""Flash attention forward kernel — SP-Optimized applied to attention.

Attention is a multiphase GEMM-GEMM chain (QKᵀ -> softmax -> PV).  In the
paper's taxonomy the naive implementation is Seq (the S x S score matrix
round-trips through memory); flash attention is exactly the SP-Optimized
inter-phase dataflow: the score tile is produced, normalized online and
consumed by the PV matmul while still in VMEM/registers — element
granularity pipelining with matched tile sizes between the phases.

Grid: (batch*heads, q blocks).  The KV sequence is walked temporally
inside the kernel with the classic running-max/denominator recurrence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(
    q_ref,  # (1, bq, D)
    k_ref,  # (1, Sk, D)
    v_ref,  # (1, Sk, D)
    o_ref,  # (1, bq, D)
    *,
    block_k: int,
    sm_scale: float,
    causal: bool,
    seq_k: int,
):
    bq, d = q_ref.shape[1], q_ref.shape[2]
    q = q_ref[0].astype(jnp.float32) * sm_scale
    q_pos = pl.program_id(1) * bq + jax.lax.iota(jnp.int32, bq)

    n_kb = pl.cdiv(seq_k, block_k)

    def body(kb, carry):
        acc, m_prev, l_prev = carry
        k_blk = k_ref[0, pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        v_blk = v_ref[0, pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        s = q @ k_blk.T  # (bq, bk) — phase 1 tile, never leaves VMEM
        k_pos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = k_pos[None, :] < seq_k
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + p.sum(axis=1)
        acc = acc * alpha[:, None] + p @ v_blk  # phase 2 consumes in place
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_kb, body, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,  # (BH, Sq, D)
    k: jax.Array,  # (BH, Sk, D) — padded to a block_k multiple
    v: jax.Array,  # (BH, Sk, D)
    *,
    block_q: int = 128,
    block_k: int = 128,
    sm_scale: float | None = None,
    causal: bool = False,
    seq_k_real: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    bh, sq, d = q.shape
    _, sk, _ = k.shape
    if sm_scale is None:
        sm_scale = 1.0 / (d**0.5)
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    grid = (bh, pl.cdiv(sq, bq))
    kernel = functools.partial(
        _kernel, block_k=bk, sm_scale=sm_scale, causal=causal,
        seq_k=seq_k_real if seq_k_real is not None else sk,
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, sk, d), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((1, sk, d), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i: (h, i, 0)),
        interpret=interpret,
    )(q, k, v)
