"""Jitted wrapper for the flash attention kernel (with GQA support)."""
import functools

import jax
import jax.numpy as jnp

from ..common import cdiv, default_interpret
from .kernel import flash_attention_kernel as _raw


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, causal=False, block_q=128, block_k=128):
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Sk, D) with Hq % Hkv == 0."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    rep = hq // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hq, sk, d)
    vf = v.reshape(b * hq, sk, d)
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    sqp = cdiv(sq, bq) * bq
    skp = cdiv(sk, bk) * bk
    qf = jnp.pad(qf, ((0, 0), (0, sqp - sq), (0, 0)))
    kf = jnp.pad(kf, ((0, 0), (0, skp - sk), (0, 0)))
    vf = jnp.pad(vf, ((0, 0), (0, skp - sk), (0, 0)))
    out = _raw(
        qf, kf, vf,
        block_q=bq, block_k=bk,
        causal=causal, seq_k_real=sk, interpret=default_interpret(),
    )
    return out[:, :sq].reshape(b, hq, sq, d)
