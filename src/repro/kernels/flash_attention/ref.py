"""Pure-jnp oracle: materialized-scores attention (the paper's Seq)."""
import jax.numpy as jnp


def attention_ref(q, k, v, sm_scale=None, causal=False):
    bh, sq, d = q.shape
    _, sk, _ = k.shape
    if sm_scale is None:
        sm_scale = 1.0 / (d**0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * sm_scale
    if causal:
        qp = jnp.arange(sq)[:, None]
        kp = jnp.arange(sk)[None, :]
        s = jnp.where(kp <= qp, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


import jax  # noqa: E402  (used by jax.nn above)
