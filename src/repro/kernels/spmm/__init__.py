from .kernel import spmm_ell
from .ops import spmm
from .ref import spmm_ref
