"""Pure-jnp oracle for ELL SpMM."""
import jax.numpy as jnp


def spmm_ref(indices, weights, x):
    gathered = x[indices]  # (V_pad, D, F)
    return jnp.einsum("vd,vdf->vf", weights.astype(jnp.float32),
                      gathered.astype(jnp.float32)).astype(x.dtype)
