"""Jitted wrapper for the ELL SpMM aggregation kernel."""
import functools

import jax
import jax.numpy as jnp

from ..common import cdiv, default_interpret
from .kernel import spmm_ell as _raw


@functools.partial(jax.jit, static_argnames=("block_v", "block_f"))
def spmm(indices, weights, x, block_v=128, block_f=128):
    v_pad, d = indices.shape
    v, f = x.shape
    bv, bf = min(block_v, v_pad), min(block_f, f)
    vp = cdiv(v_pad, bv) * bv
    fp = cdiv(f, bf) * bf
    idx = jnp.pad(indices, ((0, vp - v_pad), (0, 0)))
    wts = jnp.pad(weights, ((0, vp - v_pad), (0, 0)))
    xp = jnp.pad(x, ((0, 0), (0, fp - f)))
    out = _raw(idx, wts, xp, block_v=bv, block_f=bf,
               interpret=default_interpret())
    return out[:v_pad, :f]
