"""Jitted wrapper for the ELL SpMM aggregation kernel."""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..common import cdiv, default_interpret
from .kernel import spmm_ell as _raw


@functools.partial(jax.jit, static_argnames=("block_v", "block_f"))
def spmm(indices, weights, x, block_v=128, block_f=128):
    v_pad, d = indices.shape
    v, f = x.shape
    bv, bf = min(block_v, v_pad), min(block_f, f)
    vp = cdiv(v_pad, bv) * bv
    fp = cdiv(f, bf) * bf
    idx = jnp.pad(indices, ((0, vp - v_pad), (0, 0)))
    wts = jnp.pad(weights, ((0, vp - v_pad), (0, 0)))
    xp = jnp.pad(x, ((0, 0), (0, fp - f)))
    out = _raw(idx, wts, xp, block_v=bv, block_f=bf,
               interpret=default_interpret())
    return out[:v_pad, :f]


def spmm_streamed(indices, weights, x, *, block_rows=4096,
                  block_v=128, block_f=128):
    """Row-streamed SpMM for feature tables too large to stage at once.

    Splits the ELL rows into ``block_rows`` slabs; each slab gathers only
    the feature rows it references (the halo gather) and runs :func:`spmm`
    on the compact table, so the per-call working set is bounded by the
    slab's closure instead of the full V x F matrix.  Rows are independent,
    so the concatenated result is bit-identical to
    ``spmm(indices, weights, x)``.
    """
    v_pad = indices.shape[0]
    if v_pad <= block_rows:
        return spmm(indices, weights, x, block_v=block_v, block_f=block_f)
    idx_h = np.asarray(indices)
    outs = []
    for s in range(0, v_pad, block_rows):
        blk = idx_h[s:s + block_rows]
        uniq, inv = np.unique(blk, return_inverse=True)
        outs.append(spmm(
            jnp.asarray(inv.reshape(blk.shape).astype(idx_h.dtype)),
            weights[s:s + block_rows],
            x[uniq],
            block_v=block_v,
            block_f=block_f,
        ))
    return jnp.concatenate(outs, axis=0)
