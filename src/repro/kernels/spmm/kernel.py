"""Aggregation-phase SpMM kernel over padded-ELL adjacency.

TPU adaptation of the paper's CSR aggregation (Sec. 2.1): rows are grouped
into blocks of ``block_v`` (the paper's T_V), neighbor lists are padded to
the ELL width D, and features are blocked by ``block_f`` (T_F).  The grid
is (row blocks x feature blocks) — both "spatial" in taxonomy terms — and
the neighbor dimension is walked temporally inside the kernel
(``V_s F_s N_t``), gathering one neighbor row slice per step and
accumulating in a VMEM register tile.

The padded slots (weight 0, index 0) are the lockstep/evil-row waste the
paper's simulator charges for — here they cost real gather steps, so the
kernel's cost structure matches the cost model's.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(idx_ref, wts_ref, x_ref, o_ref, *, ell_width: int, block_v: int):
    """o[b, :] = sum_d wts[b, d] * x[idx[b, d], :] for the row block."""

    def body(d, acc):
        # gather one neighbor row per lane-row; x_ref holds the full vertex
        # table for this feature block (graphs are sliced to fit on-chip,
        # paper Sec. 5.1.2)
        rows = idx_ref[:, d]  # (B,)
        gathered = x_ref[rows, :]  # (B, TF) dynamic row gather
        return acc + wts_ref[:, d][:, None] * gathered

    acc0 = jnp.zeros_like(o_ref)
    o_ref[...] = jax.lax.fori_loop(0, ell_width, body, acc0)


def spmm_ell(
    indices: jax.Array,  # (V_pad, D) int32
    weights: jax.Array,  # (V_pad, D) f32
    x: jax.Array,  # (V, F)
    *,
    block_v: int = 128,
    block_f: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """out[v] = sum_d weights[v, d] * x[indices[v, d]]  — (V_pad, F)."""
    v_pad, d = indices.shape
    v, f = x.shape
    bv, bf = min(block_v, v_pad), min(block_f, f)
    grid = (pl.cdiv(v_pad, bv), pl.cdiv(f, bf))
    kernel = functools.partial(_kernel, ell_width=d, block_v=bv)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((v_pad, f), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bv, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bv, d), lambda i, j: (i, 0)),
            pl.BlockSpec((v, bf), lambda i, j: (0, j)),  # full vertex table
        ],
        out_specs=pl.BlockSpec((bv, bf), lambda i, j: (i, j)),
        interpret=interpret,
    )(indices, weights, x)
