"""Shared kernel utilities."""
from __future__ import annotations

import jax


def default_interpret() -> bool:
    """Pallas kernels target TPU; on CPU hosts we validate with the
    interpreter (assignment: interpret=True executes the kernel body in
    Python for correctness)."""
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)
