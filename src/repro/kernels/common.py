"""Shared kernel utilities."""
from __future__ import annotations

import time

import jax
import numpy as np


def default_interpret() -> bool:
    """Pallas kernels target TPU; on CPU hosts we validate with the
    interpreter (assignment: interpret=True executes the kernel body in
    Python for correctness)."""
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def measure_wall(
    fn,
    *,
    warmup: int = 1,
    iters: int = 5,
    reduce: str = "median",
) -> float:
    """Wall-clock seconds of one ``fn()`` call, measured properly.

    The one timing helper shared by the calibration harness, the serving
    engine's measured re-ranking and the benchmark lanes, so warmup and
    aggregation rules cannot drift between them:

    - every call is followed by ``jax.block_until_ready`` on its result
      (async dispatch otherwise times the enqueue, not the kernel);
    - the first ``warmup`` calls are discarded (compilation/tracing and
      allocator warmup land there);
    - the remaining ``iters`` timings are reduced by ``median`` (robust
      to scheduler noise; default), ``min`` or ``mean``.
    """
    if reduce not in ("median", "min", "mean"):
        raise ValueError(
            f"reduce must be 'median', 'min' or 'mean', got {reduce!r}"
        )
    for _ in range(max(0, warmup)):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    agg = {"median": np.median, "min": np.min, "mean": np.mean}[reduce]
    return float(agg(ts))
