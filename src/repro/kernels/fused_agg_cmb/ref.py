"""Pure-jnp oracle for the fused SP-Optimized aggregation+combination."""
import jax.numpy as jnp


def fused_ref(indices, weights, x, w):
    gathered = x[indices]  # (V_pad, D, F)
    h = jnp.einsum("vd,vdf->vf", weights.astype(jnp.float32),
                   gathered.astype(jnp.float32))
    return jnp.dot(h, w.astype(jnp.float32),
                   preferred_element_type=jnp.float32).astype(x.dtype)
