"""Jitted wrapper for the fused SP-Optimized kernel.

``band_size`` is the Pallas row block (the schedule's T_V) and ``block_f``
the feature block (T_F): when given, the contraction dimension is walked in
``block_f`` chunks with a float32 accumulator over the output — the
schedule IR's column tiling lowered onto the kernel grid, so a mapper
choice like ``Vs(64)Fs(8)`` executes with exactly those block shapes.
"""
import functools

import jax
import jax.numpy as jnp

from ..common import cdiv, default_interpret
from .kernel import fused_agg_cmb_kernel as _raw


@functools.partial(jax.jit, static_argnames=("band_size", "block_f"))
def fused_agg_cmb(indices, weights, x, w, band_size=128, block_f=None):
    v_pad, d = indices.shape
    f, g = w.shape
    bv = min(band_size, v_pad)
    vp = cdiv(v_pad, bv) * bv
    idx = jnp.pad(indices, ((0, vp - v_pad), (0, 0)))
    wts = jnp.pad(weights, ((0, vp - v_pad), (0, 0)))
    interpret = default_interpret()
    if block_f is None or block_f >= f:
        out = _raw(idx, wts, x, w, block_v=bv, interpret=interpret)
        return out[:v_pad]

    bf = max(int(block_f), 1)
    fp = cdiv(f, bf) * bf
    xp = jnp.pad(x, ((0, 0), (0, fp - f)))
    wp = jnp.pad(w, ((0, fp - f), (0, 0)))

    def step(acc, fc):
        xc = jax.lax.dynamic_slice_in_dim(xp, fc * bf, bf, axis=1)
        wc = jax.lax.dynamic_slice_in_dim(wp, fc * bf, bf, axis=0)
        part = _raw(idx, wts, xc, wc, block_v=bv, interpret=interpret)
        return acc + part.astype(jnp.float32), None

    acc0 = jnp.zeros((vp, g), jnp.float32)
    acc, _ = jax.lax.scan(step, acc0, jnp.arange(fp // bf))
    return acc[:v_pad].astype(x.dtype)
