"""Jitted wrapper for the fused SP-Optimized kernel."""
import functools

import jax
import jax.numpy as jnp

from ..common import cdiv, default_interpret
from .kernel import fused_agg_cmb_kernel as _raw


@functools.partial(jax.jit, static_argnames=("band_size",))
def fused_agg_cmb(indices, weights, x, w, band_size=128):
    v_pad, d = indices.shape
    bv = min(band_size, v_pad)
    vp = cdiv(v_pad, bv) * bv
    idx = jnp.pad(indices, ((0, vp - v_pad), (0, 0)))
    wts = jnp.pad(weights, ((0, vp - v_pad), (0, 0)))
    out = _raw(idx, wts, x, w, block_v=bv, interpret=default_interpret())
    return out[:v_pad]
