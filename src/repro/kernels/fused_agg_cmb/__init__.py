from .kernel import fused_agg_cmb_kernel
from .ops import fused_agg_cmb
from .ref import fused_ref
