"""SP-Optimized fused aggregation+combination kernel.

The paper's SP-Optimized inter-phase dataflow (Sec. 4.2, Table 2 row 2):
the aggregated tile is kept *in the PEs* and consumed directly by the
combination phase — ``SP_AC({V_x F_x} N_t, {V_x F_x} G_t)`` with
T_V/T_F shared between phases and temporal reduction (T_N = 1).

TPU translation: one ``pallas_call`` whose grid walks row blocks (T_V).
Each step (a) gathers + accumulates the neighbor rows into a VMEM register
tile h (the aggregation), then (b) immediately feeds h into the MXU matmul
with the weight block (the combination).  The V x F intermediate never
exists in HBM — that is the entire point of SP-Optimized, and it is the
same trick flash-attention plays on the attention GEMM-GEMM chain.

The feature dimension is walked in ``block_f`` chunks with a float32 VMEM
accumulator for the output — the paper's partial-sum overhead appears here
as the accumulator revisits (kept on-chip because T_G = G fits VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(idx_ref, wts_ref, x_ref, w_ref, o_ref, *, ell_width: int):
    """out[b, :] = (sum_d wts[b,d] * x[idx[b,d], :]) @ w — fused."""

    def agg_body(d, acc):
        rows = idx_ref[:, d]
        gathered = x_ref[rows, :]  # (B, F)
        return acc + wts_ref[:, d][:, None] * gathered

    b = idx_ref.shape[0]
    f = x_ref.shape[1]
    h = jax.lax.fori_loop(
        0, ell_width, agg_body, jnp.zeros((b, f), jnp.float32)
    )  # the intermediate tile — lives only in VMEM
    o_ref[...] = jnp.dot(
        h, w_ref[...].astype(jnp.float32), preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def fused_agg_cmb_kernel(
    indices: jax.Array,  # (V_pad, D)
    weights: jax.Array,  # (V_pad, D)
    x: jax.Array,  # (V, F)
    w: jax.Array,  # (F, G)
    *,
    block_v: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Fused (A @ X) @ W with the intermediate pinned in VMEM."""
    v_pad, d = indices.shape
    v, f = x.shape
    f2, g = w.shape
    assert f == f2
    bv = min(block_v, v_pad)
    grid = (pl.cdiv(v_pad, bv),)
    kernel = functools.partial(_kernel, ell_width=d)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((v_pad, g), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bv, d), lambda i: (i, 0)),
            pl.BlockSpec((bv, d), lambda i: (i, 0)),
            pl.BlockSpec((v, f), lambda i: (0, 0)),  # vertex table resident
            pl.BlockSpec((f, g), lambda i: (0, 0)),  # weights resident
        ],
        out_specs=pl.BlockSpec((bv, g), lambda i: (i, 0)),
        interpret=interpret,
    )(indices, weights, x, w)
