"""Pure-jnp oracle for the dataflow-configurable GEMM."""
import jax.numpy as jnp


def gemm_ref(x, w):
    return jnp.dot(
        x.astype(jnp.float32), w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)
