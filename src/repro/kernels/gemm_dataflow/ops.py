"""Jitted public wrapper for the dataflow GEMM kernel.

Pads operands to block multiples (Pallas partial blocks are undefined in
the out-of-range region) and slices the result back.
"""
import functools

import jax
import jax.numpy as jnp

from ..common import cdiv, default_interpret
from .kernel import DATAFLOWS, gemm_dataflow as _raw


@functools.partial(
    jax.jit, static_argnames=("dataflow", "block_v", "block_g", "block_f")
)
def gemm(x, w, dataflow="output_stationary", block_v=128, block_g=128, block_f=128):
    v, f = x.shape
    _, g = w.shape
    bv, bg, bf = min(block_v, v), min(block_g, g), min(block_f, f)
    vp, gp, fp = cdiv(v, bv) * bv, cdiv(g, bg) * bg, cdiv(f, bf) * bf
    xp = jnp.pad(x, ((0, vp - v), (0, fp - f)))
    wp = jnp.pad(w, ((0, fp - f), (0, gp - g)))
    out = _raw(
        xp, wp,
        dataflow=dataflow,
        block_v=bv, block_g=bg, block_f=bf,
        interpret=default_interpret(),
    )
    return out[:v, :g]
