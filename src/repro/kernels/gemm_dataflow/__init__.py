from .kernel import DATAFLOWS, gemm_dataflow
from .ops import gemm
from .ref import gemm_ref
