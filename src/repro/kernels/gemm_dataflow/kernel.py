"""Dataflow-configurable tiled GEMM (the combination phase on the MXU).

This kernel makes the paper's Table 1 concrete on TPU: the three classic
GEMM dataflows differ in *which loop is the revisiting grid axis* and which
operand tile stays resident in VMEM across it:

  * ``output_stationary``  ({V_s G_s} F_t): grid = (V, G, F) with F minor —
    the (V, G) accumulator tile stays in VMEM while F-tiles of both inputs
    stream through (temporal reduction in the paper's terms).
  * ``weight_stationary``  ({G_s F_s} V_t): grid = (G, F, V) with V minor —
    the (F, G) weight tile is resident while V-tiles of the input stream
    under it; partial sums revisit the output tile (spatial reduction /
    psum traffic in the paper's accounting).
  * ``input_stationary``   ({V_s F_s} G_t): grid = (V, F, G) with G minor —
    the (V, F) input tile is resident while weight tiles stream.

Block shapes are the paper's tile sizes T_V/T_G/T_F; they must be MXU
aligned (multiples of 8x128 for f32) on real hardware — the wrapper rounds
up and masks instead of failing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DATAFLOWS = ("output_stationary", "weight_stationary", "input_stationary")


def _kernel(x_ref, w_ref, o_ref, *, n_red: int, red_axis: int):
    """One grid step: o += x @ w, zeroing o on the first reduction step."""
    k = pl.program_id(red_axis)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # accumulate in float32 regardless of input dtype (MXU practice);
    # the wrapper casts back after the last reduction step.
    acc = jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] += acc


def gemm_dataflow(
    x: jax.Array,  # (V, F)
    w: jax.Array,  # (F, G)
    *,
    dataflow: str = "output_stationary",
    block_v: int = 128,
    block_g: int = 128,
    block_f: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Tiled GEMM under one of the paper's combination dataflows."""
    if dataflow not in DATAFLOWS:
        raise ValueError(f"dataflow must be one of {DATAFLOWS}")
    v, f = x.shape
    f2, g = w.shape
    assert f == f2, (x.shape, w.shape)
    bv, bg, bf = min(block_v, v), min(block_g, g), min(block_f, f)
    nv, ng, nf = pl.cdiv(v, bv), pl.cdiv(g, bg), pl.cdiv(f, bf)

    # grid axes ordered outermost -> innermost; the innermost ("temporal")
    # axis determines which operand stays stationary across steps.
    if dataflow == "output_stationary":
        grid = (nv, ng, nf)
        ix = lambda i, j, k: (i, k)  # x[v, f]
        iw = lambda i, j, k: (k, j)  # w[f, g]
        io = lambda i, j, k: (i, j)  # o[v, g]  (same block across k: resident)
        red_axis = 2
    elif dataflow == "weight_stationary":
        grid = (ng, nf, nv)
        ix = lambda j, k, i: (i, k)
        iw = lambda j, k, i: (k, j)  # same block across i: resident
        io = lambda j, k, i: (i, j)
        red_axis = 1
    else:  # input_stationary
        grid = (nv, nf, ng)
        ix = lambda i, k, j: (i, k)  # same block across j: resident
        iw = lambda i, k, j: (k, j)
        io = lambda i, k, j: (i, j)
        red_axis = 1

    kernel = functools.partial(_kernel, n_red=nf, red_axis=red_axis)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((v, g), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bv, bf), ix),
            pl.BlockSpec((bf, bg), iw),
        ],
        out_specs=pl.BlockSpec((bv, bg), io),
        interpret=interpret,
    )(x, w)
    return out.astype(x.dtype)
