"""The paper's Parallel-Pipeline dataflow across two device groups.

Launches with 2 virtual devices: group 0 aggregates row band i while
group 1 runs the combination GEMM on band i-1, handing off via
collective_permute (Table 2 "NoC connecting Agg and Cmb units").

    PYTHONPATH=src python examples/gnn_parallel_pipeline.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")

import jax
import jax.numpy as jnp
import numpy as np

from repro.gnn import EllAdjacency, multiphase_matmul
from repro.graphs import load_dataset

g, spec = load_dataset("mutag")
adj = EllAdjacency.from_csr(g)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(g.n_nodes, spec.n_features)).astype(np.float32))
w = jnp.asarray(rng.normal(size=(spec.n_features, 16)).astype(np.float32))

mesh = jax.make_mesh((2,), ("phase",),
                     axis_types=(jax.sharding.AxisType.Auto,))
ref = multiphase_matmul(adj, x, w, policy="seq")
out = multiphase_matmul(adj, x, w, policy="pp", mesh=mesh)
err = float(jnp.abs(out - ref).max())
print(f"PP across 2 device groups: V={g.n_nodes} bands handed off via ppermute")
print(f"max |PP - Seq| = {err:.2e}  ({'OK' if err < 1e-3 else 'MISMATCH'})")
