"""Async continuous-batching serving over a 4-device mesh.

Launches with 4 virtual devices: an :class:`repro.runtime.AsyncEngine`
front-end admits each request, parks it in its padding bucket's batching
window (flush on 64 graphs or a 15 ms deadline, whichever first), and a
:class:`repro.runtime.BucketPlacer` routes distinct buckets to distinct
devices — each with its own executable cache, all on one shared program
store.  Per-request futures measure enqueue -> result latency.

    PYTHONPATH=src python examples/serve_async.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import time

import jax
import numpy as np

from repro.graphs import TABLE4
from repro.graphs.datasets import make_graph
from repro.runtime import AsyncEngine, InferenceEngine, Request

DIMS = [(32, 16), (16, 8)]  # 2-layer GCN

rng = np.random.default_rng(0)
names = ("mutag", "imdb-bin", "collab")
requests = []
for i in range(60):
    g = make_graph(TABLE4[names[i % 3]], rng)
    x = rng.normal(size=(g.n_nodes, 32)).astype(np.float32)
    requests.append(Request(graph=g, x=x, rid=i))

params = InferenceEngine(DIMS).init(jax.random.PRNGKey(0))

with AsyncEngine(DIMS, params, window_ms=15.0, readout="mean") as engine:
    engine.submit(requests)  # warm pass: compiles land off the clock

    t0 = time.perf_counter()
    futures = [engine.submit_async(r) for r in requests]  # arrival stream
    results = [f.result() for f in futures]
    wall = time.perf_counter() - t0
    stats = engine.stats()

ok = sum(r.ok for r in results)
lat_ms = np.array([r.latency_s for r in results]) * 1e3  # enqueue -> result
print(f"served {ok}/{len(results)} requests in {wall * 1e3:.0f} ms "
      f"({ok / wall:.0f} graphs/s) across {stats.n_devices} devices")
print(f"per-request p50 {np.percentile(lat_ms, 50):.1f} ms / "
      f"p99 {np.percentile(lat_ms, 99):.1f} ms "
      f"(windows: {stats.n_flushes_full} full, "
      f"{stats.n_flushes_deadline} deadline)")
print("bucket placement:")
for bucket, devs in stats.placement.items():
    print(f"  {bucket:>8} -> {', '.join(devs)}")
