"""End-to-end LM training driver (deliverable b).

CPU smoke (runs here):
    PYTHONPATH=src python examples/train_lm.py --smoke

The ~100M-parameter deliverable run (real hardware; identical code path):
    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 300 --batch 32 --seq 512 --checkpoint-dir /tmp/ckpt_135m

This wrapper demonstrates resumable training: it trains, simulates a
preemption, then resumes from the atomic checkpoint and verifies the loss
trajectory continues.
"""
import argparse
import shutil
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", default=True)
    args, _ = ap.parse_known_args()
    ckpt = "/tmp/repro_train_lm_example"
    shutil.rmtree(ckpt, ignore_errors=True)
    base = ["--arch", "smollm-135m", "--reduced", "--batch", "4", "--seq", "64",
            "--checkpoint-dir", ckpt, "--checkpoint-every", "10"]
    print("=== phase 1: train 20 steps, checkpointing every 10 ===")
    train_main(base + ["--steps", "20"])
    print("=== phase 2: 'preemption' -> resume to 40 steps from the checkpoint ===")
    train_main(base + ["--steps", "40"])
