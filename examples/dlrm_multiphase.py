"""Beyond GNNs: the taxonomy on a DLRM-style SpMM->GEMM chain (paper Sec. 6).

DLRM's embedding-bag lookup is an SpMM over a (batch x table) incidence
matrix; the MLP stack is dense GEMMs.  The same inter-phase question —
where does the pooled-embedding intermediate live? — is answered by the
same cost model.

    PYTHONPATH=src python examples/dlrm_multiphase.py
"""
import numpy as np

from repro.core import AcceleratorConfig, GNNLayerWorkload, named_skeleton, optimize_tiles

# batch of 4096 requests, each pooling ~40 of 1M embedding rows (F=64),
# followed by a 64->256 MLP layer: aggregation = pooled lookup (nnz = bag
# size), combination = the first MLP GEMM.
rng = np.random.default_rng(0)
bag_sizes = rng.poisson(40, size=4096).clip(1)
wl = GNNLayerWorkload(bag_sizes, f_in=64, g_out=256, name="dlrm-bag")

print("DLRM embedding-bag + MLP as a multiphase workload:")
for name in ("Seq-Nt", "SP-FsNt-Fs", "SP-VsNt-Vs", "PP-Nt-Vsh"):
    r = optimize_tiles(named_skeleton(name), wl, objective="edp",
                       pe_splits=(0.25, 0.5, 0.75))
    s = r.stats
    print(f"  {name:12s} cycles={s.cycles:9.0f} energy={s.energy_pj/1e6:7.1f}uJ "
          f"buffer={s.buffering_elems:8.0f}  {r.dataflow}")
print("\n-> the same SP-opt fusion that wins for GNN aggregation keeps the")
print("   pooled embeddings in-registers through the first MLP GEMM.")
