"""End-to-end GNN training with a mapper-chosen model-level schedule.

The model-level mapper (`search_model`) picks one dataflow *per layer* via
dynamic programming over inter-layer transition costs (paper Sec. 4.4: the
pipelining granularity of one layer's output constrains the next layer),
compares it against the best homogeneous shared-dataflow baseline, and the
resulting `ModelSchedule` is lowered to executable knobs that drive the
actual JAX execution of a 2-layer GCN trained on a node-classification
task.

    PYTHONPATH=src python examples/train_gnn_dataflow.py [--dataset cora]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import GNNLayerWorkload, search_model
from repro.gnn import EllAdjacency, GNNConfig, gnn_loss, init_gnn
from repro.gnn.model import make_node_classification_task
from repro.graphs import load_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cora")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--classes", type=int, default=8)
    args = ap.parse_args()

    g, spec = load_dataset(args.dataset)
    wls = [
        GNNLayerWorkload(g.nnz, spec.n_features, args.hidden, name="layer0"),
        GNNLayerWorkload(g.nnz, args.hidden, args.classes, name="layer1"),
    ]

    # 1. the model-level mapper picks a dataflow per layer (DP over
    #    transition costs) and the homogeneous baseline for comparison
    schedule = search_model(wls, objective="cycles")
    homo = schedule.shared_baseline  # homogeneous best, from the same sweep
    print(f"{args.dataset}: mapper-chosen model schedule")
    print(schedule)
    print(
        f"  heterogeneous: {schedule.stats.cycles:.0f} cycles "
        f"({schedule.stats.transition_cycles:.0f} in transitions, "
        f"{schedule.stats.n_relayouts} relayouts)"
    )
    print(f"  homogeneous best: {homo.stats.cycles:.0f} cycles "
          f"({homo.layers[0].dataflow.to_string()})")
    print(f"  exec policies: {[s.policy for s in schedule.lower()]}")

    # 2. train a 2-layer GCN under the lowered schedule
    cfg = GNNConfig(kind="gcn", f_in=spec.n_features, hidden=args.hidden,
                    n_classes=args.classes)
    adj = EllAdjacency.from_schedule(g, schedule)  # schedule-chosen ELL rows
    x, labels, mask = make_node_classification_task(
        g, spec.n_features, args.classes
    )
    params = init_gnn(cfg, jax.random.PRNGKey(0))

    @jax.jit
    def step(p):
        l, grads = jax.value_and_grad(
            lambda q: gnn_loss(cfg, q, adj, x, labels, mask, schedule=schedule)
        )(p)
        return l, jax.tree_util.tree_map(lambda a, b: a - 0.05 * b, p, grads)

    for i in range(args.steps):
        loss, params = step(params)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"  step {i:3d} loss {float(loss):.4f}")


if __name__ == "__main__":
    main()
