"""End-to-end GNN training with mapper-chosen dataflows.

For each dataset the mapping optimizer picks the best inter-phase dataflow
(paper Sec. 5.2 "flexibility to choose from SP and PP leads to optimal
dataflow"); the chosen policy then drives the actual JAX execution of a
2-layer GCN trained on a node-classification task.

    PYTHONPATH=src python examples/train_gnn_dataflow.py [--dataset cora]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import GNNLayerWorkload, search_dataflows
from repro.core.taxonomy import InterPhase
from repro.gnn import EllAdjacency, GNNConfig, gnn_loss, init_gnn
from repro.gnn.model import make_node_classification_task
from repro.graphs import load_dataset

POLICY_OF = {InterPhase.SEQ: "seq", InterPhase.SP: "sp_opt", InterPhase.PP: "sp_generic"}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cora")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--hidden", type=int, default=16)
    args = ap.parse_args()

    g, spec = load_dataset(args.dataset)
    wl = GNNLayerWorkload(g.nnz, spec.n_features, args.hidden, name=args.dataset)

    # 1. mapper chooses the dataflow for this workload
    best = search_dataflows(wl, objective="edp")[0]
    inter = best.dataflow.inter
    policy = POLICY_OF[inter]
    print(f"{args.dataset}: mapper chose {best.skeleton} -> {best.dataflow}")
    print(f"  simulated: cycles={best.stats.cycles:.0f} "
          f"energy={best.stats.energy_pj/1e6:.1f}uJ -> JAX policy {policy!r}")

    # 2. train a 2-layer GCN under that execution policy
    cfg = GNNConfig(kind="gcn", f_in=spec.n_features, hidden=args.hidden,
                    n_classes=8, policy=policy)
    adj = EllAdjacency.from_csr(g)
    x, labels, mask = make_node_classification_task(g, spec.n_features, 8)
    params = init_gnn(cfg, jax.random.PRNGKey(0))

    @jax.jit
    def step(p):
        l, grads = jax.value_and_grad(lambda q: gnn_loss(cfg, q, adj, x, labels, mask))(p)
        return l, jax.tree_util.tree_map(lambda a, b: a - 0.05 * b, p, grads)

    for i in range(args.steps):
        loss, params = step(params)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"  step {i:3d} loss {float(loss):.4f}")


if __name__ == "__main__":
    main()
