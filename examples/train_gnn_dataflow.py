"""End-to-end GNN training on a compiled Program.

`repro.compile()` runs the model-level mapper (one dataflow *per layer*
via dynamic programming over inter-layer transition costs — paper
Sec. 4.4), lowers the winning `ModelSchedule` to executable knobs, and
returns a frozen `Program` already bound to the graph; `program.train_step`
then drives the actual JAX training of a 2-layer GCN on a
node-classification task through the Program's shared executable cache:
the fused loss/grad/update step is traced **once** on the first step and
every later step — every later *epoch* — reuses the jitted executable
(the second epoch asserts a `repro.trace_count()` delta of exactly 0).

    PYTHONPATH=src python examples/train_gnn_dataflow.py [--dataset cora]
"""
import argparse

import jax

import repro
from repro.gnn import GNNConfig
from repro.gnn.model import make_node_classification_task
from repro.graphs import load_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cora")
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--steps", type=int, default=20, help="steps per epoch")
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--classes", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.05)
    args = ap.parse_args()

    g, spec = load_dataset(args.dataset)

    # 1. compile: mapper search (DP over transition costs) + lowering +
    #    graph binding, in one call
    cfg = GNNConfig(kind="gcn", f_in=spec.n_features, hidden=args.hidden,
                    n_classes=args.classes)
    program = repro.compile(cfg, graph=g, objective="cycles")
    homo = program.schedule.shared_baseline  # homogeneous best, same sweep
    print(f"{args.dataset}: compiled program")
    print(program)
    print(
        f"  heterogeneous: {program.stats.cycles:.0f} cycles "
        f"({program.stats.transition_cycles:.0f} in transitions, "
        f"{program.stats.n_relayouts} relayouts)"
    )
    print(f"  homogeneous best: {homo.stats.cycles:.0f} cycles "
          f"({homo.layers[0].dataflow.to_string()})")
    print(f"  exec policies: {[s.policy for s in program.specs]}")

    # 2. train a 2-layer GCN through the compiled program's own fused
    #    step — the jitted executable lives in the Program's exec cache
    x, labels, mask = make_node_classification_task(
        g, spec.n_features, args.classes
    )
    params = program.init(jax.random.PRNGKey(0))

    for epoch in range(args.epochs):
        traces_before = repro.trace_count()
        for i in range(args.steps):
            loss, params = program.train_step(
                params, x, labels, mask, lr=args.lr
            )
            if i % 10 == 0 or i == args.steps - 1:
                print(f"  epoch {epoch} step {i:3d} loss {float(loss):.4f}")
        delta = repro.trace_count() - traces_before
        print(f"  epoch {epoch}: {delta} new XLA traces")
        if epoch > 0:
            # the executable cache must make warm epochs trace-free
            assert delta == 0, (
                f"epoch {epoch} took {delta} new traces; the train-step "
                f"executable should have been cached after epoch 0"
            )


if __name__ == "__main__":
    main()
