"""Batched serving example (deliverable b): prefill + KV-cache decode.

    PYTHONPATH=src python examples/serve_lm.py [--arch tinyllama-1.1b]

Uses the reduced config on CPU; the identical serve path is what the
decode_32k / long_500k dry-run cells lower for the production mesh.
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.exit(main(["--reduced", "--batch", "4", "--prompt-len", "16",
                   "--new-tokens", "12", *sys.argv[1:]]))
