"""Design-space exploration: sweep the Table-5 dataflows over every
Table-4 dataset and print the full comparison (the paper's Figs 9-10 as
one table), plus the mapper's per-dataset winner.

    PYTHONPATH=src python examples/dataflow_explorer.py
"""
from repro.core import (
    GNNLayerWorkload,
    TABLE5_NAMES,
    TileStats,
    named_skeleton,
    optimize_tiles,
)
from repro.graphs import TABLE4, load_dataset

G_HIDDEN = 16

print(f"{'dataset':12s} {'cat':4s} | " + " ".join(f"{n:>12s}" for n in TABLE5_NAMES))
for name in TABLE4:
    g, spec = load_dataset(name)
    wl = GNNLayerWorkload(g.nnz, spec.n_features, G_HIDDEN, name=name)
    ts = TileStats(wl.nnz)  # tile ladder shared by all skeleton searches
    base = None
    cells = []
    best = (None, float("inf"))
    for sk in TABLE5_NAMES:
        try:
            r = optimize_tiles(named_skeleton(sk), wl, objective="cycles",
                               pe_splits=(0.25, 0.5, 0.75), tile_stats=ts)
            c = r.stats.cycles
            base = base or c
            cells.append(f"{c / base:12.2f}")
            if c < best[1]:
                best = (sk, c)
        except Exception:
            cells.append(f"{'—':>12s}")
    print(f"{name:12s} {spec.category:4s} | " + " ".join(cells) + f"   best={best[0]}")
