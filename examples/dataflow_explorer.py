"""Design-space exploration: sweep the Table-5 dataflows over every
Table-4 dataset and print the full comparison (the paper's Figs 9-10 as
one table), then package each dataset's winner into a compiled Program
via `repro.compile(..., schedule=...)` — the sweep is reused, not re-run.

    PYTHONPATH=src python examples/dataflow_explorer.py
"""
import repro
from repro.core import (
    GNNLayerWorkload,
    ModelSchedule,
    TABLE5_NAMES,
    TileStats,
    named_skeleton,
    optimize_tiles,
)
from repro.graphs import TABLE4, load_dataset

G_HIDDEN = 16

print(f"{'dataset':12s} {'cat':4s} | " + " ".join(f"{n:>12s}" for n in TABLE5_NAMES))
programs = {}
for name in TABLE4:
    g, spec = load_dataset(name)
    wl = GNNLayerWorkload(g.nnz, spec.n_features, G_HIDDEN, name=name)
    ts = TileStats(wl.nnz)  # tile ladder shared by all skeleton searches
    base = None
    cells = []
    best = (None, float("inf"))
    for sk in TABLE5_NAMES:
        try:
            r = optimize_tiles(named_skeleton(sk), wl, objective="cycles",
                               pe_splits=(0.25, 0.5, 0.75), tile_stats=ts)
            c = r.stats.cycles
            base = base or c
            cells.append(f"{c / base:12.2f}")
            if c < best[1]:
                best = (r.dataflow, c)
        except Exception:
            cells.append(f"{'—':>12s}")
    # package the sweep's winner into a Program: compile with an explicit
    # schedule skips the search and just prices + lowers it
    schedule = ModelSchedule.from_dataflows(
        [best[0]], [(wl.f_in, wl.g_out)], v=wl.v, names=[name]
    )
    programs[name] = repro.compile([wl], schedule=schedule)
    print(f"{name:12s} {spec.category:4s} | " + " ".join(cells))

print("\ncompiled winners (repro.compile over each sweep's best dataflow):")
for name, prog in programs.items():
    layer = prog.schedule.layers[0]
    print(f"  {name:12s} {prog.stats.cycles:12.0f} cycles "
          f"{prog.stats.energy_pj / 1e6:8.1f} uJ  {layer.dataflow.to_string()}")
