"""Quickstart: the paper in 40 lines.

Describe a GNN dataflow with the taxonomy, simulate it on the spatial
accelerator model, let the mapper pick the best dataflow per workload, and
run the numerically-identical JAX execution policies.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import (
    AcceleratorConfig,
    GNNLayerWorkload,
    ModelSchedule,
    named_dataflow,
    named_skeleton,
    optimize_tiles,
    search_dataflows,
    search_model,
    simulate,
)
from repro.gnn import EllAdjacency, multiphase_matmul
from repro.graphs import load_dataset

# --- 1. a workload: one GCN layer over Cora --------------------------------
graph, spec = load_dataset("cora")
wl = GNNLayerWorkload(graph.nnz, f_in=spec.n_features, g_out=16, name="cora")
print(f"cora: V={wl.v} E={wl.e} F={wl.f_in} max_deg={graph.max_degree}")

# --- 2. describe + simulate one dataflow (HyGCN's, Table 2 row 5) ----------
hygcn = named_dataflow("HyGCN", T_F_AGG=32, T_V_CMB=8, T_G=16, T_F_CMB=2)
stats = simulate(hygcn, wl, AcceleratorConfig())
print(f"\nHyGCN dataflow {hygcn}\n  cycles={stats.cycles:.0f} "
      f"energy={stats.energy_pj/1e6:.1f}uJ util={stats.pe_utilization:.2f}")

# --- 3. the mapper searches tile sizes + dataflows (paper Sec. 6) ----------
# the whole Table-5 sweep runs on the batched, cache-backed engine; ask for
# top_k > 1 to see near-optimal alternatives per skeleton
ranked = search_dataflows(wl, objective="edp", top_k=2)
print("\nmapper ranking (EDP):")
for r in ranked[:4]:
    print(f"  {r.skeleton:12s} cycles={r.stats.cycles:9.0f} "
          f"E={r.stats.energy_pj/1e6:8.1f}uJ  {r.dataflow}")

# --- 4. model-level search: one dataflow per layer, transitions priced -----
# the 2-layer Kipf GCN shrinks 1433 -> 16 -> 8, so the optimal dataflow
# changes per layer; the DP also charges re-laying-out the intermediate
# when consecutive layers walk it differently
wls = [
    GNNLayerWorkload(graph.nnz, spec.n_features, 16, name="layer0"),
    GNNLayerWorkload(graph.nnz, 16, 8, name="layer1"),
]
schedule = search_model(wls, objective="cycles")
homo = schedule.shared_baseline  # best shared dataflow, from the same sweep
print(f"\nmodel-level schedule ({schedule.stats.cycles:.0f} cycles vs "
      f"{homo.stats.cycles:.0f} homogeneous):")
print(schedule)
assert ModelSchedule.from_json(schedule.to_json()).dataflows == schedule.dataflows

# --- 5. execute the same layer in JAX under each inter-phase policy --------
adj = EllAdjacency.from_csr(graph)
rng = np.random.default_rng(0)
x = rng.normal(size=(graph.n_nodes, spec.n_features)).astype(np.float32)
w = rng.normal(size=(spec.n_features, 16)).astype(np.float32)
outs = {
    p: multiphase_matmul(adj, jax.numpy.asarray(x), jax.numpy.asarray(w), policy=p)
    for p in ("seq", "sp_generic", "sp_opt")
}
ref = np.asarray(outs["seq"])
for p, o in outs.items():
    print(f"policy {p:10s} max|Δ| vs seq = {np.abs(np.asarray(o) - ref).max():.2e}")
