"""Quickstart: the paper in 40 lines.

Describe a GNN dataflow with the taxonomy, simulate it on the spatial
accelerator model, then let `repro.compile()` do the whole pipeline —
mapper search, lowering to executable knobs, and packaging into a frozen,
cacheable Program — and execute it in JAX.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile
from pathlib import Path

import jax
import numpy as np

import repro
from repro.core import (
    AcceleratorConfig,
    GNNLayerWorkload,
    named_dataflow,
    search_dataflows,
    simulate,
)
from repro.gnn import EllAdjacency, multiphase_matmul
from repro.graphs import load_dataset

# --- 1. a workload: one GCN layer over Cora --------------------------------
graph, spec = load_dataset("cora")
wl = GNNLayerWorkload(graph.nnz, f_in=spec.n_features, g_out=16, name="cora")
print(f"cora: V={wl.v} E={wl.e} F={wl.f_in} max_deg={graph.max_degree}")

# --- 2. describe + simulate one dataflow (HyGCN's, Table 2 row 5) ----------
hygcn = named_dataflow("HyGCN", T_F_AGG=32, T_V_CMB=8, T_G=16, T_F_CMB=2)
stats = simulate(hygcn, wl, AcceleratorConfig())
print(f"\nHyGCN dataflow {hygcn}\n  cycles={stats.cycles:.0f} "
      f"energy={stats.energy_pj/1e6:.1f}uJ util={stats.pe_utilization:.2f}")

# --- 3. the mapper searches tile sizes + dataflows (paper Sec. 6) ----------
# the whole Table-5 sweep runs on the batched, cache-backed engine; ask for
# top_k > 1 to see near-optimal alternatives per skeleton
ranked = search_dataflows(wl, objective="edp", top_k=2)
print("\nmapper ranking (EDP):")
for r in ranked[:4]:
    print(f"  {r.skeleton:12s} cycles={r.stats.cycles:9.0f} "
          f"E={r.stats.energy_pj/1e6:8.1f}uJ  {r.dataflow}")

# --- 4. repro.compile(): search -> lower -> execute in one call ------------
# the 2-layer Kipf GCN shrinks 1433 -> 16 -> 8, so the optimal dataflow
# changes per layer; compile runs the model-level DP (transition costs
# priced), lowers the winning schedule, and binds the graph
wls = [
    GNNLayerWorkload(graph.nnz, spec.n_features, 16, name="layer0"),
    GNNLayerWorkload(graph.nnz, 16, 8, name="layer1"),
]
program = repro.compile(wls, graph=graph, objective="cycles")
homo = program.schedule.shared_baseline  # best shared dataflow, same sweep
print(f"\ncompiled program ({program.stats.cycles:.0f} cycles vs "
      f"{homo.stats.cycles:.0f} homogeneous):")
print(program)

# the Program is a cacheable artifact: serving paths save it once and skip
# the mapper forever after
with tempfile.TemporaryDirectory() as td:
    path = program.save(Path(td) / "cora.program.json")
    reloaded = repro.Program.load(path, graph=graph)
    assert reloaded.schedule == program.schedule
    assert reloaded.stats == program.stats
    print(f"saved + reloaded artifact: {path.name} "
          f"({path.stat().st_size} bytes, byte-stable JSON)")

# --- 5. execute the compiled program, and each policy by hand --------------
params = program.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
x = jax.numpy.asarray(
    rng.normal(size=(graph.n_nodes, spec.n_features)).astype(np.float32))
logits = program.run(params, x)
print(f"\nprogram.run -> logits {logits.shape}")

adj = EllAdjacency.from_csr(graph)
w = jax.numpy.asarray(
    rng.normal(size=(spec.n_features, 16)).astype(np.float32))
outs = {
    p: multiphase_matmul(adj, x, w, policy=p)
    for p in ("seq", "sp_generic", "sp_opt")
}
ref = np.asarray(outs["seq"])
for p, o in outs.items():
    print(f"policy {p:10s} max|Δ| vs seq = {np.abs(np.asarray(o) - ref).max():.2e}")
