"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture is instantiated at a REDUCED config of the same
family and runs one forward + one train step on CPU, asserting output
shapes and the absence of NaNs.  Decode parity against the full forward is
asserted for every family (cache/state correctness).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, applicable, get_config
from repro.models import (
    count_params,
    decode_step,
    forward,
    init_cache,
    init_params,
    lm_loss,
    make_inputs,
)
from repro.optim import adamw


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    x = make_inputs(cfg, 2, 16)
    logits, aux = forward(cfg, params, x)
    assert logits.shape == (2, 16, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    init_opt, update = adamw(lr=1e-3)
    opt = init_opt(params)
    batch = {
        "inputs": make_inputs(cfg, 2, 16),
        "labels": jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, (2, 16)), jnp.int32
        ),
    }

    @jax.jit
    def step(params, opt):
        loss, grads = jax.value_and_grad(lambda p: lm_loss(cfg, p, batch))(params)
        params, opt = update(grads, opt, params)
        return loss, params, opt

    l0, params, opt = step(params, opt)
    l1, params, opt = step(params, opt)
    assert np.isfinite(float(l0)) and np.isfinite(float(l1))
    for leaf in jax.tree_util.tree_leaves(params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", ["granite-8b", "granite-moe-1b-a400m",
                                  "recurrentgemma-2b", "xlstm-1.3b",
                                  "musicgen-large"])
def test_decode_matches_forward(arch):
    """Cache/state correctness per family (dense, MoE, hybrid, ssm, audio)."""
    cfg = get_config(arch).reduced(attn_chunk=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    x = make_inputs(cfg, 2, 12)
    full, _ = forward(cfg, params, x)
    cache = init_cache(cfg, 2, 12)

    @jax.jit
    def dstep(cache, tok, t):
        return decode_step(cfg, params, cache, tok, t)

    tol = 5e-4 if arch == "xlstm-1.3b" else 5e-5
    for t in range(12):
        lg, cache = dstep(cache, x[:, t : t + 1], t)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32),
            np.asarray(full[:, t], np.float32),
            rtol=1e-2,
            atol=tol * 100,
            err_msg=f"{arch} t={t}",
        )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_shape_applicability(arch):
    """long_500k runs only for sub-quadratic archs (assignment rule)."""
    cfg = get_config(arch)
    long_ok = applicable(cfg, SHAPES["long_500k"])
    assert long_ok == (arch in ("recurrentgemma-2b", "xlstm-1.3b"))
    for s in ("train_4k", "prefill_32k", "decode_32k"):
        assert applicable(cfg, SHAPES[s])


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full (unreduced) configs carry the exact assigned dimensions."""
    spec = {
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab)
    assert got == spec


def test_param_counts_in_expected_range():
    """Analytic param counts should land near the named model sizes."""
    expect = {
        "granite-8b": (6e9, 10e9),
        "tinyllama-1.1b": (0.9e9, 1.4e9),
        "smollm-135m": (0.1e9, 0.2e9),
        "llava-next-34b": (28e9, 40e9),
        "xlstm-1.3b": (0.9e9, 1.8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)
