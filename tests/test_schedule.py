"""Model-level schedule IR tests: lowering, JSON round-trips, transition
costing, ModelStats accounting, and `search_model` (DP vs brute force,
heterogeneous vs homogeneous, lowered end-to-end execution)."""
import itertools

import numpy as np
import pytest

from repro.core import (
    AcceleratorConfig,
    GNNLayerWorkload,
    LayerSchedule,
    ModelSchedule,
    named_dataflow,
    parse_dataflow,
    search_model,
    simulate,
    simulate_model,
    transition_cost,
)
from repro.core.mapper import _dp_assign, search_dataflows
from repro.core.schedule import default_dataflow, policy_of, transition_spec

HW = AcceleratorConfig()
RNG = np.random.default_rng(7)


def chain_workloads(v=400, widths=(48, 16, 8), max_deg=10, rng=RNG):
    nnz = rng.integers(1, max_deg + 1, size=v)
    return [
        GNNLayerWorkload(nnz, widths[i], widths[i + 1], name=f"l{i}")
        for i in range(len(widths) - 1)
    ]


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


class TestLowering:
    @pytest.mark.parametrize("policy", ["seq", "sp_generic", "sp_opt", "pp"])
    @pytest.mark.parametrize("order", ["AC", "CA"])
    def test_default_dataflow_round_trips_policy(self, policy, order):
        df = default_dataflow(policy, order=order, band_size=64)
        df.validate()
        assert policy_of(df) == policy
        spec = LayerSchedule(df, 128, 16).lower()
        assert spec.policy == policy
        assert spec.order == order
        assert spec.band_size == 64
        assert spec.ell_block_rows == 64

    def test_lower_uses_row_tiles_as_band(self):
        df = named_dataflow("HyGCN", T_F_AGG=16, T_V_CMB=32, T_G=4)
        spec = LayerSchedule(df, 64, 16).lower()
        assert spec.policy == "pp"
        assert spec.band_size == 32  # max of the two phases' V tiles
        assert spec.block_f == 16

    def test_lower_sp_opt_detected(self):
        df = named_dataflow("EnGN", T_V_AGG=16, T_F_AGG=8, T_V_CMB=16, T_F_CMB=8)
        spec = LayerSchedule(df, 64, 16).lower(use_pallas=True)
        assert spec.policy == "sp_opt"
        assert spec.use_pallas

    def test_temporal_rows_fall_back_to_default_band(self):
        df = named_dataflow("Seq-Nt")  # all tiles 1
        spec = LayerSchedule(df, 64, 16).lower(default_band=256)
        assert spec.band_size == 256


# ---------------------------------------------------------------------------
# ModelSchedule construction + serialization
# ---------------------------------------------------------------------------


class TestModelSchedule:
    def test_chain_validation(self):
        df = default_dataflow("seq")
        with pytest.raises(ValueError, match="f_in=32"):
            ModelSchedule.from_dataflows([df, df], [(128, 16), (32, 8)])

    def test_transition_count_validation(self):
        df = default_dataflow("seq")
        with pytest.raises(ValueError, match="transitions"):
            ModelSchedule((LayerSchedule(df, 8, 8), LayerSchedule(df, 8, 8)))

    def test_json_round_trip(self):
        dfs = [
            named_dataflow("Seq-Nt", T_V_AGG=8, T_F_AGG=16, T_V_CMB=8, T_G=8),
            named_dataflow("AWB-GCN", T_F_AGG=8, T_V_AGG=16, T_V_CMB=16),
            named_dataflow("EnGN", T_V_AGG=8, T_F_AGG=8, T_V_CMB=8, T_F_CMB=8),
        ]
        ms = ModelSchedule.from_dataflows(
            dfs, [(128, 16), (16, 16), (16, 8)], v=1000
        )
        ms2 = ModelSchedule.from_json(ms.to_json())
        assert ms2 == ms
        assert ms2.dataflows == dfs
        assert [t.relayout for t in ms2.transitions] == [
            t.relayout for t in ms.transitions
        ]

    def test_str_marks_relayouts(self):
        dfs = [
            named_dataflow("Seq-Nt", T_V_AGG=8, T_F_AGG=16, T_V_CMB=8, T_G=8),
            named_dataflow("AWB-GCN", T_F_AGG=8, T_V_AGG=16, T_V_CMB=16),
        ]
        ms = ModelSchedule.from_dataflows(dfs, [(128, 16), (16, 8)], v=100)
        assert ms.n_relayouts == 1
        assert "relayout" in str(ms)


# ---------------------------------------------------------------------------
# Transition costing
# ---------------------------------------------------------------------------


class TestTransitionCost:
    seq = named_dataflow("Seq-Nt", T_V_AGG=8, T_F_AGG=16, T_V_CMB=8, T_G=8)
    awb = named_dataflow("AWB-GCN", T_F_AGG=8, T_V_AGG=16, T_V_CMB=16)

    def test_same_dataflow_is_free(self):
        t = transition_cost(self.seq, self.seq, v=1000, f=16, hw=HW)
        assert not t.relayout
        assert t.cycles == 0.0 and t.energy_pj == 0.0

    def test_walk_mismatch_charges_relayout(self):
        t = transition_cost(self.awb, self.seq, v=1000, f=16, hw=HW)
        assert t.relayout
        assert t.gb_accesses == 2 * 1000 * 16
        assert t.cycles == pytest.approx(2 * 1000 * 16 / HW.gb_bandwidth)
        assert t.energy_pj == pytest.approx(2 * 1000 * 16 * HW.gb_energy_pj)

    def test_dram_priced_when_gb_overflows(self):
        small = AcceleratorConfig(gb_capacity_bytes=1024)
        t = transition_cost(self.awb, self.seq, v=1000, f=16, hw=small)
        assert t.energy_pj == pytest.approx(2 * 1000 * 16 * small.dram_energy_pj)

    def test_spec_matches_classifier(self):
        spec = transition_spec(self.awb, self.seq, v=10, f=4)
        assert spec.producer_walk == "column"
        assert spec.consumer_walk == "row"
        assert spec.producer_granularity == "column"
        assert spec.elements == 40


# ---------------------------------------------------------------------------
# simulate_model / ModelStats
# ---------------------------------------------------------------------------


class TestSimulateModel:
    def test_totals_are_sums(self):
        wls = chain_workloads()
        dfs = [
            named_dataflow("Seq-Nt", T_V_AGG=8, T_F_AGG=16, T_V_CMB=8, T_G=8),
            named_dataflow("AWB-GCN", T_F_AGG=8, T_V_AGG=16, T_V_CMB=16),
        ]
        ms = simulate_model(dfs, wls, HW)
        per_layer = [simulate(d, w, HW) for d, w in zip(dfs, wls)]
        assert ms.layer_cycles == pytest.approx(sum(s.cycles for s in per_layer))
        assert ms.cycles == pytest.approx(
            ms.layer_cycles + ms.transition_cycles
        )
        assert ms.energy_pj == pytest.approx(
            ms.layer_energy_pj + ms.transition_energy_pj
        )
        assert len(ms.transitions) == 1

    def test_shared_dataflow_broadcasts(self):
        wls = chain_workloads(widths=(16, 16, 16))
        df = named_dataflow("EnGN", T_V_AGG=8, T_F_AGG=8, T_V_CMB=8, T_F_CMB=8)
        ms = simulate_model([df], wls, HW)
        assert len(ms.layers) == 2
        assert ms.n_relayouts == 0  # identical dataflows never re-lay-out

    def test_bad_count_rejected_naming_both_lengths(self):
        wls = chain_workloads(widths=(16, 16, 16, 16))
        df = named_dataflow("Seq-Nt")
        with pytest.raises(ValueError, match=r"2 dataflows for 3 layer"):
            simulate_model([df, df], wls, HW)

    def test_unchained_workloads_rejected(self):
        nnz = RNG.integers(1, 5, size=64)
        wls = [
            GNNLayerWorkload(nnz, 32, 16, name="a"),
            GNNLayerWorkload(nnz, 8, 4, name="b"),
        ]
        with pytest.raises(ValueError, match="f_in=8"):
            simulate_model([named_dataflow("Seq-Nt")], wls, HW)


# ---------------------------------------------------------------------------
# search_model
# ---------------------------------------------------------------------------


class TestSearchModel:
    def test_dp_matches_brute_force(self):
        wls = chain_workloads(v=300, widths=(32, 16, 8))
        layer_cands = [
            search_dataflows(wl, HW, objective="cycles", top_k=2) for wl in wls
        ]
        layer_dfs = [[r.dataflow for r in c] for c in layer_cands]
        layer_obj = [
            np.array([r.stats.cycles for r in c]) for c in layer_cands
        ]
        idx, total = _dp_assign(layer_dfs, layer_obj, wls, HW, "cycles")
        # brute force over the exact same candidate lists
        best = np.inf
        for pick in itertools.product(*[range(len(d)) for d in layer_dfs]):
            t = sum(layer_obj[i][j] for i, j in enumerate(pick))
            for i in range(1, len(pick)):
                t += transition_cost(
                    layer_dfs[i - 1][pick[i - 1]],
                    layer_dfs[i][pick[i]],
                    v=wls[i].v,
                    f=wls[i].f_in,
                    hw=HW,
                ).cycles
            best = min(best, t)
        assert total == pytest.approx(best)
        assert len(idx) == len(wls)

    def test_heterogeneous_never_worse_than_homogeneous(self):
        # the 3-layer Kipf GCN shape: feature widths shrink 128 -> 16 -> 8
        wls = chain_workloads(v=800, widths=(128, 16, 16, 8))
        het = search_model(wls, HW, objective="cycles")
        homo = het.shared_baseline  # attached by the same sweep
        assert homo is not None
        assert het.stats.cycles <= homo.stats.cycles * (1 + 1e-9)
        assert len({l.dataflow for l in homo.layers}) == 1
        assert het.n_layers == 3
        # explicit shared mode returns the same baseline (no second sweep
        # needed, but the API still works)
        explicit = search_model(
            wls, HW, objective="cycles", shared_dataflow=True
        )
        assert explicit.dataflows == homo.dataflows
        assert explicit.stats.cycles == pytest.approx(homo.stats.cycles)
        assert explicit.shared_baseline is None

    def test_stats_attached_and_consistent(self):
        wls = chain_workloads(v=256, widths=(32, 16, 8))
        ms = search_model(wls, HW, objective="cycles")
        assert ms.stats is not None
        recomputed = simulate_model(ms.dataflows, wls, HW)
        assert ms.stats.cycles == pytest.approx(recomputed.cycles)
        for l in ms.layers:
            assert l.stats is not None and l.stats.cycles > 0

    def test_energy_objective(self):
        wls = chain_workloads(v=256, widths=(32, 16, 8))
        het = search_model(wls, HW, objective="energy")
        assert het.stats.energy_pj <= het.shared_baseline.stats.energy_pj * (
            1 + 1e-9
        )

    def test_non_additive_objective_rejected(self):
        wls = chain_workloads(v=64, widths=(16, 8))
        with pytest.raises(ValueError, match="additive"):
            search_model(wls, HW, objective="edp")

    def test_searched_schedule_json_round_trips(self):
        wls = chain_workloads(v=256, widths=(32, 16, 8))
        ms = search_model(wls, HW, objective="cycles")
        ms2 = ModelSchedule.from_json(ms.to_json())
        assert ms2.dataflows == ms.dataflows
        assert [t.relayout for t in ms2.transitions] == [
            t.relayout for t in ms.transitions
        ]


# ---------------------------------------------------------------------------
# search -> lower -> execute, against the dense reference
# ---------------------------------------------------------------------------


class TestEndToEndExecution:
    def test_lowered_schedule_matches_dense_reference(self):
        jax = pytest.importorskip("jax")
        import jax.numpy as jnp

        from repro.gnn import EllAdjacency, GNNConfig, gnn_forward, init_gnn
        from repro.graphs import from_edges

        rng = np.random.default_rng(3)
        v = 173  # v_pad % band_size != 0 for every pow-2 band
        g = from_edges(v, rng.integers(0, v, 500), rng.integers(0, v, 500))
        wls = [
            GNNLayerWorkload(g.nnz, 24, 16, name="l0"),
            GNNLayerWorkload(g.nnz, 16, 8, name="l1"),
        ]
        ms = search_model(wls, HW, objective="cycles", top_k=2)

        cfg = GNNConfig(kind="gcn", f_in=24, hidden=16, n_classes=8)
        params = init_gnn(cfg, jax.random.PRNGKey(0))
        # adjacency padded to the schedule's lowered ELL block rows
        adj = EllAdjacency.from_schedule(g, ms)
        assert adj.v_pad % ms.ell_block_rows == 0
        x = jnp.asarray(rng.normal(size=(v, 24)).astype(np.float32))

        logits = gnn_forward(cfg, params, adj, x, schedule=ms)

        # dense reference: relu(A X W0 + b0) -> A H W1 + b1
        dense = jnp.asarray(g.to_dense())
        h = jax.nn.relu(dense @ x @ params[0]["w"] + params[0]["b"])
        ref = jax.nn.relu(dense @ h @ params[1]["w"] + params[1]["b"])
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(ref), rtol=2e-4, atol=2e-4
        )

    def test_shim_equals_explicit_default_schedule(self):
        jax = pytest.importorskip("jax")
        import jax.numpy as jnp

        from repro.gnn import EllAdjacency, GNNConfig, gnn_forward, init_gnn
        from repro.graphs import from_edges

        rng = np.random.default_rng(5)
        g = from_edges(60, rng.integers(0, 60, 150), rng.integers(0, 60, 150))
        cfg = GNNConfig(kind="gcn", f_in=12, hidden=8, n_classes=4,
                        policy="sp_generic", order="CA", band_size=16)
        params = init_gnn(cfg, jax.random.PRNGKey(1))
        adj = EllAdjacency.from_csr(g)
        x = jnp.asarray(rng.normal(size=(60, 12)).astype(np.float32))
        a = gnn_forward(cfg, params, adj, x)
        b = gnn_forward(cfg, params, adj, x, schedule=cfg.default_schedule())
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
