"""Beyond-capacity execution tests (PR 9).

Covers the four layers of the partitioned-serving stack:

- the simulator's additive communication term (``partition_comm_cost``)
  and the staged-intermediate footprint used by admission,
- the spill-model-driven planner (``plan_partition``) and the halo
  closure extractor it drives,
- bit-exactness of the ``row_stream`` lane: stitching per-partition
  ``[:n_own]`` slices reproduces the whole-graph forward **bitwise**
  (``np.array_equal``) across policies, orders, and model kinds — rows
  are independent reductions, so per-row results don't depend on which
  other rows share the launch,
- the serving integration: the sync engine's partitioned lane and the
  async front-end's diversion of oversized arrivals.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import GNNLayerWorkload
from repro.core.hw import DEFAULT_ACCEL
from repro.core.schedule import ExecSpec, ModelSchedule
from repro.core.simulator import (
    PARTITION_KINDS,
    intermediate_footprint_bytes,
    partition_comm_cost,
)
from repro.gnn.layers import EllAdjacency, init_layer
from repro.gnn.model import forward_layers
from repro.graphs import BucketPolicy, from_edges
from repro.graphs.partition import (
    extract_row_partitions,
    feature_chunk_forward,
    plan_partition,
    row_stream_forward,
)
from repro.runtime.engine import InferenceEngine, Request
from repro.runtime.scheduler import AsyncEngine

DIMS = [(16, 16), (16, 8)]


def band_graph(v: int, seed: int = 0) -> "repro.graphs.CSRGraph":
    """Ring-of-bands graph: every row touches its +/-1 neighbours, so
    closures stay small and row-streaming is the planner's honest win."""
    rows = np.repeat(np.arange(v), 2)
    cols = (rows + np.tile(np.array([-1, 1]), v)) % v
    return from_edges(v, rows, cols)


def dense_block_graph(v: int, seed: int = 0) -> "repro.graphs.CSRGraph":
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, v, size=v * 8)
    cols = rng.integers(0, v, size=v * 8)
    return from_edges(v, rows, cols)


def make_params(kind: str, dims=DIMS, seed: int = 0):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(dims))
    return [init_layer(kind, k, fi, fo) for k, (fi, fo) in zip(keys, dims)]


def features(g, f_in: int = DIMS[0][0], seed: int = 1) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.standard_normal((g.n_nodes, f_in)).astype(np.float32)


# ---------------------------------------------------------------------------
# Simulator: communication term + footprint
# ---------------------------------------------------------------------------


class TestCommCost:
    def test_monolithic_is_free(self):
        c = partition_comm_cost("monolithic", 1, v=1000, f=64)
        assert c.cycles == 0 and c.energy_pj == 0 and c.elems == 0

    def test_single_partition_is_free_for_every_kind(self):
        for kind in PARTITION_KINDS:
            c = partition_comm_cost(kind, 1, v=1000, f=64)
            assert c.energy_pj == 0, kind

    def test_row_stream_prices_halo_round_trip_in_dram(self):
        hw = DEFAULT_ACCEL
        c = partition_comm_cost("row_stream", 4, v=1000, f=32, halo_elems=500)
        assert c.dram_accesses == 2 * 500
        assert c.gb_accesses == 0
        assert c.energy_pj == pytest.approx(2 * 500 * hw.dram_energy_pj)

    def test_pp_shard_stays_on_chip(self):
        c = partition_comm_cost("pp_shard", 2, v=1000, f=32)
        assert c.dram_accesses == 0
        assert c.gb_accesses == 2 * 1000 * 32

    def test_feature_chunk_spills_full_intermediate(self):
        c = partition_comm_cost("feature_chunk", 3, v=100, f=48)
        assert c.dram_accesses == 2 * 100 * 48

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError):
            partition_comm_cost("diagonal", 2, v=10, f=4)

    def test_non_additive_objective_rejected(self):
        c = partition_comm_cost("row_stream", 2, v=10, f=4, halo_elems=8)
        with pytest.raises(ValueError):
            c.objective("edp")

    def test_footprint_scales_with_v_and_f(self):
        hw = DEFAULT_ACCEL
        assert (
            intermediate_footprint_bytes(100, 32, hw)
            == 100 * 32 * hw.bytes_per_elem
        )


# ---------------------------------------------------------------------------
# Halo closures
# ---------------------------------------------------------------------------


class TestRowPartitions:
    def test_own_blocks_tile_the_graph_in_order(self):
        g = band_graph(300)
        parts = extract_row_partitions(g, 128, 2)
        own = np.concatenate([p.nodes[: p.n_own] for p in parts])
        assert np.array_equal(own, np.arange(300))

    def test_halo_nodes_present_on_band_graph(self):
        g = band_graph(300)
        parts = extract_row_partitions(g, 128, 2)
        assert all(p.n_halo > 0 for p in parts)

    def test_closure_rows_match_whole_graph_rows(self):
        g = band_graph(200)
        dense = g.to_dense()
        for p in extract_row_partitions(g, 64, 1):
            sub = p.graph.to_dense()
            lifted = np.zeros((p.n_own, g.n_nodes), dtype=sub.dtype)
            for li in range(p.n_own):
                lifted[li, p.nodes] = sub[li]
            assert np.allclose(lifted, dense[p.nodes[: p.n_own]])

    def test_single_block_is_the_whole_graph(self):
        g = band_graph(50)
        (p,) = extract_row_partitions(g, 64, 2)
        assert p.n_own == 50 and p.n_halo == 0

    def test_bad_args_rejected(self):
        g = band_graph(10)
        with pytest.raises(ValueError):
            extract_row_partitions(g, 0, 1)
        with pytest.raises(ValueError):
            extract_row_partitions(g, 4, 0)


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------


def capped(bytes_: int):
    return dataclasses.replace(DEFAULT_ACCEL, gb_capacity_bytes=bytes_)


class TestPlanner:
    def test_fitting_graph_plans_monolithic(self):
        g = band_graph(64)
        plan = plan_partition(g, DIMS, capped(1 << 20))
        assert plan.kind == "monolithic"
        assert plan.n_partitions == 1

    def test_banded_overflow_plans_row_stream(self):
        g = band_graph(700)
        plan = plan_partition(g, DIMS, capped(16 * 1024))
        assert plan.kind == "row_stream"
        assert plan.n_partitions > 1
        assert plan.block_rows > 0 and plan.halo_nodes > 0
        assert plan.n_hops == len(DIMS)

    def test_plan_keeps_ranked_candidate_evidence(self):
        g = band_graph(700)
        plan = plan_partition(g, DIMS, capped(16 * 1024))
        kinds = {c.kind for c in plan.candidates}
        assert kinds == set(PARTITION_KINDS)
        vals = [c.objective_value for c in plan.candidates if c.feasible]
        assert vals == sorted(vals)
        assert plan.as_dict()["candidates"][0]["kind"] == plan.kind

    def test_disallowing_monolithic_forces_a_partitioned_kind(self):
        g = band_graph(700)
        plan = plan_partition(
            g, DIMS, capped(16 * 1024), allow_monolithic=False
        )
        assert plan.kind != "monolithic"

    def test_multi_device_offers_pp_shard(self):
        g = dense_block_graph(700)
        plan = plan_partition(g, DIMS, capped(16 * 1024), n_devices=4)
        pp = [c for c in plan.candidates if c.kind == "pp_shard"]
        assert pp and pp[0].feasible and pp[0].n_partitions == 4

    def test_no_feasible_plan_raises(self):
        g = dense_block_graph(700)
        with pytest.raises(ValueError, match="no feasible"):
            plan_partition(
                g,
                DIMS,
                capped(256),  # nothing fits: closures nor column chunks
                allow_monolithic=False,
                max_partitions=2,
            )

    def test_footprint_recorded(self):
        g = band_graph(700)
        hw = capped(16 * 1024)
        plan = plan_partition(g, DIMS, hw)
        assert plan.footprint_bytes == intermediate_footprint_bytes(
            700, 16, hw
        )


# ---------------------------------------------------------------------------
# Bit-exact row streaming
# ---------------------------------------------------------------------------


def whole_graph_reference(g, x, params, kind, policy, order, band_size=128):
    adj = EllAdjacency.from_csr(g)
    specs = [ExecSpec(policy, order, band_size, None, 1, False)] * len(params)
    return np.asarray(
        forward_layers(kind, params, adj, jnp.asarray(x), specs)
    )


class TestRowStreamBitExact:
    """v = 200 with block_rows = 96: v_pad % band_size != 0 on both the
    whole graph and the closures, so padded-tail handling is exercised."""

    V = 200
    BLOCK = 96

    @pytest.mark.parametrize("kind", ["gcn", "sage", "gin"])
    def test_kinds_bit_identical(self, kind):
        g = band_graph(self.V)
        x = features(g)
        params = make_params(kind)
        ref = whole_graph_reference(g, x, params, kind, "sp_opt", "AC")
        out = row_stream_forward(
            g, x, params, kind=kind, policy="sp_opt", order="AC",
            block_rows=self.BLOCK,
        )
        assert np.array_equal(out, ref)

    @pytest.mark.parametrize("policy", ["seq", "sp_generic", "sp_opt"])
    @pytest.mark.parametrize("order", ["AC", "CA"])
    def test_policies_orders_bit_identical(self, policy, order):
        g = band_graph(self.V)
        x = features(g)
        params = make_params("gcn")
        ref = whole_graph_reference(g, x, params, "gcn", policy, order)
        out = row_stream_forward(
            g, x, params, kind="gcn", policy=policy, order=order,
            block_rows=self.BLOCK,
        )
        assert np.array_equal(out, ref)

    def test_readout_bit_identical(self):
        g = band_graph(self.V)
        x = features(g)
        params = make_params("gcn")
        from repro.gnn.layers import segment_readout

        ref = whole_graph_reference(g, x, params, "gcn", "sp_opt", "AC")
        ref_read = np.asarray(
            segment_readout(
                jnp.asarray(ref),
                jnp.zeros(ref.shape[0], dtype=jnp.int32),
                1,
                reduce="mean",
            )
        )[0]
        out = row_stream_forward(
            g, x, params, kind="gcn", policy="sp_opt", order="AC",
            block_rows=self.BLOCK, readout="mean",
        )
        assert np.array_equal(out, ref_read)


class TestFeatureChunk:
    def test_chunked_columns_match_to_float_tolerance(self):
        g = band_graph(120)
        x = features(g)
        params = make_params("gcn")
        ref = whole_graph_reference(g, x, params, "gcn", "seq", "AC")
        for order in ("AC", "CA"):
            out = feature_chunk_forward(
                g, x, params, kind="gcn", order=order, chunk_f=5
            )
            np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


class TestStreamedSpmm:
    def test_streamed_matches_monolithic_bitwise(self):
        from repro.kernels.spmm.ops import spmm, spmm_streamed

        g = band_graph(300)
        adj = EllAdjacency.from_csr(g)
        x = jnp.asarray(features(g, f_in=24))
        full = np.asarray(spmm(adj.indices, adj.weights, x))
        streamed = np.asarray(
            spmm_streamed(adj.indices, adj.weights, x, block_rows=128)
        )
        assert np.array_equal(streamed, full)

    def test_small_input_short_circuits(self):
        from repro.kernels.spmm.ops import spmm, spmm_streamed

        g = band_graph(64)
        adj = EllAdjacency.from_csr(g)
        x = jnp.asarray(features(g, f_in=8))
        assert np.array_equal(
            np.asarray(spmm_streamed(adj.indices, adj.weights, x,
                                     block_rows=4096)),
            np.asarray(spmm(adj.indices, adj.weights, x)),
        )


# ---------------------------------------------------------------------------
# Admission: footprint-aware oversized_reason
# ---------------------------------------------------------------------------


class TestOversizedReason:
    def test_node_cap_still_first(self):
        pol = BucketPolicy(max_nodes=64)
        g = band_graph(100)
        assert "max_nodes" in pol.oversized_reason(g)

    def test_footprint_check_fires_under_capacity(self):
        pol = BucketPolicy(max_nodes=4096)
        g = band_graph(1500)
        hw = capped(64 * 1024)
        reason = pol.oversized_reason(g, f=16, hw=hw)
        assert reason is not None and "gb_capacity_bytes" in reason

    def test_no_capacity_no_footprint_rejection(self):
        pol = BucketPolicy(max_nodes=4096)
        g = band_graph(1500)
        assert pol.oversized_reason(g, f=16, hw=DEFAULT_ACCEL) is None


# ---------------------------------------------------------------------------
# Serving integration
# ---------------------------------------------------------------------------


SCHEDULE = ModelSchedule.from_policies("sp_opt", "AC", DIMS)


def engine_params(g):
    wls = [GNNLayerWorkload(g.nnz, fi, fo) for fi, fo in DIMS]
    prog = repro.compile(wls, graph=g, schedule=SCHEDULE)
    return prog.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def giantish():
    g = band_graph(1500)
    return g, features(g), engine_params(g)


class TestEnginePartitionedLane:
    HW = capped(64 * 1024)
    POL = BucketPolicy(max_nodes=1024)

    def partitioned_engine(self, params, **kw):
        return InferenceEngine(
            DIMS,
            params,
            policy=self.POL,
            hw=self.HW,
            schedule=SCHEDULE,
            objective="edp",
            partition_oversized=True,
            store=None,
            **kw,
        )

    def test_oversized_without_flag_rejects(self, giantish):
        g, x, params = giantish
        eng = InferenceEngine(
            DIMS, params, policy=self.POL, hw=self.HW, schedule=SCHEDULE,
            store=None,
        )
        (res,) = eng.submit([Request(graph=g, x=x, rid=0)])
        assert res.status == "rejected"
        assert res.error_type == "oversized_graph"

    def test_partitioned_bit_identical_to_monolithic(self, giantish):
        g, x, params = giantish
        eng = self.partitioned_engine(params)
        (res,) = eng.submit([Request(graph=g, x=x, rid=0)])
        assert res.status == "ok", res.error
        assert res.plan == "row_stream"
        assert res.n_partitions > 1
        assert res.partition_wall_s > 0

        ref_eng = InferenceEngine(
            DIMS, params, policy=BucketPolicy(max_nodes=2048),
            schedule=SCHEDULE, store=None,
        )
        (ref,) = ref_eng.submit([Request(graph=g, x=x, rid=0)])
        assert ref.status == "ok", ref.error
        assert np.array_equal(
            np.asarray(res.output), np.asarray(ref.output)
        )

        st = eng.stats()
        assert st.n_partitioned == 1
        assert st.partition_plans == {"row_stream": 1}
        assert st.partition_wall_s > 0

    def test_mixed_batch_serves_both_lanes(self, giantish):
        g, x, params = giantish
        small = band_graph(100)
        xs = features(small)
        eng = self.partitioned_engine(params)
        results = eng.submit([
            Request(graph=small, x=xs, rid=0),
            Request(graph=g, x=x, rid=1),
        ])
        assert [r.status for r in results] == ["ok", "ok"]
        assert results[0].n_partitions == 0
        assert results[1].n_partitions > 1

    def test_plan_cached_across_requests(self, giantish):
        g, x, params = giantish
        eng = self.partitioned_engine(params)
        eng.submit([Request(graph=g, x=x, rid=0)])
        searches = eng.stats().n_searches
        eng.submit([Request(graph=g, x=x, rid=1)])
        assert eng.stats().n_searches == searches
        assert eng.stats().n_partitioned == 2


class TestAsyncPartitionedLane:
    def test_async_oversized_routes_to_partitioned_lane(self, giantish):
        g, x, params = giantish
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            ae = AsyncEngine(
                DIMS,
                params,
                window_ms=5,
                policy=BucketPolicy(max_nodes=1024),
                hw=capped(64 * 1024),
                schedule=SCHEDULE,
                objective="edp",
                partition_oversized=True,
                store=None,
            )
            ae.start()
            try:
                fut = ae.submit_async(ae.make_request(g, x))
                res = fut.result(timeout=300)
            finally:
                ae.close()
        assert res.status == "ok", res.error
        assert res.plan == "row_stream"
        assert res.n_partitions > 1
        st = ae.stats()
        assert st.n_ok == 1
        label = next(iter(st.per_device))
        assert st.per_device[label]["n_partitioned"] == 1

    def test_async_without_flag_still_rejects(self, giantish):
        g, x, params = giantish
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            ae = AsyncEngine(
                DIMS,
                params,
                window_ms=5,
                policy=BucketPolicy(max_nodes=1024),
                hw=capped(64 * 1024),
                schedule=SCHEDULE,
                store=None,
            )
            ae.start()
            try:
                res = ae.submit_async(ae.make_request(g, x)).result(timeout=60)
            finally:
                ae.close()
        assert res.status == "rejected"
        assert res.error_type == "oversized_graph"
