"""Mapper tests: candidate enumeration, the TileStats cache, dominance
pruning / top-k, and batch-vs-scalar engine equivalence."""
import numpy as np
import pytest

from repro.core import (
    AcceleratorConfig,
    GNNLayerWorkload,
    TileStats,
    named_dataflow,
    named_skeleton,
    optimize_tiles,
    optimize_tiles_topk,
    search_dataflows,
    simulate,
    simulate_batch,
)
from repro.core.mapper import TABLE5_NAMES, _phase_tilings, _pow2_up_to
from repro.core.cost_model import _tiles_of

HW = AcceleratorConfig()
RNG = np.random.default_rng(3)


def wl_random(v=512, f=64, g=16, max_deg=12, rng=RNG):
    nnz = rng.integers(1, max_deg + 1, size=v)
    nnz[rng.integers(v)] = max_deg * 20  # one evil row
    return GNNLayerWorkload(nnz, f, g)


class TestPow2Ladder:
    def test_includes_pow2_and_3x2k(self):
        assert _pow2_up_to(100, 512) == [1, 2, 3, 4, 6, 8, 12, 16, 24, 32,
                                         48, 64, 96, 128, 192]

    def test_capped_by_budget(self):
        assert max(_pow2_up_to(10**6, 256)) <= 256

    def test_small_extent(self):
        assert _pow2_up_to(1, 512) == [1]


class TestPhaseTilings:
    def test_footprint_within_budget(self):
        sk = named_skeleton("Seq-Nt")
        ext = {"V": 1000, "N": 30, "F": 64}
        for t in _phase_tilings(sk.agg, ext, budget=128):
            assert t["V"] * t["N"] * t["F"] <= 128

    def test_prefers_filled_tilings(self):
        sk = named_skeleton("Seq-Nt")
        ext = {"V": 1000, "N": 30, "F": 64}
        tilings = _phase_tilings(sk.agg, ext, budget=128, min_fill=0.25)
        assert all(t["V"] * t["N"] * t["F"] >= 32 for t in tilings)

    def test_falls_back_to_loose_when_unfillable(self):
        sk = named_skeleton("Seq-Nt")
        ext = {"V": 2, "N": 1, "F": 2}  # tiny extents can't fill 512 PEs
        tilings = _phase_tilings(sk.agg, ext, budget=512)
        assert tilings  # loose fallback still returns legal tilings


class TestTileStats:
    def test_doubling_matches_direct(self):
        nnz = np.random.default_rng(0).integers(0, 50, size=777)
        ts = TileStats(nnz)
        for t_v in (1, 2, 3, 4, 6, 8, 16, 64, 96, 512):
            np.testing.assert_array_equal(ts.tile_max(t_v), _tiles_of(nnz, t_v))

    def test_sum_ntrips_matches_direct(self):
        nnz = np.random.default_rng(1).integers(1, 40, size=300)
        ts = TileStats(nnz)
        for t_v, t_n in [(1, 1), (4, 2), (8, 3), (16, 16)]:
            tm = _tiles_of(nnz, t_v)
            expect = float(np.maximum(1, -(-tm // t_n)).sum())
            assert ts.sum_ntrips(t_v, t_n) == expect

    def test_aggregation_cost_accepts_stats(self):
        from repro.core import aggregation_cost, intra

        nnz = np.random.default_rng(4).integers(1, 20, size=333)
        ts = TileStats(nnz)
        df = intra("VsFsNt", "agg", V=8, F=16)
        plain = aggregation_cost(df, nnz, 64, HW)
        cached = aggregation_cost(df, nnz, 64, HW, stats=ts)
        assert cached.cycles == plain.cycles
        assert cached.gb_reads == plain.gb_reads
        assert cached.gb_writes == plain.gb_writes
        # a row_slice must bypass the full-workload cache
        sliced = aggregation_cost(df, nnz, 64, HW, row_slice=slice(0, 100), stats=ts)
        ref = aggregation_cost(df, nnz[:100], 64, HW)
        assert sliced.cycles == ref.cycles

    def test_band_stats_sum_max(self):
        nnz = np.random.default_rng(2).integers(1, 30, size=257)
        ts = TileStats(nnz)
        bs = ts.band_stats(4, 2, 3)
        alpha, gamma = np.array([2.0, 5.0]), np.array([30.0, 1.0])
        expect_all = np.array(
            [np.maximum(a * bs.band, g).sum() for a, g in zip(alpha, gamma)]
        )
        np.testing.assert_allclose(bs.sum_max_all(alpha, gamma), expect_all)
        expect_tail = np.array(
            [np.maximum(a * bs.band[1:], g).sum() for a, g in zip(alpha, gamma)]
        )
        np.testing.assert_allclose(bs.sum_max_tail(alpha, gamma), expect_tail)


class TestBatchScalarEquivalence:
    """`simulate_batch` must agree with the scalar oracle to 1e-6 rel."""

    def test_random_candidates(self):
        rng = np.random.default_rng(11)
        wl = wl_random(v=700, f=96, g=16, rng=rng)
        tiles = [1, 2, 4, 8, 16, 32]
        names = ["Seq-Nt", "Seq-Ns", "EnGN", "HyGCN", "AWB-GCN",
                 "SP-FsNt-Fs", "SP-VsNt-Vs", "PP-Nt-Vt/sl", "PP-Ns-Vsh",
                 "High-Vs-SP"]
        dfs = []
        while len(dfs) < 200:
            name = names[rng.integers(len(names))]
            kw = dict(
                T_V_AGG=int(rng.choice(tiles)), T_N=int(rng.choice(tiles)),
                T_F_AGG=int(rng.choice(tiles)), T_V_CMB=int(rng.choice(tiles)),
                T_G=int(rng.choice([1, 2, 4, 8])),
                T_F_CMB=int(rng.choice(tiles)),
                pe_split=float(rng.choice([0.25, 0.5, 0.75])),
            )
            dfs.append(named_dataflow(name, **kw))
        # PP element-granularity (both phases walk the V x F intermediate
        # element-wise) — not reachable through the named catalog above
        from repro.core import (
            GNNDataflow, Granularity, InterPhase, PhaseOrder, intra,
        )

        for _ in range(30):
            df = GNNDataflow(
                InterPhase.PP,
                PhaseOrder.AC,
                intra("VsFsNt", "agg", V=int(rng.choice(tiles)),
                      F=int(rng.choice(tiles))),
                intra("VsFsGt", "cmb", V=int(rng.choice(tiles)),
                      F=int(rng.choice(tiles))),
                pe_split=float(rng.choice([0.25, 0.5, 0.75])),
            )
            assert df.granularity == Granularity.ELEMENT
            dfs.append(df)
        bs = simulate_batch(dfs, wl, HW)
        legal = 0
        for i, df in enumerate(dfs):
            try:
                s = simulate(df, wl, HW)
            except ValueError:
                assert not bs.legal[i], df
                continue
            assert bs.legal[i], df
            legal += 1
            assert bs.cycles[i] == pytest.approx(s.cycles, rel=1e-6)
            assert bs.energy_pj[i] == pytest.approx(s.energy_pj, rel=1e-6)
            assert bs.agg_cycles[i] == pytest.approx(s.agg_cycles, rel=1e-6)
            assert bs.cmb_cycles[i] == pytest.approx(s.cmb_cycles, rel=1e-6)
            assert bs.macs[i] == pytest.approx(s.macs, rel=1e-6)
        assert legal >= 100  # the sample must actually exercise the engine

    @pytest.mark.parametrize("name", TABLE5_NAMES)
    def test_optimizer_engines_agree(self, name):
        wl = wl_random(v=384, f=48, g=16)
        kw = dict(objective="edp", pe_splits=(0.25, 0.5, 0.75))
        batch = optimize_tiles(named_skeleton(name), wl, HW, **kw)
        scalar = optimize_tiles(named_skeleton(name), wl, HW, engine="scalar", **kw)
        assert batch.objective("edp") == pytest.approx(
            scalar.objective("edp"), rel=1e-9
        )


class TestTopKAndPruning:
    def test_topk_sorted_and_legal(self):
        wl = wl_random()
        res = optimize_tiles_topk(
            named_skeleton("Seq-Nt"), wl, HW, objective="edp", top_k=5
        )
        assert 1 <= len(res) <= 5
        objs = [r.objective("edp") for r in res]
        assert objs == sorted(objs)
        for r in res:
            r.dataflow.validate(HW.n_pes)

    def test_best_result_is_undominated(self):
        # dominance pruning: nothing returned strictly dominates the winner
        wl = wl_random()
        res = optimize_tiles_topk(
            named_skeleton("PP-Nt-Vt/sl"), wl, HW, objective="edp",
            pe_splits=(0.25, 0.5, 0.75), top_k=8
        )
        best = res[0].stats
        for r in res[1:]:
            s = r.stats
            dominates = (
                s.cycles <= best.cycles
                and s.energy_pj <= best.energy_pj
                and (s.cycles < best.cycles or s.energy_pj < best.energy_pj)
            )
            assert not dominates

    def test_search_dataflows_topk(self):
        wl = wl_random(v=256)
        flat = search_dataflows(wl, HW, top_k=2)
        assert len(flat) >= len(search_dataflows(wl, HW, top_k=1))
        objs = [r.objective("edp") for r in flat]
        assert objs == sorted(objs)

    def test_shared_tile_stats(self):
        wl = wl_random(v=256)
        ts = TileStats(wl.nnz)
        a = search_dataflows(wl, HW, tile_stats=ts)
        b = search_dataflows(wl, HW)
        assert [r.skeleton for r in a] == [r.skeleton for r in b]
        assert a[0].stats.cycles == b[0].stats.cycles
