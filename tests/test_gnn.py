"""GNN substrate tests: policy equivalence, phase order, models, datasets,
and the device-level Parallel Pipeline (subprocess, 2 virtual devices)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.gnn import (
    EllAdjacency,
    GNNConfig,
    POLICIES,
    gnn_forward,
    gnn_loss,
    init_gnn,
    make_node_classification_task,
    multiphase_matmul,
)
from repro.graphs import TABLE4, from_edges, load_dataset


@pytest.fixture(scope="module")
def small_graph():
    g, spec = load_dataset("mutag")
    return g, spec


class TestPolicyEquivalence:
    """All inter-phase policies and both phase orders compute (A X) W."""

    def test_policies_match_dense_reference(self, small_graph):
        g, spec = small_graph
        adj = EllAdjacency.from_csr(g)
        x, _, _ = make_node_classification_task(g, spec.n_features, 4)
        w = jax.random.normal(jax.random.PRNGKey(0), (spec.n_features, 16)) * 0.1
        dense = jnp.asarray(g.to_dense())
        ref = (dense @ x) @ w
        for policy in ("seq", "sp_generic", "sp_opt"):
            for order in ("AC", "CA"):
                out = multiphase_matmul(adj, x, w, policy=policy, order=order)
                np.testing.assert_allclose(
                    np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4,
                    err_msg=f"{policy}/{order}",
                )

    def test_band_size_does_not_change_result(self, small_graph):
        g, spec = small_graph
        adj = EllAdjacency.from_csr(g)
        x, _, _ = make_node_classification_task(g, spec.n_features, 4)
        w = jax.random.normal(jax.random.PRNGKey(0), (spec.n_features, 8)) * 0.1
        outs = [
            multiphase_matmul(adj, x, w, policy="sp_generic", band_size=b)
            for b in (32, 128, 1024)
        ]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]), rtol=1e-5)

    def test_invalid_policy_raises(self, small_graph):
        g, spec = small_graph
        adj = EllAdjacency.from_csr(g)
        x = jnp.zeros((g.n_nodes, 4))
        w = jnp.zeros((4, 4))
        with pytest.raises(ValueError, match="policy"):
            multiphase_matmul(adj, x, w, policy="bogus")


class TestModels:
    @pytest.mark.parametrize("kind", ["gcn", "sage", "gin"])
    def test_forward_and_grads_finite(self, small_graph, kind):
        g, spec = small_graph
        adj = EllAdjacency.from_csr(g)
        x, labels, mask = make_node_classification_task(g, spec.n_features, 4)
        cfg = GNNConfig(kind=kind, f_in=spec.n_features, n_classes=4)
        params = init_gnn(cfg, jax.random.PRNGKey(1))
        logits = gnn_forward(cfg, params, adj, x)
        assert logits.shape == (g.n_nodes, 4)
        loss, grads = jax.value_and_grad(
            lambda p: gnn_loss(cfg, p, adj, x, labels, mask)
        )(params)
        assert np.isfinite(float(loss))
        for leaf in jax.tree_util.tree_leaves(grads):
            assert np.isfinite(np.asarray(leaf)).all()

    def test_training_reduces_loss(self, small_graph):
        g, spec = small_graph
        adj = EllAdjacency.from_csr(g)
        x, labels, mask = make_node_classification_task(g, spec.n_features, 4)
        cfg = GNNConfig(kind="gcn", f_in=spec.n_features, n_classes=4)
        params = init_gnn(cfg, jax.random.PRNGKey(1))

        @jax.jit
        def step(p):
            l, g_ = jax.value_and_grad(
                lambda q: gnn_loss(cfg, q, adj, x, labels, mask)
            )(p)
            return l, jax.tree_util.tree_map(lambda a, b: a - 0.05 * b, p, g_)

        l0, params = step(params)
        for _ in range(30):
            l, params = step(params)
        assert float(l) < float(l0)


class TestDatasets:
    @pytest.mark.parametrize("name", list(TABLE4))
    def test_stats_near_table4(self, name):
        g, spec = load_dataset(name)
        g.validate()
        expect_v = spec.avg_nodes * spec.n_graphs
        assert 0.5 * expect_v <= g.n_nodes <= 2.0 * expect_v
        # self-loops add V edges on top of ~2x undirected listing
        raw_e = spec.avg_edges * spec.n_graphs
        assert g.n_edges >= raw_e * 0.5
        assert g.nnz.min() >= 1  # self loops guarantee no empty rows

    def test_hf_datasets_have_skewed_degrees(self):
        for name in ("reddit-bin", "citeseer", "cora"):
            g, _ = load_dataset(name)
            assert g.max_degree > 4 * g.avg_degree, name  # evil rows exist

    def test_deterministic_given_seed(self):
        a, _ = load_dataset("mutag", seed=7)
        b, _ = load_dataset("mutag", seed=7)
        assert np.array_equal(a.col_idx, b.col_idx)
        c, _ = load_dataset("mutag", seed=8)
        assert not np.array_equal(a.col_idx, c.col_idx)


@settings(max_examples=20, deadline=None)
@given(
    v=st.integers(4, 60),
    extra=st.integers(0, 120),
    f=st.integers(1, 32),
    gdim=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_policies_agree_on_random_graphs(v, extra, f, gdim, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, v, size=extra)
    dst = rng.integers(0, v, size=extra)
    g = from_edges(v, src, dst)
    adj = EllAdjacency.from_csr(g)
    x = jnp.asarray(rng.normal(size=(v, f)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(f, gdim)).astype(np.float32))
    ref = multiphase_matmul(adj, x, w, policy="seq", order="AC")
    for policy, order in [("sp_generic", "AC"), ("sp_opt", "AC"), ("seq", "CA")]:
        out = multiphase_matmul(adj, x, w, policy=policy, order=order, band_size=16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-3, atol=1e-4)


PP_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax, jax.numpy as jnp, numpy as np
    from repro.gnn import EllAdjacency, multiphase_matmul
    from repro.graphs import load_dataset

    g, spec = load_dataset("mutag")
    adj = EllAdjacency.from_csr(g)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(g.n_nodes, spec.n_features)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(spec.n_features, 16)).astype(np.float32))
    mesh = jax.make_mesh((2,), ("phase",))
    ref = multiphase_matmul(adj, x, w, policy="seq")
    out = multiphase_matmul(adj, x, w, policy="pp", mesh=mesh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-3, atol=1e-4)
    print("PP-OK")
    """
)


def test_parallel_pipeline_two_device_groups():
    """The paper's PP dataflow as producer/consumer device groups with a
    collective_permute hand-off — run in a subprocess so the 2-device
    override does not pollute this process's jax."""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", PP_SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=300,
    )
    assert "PP-OK" in r.stdout, r.stderr[-2000:]
