"""Persistent program-store tests: atomic artifact saves survive injected
failures, corrupt artifacts degrade to counted misses (never exceptions),
traffic profiles round-trip, and a revived engine — fresh process state,
same store — serves bit-identical outputs with zero mapper searches and,
after precompile(), zero new XLA traces on its first request."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import GNNLayerWorkload
from repro.core.schedule import ModelSchedule
from repro.graphs import BucketPolicy, TrafficProfile, from_edges
from repro.runtime import ProgramStore, key_digest, store_key
from repro.runtime.engine import InferenceEngine, Request

DIMS = [(12, 16), (16, 4)]
SCHEDULE = ModelSchedule.from_policies("sp_opt", "AC", DIMS)
POLICY = BucketPolicy(min_nodes=16, min_degree=4, max_graphs=4)


def ring_graph(n: int, seed: int = 0):
    src = np.arange(n)
    dst = (src + 1) % n
    return from_edges(n, np.concatenate([src, dst]), np.concatenate([dst, src]))


def make_request(n: int, seed: int, rid: int = 0) -> Request:
    g = ring_graph(n, seed=seed)
    x = np.random.default_rng(seed).normal(size=(n, DIMS[0][0])).astype(np.float32)
    return Request(graph=g, x=x, rid=rid)


def compiled(graph, schedule=SCHEDULE):
    wls = [GNNLayerWorkload(graph.nnz, fi, fo) for fi, fo in DIMS]
    return repro.compile(wls, graph=graph, schedule=schedule)


@pytest.fixture(scope="module")
def prog():
    return compiled(ring_graph(16))


@pytest.fixture(scope="module")
def params(prog):
    return prog.init(jax.random.PRNGKey(0))


def a_key(bucket=(16, 4), v_total=16, **kw):
    kw.setdefault("kind", "gcn")
    kw.setdefault("objective", "cycles")
    kw.setdefault("use_pallas", False)
    return store_key(DIMS, bucket, v_total, **kw)


class TestAtomicSave:
    def test_injected_failure_leaves_previous_artifact_intact(
        self, tmp_path, prog, monkeypatch
    ):
        target = tmp_path / "prog.json"
        prog.save(target)
        before = target.read_text()

        def boom(src, dst):
            raise OSError("injected: disk full")

        monkeypatch.setattr(os, "replace", boom)
        with pytest.raises(OSError, match="injected"):
            prog.save(target)
        monkeypatch.undo()
        # the reader's view: previous complete artifact, no temp strays
        assert target.read_text() == before
        assert not list(tmp_path.glob("*.tmp.*"))

    def test_injected_failure_on_first_write_leaves_nothing(
        self, tmp_path, prog, monkeypatch
    ):
        target = tmp_path / "fresh.json"

        def boom(src, dst):
            raise OSError("injected")

        monkeypatch.setattr(os, "replace", boom)
        with pytest.raises(OSError):
            prog.save(target)
        monkeypatch.undo()
        assert not target.exists()
        assert not list(tmp_path.glob("*.tmp.*"))

    def test_save_bytes_stable_across_round_trip(self, tmp_path, prog):
        p1 = tmp_path / "a.json"
        p2 = tmp_path / "b.json"
        prog.save(p1)
        type(prog).from_json(p1.read_text()).save(p2)
        assert p1.read_text() == p2.read_text()


class TestProgramStore:
    def test_round_trip_serves_bit_identical(self, tmp_path, prog, params):
        store = ProgramStore(tmp_path)
        key = a_key()
        store.put(key, prog)
        # a fresh store (new process, same directory) must hit
        revived = ProgramStore(tmp_path)
        loaded = revived.get(key)
        assert loaded is not None and revived.hits == 1
        g = ring_graph(16)
        x = jnp.ones((16, DIMS[0][0]), jnp.float32)
        want = np.asarray(prog.run(params, x))
        got = np.asarray(
            loaded.bind(g, pad_degree=g.max_degree).run(params, x)
        )
        assert np.array_equal(want, got)

    def test_absent_key_is_plain_miss(self, tmp_path):
        store = ProgramStore(tmp_path)
        assert store.get(a_key(bucket=(32, 4), v_total=32)) is None
        assert store.misses == 1 and store.corrupt == 0

    @pytest.mark.parametrize("mangle", ["garbage", "truncated", "format"])
    def test_bad_artifact_is_counted_miss_never_raises(
        self, tmp_path, prog, mangle
    ):
        store = ProgramStore(tmp_path)
        key = a_key()
        path = store.put(key, prog)
        text = path.read_text()
        if mangle == "garbage":
            path.write_text("{ not json at all")
        elif mangle == "truncated":
            path.write_text(text[: len(text) // 2])
        else:  # a PROGRAM_FORMAT bump invalidates old stores gracefully
            d = json.loads(text)
            d["format"] = "repro.program/v0"
            path.write_text(json.dumps(d))
        assert store.get(key) is None
        assert store.corrupt == 1 and store.misses == 1
        # put repairs the entry and get recovers
        store.put(key, prog)
        assert store.get(key) is not None

    def test_corrupt_index_is_cosmetic(self, tmp_path, prog):
        store = ProgramStore(tmp_path)
        k1, k2 = a_key(), a_key(bucket=(16, 4), v_total=32)
        store.put(k1, prog)
        store.put(k2, prog)
        (tmp_path / "index.json").write_text("not an index {{{")
        # paths derive from key digests, so artifacts still resolve
        revived = ProgramStore(tmp_path)
        assert len(revived) == 2
        assert revived.get(k1) is not None and revived.get(k2) is not None
        # the next put rewrites a valid index
        revived.put(k1, prog)
        d = json.loads((tmp_path / "index.json").read_text())
        assert d["format"] == "repro.store/v1"

    def test_key_digest_is_order_insensitive_and_distinct(self):
        k = a_key()
        assert key_digest(k) == key_digest(dict(reversed(list(k.items()))))
        assert key_digest(k) != key_digest(a_key(use_pallas=True))
        assert key_digest(k) != key_digest(a_key(v_total=32))


class TestTrafficProfile:
    def test_record_merge_and_heat_order(self):
        p = TrafficProfile()
        p.record_request((16, 4), n=10)
        p.record_request((32, 4), n=2)
        p.record_batch((16, 4), slots=4)
        p.record_batch((16, 4), slots=1)
        p.record_batch((32, 4), slots=2)
        assert p.n_requests == 12
        shapes = p.hot_shapes()
        # the hotter bucket's shapes come first, then the cold bucket's
        assert [b for b, _ in shapes] == [(16, 4), (16, 4), (32, 4)]
        q = TrafficProfile()
        q.record_request((16, 4), n=5)
        q.record_batch((16, 4), slots=4)
        merged = p.merge(q)
        assert merged.n_requests == 17
        assert merged.batches[(16, 4, 4)] == 2

    def test_save_load_round_trip(self, tmp_path):
        p = TrafficProfile()
        p.record_request((16, 4), n=3)
        p.record_batch((16, 4), slots=2)
        path = p.save(tmp_path / "traffic.json")
        q = TrafficProfile.load(path)
        assert q.requests == p.requests and q.batches == p.batches

    def test_store_tolerates_garbage_profile(self, tmp_path):
        store = ProgramStore(tmp_path)
        store.profile_path.write_text("}} nope")
        assert store.load_profile() is None
        assert store.corrupt == 1
        assert ProgramStore(tmp_path).load_profile() is None  # still no raise


class TestRestartParity:
    @pytest.mark.parametrize("kind", ["gcn", "sage"])
    def test_revived_engine_is_bit_identical_and_search_free(
        self, tmp_path, kind
    ):
        reqs = [make_request(12, seed=i, rid=i) for i in range(4)]
        cold = InferenceEngine(
            DIMS, kind=kind, policy=POLICY, readout="mean",
            store=ProgramStore(tmp_path),
        )
        params = cold.init(jax.random.PRNGKey(0))
        got_cold = cold.submit(reqs)
        assert cold.stats().n_searches >= 1  # the search actually ran once
        revived = InferenceEngine(
            DIMS, params, kind=kind, policy=POLICY, readout="mean",
            store=ProgramStore(tmp_path),
        )
        got = revived.submit(reqs)
        stats = revived.stats()
        assert stats.n_searches == 0, "a warm store must preempt the mapper"
        assert stats.store_hits >= 1
        for a, b in zip(got_cold, got):
            assert a.ok and b.ok
            assert np.array_equal(a.output, b.output)

    def test_pallas_tier_round_trips_through_store(self, tmp_path):
        reqs = [make_request(12, seed=i, rid=i) for i in range(2)]
        cold = InferenceEngine(
            DIMS, use_pallas=True, policy=POLICY, readout="mean",
            store=ProgramStore(tmp_path),
        )
        params = cold.init(jax.random.PRNGKey(0))
        got_cold = cold.submit(reqs)
        revived = InferenceEngine(
            DIMS, params, use_pallas=True, policy=POLICY, readout="mean",
            store=ProgramStore(tmp_path),
        )
        got = revived.submit(reqs)
        assert revived.stats().n_searches == 0
        for a, b in zip(got_cold, got):
            assert a.ok and b.ok
            assert np.array_equal(a.output, b.output)

    def test_degraded_twin_of_loaded_program_is_bit_identical(
        self, tmp_path, prog, params
    ):
        store = ProgramStore(tmp_path)
        key = a_key(use_pallas=True)
        store.put(key, prog)
        loaded = ProgramStore(tmp_path).get(key)
        g = ring_graph(16)
        x = jnp.ones((16, DIMS[0][0]), jnp.float32)
        want = np.asarray(prog.degraded(use_pallas=False).run(params, x))
        twin = loaded.bind(g, pad_degree=g.max_degree).degraded(
            use_pallas=False
        )
        assert np.array_equal(want, np.asarray(twin.run(params, x)))


class TestPrecompile:
    def test_first_request_after_precompile_is_trace_free(self, tmp_path):
        reqs = [make_request(12, seed=i, rid=i) for i in range(5)]
        cold = InferenceEngine(
            DIMS, policy=POLICY, readout="mean",
            store=ProgramStore(tmp_path),
        )
        params = cold.init(jax.random.PRNGKey(0))
        # solo first arrival + bulk: the traffic profile records both the
        # slots=1 and the packed micro-batch shapes
        cold.submit(reqs[:1])
        cold.submit(reqs[1:])
        revived = InferenceEngine(
            DIMS, params, policy=POLICY, readout="mean",
            store=ProgramStore(tmp_path),
        )
        rep = revived.precompile()
        assert rep.n_shapes >= 2
        assert rep.n_store_hits == rep.n_shapes
        assert rep.n_searches == 0 and rep.n_compiled == 0
        assert rep.n_traces >= 1  # the traces happened here, at startup...
        before = repro.trace_count()
        got = revived.submit(reqs[:1])
        assert repro.trace_count() == before  # ...not on the request path
        assert revived.stats().n_searches == 0
        assert got[0].ok

    def test_precompile_without_params_rejected(self, tmp_path):
        engine = InferenceEngine(DIMS, store=ProgramStore(tmp_path))
        with pytest.raises(ValueError, match="params"):
            engine.precompile()

    def test_precompile_max_shapes_bounds_startup_work(self, tmp_path):
        profile = TrafficProfile()
        profile.record_request((16, 4), n=9)
        profile.record_batch((16, 4), slots=1)
        profile.record_batch((16, 4), slots=2)
        engine = InferenceEngine(DIMS, policy=POLICY, readout="mean",
                                 store=ProgramStore(tmp_path))
        engine.init(jax.random.PRNGKey(0))
        rep = engine.precompile(profile, max_shapes=1)
        assert rep.n_shapes == 1


class TestStatsSplit:
    def test_compile_time_splits_into_search_and_trace(self, tmp_path):
        engine = InferenceEngine(
            DIMS, policy=POLICY, readout="mean",
            store=ProgramStore(tmp_path),
        )
        engine.init(jax.random.PRNGKey(0))
        engine.submit([make_request(12, seed=i, rid=i) for i in range(3)])
        stats = engine.stats()
        assert stats.search_s > 0.0, "a cold engine ran the mapper"
        assert stats.trace_s > 0.0, "a cold engine took XLA traces"
        assert stats.compile_s == pytest.approx(
            stats.search_s + stats.trace_s
        )
        assert stats.n_searches >= 1
