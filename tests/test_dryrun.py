"""Dry-run infrastructure tests: HLO accounting, analytic FLOPs, mesh
construction, and one real 512-device cell (subprocess)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.configs import SHAPES, get_config
from repro.launch.analytic import cell_flops, cell_hbm_floor_bytes
from repro.launch.hlo import (
    collective_bytes,
    collective_bytes_scaled,
    execution_counts,
    shape_bytes,
    while_trip_counts,
)
from repro.launch.roofline import model_flops

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(script: str, timeout=600):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, cwd=REPO, timeout=timeout,
    )


class TestHloParsing:
    HLO = textwrap.dedent(
        """
        HloModule test

        %region_body (p: (s32[], f32[8,64])) -> (s32[], f32[8,64]) {
          %ag = f32[8,64]{1,0} all-gather(%x), replica_groups=[4,4]<=[16], dimensions={1}
          %ar = f32[8,64]{1,0} all-reduce(%ag), replica_groups=[2,8]<=[16]
        }

        %region_cond (p: (s32[], f32[8,64])) -> pred[] {
          %lt = pred[] compare(%a, %b)
        }

        ENTRY %main (a: f32[8,64]) -> f32[8,64] {
          %w = (s32[], f32[8,64]) while(%t), condition=%region_cond, body=%region_body, backend_config={"known_trip_count":{"n":"12"}}
          %rs = f32[8,16]{1,0} reduce-scatter(%y), replica_groups=[4,4]<=[16], dimensions={1}
          %cp = bf16[4,4]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
        }
        """
    )

    def test_shape_bytes(self):
        assert shape_bytes("f32[8,64]{1,0}") == 8 * 64 * 4
        assert shape_bytes("bf16[4,4]") == 32
        assert shape_bytes("(f32[2,2], s32[3])") == 16 + 12
        assert shape_bytes("pred[]") == 1

    def test_trip_counts(self):
        assert while_trip_counts(self.HLO) == [12]

    def test_execution_counts_propagate_into_body(self):
        mult = execution_counts(self.HLO)
        assert mult["region_body"] == 12
        assert mult["main"] == 1

    def test_unscaled_vs_scaled(self):
        raw = collective_bytes(self.HLO)
        scaled = collective_bytes_scaled(self.HLO)
        # in-body ops multiply by 12; entry ops do not
        assert scaled.count_by_op["all-gather"] == 12
        assert scaled.count_by_op["reduce-scatter"] == 1
        ag_operand = (8 * 64 * 4) // 4  # result / participants
        assert raw.bytes_by_op["all-gather"] == ag_operand
        assert scaled.bytes_by_op["all-gather"] == 12 * ag_operand
        # reduce-scatter operand = result * participants
        assert scaled.bytes_by_op["reduce-scatter"] == 8 * 16 * 4 * 4

    def test_allreduce_ring_link_bytes(self):
        scaled = collective_bytes_scaled(self.HLO)
        operand = 8 * 64 * 4
        assert scaled.link_bytes_by_op["all-reduce"] == 12 * int(2 * operand * 7 / 8)


class TestAnalyticAccounting:
    @pytest.mark.parametrize("arch", ["granite-8b", "tinyllama-1.1b", "olmo-1b"])
    def test_dense_train_flops_near_6nd(self, arch):
        """Analytic cell FLOPs for dense archs ~ 6·N·D x remat factor."""
        cfg = get_config(arch)
        shape = SHAPES["train_4k"]
        analytic = cell_flops(cfg, shape)
        canonical = model_flops(cfg, shape)
        # remat -> 8/6 x; attention quadratic adds more
        assert 0.9 < analytic / canonical < 2.5, (arch, analytic / canonical)

    def test_moe_counts_active_params_only(self):
        cfg = get_config("granite-moe-1b-a400m")
        dense_equiv = cfg.param_count()
        active = cfg.active_param_count()
        assert active < dense_equiv  # top-8 of 32 experts
        assert model_flops(cfg, SHAPES["train_4k"]) == 6.0 * active * 4096 * 256

    def test_decode_memory_floor_has_cache(self):
        cfg = get_config("granite-8b")
        floor = cell_hbm_floor_bytes(cfg, SHAPES["decode_32k"], 256, 16)
        params_only = cfg.param_count() / 16 * 2
        assert floor > 1.5 * params_only  # the 32k KV cache dominates

    def test_subquadratic_decode_floor_tiny(self):
        xl = get_config("xlstm-1.3b")
        floor = cell_hbm_floor_bytes(xl, SHAPES["long_500k"], 256, 16)
        # state-based decode: no 512k KV cache anywhere
        assert floor < 1e9


SCAN_CALIB = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    D, L, B = 256, 4, 8
    def scanned(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, ws)
        return out.sum()
    def unrolled(x, ws):
        for i in range(L):
            x = jnp.tanh(x @ ws[i])
        return x.sum()
    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    with jax.set_mesh(mesh):
        fl = []
        for fn in (scanned, unrolled):
            c = jax.jit(fn, in_shardings=(P("data", None), P(None, None, "model"))).lower(x, ws).compile()
            fl.append(c.cost_analysis()["flops"])
    # scan body counted once: unrolled ~= L x scanned (matmul part)
    assert fl[1] > 3.5 * fl[0], fl
    print("SCAN-ONCE-CONFIRMED")
    """
)


def test_cost_analysis_counts_scan_body_once():
    """The calibration underpinning the §Roofline methodology."""
    r = run_sub(SCAN_CALIB)
    assert "SCAN-ONCE-CONFIRMED" in r.stdout, r.stderr[-2000:]


DRYRUN_CELL = textwrap.dedent(
    """
    from repro.launch.dryrun import run_cell
    r = run_cell("smollm-135m", "decode_32k", multi_pod=False, save=False)
    assert r["n_chips"] == 256
    assert r["cost"]["flops_per_device"] > 0
    rf = r["roofline"]
    assert rf["dominant_term"] in ("compute", "memory", "collective")
    assert rf["bound_s"] > 0
    r2 = run_cell("smollm-135m", "long_500k", multi_pod=False, save=False)
    assert r2["skipped"]
    print("CELL-OK", rf["dominant_term"])
    """
)


def test_one_real_dryrun_cell_256_chips():
    """Full lower+compile of a serve_step on the 16x16 production mesh."""
    r = run_sub(DRYRUN_CELL)
    assert "CELL-OK" in r.stdout, r.stderr[-3000:]


MULTIPOD_CELL = textwrap.dedent(
    """
    from repro.launch.dryrun import run_cell
    r = run_cell("smollm-135m", "decode_32k", multi_pod=True, save=False)
    assert r["n_chips"] == 512 and r["mesh"].startswith("pod2x16x16")
    print("MULTIPOD-OK")
    """
)


def test_multipod_cell_512_chips():
    r = run_sub(MULTIPOD_CELL)
    assert "MULTIPOD-OK" in r.stdout, r.stderr[-3000:]


def test_sweep_artifacts_complete():
    """The committed sweep covers every (arch x shape x mesh) cell."""
    d = os.path.join(REPO, "experiments", "dryrun")
    if not os.path.isdir(d):
        pytest.skip("sweep not run")
    names = os.listdir(d)
    from repro.configs import ARCH_IDS, applicable

    missing = []
    for arch in ARCH_IDS:
        for shape_name, shape in SHAPES.items():
            if not applicable(get_config(arch), shape):
                continue
            for mesh in ("pod16x16", "pod2x16x16"):
                f = f"{arch}__{shape_name}__{mesh}.json"
                if f not in names:
                    missing.append(f)
    assert not missing, missing[:5]
