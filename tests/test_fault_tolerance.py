"""Fault-injection tests for the training-side runtime: ResilientRunner
retries transient faults, restores from the last checkpoint on persistent
ones (resuming to bit-identical parameters, with no replayed step logged
twice), and the StragglerMonitor flags slow steps and fires its hook."""
import numpy as np
import pytest

from repro.runtime import ResilientRunner, RetryPolicy, StragglerMonitor
from repro.runtime import fault_tolerance as ft_mod


def sgd_step(state, batch):
    """A tiny deterministic 'training' step: state is a float32 vector."""
    return state - 0.1 * (state - batch), {"loss": float(np.sum(state**2))}


def make_batches(seed: int = 0):
    rng = np.random.default_rng(seed)
    data = rng.normal(size=(64, 4)).astype(np.float32)
    return lambda s: data[s % len(data)]


def run_clean(num_steps: int, checkpoint_every: int = 4):
    """The fault-free reference trajectory."""
    saved = {}

    def save(step, st):
        saved[step] = np.array(st, copy=True)

    runner = ResilientRunner(
        step_fn=sgd_step,
        save_fn=save,
        restore_fn=lambda: (_ for _ in ()).throw(AssertionError("no restore")),
        checkpoint_every=checkpoint_every,
    )
    state, metrics = runner.run(
        np.ones(4, np.float32), make_batches(), 0, num_steps
    )
    return state, metrics


class TestResilientRunner:
    def test_transient_fault_retried_to_identical_result(self):
        """One transient raise is absorbed by retry; the trajectory is
        bit-identical to the fault-free run."""
        clean_state, clean_metrics = run_clean(10)
        calls = {"n": 0}

        def flaky(state, batch):
            calls["n"] += 1
            if calls["n"] == 4:
                raise RuntimeError("transient node failure")
            return sgd_step(state, batch)

        runner = ResilientRunner(
            step_fn=flaky,
            save_fn=lambda s, st: None,
            restore_fn=lambda: (0, np.ones(4, np.float32)),
            checkpoint_every=100,
        )
        state, metrics = runner.run(np.ones(4, np.float32), make_batches(), 0, 10)
        assert np.array_equal(state, clean_state)
        assert metrics == clean_metrics

    def test_retry_then_restore_resumes_bit_identical(self):
        """The docstring contract: a persistent fault exhausts retries,
        restores from the last atomic checkpoint, and the deterministic
        batch replay resumes to bit-identical parameters."""
        clean_state, clean_metrics = run_clean(12, checkpoint_every=4)

        saved = {}

        def save(step, st):
            saved["step"], saved["state"] = step, np.array(st, copy=True)

        # fault at step 6 (after the step-4 checkpoint): fails 4 times,
        # which exceeds max_retries=2 and forces a restore mid-failure
        failing_step = 6
        fail_budget = {"n": 4}
        runner_step_counter = {"step": 0}

        def step_with_fault(state, batch):
            if (
                runner_step_counter["step"] == failing_step
                and fail_budget["n"] > 0
            ):
                fail_budget["n"] -= 1
                raise RuntimeError("persistent kernel fault")
            return sgd_step(state, batch)

        batches = make_batches()

        def counting_batches(s):
            runner_step_counter["step"] = s
            return batches(s)

        def restore():
            return saved["step"], np.array(saved["state"], copy=True)

        runner = ResilientRunner(
            step_fn=step_with_fault,
            save_fn=save,
            restore_fn=restore,
            checkpoint_every=4,
            max_retries=2,
        )
        state, metrics = runner.run(
            np.ones(4, np.float32), counting_batches, 0, 12
        )
        assert np.array_equal(state, clean_state), (
            "restore + deterministic replay must resume to bit-identical "
            "parameters"
        )
        assert metrics == clean_metrics

    def test_restore_truncates_replayed_metrics(self):
        """The replay-bookkeeping fix: after a restore rolls the step
        back, entries past the restore point are dropped, so no step
        appears twice in the metrics log."""
        saved = {}

        def save(step, st):
            saved["step"], saved["state"] = step, np.array(st, copy=True)

        fail_budget = {"n": 2}
        where = {"step": 0}

        def step_fn(state, batch):
            if where["step"] == 5 and fail_budget["n"] > 0:
                fail_budget["n"] -= 1
                raise RuntimeError("fault")
            return sgd_step(state, batch)

        batches = make_batches()

        def tracking_batches(s):
            where["step"] = s
            return batches(s)

        runner = ResilientRunner(
            step_fn=step_fn,
            save_fn=save,
            restore_fn=lambda: (saved["step"], np.array(saved["state"])),
            checkpoint_every=2,
            max_retries=1,  # budget 2 > 1 retry -> restore fires
        )
        _, metrics = runner.run(np.ones(4, np.float32), tracking_batches, 0, 8)
        steps = [m["step"] for m in metrics]
        assert steps == list(range(8)), f"replayed steps logged twice: {steps}"

    def test_no_backoff_sleep_on_restore_branch(self, monkeypatch):
        """A restore replaces retrying; the backoff sleep must not fire on
        that branch (it would stall recovery by max_backoff for nothing)."""
        sleeps: list[float] = []
        monkeypatch.setattr(
            ft_mod.time, "sleep", lambda s: sleeps.append(s)
        )
        saved = {"step": 0, "state": np.ones(4, np.float32)}
        budget = {"n": 1}

        def step_fn(state, batch):
            if budget["n"] > 0:
                budget["n"] -= 1
                raise RuntimeError("fault")
            return sgd_step(state, batch)

        runner = ResilientRunner(
            step_fn=step_fn,
            save_fn=lambda s, st: None,
            restore_fn=lambda: (saved["step"], saved["state"]),
            max_retries=0,  # first failure restores immediately
            backoff_s=5.0,
        )
        runner.run(np.ones(4, np.float32), make_batches(), 0, 3)
        assert sleeps == [], f"restore branch slept the backoff: {sleeps}"

    def test_retry_policy_is_shared_machinery(self):
        """The runner's backoff comes from the same RetryPolicy the
        serving engine uses, with bounded exponential delays."""
        runner = ResilientRunner(
            step_fn=sgd_step,
            save_fn=lambda s, st: None,
            restore_fn=lambda: (0, None),
            max_retries=3,
            backoff_s=0.1,
        )
        policy = runner.retry_policy
        assert isinstance(policy, RetryPolicy)
        assert policy.max_retries == 3
        assert policy.delay(0) == pytest.approx(0.1)
        assert policy.delay(1) == pytest.approx(0.2)
        assert policy.delay(10) <= policy.max_backoff_s


class TestStragglerMonitor:
    def test_flags_3x_median_step_and_fires_hook(self):
        fired: list[tuple[int, float, float]] = []
        mon = StragglerMonitor(
            threshold=3.0,
            on_straggler=lambda step, s, med: fired.append((step, s, med)),
        )
        for i in range(10):
            assert not mon.record(i, 0.010)
        assert mon.record(10, 0.031 * 1.01)  # just over 3x the 10ms median
        assert mon.flagged == [10]
        assert len(fired) == 1
        step, seconds, med = fired[0]
        assert step == 10
        assert seconds > 3.0 * med

    def test_below_threshold_not_flagged(self):
        mon = StragglerMonitor(threshold=3.0)
        for i in range(10):
            mon.record(i, 0.010)
        assert not mon.record(10, 0.029)
        assert mon.flagged == []

    def test_needs_history_before_flagging(self):
        mon = StragglerMonitor(threshold=3.0)
        # fewer than 8 samples: never flags, however slow
        for i in range(7):
            assert not mon.record(i, 10.0 if i == 6 else 0.01)
        assert mon.flagged == []
