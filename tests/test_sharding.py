"""Sharding-rule unit tests: param specs, divisibility guards, ZeRO-1,
TP head alignment arithmetic."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models.attention import aligned_kv_heads, head_alignment
from repro.models.sharding import (
    ShardingRules,
    _divisible,
    production_rules,
    spec_for_param,
    tuned_rules,
)

RULES = production_rules()


class TestParamSpecs:
    @pytest.mark.parametrize(
        "path,ndim,expected",
        [
            ("scanned/0/attn/wq", 2, P(None, "model")),
            ("scanned/0/attn/wo", 2, P("model", None)),
            ("scanned/0/mlp/w_gate", 2, P(None, "model")),
            ("scanned/0/mlp/w_down", 2, P("model", None)),
            ("scanned/0/moe/experts_gate", 3, P("model", None, None)),
            ("embeddings/embed", 2, P("model", None)),
            ("embeddings/lm_head", 2, P(None, "model")),
            ("scanned/0/ln1", 1, P()),
            # stacked-layer leading dim stays unsharded
            ("scanned/0/attn/wq", 3, P(None, None, "model")),
        ],
    )
    def test_pattern_matching(self, path, ndim, expected):
        assert spec_for_param(path, ndim, RULES) == expected

    def test_divisibility_guard_drops_unshardable_dims(self):
        mesh = jax.make_mesh(
            (1,), ("model",), axis_types=(jax.sharding.AxisType.Auto,)
        )
        # fake a 16-wide axis via a stub mesh-like object
        class FakeMesh:
            shape = {"model": 16}

        assert _divisible(P("model", None), (49155, 8), FakeMesh()) == P(None, None)
        assert _divisible(P("model", None), (49152, 8), FakeMesh()) == P("model", None)
        assert _divisible(P(("a", "b"), None), (8, 8), type("M", (), {"shape": {"a": 2, "b": 2}})()) == P(("a", "b"), None)


class TestHeadAlignment:
    @pytest.mark.parametrize(
        "arch,ts,kv_new,overhead_max",
        [
            ("granite-8b", 16, 16, 1.01),          # 32q/8kv -> rep 2, G 4->2
            ("tinyllama-1.1b", 16, 16, 1.01),      # 32q/4kv -> rep 4, G 8->2
            ("llava-next-34b", 16, 16, 1.15),      # 56q/8kv -> rep 2, G 7->4
            ("olmo-1b", 16, 16, 1.01),             # MHA 16/16: already aligned
            ("musicgen-large", 16, 32, 1.01),      # 32kv already divides
            ("granite-moe-3b-a800m", 16, 16, 1.34),  # 24q/8kv -> G 3->2
        ],
    )
    def test_alignment_overhead(self, arch, ts, kv_new, overhead_max):
        cfg = get_config(arch)
        rep, g_new, aligned = head_alignment(cfg, ts)
        hkv_new = cfg.n_kv_heads * rep
        assert hkv_new == kv_new
        if aligned:
            assert hkv_new % ts == 0 or cfg.n_kv_heads % ts == 0
        overhead = (hkv_new * g_new) / cfg.n_heads
        assert overhead <= overhead_max + 1e-9

    def test_smollm_keeps_attention_unsharded(self):
        """9 heads on 16-way TP would cost 5.3x — alignment declines."""
        cfg = get_config("smollm-135m")
        rep, g_new, aligned = head_alignment(cfg, 16)
        assert not aligned and rep == 1

    def test_no_mesh_means_no_padding(self):
        cfg = get_config("llava-next-34b")
        rep, g_new, aligned = head_alignment(cfg, 1)
        assert rep == 1 and g_new == cfg.n_heads // cfg.n_kv_heads
        assert aligned is False
        assert aligned_kv_heads(cfg, 1) == cfg.n_kv_heads


class TestTunedRules:
    def test_tuned_adds_sequence_parallelism(self):
        r = tuned_rules("granite-8b")
        assert r.sequence == "model" and r.heads == "model"

    def test_multi_pod_batch_axes(self):
        r = production_rules(multi_pod=True)
        assert r.batch == ("pod", "data")
        r1 = production_rules(multi_pod=False)
        assert r1.batch == ("data",)
