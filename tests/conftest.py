"""Ensures the tests directory is importable (for hypothesis_compat)."""
