"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
executed in Pallas interpret mode (kernels target TPU; this container is
CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.graphs import from_edges
from repro.kernels.fused_agg_cmb import fused_agg_cmb, fused_ref
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.gemm_dataflow import DATAFLOWS, gemm_ref
from repro.kernels.gemm_dataflow.ops import gemm
from repro.kernels.spmm import spmm, spmm_ref

RNG = np.random.default_rng(42)


def rand(shape, dtype=np.float32, rng=RNG):
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


class TestGemmDataflow:
    @pytest.mark.parametrize("dataflow", DATAFLOWS)
    @pytest.mark.parametrize(
        "v,f,g", [(128, 128, 128), (96, 80, 72), (33, 17, 5), (256, 64, 512)]
    )
    def test_matches_oracle(self, dataflow, v, f, g):
        x, w = rand((v, f)), rand((f, g))
        out = gemm(x, w, dataflow=dataflow, block_v=32, block_g=32, block_f=32)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(gemm_ref(x, w)), rtol=1e-4, atol=1e-4
        )

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        x = rand((64, 64)).astype(dtype)
        w = rand((64, 64)).astype(dtype)
        out = gemm(x, w, dataflow="output_stationary", block_v=32, block_g=32, block_f=32)
        ref = gemm_ref(x, w)
        np.testing.assert_allclose(
            np.asarray(out, np.float32),
            np.asarray(ref, np.float32),
            rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
            atol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
        )

    @settings(max_examples=15, deadline=None)
    @given(
        v=st.integers(1, 150),
        f=st.integers(1, 150),
        g=st.integers(1, 150),
        df=st.sampled_from(DATAFLOWS),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_shapes(self, v, f, g, df, seed):
        rng = np.random.default_rng(seed)
        x, w = rand((v, f), rng=rng), rand((f, g), rng=rng)
        out = gemm(x, w, dataflow=df, block_v=32, block_g=32, block_f=32)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(gemm_ref(x, w)), rtol=2e-4, atol=2e-4
        )


def random_ell(v, max_deg, seed=0):
    rng = np.random.default_rng(seed)
    extra = rng.integers(0, v * max_deg // 2 + 1)
    g = from_edges(v, rng.integers(0, v, extra), rng.integers(0, v, extra))
    idx, wts, _ = g.to_ell()
    return jnp.asarray(idx), jnp.asarray(wts)


class TestSpmm:
    @pytest.mark.parametrize("v,f,deg", [(64, 32, 4), (200, 96, 8), (17, 5, 3)])
    def test_matches_oracle(self, v, f, deg):
        idx, wts = random_ell(v, deg, seed=v)
        x = rand((v, f))
        out = spmm(idx, wts, x, block_v=32, block_f=32)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(spmm_ref(idx, wts, x)), rtol=1e-4, atol=1e-5
        )

    def test_matches_dense_spmm(self):
        g = from_edges(50, np.arange(49), np.arange(1, 50))
        idx, wts, _ = g.to_ell()
        x = rand((50, 24))
        dense = jnp.asarray(g.to_dense())
        out = spmm(jnp.asarray(idx), jnp.asarray(wts), x, block_v=16, block_f=8)
        np.testing.assert_allclose(
            np.asarray(out[:50]), np.asarray(dense @ x), rtol=1e-4, atol=1e-5
        )

    @settings(max_examples=15, deadline=None)
    @given(
        v=st.integers(2, 120),
        f=st.integers(1, 80),
        deg=st.integers(1, 10),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property(self, v, f, deg, seed):
        idx, wts = random_ell(v, deg, seed=seed)
        rng = np.random.default_rng(seed)
        x = rand((v, f), rng=rng)
        out = spmm(idx, wts, x, block_v=32, block_f=32)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(spmm_ref(idx, wts, x)), rtol=1e-4, atol=1e-4
        )


class TestFusedAggCmb:
    """The SP-Optimized kernel: fused == aggregate-then-GEMM."""

    @pytest.mark.parametrize("v,f,g,deg", [(64, 32, 16, 4), (130, 48, 8, 6)])
    def test_matches_oracle(self, v, f, g, deg):
        idx, wts = random_ell(v, deg, seed=v)
        x, w = rand((v, f)), rand((f, g))
        out = fused_agg_cmb(idx, wts, x, w, band_size=32)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(fused_ref(idx, wts, x, w)), rtol=1e-4, atol=1e-4
        )

    def test_fused_equals_two_phase(self):
        v, f, g, deg = 96, 40, 12, 5
        idx, wts = random_ell(v, deg, seed=1)
        x, w = rand((v, f)), rand((f, g))
        fused = fused_agg_cmb(idx, wts, x, w, band_size=32)
        seq = spmm(idx, wts, x, block_v=32, block_f=32) @ w
        np.testing.assert_allclose(np.asarray(fused), np.asarray(seq), rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(
        v=st.integers(4, 100),
        f=st.integers(1, 64),
        g=st.integers(1, 32),
        deg=st.integers(1, 8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property(self, v, f, g, deg, seed):
        idx, wts = random_ell(v, deg, seed=seed)
        rng = np.random.default_rng(seed)
        x, w = rand((v, f), rng=rng), rand((f, g), rng=rng)
        out = fused_agg_cmb(idx, wts, x, w, band_size=16)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(fused_ref(idx, wts, x, w)), rtol=2e-4, atol=2e-4
        )


class TestFlashAttention:
    @pytest.mark.parametrize("causal", [False, True])
    @pytest.mark.parametrize(
        "b,hq,hkv,sq,sk,d",
        [(2, 4, 2, 96, 96, 32), (1, 8, 1, 64, 128, 16), (2, 2, 2, 33, 33, 64)],
    )
    def test_matches_oracle(self, b, hq, hkv, sq, sk, d, causal):
        q = rand((b, hq, sq, d))
        k = rand((b, hkv, sk, d))
        v = rand((b, hkv, sk, d))
        out = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
        rep = hq // hkv
        kr = jnp.repeat(k, rep, axis=1).reshape(b * hq, sk, d)
        vr = jnp.repeat(v, rep, axis=1).reshape(b * hq, sk, d)
        ref = attention_ref(q.reshape(b * hq, sq, d), kr, vr, causal=causal)
        np.testing.assert_allclose(
            np.asarray(out).reshape(b * hq, sq, d), np.asarray(ref), rtol=2e-4, atol=2e-5
        )

    def test_bf16(self):
        q = rand((1, 2, 64, 32)).astype(jnp.bfloat16)
        k = rand((1, 2, 64, 32)).astype(jnp.bfloat16)
        v = rand((1, 2, 64, 32)).astype(jnp.bfloat16)
        out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
        ref = attention_ref(
            q.reshape(2, 64, 32), k.reshape(2, 64, 32), v.reshape(2, 64, 32), causal=True
        )
        np.testing.assert_allclose(
            np.asarray(out, np.float32).reshape(2, 64, 32),
            np.asarray(ref, np.float32),
            rtol=5e-2,
            atol=5e-2,
        )

    @settings(max_examples=10, deadline=None)
    @given(
        sq=st.integers(1, 120),
        sk=st.integers(1, 120),
        d=st.sampled_from([8, 16, 32]),
        causal=st.booleans(),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property(self, sq, sk, d, causal, seed):
        rng = np.random.default_rng(seed)
        q = rand((1, 2, sq, d), rng=rng)
        k = rand((1, 2, sk, d), rng=rng)
        v = rand((1, 2, sk, d), rng=rng)
        out = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
        ref = attention_ref(
            q.reshape(2, sq, d), k.reshape(2, sk, d), v.reshape(2, sk, d), causal=causal
        )
        np.testing.assert_allclose(
            np.asarray(out).reshape(2, sq, d), np.asarray(ref), rtol=3e-4, atol=3e-5
        )
