"""LM substrate unit tests: attention policies, MoE paths, recurrent blocks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import use_sharding, ShardingRules
from repro.models.attention import KVCache, attention, decode_attention, init_attention
from repro.models.config import ArchConfig, MoEConfig
from repro.models.moe import init_moe, moe_dense, moe_ep, moe_ragged
from repro.models.rglru import RGLRUState, init_rglru, rglru_block, rglru_decode
from repro.models.xlstm import (
    MLSTMState,
    init_mlstm,
    mlstm_block,
    mlstm_decode,
    init_slstm,
    slstm_block,
    slstm_decode,
    SLSTMState,
)

CFG = ArchConfig(
    name="t", family="dense", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab=128, attn_chunk=8,
)
RNG = np.random.default_rng(0)


def rand(shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


class TestAttention:
    def test_chunked_equals_seq(self):
        """SP-Optimized chunked == Seq materialized (the paper's policies
        compute the same function)."""
        p = init_attention(CFG, jax.random.PRNGKey(0))
        x = rand((2, 24, 32)) * 0.3
        pos = jnp.broadcast_to(jnp.arange(24), (2, 24))
        o1 = attention(CFG.with_(attn_policy="seq"), p, x, pos)
        o2 = attention(CFG.with_(attn_policy="sp_opt"), p, x, pos)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4, atol=1e-5)

    def test_window_masks_past(self):
        p = init_attention(CFG, jax.random.PRNGKey(0))
        x = rand((1, 32, 32)) * 0.3
        pos = jnp.broadcast_to(jnp.arange(32), (1, 32))
        full = attention(CFG, p, x, pos)
        win = attention(CFG, p, x, pos, window=4)
        # early tokens (inside the window) identical, late ones differ
        np.testing.assert_allclose(
            np.asarray(full[:, :4]), np.asarray(win[:, :4]), rtol=1e-4, atol=1e-5
        )
        assert np.abs(np.asarray(full[:, -1]) - np.asarray(win[:, -1])).max() > 1e-4

    def test_decode_matches_forward(self):
        p = init_attention(CFG, jax.random.PRNGKey(0))
        x = rand((2, 12, 32)) * 0.3
        pos = jnp.broadcast_to(jnp.arange(12), (2, 12))
        full = attention(CFG, p, x, pos)
        cache = KVCache.zeros(CFG, 2, 12)
        for t in range(12):
            out, cache = decode_attention(CFG, p, x[:, t : t + 1], cache, t)
            np.testing.assert_allclose(
                np.asarray(out[:, 0]), np.asarray(full[:, t]), rtol=2e-4, atol=2e-5
            )

    def test_ring_buffer_window_decode(self):
        """Windowed decode with a ring cache == windowed forward."""
        cfg = CFG.with_(window=6)
        p = init_attention(cfg, jax.random.PRNGKey(1))
        x = rand((1, 20, 32)) * 0.3
        pos = jnp.broadcast_to(jnp.arange(20), (1, 20))
        full = attention(cfg, p, x, pos, window=6)
        cache = KVCache.zeros(cfg, 1, 20, window=6)
        assert cache.k.shape[1] == 6  # ring buffer, not full length
        for t in range(20):
            out, cache = decode_attention(cfg, p, x[:, t : t + 1], cache, t, window=6)
            np.testing.assert_allclose(
                np.asarray(out[:, 0]), np.asarray(full[:, t]), rtol=2e-4, atol=2e-5,
                err_msg=f"t={t}",
            )


class TestMoE:
    cfg = ArchConfig(
        name="m", family="moe", n_layers=2, d_model=16, n_heads=2, n_kv_heads=2,
        d_ff=32, vocab=64, block_pattern=("moe",), moe=MoEConfig(n_experts=4, top_k=2),
    )

    def test_ragged_matches_dense(self):
        p = init_moe(self.cfg, jax.random.PRNGKey(0))
        x = rand((2, 8, 16)) * 0.3
        d_out, d_aux = moe_dense(self.cfg, p, x)
        r_out, r_aux = moe_ragged(self.cfg, p, x)
        np.testing.assert_allclose(np.asarray(d_out), np.asarray(r_out), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(float(d_aux), float(r_aux), rtol=1e-5)

    def test_ep_matches_dense_single_device(self):
        """EP shard_map path on a (1,1) mesh == dense oracle (capacity set
        high enough that nothing drops)."""
        cfg = self.cfg.with_(moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=8.0))
        p = init_moe(cfg, jax.random.PRNGKey(0))
        x = rand((2, 8, 16)) * 0.3
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = ShardingRules(batch=("data",), heads="model", d_ff="model",
                              experts="model", vocab="model")
        d_out, _ = moe_dense(cfg, p, x)
        e_out, _ = moe_ep(cfg, p, x, mesh, rules)
        np.testing.assert_allclose(np.asarray(d_out), np.asarray(e_out), rtol=1e-4, atol=1e-5)

    def test_capacity_drops_tokens(self):
        cfg = self.cfg.with_(moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=0.1))
        p = init_moe(cfg, jax.random.PRNGKey(0))
        x = rand((2, 32, 16)) * 0.3
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = ShardingRules(batch=("data",), experts="model")
        e_out, _ = moe_ep(cfg, p, x, mesh, rules)
        d_out, _ = moe_dense(cfg, p, x)
        # with a tiny capacity factor some tokens must be dropped
        assert np.abs(np.asarray(e_out) - np.asarray(d_out)).max() > 1e-4

    def test_aux_loss_uniform_router_is_one(self):
        """Switch aux loss == aux_weight when routing is perfectly uniform."""
        cfg = self.cfg
        p = init_moe(cfg, jax.random.PRNGKey(0))
        p = dict(p, router=jnp.zeros_like(p["router"]))  # uniform gates
        x = rand((1, 64, 16))
        _, aux = moe_dense(cfg, p, x)
        expected = cfg.moe.router_aux_weight  # E * (1/E * k/E) * E/k ... = w
        k, e = cfg.moe.top_k, cfg.moe.n_experts
        # aux = w * E * sum_e (1/E * frac_e) with sum frac = 1 -> w
        np.testing.assert_allclose(float(aux), expected, rtol=1e-3)


class TestRGLRU:
    cfg = ArchConfig(
        name="r", family="hybrid", n_layers=3, d_model=24, n_heads=2, n_kv_heads=1,
        d_ff=48, vocab=64, block_pattern=("rglru", "rglru", "local"), d_rnn=24,
    )

    def test_scan_matches_stepwise(self):
        p = init_rglru(self.cfg, jax.random.PRNGKey(0))
        x = rand((2, 10, 24)) * 0.3
        full = rglru_block(self.cfg, p, x)
        state = RGLRUState.zeros(self.cfg, 2)
        for t in range(10):
            out, state = rglru_decode(self.cfg, p, x[:, t : t + 1], state)
            np.testing.assert_allclose(
                np.asarray(out[:, 0]), np.asarray(full[:, t]), rtol=2e-4, atol=2e-5,
                err_msg=f"t={t}",
            )

    def test_decay_bounded(self):
        """RG-LRU a_t must stay in (0, 1) — stability of the recurrence."""
        from repro.models.rglru import _gates

        p = init_rglru(self.cfg, jax.random.PRNGKey(0))
        u = rand((4, 24)) * 10
        a_t, _ = _gates(p, u)
        assert (np.asarray(a_t) > 0).all() and (np.asarray(a_t) < 1).all()


class TestXLSTM:
    cfg = ArchConfig(
        name="x", family="ssm", n_layers=8, d_model=16, n_heads=2, n_kv_heads=2,
        d_ff=0, vocab=64, block_pattern=("mlstm",) * 7 + ("slstm",),
    )

    def test_mlstm_chunkwise_matches_recurrent(self):
        """Chunkwise-parallel mLSTM == step-by-step recurrence (the
        chunkwise form is the SP-Generic pipelining of the same math)."""
        p = init_mlstm(self.cfg, jax.random.PRNGKey(0))
        x = rand((2, 12, 16)) * 0.3
        full = mlstm_block(self.cfg, p, x, chunk=4)
        state = MLSTMState.zeros(self.cfg, 2)
        for t in range(12):
            out, state = mlstm_decode(self.cfg, p, x[:, t : t + 1], state)
            np.testing.assert_allclose(
                np.asarray(out[:, 0]), np.asarray(full[:, t]), rtol=1e-3, atol=1e-4,
                err_msg=f"t={t}",
            )

    def test_mlstm_chunk_size_invariance(self):
        p = init_mlstm(self.cfg, jax.random.PRNGKey(0))
        x = rand((1, 16, 16)) * 0.3
        outs = [mlstm_block(self.cfg, p, x, chunk=c) for c in (2, 4, 16)]
        for o in outs[1:]:
            np.testing.assert_allclose(
                np.asarray(o), np.asarray(outs[0]), rtol=1e-3, atol=1e-4
            )

    def test_mlstm_long_sequence_stable(self):
        """Exponential gating must not overflow on long inputs."""
        p = init_mlstm(self.cfg, jax.random.PRNGKey(0))
        x = rand((1, 256, 16)) * 2.0
        out = mlstm_block(self.cfg, p, x, chunk=32)
        assert np.isfinite(np.asarray(out)).all()

    def test_slstm_scan_matches_stepwise(self):
        p = init_slstm(self.cfg, jax.random.PRNGKey(1))
        x = rand((2, 10, 16)) * 0.3
        full = slstm_block(self.cfg, p, x)
        state = SLSTMState.zeros(self.cfg, 2)
        for t in range(10):
            out, state = slstm_decode(self.cfg, p, x[:, t : t + 1], state)
            np.testing.assert_allclose(
                np.asarray(out[:, 0]), np.asarray(full[:, t]), rtol=2e-4, atol=2e-5
            )
