"""Optional-`hypothesis` shim for the property-based tests.

``hypothesis`` is a dev-only extra (see pyproject.toml).  When it is
missing we must not fail collection — the paper-repro suite has plenty of
non-property tests per module — so this shim exports either the real
``given / settings / strategies`` or inert stand-ins that skip each
property test individually (the per-test equivalent of
``pytest.importorskip("hypothesis")``).
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import assume, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised in minimal envs
    HAVE_HYPOTHESIS = False
    assume = None

    class _Anything:
        """Absorbs any strategy-construction call at module import time."""

        def __getattr__(self, name):
            return _Anything()

        def __call__(self, *args, **kwargs):
            return _Anything()

    st = _Anything()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco

    def given(*args, **kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub():
                pass

            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub

        return deco


requires_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed"
)
