"""Calibrated cost model: LatencyModel threading, fit, persistence.

Covers the predicted<->measured loop's model layer:

- the identity (default) LatencyModel is **bit-identical** to the
  pre-calibration simulator on both the scalar and vectorized paths;
- a calibrated model applies exactly ``overhead * cycles + c_setup``;
- :func:`fit_latency_model` is pure and deterministic, and recovers
  planted constants from exact synthetic observations;
- serialization is byte-stable (to_json/from_json/save/load), with env
  (``REPRO_LATENCY_MODEL``) and :class:`ProgramStore` resolution;
- the :class:`TrafficProfile` observation ledger round-trips;
- :func:`search_model_topk` returns deduplicated, analytic-best-first
  candidates;
- ``Program.train_step`` reuses its cached executable (zero retraces).
"""
import json
from dataclasses import asdict, replace

import jax
import numpy as np
import pytest

import repro
from repro.core import GNNLayerWorkload
from repro.core.calibrate import CalibrationPoint, fit_latency_model
from repro.core.hw import (
    DEFAULT_ACCEL,
    DEFAULT_LATENCY,
    LATENCY_MODEL_ENV,
    AcceleratorConfig,
    LatencyModel,
)
from repro.core.mapper import search_model, search_model_topk
from repro.core.schedule import ModelSchedule
from repro.core.simulator import simulate, simulate_batch
from repro.graphs import TrafficProfile, from_edges
from repro.runtime import ProgramStore

POLICY_FAMILY = {
    "seq": "seq", "sp_generic": "sp_generic", "sp_opt": "sp_opt", "pp": "pp"
}
CAL = LatencyModel(
    overhead_seq=2.0,
    overhead_sp_generic=1.5,
    overhead_sp_opt=1.25,
    overhead_pp=3.0,
    c_setup=100.0,
    cycle_time_s=1e-9,
    backend="test:unit:jax-0",
    fit_error_median=0.01,
)


def _df(policy: str, order: str = "AC"):
    return ModelSchedule.from_policies(
        policy, order, [(32, 16)], v=1024
    ).dataflows[0]


class TestIdentityParity:
    """The default model must not perturb a single simulator bit."""

    def test_simulate_bit_identical_under_explicit_identity(self):
        wl = GNNLayerWorkload(np.full(1024, 8), 32, 16, name="t")
        hw_explicit = replace(DEFAULT_ACCEL, latency=LatencyModel())
        for policy in POLICY_FAMILY:
            for order in ("AC", "CA"):
                a = simulate(_df(policy, order), wl, DEFAULT_ACCEL)
                b = simulate(_df(policy, order), wl, hw_explicit)
                assert a.cycles == b.cycles
                assert a.energy_pj == b.energy_pj
                assert a.stall_factor == b.stall_factor

    def test_simulate_batch_bit_identical_under_explicit_identity(self):
        wl = GNNLayerWorkload(np.full(1024, 8), 32, 16, name="t")
        dfs = [_df(p, o) for p in POLICY_FAMILY for o in ("AC", "CA")]
        a = simulate_batch(dfs, wl, DEFAULT_ACCEL)
        b = simulate_batch(dfs, wl, replace(DEFAULT_ACCEL, latency=LatencyModel()))
        assert np.array_equal(a.cycles, b.cycles)
        assert np.array_equal(a.energy_pj, b.energy_pj)
        assert np.array_equal(a.legal, b.legal)


class TestCalibratedCycles:
    """A fitted model is exactly ``overhead(family) * cycles + c_setup``."""

    def test_simulate_applies_family_overhead_and_setup(self):
        wl = GNNLayerWorkload(np.full(1024, 8), 32, 16, name="t")
        hw_cal = replace(DEFAULT_ACCEL, latency=CAL)
        for policy, family in POLICY_FAMILY.items():
            base = simulate(_df(policy), wl, DEFAULT_ACCEL)
            cal = simulate(_df(policy), wl, hw_cal)
            assert cal.cycles == base.cycles * CAL.overhead(family) + 100.0
            # energy is a first-principles count; calibration leaves it alone
            assert cal.energy_pj == base.energy_pj

    def test_simulate_batch_matches_scalar_calibration(self):
        wl = GNNLayerWorkload(np.full(1024, 8), 32, 16, name="t")
        hw_cal = replace(DEFAULT_ACCEL, latency=CAL)
        for policy, family in POLICY_FAMILY.items():
            dfs = [_df(policy, "AC"), _df(policy, "CA")]
            base = simulate_batch(dfs, wl, DEFAULT_ACCEL)
            cal = simulate_batch(dfs, wl, hw_cal)
            expect = base.cycles * CAL.overhead(family) + 100.0
            assert np.allclose(cal.cycles, expect, rtol=0, atol=0)

    def test_wall_seconds_requires_calibration(self):
        assert not DEFAULT_LATENCY.calibrated
        with pytest.raises(ValueError):
            DEFAULT_LATENCY.wall_s(1e6)
        assert CAL.wall_s(1e6) == pytest.approx(1e6 * 1e-9)


def _planted_points():
    """Exact observations of a known model: overheads {seq:3, spg:1,
    spo:1.5}, cycle_time 5ns, setup 20us — zero-residual by construction."""
    true = {"seq": 3.0, "sp_generic": 1.0, "sp_opt": 1.5}
    ct, setup = 5e-9, 2e-5
    pts = []
    for policy, ov in true.items():
        for i, cyc in enumerate((1e5, 5e5, 2e6)):
            pts.append(CalibrationPoint(
                policy=policy, order="AC", v=256 * (i + 1), degree=8,
                f_in=32, f_out=32, use_pallas=False, cycles=cyc,
                measured_s=ct * ov * cyc + setup,
                # a proportional bw ladder would fit exactly at *every*
                # multiplier (degenerate); pin the search to 1.0
                cycles_by_bw=((1.0, cyc),),
            ))
    return pts


class TestFit:
    def test_fit_is_deterministic(self):
        r1 = fit_latency_model(_planted_points(), backend="test")
        r2 = fit_latency_model(list(_planted_points()), backend="test")
        assert r1.model == r2.model
        assert r1.errors == r2.errors
        assert r1.bw_mult == r2.bw_mult

    def test_fit_recovers_planted_constants(self):
        r = fit_latency_model(_planted_points(), hw=DEFAULT_ACCEL, backend="t")
        assert r.error_median < 1e-6
        assert r.bw_mult == 1.0 and r.model.bw_eff is None
        assert r.model.overhead_seq == pytest.approx(3.0, rel=1e-6)
        assert r.model.overhead_sp_opt == pytest.approx(1.5, rel=1e-6)
        assert r.model.overhead_sp_generic == pytest.approx(1.0, rel=1e-6)
        # pp never measured on a single device: tied to the sp_generic
        # band-scan fallback it actually executes through
        assert r.model.overhead_pp == r.model.overhead_sp_generic
        assert r.model.cycle_time_s == pytest.approx(5e-9, rel=1e-6)
        assert r.model.c_setup == pytest.approx(2e-5 / 5e-9, rel=1e-6)

    def test_fit_rejects_zero_points(self):
        with pytest.raises(ValueError):
            fit_latency_model([])


class TestSerialization:
    def test_json_roundtrip_byte_stable(self, tmp_path):
        text = CAL.to_json()
        again = LatencyModel.from_json(text)
        assert again == CAL
        assert again.to_json() == text
        p = tmp_path / "m.json"
        CAL.save(p)
        assert p.read_text() == text
        assert LatencyModel.load(p) == CAL

    def test_from_json_rejects_unknown_format(self):
        with pytest.raises(ValueError):
            LatencyModel.from_json(json.dumps({"format": "nope"}))

    def test_from_env(self, tmp_path, monkeypatch):
        monkeypatch.delenv(LATENCY_MODEL_ENV, raising=False)
        assert LatencyModel.from_env() is None
        p = tmp_path / "m.json"
        CAL.save(p)
        monkeypatch.setenv(LATENCY_MODEL_ENV, str(p))
        assert LatencyModel.from_env() == CAL
        monkeypatch.setenv(LATENCY_MODEL_ENV, str(tmp_path / "missing.json"))
        with pytest.raises((OSError, ValueError)):
            LatencyModel.from_env()

    def test_accelerator_config_from_dict_backcompat(self):
        d = asdict(DEFAULT_ACCEL)
        d.pop("latency")  # pre-calibration artifacts have no latency key
        hw = AcceleratorConfig.from_dict(d)
        assert hw == DEFAULT_ACCEL
        assert hw.latency == DEFAULT_LATENCY
        d2 = asdict(replace(DEFAULT_ACCEL, latency=CAL))
        assert AcceleratorConfig.from_dict(d2).latency == CAL


class TestStorePersistence:
    def test_roundtrip_keyed_by_backend(self, tmp_path):
        store = ProgramStore(tmp_path)
        store.save_latency_model(CAL)
        assert store.load_latency_model(CAL.backend) == CAL
        assert store.load_latency_model("other:backend") is None
        other = replace(CAL, backend="other:backend", overhead_seq=9.0)
        store.save_latency_model(other)  # merges, does not clobber
        assert store.load_latency_model(CAL.backend) == CAL
        assert store.load_latency_model("other:backend") == other

    def test_refuses_unfitted_model(self, tmp_path):
        store = ProgramStore(tmp_path)
        with pytest.raises(ValueError):
            store.save_latency_model(LatencyModel())  # no backend fingerprint

    def test_corrupt_file_degrades_to_none(self, tmp_path):
        store = ProgramStore(tmp_path)
        store.save_latency_model(CAL)
        store.latency_path.write_text("{garbage")
        assert store.load_latency_model(CAL.backend) is None
        assert store.corrupt > 0


class TestObservationLedger:
    def test_record_mean_and_roundtrip(self):
        p = TrafficProfile()
        p.record_wall((32, 8), 4, "abcd1234", 0.5)
        p.record_wall((32, 8), 4, "abcd1234", 0.25)
        p.record_wall((64, 8), 2, "ffff0000", 1.0)
        assert p.mean_wall((32, 8), 4, "abcd1234") == pytest.approx(0.375)
        assert p.mean_wall((32, 8), 4, "zzzz") is None
        q = TrafficProfile.from_json(p.to_json())
        assert q.observed == p.observed

    def test_merge_sums_and_subset_filters(self):
        p = TrafficProfile()
        p.record_wall((32, 8), 4, "abcd1234", 0.5)
        q = TrafficProfile()
        q.record_wall((32, 8), 4, "abcd1234", 0.1)
        q.record_wall((64, 8), 2, "ffff0000", 1.0)
        m = p.merge(q)
        assert m.observed[(32, 8, 4, "abcd1234")] == (2, pytest.approx(0.6))
        s = m.subset([(32, 8)])
        assert (64, 8, 2, "ffff0000") not in s.observed
        assert (32, 8, 4, "abcd1234") in s.observed

    def test_legacy_json_without_observed_loads(self):
        p = TrafficProfile()
        p.record_request((32, 8), 3)
        d = json.loads(p.to_json())
        d.pop("observed")
        q = TrafficProfile.from_json(json.dumps(d))
        assert q.observed == {}
        assert q.requests == p.requests


class TestSearchModelTopK:
    def test_candidates_ranked_and_deduplicated(self):
        wls = [
            GNNLayerWorkload(np.full(512, 8), 16, 16, name="l0"),
            GNNLayerWorkload(np.full(512, 8), 16, 8, name="l1"),
        ]
        top = search_model_topk(wls, top_k=4)
        assert 1 <= len(top) <= 4
        digests = [s.digest() for s in top]
        assert len(set(digests)) == len(digests)
        objs = [s.stats.objective("cycles") for s in top]
        assert objs == sorted(objs)
        winner = search_model(wls)
        assert top[0].digest() == winner.digest()


class TestTrainStep:
    def test_warm_steps_take_zero_traces(self):
        v = 32
        src = np.arange(v)
        g = from_edges(
            v,
            np.concatenate([src, (src + 1) % v]),
            np.concatenate([(src + 1) % v, src]),
        )
        dims = [(12, 16), (16, 4)]
        wls = [GNNLayerWorkload(g.nnz, fi, fo) for fi, fo in dims]
        prog = repro.compile(
            wls, graph=g,
            schedule=ModelSchedule.from_policies("sp_opt", "AC", dims),
        )
        params = prog.init(jax.random.PRNGKey(0))
        from repro.gnn.model import make_node_classification_task

        x, labels, mask = make_node_classification_task(g, 12, 4)
        loss0, params = prog.train_step(params, x, labels, mask)
        traces0 = repro.trace_count()
        for _ in range(3):
            loss, params = prog.train_step(params, x, labels, mask)
        assert repro.trace_count() == traces0
        assert float(loss) < float(loss0)
