"""Serving-resilience tests: engine-boundary validation, admission
control, the degradation ladder, solo-retry quarantine, deterministic
fault injection — and the chaos acceptance run (1000-request stream, 20%
poisoned, submit() never raises, healthy outputs bit-identical to a
fault-free run)."""
import time

import jax
import numpy as np
import pytest

from repro.core.schedule import ModelSchedule
from repro.graphs import BucketPolicy, CSRGraph, from_edges
from repro.runtime import (
    COMPILE,
    FaultInjector,
    FaultRule,
    InferenceEngine,
    Request,
    RetryPolicy,
    kill_pallas,
    validate_request,
)

DIMS = [(12, 16), (16, 4)]
SCHEDULE = ModelSchedule.from_policies("sp_opt", "AC", DIMS)
POL = BucketPolicy(min_nodes=16, min_degree=4, max_graphs=4)
FAST = RetryPolicy(max_retries=0, backoff_s=0.0)


def ring_graph(n: int, seed: int = 0) -> CSRGraph:
    src = np.arange(n)
    dst = (src + 1) % n
    return from_edges(n, np.concatenate([src, dst]), np.concatenate([dst, src]))


def make_request(n: int, seed: int, rid: int = 0, **kw) -> Request:
    g = ring_graph(n, seed=seed)
    x = np.random.default_rng(seed).normal(size=(n, DIMS[0][0])).astype(np.float32)
    return Request(graph=g, x=x, rid=rid, **kw)


def make_engine(params, **kw) -> InferenceEngine:
    kw.setdefault("policy", POL)
    kw.setdefault("schedule", SCHEDULE)
    kw.setdefault("retry", FAST)
    return InferenceEngine(DIMS, params, **kw)


@pytest.fixture(scope="module")
def params():
    eng = InferenceEngine(DIMS, policy=POL, schedule=SCHEDULE)
    return eng.init(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Engine-boundary validation
# ---------------------------------------------------------------------------


class TestValidation:
    def _reject(self, params, req):
        eng = make_engine(params)
        (res,) = eng.submit([req])
        assert res.status == "rejected"
        assert res.error_type == "invalid_request"
        assert res.output is None
        assert f"request {req.rid}" in res.error
        return res

    def test_nan_features_rejected(self, params):
        req = make_request(16, seed=0, rid=7)
        req.x[3, 2] = np.nan
        res = self._reject(params, req)
        assert "non-finite" in res.error

    def test_float64_features_rejected(self, params):
        good = make_request(16, seed=0, rid=9)
        req = Request(graph=good.graph, x=good.x.astype(np.float64), rid=9)
        res = self._reject(params, req)
        assert "float32" in res.error

    def test_wrong_shape_rejected(self, params):
        good = make_request(16, seed=0, rid=11)
        req = Request(graph=good.graph, x=good.x[:, :-1].copy(), rid=11)
        self._reject(params, req)

    def test_out_of_range_col_idx_rejected(self, params):
        good = make_request(16, seed=0, rid=13)
        g = good.graph
        ci = np.array(g.col_idx, copy=True)
        ci[0] = g.n_nodes + 5  # dangling edge target
        bad = CSRGraph(row_ptr=g.row_ptr, col_idx=ci, values=g.values,
                       n_nodes=g.n_nodes)
        res = self._reject(params, Request(graph=bad, x=good.x, rid=13))
        assert "out of range" in res.error

    def test_csr_invariants_direct(self):
        """Each CSR invariant raises a typed InvalidRequest naming the rid."""
        from repro.runtime import InvalidRequest

        good = make_request(16, seed=0, rid=21)
        g = good.graph

        def expect(graph, match):
            with pytest.raises(InvalidRequest, match=match) as e:
                validate_request(Request(graph=graph, x=good.x, rid=21),
                                 DIMS[0][0])
            assert "request 21" in str(e.value)

        expect(
            CSRGraph(g.row_ptr[:-1], g.col_idx, g.values, g.n_nodes),
            "row_ptr has length",
        )
        rp = np.array(g.row_ptr, copy=True)
        rp[3], rp[4] = rp[4], rp[3] + 2  # break monotonicity
        expect(CSRGraph(rp, g.col_idx, g.values, g.n_nodes), "monoton")
        expect(
            CSRGraph(g.row_ptr, g.col_idx, g.values[:-1], g.n_nodes),
            "lengths",
        )
        vals = np.array(g.values, copy=True)
        vals[0] = np.inf
        expect(CSRGraph(g.row_ptr, g.col_idx, vals, g.n_nodes), "non-finite")

    def test_healthy_neighbors_unaffected(self, params):
        """One malformed request in a submit slice: it is rejected at the
        boundary and the rest of the slice is served normally."""
        reqs = [make_request(16, seed=s, rid=s) for s in range(4)]
        reqs[2].x[0, 0] = np.nan
        eng = make_engine(params)
        results = eng.submit(reqs)
        assert [r.status for r in results] == ["ok", "ok", "rejected", "ok"]
        assert all(r.output is not None for i, r in enumerate(results) if i != 2)


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------


class TestAdmissionControl:
    def test_overload_sheds_with_retry_after(self, params):
        eng = make_engine(params, max_inflight_graphs=2)
        results = eng.submit([make_request(16, seed=s, rid=s) for s in range(5)])
        shed = [r for r in results if r.status == "rejected"]
        served = [r for r in results if r.ok]
        assert len(served) == 2 and len(shed) == 3
        for r in shed:
            assert r.error_type == "engine_overloaded"
            assert r.retry_after_s is not None and r.retry_after_s > 0
        assert eng.stats().n_rejected == 3
        assert eng.stats().errors == {"engine_overloaded": 3}

    def test_oversized_graph_rejected(self, params):
        eng = make_engine(
            params,
            policy=BucketPolicy(min_nodes=16, min_degree=4, max_graphs=4,
                                max_nodes=32),
        )
        ok_req = make_request(16, seed=0, rid=0)
        big = make_request(40, seed=1, rid=1)
        res_ok, res_big = eng.submit([ok_req, big])
        assert res_ok.ok
        assert res_big.status == "rejected"
        assert res_big.error_type == "oversized_graph"
        assert "max_nodes=32" in res_big.error

    def test_expired_deadline_fails_at_assembly(self, params):
        eng = make_engine(params)
        healthy = make_request(16, seed=0, rid=0)
        expired = make_request(16, seed=1, rid=1, deadline_s=0.0)
        res_h, res_e = eng.submit([healthy, expired])
        assert res_h.ok
        assert res_e.status == "failed"
        assert res_e.error_type == "deadline_exceeded"
        assert "deadline" in res_e.error
        # the expired request freed its batch slot; the healthy one ran
        assert eng.stats().n_failed == 1 and eng.stats().n_ok == 1

    def test_generous_deadline_served(self, params):
        eng = make_engine(params)
        (res,) = eng.submit([make_request(16, seed=0, rid=0, deadline_s=60.0)])
        assert res.ok


# ---------------------------------------------------------------------------
# Fault isolation: solo-retry quarantine + typed failures
# ---------------------------------------------------------------------------


class TestFaultIsolation:
    def test_poisoned_request_fails_alone_neighbors_bit_identical(self, params):
        """The core isolation property: a sticky per-rid kernel fault takes
        down its whole micro-batch at every tier, the engine quarantines by
        re-running members solo, and only the poisoned rid fails — with the
        healthy neighbors' outputs bit-identical to a fault-free run."""
        reqs = [make_request(16, seed=s, rid=s) for s in range(4)]
        clean = make_engine(params).submit(reqs)

        inj = FaultInjector(rules=[FaultRule(kind="exception", rid=2)])
        eng = make_engine(params, fault_injector=inj)
        chaos = eng.submit(reqs)

        assert chaos[2].status == "failed"
        assert chaos[2].error_type == "kernel_fault"
        assert chaos[2].output is None
        for i in (0, 1, 3):
            assert chaos[i].status == "ok"
            assert np.array_equal(chaos[i].output, clean[i].output), (
                f"rid {i}: quarantined solo output differs from the "
                f"fault-free batched output"
            )
        stats = eng.stats()
        assert stats.n_solo_retries == 4  # every member re-ran alone
        assert stats.n_failed == 1 and stats.n_ok == 3
        assert stats.errors.get("kernel_fault", 0) >= 1

    def test_transient_fault_retried_to_ok(self, params):
        inj = FaultInjector(
            rules=[FaultRule(kind="exception", rid=0, max_fires=1)]
        )
        eng = make_engine(
            params, fault_injector=inj, retry=RetryPolicy(max_retries=1)
        )
        (res,) = eng.submit([make_request(16, seed=0, rid=0)])
        assert res.status == "ok"
        assert res.n_retries >= 1
        assert eng.stats().n_retries >= 1

    def test_persistent_nan_fails_with_numerical_fault(self, params):
        inj = FaultInjector(rules=[FaultRule(kind="nan", rid=1)])
        eng = make_engine(params, fault_injector=inj)
        res0, res1 = eng.submit(
            [make_request(16, seed=0, rid=0), make_request(16, seed=1, rid=1)]
        )
        assert res0.status == "ok"
        assert res1.status == "failed"
        assert res1.error_type == "numerical_fault"
        assert "non-finite" in res1.error

    def test_transient_nan_clears_on_retry(self, params):
        inj = FaultInjector(rules=[FaultRule(kind="nan", rid=0, max_fires=1)])
        eng = make_engine(
            params, fault_injector=inj, retry=RetryPolicy(max_retries=1)
        )
        (res,) = eng.submit([make_request(16, seed=0, rid=0)])
        assert res.status == "ok"
        assert np.isfinite(res.output).all()
        assert res.n_retries >= 1

    def test_check_numerics_off_returns_nans_silently(self, params):
        """The knob documents the tradeoff: with check_numerics=False the
        corrupted output escapes (status ok, NaNs inside)."""
        inj = FaultInjector(rules=[FaultRule(kind="nan", rid=0)])
        eng = make_engine(params, fault_injector=inj, check_numerics=False)
        (res,) = eng.submit([make_request(16, seed=0, rid=0)])
        assert res.status == "ok"
        assert np.isnan(res.output).any()

    def test_compile_boundary_fault_retried(self, params):
        """A transient compile fault on a cold bucket clears on retry."""
        inj = FaultInjector(
            rules=[
                FaultRule(kind="exception", bucket=(16, 4),
                          batch_index=COMPILE, max_fires=1)
            ]
        )
        eng = make_engine(
            params, fault_injector=inj, retry=RetryPolicy(max_retries=1)
        )
        (res,) = eng.submit([make_request(16, seed=0, rid=0)])
        assert res.status == "ok"
        assert res.n_retries >= 1
        assert any(ev.boundary == "compile" for ev in inj.log)

    def test_latency_spike_flags_straggler_but_serves(self, params):
        """An injected latency spike is flagged by the straggler monitor;
        the request itself still completes ok."""
        inj = FaultInjector(
            rules=[FaultRule(kind="latency", batch_index=10, latency_s=0.3)]
        )
        eng = make_engine(params, fault_injector=inj)
        results = []
        for i in range(12):  # one single-request micro-batch per submit
            results += eng.submit([make_request(16, seed=i, rid=i)])
        assert all(r.status == "ok" for r in results)
        stats = eng.stats()
        assert stats.n_stragglers >= 1, (
            "the 0.3s injected spike should dwarf the warm-batch median"
        )
        assert any(ev.kind == "latency" for ev in inj.log)


# ---------------------------------------------------------------------------
# Graceful degradation
# ---------------------------------------------------------------------------


class TestDegradation:
    def test_pallas_outage_mid_stream(self, params):
        """kill_pallas models a live backend outage: buckets whose
        executables are already traced keep serving on the pallas tier;
        cold buckets degrade to jnp+searched with a recorded downgrade."""
        eng = make_engine(params, use_pallas=True)
        warm = eng.submit([make_request(16, seed=0, rid=0),
                           make_request(16, seed=1, rid=1)])
        assert [r.status for r in warm] == ["ok", "ok"]
        assert all(r.tier == "pallas+searched" for r in warm)

        with kill_pallas():
            # same bucket, same slot count -> warm executable still serves
            still_warm = eng.submit([make_request(16, seed=2, rid=2),
                                     make_request(16, seed=3, rid=3)])
            # new bucket -> pallas cannot trace -> degrade down the ladder
            cold = eng.submit([make_request(32, seed=4, rid=4)])

        assert [r.status for r in still_warm] == ["ok", "ok"]
        assert all(r.tier == "pallas+searched" for r in still_warm)
        assert cold[0].status == "degraded"
        assert cold[0].ok  # degraded results are served answers
        assert cold[0].tier == "jnp+searched"
        stats = eng.stats()
        assert stats.n_downgrades == 1 and stats.n_degraded == 1

    @pytest.mark.parametrize("policy", ["seq", "sp_generic", "sp_opt"])
    @pytest.mark.parametrize("order", ["AC", "CA"])
    def test_degraded_numerics_match_reference(self, params, policy, order):
        """Satellite acceptance: for every (policy, order), the jnp
        fallback the ladder lands on when the Pallas backend dies
        mid-stream matches a pure-jnp reference engine to 1e-6."""
        sched = ModelSchedule.from_policies(policy, order, DIMS)
        reqs = [make_request(16, seed=s, rid=s) for s in range(3)]

        ref_eng = make_engine(params, schedule=sched, use_pallas=False)
        ref = ref_eng.submit(reqs)
        assert all(r.status == "ok" for r in ref)

        eng = make_engine(params, schedule=sched, use_pallas=True)
        with kill_pallas():
            res = eng.submit(reqs)

        for r, rr in zip(res, ref):
            assert r.status == "degraded" and r.tier == "jnp+searched"
            np.testing.assert_allclose(
                r.output, rr.output, atol=1e-6, rtol=0,
                err_msg=f"({policy}, {order}) degraded path diverged",
            )


# ---------------------------------------------------------------------------
# Injector determinism
# ---------------------------------------------------------------------------


class TestInjectorDeterminism:
    def test_same_seed_same_faults(self, params):
        reqs = [make_request(16, seed=s, rid=s) for s in range(24)]

        def run(seed):
            inj = FaultInjector(seed, p_exception=0.5)
            eng = make_engine(params, fault_injector=inj)
            results = eng.submit(reqs)
            return [(r.rid, r.status, r.error_type) for r in results], inj.log

        a_res, a_log = run(seed=7)
        b_res, b_log = run(seed=7)
        assert a_res == b_res, "same seed must reproduce the same statuses"
        assert a_log == b_log, "same seed must reproduce the same injections"
        assert a_log, "p_exception=0.5 over the stream must inject something"

    def test_rule_max_fires_bounds_injection(self):
        rule = FaultRule(kind="exception", rid=5, max_fires=2)
        inj = FaultInjector(rules=[rule])
        fired = 0
        for _ in range(5):
            try:
                inj.on_run((16, 4), 0, [5], "jnp+searched")
            except Exception:
                fired += 1
        assert fired == 2 and rule.fires == 2

    def test_rule_targeting_fields(self):
        rule = FaultRule(kind="nan", bucket=(32, 8), tier="pallas+searched")
        assert rule.matches((32, 8), 3, [1, 2], "pallas+searched")
        assert not rule.matches((16, 4), 3, [1, 2], "pallas+searched")
        assert not rule.matches((32, 8), 3, [1, 2], "jnp+default")

    def test_invalid_config_rejected(self):
        with pytest.raises(ValueError, match="kind"):
            FaultRule(kind="segfault")
        with pytest.raises(ValueError, match="p_exception"):
            FaultInjector(p_exception=1.5)
        with pytest.raises(ValueError, match="sum"):
            FaultInjector(p_exception=0.6, p_nan=0.6)

    def test_corrupt_output_fraction(self):
        inj = FaultInjector(nan_fraction=0.25)
        out = inj.corrupt_output(np.zeros((8, 8), np.float32))
        frac = float(np.isnan(out).mean())
        assert frac == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# Chaos acceptance: the headline isolation proof
# ---------------------------------------------------------------------------


class TestChaosAcceptance:
    def test_1000_request_stream_20pct_poisoned(self, params):
        """ISSUE acceptance: a 1000-request stream with 20% poisoned
        requests completes with submit() never raising, every non-ok
        result typed, EngineStats counters matching the per-result tally,
        and healthy outputs bit-identical to a fault-free run."""
        n_total = 1000
        policy = BucketPolicy(min_nodes=16, min_degree=4, max_graphs=4,
                              max_nodes=64)
        kernel_rids = []
        reqs = []
        for rid in range(n_total):
            if rid % 5 == 0:  # 200 poisoned, 40 per class
                cls = (rid // 5) % 5
                if cls == 0:  # NaN features
                    r = make_request(16, seed=rid, rid=rid)
                    r.x[0, 0] = np.nan
                elif cls == 1:  # float64 features
                    g = make_request(16, seed=rid, rid=rid)
                    r = Request(graph=g.graph, x=g.x.astype(np.float64),
                                rid=rid)
                elif cls == 2:  # broken CSR
                    g = make_request(16, seed=rid, rid=rid)
                    ci = np.array(g.graph.col_idx, copy=True)
                    ci[0] = 999
                    r = Request(
                        graph=CSRGraph(g.graph.row_ptr, ci, g.graph.values,
                                       g.graph.n_nodes),
                        x=g.x, rid=rid,
                    )
                elif cls == 3:  # oversized
                    r = make_request(100, seed=rid, rid=rid)
                else:  # sticky per-rid kernel fault
                    r = make_request(16, seed=rid, rid=rid)
                    kernel_rids.append(rid)
            else:
                r = make_request(16, seed=rid, rid=rid)
            reqs.append(r)

        inj = FaultInjector(
            rules=[FaultRule(kind="exception", rid=rid) for rid in kernel_rids]
        )
        eng = make_engine(params, policy=policy, fault_injector=inj)
        results = eng.submit(reqs)  # must never raise

        assert len(results) == n_total
        by_status: dict[str, int] = {}
        for req, res in zip(reqs, results):
            assert res.rid == req.rid
            by_status[res.status] = by_status.get(res.status, 0) + 1
            if res.ok:
                assert res.output is not None
                assert np.isfinite(res.output).all()
                assert res.error is None and res.error_type is None
            else:
                assert res.output is None
                assert res.error_type is not None, (
                    f"rid {res.rid}: non-ok result must carry a typed cause"
                )
                assert f"request {res.rid}" in res.error or res.error

        assert by_status.get("ok", 0) == 800
        assert by_status.get("rejected", 0) == 160  # nan/f64/csr/oversized
        assert by_status.get("failed", 0) == 40  # the kernel-fault rids
        failed_rids = {r.rid for r in results if r.status == "failed"}
        assert failed_rids == set(kernel_rids), (
            "exactly the poisoned rids fail; quarantine must not take "
            "healthy neighbors down"
        )

        stats = eng.stats()
        assert stats.n_requests == n_total
        assert stats.n_ok == 800
        assert stats.n_rejected == 160
        assert stats.n_failed == 40
        assert stats.n_ok + stats.n_rejected + stats.n_failed \
            + stats.n_degraded == n_total
        assert stats.n_solo_retries > 0  # quarantine actually ran
        assert stats.errors.get("invalid_request", 0) == 120
        assert stats.errors.get("oversized_graph", 0) == 40
        assert stats.errors.get("kernel_fault", 0) == 40

        # healthy outputs are bit-identical to a fault-free run of the
        # same requests (block-diagonal batching computes each graph
        # independently, so batch composition cannot change the answer)
        healthy = [r for r in reqs if r.rid % 5 != 0]
        ref_eng = make_engine(params, policy=policy)
        ref = {res.rid: res for res in ref_eng.submit(healthy)}
        for res in results:
            if res.status == "ok":
                assert np.array_equal(res.output, ref[res.rid].output), (
                    f"rid {res.rid}: chaos output differs from fault-free run"
                )
