"""Tests for the `repro.compile()` front-end: Program execution parity
with the pre-redesign string-policy path, the save/load artifact
round-trip, kernel-registry dispatch, the unified objective registry, and
the deprecation shim."""
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import (
    GNNLayerWorkload,
    objective_names,
    parse_dataflow,
    register_objective,
    search_dataflows,
    search_model,
    unregister_objective,
)
from repro.core.mapper import MappingResult
from repro.core.schedule import ExecSpec
from repro.core.simulator import BatchStats, ModelStats, RunStats
from repro.gnn import EllAdjacency, GNNConfig, gnn_forward, init_gnn
from repro.gnn import model as gnn_model
from repro.gnn.layers import LAYER_FNS, POLICIES, multiphase_matmul
from repro.graphs import load_dataset


@pytest.fixture(scope="module")
def graph():
    g, spec = load_dataset("mutag")
    return g, spec


@pytest.fixture(scope="module")
def workloads(graph):
    g, spec = graph
    return [
        GNNLayerWorkload(g.nnz, spec.n_features, 16, name="layer0"),
        GNNLayerWorkload(g.nnz, 16, 4, name="layer1"),
    ]


@pytest.fixture(scope="module")
def program(graph, workloads):
    g, _ = graph
    return repro.compile(workloads, graph=g, objective="cycles")


def _x(graph, f):
    g, _ = graph
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.normal(size=(g.n_nodes, f)).astype(np.float32))


# ---------------------------------------------------------------------------
# compile() + Program basics
# ---------------------------------------------------------------------------


class TestCompile:
    def test_returns_bound_program_with_stats(self, program, workloads):
        assert isinstance(program, repro.Program)
        assert program.n_layers == 2
        assert program.stats is not None and program.stats.cycles > 0
        assert program.schedule.stats is program.stats
        assert program.dims == [(wl.f_in, wl.g_out) for wl in workloads]
        assert program.fingerprint["v"] == workloads[0].v

    def test_run_executes_searched_schedule(self, program, graph, workloads):
        params = program.init(jax.random.PRNGKey(0))
        out = program.run(params, _x(graph, workloads[0].f_in))
        assert out.shape == (graph[0].n_nodes, 4)
        assert np.isfinite(np.asarray(out)).all()

    def test_loss_is_finite_and_differentiable(self, program, graph, workloads):
        g, _ = graph
        params = program.init(jax.random.PRNGKey(1))
        x = _x(graph, workloads[0].f_in)
        rng = np.random.default_rng(3)
        labels = jnp.asarray(rng.integers(0, 4, g.n_nodes).astype(np.int32))
        mask = jnp.asarray((rng.random(g.n_nodes) < 0.3).astype(np.float32))
        loss, grads = jax.value_and_grad(
            lambda p: program.loss(p, x, labels, mask)
        )(params)
        assert np.isfinite(float(loss))
        for leaf in jax.tree_util.tree_leaves(grads):
            assert np.isfinite(np.asarray(leaf)).all()

    def test_compile_from_gnn_config(self, graph):
        g, spec = graph
        cfg = GNNConfig(kind="sage", f_in=spec.n_features, hidden=8,
                        n_classes=4)
        prog = repro.compile(cfg, graph=g)
        assert prog.kind == "sage"
        assert prog.dims == cfg.dims
        out = prog.run(prog.init(jax.random.PRNGKey(0)),
                       _x(graph, spec.n_features))
        assert out.shape == (g.n_nodes, 4)

    def test_config_without_graph_rejected(self):
        with pytest.raises(ValueError, match="graph"):
            repro.compile(GNNConfig())

    def test_unbound_program_refuses_to_run(self, workloads):
        prog = repro.compile(workloads)
        with pytest.raises(ValueError, match="bind"):
            prog.run([], jnp.zeros((1, 1)))

    def test_explicit_schedule_skips_search_and_is_priced(
        self, graph, workloads
    ):
        g, _ = graph
        cfg = GNNConfig(f_in=workloads[0].f_in, hidden=16, n_classes=4,
                        policy="seq")
        sched = cfg.default_schedule()
        assert sched.stats is None
        prog = repro.compile(workloads, graph=g, schedule=sched)
        assert prog.stats is not None and prog.stats.cycles > 0

    def test_mismatched_schedule_shapes_rejected(self, graph, workloads):
        g, _ = graph
        bad = GNNConfig(f_in=7, hidden=5, n_classes=3).default_schedule()
        with pytest.raises(ValueError, match="shapes"):
            repro.compile(workloads, graph=g, schedule=bad)


# ---------------------------------------------------------------------------
# Numerics: Program.run == the pre-redesign string-policy gnn_forward
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", sorted(LAYER_FNS))
@pytest.mark.parametrize("order", ["AC", "CA"])
@pytest.mark.parametrize("policy", POLICIES)
def test_program_matches_string_policy_forward(graph, kind, order, policy):
    """The full policy x order x kind matrix: a Program built from the
    policy's default schedule reproduces the string-configured forward
    pass (itself pinned to the dense reference in test_layers_numerics)."""
    g, spec = graph
    cfg = GNNConfig(kind=kind, f_in=spec.n_features, hidden=8, n_classes=4,
                    policy=policy, order=order, band_size=32)
    prog = repro.compile(cfg, graph=g, schedule=cfg.default_schedule())
    params = init_gnn(cfg, jax.random.PRNGKey(7))
    x = _x(graph, spec.n_features)
    ref = gnn_forward(cfg, params, prog.adj, x,
                      schedule=cfg.default_schedule())
    out = prog.run(params, x)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5,
        err_msg=f"{kind}/{policy}/{order}",
    )


# ---------------------------------------------------------------------------
# Artifact round-trip
# ---------------------------------------------------------------------------


class TestArtifact:
    def test_save_load_round_trip(self, program, graph, tmp_path):
        g, _ = graph
        path = program.save(tmp_path / "model.program.json")
        loaded = repro.Program.load(path, graph=g)
        assert loaded.schedule == program.schedule
        assert loaded.hw == program.hw
        assert loaded.stats == program.stats  # predicted ModelStats intact
        assert loaded.fingerprint == program.fingerprint
        assert loaded.objective == program.objective

    def test_round_trip_is_byte_stable(self, program, tmp_path):
        first = program.save(tmp_path / "a.json").read_bytes()
        again = repro.Program.load(tmp_path / "a.json").save(
            tmp_path / "b.json"
        ).read_bytes()
        assert first == again

    def test_loaded_program_runs_identically(self, program, graph, workloads,
                                             tmp_path):
        g, _ = graph
        path = program.save(tmp_path / "p.json")
        loaded = repro.Program.load(path, graph=g)
        params = program.init(jax.random.PRNGKey(2))
        x = _x(graph, workloads[0].f_in)
        np.testing.assert_array_equal(
            np.asarray(program.run(params, x)),
            np.asarray(loaded.run(params, x)),
        )

    def test_fingerprint_mismatch_rejected(self, program, tmp_path):
        other, _ = load_dataset("cora")
        path = program.save(tmp_path / "p.json")
        with pytest.raises(ValueError, match="fingerprint"):
            repro.Program.load(path, graph=other)

    def test_not_a_program_artifact_rejected(self, tmp_path):
        p = tmp_path / "junk.json"
        p.write_text(json.dumps({"format": "something/else"}))
        with pytest.raises(ValueError, match="artifact"):
            repro.Program.load(p)


# ---------------------------------------------------------------------------
# Kernel registry dispatch + ExecSpec/kwargs conflicts
# ---------------------------------------------------------------------------


class TestDispatch:
    @pytest.fixture(scope="class")
    def operands(self, graph):
        g, spec = graph
        adj = EllAdjacency.from_csr(g)
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.normal(size=(g.n_nodes, spec.n_features))
                        .astype(np.float32))
        w = jnp.asarray(rng.normal(size=(spec.n_features, 8))
                        .astype(np.float32))
        return adj, x, w

    def test_conflicting_spec_kwargs_raise(self, operands):
        adj, x, w = operands
        spec = ExecSpec(policy="sp_opt", order="AC", band_size=64)
        for bad in (dict(policy="seq"), dict(order="CA"),
                    dict(band_size=128), dict(use_pallas=True)):
            with pytest.raises(ValueError, match="conflicting"):
                multiphase_matmul(adj, x, w, spec=spec, **bad)

    def test_matching_spec_kwargs_allowed(self, operands):
        adj, x, w = operands
        spec = ExecSpec(policy="sp_opt", order="AC", band_size=64)
        out = multiphase_matmul(adj, x, w, spec=spec, policy="sp_opt",
                                band_size=64)
        ref = multiphase_matmul(adj, x, w, spec=spec)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_unknown_policy_and_order_raise(self, operands):
        adj, x, w = operands
        with pytest.raises(ValueError, match="policy"):
            multiphase_matmul(adj, x, w, policy="bogus")
        with pytest.raises(ValueError, match="order"):
            multiphase_matmul(adj, x, w, policy="seq", order="ZZ")

    def test_no_policy_string_dispatch_left_in_layers(self):
        """The acceptance criterion: dispatch is registry-driven."""
        import inspect
        import repro.gnn.layers as layers

        src = inspect.getsource(layers.multiphase_matmul)
        assert "if policy ==" not in src and 'policy == "' not in src


# ---------------------------------------------------------------------------
# Objective registry: one consistent error everywhere, extensible
# ---------------------------------------------------------------------------


def _run_stats(cycles=2.0, energy=3.0):
    return RunStats(
        dataflow="x", cycles=cycles, energy_pj=energy, energy_breakdown={},
        gb_accesses={}, rf_accesses=0.0, buffering_elems=0.0, macs=0.0,
        pe_utilization=1.0, stall_factor=1.0, agg_cycles=1.0, cmb_cycles=1.0,
    )


class TestObjectives:
    def test_unknown_objective_error_is_consistent(self, workloads):
        df = parse_dataflow("Seq_AC(VsFtNt, VsGtFt)")
        mapping = MappingResult(df, _run_stats())
        batch = BatchStats(
            cycles=np.ones(2), energy_pj=np.ones(2),
            legal=np.ones(2, dtype=bool), agg_cycles=np.ones(2),
            cmb_cycles=np.ones(2), macs=np.ones(2),
        )
        model = ModelStats([_run_stats()], [])
        for fail in (
            lambda: mapping.objective("bogus"),
            lambda: batch.objective("bogus"),
            lambda: model.objective("bogus"),
            lambda: search_dataflows(workloads[0], objective="bogus"),
        ):
            with pytest.raises(ValueError, match="valid objectives") as e:
                fail()
            for name in ("cycles", "energy", "edp"):
                assert name in str(e.value)

    def test_model_search_rejects_non_additive(self, workloads):
        with pytest.raises(ValueError, match="additive"):
            search_model(workloads, objective="edp")

    def test_known_objectives_agree_with_closed_forms(self):
        model = ModelStats([_run_stats(cycles=2.0, energy=3.0)], [])
        assert model.objective("cycles") == 2.0
        assert model.objective("energy") == 3.0
        assert model.objective("edp") == 6.0

    def test_registered_objective_usable_everywhere(self):
        register_objective(
            "test_sum", lambda c, e: c + e, additive=True,
            description="test-only",
        )
        try:
            assert "test_sum" in objective_names(additive_only=True)
            model = ModelStats([_run_stats(cycles=2.0, energy=3.0)], [])
            assert model.objective("test_sum") == 5.0
            mapping = MappingResult(
                parse_dataflow("Seq_AC(VsFtNt, VsGtFt)"), _run_stats()
            )
            assert mapping.objective("test_sum") == 5.0
        finally:
            unregister_objective("test_sum")
        with pytest.raises(ValueError, match="valid objectives"):
            model.objective("test_sum")

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            register_objective("cycles", lambda c, e: c)


# ---------------------------------------------------------------------------
# Deprecation shim
# ---------------------------------------------------------------------------


def test_string_policy_shim_warns_once(graph, monkeypatch):
    g, spec = graph
    monkeypatch.setattr(gnn_model, "_POLICY_SHIM_WARNED", False)
    cfg = GNNConfig(kind="gcn", f_in=spec.n_features, n_classes=4)
    adj = EllAdjacency.from_csr(g)
    params = init_gnn(cfg, jax.random.PRNGKey(0))
    x = _x(graph, spec.n_features)
    with pytest.warns(DeprecationWarning, match="repro.compile"):
        gnn_forward(cfg, params, adj, x)
    with warnings.catch_warnings():
        # a second shim warning would raise
        warnings.simplefilter("error", DeprecationWarning)
        gnn_forward(cfg, params, adj, x)


def test_schedule_path_does_not_warn(graph, monkeypatch):
    g, spec = graph
    monkeypatch.setattr(gnn_model, "_POLICY_SHIM_WARNED", False)
    cfg = GNNConfig(kind="gcn", f_in=spec.n_features, n_classes=4)
    adj = EllAdjacency.from_csr(g)
    params = init_gnn(cfg, jax.random.PRNGKey(0))
    x = _x(graph, spec.n_features)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        gnn_forward(cfg, params, adj, x, schedule=cfg.default_schedule())
