"""Async front-end + multi-device bucket placement tests.

The in-process lane covers the :class:`~repro.runtime.scheduler.BucketPlacer`
policy, the profile heat/subset helpers, the backlog-proportional
``retry_after_s`` hint, and the single-device ``AsyncEngine`` contract
(admission before queueing, window flushes, per-request futures).

The multi-device lane runs in a subprocess under
``--xla_force_host_platform_device_count=4`` (so the override cannot
pollute this process's jax) and asserts the three placement properties
the ISSUE names: (a) distinct buckets land on distinct devices,
(b) outputs are bit-identical to the single-device sync engine, and
(c) a faulted request on one device never perturbs results on another.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.graphs import TABLE4, BucketPolicy
from repro.graphs.batching import TrafficProfile
from repro.graphs.datasets import make_graph
from repro.runtime import (
    AsyncEngine,
    BucketPlacer,
    InferenceEngine,
    Request,
)
from repro.runtime.resilience import backlog_retry_after


# ---------------------------------------------------------------------------
# BucketPlacer policy
# ---------------------------------------------------------------------------


def test_placer_distinct_buckets_distinct_devices():
    p = BucketPlacer(4)
    for i, b in enumerate([(32, 8), (64, 8), (128, 16), (256, 16)]):
        p.record(b, 10)
    homes = [p.assignment[b][0] for b in p.assignment]
    assert sorted(homes) == [0, 1, 2, 3]


def test_placer_hot_bucket_gets_replica():
    p = BucketPlacer(4, replicas=2)
    p.record((32, 8), 1)
    p.record((64, 8), 1)
    # (32, 8) becomes far hotter than a fair 1/4 share -> second device
    p.record((32, 8), 100)
    assert len(p.assignment[(32, 8)]) == 2
    assert len(set(p.assignment[(32, 8)])) == 2
    # the cold bucket stays single-homed
    assert len(p.assignment[(64, 8)]) == 1


def test_placer_replicas_capped_by_knob_and_devices():
    p = BucketPlacer(2, replicas=8)  # knob beyond the mesh clamps
    assert p.replicas == 2
    p.record((32, 8), 1000)
    p.record((32, 8), 1000)
    assert len(p.assignment[(32, 8)]) <= 2


def test_placer_pick_prefers_least_outstanding_replica():
    p = BucketPlacer(2, replicas=2)
    p.record((32, 8), 100)
    p.record((32, 8), 100)  # hot -> both devices
    assert len(p.assignment[(32, 8)]) == 2
    d0 = p.pick((32, 8), 10)
    d1 = p.pick((32, 8), 1)  # first pick is busier now
    assert d1 != d0
    p.done(d0, 10)
    p.done(d1, 1)
    assert p.outstanding == [0, 0]


def test_placer_buckets_for_covers_assignment():
    p = BucketPlacer(2)
    p.record((32, 8), 1)
    p.record((64, 8), 1)
    all_buckets = set()
    for d in range(2):
        all_buckets |= p.buckets_for(d)
    assert all_buckets == {(32, 8), (64, 8)}


# ---------------------------------------------------------------------------
# Satellite: backlog-proportional retry_after + profile helpers
# ---------------------------------------------------------------------------


def test_backlog_retry_after_scales_with_queue_depth():
    shallow = backlog_retry_after(10, 0.02, 64)
    deep = backlog_retry_after(640, 0.02, 64)
    assert shallow == pytest.approx(0.02)  # one batch drains it
    assert deep == pytest.approx(0.2)  # ten batches
    assert backlog_retry_after(0, 0.02, 64) == pytest.approx(0.02)  # floor


def test_profile_heat_orders_hottest_first():
    prof = TrafficProfile()
    prof.record_request((32, 8), 5)
    prof.record_request((64, 8), 50)
    assert prof.heat()[0] == ((64, 8), 50)


def test_profile_subset_filters_both_ledgers():
    prof = TrafficProfile()
    prof.record_request((32, 8), 5)
    prof.record_request((64, 8), 7)
    prof.record_batch((32, 8), 4)
    prof.record_batch((64, 8), 8)
    sub = prof.subset({(32, 8)})
    assert sub.requests == {(32, 8): 5}
    assert sub.batches == {(32, 8, 4): 1}
    # the original is untouched
    assert prof.requests[(64, 8)] == 7


# ---------------------------------------------------------------------------
# AsyncEngine, single device (in-process)
# ---------------------------------------------------------------------------

DIMS = [(16, 8)]


def _stream(n, f_in=16, seed=0, names=("mutag", "imdb-bin")):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        g = make_graph(TABLE4[names[i % len(names)]], rng)
        x = rng.normal(size=(g.n_nodes, f_in)).astype(np.float32)
        reqs.append(Request(graph=g, x=x, rid=i))
    return reqs


@pytest.fixture(scope="module")
def params():
    return InferenceEngine(DIMS).init(jax.random.PRNGKey(0))


def test_async_single_device_matches_sync(params):
    reqs = _stream(8)
    sync = InferenceEngine(DIMS, params)
    sync_res = sync.submit(reqs)
    with AsyncEngine(DIMS, params, window_ms=5.0) as a:
        res = a.submit(reqs)
    for r, s in zip(res, sync_res):
        assert r.status == s.status == "ok"
        np.testing.assert_array_equal(r.output, s.output)
    st = a.stats()
    assert st.n_requests == 8
    assert st.n_ok == 8
    assert st.p99_ms >= st.p50_ms > 0


def test_async_admission_before_queueing(params):
    """Malformed and oversized requests resolve immediately as rejected —
    they never occupy a window slot or reach a device."""
    from repro.graphs import from_edges

    policy = BucketPolicy(max_nodes=64)
    good = _stream(1, names=("mutag",))[0]
    n_big = 100  # deterministic chain over the 64-node cap
    big = from_edges(
        n_big, np.arange(n_big - 1), np.arange(1, n_big)
    )
    oversized = Request(
        graph=big,
        x=np.zeros((big.n_nodes, 16), np.float32),
        rid=100,
    )
    bad_x = Request(graph=good.graph, x=np.zeros((3, 16), np.float32), rid=101)
    with AsyncEngine(DIMS, params, window_ms=5.0, policy=policy) as a:
        f_bad = a.submit_async(bad_x)
        f_big = a.submit_async(oversized)
        assert f_bad.result(timeout=1).status == "rejected"
        assert f_big.result(timeout=1).status == "rejected"
        ok = a.submit_async(good).result(timeout=60)
        assert ok.status == "ok"
    st = a.stats()
    assert st.n_rejected == 2
    assert st.errors.get("invalid_request") == 1
    assert st.errors.get("oversized_graph") == 1


def test_async_queue_cap_sheds_with_backlog_hint(params):
    reqs = _stream(6, names=("mutag",))
    with AsyncEngine(
        DIMS, params, window_ms=200.0, max_queue_graphs=4
    ) as a:
        futs = [a.submit_async(r) for r in reqs]
        shed = [f.result(timeout=120) for f in futs[4:]]
        served = [f.result(timeout=120) for f in futs[:4]]
    assert all(r.status == "rejected" for r in shed)
    assert all(r.error_type == "engine_overloaded" for r in shed)
    assert all(r.retry_after_s is not None and r.retry_after_s > 0
               for r in shed)
    assert all(r.status == "ok" for r in served)


def test_async_window_flushes_on_fill_before_deadline(params):
    """A window that reaches max_graphs flushes immediately — a huge
    window_ms must not delay a full batch."""
    policy = BucketPolicy(max_graphs=4)
    reqs = _stream(4, names=("mutag",))
    with AsyncEngine(
        DIMS, params, window_ms=60_000.0, policy=policy
    ) as a:
        res = a.submit(reqs)  # would hang for a minute if fill didn't flush
    assert all(r.status == "ok" for r in res)
    assert a.stats().n_flushes_full >= 1


def test_async_deadline_enforced_at_window(params):
    """A request whose deadline expires while parked in the window fails
    typed at the flush boundary (PR 6 contract), not silently late."""
    req = _stream(1, names=("mutag",))[0]
    expired = Request(graph=req.graph, x=req.x, rid=0, deadline_s=1e-9)
    with AsyncEngine(DIMS, params, window_ms=30.0) as a:
        r = a.submit_async(expired).result(timeout=60)
    assert r.status == "failed"
    assert r.error_type == "deadline_exceeded"


def test_async_per_request_latency_includes_queue_wait(params):
    """Per-request latency is enqueue -> result: a request parked for the
    whole window must be charged at least the window it waited."""
    req = _stream(1, names=("mutag",))[0]
    with AsyncEngine(DIMS, params, window_ms=80.0) as a:
        a.submit([req])  # warm the bucket (compile off the clock)
        r = a.submit_async(
            Request(graph=req.graph, x=req.x, rid=1)
        ).result(timeout=60)
    assert r.status == "ok"
    # lone request -> deadline flush -> waited ~the full 80 ms window
    assert r.latency_s >= 0.05


def test_async_precompile_warms_assigned_buckets(tmp_path, params):
    """precompile() on a revived engine loads from the shared store and
    leaves the first real request trace-free (PR 7 contract)."""
    from repro.api import trace_count
    from repro.runtime import ProgramStore

    reqs = _stream(6)
    with AsyncEngine(
        DIMS, params, window_ms=5.0, store=ProgramStore(tmp_path)
    ) as a:
        assert all(r.ok for r in a.submit(reqs))
    # revive: fresh engine on the same store
    with AsyncEngine(
        DIMS, params, window_ms=5.0, store=ProgramStore(tmp_path)
    ) as b:
        rep = b.precompile()
        assert rep.n_shapes > 0
        assert rep.n_searches == 0  # every program came from the store
        before = trace_count()
        res = b.submit(reqs)
        assert all(r.ok for r in res)
        assert trace_count() == before  # warm path: zero new traces


# ---------------------------------------------------------------------------
# Multi-device lane (subprocess, 4 forced host devices)
# ---------------------------------------------------------------------------

MULTI_DEVICE_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import numpy as np
    from repro.graphs import TABLE4
    from repro.graphs.datasets import make_graph
    from repro.runtime import (
        AsyncEngine, FaultInjector, FaultRule, InferenceEngine, Request,
    )

    assert jax.device_count() == 4, jax.devices()
    DIMS = [(16, 8)]
    rng = np.random.default_rng(0)
    names = ["mutag", "imdb-bin", "collab"]
    reqs = []
    for i in range(24):
        g = make_graph(TABLE4[names[i % 3]], rng)
        x = rng.normal(size=(g.n_nodes, 16)).astype(np.float32)
        reqs.append(Request(graph=g, x=x, rid=i))

    sync = InferenceEngine(DIMS)
    params = sync.init(jax.random.PRNGKey(0))
    sync_res = sync.submit(reqs)

    # (a) + (b): distinct buckets -> distinct devices, outputs bit-identical
    with AsyncEngine(DIMS, params, window_ms=10.0) as a:
        res = a.submit(reqs)
    placement = a.placement()
    homes = [devs[0] for devs in placement.values()]
    assert len(placement) >= 3, placement
    # distinct buckets spread one per device while free devices remain
    assert len(set(homes)) == min(len(homes), 4), (
        "distinct buckets must land on distinct devices: %r" % placement)
    for r, s in zip(res, sync_res):
        assert r.status == s.status == "ok", (r.rid, r.status, r.error)
        assert np.array_equal(r.output, s.output), r.rid
    assert len({r.device for r in res}) >= 3, {r.device for r in res}
    print("PLACEMENT-OK")

    # (c) fault isolation across devices: a sticky injected fault pinned to
    # one bucket (hence one device) fails those requests typed, while every
    # request on the other devices stays bit-identical to the fault-free run
    target = sorted(
        set((r.bucket for r in res)), key=lambda b: (b[0], b[1]))[0]
    inj = FaultInjector(rules=[
        FaultRule(kind="exception", bucket=tuple(target), max_fires=None),
    ])
    with AsyncEngine(
        DIMS, params, window_ms=10.0, fault_injector=inj,
        check_numerics=True,
    ) as c:
        chaos = c.submit(reqs)
    n_failed = 0
    for r, clean in zip(chaos, res):
        if clean.bucket == target:
            assert r.status == "failed", (r.rid, r.status)
            assert r.error_type == "kernel_fault", r.error_type
            n_failed += 1
        else:
            assert r.status == "ok", (r.rid, r.status, r.error)
            assert r.device == clean.device, (r.device, clean.device)
            assert np.array_equal(r.output, clean.output), r.rid
    assert n_failed > 0
    print("FAULT-ISOLATION-OK")
    """
)


def test_multi_device_placement_identity_and_isolation():
    """ISSUE satellite: under 4 forced host devices — (a) distinct buckets
    on distinct devices, (b) bit-identical to the sync single-device
    engine, (c) faults on one device never perturb another."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", MULTI_DEVICE_SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert "PLACEMENT-OK" in r.stdout, (r.stdout[-2000:], r.stderr[-2000:])
    assert "FAULT-ISOLATION-OK" in r.stdout, (
        r.stdout[-2000:], r.stderr[-2000:])
