"""Numerics matrix for `repro.gnn.layers`: every policy x order x kind
combination must match a dense reference built from `aggregate_full` on a
random CSR graph, including when ``v_pad % band_size != 0``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.gnn import EllAdjacency, POLICIES, init_layer, multiphase_matmul
from repro.gnn.layers import LAYER_FNS, aggregate_full
from repro.graphs import from_edges

V = 157  # prime: v_pad % band_size != 0 for every power-of-two band
F_IN, F_OUT = 20, 12
BAND = 32  # 157 % 32 != 0


@pytest.fixture(scope="module")
def graph():
    rng = np.random.default_rng(11)
    return from_edges(V, rng.integers(0, V, 600), rng.integers(0, V, 600))


@pytest.fixture(scope="module")
def adj(graph):
    return EllAdjacency.from_csr(graph)


@pytest.fixture(scope="module")
def x(graph):
    rng = np.random.default_rng(12)
    return jnp.asarray(rng.normal(size=(V, F_IN)).astype(np.float32))


def dense_layer_reference(kind, params, adj, x):
    """The layer math with the aggregation done by dense `aggregate_full`."""
    agg = aggregate_full(adj, x)[: adj.n_nodes]
    xs = x[: adj.n_nodes]
    if kind == "gcn":
        return jax.nn.relu(agg @ params["w"] + params["b"])
    if kind == "sage":
        return jax.nn.relu(
            xs @ params["w_top"] + agg @ params["w_bottom"] + params["b"]
        )
    if kind == "gin":
        unit = EllAdjacency(
            adj.indices, (adj.weights > 0).astype(x.dtype), adj.n_nodes
        )
        s = aggregate_full(unit, x)[: adj.n_nodes]
        h = jax.nn.relu(
            s @ params["w1"]
            + (1.0 + params["eps"]) * xs @ params["w1"]
            + params["b1"]
        )
        return jax.nn.relu(h @ params["w2"] + params["b2"])
    raise KeyError(kind)


@pytest.mark.parametrize("kind", sorted(LAYER_FNS))
@pytest.mark.parametrize("order", ["AC", "CA"])
@pytest.mark.parametrize("policy", POLICIES)
def test_policy_order_kind_matrix(kind, order, policy, adj, x):
    """`pp` with mesh=None exercises its documented sp_generic fallback."""
    params = init_layer(kind, jax.random.PRNGKey(42), F_IN, F_OUT)
    ref = dense_layer_reference(kind, params, adj, x)
    out = LAYER_FNS[kind](
        params, adj, x, policy=policy, order=order, band_size=BAND
    )
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(ref),
        rtol=2e-4,
        atol=2e-4,
        err_msg=f"{kind}/{policy}/{order}",
    )


@pytest.mark.parametrize("order", ["AC", "CA"])
@pytest.mark.parametrize("policy", ["seq", "sp_opt"])
def test_pallas_lowering_matches(policy, order, adj, x):
    """The Pallas-backed paths (spmm for seq, fused agg+cmb for sp_opt) with
    schedule-style block shapes agree with the jnp reference."""
    rng = np.random.default_rng(13)
    w = jnp.asarray(rng.normal(size=(F_IN, F_OUT)).astype(np.float32))
    ref = multiphase_matmul(adj, x, w, policy="seq", order="AC")
    out = multiphase_matmul(
        adj, x, w, policy=policy, order=order,
        band_size=BAND, block_f=8, use_pallas=True,
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4
    )


def test_ragged_band_sizes_agree(adj, x):
    rng = np.random.default_rng(14)
    w = jnp.asarray(rng.normal(size=(F_IN, F_OUT)).astype(np.float32))
    ref = multiphase_matmul(adj, x, w, policy="seq", order="AC")
    for band in (7, 13, 32, 100, 1024):  # none divide v_pad evenly
        out = multiphase_matmul(
            adj, x, w, policy="sp_generic", order="AC", band_size=band
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4,
            err_msg=f"band={band}",
        )
