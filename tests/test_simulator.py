"""Inter-phase simulator tests: Table 3 semantics + paper claims + property
tests over random workloads."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (
    AcceleratorConfig,
    GNNDataflow,
    GNNLayerWorkload,
    InterPhase,
    PhaseOrder,
    intra,
    named_dataflow,
    named_skeleton,
    optimize_tiles,
    simulate,
)
from repro.graphs import load_dataset

HW = AcceleratorConfig()
RNG = np.random.default_rng(0)


def wl_random(v=256, f=64, g=16, max_deg=8, rng=RNG):
    nnz = rng.integers(1, max_deg + 1, size=v)
    return GNNLayerWorkload(nnz, f, g)


def df_seq(**tiles):
    return named_dataflow("Seq-Nt", **tiles)


class TestInterPhaseSemantics:
    wl = wl_random()

    def test_seq_is_sum_of_phases_plus_transfer(self):
        df = df_seq(T_V_AGG=8, T_F_AGG=16, T_V_CMB=8, T_G=8, T_F_CMB=8)
        s = simulate(df, self.wl, HW)
        assert s.cycles >= s.agg_cycles + s.cmb_cycles
        # intermediate transfer is serialized at the phase boundary
        t_xfer = 2 * self.wl.v * self.wl.f_in / HW.gb_bandwidth
        assert s.cycles == pytest.approx(s.agg_cycles + s.cmb_cycles + t_xfer, rel=0.3)

    def test_sp_optimized_saves_transfer_and_int_traffic(self):
        # cmb tiles with T_G = G so the intermediate is read exactly once
        seq = simulate(
            df_seq(T_V_AGG=8, T_F_AGG=16, T_V_CMB=8, T_G=16, T_F_CMB=4),
            self.wl,
            HW,
        )
        spo = simulate(
            named_dataflow("EnGN", T_V_AGG=8, T_F_AGG=16, T_V_CMB=8, T_F_CMB=16),
            self.wl,
            HW,
        )
        assert "int" not in spo.gb_accesses
        assert seq.gb_accesses["int"] == 2 * self.wl.v * self.wl.f_in

    def test_pp_uses_pingpong_buffer_energy(self):
        df = named_dataflow("HyGCN", T_F_AGG=16, T_V_CMB=8, T_G=8)
        s = simulate(df, self.wl, HW)
        seq = simulate(df_seq(T_V_AGG=8, T_F_AGG=16, T_V_CMB=8, T_G=8), self.wl, HW)
        # same int access count, cheaper per access (small ping-pong buffer)
        assert s.gb_accesses["int"] == seq.gb_accesses["int"]
        assert s.energy_breakdown["gb_int"] < seq.energy_breakdown["gb_int"]

    def test_pp_pipeline_shorter_than_sum_on_balanced_load(self):
        df = named_dataflow("HyGCN", T_F_AGG=16, T_V_CMB=4, T_G=16, T_F_CMB=4)
        s = simulate(df, self.wl, HW)
        # pipelining overlaps the phases: total < serialized phase times
        assert s.cycles < s.agg_cycles + s.cmb_cycles

    def test_macs_identical_across_dataflows(self):
        flows = [
            df_seq(T_V_AGG=8, T_F_AGG=16),
            named_dataflow("EnGN", T_V_AGG=8, T_F_AGG=16, T_V_CMB=8, T_F_CMB=16),
            named_dataflow("HyGCN", T_F_AGG=16, T_V_CMB=8, T_G=8),
        ]
        macs = {simulate(d, self.wl, HW).macs for d in flows}
        assert len(macs) == 1

    def test_ca_order_changes_agg_macs(self):
        wl = wl_random(f=64, g=16)
        ac = simulate(df_seq(T_V_AGG=8, T_F_AGG=16), wl, HW)
        ca = simulate(
            named_dataflow("AWB-GCN", T_F_AGG=8, T_V_AGG=16, T_V_CMB=16), wl, HW
        )
        agg_ac, cmb = wl.macs(PhaseOrder.AC)
        agg_ca, _ = wl.macs(PhaseOrder.CA)
        assert ac.macs == agg_ac + cmb
        assert ca.macs == agg_ca + cmb
        assert agg_ca < agg_ac  # G < F: combination-first shrinks aggregation


class TestPaperClaims:
    """Qualitative claims from Sec. 5.2 / 5.3, on the paper's datasets."""

    @pytest.fixture(scope="class")
    def citeseer(self):
        g, spec = load_dataset("citeseer")
        return GNNLayerWorkload(g.nnz, spec.n_features, 16, name="citeseer")

    @pytest.fixture(scope="class")
    def collab(self):
        g, spec = load_dataset("collab")
        return GNNLayerWorkload(g.nnz, spec.n_features, 16, name="collab")

    def test_high_vs_sp_pays_psum_and_runtime(self, citeseer):
        """Sec 5.4: the rigid T_F=T_N=1 mapping has huge runtime + psum
        energy — the case for configurable tile sizes."""
        best = optimize_tiles(named_skeleton("SP-FsNt-Fs"), citeseer, HW, "cycles")
        rigid = optimize_tiles(named_skeleton("High-Vs-SP"), citeseer, HW, "cycles")
        assert rigid.stats.cycles > 1.5 * best.stats.cycles
        assert rigid.stats.energy_pj > 1.5 * best.stats.energy_pj
        assert rigid.stats.gb_accesses.get("psum", 0) > 0

    def test_pp_load_imbalance_on_dense_graphs(self, collab):
        """Sec 5.2.1: Collab PP is worse than Seq (agg/cmb imbalance)."""
        seq = optimize_tiles(named_skeleton("Seq-Nt"), collab, HW, "cycles")
        pp = optimize_tiles(
            named_skeleton("PP-Nt-Vt/sl"), collab, HW, "cycles", pe_splits=(0.5,)
        )
        assert pp.stats.cycles > seq.stats.cycles

    def test_pe_allocation_matches_phase_balance(self, collab, citeseer):
        """Fig 12: agg-heavy Collab suffers at 25-75; cmb-heavy Citeseer
        suffers at 75-25."""
        def t(wl, split):
            return optimize_tiles(
                named_skeleton("PP-Nt-Vt/sl"), wl, HW, "cycles", pe_splits=(split,)
            ).stats.cycles

        assert t(collab, 0.25) > 1.5 * t(collab, 0.75)
        assert t(citeseer, 0.75) > 1.5 * t(citeseer, 0.25)

    def test_pp_suffers_most_at_low_bandwidth(self, citeseer):
        """Fig 13: with tiles fixed, PP degrades more than Seq when GB
        bandwidth shrinks (phases share the bandwidth)."""
        def degrade(name):
            res = optimize_tiles(named_skeleton(name), citeseer, HW, "cycles",
                                 pe_splits=(0.5,))
            lo = simulate(res.dataflow, citeseer, AcceleratorConfig(gb_bandwidth=64))
            return lo.cycles / res.stats.cycles

        assert degrade("PP-Nt-Vt/sl") > degrade("Seq-Nt")

    def test_evil_rows_punish_high_tv(self):
        """Sec 5.2.1: one dense row stalls high-T_V SP dataflows."""
        nnz = np.full(4096, 2)
        nnz[7] = 2048  # the evil row
        wl = GNNLayerWorkload(nnz, 256, 16)
        even = GNNLayerWorkload(np.full(4096, 2), 256, 16)
        hi = named_skeleton("High-Vs-SP")
        slow = optimize_tiles(hi, wl, HW, "cycles").stats.cycles
        fast = optimize_tiles(hi, even, HW, "cycles").stats.cycles
        assert slow > 5 * fast


# ---------------------------------------------------------------------------
# Property tests
# ---------------------------------------------------------------------------

tile_pow2 = st.sampled_from([1, 2, 4, 8, 16])


@settings(max_examples=60, deadline=None)
@given(
    v=st.integers(4, 300),
    f=st.integers(1, 200),
    g=st.integers(1, 64),
    max_deg=st.integers(1, 40),
    tv=tile_pow2,
    tf=tile_pow2,
    tg=tile_pow2,
    seed=st.integers(0, 2**31 - 1),
)
def test_simulation_invariants(v, f, g, max_deg, tv, tf, tg, seed):
    from hypothesis import assume

    assume(tv * tf * tg <= HW.n_pes)  # combination footprint must fit
    rng = np.random.default_rng(seed)
    wl = GNNLayerWorkload(rng.integers(1, max_deg + 1, size=v), f, g)
    flows = [
        named_dataflow("Seq-Nt", T_V_AGG=tv, T_F_AGG=tf, T_V_CMB=tv, T_G=tg, T_F_CMB=tf),
        named_dataflow("EnGN", T_V_AGG=tv, T_F_AGG=tf, T_V_CMB=tv, T_F_CMB=tf),
        named_dataflow("HyGCN", T_F_AGG=tf, T_V_CMB=tv, T_G=tg),
        named_dataflow("AWB-GCN", T_F_AGG=tf, T_V_AGG=tv, T_V_CMB=tv),
    ]
    stats = [simulate(d, wl, HW) for d in flows]
    agg_m, cmb_m = wl.macs(PhaseOrder.AC)
    for d, s in zip(flows, stats):
        assert s.cycles > 0 and np.isfinite(s.cycles)
        assert s.energy_pj > 0 and np.isfinite(s.energy_pj)
        assert 0 <= s.pe_utilization <= 1
        assert s.stall_factor >= 0.99
        assert s.buffering_elems >= 0
        # work conservation: the dataflow never changes the MAC count
        if d.order == PhaseOrder.AC:
            assert s.macs == agg_m + cmb_m
        # a single PE-cycle can do at most one MAC
        assert s.macs <= s.cycles * HW.n_pes * s.stall_factor + 1e-6
    # Seq pays at least the intermediate through the GB; SP-opt never does
    assert stats[0].gb_accesses["int"] >= 2 * v * f
    assert "int" not in stats[1].gb_accesses


@settings(max_examples=30, deadline=None)
@given(
    v=st.integers(32, 400),
    f=st.integers(8, 256),
    seed=st.integers(0, 2**31 - 1),
)
def test_mapper_finds_legal_mappings(v, f, seed):
    rng = np.random.default_rng(seed)
    wl = GNNLayerWorkload(rng.integers(1, 9, size=v), f, 16)
    for name in ("Seq-Nt", "SP-FsNt-Fs", "PP-Nt-Vt/sl"):
        res = optimize_tiles(named_skeleton(name), wl, HW, "edp")
        res.dataflow.validate()
        assert res.stats.cycles > 0


class TestGBCapacitySpill:
    """The gb_capacity check prices each strategy's own *live* intermediate
    footprint: the whole V x F matrix for Seq, but only the pipelined chunk
    (Table 3's buffering) for SP-Generic / PP — and charges DRAM energy per
    intermediate access when that footprint does not fit."""

    wl = wl_random(v=256, f=64, g=16)

    def _int_energy_per_access(self, df, hw):
        s = simulate(df, self.wl, hw)
        return s.energy_breakdown["gb_int"] / s.gb_accesses["int"]

    def seq_df(self):
        return df_seq(T_V_AGG=8, T_F_AGG=16, T_V_CMB=8, T_G=8, T_F_CMB=8)

    def sp_df(self):
        # SP-Generic at row granularity: chunk footprint = band x F
        return named_dataflow("SP-VsNt-Vs", T_V_AGG=8, T_F_AGG=16,
                              T_V_CMB=8, T_G=8, T_F_CMB=8)

    def pp_df(self):
        return named_dataflow("PP-Nt-Vt/sl", T_F_AGG=16, T_V_CMB=8, T_G=8)

    def test_seq_spills_when_full_matrix_exceeds_capacity(self):
        df = self.seq_df()
        full_bytes = self.wl.v * self.wl.f_in * 4
        fits = AcceleratorConfig(gb_capacity_bytes=full_bytes)
        spills = AcceleratorConfig(gb_capacity_bytes=full_bytes - 1)
        assert self._int_energy_per_access(df, fits) == fits.gb_energy_pj
        assert self._int_energy_per_access(df, spills) == spills.dram_energy_pj

    def test_sp_generic_footprint_is_the_chunk_not_vxf(self):
        df = self.sp_df()
        s = simulate(df, self.wl, AcceleratorConfig())
        chunk_bytes = int(s.buffering_elems) * 4
        full_bytes = self.wl.v * self.wl.f_in * 4
        assert chunk_bytes < full_bytes  # pipelined footprint is a band
        # capacity between chunk and full matrix: the chunk fits -> GB price
        mid = AcceleratorConfig(gb_capacity_bytes=chunk_bytes)
        assert self._int_energy_per_access(df, mid) == mid.gb_energy_pj
        # smaller than the chunk itself -> DRAM price (this was the
        # asymmetry: pipelined paths never consulted gb_capacity at all)
        tiny = AcceleratorConfig(gb_capacity_bytes=chunk_bytes - 1)
        assert self._int_energy_per_access(df, tiny) == tiny.dram_energy_pj

    def test_pp_pingpong_buffer_spills_only_below_its_own_footprint(self):
        df = self.pp_df()
        s = simulate(df, self.wl, AcceleratorConfig())
        buf_bytes = int(s.buffering_elems) * 4  # 2 x pipelined chunk
        fits = AcceleratorConfig(gb_capacity_bytes=buf_bytes)
        assert self._int_energy_per_access(df, fits) == pytest.approx(
            fits.buffer_access_energy(buf_bytes)
        )
        tiny = AcceleratorConfig(gb_capacity_bytes=buf_bytes - 1)
        assert self._int_energy_per_access(df, tiny) == tiny.dram_energy_pj

    def test_sp_optimized_is_exempt(self):
        # the fused dataflow never materializes the intermediate at all, so
        # no capacity (however small) can charge it DRAM traffic
        df = named_dataflow("EnGN", T_V_AGG=8, T_F_AGG=16, T_V_CMB=8, T_F_CMB=16)
        s = simulate(df, self.wl, AcceleratorConfig(gb_capacity_bytes=1))
        assert "int" not in s.gb_accesses
