"""Unit tests for block-diagonal batching and the pow2 bucket router
(`repro.graphs.csr.block_diagonal`, `repro.graphs.batching`)."""
import numpy as np
import pytest

from repro.graphs import (
    BucketPolicy,
    CSRGraph,
    assemble,
    block_diagonal,
    bucketize,
    from_edges,
    next_pow2,
)


def line_graph(n: int) -> CSRGraph:
    """0-1-2-...-(n-1) path, GCN-normalized with self loops."""
    src = np.arange(n - 1)
    dst = src + 1
    return from_edges(n, np.concatenate([src, dst]), np.concatenate([dst, src]))


def star_graph(n: int) -> CSRGraph:
    """Hub 0 connected to 1..n-1 (hub degree n-1: an 'evil row')."""
    spokes = np.arange(1, n)
    hub = np.zeros(n - 1, dtype=np.int64)
    return from_edges(n, np.concatenate([hub, spokes]),
                      np.concatenate([spokes, hub]))


class TestBlockDiagonal:
    def test_row_ptr_and_col_offsets(self):
        a, b = line_graph(4), star_graph(5)
        batched = block_diagonal([a, b])
        assert batched.n_nodes == a.n_nodes + b.n_nodes
        assert batched.n_edges == a.n_edges + b.n_edges
        # row_ptr: a's pointers, then b's shifted by a's edge count
        np.testing.assert_array_equal(
            batched.row_ptr[: a.n_nodes + 1], a.row_ptr
        )
        np.testing.assert_array_equal(
            batched.row_ptr[a.n_nodes :], b.row_ptr + a.n_edges
        )
        # col_idx: b's columns shifted by a's node count
        np.testing.assert_array_equal(batched.col_idx[: a.n_edges], a.col_idx)
        np.testing.assert_array_equal(
            batched.col_idx[a.n_edges :], b.col_idx + a.n_nodes
        )
        batched.validate()

    def test_values_concatenate_and_stay_normalized(self):
        """Degree normalization is per member graph: batching must not
        re-normalize across graphs."""
        a, b = line_graph(6), star_graph(7)
        batched = block_diagonal([a, b])
        np.testing.assert_array_equal(batched.values[: a.n_edges], a.values)
        np.testing.assert_array_equal(batched.values[a.n_edges :], b.values)
        # and the dense form is literally the block-diagonal of the members
        dense = batched.to_dense()
        np.testing.assert_allclose(dense[: a.n_nodes, : a.n_nodes], a.to_dense())
        np.testing.assert_allclose(dense[a.n_nodes :, a.n_nodes :], b.to_dense())
        assert dense[: a.n_nodes, a.n_nodes :].sum() == 0.0
        assert dense[a.n_nodes :, : a.n_nodes].sum() == 0.0

    def test_degrees_preserved(self):
        graphs = [line_graph(3), star_graph(4), line_graph(5)]
        batched = block_diagonal(graphs)
        np.testing.assert_array_equal(
            batched.nnz, np.concatenate([g.nnz for g in graphs])
        )


class TestBucketPolicy:
    def test_next_pow2(self):
        assert [next_pow2(n) for n in (0, 1, 2, 3, 31, 32, 33, 1000)] == [
            1, 1, 2, 4, 32, 32, 64, 1024,
        ]

    def test_node_bucket_floors_and_rounds(self):
        pol = BucketPolicy(min_nodes=32, min_degree=8)
        assert pol.node_bucket(5) == 32  # floored
        assert pol.node_bucket(32) == 32  # exact boundary stays
        assert pol.node_bucket(33) == 64
        assert pol.degree_bucket(3) == 8
        assert pol.degree_bucket(9) == 16

    def test_bucket_of_uses_max_degree(self):
        pol = BucketPolicy(min_nodes=4, min_degree=2)
        g = star_graph(9)  # hub degree 8 + self loop = 9
        assert pol.bucket_of(g) == (16, 16)

    def test_slot_count(self):
        pol = BucketPolicy(max_graphs=8)
        assert pol.slot_count(1) == 1
        assert pol.slot_count(3) == 4
        assert pol.slot_count(8) == 8
        with pytest.raises(ValueError, match="max_graphs"):
            pol.slot_count(9)

    def test_bucketize_routes_in_arrival_order(self):
        pol = BucketPolicy(min_nodes=4, min_degree=2)
        graphs = [line_graph(4), star_graph(9), line_graph(3), star_graph(10)]
        routed = bucketize(graphs, pol)
        assert routed[pol.bucket_of(graphs[0])] == [0, 2]
        assert routed[pol.bucket_of(graphs[1])] == [1, 3]


class TestAssemble:
    POL = BucketPolicy(min_nodes=8, min_degree=4, max_graphs=8)

    def test_shapes_segments_and_padding(self):
        graphs = [line_graph(5), line_graph(7), line_graph(6)]
        batch = assemble(graphs, self.POL)
        assert (batch.v_bucket, batch.d_bucket) == (8, 4)
        assert batch.v_total == 8 * 4  # 3 graphs round up to 4 slots
        assert batch.n_graphs == 3
        assert batch.n_pad == 32 - 18
        batch.graph.validate()
        # segment ids label member nodes 0..2 in order; pad rows carry 3
        np.testing.assert_array_equal(batch.segment_ids[:5], 0)
        np.testing.assert_array_equal(batch.segment_ids[5:12], 1)
        np.testing.assert_array_equal(batch.segment_ids[12:18], 2)
        np.testing.assert_array_equal(batch.segment_ids[18:], 3)
        # pad rows are isolated zero-weight self loops
        assert batch.graph.values[batch.graph.row_ptr[18] :].sum() == 0.0
        np.testing.assert_array_equal(batch.graph.nnz[18:], 1)

    def test_boundary_graph_fills_its_bucket_exactly(self):
        """A graph landing exactly on the bucket boundary pads by zero."""
        g = line_graph(8)  # node bucket is exactly 8
        batch = assemble([g], self.POL)
        assert batch.v_bucket == 8
        assert batch.v_total == 8
        assert batch.n_pad == 0
        np.testing.assert_array_equal(batch.segment_ids, 0)

    def test_mixed_buckets_rejected(self):
        with pytest.raises(ValueError, match="different buckets"):
            assemble([line_graph(5), line_graph(20)], self.POL)

    def test_features_and_split_round_trip(self):
        graphs = [line_graph(5), line_graph(7)]
        batch = assemble(graphs, self.POL)
        xs = [np.full((5, 3), 1.0, np.float32), np.full((7, 3), 2.0, np.float32)]
        x = batch.batch_features(xs)
        assert x.shape == (batch.v_total, 3)
        assert (x[12:] == 0).all()  # pad rows zeroed
        back = batch.split_nodes(x)
        for orig, got in zip(xs, back):
            np.testing.assert_array_equal(orig, got)

    def test_feature_validation(self):
        batch = assemble([line_graph(5)], self.POL)
        with pytest.raises(ValueError, match="feature arrays"):
            batch.batch_features([])
        with pytest.raises(ValueError, match="rows"):
            batch.batch_features([np.zeros((4, 3), np.float32)])

    def test_empty_batch_rejected(self):
        with pytest.raises(ValueError, match="at least one"):
            assemble([], self.POL)
