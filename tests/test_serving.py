"""Serving-layer tests: the Program jit-executable cache (zero re-tracing
on same-shape inputs), segment-aware readout parity (batched == per-graph
to 1e-6), and the bucketized InferenceEngine end to end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import GNNLayerWorkload
from repro.core.schedule import ModelSchedule
from repro.gnn.layers import segment_readout
from repro.graphs import BucketPolicy, assemble, from_edges
from repro.runtime.engine import InferenceEngine, ProgramCache, Request

DIMS = [(12, 16), (16, 4)]
SCHEDULE = ModelSchedule.from_policies("sp_opt", "AC", DIMS)


def ring_graph(n: int, chords: int = 0, seed: int = 0):
    rng = np.random.default_rng(seed)
    src = np.arange(n)
    dst = (src + 1) % n
    if chords:
        es = rng.integers(0, n, size=chords)
        ed = rng.integers(0, n, size=chords)
        src, dst = np.concatenate([src, es]), np.concatenate([dst, ed])
    return from_edges(n, np.concatenate([src, dst]), np.concatenate([dst, src]))


def make_request(n: int, seed: int, rid: int = 0, chords: int = 0) -> Request:
    """chords=0 keeps max degree at 3 (ring + self loop), so every
    same-size request routes to one deterministic bucket."""
    g = ring_graph(n, chords=chords, seed=seed)
    x = np.random.default_rng(seed).normal(size=(n, DIMS[0][0])).astype(np.float32)
    return Request(graph=g, x=x, rid=rid)


def compiled(graph, schedule=SCHEDULE):
    wls = [GNNLayerWorkload(graph.nnz, fi, fo) for fi, fo in DIMS]
    return repro.compile(wls, graph=graph, schedule=schedule)


@pytest.fixture(scope="module")
def params():
    prog = compiled(ring_graph(16))
    return prog.init(jax.random.PRNGKey(0))


class TestExecutableCache:
    def test_second_run_takes_zero_traces(self, params):
        g = ring_graph(24, chords=6)
        prog = compiled(g)
        x = jnp.ones((g.n_nodes, DIMS[0][0]), jnp.float32)
        prog.run(params, x)
        before = repro.trace_count()
        out = prog.run(params, x)
        assert repro.trace_count() == before, "same-shape run re-traced"
        assert out.shape == (g.n_nodes, DIMS[-1][1])

    def test_same_shape_rebind_takes_zero_traces(self, params):
        """The serving case: a new graph with identical padded shapes must
        reuse the compiled executable through bind()."""
        a = ring_graph(24, chords=6, seed=1)
        b = ring_graph(24, chords=6, seed=2)
        d = max(a.max_degree, b.max_degree)
        prog = compiled(a)
        bound_a = prog.bind(a, pad_degree=d)
        bound_b = prog.bind(b, pad_degree=d)
        x = jnp.ones((24, DIMS[0][0]), jnp.float32)
        bound_a.run(params, x)
        before = repro.trace_count()
        out_a = bound_a.run(params, x)
        out_b = bound_b.run(params, x)
        assert repro.trace_count() == before, "same-shape rebind re-traced"
        # different adjacency, same executable: results must differ
        assert not np.allclose(np.asarray(out_a), np.asarray(out_b))

    def test_new_shape_traces_once(self, params):
        g1, g2 = ring_graph(16), ring_graph(32)
        x1 = jnp.ones((16, DIMS[0][0]), jnp.float32)
        x2 = jnp.ones((32, DIMS[0][0]), jnp.float32)
        prog = compiled(g1)
        prog.run(params, x1)
        before = repro.trace_count()
        prog.bind(g2, pad_degree=g1.max_degree).run(params, x2)
        assert repro.trace_count() == before + 1

    def test_pad_degree_narrower_than_max_degree_rejected(self):
        g = ring_graph(16, chords=8)
        with pytest.raises(ValueError, match="narrower"):
            compiled(g).bind(g, pad_degree=1)


class TestSegmentReadout:
    def test_readout_reduces_known_values(self):
        h = jnp.asarray([[1.0], [3.0], [10.0], [99.0]])
        ids = jnp.asarray([0, 0, 1, 2])  # id 2 is out of range: pad row
        mean = segment_readout(h, ids, 2, reduce="mean")
        np.testing.assert_allclose(np.asarray(mean), [[2.0], [10.0]])
        total = segment_readout(h, ids, 2, reduce="sum")
        np.testing.assert_allclose(np.asarray(total), [[4.0], [10.0]])
        mx = segment_readout(h, ids, 2, reduce="max")
        np.testing.assert_allclose(np.asarray(mx), [[3.0], [10.0]])

    def test_invalid_reduce_rejected(self):
        with pytest.raises(ValueError, match="reduce"):
            segment_readout(jnp.zeros((2, 1)), jnp.zeros(2, jnp.int32), 1,
                            reduce="median")

    def test_batched_outputs_match_single_graph_runs(self, params):
        """Acceptance: per-graph outputs from a batched run match
        single-graph runs to 1e-6 — node logits and every readout."""
        graphs = [ring_graph(10, 3, seed=s) for s in range(3)]
        pol = BucketPolicy(min_nodes=16, min_degree=16, max_graphs=4)
        batch = assemble(graphs, pol)
        xs = [
            np.random.default_rng(s).normal(
                size=(g.n_nodes, DIMS[0][0])
            ).astype(np.float32)
            for s, g in enumerate(graphs)
        ]
        prog = compiled(batch.graph).bind(batch.graph, pad_degree=batch.d_bucket)
        x = jnp.asarray(batch.batch_features(xs))
        seg = jnp.asarray(batch.segment_ids)

        # node-level parity through split_nodes
        nodes = batch.split_nodes(np.asarray(prog.run(params, x)))
        singles = [
            np.asarray(compiled(g).run(params, jnp.asarray(xg)))
            for g, xg in zip(graphs, xs)
        ]
        for got, want in zip(nodes, singles):
            np.testing.assert_allclose(got, want, atol=1e-6)

        # per-graph readout parity
        for reduce, ref in (
            ("mean", [s.mean(axis=0) for s in singles]),
            ("sum", [s.sum(axis=0) for s in singles]),
            ("max", [s.max(axis=0) for s in singles]),
        ):
            out = prog.run(
                params, x, segment_ids=seg,
                num_segments=batch.n_graphs, readout=reduce,
            )
            assert out.shape == (batch.n_graphs, DIMS[-1][1])
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(ref), atol=1e-6,
                err_msg=f"readout={reduce}",
            )

    def test_segment_ids_require_num_segments(self, params):
        g = ring_graph(16)
        prog = compiled(g)
        with pytest.raises(ValueError, match="num_segments"):
            prog.run(params, jnp.ones((16, DIMS[0][0])),
                     segment_ids=jnp.zeros(16, jnp.int32))
        with pytest.raises(ValueError, match="segment_ids"):
            prog.run(params, jnp.ones((16, DIMS[0][0])), num_segments=3)
        with pytest.raises(ValueError, match="segment_ids"):
            prog.run(params, jnp.ones((16, DIMS[0][0])), readout="max")


class TestProgramCache:
    def test_lru_eviction(self):
        cache = ProgramCache(capacity=2)
        progs = {k: compiled(ring_graph(8 + k)) for k in range(3)}
        cache.put(("a",), progs[0])
        cache.put(("b",), progs[1])
        assert cache.get(("a",)) is progs[0]  # refresh a
        cache.put(("c",), progs[2])  # evicts b, the least recent
        assert cache.get(("b",)) is None
        assert cache.get(("a",)) is progs[0]
        assert cache.get(("c",)) is progs[2]
        assert cache.evictions == 1
        assert (cache.hits, cache.misses) == (3, 1)

    def test_capacity_validated(self):
        with pytest.raises(ValueError, match="capacity"):
            ProgramCache(capacity=0)


class TestInferenceEngine:
    POL = BucketPolicy(min_nodes=16, min_degree=4, max_graphs=4)

    def engine(self, **kw):
        eng = InferenceEngine(DIMS, policy=self.POL, schedule=SCHEDULE, **kw)
        eng.init(jax.random.PRNGKey(0))
        return eng

    def test_stream_end_to_end(self):
        eng = self.engine()
        reqs = [make_request(8 + (i % 3) * 9, seed=i, rid=100 + i)
                for i in range(10)]
        results = eng.submit(reqs)
        assert [r.rid for r in results] == [100 + i for i in range(10)]
        assert all(r.output.shape == (DIMS[-1][1],) for r in results)
        stats = eng.stats()
        assert stats.n_requests == 10
        assert stats.n_buckets >= 2  # 8-node and 17/26-node graphs differ
        assert stats.p99_ms >= stats.p50_ms > 0

    def test_warm_stream_is_trace_free_and_hits_cache(self):
        eng = self.engine()
        reqs = [make_request(12, seed=i, rid=i) for i in range(6)]
        cold = eng.submit(reqs)
        misses = eng.cache.misses
        before = repro.trace_count()
        warm = eng.submit([make_request(12, seed=i + 50, rid=i) for i in range(6)])
        assert repro.trace_count() == before, "warm same-bucket stream re-traced"
        assert eng.cache.misses == misses  # all hits
        # different graphs/features through the same executable: new outputs
        assert not np.allclose(cold[0].output, warm[0].output)

    def test_engine_matches_per_graph_serving(self):
        """The whole point: batched serving computes the same answers."""
        eng = self.engine()
        reqs = [make_request(11, seed=i, rid=i) for i in range(5)]
        results = eng.submit(reqs)
        for req, res in zip(reqs, results):
            single = compiled(req.graph).run(eng.params, jnp.asarray(req.x))
            np.testing.assert_allclose(
                res.output, np.asarray(single).mean(axis=0), atol=1e-6
            )

    def test_node_level_readout_none(self):
        eng = self.engine(readout=None)
        reqs = [make_request(9, seed=i, rid=i) for i in range(3)]
        results = eng.submit(reqs)
        for req, res in zip(reqs, results):
            assert res.output.shape == (req.graph.n_nodes, DIMS[-1][1])

    def test_feature_shape_validated(self):
        """Per-request causes no longer raise out of submit(): a bad shape
        comes back as a typed rejected Result naming the request id."""
        eng = self.engine()
        g = ring_graph(9)
        bad = Request(graph=g, x=np.zeros((9, 3), np.float32), rid=7)
        (res,) = eng.submit([bad])
        assert res.status == "rejected"
        assert res.error_type == "invalid_request"
        assert res.output is None
        assert "request 7" in res.error

    def test_params_required(self):
        eng = InferenceEngine(DIMS, policy=self.POL, schedule=SCHEDULE)
        with pytest.raises(ValueError, match="params"):
            eng.submit([make_request(9, seed=0)])

    def test_tail_fill_levels_share_the_executable(self):
        """Readout runs over the padded slot count, so fill levels that
        round to the same slot shape (3 and 4 graphs -> 4 slots) reuse one
        executable: no new traces after the slot shape is warm."""
        eng = self.engine()
        eng.submit([make_request(12, seed=i, rid=i) for i in range(3)])
        before = repro.trace_count()
        for fill in (4, 3):
            res = eng.submit(
                [make_request(12, seed=10 * fill + i, rid=i)
                 for i in range(fill)]
            )
            assert len(res) == fill
            assert all(r.output.shape == (DIMS[-1][1],) for r in res)
        assert repro.trace_count() == before, (
            "tail batches with different fill levels re-traced"
        )

    def test_colliding_v_totals_keep_distinct_programs(self):
        """Buckets whose v_bucket * slots products coincide (16x2 vs 32x1
        padded nodes) must not share a cache entry: each bucket gets its
        own Program (and, unpinned, its own mapper search)."""
        eng = self.engine()
        eng.submit([make_request(12, seed=0, rid=0),
                    make_request(12, seed=1, rid=1)])  # (16,4) x 2 slots
        misses = eng.cache.misses
        eng.submit([make_request(20, seed=2, rid=2)])  # (32,4) x 1 slot
        assert eng.cache.misses == misses + 1, (
            "a (32,4)-bucket batch reused the (16,4)x2 Program"
        )

    def test_mapper_search_runs_once_per_bucket(self):
        """Without a pinned schedule, the engine searches on a bucket's
        first batch and reuses the schedule for later slot variants."""
        eng = InferenceEngine(DIMS, policy=self.POL)
        eng.init(jax.random.PRNGKey(0))
        reqs = [make_request(12, seed=i, rid=i) for i in range(5)]
        eng.submit(reqs)  # 4-slot batch + 1-slot tail: two cache keys
        assert eng.cache.misses == 2
        assert len(eng._schedules) == 1  # but one mapper search


class TestPartitionAwareAdmission:
    """Oversized requests charge ``n_partitions`` units against
    ``max_inflight_graphs``, not one batch slot."""

    POL = BucketPolicy(min_nodes=16, min_degree=4, max_nodes=64)

    def engine(self, cap: int):
        eng = InferenceEngine(
            DIMS, policy=self.POL, partition_oversized=True,
            max_inflight_graphs=cap,
        )
        eng.init(jax.random.PRNGKey(0))
        return eng

    def giant(self, rid: int = 100) -> Request:
        return make_request(200, seed=7, rid=rid)

    def test_giant_charges_partition_units(self):
        eng = self.engine(cap=4)
        smalls = [make_request(24, seed=i + 1, rid=i) for i in range(3)]
        res = eng.submit([self.giant()] + smalls)
        g = res[0]
        assert g.ok and g.n_partitions >= 2
        # the giant's fan-out filled the budget its partitions consume
        slots_left = max(0, 4 - g.n_partitions)
        n_shed = sum(r.status == "rejected" for r in res[1:])
        assert n_shed == max(0, len(smalls) - slots_left)
        shed = [r for r in res[1:] if r.status == "rejected"]
        assert all(r.error_type == "engine_overloaded" for r in shed)
        assert all(r.retry_after_s > 0 for r in shed)

    def test_giant_behind_full_batch_is_shed_with_unit_hint(self):
        eng = self.engine(cap=4)
        smalls = [make_request(24, seed=i + 1, rid=i) for i in range(4)]
        res = eng.submit(smalls + [self.giant()])
        assert all(r.ok for r in res[:-1])
        g = res[-1]
        assert g.status == "rejected"
        assert g.error_type == "engine_overloaded"
        assert g.retry_after_s is not None and g.retry_after_s > 0
        assert "partition units" in g.error  # unit-aware shed path

    def test_empty_engine_always_admits_one_giant(self):
        # its units exceed the cap outright, but an empty engine must
        # make progress rather than starve the giant forever
        eng = self.engine(cap=2)
        res = eng.submit([self.giant(rid=1)])
        assert res[0].ok and res[0].n_partitions > 2


class TestMeasuredRerank:
    """Warm batches log measured walls; rerank_topk swaps off-path."""

    POL = BucketPolicy(min_nodes=16, min_degree=4, max_graphs=4)

    def engine(self, **kw):
        eng = InferenceEngine(DIMS, policy=self.POL, **kw)
        eng.init(jax.random.PRNGKey(0))
        return eng

    def test_warm_submit_records_wall_observations(self):
        eng = self.engine()
        reqs = [make_request(12, seed=i, rid=i) for i in range(4)]
        eng.submit(reqs)  # cold: traces, no observation
        assert not eng.profile.observed
        eng.submit(reqs)  # warm: one observation per micro-batch
        assert eng.profile.observed
        (v, d, slots, digest), (n, tot) = next(iter(eng.profile.observed.items()))
        assert (v, d) in eng._buckets_seen and n >= 1 and tot > 0
        assert eng.profile.mean_wall((v, d), slots, digest) > 0

    def test_rerank_is_trace_free_on_request_path(self):
        eng = self.engine()
        reqs = [make_request(12, seed=i, rid=i) for i in range(4)]
        eng.submit(reqs)
        eng.submit(reqs)
        rep = eng.rerank_topk(top_k=2, iters=2, warmup=1)
        assert rep.n_buckets >= 1
        assert rep.n_candidates >= 1
        before = repro.trace_count()
        res = eng.submit(reqs)
        assert all(r.ok for r in res)
        assert repro.trace_count() == before, (
            "rerank_topk leaked XLA traces onto the request path"
        )
