"""Training-substrate integration tests: determinism, checkpoint/resume,
fault injection, straggler detection, gradient compression."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data import LMDataPipeline
from repro.models import init_params, lm_loss
from repro.optim import (
    adamw,
    compress_grads,
    decompress_grads,
    init_error_feedback,
    quantize_int8,
    dequantize_int8,
)
from repro.runtime import ResilientRunner, StragglerMonitor

CFG = get_config("smollm-135m").reduced(n_layers=2, d_model=32, d_ff=64, vocab=64)


def tree_equal(a, b):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(fa, fb))


def make_step():
    init_opt, update = adamw(lr=1e-3)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(lambda p: lm_loss(CFG, p, batch))(params)
        params, opt = update(grads, opt, params)
        return loss, params, opt

    return init_opt, step


class TestDataPipeline:
    def test_deterministic_per_step(self):
        d1 = LMDataPipeline(CFG, 2, 16, seed=3)
        d2 = LMDataPipeline(CFG, 2, 16, seed=3)
        for _ in range(3):
            b1, b2 = next(d1), next(d2)
            assert np.array_equal(np.asarray(b1["inputs"]), np.asarray(b2["inputs"]))

    def test_resume_replays_stream(self):
        d1 = LMDataPipeline(CFG, 2, 16, seed=3)
        for _ in range(5):
            next(d1)
        d2 = LMDataPipeline(CFG, 2, 16, seed=3)
        d2.load_state_dict(d1.state_dict())
        assert np.array_equal(
            np.asarray(next(d1)["inputs"]), np.asarray(next(d2)["inputs"])
        )

    def test_copy_span_is_learnable_signal(self):
        d = LMDataPipeline(CFG, 1, 64, seed=0)
        b = next(d)
        toks = np.asarray(b["inputs"])[0]
        # some 8-shifted copies must exist
        assert (toks[8:] == toks[:-8]).mean() > 0.1


class TestCheckpointResume:
    def test_interrupted_equals_uninterrupted(self, tmp_path):
        """3 steps + save + restore + 3 steps == 6 straight steps, bitwise."""
        init_opt, step = make_step()
        data = LMDataPipeline(CFG, 2, 16, seed=1)
        params = init_params(CFG, jax.random.PRNGKey(0))
        opt = init_opt(params)

        # uninterrupted
        p1, o1 = params, opt
        for s in range(6):
            _, p1, o1 = step(p1, o1, data.peek(s))

        # interrupted at 3
        ck = Checkpointer(tmp_path / "ck")
        p2, o2 = params, opt
        for s in range(3):
            _, p2, o2 = step(p2, o2, data.peek(s))
        ck.save(3, {"params": p2, "opt": o2, "data": {"seed": 1, "step": 3}})
        # "crash"; restore
        state = ck.restore({"params": p2, "opt": o2, "data": {"seed": 0, "step": 0}})
        p3, o3 = state["params"], state["opt"]
        start = int(state["data"]["step"])
        for s in range(start, 6):
            _, p3, o3 = step(p3, o3, data.peek(s))
        assert tree_equal(p1, p3)

    def test_atomic_rename_and_keep(self, tmp_path):
        ck = Checkpointer(tmp_path / "ck", keep=2)
        params = init_params(CFG, jax.random.PRNGKey(0))
        for s in (10, 20, 30, 40):
            ck.save(s, {"params": params})
        assert ck.all_steps() == [30, 40]
        assert ck.latest_step() == 40
        assert not list((tmp_path / "ck").glob(".tmp*"))

    def test_async_save(self, tmp_path):
        ck = Checkpointer(tmp_path / "ck", async_save=True)
        params = init_params(CFG, jax.random.PRNGKey(0))
        ck.save(5, {"params": params})
        ck.wait()
        restored = ck.restore({"params": params})
        assert tree_equal(restored["params"], params)

    def test_missing_leaf_raises(self, tmp_path):
        ck = Checkpointer(tmp_path / "ck")
        ck.save(1, {"a": jnp.zeros((2,))})
        with pytest.raises(KeyError):
            ck.restore({"a": jnp.zeros((2,)), "b": jnp.zeros((3,))})


class TestFaultTolerance:
    def test_step_retry_on_transient_failure(self, tmp_path):
        calls = {"n": 0}

        def flaky_step(state, batch):
            calls["n"] += 1
            if calls["n"] == 2:  # one transient fault
                raise RuntimeError("simulated node failure")
            return state + 1, {"loss": float(state)}

        runner = ResilientRunner(
            step_fn=flaky_step,
            save_fn=lambda s, st: None,
            restore_fn=lambda: (0, 0),
            checkpoint_every=100,
        )
        state, metrics = runner.run(0, lambda s: None, 0, 5)
        assert state == 5
        assert len(metrics) == 5

    def test_restore_after_exhausted_retries(self, tmp_path):
        saved = {}

        def save(step, st):
            saved["step"], saved["state"] = step, st

        always = {"fail_at": 3, "n": 0}

        def step_fn(state, batch):
            if state == always["fail_at"] and always["n"] < 10:
                always["n"] += 1
                raise RuntimeError("persistent fault")
            return state + 1, {}

        def restore():
            always["fail_at"] = -1  # "replacement node" fixes the fault
            return saved["step"], saved["state"]

        runner = ResilientRunner(
            step_fn=step_fn, save_fn=save, restore_fn=restore,
            checkpoint_every=2, max_retries=2,
        )
        state, _ = runner.run(0, lambda s: None, 0, 6)
        assert state == 6

    def test_straggler_monitor_flags_outliers(self):
        mon = StragglerMonitor(threshold=3.0)
        for i in range(20):
            mon.record(i, 0.1)
        assert not mon.flagged
        mon.record(20, 1.0)
        assert mon.flagged == [20]


class TestGradCompression:
    def test_int8_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
        q, s = quantize_int8(x)
        err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x)).max()
        assert err <= float(s) * 0.5 + 1e-6

    def test_error_feedback_is_unbiased_over_steps(self):
        """Constant gradient: compressed updates converge to the true sum."""
        g = jnp.full((32,), 0.01) + jnp.arange(32) * 1e-4
        ef = init_error_feedback(g)
        total = jnp.zeros((32,))
        for _ in range(50):
            q, ef = compress_grads(g, ef)
            total = total + decompress_grads(q)
        np.testing.assert_allclose(
            np.asarray(total), np.asarray(g * 50), rtol=0.02, atol=1e-4
        )

    def test_compressed_training_still_learns(self):
        init_opt, update = adamw(lr=2e-3)
        data = LMDataPipeline(CFG, 2, 16, seed=1)
        params = init_params(CFG, jax.random.PRNGKey(0))
        opt = init_opt(params)
        ef = init_error_feedback(params)

        @jax.jit
        def step(params, opt, ef, batch):
            loss, grads = jax.value_and_grad(lambda p: lm_loss(CFG, p, batch))(params)
            q, ef = compress_grads(grads, ef)
            grads = decompress_grads(q)
            params, opt = update(grads, opt, params)
            return loss, params, opt, ef

        losses = []
        for s in range(30):
            l, params, opt, ef = step(params, opt, ef, data.peek(s))
            losses.append(float(l))
        assert losses[-1] < losses[0]


ELASTIC_SCRIPT = textwrap.dedent(
    """
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
    import jax, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import Checkpointer

    mesh = jax.make_mesh((%d, %d), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    ck = Checkpointer(sys.argv[1])
    x = np.arange(64 * 32, dtype=np.float32).reshape(64, 32)
    like = {"w": jax.numpy.zeros((64, 32))}
    if sys.argv[2] == "save":
        sharded = jax.device_put(x, NamedSharding(mesh, P("data", "model")))
        ck.save(1, {"w": sharded})
        print("SAVED")
    else:
        shardings = {"w": NamedSharding(mesh, P("data", "model"))}
        state = ck.restore(like, shardings=shardings)
        w = state["w"]
        assert w.sharding.mesh.devices.size == %d
        np.testing.assert_array_equal(np.asarray(w), x)
        print("RESTORED-OK")
    """
)


def _run_elastic(n_dev, dmesh, mmesh, ckdir, mode):
    env = dict(os.environ, PYTHONPATH="src")
    script = ELASTIC_SCRIPT % (n_dev, dmesh, mmesh, n_dev)
    return subprocess.run(
        [sys.executable, "-c", script, str(ckdir), mode],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=300,
    )


def test_elastic_restore_across_device_counts(tmp_path):
    """Checkpoint written on an 8-device (4x2) mesh restores onto a
    2-device (2x1) mesh — the elastic-rescale path (deliverable:
    checkpoint/restart + elastic scaling)."""
    ck = tmp_path / "ck"
    r1 = _run_elastic(8, 4, 2, ck, "save")
    assert "SAVED" in r1.stdout, r1.stderr[-2000:]
    r2 = _run_elastic(2, 2, 1, ck, "restore")
    assert "RESTORED-OK" in r2.stdout, r2.stderr[-2000:]
