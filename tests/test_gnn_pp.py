"""Parallel-Pipeline (PP) dataflow tests — the 2-group pipelined path.

``repro.gnn.pp`` maps the paper's spatial Agg/Cmb phase partitioning onto a
two-group device mesh; a single-device process only ever exercises its
SP-Generic fallback.  These tests force two host devices with
``--xla_force_host_platform_device_count`` (in a subprocess, so the
override cannot pollute this process's jax) and pin the pipelined path,
its CA direction, and the fallback against the Seq reference.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.gnn import EllAdjacency, multiphase_matmul
from repro.gnn.pp import pp_multiphase_matmul
from repro.graphs import load_dataset

PIPELINED_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax, jax.numpy as jnp, numpy as np
    from repro.gnn import EllAdjacency, multiphase_matmul
    from repro.gnn.pp import pp_multiphase_matmul
    from repro.graphs import load_dataset

    assert jax.device_count() == 2, jax.devices()
    g, spec = load_dataset("mutag")
    adj = EllAdjacency.from_csr(g)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(g.n_nodes, spec.n_features)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(spec.n_features, 16)).astype(np.float32))
    ref = multiphase_matmul(adj, x, w, policy="seq")
    mesh = jax.make_mesh((2,), ("phase",))

    # the real producer/consumer pipeline (collective_permute hand-off),
    # at two band sizes so the drain step is exercised on ragged tails
    for band in (64, 128):
        out = pp_multiphase_matmul(adj, x, w, order="AC", mesh=mesh,
                                   band_size=band)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-3, atol=1e-4)

    # CA: combination-first (AWB-GCN direction), aggregation of X @ W
    out = pp_multiphase_matmul(adj, x, w, order="CA", mesh=mesh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-4)

    # the single-device fallback computes the same numbers on the same mesh
    # process (mesh=None routes to SP-Generic)
    out = pp_multiphase_matmul(adj, x, w, order="AC", mesh=None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-4)
    print("PP-PIPELINED-OK")
    """
)


def test_pipelined_two_group_path_matches_fallback():
    """AC pipeline (two band sizes), CA, and the single-device fallback all
    agree with Seq under 2 forced host devices."""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", PIPELINED_SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=300,
    )
    assert "PP-PIPELINED-OK" in r.stdout, r.stderr[-2000:]


def test_single_device_fallback_in_process():
    """mesh=None (or a 1-device mesh) must fall back to the SP-Generic band
    scan and match Seq — no subprocess needed."""
    g, spec = load_dataset("mutag")
    adj = EllAdjacency.from_csr(g)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(g.n_nodes, spec.n_features)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(spec.n_features, 8)).astype(np.float32))
    ref = multiphase_matmul(adj, x, w, policy="seq")
    for order in ("AC", "CA"):
        out = pp_multiphase_matmul(adj, x, w, order=order, mesh=None)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=1e-3, atol=1e-4,
            err_msg=f"order={order}",
        )


def test_ca_path_has_no_identity_gemm():
    """Regression for the CA fast path: it used to route through the AC
    band scan with W=I, paying an O(V*G^2) identity GEMM per band.  The
    direct CA aggregation has exactly two contractions end to end (X @ W
    and the band einsum) — the identity variant had a third."""
    g, spec = load_dataset("mutag")
    adj = EllAdjacency.from_csr(g)
    x = jnp.zeros((g.n_nodes, spec.n_features), jnp.float32)
    w = jnp.zeros((spec.n_features, 8), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda x_, w_: pp_multiphase_matmul(adj, x_, w_, order="CA", mesh=None)
    )(x, w)
    assert str(jaxpr).count("dot_general") == 2, (
        "CA fallback should lower to exactly 2 contractions "
        "(combination GEMM + aggregation einsum)"
    )
