"""Hand-verified cases for the per-phase cost model (paper Table 1)."""
import numpy as np
import pytest

from repro.core import (
    AcceleratorConfig,
    GNNLayerWorkload,
    PhaseOrder,
    aggregation_cost,
    combination_cost,
    intra,
    named_dataflow,
    pipelined_elements,
    table3_buffering,
)

HW = AcceleratorConfig(n_pes=512, gb_bandwidth=10**9)  # no bandwidth stalls


class TestCombinationTraffic:
    """GEMM V=G=F=4 with 2x2x2 tiles: trips = 2 per dim."""

    def test_output_stationary(self):
        # {VsGs}Ft — Table 1 row 1: inputs and weights stream every step,
        # partial sums accumulate temporally in the PE.
        df = intra("VsGsFt", "cmb", V=2, G=2)
        c = combination_cost(df, 4, 4, 4, HW)
        assert c.cycles == 2 * 2 * 4  # T_F = 1 -> 4 F-steps
        assert c.gb_reads["inp"] == 2 * 2 * 4 * (2 * 1)  # re-read per G tile
        assert c.gb_reads["wt"] == 2 * 2 * 4 * (1 * 2)
        assert c.gb_writes["out"] == 16  # written once, no psum spills
        assert "psum" not in c.gb_writes

    def test_weight_stationary(self):
        # {GsFs}Vt — Table 1 row 2: weights stay, V streams under them.
        df = intra("GsFsVt", "cmb", G=2, F=2)
        c = combination_cost(df, 4, 4, 4, HW)
        assert c.cycles == 2 * 2 * 4
        # each weight tile fetched exactly once: F*G elements total
        assert c.gb_reads["wt"] == 16
        # reduction loop (F) is above the V loop -> psums spill
        assert c.gb_writes["psum"] > 0
        assert c.gb_writes["out"] == 16

    def test_input_stationary(self):
        # {VsFs}Gt — Table 1 row 3: input tile stays, weights stream.
        df = intra("VsFsGt", "cmb", V=2, F=2)
        c = combination_cost(df, 4, 4, 4, HW)
        assert c.gb_reads["inp"] == 16  # each input tile once
        # weight re-fetched per (V, G) step
        assert c.gb_reads["wt"] == 2 * 2 * 4 * 2

    def test_macs_invariant(self):
        for spec in ["VsGsFt", "GsFsVt", "VsFsGt", "VtGtFt", "FsGsVt"]:
            df = intra(spec, "cmb", V=2, G=2, F=2)
            assert combination_cost(df, 8, 6, 10, HW).macs == 8 * 6 * 10


class TestAggregationCost:
    nnz = np.array([3, 1, 2, 2])

    def test_lockstep_evil_row(self):
        # T_V = 2, temporal N: tile trip counts are the tile max (lockstep)
        df = intra("VsFsNt", "agg", V=2, F=2)
        c = aggregation_cost(df, self.nnz, 4, HW)
        assert c.cycles == 2 * (3 + 2)  # f_trips=2, max nnz per tile 3,2
        assert c.macs == 8 * 4

    def test_spatial_n_compresses_depth(self):
        df = intra("VsFsNs", "agg", V=2, F=2, N=2)
        c = aggregation_cost(df, self.nnz, 4, HW)
        assert c.cycles == 2 * (2 + 1)  # ceil(3/2)+ceil(2/2)

    def test_adjacency_reread_when_f_outside_n(self):
        df = intra("VsFsNt", "agg", V=2, F=2)
        c = aggregation_cost(df, self.nnz, 4, HW)
        assert c.gb_reads["adj"] == 8 * 2  # per F pass
        df2 = intra("VsNtFs", "agg", V=2, F=2)
        c2 = aggregation_cost(df2, self.nnz, 4, HW)
        assert c2.gb_reads["adj"] == 8

    def test_psum_spill_when_n_outside_f(self):
        df = intra("VsNtFs", "agg", V=2, F=2)
        c = aggregation_cost(df, self.nnz, 4, HW)
        assert c.gb_writes["psum"] > 0
        df2 = intra("VsFsNt", "agg", V=2, F=2)
        c2 = aggregation_cost(df2, self.nnz, 4, HW)
        assert "psum" not in c2.gb_writes

    def test_gathered_input_no_reuse(self):
        df = intra("VsFsNt", "agg", V=2, F=2)
        c = aggregation_cost(df, self.nnz, 4, HW)
        assert c.gb_reads["inp"] == 8 * 4  # E x feat

    def test_footprint_guard(self):
        df = intra("VsFsNs", "agg", V=64, F=64, N=4)
        with pytest.raises(ValueError, match="PE budget"):
            aggregation_cost(df, self.nnz, 4, HW)


class TestTable3Buffering:
    wl = GNNLayerWorkload(np.full(64, 4), f_in=32, g_out=8)

    def test_seq_full_intermediate(self):
        df = named_dataflow("Seq-Nt", T_V_AGG=4, T_F_AGG=4)
        assert table3_buffering(df, self.wl) == 64 * 32

    def test_sp_optimized_zero(self):
        df = named_dataflow("EnGN", T_V_AGG=4, T_F_AGG=4, T_V_CMB=4, T_F_CMB=4)
        assert table3_buffering(df, self.wl) == 0

    def test_pp_row_granularity(self):
        # PP row: 2 x T_V_max x F
        df = named_dataflow("HyGCN", T_F_AGG=8, T_V_CMB=4, T_G=8)
        assert df.granularity.value == "row"
        assert table3_buffering(df, self.wl) == 2 * 4 * 32

    def test_pp_element_granularity(self):
        from repro.core import GNNDataflow, InterPhase, intra as mk

        df = GNNDataflow(
            InterPhase.PP,
            PhaseOrder.AC,
            mk("VsFsNt", "agg", V=4, F=8),
            mk("VsFsGt", "cmb", V=4, F=8),
        )
        assert df.granularity.value == "element"
        assert table3_buffering(df, self.wl) == 2 * 4 * 8

    def test_pp_ca_row_granularity_uses_agg_v_tile(self):
        # CA intermediate (X.W) is V x G; the aggregation (second) phase
        # consumes it per *output vertex* tile, so Pel's row term must use
        # agg T_V — not T_N, which indexes gathered neighbor rows.
        from repro.core import GNNDataflow, InterPhase, intra as mk

        df = GNNDataflow(
            InterPhase.PP,
            PhaseOrder.CA,
            mk("NsVtFs", "agg", N=4, F=8),
            mk("VsGsFt", "cmb", V=2, G=4),
        )
        assert df.granularity.value == "row"
        # rows in flight = max(cmb T_V = 2, agg T_V = 1); feat = G = 8
        assert pipelined_elements(df, self.wl) == 2 * self.wl.g_out
        assert table3_buffering(df, self.wl) == 2 * 2 * self.wl.g_out

    def test_pel_max_of_tile_sizes(self):
        # imbalanced tiles: Pel uses the max per dim (paper Sec. 4.4)
        from repro.core import GNNDataflow, InterPhase, intra as mk

        df = GNNDataflow(
            InterPhase.PP,
            PhaseOrder.AC,
            mk("VsFsNt", "agg", V=2, F=8),
            mk("VsFsGt", "cmb", V=4, F=4),
        )
        assert pipelined_elements(df, self.wl) == 4 * 8
