"""Tests for the dataflow taxonomy (paper Tables 1-2, Sec. 3)."""
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (
    Binding,
    GNNDataflow,
    Granularity,
    InterPhase,
    PhaseOrder,
    enumerate_dataflows,
    intra,
    named_dataflow,
    named_skeleton,
    parse_dataflow,
)
from repro.core.taxonomy import SKELETONS, classify_granularity, input_walk, output_walk


class TestEnumeration:
    def test_total_is_6656(self):
        """The paper counts 6,656 loop-order x parallelism x phase-order
        choices across the three inter-phase classes (Sec. 3.3)."""
        dfs = enumerate_dataflows()
        assert len(dfs) == 6656

    def test_class_counts(self):
        dfs = enumerate_dataflows()
        by = {}
        for d in dfs:
            by[d.inter] = by.get(d.inter, 0) + 1
        assert by[InterPhase.SEQ] == 48 * 48 * 2
        assert by[InterPhase.SP] == 1024
        assert by[InterPhase.PP] == 1024

    def test_sp_and_pp_all_pipelineable(self):
        for d in enumerate_dataflows():
            if d.inter in (InterPhase.SP, InterPhase.PP):
                assert d.granularity != Granularity.NONE

    def test_sp_optimized_is_subset_of_sp(self):
        spopt = [d for d in enumerate_dataflows() if d.is_sp_optimized]
        assert spopt and all(d.inter == InterPhase.SP for d in spopt)
        # {VF}N_t / {VF}G_t x 2 orders x (V,F bindings)^2 x 2 phase orders
        assert len(spopt) == 64


class TestGranularity:
    """Table 2 rows 4-9 loop-order patterns."""

    @pytest.mark.parametrize(
        "agg,cmb,expected",
        [
            # row 4: element(s) wise, AC
            ("VFN", "VFG", "element"),
            ("FVN", "FVG", "element"),
            # row 5: row(s) wise (not the element pair)
            ("VNF", "VGF", "row"),
            ("VFN", "VGF", "row"),
            ("VNF", "VFG", "row"),
            # row 6: column(s) wise
            ("FNV", "FGV", "column"),
            ("FVN", "FGV", "column"),
            ("FNV", "FVG", "column"),
            # infeasible pairs
            ("NVF", "VFG", "none"),
            ("VFN", "GVF", "none"),
            ("FVN", "VGF", "none"),
        ],
    )
    def test_ac_patterns(self, agg, cmb, expected):
        g = classify_granularity(PhaseOrder.AC, tuple(agg), tuple(cmb))
        assert g.value == expected

    @pytest.mark.parametrize(
        "agg,cmb,expected",
        [
            # row 7: element(s) wise CA — (NFV, VGF) or (FNV, GVF)
            ("NFV", "VGF", "element"),
            ("FNV", "GVF", "element"),
            # row 8: row(s) wise CA (cmb V outer, agg N outer)
            ("NVF", "VGF", "row"),
            ("NFV", "VFG", "row"),
            # row 9: column(s) wise CA (cmb G outer, agg F outer)
            ("FVN", "GVF", "column"),
            ("FNV", "GFV", "column"),
            # infeasible
            ("VFN", "VGF", "none"),
        ],
    )
    def test_ca_patterns(self, agg, cmb, expected):
        g = classify_granularity(PhaseOrder.CA, tuple(agg), tuple(cmb))
        assert g.value == expected


class TestLegality:
    def test_sp_requires_pipelineable_orders(self):
        df = GNNDataflow(
            InterPhase.SP,
            PhaseOrder.AC,
            intra("NtVtFt", "agg"),
            intra("VtGtFt", "cmb"),
        )
        with pytest.raises(ValueError, match="not pipelineable"):
            df.validate()

    def test_footprint_checked_against_pes(self):
        df = named_dataflow("EnGN", T_V_AGG=64, T_F_AGG=64, T_V_CMB=64, T_F_CMB=64)
        with pytest.raises(ValueError, match="exceeds PE budget"):
            df.validate(n_pes=512)
        df.validate(n_pes=4096)

    def test_temporal_loop_rejects_tile(self):
        with pytest.raises(ValueError, match="temporal loop"):
            from repro.core.taxonomy import Loop

            Loop("V", Binding.TEMPORAL, 4)

    def test_pp_split_range(self):
        with pytest.raises(ValueError, match="pe_split"):
            GNNDataflow(
                InterPhase.PP,
                PhaseOrder.AC,
                intra("VtFtNt", "agg"),
                intra("VtGtFt", "cmb"),
                pe_split=0.0,
            )


class TestNamed:
    def test_hygcn_matches_paper(self):
        """HyGCN = PP_AC(VxFsNt, VsGsFt) (paper Sec. 3.3 / Table 2 row 5)."""
        df = named_dataflow("HyGCN", T_F_AGG=16, T_V_CMB=8, T_G=16)
        assert df.inter == InterPhase.PP and df.order == PhaseOrder.AC
        assert df.agg.binding("N") == Binding.TEMPORAL
        assert df.cmb.binding("F") == Binding.TEMPORAL
        assert df.granularity == Granularity.ROW

    def test_awb_gcn_matches_paper(self):
        """AWB-GCN = PP_CA(FsNtVs, GtFtVs) (Table 2 row 9)."""
        df = named_dataflow("AWB-GCN", T_F_AGG=16, T_V_AGG=8, T_V_CMB=8)
        assert df.inter == InterPhase.PP and df.order == PhaseOrder.CA
        assert df.granularity == Granularity.COLUMN

    def test_engn_is_sp_optimized(self):
        df = named_dataflow("EnGN", T_V_AGG=8, T_F_AGG=8, T_V_CMB=8, T_F_CMB=8)
        assert df.is_sp_optimized

    def test_all_skeletons_concretize(self):
        for name, sk in SKELETONS.items():
            df = sk.concretize({"V": 2, "N": 1, "F": 2}, {"V": 2, "G": 2, "F": 2})
            df.validate(n_pes=512)
            assert isinstance(str(df), str)

    def test_skeleton_sp_opt_flags(self):
        assert named_skeleton("SP-FsNt-Fs").sp_optimized
        assert named_skeleton("High-Vs-SP").sp_optimized
        assert not named_skeleton("PP-Nt-Vsh").sp_optimized


class TestTemplateRoundTrip:
    """`to_string` / `parse_dataflow` invert each other over the paper's
    `<Inter><order>(<AggIntra>, <CmbIntra>)` template notation."""

    def test_full_enumeration_round_trips(self):
        for df in enumerate_dataflows():
            assert parse_dataflow(df.to_string()) == df

    def test_spopt_prefix_accepted(self):
        df = named_dataflow("EnGN", T_V_AGG=8, T_F_AGG=16, T_V_CMB=8, T_F_CMB=16)
        assert str(df).startswith("SPopt_")
        assert parse_dataflow(str(df)) == df

    def test_pe_split_round_trips(self):
        df = named_dataflow(
            "AWB-GCN", T_F_AGG=8, T_V_AGG=16, T_V_CMB=16, pe_split=0.25
        )
        s = df.to_string()
        assert "[0.25]" in s
        assert parse_dataflow(s) == df
        assert parse_dataflow(s).pe_split == 0.25

    @pytest.mark.parametrize(
        "bad",
        [
            # malformed templates
            "",
            "garbage",
            "Foo_AC(VtFtNt, VtGtFt)",  # unknown inter-phase class
            "Seq_ZZ(VtFtNt, VtGtFt)",  # unknown phase order
            "Seq_AC(VtFtNt)",  # missing combination spec
            "Seq_AC(VtFtNt, VtGtFt",  # unbalanced parens
            "Seq_AC(VtFtNt, VtGtFt) extra",  # trailing garbage
            # unknown loop dims / bindings
            "Seq_AC(VtFtXt, VtGtFt)",  # X is not a dim
            "Seq_AC(VqFtNt, VtGtFt)",  # q is not a binding
            # wrong loop counts
            "Seq_AC(VtFt, VtGtFt)",
            "Seq_AC(VtFtNtNt, VtGtFt)",
            # bad tile syntax
            "Seq_AC(Vs(abc)FtNt, VtGtFt)",  # non-integer tile
            "Seq_AC(Vs()FtNt, VtGtFt)",  # empty tile
            "Seq_AC(Vs(8FtNt, VtGtFt)",  # unclosed tile paren
        ],
    )
    def test_malformed_rejected(self, bad):
        with pytest.raises(ValueError, match="parse|malformed"):
            parse_dataflow(bad)

    @settings(max_examples=50, deadline=None)
    @given(
        tv=st.sampled_from([1, 2, 8, 64]),
        tn=st.sampled_from([1, 4]),
        tf=st.sampled_from([1, 16]),
        tg=st.sampled_from([1, 2, 32]),
        split=st.sampled_from([0.25, 0.5, 0.625]),
        name=st.sampled_from(
            ["Seq-Nt", "Seq-Ns", "EnGN", "HyGCN", "AWB-GCN", "PP-Nt-Vsh"]
        ),
    )
    def test_property_tiled_round_trips(self, tv, tn, tf, tg, split, name):
        df = named_dataflow(
            name, T_V_AGG=tv, T_N=tn, T_F_AGG=tf, T_V_CMB=tv, T_G=tg,
            T_F_CMB=tf, pe_split=split,
        )
        assert parse_dataflow(df.to_string()) == df


class TestWalks:
    """Layer-boundary walk classification (model-level transitions)."""

    def test_table5_defaults_self_compatible(self):
        # reusing one Table-5 dataflow across layers must never re-lay-out
        for name in ("Seq-Nt", "EnGN", "HyGCN", "AWB-GCN"):
            df = named_dataflow(
                name, T_V_AGG=8, T_F_AGG=8, T_V_CMB=8, T_G=4, T_F_CMB=8
            )
            assert output_walk(df) == input_walk(df), name

    def test_awb_gcn_is_column_major(self):
        df = named_dataflow("AWB-GCN", T_F_AGG=8, T_V_AGG=8, T_V_CMB=8)
        assert output_walk(df) == "column"
        assert input_walk(df) == "column"

    def test_row_pipelined_ac_is_row_major(self):
        df = named_dataflow("HyGCN", T_F_AGG=8, T_V_CMB=8, T_G=4)
        assert df.granularity == Granularity.ROW
        assert output_walk(df) == "row"
        assert input_walk(df) == "row"
