"""Hardware co-design tests: the HWGrid axis through `simulate_batch`
(dataflow x hw grid oracle parity), `search_codesign` /
`flexibility_value`, and `repro.compile(hw=HWGrid(...))`."""
import json

import numpy as np
import pytest

import repro
from repro.core import (
    AcceleratorConfig,
    GNNLayerWorkload,
    HWGrid,
    ModelSchedule,
    TileStats,
    flexibility_value,
    named_dataflow,
    named_skeleton,
    optimize_tiles,
    search_codesign,
    search_model,
    search_model_codesign,
    simulate,
    simulate_batch,
    sweep_pe_splits,
)

RNG = np.random.default_rng(17)


def wl_random(v=512, f=64, g=16, max_deg=12, rng=RNG, name=""):
    nnz = rng.integers(1, max_deg + 1, size=v)
    nnz[rng.integers(v)] = max_deg * 20  # one evil row
    return GNNLayerWorkload(nnz, f, g, name=name)


def random_dataflows(n, rng, tiles=(1, 2, 4, 8, 16, 32)):
    names = ["Seq-Nt", "Seq-Ns", "EnGN", "HyGCN", "AWB-GCN", "SP-FsNt-Fs",
             "SP-VsNt-Vs", "PP-Nt-Vt/sl", "PP-Ns-Vsh", "High-Vs-SP"]
    out = []
    while len(out) < n:
        name = names[rng.integers(len(names))]
        out.append(named_dataflow(
            name,
            T_V_AGG=int(rng.choice(tiles)), T_N=int(rng.choice(tiles)),
            T_F_AGG=int(rng.choice(tiles)), T_V_CMB=int(rng.choice(tiles)),
            T_G=int(rng.choice([1, 2, 4, 8])), T_F_CMB=int(rng.choice(tiles)),
            pe_split=float(rng.choice([0.25, 0.5, 0.75])),
        ))
    return out


class TestHWGrid:
    def test_product_enumeration(self):
        g = HWGrid(n_pes=(128, 512), gb_bandwidth=(64, 256),
                   gb_capacity_bytes=(None, 4096))
        assert len(g) == 8
        cfgs = g.configs()
        assert len(cfgs) == 8
        assert cfgs[0] == AcceleratorConfig(n_pes=128, gb_bandwidth=64)
        # C order: capacity minor, n_pes major
        assert cfgs[1].gb_capacity_bytes == 4096
        assert cfgs[-1] == AcceleratorConfig(
            n_pes=512, gb_bandwidth=256, gb_capacity_bytes=4096
        )

    def test_scalar_axes_coerce(self):
        g = HWGrid(n_pes=256, gb_bandwidth=(64, 128))
        assert g.n_pes == (256,)
        assert len(g) == 2

    def test_columns_and_cost(self):
        g = HWGrid(n_pes=(128, 512), gb_bandwidth=(64,),
                   gb_capacity_bytes=(None, 1024))
        cols = g.columns()
        np.testing.assert_array_equal(cols["n_pes"], [128, 128, 512, 512])
        assert cols["gb_cap"][0] == np.inf and cols["gb_cap"][1] == 1024.0
        np.testing.assert_array_equal(g.hw_cost(), [8192.0] * 2 + [32768.0] * 2)

    def test_base_carries_energy_constants(self):
        base = AcceleratorConfig(gb_energy_pj=2.0)
        g = HWGrid(n_pes=(64,), base=base)
        assert g.configs()[0].gb_energy_pj == 2.0

    def test_rejects_bad_axes(self):
        with pytest.raises(ValueError):
            HWGrid(n_pes=())
        with pytest.raises(ValueError):
            HWGrid(n_pes=(0,))
        with pytest.raises(ValueError):
            HWGrid(gb_bandwidth=(0,))
        # fractional axes would be priced differently by columns() (float)
        # and configs() (AcceleratorConfig ints) — rejected up front
        with pytest.raises(ValueError):
            HWGrid(gb_bandwidth=(96.5,))
        with pytest.raises(ValueError):
            HWGrid(n_pes=(128.5,))

    def test_float_valued_integral_axes_coerce(self):
        g = HWGrid(n_pes=(128.0,), gb_bandwidth=(64.0,),
                   gb_capacity_bytes=(4096.0,))
        assert g.configs()[0] == AcceleratorConfig(
            n_pes=128, gb_bandwidth=64, gb_capacity_bytes=4096
        )


class TestBufferEnergySingleSource:
    """`buffer_access_energy` is the one clamp/exponent implementation for
    both the scalar and vectorized paths."""

    def test_vectorized_matches_scalar(self):
        hw = AcceleratorConfig()
        caps = np.array([0, 1, 512, 4096, 1 << 20, 1 << 28, 1 << 40])
        vec = hw.buffer_access_energy(caps)
        for c, e in zip(caps, vec):
            assert e == pytest.approx(hw.buffer_access_energy(int(c)))

    def test_clamps(self):
        hw = AcceleratorConfig()
        assert hw.buffer_access_energy(0) == hw.rf_energy_pj
        assert hw.buffer_access_energy(1) == hw.rf_energy_pj  # lower clamp
        assert hw.buffer_access_energy(1 << 50) == hw.dram_energy_pj  # upper
        assert isinstance(hw.buffer_access_energy(4096), float)


class TestGridOracleParity:
    """`simulate_batch` over a dataflow x hw grid must match the scalar
    `simulate` oracle to 1e-6 at every grid point — including
    capacity-exceeded points, tiny PE arrays and bandwidth != n_pes."""

    def test_dataflow_x_hw_grid(self):
        rng = np.random.default_rng(5)
        wl = wl_random(v=700, f=96, g=16, rng=rng)
        dfs = random_dataflows(80, rng)
        full_bytes = wl.v * wl.f_in * 4
        grid = HWGrid(
            n_pes=(8, 64, 512),
            gb_bandwidth=(16, 512),
            # None / smaller-than-a-chunk / between chunk and full matrix
            gb_capacity_bytes=(None, 512, full_bytes // 2),
        )
        bs = simulate_batch(dfs, wl, grid)
        assert bs.cycles.shape == (len(dfs), len(grid))
        assert bs.grid is grid
        legal = 0
        for i, df in enumerate(dfs):
            for j, cfg in enumerate(grid.configs()):
                try:
                    s = simulate(df, wl, cfg)
                except ValueError:
                    assert not bs.legal[i, j], (df, cfg)
                    continue
                assert bs.legal[i, j], (df, cfg)
                legal += 1
                assert bs.cycles[i, j] == pytest.approx(s.cycles, rel=1e-6)
                assert bs.energy_pj[i, j] == pytest.approx(s.energy_pj, rel=1e-6)
                assert bs.agg_cycles[i, j] == pytest.approx(s.agg_cycles, rel=1e-6)
                assert bs.cmb_cycles[i, j] == pytest.approx(s.cmb_cycles, rel=1e-6)
        # the sample must exercise both capacity sides and small PE arrays
        assert legal >= 200

    @pytest.mark.parametrize(
        "hw",
        [
            AcceleratorConfig(gb_capacity_bytes=2048),  # widely exceeded
            AcceleratorConfig(gb_capacity_bytes=1 << 30),  # never exceeded
            AcceleratorConfig(n_pes=512, gb_bandwidth=32),  # bw != n_pes
            AcceleratorConfig(n_pes=16, gb_bandwidth=512),  # tiny PE array
            AcceleratorConfig(n_pes=7, gb_bandwidth=3, gb_capacity_bytes=4096),
        ],
        ids=["cap-exceeded", "cap-large", "narrow-bw", "tiny-pes", "odd-all"],
    )
    def test_scalar_hw_nondefault(self, hw):
        """Satellite: oracle parity under non-default AcceleratorConfig
        (the pre-existing parity tests only exercised DEFAULT_ACCEL)."""
        rng = np.random.default_rng(23)
        wl = wl_random(v=400, f=64, g=16, rng=rng)
        dfs = random_dataflows(60, rng, tiles=(1, 2, 4, 8))
        bs = simulate_batch(dfs, wl, hw)
        legal = 0
        for i, df in enumerate(dfs):
            try:
                s = simulate(df, wl, hw)
            except ValueError:
                assert not bs.legal[i], df
                continue
            assert bs.legal[i], df
            legal += 1
            assert bs.cycles[i] == pytest.approx(s.cycles, rel=1e-6)
            assert bs.energy_pj[i] == pytest.approx(s.energy_pj, rel=1e-6)
        assert legal >= 5  # tiny PE arrays leave few legal candidates


class TestSweepPESplits:
    def test_matches_per_split_optimize(self):
        wl = wl_random(v=384, f=48, g=16)
        ts = TileStats(wl.nnz)
        sk = named_skeleton("PP-Nt-Vt/sl")
        splits = (0.25, 0.5, 0.75)
        per = sweep_pe_splits(sk, wl, objective="cycles", pe_splits=splits,
                              tile_stats=ts)
        assert set(per) == set(splits)
        for s in splits:
            ref = optimize_tiles(sk, wl, objective="cycles", pe_splits=(s,),
                                 tile_stats=ts)
            assert per[s].stats.cycles == pytest.approx(ref.stats.cycles)

    def test_non_pp_collapses_to_single_entry(self):
        wl = wl_random(v=256)
        per = sweep_pe_splits(named_skeleton("Seq-Nt"), wl,
                              pe_splits=(0.25, 0.5, 0.75))
        assert list(per) == [0.5]


class TestSearchCodesign:
    def setup_method(self):
        rng = np.random.default_rng(3)
        self.wls = [
            wl_random(v=500, f=64, g=16, rng=rng, name="a"),
            wl_random(v=300, f=16, g=16, max_deg=40, rng=rng, name="b"),
        ]
        self.grid = HWGrid(n_pes=(128, 512), gb_bandwidth=(64, 512))

    def test_frontier_is_nondominated_and_spans(self):
        res = search_codesign(self.wls, self.grid, objective="cycles")
        assert len(res.points) == len(self.grid)
        front = res.frontier
        assert front
        for p in front:
            for q in res.points:
                if not q.feasible:
                    continue
                assert not (
                    q.objective_total <= p.objective_total
                    and q.hw_cost <= p.hw_cost
                    and (q.objective_total < p.objective_total
                         or q.hw_cost < p.hw_cost)
                )
        # the global best objective and the cheapest feasible hw are on it
        assert res.best in front or any(
            p.objective_total == res.best.objective_total for p in front
        )

    def test_more_hardware_never_hurts(self):
        res = search_codesign(self.wls, self.grid, objective="cycles")
        by_hw = {(p.hw.n_pes, p.hw.gb_bandwidth): p.objective_total
                 for p in res.points}
        # 2% slack: max_evals subsampling differs per PE budget, so the
        # bigger budget's grid can narrowly miss the smaller one's winner
        assert by_hw[(512, 512)] <= by_hw[(128, 64)] * 1.02
        assert by_hw[(512, 512)] <= by_hw[(512, 64)] * 1.02
        assert by_hw[(512, 512)] <= by_hw[(128, 512)] * 1.02

    def test_frontier_mappings_match_oracle(self):
        res = search_codesign(self.wls, self.grid, objective="cycles")
        for p in res.frontier:
            assert p.mappings is not None
            total = 0.0
            for m, df in zip(p.mappings, p.dataflows):
                assert m.dataflow == df
                total += m.stats.cycles
            # scalar re-pricing agrees with the vectorized sweep total
            assert total == pytest.approx(p.objective_total, rel=1e-6)

    def test_point_objective_matches_per_point_search(self):
        # one grid point must reproduce the plain per-hw search
        from repro.core import search_dataflows

        res = search_codesign(self.wls, HWGrid(n_pes=(512,),
                                               gb_bandwidth=(512,)),
                              objective="cycles")
        want = sum(
            search_dataflows(wl, AcceleratorConfig(), objective="cycles")[0]
            .stats.cycles
            for wl in self.wls
        )
        assert res.points[0].objective_total == pytest.approx(want, rel=1e-6)

    def test_rejects_non_grid(self):
        with pytest.raises(TypeError):
            search_codesign(self.wls, AcceleratorConfig())


class TestFlexibilityValue:
    def test_value_at_least_one_and_consistent(self):
        rng = np.random.default_rng(9)
        suite = [
            wl_random(v=500, f=128, g=16, rng=rng, name="hf"),
            wl_random(v=300, f=16, g=16, max_deg=60, rng=rng, name="he"),
            wl_random(v=200, f=512, g=8, rng=rng, name="wide"),
        ]
        rep = flexibility_value(suite, objective="cycles")
        assert rep.value >= 1.0 - 1e-6  # scalar/batch oracle-parity slack
        assert len(rep.per_workload) == len(suite) == len(rep.fixed)
        # the fixed side really is one dataflow everywhere
        assert all(m.dataflow == rep.fixed_dataflow for m in rep.fixed)
        # stats come from the scalar oracle
        for m, wl in zip(rep.per_workload, suite):
            assert m.stats.cycles == pytest.approx(
                simulate(m.dataflow, wl, rep.hw).cycles
            )
        # each flexible pick is no worse than the fixed dataflow there
        for flex, fixed in zip(rep.per_workload, rep.fixed):
            assert flex.objective("cycles") <= fixed.objective("cycles") * (
                1 + 1e-9
            )
        assert rep.win_pct == pytest.approx((rep.value - 1) * 100)


class TestScheduleHW:
    def test_search_model_records_hw_and_serializes(self):
        rng = np.random.default_rng(1)
        nnz = np.maximum(1, rng.poisson(6, size=400))
        wls = [GNNLayerWorkload(nnz, 64, 16), GNNLayerWorkload(nnz, 16, 8)]
        hw = AcceleratorConfig(n_pes=256, gb_bandwidth=128)
        sched = search_model(wls, hw, objective="cycles")
        assert sched.hw == hw
        assert sched.shared_baseline.hw == hw
        rt = ModelSchedule.from_json(sched.to_json())
        assert rt.hw == hw
        # hw is not part of identity, and old JSONs (no "hw") still load
        assert rt == sched
        d = json.loads(sched.to_json())
        del d["hw"]
        legacy = ModelSchedule.from_json(json.dumps(d))
        assert legacy.hw is None and legacy == sched

    def test_transitions_repriced_per_hw_point(self):
        rng = np.random.default_rng(2)
        nnz = np.maximum(1, rng.poisson(6, size=400))
        wls = [GNNLayerWorkload(nnz, 64, 16), GNNLayerWorkload(nnz, 16, 8)]
        grid = HWGrid(gb_bandwidth=(64, 512))
        scheds = search_model_codesign(wls, grid, objective="cycles")
        assert len(scheds) == 2
        for sched, cfg in zip(scheds, grid.configs()):
            assert sched is not None and sched.hw == cfg
            # stats really were priced on that point's bandwidth
            from repro.core import simulate_model

            ref = simulate_model(sched.dataflows, wls, cfg)
            assert sched.stats.cycles == pytest.approx(ref.cycles, rel=1e-9)


class TestCompileHWGrid:
    rng = np.random.default_rng(4)
    nnz = np.maximum(1, rng.poisson(6, size=500))
    wls = [GNNLayerWorkload(nnz, 64, 16), GNNLayerWorkload(nnz, 16, 8)]
    grid = HWGrid(n_pes=(128, 512), gb_bandwidth=(64, 512))

    def test_chosen_hw_lands_in_program_and_artifact(self, tmp_path):
        prog = repro.compile(self.wls, hw=self.grid, objective="cycles")
        assert prog.hw in self.grid.configs()
        assert prog.schedule.hw == prog.hw
        assert prog.codesign is not None and len(prog.codesign) == len(self.grid)
        # the winner really is the grid's best objective
        objs = [o for _, o in prog.codesign]
        assert prog.stats.objective("cycles") == pytest.approx(min(objs))
        p = tmp_path / "prog.json"
        prog.save(p)
        loaded = repro.Program.load(p)
        assert loaded.hw == prog.hw
        assert loaded.schedule.hw == prog.hw
        assert loaded.to_json() == prog.to_json()  # byte-stable

    def test_beats_or_matches_every_single_point_compile(self):
        prog = repro.compile(self.wls, hw=self.grid, objective="cycles")
        for cfg in self.grid.configs():
            single = repro.compile(self.wls, hw=cfg, objective="cycles")
            assert prog.stats.cycles <= single.stats.cycles * (1 + 1e-9)

    def test_explicit_schedule_grid_repricing(self):
        base = repro.compile(self.wls, hw=AcceleratorConfig(),
                             objective="cycles")
        prog = repro.compile(self.wls, hw=self.grid, objective="cycles",
                             schedule=base.schedule)
        assert prog.hw in self.grid.configs()
        # the re-priced schedule must record the *chosen* hw and the stats
        # priced on it, not those from its original search
        assert prog.schedule.hw == prog.hw
        assert prog.schedule.stats.cycles == pytest.approx(prog.stats.cycles)
        rigid = AcceleratorConfig(n_pes=512, gb_bandwidth=64)
        single = repro.compile(self.wls, hw=rigid, objective="cycles",
                               schedule=base.schedule)
        assert single.schedule.hw == rigid
        # re-pricing a fixed schedule picks the grid's best feasible point
        from repro.core import simulate_model

        cands = []
        for cfg in self.grid.configs():
            try:
                cands.append(simulate_model(base.schedule.dataflows,
                                            self.wls, cfg).cycles)
            except ValueError:  # schedule infeasible at this point
                continue
        assert prog.stats.cycles == pytest.approx(min(cands), rel=1e-9)

    def test_statless_schedule_on_same_hw_gets_stats(self):
        # a deserialized schedule round-trips hw but not stats; compiling
        # it on that very hw must still attach the re-priced stats
        base = repro.compile(self.wls, hw=AcceleratorConfig(),
                             objective="cycles")
        bare = ModelSchedule.from_json(base.schedule.to_json())
        assert bare.stats is None and bare.hw == base.hw
        prog = repro.compile(self.wls, hw=base.hw, objective="cycles",
                             schedule=bare)
        assert prog.schedule.stats is not None
        assert prog.schedule.stats.cycles == pytest.approx(prog.stats.cycles)

    def test_objective_x_cost_selection(self):
        prog = repro.compile(self.wls, hw=self.grid, objective="cycles",
                             hw_selection="objective_x_cost")
        assert prog.hw in self.grid.configs()
        chosen = prog.stats.objective("cycles") * prog.hw.n_pes * prog.hw.gb_bandwidth
        for cfg, obj in prog.codesign:
            if np.isfinite(obj):
                assert chosen <= obj * cfg.n_pes * cfg.gb_bandwidth * (1 + 1e-9)

    def test_bad_selection_rejected(self):
        with pytest.raises(ValueError):
            repro.compile(self.wls, hw=self.grid, hw_selection="nope")
