"""Calibrated cost model: fit error + measured re-ranking evidence.

    PYTHONPATH=src python -m benchmarks.calibrate_model [--fast]

Closes the predicted<->measured loop end to end and commits the evidence:

1. **Fit** — :func:`repro.core.calibrate.calibrate` microbenchmarks the
   kernel grid (policy x phase order x graph size), least-squares fits a
   :class:`~repro.core.hw.LatencyModel` (per-family overheads, effective
   bandwidth, per-dispatch setup) and reports per-point relative error.
   The fitted model is persisted beside a
   :class:`~repro.runtime.store.ProgramStore` keyed by
   :func:`~repro.core.calibrate.backend_fingerprint`.
2. **Serve** — an :class:`~repro.runtime.engine.InferenceEngine` on that
   store (it auto-loads the fitted model) serves a seeded request stream
   to a warm state, measures the warm wall, runs
   :meth:`~repro.runtime.engine.InferenceEngine.rerank_topk` and measures
   the warm wall again on the identical stream — with a
   ``repro.trace_count()`` delta of **zero** on the post-rerank request
   path (the swap is trace-cached, never on the request path).

Full runs commit ``experiments/benchmarks/calibrate_model.json`` and
guard (a) fit median relative error <= ``ERROR_CEIL`` and (b) the
re-ranked warm wall never slower than the analytic-best warm wall beyond
timer noise (``NEVER_SLOWER_CEIL``); ``--fast`` shrinks the grid and the
stream and re-checks the error guard against the *committed* JSON's
ceiling without rewriting it (the CI smoke lane).  Evidence is saved
before any guard raises, so a regression still leaves the JSON behind.
"""
from __future__ import annotations

import sys
import tempfile
import time

import jax

import repro
from repro.core.calibrate import backend_fingerprint, calibrate
from repro.kernels.common import measure_wall
from repro.runtime import ProgramStore
from repro.runtime.engine import InferenceEngine

from .common import OUT_DIR, emit, save_json
from .serve_gnn import DIMS, make_stream

#: ISSUE acceptance bar: calibrated model must land within 25% median
#: relative error on its own grid (committed in the evidence JSON; the
#: CI fast lane re-checks against the committed value).
ERROR_CEIL = 0.25
#: re-ranking must never make warm serving slower; 10% headroom absorbs
#: scheduler noise on a shared container (rerank itself only swaps on a
#: measured >= 3% win, so the true floor is "no change or better").
NEVER_SLOWER_CEIL = 1.10
N_FULL = 1000
N_FAST = 64
SEED = 0


def _committed_error_ceil() -> float:
    """The committed evidence's error ceiling (regression guard for the
    fast lane), or the default when no evidence is committed yet."""
    import json

    path = OUT_DIR / "calibrate_model.json"
    try:
        return float(json.loads(path.read_text())["guards"]["error_ceil"])
    except Exception:
        return ERROR_CEIL


def run(fast: bool = False) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    failures: list[str] = []
    backend = backend_fingerprint()

    with tempfile.TemporaryDirectory() as tmp:
        store = ProgramStore(tmp)

        # -- part 1: calibration fit -----------------------------------------
        t0 = time.perf_counter()
        report = calibrate(fast=fast, store=store, seed=SEED)
        fit_wall = time.perf_counter() - t0
        rows.append((
            "calibrate_fit",
            fit_wall * 1e6,
            f"err_med={report.error_median:.3f}"
            f"_max={report.error_max:.3f}_n={report.n_points}"
            f"_bw={report.bw_mult:g}x",
        ))
        for fam, d in sorted(report.per_family.items()):
            rows.append((
                f"calibrate_{fam}",
                0.0,
                f"overhead={d['overhead']:.2f}_err={d['error_median']:.3f}",
            ))
        err_ceil = _committed_error_ceil() if fast else ERROR_CEIL
        if report.error_median > err_ceil:
            failures.append(
                f"calibration fit error regressed: median relative error "
                f"{report.error_median:.3f} > ceiling {err_ceil:.3f} "
                f"on {backend}"
            )

        # -- part 2: measured re-ranking on a warm stream --------------------
        n = N_FAST if fast else N_FULL
        stream = make_stream(n, seed=SEED)
        # the engine auto-loads the fitted model from the store (keyed by
        # the backend fingerprint calibrate() just wrote)
        engine = InferenceEngine(DIMS, store=store, use_pallas=False)
        engine.init(jax.random.PRNGKey(SEED))
        assert engine.hw.latency.calibrated, (
            "engine did not auto-load the fitted LatencyModel from the store"
        )

        def warm_pass():
            res = engine.submit(stream)
            assert all(r.ok for r in res), [r.error for r in res if not r.ok]
            return res

        warm_pass()  # cold pass: searches + traces happen here
        wall_before = measure_wall(warm_pass, warmup=1, iters=3, reduce="min")

        rerank = engine.rerank_topk(iters=3 if fast else 5)

        traces0 = repro.trace_count()
        warm_pass()  # post-rerank request path must re-trace nothing
        trace_delta = repro.trace_count() - traces0
        wall_after = measure_wall(warm_pass, warmup=0, iters=3, reduce="min")

        if trace_delta != 0:
            failures.append(
                f"re-ranking leaked {trace_delta} XLA traces onto the "
                f"request path (must be 0: swaps are trace-cached)"
            )
        # the wall guard needs the full stream to rise above scheduler
        # noise (the fast lane's ~20 ms walls jitter more than 10%)
        if not fast and wall_after > wall_before * NEVER_SLOWER_CEIL:
            failures.append(
                f"re-ranked warm wall {wall_after:.3f}s slower than "
                f"analytic-best {wall_before:.3f}s "
                f"(ceiling {NEVER_SLOWER_CEIL}x)"
            )
        gps_before = n / wall_before
        gps_after = n / wall_after
        rows.append((
            "rerank_warm_before",
            wall_before * 1e6,
            f"gps={gps_before:.0f}",
        ))
        rows.append((
            "rerank_warm_after",
            wall_after * 1e6,
            f"gps={gps_after:.0f}_swapped={rerank.n_swapped}"
            f"_traces={trace_delta}",
        ))

        if not fast:
            save_json("calibrate_model", {
                "backend": backend,
                "fit": report.to_dict(),
                "fit_wall_s": fit_wall,
                "guards": {
                    "error_ceil": ERROR_CEIL,
                    "never_slower_ceil": NEVER_SLOWER_CEIL,
                },
                "serving": {
                    "n_requests": n,
                    "warm_wall_before_s": wall_before,
                    "warm_wall_after_s": wall_after,
                    "warm_gps_before": gps_before,
                    "warm_gps_after": gps_after,
                    "request_path_traces_after_rerank": trace_delta,
                    "rerank": rerank.as_dict(),
                },
            })
    if failures:
        raise RuntimeError("; ".join(failures))
    return rows


def main(argv=None) -> int:
    fast = "--fast" in (argv if argv is not None else sys.argv[1:])
    print("name,us_per_call,derived")
    emit(run(fast=fast))
    return 0


if __name__ == "__main__":
    sys.exit(main())
