"""Paper Fig. 12: PP runtimes under 25-75 / 50-50 / 75-25 PE allocations
(load balancing across the aggregation/combination engines)."""
from __future__ import annotations

from repro.core import TileStats, named_skeleton, optimize_tiles

from .common import emit, save_json, timed, workloads

DATASETS = ["collab", "mutag", "citeseer"]


def run():
    rows, table = [], {}
    for name, spec, wl in workloads(DATASETS):
        table[name] = {}
        base = None
        ts = TileStats(wl.nnz)
        for split in (0.25, 0.5, 0.75):
            res, us = timed(
                optimize_tiles, named_skeleton("PP-Nt-Vt/sl"), wl,
                objective="cycles", pe_splits=(split,), tile_stats=ts,
            )
            cyc = res.stats.cycles
            if split == 0.5:
                base = cyc
            table[name][f"{int(split*100)}-{100-int(split*100)}"] = cyc
            rows.append((f"fig12/{name}/{int(split*100)}-{100-int(split*100)}",
                         us, f"cycles={cyc:.0f}"))
        best = min(table[name], key=table[name].get)
        rows.append((f"fig12/{name}/best_alloc", 0.0, best))
    save_json("fig12_pe_allocation", table)
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
