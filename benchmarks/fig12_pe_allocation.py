"""Paper Fig. 12: PP runtimes under 25-75 / 50-50 / 75-25 PE allocations
(load balancing across the aggregation/combination engines).

Rebuilt on the batched allocation axis: `sweep_pe_splits` prices the whole
(tiling x split) grid in one vectorized pass per dataset, against the
legacy per-point loop (one scalar-engine `optimize_tiles` per allocation)
it must beat by >= SPEEDUP_FLOOR x — the wall-clock guard raises *after*
the evidence JSON is saved.
"""
from __future__ import annotations

from repro.core import TileStats, named_skeleton, optimize_tiles, sweep_pe_splits

from .common import check_speedup, emit, save_json, speedup_entry, timed, workloads

DATASETS = ["collab", "mutag", "citeseer"]
SKELETON = "PP-Nt-Vt/sl"
SPLITS = (0.25, 0.5, 0.75)
SPEEDUP_FLOOR = 10.0


def _scalar_loop(wl):
    """The pre-batch sweep: one full scalar-engine search per allocation."""
    for split in SPLITS:
        optimize_tiles(
            named_skeleton(SKELETON), wl, objective="cycles",
            pe_splits=(split,), engine="scalar",
        )


def run(with_baseline: bool = True):
    rows, table, errors = [], {}, []
    for name, spec, wl in workloads(DATASETS):
        ts = TileStats(wl.nnz)
        per_split, us = timed(
            sweep_pe_splits, named_skeleton(SKELETON), wl,
            objective="cycles", pe_splits=SPLITS, tile_stats=ts,
        )
        entry = {}
        for split in SPLITS:
            alloc = f"{int(split * 100)}-{100 - int(split * 100)}"
            if split not in per_split:  # sweep omits infeasible splits
                raise RuntimeError(
                    f"fig12/{name}: no legal tiling for the {alloc} allocation"
                )
            cyc = per_split[split].stats.cycles
            entry[alloc] = cyc
            rows.append((f"fig12/{name}/{alloc}", us / len(SPLITS),
                         f"cycles={cyc:.0f}"))
        best = min(entry, key=entry.get)
        rows.append((f"fig12/{name}/best_alloc", 0.0, best))
        table[name] = {"cycles": entry, "best_alloc": best}
        if with_baseline:
            _, base_us = timed(_scalar_loop, wl)
            table[name].update(speedup_entry(us, base_us, len(SPLITS)))
            speedup = table[name]["speedup"]
            rows.append((f"fig12/{name}/speedup", us,
                         f"scalar_us={base_us:.0f};speedup={speedup:.1f}x"))
            errors += check_speedup("fig12", name, speedup, SPEEDUP_FLOOR)
    if with_baseline:
        # only a full (baseline-measured) run refreshes the committed
        # evidence — a --fast run would silently drop the speedup fields
        save_json("fig12_pe_allocation", table)
    if errors:
        raise RuntimeError("; ".join(errors))
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
