"""Paper Fig. 13: runtime vs Global-Buffer bandwidth (512/256/128/64
elements-per-cycle), tiles FIXED at the bw=512 optimum — PP suffers most
because both phases share the bandwidth."""
from __future__ import annotations

from repro.core import (
    AcceleratorConfig,
    TileStats,
    named_skeleton,
    optimize_tiles,
    simulate,
)

from .common import emit, save_json, timed, workloads

FLOWS = ("Seq-Nt", "Seq-Ns", "SP-FsNt-Fs", "PP-Nt-Vt/sl", "PP-Nt-Vsh")


def run():
    rows, table = [], {}
    for name, spec, wl in workloads(["citeseer", "collab"]):
        table[name] = {}
        ts = TileStats(wl.nnz)
        for sk in FLOWS:
            res = optimize_tiles(
                named_skeleton(sk), wl, AcceleratorConfig(gb_bandwidth=512),
                objective="cycles", pe_splits=(0.5,), tile_stats=ts,
            )
            ref = None
            series = {}
            for bw in (512, 256, 128, 64):
                s, us = timed(
                    simulate, res.dataflow, wl, AcceleratorConfig(gb_bandwidth=bw)
                )
                ref = ref or s.cycles
                series[bw] = s.cycles / ref
            table[name][sk] = series
            rows.append((f"fig13/{name}/{sk}", us,
                         f"slowdown@64={series[64]:.2f}x"))
    save_json("fig13_bandwidth", table)
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
