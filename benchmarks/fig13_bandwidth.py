"""Paper Fig. 13: runtime vs Global-Buffer bandwidth, tiles FIXED at the
bw=512 optimum — PP suffers most because both phases share the bandwidth.

Rebuilt on the batched hardware axis: the whole (dataflow x bandwidth) grid
is priced by ONE `simulate_batch(HWGrid)` call per dataset (1e-6 oracle
parity with the scalar path is pinned by tests/test_codesign.py), on a
bandwidth axis denser than the paper's four points.  The legacy per-point
loop (one scalar `simulate` per flow per bandwidth) is timed alongside and
must be beaten by >= SPEEDUP_FLOOR x — the guard raises *after* the
evidence JSON is saved.
"""
from __future__ import annotations

from repro.core import (
    AcceleratorConfig,
    HWGrid,
    TileStats,
    named_skeleton,
    optimize_tiles,
    simulate,
    simulate_batch,
)

from .common import check_speedup, emit, save_json, speedup_entry, timed, workloads

FLOWS = ("Seq-Nt", "Seq-Ns", "SP-FsNt-Fs", "PP-Nt-Vt/sl", "PP-Nt-Vsh")
#: Dense sweep (the batch call's cost is nearly flat in grid size, the
#: legacy loop's is linear); the paper's canonical 512/256/128/64 points
#: are a subset.
BANDWIDTHS = tuple(range(512, 24, -8))  # 512, 504, ..., 40, 32
SPEEDUP_FLOOR = 10.0


def _scalar_loop(dfs, wl):
    """The pre-batch sweep: one scalar simulate per (flow, bandwidth)."""
    for df in dfs:
        for bw in BANDWIDTHS:
            simulate(df, wl, AcceleratorConfig(gb_bandwidth=bw))


def run(with_baseline: bool = True):
    rows, table, errors = [], {}, []
    grid = HWGrid(gb_bandwidth=BANDWIDTHS)
    for name, spec, wl in workloads(["citeseer", "collab"]):
        ts = TileStats(wl.nnz)
        chosen = [
            optimize_tiles(
                named_skeleton(sk), wl, AcceleratorConfig(gb_bandwidth=512),
                objective="cycles", pe_splits=(0.5,), tile_stats=ts,
            )
            for sk in FLOWS
        ]
        dfs = [r.dataflow for r in chosen]
        batch, us = timed(simulate_batch, dfs, wl, grid, tile_stats=ts)
        table[name] = {"series": {}}
        for i, sk in enumerate(FLOWS):
            ref = batch.cycles[i, 0]  # bw = 512
            series = {bw: batch.cycles[i, j] / ref
                      for j, bw in enumerate(BANDWIDTHS)}
            table[name]["series"][sk] = series
            rows.append((f"fig13/{name}/{sk}", us / len(FLOWS),
                         f"slowdown@64={series[64]:.2f}x"))
        if with_baseline:
            _, base_us = timed(_scalar_loop, dfs, wl)
            table[name].update(
                speedup_entry(us, base_us, len(FLOWS) * len(BANDWIDTHS))
            )
            speedup = table[name]["speedup"]
            rows.append((f"fig13/{name}/speedup", us,
                         f"scalar_us={base_us:.0f};speedup={speedup:.1f}x"))
            errors += check_speedup("fig13", name, speedup, SPEEDUP_FLOOR)
    if with_baseline:
        # only a full (baseline-measured) run refreshes the committed
        # evidence — a --fast run would silently drop the speedup fields
        save_json("fig13_bandwidth", table)
    if errors:
        raise RuntimeError("; ".join(errors))
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
