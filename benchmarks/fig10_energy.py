"""Paper Fig. 10: on-chip buffer access energy (GB vs RF breakdown) of the
Table-5 dataflows across datasets (same runtime-optimal mappings as Fig 9)."""
from __future__ import annotations

from .common import emit, save_json, skeleton_sweep, workloads


def run(datasets=None):
    rows, table = [], {}
    for name, spec, wl in workloads(datasets):
        base = None
        table[name] = {}
        for sk, res, us in skeleton_sweep(wl):
            s = res.stats
            base = base or s.energy_pj
            gb = sum(v for k, v in s.energy_breakdown.items() if k.startswith("gb"))
            table[name][sk] = {
                "energy_pj": s.energy_pj,
                "gb_pj": gb,
                "rf_pj": s.energy_breakdown.get("rf", 0.0),
                "norm": s.energy_pj / base,
            }
            rows.append((f"fig10/{name}/{sk}", us,
                         f"uJ={s.energy_pj/1e6:.2f};gb_frac={gb/s.energy_pj:.2f}"))
    save_json("fig10_energy", table)
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
