"""Mapper search latency: `search_dataflows` over synthetic Poisson graphs
(small/medium/large) plus the Table 4 datasets.

This is the regression guard for the batched, cache-backed search engine:
the `large` case (50k vertices, Poisson(8) degrees, f_in=128, g_out=16) took
~52s per sweep with the scalar per-candidate loop and must stay <= 2.5s with
the batch engine (>= 20x).  Pass ``--with-baseline`` to also time the scalar
reference engine and report the measured speedup (slow: re-runs the legacy
O(V)-per-candidate path).

The ``synth-model-3layer`` case times the compiler front-end
(`repro.compile`: per-layer top-k candidates + DP over inter-layer
transition costs, lowered and packaged into a Program) on a 3-layer,
50k-vertex Kipf-style chain, asserts the heterogeneous result never loses
to the homogeneous shared-dataflow baseline, guards its wall clock, and
emits ``experiments/benchmarks/search_model.json``.

    PYTHONPATH=src python -m benchmarks.mapper_search [--with-baseline]
"""
from __future__ import annotations

import numpy as np

import repro
from repro.core import GNNLayerWorkload, TABLE5_NAMES, TileStats, named_skeleton
from repro.core.mapper import optimize_tiles, search_dataflows

from .common import emit, save_json, timed, workloads

#: v, mean degree, f_in, g_out for the synthetic Poisson cases.
SYNTH_CASES = {
    "synth-small": (5_000, 8, 128, 16),
    "synth-medium": (20_000, 8, 128, 16),
    "synth-large": (50_000, 8, 128, 16),
}

#: Threshold (us) the large synthetic sweep must stay under (>= 20x the
#: ~52.6s scalar baseline recorded in README.md).
LARGE_BUDGET_US = 2.5e6

#: Wall-clock guard for the 3-layer model-level search (DP over per-layer
#: top-k candidates + homogeneous baseline, one shared TileStats ladder).
MODEL_CASE = "synth-model-3layer"
MODEL_WIDTHS = (128, 16, 16, 8)  # Kipf-style 3-layer feature chain
MODEL_BUDGET_US = 10e6

PE_SPLITS = (0.25, 0.5, 0.75)


def synth_workload(name: str) -> GNNLayerWorkload:
    v, deg, f_in, g_out = SYNTH_CASES[name]
    rng = np.random.default_rng(0)
    nnz = np.maximum(1, rng.poisson(deg, size=v))
    return GNNLayerWorkload(nnz, f_in, g_out, name=name)


def model_workloads(v: int = 50_000, deg: int = 8) -> list[GNNLayerWorkload]:
    """The 3-layer, 50k-vertex model-search case (one shared graph)."""
    rng = np.random.default_rng(0)
    nnz = np.maximum(1, rng.poisson(deg, size=v))
    return [
        GNNLayerWorkload(nnz, MODEL_WIDTHS[i], MODEL_WIDTHS[i + 1],
                         name=f"layer{i}")
        for i in range(len(MODEL_WIDTHS) - 1)
    ]


def run_model_case() -> tuple[list[tuple[str, float, str]], dict, list[str]]:
    """Time `repro.compile` (heterogeneous DP + homogeneous baseline, both
    from one sweep, packaged into a Program) on the 3-layer 50k-vertex
    workload; emit evidence JSON + regression guard."""
    wls = model_workloads()
    prog, het_us = timed(repro.compile, wls, objective="cycles")
    het = prog.schedule
    homo = het.shared_baseline
    entry = {
        "v": wls[0].v,
        "widths": list(MODEL_WIDTHS),
        "het_us": het_us,
        "het_cycles": het.stats.cycles,
        "homo_cycles": homo.stats.cycles,
        "het_energy_pj": het.stats.energy_pj,
        "homo_energy_pj": homo.stats.energy_pj,
        "transition_cycles": het.stats.transition_cycles,
        "relayouts": het.stats.n_relayouts,
        "heterogeneous": het.is_heterogeneous,
        "dataflows": [df.to_string() for df in het.dataflows],
        "shared_dataflow": homo.dataflows[0].to_string(),
        "budget_us": MODEL_BUDGET_US,
    }
    gain = homo.stats.cycles / max(het.stats.cycles, 1e-9)
    rows = [
        (
            f"mapper/{MODEL_CASE}",
            het_us,
            f"v={wls[0].v};layers=3;het_cycles={het.stats.cycles:.0f};"
            f"homo_cycles={homo.stats.cycles:.0f};gain={gain:.3f}x",
        ),
        (
            f"mapper/{MODEL_CASE}/budget",
            het_us,
            f"budget_us={MODEL_BUDGET_US:.0f};ok={het_us <= MODEL_BUDGET_US}",
        ),
    ]
    # guard failures are reported to the caller so evidence JSON is saved
    # before anything raises
    errors = []
    if het.stats.cycles > homo.stats.cycles * (1 + 1e-9):
        errors.append(
            f"model search regression: heterogeneous {het.stats.cycles:.0f} "
            f"cycles > homogeneous {homo.stats.cycles:.0f}"
        )
    if het_us > MODEL_BUDGET_US:
        errors.append(
            f"model search regression: {het_us:.0f}us > {MODEL_BUDGET_US:.0f}us"
        )
    return rows, entry, errors


def _scalar_sweep(wl: GNNLayerWorkload) -> None:
    """The pre-batch search: one scalar simulate() per candidate."""
    for sk in TABLE5_NAMES:
        try:
            optimize_tiles(
                named_skeleton(sk), wl, objective="edp", pe_splits=PE_SPLITS,
                engine="scalar",
            )
        except (RuntimeError, ValueError):
            continue


def run(cases: list[str] | None = None, with_baseline: bool = False):
    rows, table = [], {}
    run_model = cases is None or MODEL_CASE in cases
    if cases is None:
        synth_names = list(SYNTH_CASES)
        dataset_names = None  # all of Table 4
    else:
        cases = [c for c in cases if c != MODEL_CASE]
        synth_names = [c for c in cases if c in SYNTH_CASES]
        dataset_names = [c for c in cases if c not in SYNTH_CASES]

    wls = [(n, synth_workload(n)) for n in synth_names]
    if dataset_names is None or dataset_names:
        wls += [(n, wl) for n, _, wl in workloads(dataset_names)]

    for name, wl in wls:
        res, us = timed(search_dataflows, wl, objective="edp", pe_splits=PE_SPLITS)
        best = res[0]
        entry = {
            "v": wl.v,
            "e": wl.e,
            "batch_us": us,
            "results": len(res),
            "best": best.skeleton,
            "best_cycles": best.stats.cycles,
        }
        derived = f"v={wl.v};best={best.skeleton};cycles={best.stats.cycles:.0f}"
        if with_baseline:
            _, base_us = timed(_scalar_sweep, wl)
            entry["scalar_us"] = base_us
            entry["speedup"] = base_us / us
            derived += f";speedup={base_us / us:.1f}x"
        table[name] = entry
        rows.append((f"mapper/{name}", us, derived))
        if name == "synth-large":
            ok = us <= LARGE_BUDGET_US
            rows.append(
                (f"mapper/{name}/budget", us,
                 f"budget_us={LARGE_BUDGET_US:.0f};ok={ok}")
            )
    model_errors: list[str] = []
    if run_model:
        model_rows, model_entry, model_errors = run_model_case()
        rows.extend(model_rows)
        save_json("search_model", model_entry)
    if cases is None:
        # only a full sweep refreshes the committed evidence — a partial
        # (--fast / --only) run would silently truncate it
        save_json("mapper_search", table)
    slow = table.get("synth-large", {}).get("batch_us", 0.0)
    if slow > LARGE_BUDGET_US:
        raise RuntimeError(
            f"mapper search regression: {slow:.0f}us > {LARGE_BUDGET_US:.0f}us"
        )
    if model_errors:
        raise RuntimeError("; ".join(model_errors))
    return rows


def main(argv: list[str] | None = None):
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--with-baseline", action="store_true",
                    help="also time the scalar reference engine (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated case subset (synth-* or dataset names)")
    args = ap.parse_args(argv)
    cases = args.only.split(",") if args.only else None
    emit(run(cases, with_baseline=args.with_baseline))


if __name__ == "__main__":
    main()
