"""Mapper search latency: `search_dataflows` over synthetic Poisson graphs
(small/medium/large) plus the Table 4 datasets.

This is the regression guard for the batched, cache-backed search engine:
the `large` case (50k vertices, Poisson(8) degrees, f_in=128, g_out=16) took
~52s per sweep with the scalar per-candidate loop and must stay <= 2.5s with
the batch engine (>= 20x).  Pass ``--with-baseline`` to also time the scalar
reference engine and report the measured speedup (slow: re-runs the legacy
O(V)-per-candidate path).

    PYTHONPATH=src python -m benchmarks.mapper_search [--with-baseline]
"""
from __future__ import annotations

import numpy as np

from repro.core import GNNLayerWorkload, TABLE5_NAMES, TileStats, named_skeleton
from repro.core.mapper import optimize_tiles, search_dataflows

from .common import emit, save_json, timed, workloads

#: v, mean degree, f_in, g_out for the synthetic Poisson cases.
SYNTH_CASES = {
    "synth-small": (5_000, 8, 128, 16),
    "synth-medium": (20_000, 8, 128, 16),
    "synth-large": (50_000, 8, 128, 16),
}

#: Threshold (us) the large synthetic sweep must stay under (>= 20x the
#: ~52.6s scalar baseline recorded in README.md).
LARGE_BUDGET_US = 2.5e6

PE_SPLITS = (0.25, 0.5, 0.75)


def synth_workload(name: str) -> GNNLayerWorkload:
    v, deg, f_in, g_out = SYNTH_CASES[name]
    rng = np.random.default_rng(0)
    nnz = np.maximum(1, rng.poisson(deg, size=v))
    return GNNLayerWorkload(nnz, f_in, g_out, name=name)


def _scalar_sweep(wl: GNNLayerWorkload) -> None:
    """The pre-batch search: one scalar simulate() per candidate."""
    for sk in TABLE5_NAMES:
        try:
            optimize_tiles(
                named_skeleton(sk), wl, objective="edp", pe_splits=PE_SPLITS,
                engine="scalar",
            )
        except (RuntimeError, ValueError):
            continue


def run(cases: list[str] | None = None, with_baseline: bool = False):
    rows, table = [], {}
    if cases is None:
        synth_names = list(SYNTH_CASES)
        dataset_names = None  # all of Table 4
    else:
        synth_names = [c for c in cases if c in SYNTH_CASES]
        dataset_names = [c for c in cases if c not in SYNTH_CASES]

    wls = [(n, synth_workload(n)) for n in synth_names]
    if dataset_names is None or dataset_names:
        wls += [(n, wl) for n, _, wl in workloads(dataset_names)]

    for name, wl in wls:
        res, us = timed(search_dataflows, wl, objective="edp", pe_splits=PE_SPLITS)
        best = res[0]
        entry = {
            "v": wl.v,
            "e": wl.e,
            "batch_us": us,
            "results": len(res),
            "best": best.skeleton,
            "best_cycles": best.stats.cycles,
        }
        derived = f"v={wl.v};best={best.skeleton};cycles={best.stats.cycles:.0f}"
        if with_baseline:
            _, base_us = timed(_scalar_sweep, wl)
            entry["scalar_us"] = base_us
            entry["speedup"] = base_us / us
            derived += f";speedup={base_us / us:.1f}x"
        table[name] = entry
        rows.append((f"mapper/{name}", us, derived))
        if name == "synth-large":
            ok = us <= LARGE_BUDGET_US
            rows.append(
                (f"mapper/{name}/budget", us,
                 f"budget_us={LARGE_BUDGET_US:.0f};ok={ok}")
            )
    save_json("mapper_search", table)
    slow = table.get("synth-large", {}).get("batch_us", 0.0)
    if slow > LARGE_BUDGET_US:
        raise RuntimeError(
            f"mapper search regression: {slow:.0f}us > {LARGE_BUDGET_US:.0f}us"
        )
    return rows


def main(argv: list[str] | None = None):
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--with-baseline", action="store_true",
                    help="also time the scalar reference engine (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated case subset (synth-* or dataset names)")
    args = ap.parse_args(argv)
    cases = args.only.split(",") if args.only else None
    emit(run(cases, with_baseline=args.with_baseline))


if __name__ == "__main__":
    main()
