"""Assignment §Roofline: aggregate the dry-run JSONs into the per-cell
roofline table (compute/memory/collective terms, dominant bottleneck)."""
from __future__ import annotations

import json
from pathlib import Path

from repro.launch.roofline import format_table

from .common import emit

DRYRUN = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def load_results():
    out = []
    for p in sorted(DRYRUN.glob("*.json")):
        out.append(json.loads(p.read_text()))
    return out


def run():
    results = load_results()
    rows = []
    for r in results:
        if r.get("skipped"):
            continue
        rf = r["roofline"]
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            r.get("compile_s", 0) * 1e6,
            f"bound={rf['dominant_term']};RF={rf['roofline_fraction']:.3f}",
        ))
    return rows


def main():
    results = load_results()
    print(format_table(results))
    emit(run())


if __name__ == "__main__":
    main()
