"""Paper Fig. 9: runtimes of the Table-5 dataflows, normalized to Seq-Nt,
across the Table-4 datasets (GCN layer, mapper-chosen tile sizes)."""
from __future__ import annotations

from repro.core import TABLE5_NAMES, TileStats, named_skeleton, optimize_tiles

from .common import emit, save_json, timed, workloads

SPLITS = (0.25, 0.5, 0.75)


def run(datasets=None):
    rows, table = [], {}
    for name, spec, wl in workloads(datasets):
        base = None
        table[name] = {}
        ts = TileStats(wl.nnz)
        for sk in TABLE5_NAMES:
            try:
                res, us = timed(
                    optimize_tiles, named_skeleton(sk), wl,
                    objective="cycles", pe_splits=SPLITS, tile_stats=ts,
                )
            except (RuntimeError, ValueError):
                continue
            cyc = res.stats.cycles
            base = base or cyc
            table[name][sk] = {
                "cycles": cyc,
                "norm_to_seq_nt": cyc / base,
                "mapping": str(res.dataflow),
            }
            rows.append(
                (f"fig9/{name}/{sk}", us, f"norm={cyc / base:.3f}")
            )
        best = min(table[name], key=lambda k: table[name][k]["cycles"])
        rows.append((f"fig9/{name}/best", 0.0, best))
    save_json("fig9_runtime", table)
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
