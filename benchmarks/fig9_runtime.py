"""Paper Fig. 9: runtimes of the Table-5 dataflows, normalized to Seq-Nt,
across the Table-4 datasets (GCN layer, mapper-chosen tile sizes)."""
from __future__ import annotations

from .common import emit, save_json, skeleton_sweep, workloads


def run(datasets=None):
    rows, table = [], {}
    for name, spec, wl in workloads(datasets):
        base = None
        table[name] = {}
        for sk, res, us in skeleton_sweep(wl):
            cyc = res.stats.cycles
            base = base or cyc
            table[name][sk] = {
                "cycles": cyc,
                "norm_to_seq_nt": cyc / base,
                "mapping": str(res.dataflow),
            }
            rows.append(
                (f"fig9/{name}/{sk}", us, f"norm={cyc / base:.3f}")
            )
        best = min(table[name], key=lambda k: table[name][k]["cycles"])
        rows.append((f"fig9/{name}/best", 0.0, best))
    save_json("fig9_runtime", table)
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
