"""Serving throughput: the bucketized engine vs naive per-graph compile+run.

    PYTHONPATH=src python -m benchmarks.serve_gnn [--smoke] [--chaos]

Drives a 500-request synthetic molecule/ego stream (mutag- and
imdb-bin-structured graphs, Table 4) through
:class:`repro.runtime.engine.InferenceEngine` and through the naive
serving loop the engine replaces — one ``repro.compile`` + ``Program.run``
per request.  The naive loop is handed its ModelSchedule for free (no
per-request mapper search), so the measured speedup is a *lower* bound on
what bucketized batching + the program cache actually buy.

Full runs commit ``experiments/benchmarks/serve_gnn.json`` (graphs/sec,
p50/p99 request latency, cache behavior, the naive comparison) and guard
that the engine beats naive per-graph serving by >= 10x wall-clock on the
same stream; ``--smoke`` serves a short stream with no JSON / no guard
(CI lane).  Both modes cross-check engine outputs against the naive
per-graph outputs to 1e-5.

``--chaos`` runs the fault-isolation lane instead: the same stream with a
seeded 10% fault mix (NaN / float64 features, broken CSR, oversized
graphs, sticky per-request kernel faults) through an engine with a
:class:`~repro.runtime.faults.FaultInjector` attached.  It proves the
resilience contract under load — ``submit()`` never raises, every fault
lands as a typed non-``ok`` status, healthy outputs stay **bit-identical**
to a fault-free run, and the chaos slowdown stays under
``CHAOS_SLOWDOWN_CEIL`` — and commits
``experiments/benchmarks/serve_gnn_chaos.json``.

``--restart`` runs the zero-cold-start lane: a cold engine serves the
stream into a fresh :class:`~repro.runtime.store.ProgramStore` (with
JAX's persistent compilation cache wired underneath), is killed, and a
revived engine on the same store ``precompile()``\\ s the recorded bucket
grid and serves the stream again.  It proves the restart contract — the
revived engine's first request runs with **zero mapper searches and zero
new XLA traces**, first-request latency at warm-path speed (vs the cold
p99), outputs bit-identical across the restart, and a corrupted artifact
degrades to a recompile instead of an exception — and commits
``experiments/benchmarks/serve_gnn_restart.json``.  Set
``REPRO_STORE_DIR`` to persist the store across invocations (the CI lane
does, via ``actions/cache``).

``--async`` runs the continuous-batching lane on >= 2 forced host devices
(the process re-execs itself with ``--xla_force_host_platform_device_count``
when it finds only one): an :class:`~repro.runtime.scheduler.AsyncEngine`
serves the stream through per-bucket batching windows placed over the
device mesh, against the synchronous per-arrival front-end it replaces
(one ``submit([req])`` per arrival — what a sync engine actually does
when requests come one at a time).  It proves the async contract —
blast-phase throughput >= ``ASYNC_SPEEDUP_FLOOR`` x the per-arrival sync
engine with **bit-identical** outputs, and a paced (sub-capacity,
no-fault) phase whose per-request p99 tracks the batching window
(<= ``ASYNC_P99_WINDOW_FACTOR`` x ``window_ms``) — and commits
``experiments/benchmarks/serve_gnn_async.json``.  The bulk-submit sync
engine (all requests in one call — an oracle no real front-end sees) is
reported alongside for context.  On this single-core container the win
is continuous batching itself; on a multi-core host the per-device
streams additionally overlap.

``--giant`` runs the beyond-capacity lane: banded graphs whose staged
V x F intermediate exceeds the modeled ``gb_capacity_bytes`` (a plain
engine rejects the entire stream) are served through the partitioned
lane — ``plan_partition`` picks ``row_stream`` under the ``edp``
objective, L-hop halo closures stream through one shared closure-bucket
Program, and stitched outputs must be **bit-identical**
(``np.array_equal``) to the monolithic per-graph fallback.  Full runs
commit ``experiments/benchmarks/serve_gnn_giant.json`` (the ranked plan
candidates for the largest graph, partition counts, trace counts, the
fallback comparison) and guard the wall-clock win at
``GIANT_SPEEDUP_FLOOR`` x; ``--smoke`` serves two smaller
beyond-capacity graphs with the same bit-identity checks (CI lane).
"""
from __future__ import annotations

import os
import shutil
import sys
import tempfile
import time
from pathlib import Path

import jax
import numpy as np

import repro
from repro.core import GNNLayerWorkload
from repro.core.schedule import ModelSchedule
from repro.graphs import TABLE4, BucketPolicy, CSRGraph, from_edges
from repro.graphs.datasets import make_graph
from repro.runtime import FaultInjector, FaultRule, ProgramStore, RetryPolicy
from repro.runtime.engine import InferenceEngine, Request

from .common import emit, save_json

DIMS = [(32, 16), (16, 8)]  # 2-layer GCN, Kipf-style widths
MIX = ("mutag", "imdb-bin")  # molecules + ego nets (paper Table 4)
#: the engine's cold cost is nearly fixed (per-bucket mapper searches +
#: one XLA trace per bucket shape) while naive serving scales linearly,
#: so the stream must be long enough to amortize cold start the way real
#: serving does; 1000 keeps the guard's margin robust to naive-side
#: timing variance (~2x run to run on this container).
N_FULL = 1000
N_SMOKE = 64
SPEEDUP_FLOOR = 10.0
SEED = 0


def make_stream(n: int, seed: int = SEED) -> list[Request]:
    """A seeded request stream alternating molecule / ego-net structure."""
    rng = np.random.default_rng(seed)
    f_in = DIMS[0][0]
    reqs = []
    for i in range(n):
        spec = TABLE4[MIX[i % len(MIX)]]
        g = make_graph(spec, rng)
        x = rng.normal(size=(g.n_nodes, f_in)).astype(np.float32)
        reqs.append(Request(graph=g, x=x, rid=i))
    return reqs


def naive_serve(requests, params, schedule: ModelSchedule):
    """The loop the engine replaces: per-request compile (schedule given —
    no mapper search, conservatively cheap) + bind + run + mean readout.
    Every request pays its own XLA trace; nothing is shared."""
    outs = []
    t0 = time.perf_counter()
    for req in requests:
        wls = [
            GNNLayerWorkload(req.graph.nnz, fi, fo, name=f"layer{i}")
            for i, (fi, fo) in enumerate(DIMS)
        ]
        prog = repro.compile(wls, graph=req.graph, schedule=schedule)
        logits = prog.run(params, jax.numpy.asarray(req.x))
        outs.append(np.asarray(jax.block_until_ready(logits)).mean(axis=0))
    return outs, time.perf_counter() - t0


def run(smoke: bool = False):
    n = N_SMOKE if smoke else N_FULL
    requests = make_stream(n)

    engine = InferenceEngine(
        DIMS, policy=BucketPolicy(max_graphs=64), readout="mean"
    )
    params = engine.init(jax.random.PRNGKey(0))

    traces_before = repro.trace_count()
    results = engine.submit(requests)
    stats = engine.stats()
    cold_traces = repro.trace_count() - traces_before

    # steady state: re-serving the same-shaped stream must hit only cached
    # programs and take zero new traces
    warm_engine_start = time.perf_counter()
    traces_before = repro.trace_count()
    engine.submit(requests)
    warm_s = time.perf_counter() - warm_engine_start
    warm_traces = repro.trace_count() - traces_before
    if warm_traces != 0:
        raise RuntimeError(
            f"serve: warm stream took {warm_traces} new traces; the "
            f"program cache must make steady-state serving trace-free"
        )

    # naive per-graph serving on the same (cold) stream; smoke mode only
    # checks parity on a slice so the CI lane stays fast
    naive_reqs = requests[: 8 if smoke else n]
    schedule = ModelSchedule.from_policies("sp_opt", "AC", DIMS)
    naive_outs, naive_s = naive_serve(naive_reqs, params, schedule)

    diffs = [
        float(np.abs(results[i].output - naive_outs[i]).max())
        for i in range(len(naive_reqs))
    ]
    parity = max(diffs)
    if parity > 1e-5:
        raise RuntimeError(
            f"serve: engine vs per-graph outputs differ by {parity:.2e}"
        )

    engine_us = stats.wall_s / n * 1e6
    warm_us = warm_s / n * 1e6
    naive_us = naive_s / len(naive_reqs) * 1e6
    speedup = naive_us / engine_us
    rows = [
        ("serve/engine", engine_us,
         f"graphs_per_sec={stats.graphs_per_sec:.1f};p50_ms={stats.p50_ms:.1f};"
         f"p99_ms={stats.p99_ms:.1f};buckets={stats.n_buckets};"
         f"batches={stats.n_batches};traces={cold_traces}"),
        ("serve/engine_warm", warm_us,
         f"graphs_per_sec={n / warm_s:.1f};traces={warm_traces}"),
        ("serve/naive", naive_us,
         f"graphs_per_sec={1e6 / naive_us:.1f};n={len(naive_reqs)}"),
        ("serve/speedup", 0.0, f"x{speedup:.1f};parity={parity:.1e}"),
    ]

    if not smoke:
        save_json("serve_gnn", {
            "stream": {
                "n_requests": n,
                "mix": list(MIX),
                "dims": [list(d) for d in DIMS],
                "seed": SEED,
            },
            "engine": {
                **stats.as_dict(),
                "us_per_request": engine_us,
                "cold_traces": cold_traces,
                "warm_wall_s": warm_s,
                "warm_us_per_request": warm_us,
                "warm_traces": warm_traces,
                "warm_graphs_per_sec": n / warm_s,
            },
            "naive": {
                "n_requests": len(naive_reqs),
                "wall_s": naive_s,
                "us_per_request": naive_us,
                "graphs_per_sec": 1e6 / naive_us,
            },
            "speedup": speedup,
            "parity_max_abs_diff": parity,
        })
        # the guard runs after the evidence lands, so a regression still
        # leaves the numbers behind for diagnosis
        if speedup < SPEEDUP_FLOOR:
            raise RuntimeError(
                f"serve: bucketized engine only {speedup:.1f}x faster than "
                f"naive per-graph compile+run (floor {SPEEDUP_FLOOR:.0f}x)"
            )
    return rows


# -- chaos lane --------------------------------------------------------------
#: 10% of the stream is poisoned (one request in CHAOS_FAULT_EVERY, the
#: five fault classes in rotation), mirroring the fault-injection tests at
#: benchmark scale.
N_CHAOS = 1000
N_CHAOS_SMOKE = 100
CHAOS_FAULT_EVERY = 10
#: healthy synthetic graphs top out around 32 nodes (Table 4 mutag /
#: imdb-bin structure), so a 128-node admission cap only ever rejects the
#: injected oversized graphs.
CHAOS_MAX_NODES = 128
CHAOS_OVERSIZED_NODES = 200
#: wall-clock ceiling for the chaos stream vs the fault-free run of the
#: same healthy requests: quarantine solo re-runs and ladder retries may
#: cost work, but isolation must not collapse throughput.
CHAOS_SLOWDOWN_CEIL = 5.0

CHAOS_CLASSES = ("nan_features", "float64_features", "broken_csr",
                 "oversized", "kernel_fault")


def _oversized_request(rid: int, rng: np.random.Generator) -> Request:
    """A ring graph far over the admission cap (rejected before compile)."""
    n = CHAOS_OVERSIZED_NODES
    src, dst = np.arange(n), (np.arange(n) + 1) % n
    g = from_edges(n, np.concatenate([src, dst]), np.concatenate([dst, src]))
    x = rng.normal(size=(n, DIMS[0][0])).astype(np.float32)
    return Request(graph=g, x=x, rid=rid)


def make_chaos_stream(n: int, seed: int = SEED):
    """The healthy stream with every CHAOS_FAULT_EVERY-th request poisoned.

    Returns ``(requests, kernel_rids, class_counts)`` — ``kernel_rids``
    need sticky injector rules; the other classes are malformed payloads.
    """
    rng = np.random.default_rng(seed + 1)
    requests = []
    kernel_rids: list[int] = []
    counts = {c: 0 for c in CHAOS_CLASSES}
    for req in make_stream(n, seed):
        rid = req.rid
        if rid % CHAOS_FAULT_EVERY != 0:
            requests.append(req)
            continue
        cls = CHAOS_CLASSES[(rid // CHAOS_FAULT_EVERY) % len(CHAOS_CLASSES)]
        counts[cls] += 1
        if cls == "nan_features":
            x = np.array(req.x, copy=True)
            x[0, 0] = np.nan
            req = Request(graph=req.graph, x=x, rid=rid)
        elif cls == "float64_features":
            req = Request(graph=req.graph, x=req.x.astype(np.float64), rid=rid)
        elif cls == "broken_csr":
            ci = np.array(req.graph.col_idx, copy=True)
            ci[0] = req.graph.n_nodes + 7  # dangling edge target
            req = Request(
                graph=CSRGraph(req.graph.row_ptr, ci, req.graph.values,
                               req.graph.n_nodes),
                x=req.x, rid=rid,
            )
        elif cls == "oversized":
            req = _oversized_request(rid, rng)
        else:  # kernel_fault: payload is healthy, the injector poisons it
            kernel_rids.append(rid)
        requests.append(req)
    return requests, kernel_rids, counts


def run_chaos(smoke: bool = False):
    """The fault-isolation lane: seeded 10% fault mix through an injected
    engine, checked against a fault-free run of the same healthy stream."""
    n = N_CHAOS_SMOKE if smoke else N_CHAOS
    requests, kernel_rids, class_counts = make_chaos_stream(n)
    poisoned = {r.rid for r in requests if r.rid % CHAOS_FAULT_EVERY == 0}
    policy = BucketPolicy(max_graphs=64, max_nodes=CHAOS_MAX_NODES)

    injector = FaultInjector(
        seed=SEED,
        rules=[FaultRule(kind="exception", rid=r) for r in kernel_rids],
    )
    engine = InferenceEngine(
        DIMS,
        policy=policy,
        readout="mean",
        fault_injector=injector,
        retry=RetryPolicy(max_retries=1),
    )
    params = engine.init(jax.random.PRNGKey(0))

    # reaching the next statement at all IS the headline claim: submit()
    # never raises for a per-request cause, whatever the mix throws at it
    results = engine.submit(requests)
    stats = engine.stats()

    by_status: dict[str, int] = {}
    for res in results:
        by_status[res.status] = by_status.get(res.status, 0) + 1
        if not res.ok and res.error_type is None:
            raise RuntimeError(
                f"chaos: rid {res.rid} ended {res.status} without a typed "
                f"error cause"
            )
    n_kernel = len(kernel_rids)
    n_rejected_exp = len(poisoned) - n_kernel
    if by_status.get("failed", 0) != n_kernel:
        raise RuntimeError(
            f"chaos: {by_status.get('failed', 0)} failed requests, expected "
            f"exactly the {n_kernel} kernel-poisoned rids"
        )
    if by_status.get("rejected", 0) != n_rejected_exp:
        raise RuntimeError(
            f"chaos: {by_status.get('rejected', 0)} rejected requests, "
            f"expected {n_rejected_exp} (malformed + oversized)"
        )
    healthy_ok = by_status.get("ok", 0) + by_status.get("degraded", 0)
    if healthy_ok != n - len(poisoned):
        raise RuntimeError(
            f"chaos: {healthy_ok} healthy completions of {n - len(poisoned)} "
            f"healthy requests — isolation leaked onto healthy neighbors"
        )

    # fault-free reference over the same healthy requests: outputs must be
    # bit-identical (block-diagonal batching computes graphs independently,
    # so neither quarantine solo re-runs nor batch composition may change
    # a healthy answer)
    healthy_reqs = [r for r in requests if r.rid not in poisoned]
    ref_engine = InferenceEngine(
        DIMS, params, policy=policy, readout="mean",
        retry=RetryPolicy(max_retries=1),
    )
    ref = {res.rid: res for res in ref_engine.submit(healthy_reqs)}
    ref_stats = ref_engine.stats()
    n_compared = 0
    for res in results:
        if res.rid in poisoned:
            continue
        if not np.array_equal(res.output, ref[res.rid].output):
            raise RuntimeError(
                f"chaos: rid {res.rid} output differs from the fault-free "
                f"run — healthy answers must be bit-identical under chaos"
            )
        n_compared += 1

    slowdown = stats.wall_s / ref_stats.wall_s if ref_stats.wall_s > 0 else 1.0
    chaos_us = stats.wall_s / n * 1e6
    rows = [
        ("serve/chaos", chaos_us,
         f"ok={by_status.get('ok', 0)};rejected={by_status.get('rejected', 0)};"
         f"failed={by_status.get('failed', 0)};"
         f"degraded={by_status.get('degraded', 0)};"
         f"solo_retries={stats.n_solo_retries};retries={stats.n_retries};"
         f"bit_identical={n_compared};slowdown=x{slowdown:.2f}"),
    ]

    if not smoke:
        save_json("serve_gnn_chaos", {
            "stream": {
                "n_requests": n,
                "fault_every": CHAOS_FAULT_EVERY,
                "n_poisoned": len(poisoned),
                "classes": class_counts,
                "mix": list(MIX),
                "dims": [list(d) for d in DIMS],
                "seed": SEED,
                "max_nodes_cap": CHAOS_MAX_NODES,
            },
            "engine": stats.as_dict(),
            "statuses": by_status,
            "injected": injector.counts(),
            "escaped_exceptions": 0,  # submit() returned; nothing escaped
            "healthy": {
                "n": n - len(poisoned),
                "n_served": healthy_ok,
                "n_bit_identical": n_compared,
            },
            "reference": {
                "wall_s": ref_stats.wall_s,
                "graphs_per_sec": ref_stats.graphs_per_sec,
            },
            "slowdown_vs_fault_free": slowdown,
            "slowdown_ceiling": CHAOS_SLOWDOWN_CEIL,
        })
        # guard after the evidence lands, same policy as the main lane
        if slowdown > CHAOS_SLOWDOWN_CEIL:
            raise RuntimeError(
                f"chaos: fault isolation cost x{slowdown:.2f} wall-clock vs "
                f"fault-free (ceiling x{CHAOS_SLOWDOWN_CEIL:.1f})"
            )
    return rows


# -- restart lane ------------------------------------------------------------
N_RESTART = 1000
N_RESTART_SMOKE = 64
#: first-request latency ceiling for a revived engine with a warm store:
#: warm-path speed (vs the 913 ms cold p99), guarded on full runs against
#: a store that actually started cold.
RESTART_FIRST_MS_CEIL = 20.0
RESTART_SPEEDUP_FLOOR = 10.0


def _store_root() -> tuple[Path, bool]:
    """The store directory: ``REPRO_STORE_DIR`` when set (CI persists it
    across workflow runs via actions/cache), else a throwaway temp dir so
    full runs always measure a genuinely cold start."""
    env = os.environ.get("REPRO_STORE_DIR")
    if env:
        return Path(env).expanduser(), False
    return Path(tempfile.mkdtemp(prefix="repro-store-")), True


def _serve_split(engine, requests):
    """First request solo, rest in bulk — the realistic arrival pattern,
    and it makes first-request latency a clean cold/warm probe (the solo
    micro-batch's shapes land in the traffic profile, so a revived
    engine's precompile warms exactly what the first arrival needs)."""
    return engine.submit(requests[:1]) + engine.submit(requests[1:])


def run_restart(smoke: bool = False):
    """The zero-cold-start lane: serve -> kill -> revive -> serve again.

    Phase 1 streams into a fresh engine backed by a ProgramStore (JAX
    persistent compilation cache wired underneath).  Phase 2 builds a new
    engine — new Programs, new executables, nothing in-process survives
    except what the store holds — precompiles from the recorded traffic
    profile, and must serve its first request with zero mapper searches
    and zero new XLA traces at warm-path latency.  Phase 3 corrupts every
    stored artifact and proves the store degrades to a recompile.
    """
    n = N_RESTART_SMOKE if smoke else N_RESTART
    requests = make_stream(n)
    root, is_temp = _store_root()
    policy = BucketPolicy(max_graphs=64)
    try:
        store = ProgramStore(root, jax_cache=True)
        store_was_cold = len(store) == 0

        # -- phase 1: cold process ------------------------------------------
        engine = InferenceEngine(
            DIMS, policy=policy, readout="mean", store=store
        )
        params = engine.init(jax.random.PRNGKey(0))
        tc0 = repro.trace_count()
        cold_results = _serve_split(engine, requests)
        cold_stats = engine.stats()
        cold_traces = repro.trace_count() - tc0
        cold_first_ms = cold_results[0].latency_s * 1e3

        # -- phase 2: kill + revive -----------------------------------------
        revived = InferenceEngine(
            DIMS, params, policy=policy, readout="mean",
            store=ProgramStore(root, jax_cache=True),
        )
        rep = revived.precompile()
        if rep.n_searches != 0:
            raise RuntimeError(
                f"restart: precompile ran {rep.n_searches} mapper searches; "
                f"a warm store must satisfy every bucket"
            )
        tb = repro.trace_count()
        first = revived.submit(requests[:1])
        first_ms = first[0].latency_s * 1e3
        first_traces = repro.trace_count() - tb
        if not first[0].ok:
            raise RuntimeError(
                f"restart: revived first request ended {first[0].status}: "
                f"{first[0].error}"
            )
        if first_traces != 0 or revived.stats().n_searches != 0:
            raise RuntimeError(
                f"restart: revived first request took {first_traces} new "
                f"traces and {revived.stats().n_searches} mapper searches; "
                f"precompile must leave the request path trace-free"
            )
        rest = revived.submit(requests[1:])
        warm_traces = repro.trace_count() - tb
        if warm_traces != 0:
            raise RuntimeError(
                f"restart: revived stream took {warm_traces} new traces; "
                f"the recorded traffic profile must cover every shape"
            )
        revived_results = first + rest
        n_identical = sum(
            int(np.array_equal(c.output, r.output))
            for c, r in zip(cold_results, revived_results)
        )
        if n_identical != n:
            raise RuntimeError(
                f"restart: only {n_identical}/{n} outputs bit-identical "
                f"across the restart"
            )
        revived_stats = revived.stats()

        # -- phase 3: corruption drill --------------------------------------
        for art in sorted(root.glob("*.program.json")):
            art.write_text("{ not a program artifact")
        drill_store = ProgramStore(root, jax_cache=True)
        drill = InferenceEngine(
            DIMS, params, policy=policy, readout="mean", store=drill_store
        )
        drill_res = drill.submit(requests[:1])  # must recompile, not raise
        if not drill_res[0].ok:
            raise RuntimeError(
                f"restart: corrupted store ended the request "
                f"{drill_res[0].status} ({drill_res[0].error}); corruption "
                f"must degrade to a recompile"
            )
        if drill_store.corrupt == 0:
            raise RuntimeError(
                "restart: the drill never saw a corrupt artifact — the "
                "corruption injection missed the request's keys"
            )
        if not np.array_equal(drill_res[0].output, cold_results[0].output):
            raise RuntimeError(
                "restart: recompiled-after-corruption output differs from "
                "the cold run"
            )

        speedup = cold_first_ms / max(first_ms, 1e-9)
        rows = [
            ("serve/restart_cold", cold_stats.wall_s / n * 1e6,
             f"first_ms={cold_first_ms:.1f};p99_ms={cold_stats.p99_ms:.1f};"
             f"search_s={cold_stats.search_s:.2f};"
             f"trace_s={cold_stats.trace_s:.2f};traces={cold_traces};"
             f"store_cold={store_was_cold}"),
            ("serve/restart_precompile", rep.wall_s * 1e6,
             f"shapes={rep.n_shapes};store_hits={rep.n_store_hits};"
             f"compiled={rep.n_compiled};searches={rep.n_searches};"
             f"traces={rep.n_traces}"),
            ("serve/restart_revived", revived_stats.wall_s / n * 1e6,
             f"first_ms={first_ms:.2f};first_traces={first_traces};"
             f"searches={revived_stats.n_searches};"
             f"store_hits={revived_stats.store_hits};"
             f"bit_identical={n_identical}"),
            ("serve/restart_speedup", 0.0,
             f"x{speedup:.1f};corrupt_recovered={drill_store.corrupt}"),
        ]

        if not smoke:
            save_json("serve_gnn_restart", {
                "stream": {
                    "n_requests": n,
                    "mix": list(MIX),
                    "dims": [list(d) for d in DIMS],
                    "seed": SEED,
                },
                "store": {
                    "was_cold": store_was_cold,
                    **drill_store.stats(),
                },
                "cold": {
                    **cold_stats.as_dict(),
                    "first_request_ms": cold_first_ms,
                    "traces": cold_traces,
                },
                "precompile": rep.as_dict(),
                "revived": {
                    **revived_stats.as_dict(),
                    "first_request_ms": first_ms,
                    "first_request_traces": first_traces,
                    "stream_traces": warm_traces,
                    "us_per_request": revived_stats.wall_s / n * 1e6,
                    "n_bit_identical": n_identical,
                },
                "corruption_drill": {
                    "artifacts_corrupted": True,
                    "served_ok": bool(drill_res[0].ok),
                    "corrupt_detected": drill_store.corrupt,
                    "recompiles": drill.stats().n_searches,
                },
                "cold_start_speedup": speedup,
                "first_ms_ceiling": RESTART_FIRST_MS_CEIL,
                "speedup_floor": RESTART_SPEEDUP_FLOOR,
            })
            # guards run after the evidence lands; they only apply when the
            # store really started cold (a pre-warmed REPRO_STORE_DIR makes
            # the cold phase warm, which is the point of the CI cache)
            if store_was_cold:
                if first_ms > RESTART_FIRST_MS_CEIL:
                    raise RuntimeError(
                        f"restart: revived first request took {first_ms:.1f} "
                        f"ms (ceiling {RESTART_FIRST_MS_CEIL:.0f} ms)"
                    )
                if speedup < RESTART_SPEEDUP_FLOOR:
                    raise RuntimeError(
                        f"restart: only x{speedup:.1f} cold-start speedup "
                        f"(floor x{RESTART_SPEEDUP_FLOOR:.0f})"
                    )
        return rows
    finally:
        if is_temp:
            shutil.rmtree(root, ignore_errors=True)


# -- async lane --------------------------------------------------------------
N_ASYNC = 600
N_ASYNC_SMOKE = 48
N_ASYNC_PACED = 200
N_ASYNC_PACED_SMOKE = 24
ASYNC_DEVICES = 4  # forced host devices when the lane must re-exec
ASYNC_WINDOW_MS = 20.0
#: blast throughput floor vs the per-arrival sync front-end.  Measured
#: headroom on this container is ~9x (746 vs ~6900 graphs/s warm), so the
#: guard has a wide margin over timing noise.
ASYNC_SPEEDUP_FLOOR = 1.5
#: paced-phase per-request p99 ceiling, as a multiple of window_ms: an
#: in-window request waits at most its window plus one micro-batch.
ASYNC_P99_WINDOW_FACTOR = 2.0
#: paced arrival spacing — well under capacity (a warm micro-batch runs
#: in single-digit ms), so every request is in-window by construction.
ASYNC_PACE_S = 0.004


def _reexec_async(smoke: bool) -> list:
    """Re-run this lane in a subprocess with forced host devices (the
    XLA device count is fixed at backend init, so an already-initialized
    single-device process cannot grow a mesh in place)."""
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={ASYNC_DEVICES} "
        + env.get("XLA_FLAGS", "")
    ).strip()
    cmd = [sys.executable, "-m", "benchmarks.serve_gnn", "--async"]
    if smoke:
        cmd.append("--smoke")
    r = subprocess.run(
        cmd, env=env, cwd=Path(__file__).resolve().parents[1], text=True,
        capture_output=True, timeout=3600,
    )
    sys.stdout.write(r.stdout)
    sys.stderr.write(r.stderr)
    if r.returncode != 0:
        raise RuntimeError(
            f"async: re-exec with {ASYNC_DEVICES} forced devices failed "
            f"(rc={r.returncode})"
        )
    return []  # the child already emitted its rows and saved the JSON


def run_async(smoke: bool = False):
    """The continuous-batching lane: AsyncEngine over a device mesh vs
    the per-arrival sync front-end it replaces.

    Phase 1 (blast): every request enqueued as fast as the front-end
    accepts it; windows fill to ``max_graphs`` and flush across devices.
    Phase 2 (paced): sub-capacity arrivals every ``ASYNC_PACE_S`` so each
    request's latency is its window wait plus one micro-batch — p99 must
    track ``window_ms``, not whole-batch wall.  Outputs are checked
    bit-identical to the single-device sync engine throughout.
    """
    from repro.runtime import AsyncEngine

    if jax.device_count() < 2:
        return _reexec_async(smoke)

    from repro.graphs.batching import TrafficProfile

    n = N_ASYNC_SMOKE if smoke else N_ASYNC
    n_paced = N_ASYNC_PACED_SMOKE if smoke else N_ASYNC_PACED
    requests = make_stream(n)
    paced_reqs = make_stream(n_paced, seed=SEED + 1)
    policy = BucketPolicy(max_graphs=64)

    # single-device sync reference (the engine every prior lane measures);
    # warm both the bulk slot shapes and the per-arrival slots=1 shapes so
    # neither timed sync pass pays a trace the async engine doesn't
    sync = InferenceEngine(DIMS, policy=policy, readout="mean")
    params = sync.init(jax.random.PRNGKey(0))
    sync.submit(requests)
    for req in requests:
        sync.submit([req])

    # bulk-submit oracle: all n requests in one call — ideal batching no
    # real arrival process delivers; reported, not guarded against
    t0 = time.perf_counter()
    sync_results = sync.submit(requests)
    sync_bulk_s = time.perf_counter() - t0

    # per-arrival sync front-end: what submit() actually does when
    # requests arrive one at a time — the baseline the async engine
    # replaces (continuous batching is exactly this gap)
    t0 = time.perf_counter()
    for req in requests:
        sync.submit([req])
    sync_arrival_s = time.perf_counter() - t0

    # CI persists a store via REPRO_STORE_DIR (actions/cache): the async
    # engine's per-device precompile then pulls programs + XLA binaries
    # from disk instead of searching/compiling.  Unset -> no store, the
    # warm-up just costs in-process compiles off the clock.
    env_root = os.environ.get("REPRO_STORE_DIR")
    store = (
        ProgramStore(Path(env_root).expanduser(), jax_cache=True)
        if env_root else None
    )
    engine = AsyncEngine(
        DIMS, params, window_ms=ASYNC_WINDOW_MS, policy=policy,
        readout="mean", store=store,
    )
    engine.start()
    try:
        # warm every pow2 slot variant of every bucket both streams can
        # produce, on each bucket's assigned device: paced windows flush
        # at arbitrary fill levels, and a cold XLA trace mid-paced-phase
        # would charge compile time to the p99-tracks-window guard
        warm_prof = TrafficProfile()
        for req in list(requests) + list(paced_reqs):
            warm_prof.record_request(policy.bucket_of(req.graph))
        for bucket in list(warm_prof.requests):
            slots = 1
            while slots <= policy.max_graphs:
                warm_prof.record_batch(bucket, slots)
                slots *= 2
        engine.precompile(warm_prof)
        engine.submit(requests)  # end-to-end warm pass through the windows

        # -- phase 1: blast -------------------------------------------------
        t0 = time.perf_counter()
        futs = [engine.submit_async(r) for r in requests]
        async_results = [f.result() for f in futs]
        blast_s = time.perf_counter() - t0

        n_identical = sum(
            int(
                a.ok and s.ok and np.array_equal(a.output, s.output)
            )
            for a, s in zip(async_results, sync_results)
        )
        if n_identical != n:
            raise RuntimeError(
                f"async: only {n_identical}/{n} outputs bit-identical to "
                f"the single-device sync engine"
            )

        # -- phase 2: paced (no-fault, sub-capacity, in-window) -------------
        paced_futs = []
        t0 = time.perf_counter()
        for req in paced_reqs:
            paced_futs.append(engine.submit_async(req))
            time.sleep(ASYNC_PACE_S)
        paced_results = [f.result() for f in paced_futs]
        paced_s = time.perf_counter() - t0
        stats = engine.stats()
    finally:
        engine.close()

    if not all(r.ok for r in paced_results):
        bad = next(r for r in paced_results if not r.ok)
        raise RuntimeError(
            f"async: paced no-fault request {bad.rid} ended "
            f"{bad.status}: {bad.error}"
        )
    paced_lat_ms = np.asarray(
        [r.latency_s for r in paced_results]
    ) * 1e3
    paced_p50 = float(np.percentile(paced_lat_ms, 50))
    paced_p99 = float(np.percentile(paced_lat_ms, 99))

    async_gps = n / blast_s
    arrival_gps = n / sync_arrival_s
    bulk_gps = n / sync_bulk_s
    speedup = async_gps / arrival_gps
    devices_used = sorted(
        {r.device for r in async_results if r.device is not None}
    )
    rows = [
        ("serve/async_blast", blast_s / n * 1e6,
         f"graphs_per_sec={async_gps:.1f};devices={len(devices_used)};"
         f"flushes_full={stats.n_flushes_full};"
         f"flushes_deadline={stats.n_flushes_deadline};"
         f"bit_identical={n_identical}"),
        ("serve/async_paced", paced_s / n_paced * 1e6,
         f"p50_ms={paced_p50:.1f};p99_ms={paced_p99:.1f};"
         f"window_ms={ASYNC_WINDOW_MS:.0f};pace_ms={ASYNC_PACE_S * 1e3:.0f}"),
        ("serve/sync_per_arrival", sync_arrival_s / n * 1e6,
         f"graphs_per_sec={arrival_gps:.1f}"),
        ("serve/sync_bulk_oracle", sync_bulk_s / n * 1e6,
         f"graphs_per_sec={bulk_gps:.1f}"),
        ("serve/async_speedup", 0.0,
         f"x{speedup:.1f}_vs_per_arrival;x{async_gps / bulk_gps:.2f}"
         f"_vs_bulk_oracle"),
    ]

    if not smoke:
        save_json("serve_gnn_async", {
            "stream": {
                "n_requests": n,
                "n_paced": n_paced,
                "mix": list(MIX),
                "dims": [list(d) for d in DIMS],
                "seed": SEED,
            },
            "mesh": {
                "n_devices": jax.device_count(),
                "devices_used": devices_used,
                "placement": stats.placement,
                "note": (
                    "forced host devices on one CPU core: per-device "
                    "streams cannot overlap compute here, so the measured "
                    "win is continuous batching vs the per-arrival sync "
                    "front-end; on a multi-core or real multi-accelerator "
                    "host the placement additionally overlaps execution"
                ),
            },
            "async": {
                **stats.as_dict(),
                "window_ms": ASYNC_WINDOW_MS,
                "blast_wall_s": blast_s,
                "blast_graphs_per_sec": async_gps,
                "paced": {
                    "n": n_paced,
                    "pace_s": ASYNC_PACE_S,
                    "wall_s": paced_s,
                    "p50_ms": paced_p50,
                    "p99_ms": paced_p99,
                },
            },
            "sync": {
                "per_arrival_wall_s": sync_arrival_s,
                "per_arrival_graphs_per_sec": arrival_gps,
                "bulk_oracle_wall_s": sync_bulk_s,
                "bulk_oracle_graphs_per_sec": bulk_gps,
            },
            "n_bit_identical": n_identical,
            "throughput_speedup_vs_per_arrival": speedup,
            "speedup_floor": ASYNC_SPEEDUP_FLOOR,
            "p99_window_factor": paced_p99 / ASYNC_WINDOW_MS,
            "p99_window_factor_ceiling": ASYNC_P99_WINDOW_FACTOR,
        })
        # guards run after the evidence lands, same policy as every lane
        if speedup < ASYNC_SPEEDUP_FLOOR:
            raise RuntimeError(
                f"async: only x{speedup:.2f} throughput vs the per-arrival "
                f"sync engine (floor x{ASYNC_SPEEDUP_FLOOR:.1f})"
            )
        if paced_p99 > ASYNC_P99_WINDOW_FACTOR * ASYNC_WINDOW_MS:
            raise RuntimeError(
                f"async: paced p99 {paced_p99:.1f} ms does not track the "
                f"{ASYNC_WINDOW_MS:.0f} ms batching window (ceiling "
                f"{ASYNC_P99_WINDOW_FACTOR:.0f}x)"
            )
    return rows


# -- giant lane --------------------------------------------------------------
#: banded giant graphs at distinct sizes spanning several pow2 buckets, so
#: the monolithic fallback pays one XLA trace per shape while the
#: partitioned lane reuses a single closure-bucket Program for everything.
GIANT_SIZES = (5000, 6500, 8000, 9500, 11000, 13000)
GIANT_SIZES_SMOKE = (3000, 4200)
#: modeled global-buffer capacity: every giant graph's staged V x F
#: intermediate (V * 32 * 4 bytes) exceeds it, so admission routes the
#: whole stream to the partitioned lane.
GIANT_CAP_BYTES = 256 * 1024
GIANT_MAX_NODES = 2048  # admission cap == the closure bucket's ceiling
GIANT_SPEEDUP_FLOOR = 1.5
GIANT_SCHEDULE = ModelSchedule.from_policies("sp_opt", "AC", DIMS)


def make_giant_stream(sizes, seed: int = SEED) -> list[Request]:
    """Banded (ring +/-1) giant graphs: tiny halos, honest row_stream win."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i, v in enumerate(sizes):
        rows = np.repeat(np.arange(v), 2)
        cols = (rows + np.tile(np.array([-1, 1]), v)) % v
        g = from_edges(v, rows, cols)
        x = rng.normal(size=(v, DIMS[0][0])).astype(np.float32)
        reqs.append(Request(graph=g, x=x, rid=i))
    return reqs


def _naive_giant(requests, params, schedule: ModelSchedule):
    """The monolithic fallback the partitioned lane replaces: compile the
    whole beyond-capacity graph as one Program per request (schedule given
    for free) and run it.  Every distinct V pays its own XLA trace."""
    outs = []
    t0 = time.perf_counter()
    for req in requests:
        wls = [
            GNNLayerWorkload(req.graph.nnz, fi, fo, name=f"layer{i}")
            for i, (fi, fo) in enumerate(DIMS)
        ]
        prog = repro.compile(wls, graph=req.graph, schedule=schedule)
        logits = prog.run(params, jax.numpy.asarray(req.x))
        outs.append(np.asarray(jax.block_until_ready(logits)))
    return outs, time.perf_counter() - t0


def run_giant(smoke: bool = False):
    """The beyond-capacity lane: spill-model-planned partitioned serving
    vs the monolithic per-graph fallback, bit-identical outputs.

    Every request's staged intermediate exceeds the modeled
    ``gb_capacity_bytes``, so a plain engine would reject it and the only
    alternative is one monolithic compile+run per graph.  The partitioned
    engine instead plans once per bucket (``plan_partition`` under the
    ``edp`` objective), streams L-hop halo closures through a single
    shared closure-bucket Program, and stitches ``[:n_own]`` slices —
    outputs must be **bit-identical** to the monolithic fallback
    (``np.array_equal``), and the full lane guards the wall-clock win at
    ``GIANT_SPEEDUP_FLOOR`` x after the evidence JSON lands.
    """
    import dataclasses

    from repro.core.hw import DEFAULT_ACCEL
    from repro.graphs.partition import plan_partition

    sizes = GIANT_SIZES_SMOKE if smoke else GIANT_SIZES
    n = len(sizes)
    requests = make_giant_stream(sizes)
    hw = dataclasses.replace(DEFAULT_ACCEL, gb_capacity_bytes=GIANT_CAP_BYTES)
    policy = BucketPolicy(max_nodes=GIANT_MAX_NODES)

    env_root = os.environ.get("REPRO_STORE_DIR")
    store = (
        ProgramStore(Path(env_root).expanduser(), jax_cache=True)
        if env_root else None
    )
    engine = InferenceEngine(
        DIMS,
        policy=policy,
        hw=hw,
        schedule=GIANT_SCHEDULE,
        objective="edp",
        partition_oversized=True,
        readout=None,
        store=store,
    )
    params = engine.init(jax.random.PRNGKey(0))

    # a plain engine under the same capacity rejects the whole stream —
    # that's the gap this lane closes
    plain = InferenceEngine(
        DIMS, params, policy=policy, hw=hw, schedule=GIANT_SCHEDULE,
        store=None,
    )
    n_rejected = sum(
        int(r.status == "rejected") for r in plain.submit(requests)
    )
    if n_rejected != n:
        raise RuntimeError(
            f"giant: plain engine rejected {n_rejected}/{n} beyond-capacity "
            f"requests; the stream must be inadmissible without partitioning"
        )

    tc0 = repro.trace_count()
    t0 = time.perf_counter()
    results = engine.submit(requests)
    part_s = time.perf_counter() - t0
    part_traces = repro.trace_count() - tc0
    stats = engine.stats()
    for res in results:
        if res.status != "ok":
            raise RuntimeError(
                f"giant: rid {res.rid} ended {res.status}: {res.error}"
            )
        if res.plan != "row_stream" or res.n_partitions < 2:
            raise RuntimeError(
                f"giant: rid {res.rid} served as {res.plan} with "
                f"{res.n_partitions} partitions; expected a multi-partition "
                f"row_stream plan"
            )

    # steady state: same stream again — plans and the shared closure
    # Program are cached, so the warm pass must take zero new traces
    tc0 = repro.trace_count()
    t0 = time.perf_counter()
    engine.submit(requests)
    warm_s = time.perf_counter() - t0
    warm_traces = repro.trace_count() - tc0
    if warm_traces != 0:
        raise RuntimeError(
            f"giant: warm partitioned stream took {warm_traces} new traces"
        )

    naive_outs, naive_s = _naive_giant(requests, params, GIANT_SCHEDULE)
    n_identical = sum(
        int(np.array_equal(np.asarray(results[i].output), naive_outs[i]))
        for i in range(n)
    )
    if n_identical != n:
        raise RuntimeError(
            f"giant: only {n_identical}/{n} partitioned outputs "
            f"bit-identical to the monolithic fallback"
        )

    speedup = naive_s / part_s
    total_parts = sum(r.n_partitions for r in results)
    rows = [
        ("serve/giant_partitioned", part_s / n * 1e6,
         f"graphs={n};partitions={total_parts};traces={part_traces};"
         f"plans={','.join(sorted(stats.partition_plans))};"
         f"search_s={stats.search_s:.2f}"),
        ("serve/giant_warm", warm_s / n * 1e6,
         f"traces={warm_traces}"),
        ("serve/giant_naive", naive_s / n * 1e6,
         f"graphs={n}"),
        ("serve/giant_speedup", 0.0,
         f"x{speedup:.1f};bit_identical={n_identical}/{n};"
         f"rejected_without_flag={n_rejected}/{n}"),
    ]

    if not smoke:
        biggest = requests[-1].graph
        plan = plan_partition(
            biggest, DIMS, hw, objective="edp", allow_monolithic=False,
            max_block_rows=GIANT_MAX_NODES,
        )
        save_json("serve_gnn_giant", {
            "stream": {
                "sizes": list(sizes),
                "dims": [list(d) for d in DIMS],
                "seed": SEED,
                "gb_capacity_bytes": GIANT_CAP_BYTES,
                "max_nodes_cap": GIANT_MAX_NODES,
            },
            "admission": {
                "rejected_without_flag": n_rejected,
                "footprint_bytes_largest": plan.footprint_bytes,
            },
            "plan_largest": plan.as_dict(),
            "partitioned": {
                **stats.as_dict(),
                "wall_s": part_s,
                "us_per_graph": part_s / n * 1e6,
                "traces": part_traces,
                "warm_wall_s": warm_s,
                "warm_us_per_graph": warm_s / n * 1e6,
                "warm_traces": warm_traces,
                "total_partitions": total_parts,
            },
            "naive_monolithic": {
                "wall_s": naive_s,
                "us_per_graph": naive_s / n * 1e6,
            },
            "speedup": speedup,
            "speedup_floor": GIANT_SPEEDUP_FLOOR,
            "n_bit_identical": n_identical,
        })
        # guard after the evidence lands, same policy as every lane
        if speedup < GIANT_SPEEDUP_FLOOR:
            raise RuntimeError(
                f"giant: partitioned serving only x{speedup:.2f} vs the "
                f"monolithic fallback (floor x{GIANT_SPEEDUP_FLOOR:.1f})"
            )
    return rows


def main(argv: list[str] | None = None) -> int:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="64-request stream, parity-checked, no JSON/guard")
    ap.add_argument("--chaos", action="store_true",
                    help="fault-isolation lane: seeded 10%% fault mix, "
                         "bit-identical healthy outputs, typed statuses")
    ap.add_argument("--restart", action="store_true",
                    help="zero-cold-start lane: serve -> kill -> revive; "
                         "revived first request must be trace-free")
    ap.add_argument("--async", dest="async_", action="store_true",
                    help="continuous-batching lane: AsyncEngine over "
                         "forced host devices vs the per-arrival sync "
                         "front-end; p99 must track the batching window")
    ap.add_argument("--giant", action="store_true",
                    help="beyond-capacity lane: spill-model-planned "
                         "partitioned serving vs the monolithic fallback; "
                         "outputs bit-identical, wall-clock guarded")
    args = ap.parse_args(argv)
    if args.giant:
        rows = run_giant(smoke=args.smoke)
    elif args.async_:
        rows = run_async(smoke=args.smoke)
    elif args.restart:
        rows = run_restart(smoke=args.smoke)
    elif args.chaos:
        rows = run_chaos(smoke=args.smoke)
    else:
        rows = run(smoke=args.smoke)
    emit(rows)
    return 0


if __name__ == "__main__":
    sys.exit(main())
