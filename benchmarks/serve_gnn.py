"""Serving throughput: the bucketized engine vs naive per-graph compile+run.

    PYTHONPATH=src python -m benchmarks.serve_gnn [--smoke]

Drives a 500-request synthetic molecule/ego stream (mutag- and
imdb-bin-structured graphs, Table 4) through
:class:`repro.runtime.engine.InferenceEngine` and through the naive
serving loop the engine replaces — one ``repro.compile`` + ``Program.run``
per request.  The naive loop is handed its ModelSchedule for free (no
per-request mapper search), so the measured speedup is a *lower* bound on
what bucketized batching + the program cache actually buy.

Full runs commit ``experiments/benchmarks/serve_gnn.json`` (graphs/sec,
p50/p99 request latency, cache behavior, the naive comparison) and guard
that the engine beats naive per-graph serving by >= 10x wall-clock on the
same stream; ``--smoke`` serves a short stream with no JSON / no guard
(CI lane).  Both modes cross-check engine outputs against the naive
per-graph outputs to 1e-5.
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

import repro
from repro.core import GNNLayerWorkload
from repro.core.schedule import ModelSchedule
from repro.graphs import TABLE4, BucketPolicy
from repro.graphs.datasets import make_graph
from repro.runtime.engine import InferenceEngine, Request

from .common import emit, save_json

DIMS = [(32, 16), (16, 8)]  # 2-layer GCN, Kipf-style widths
MIX = ("mutag", "imdb-bin")  # molecules + ego nets (paper Table 4)
#: the engine's cold cost is nearly fixed (per-bucket mapper searches +
#: one XLA trace per bucket shape) while naive serving scales linearly,
#: so the stream must be long enough to amortize cold start the way real
#: serving does; 1000 keeps the guard's margin robust to naive-side
#: timing variance (~2x run to run on this container).
N_FULL = 1000
N_SMOKE = 64
SPEEDUP_FLOOR = 10.0
SEED = 0


def make_stream(n: int, seed: int = SEED) -> list[Request]:
    """A seeded request stream alternating molecule / ego-net structure."""
    rng = np.random.default_rng(seed)
    f_in = DIMS[0][0]
    reqs = []
    for i in range(n):
        spec = TABLE4[MIX[i % len(MIX)]]
        g = make_graph(spec, rng)
        x = rng.normal(size=(g.n_nodes, f_in)).astype(np.float32)
        reqs.append(Request(graph=g, x=x, rid=i))
    return reqs


def naive_serve(requests, params, schedule: ModelSchedule):
    """The loop the engine replaces: per-request compile (schedule given —
    no mapper search, conservatively cheap) + bind + run + mean readout.
    Every request pays its own XLA trace; nothing is shared."""
    outs = []
    t0 = time.perf_counter()
    for req in requests:
        wls = [
            GNNLayerWorkload(req.graph.nnz, fi, fo, name=f"layer{i}")
            for i, (fi, fo) in enumerate(DIMS)
        ]
        prog = repro.compile(wls, graph=req.graph, schedule=schedule)
        logits = prog.run(params, jax.numpy.asarray(req.x))
        outs.append(np.asarray(jax.block_until_ready(logits)).mean(axis=0))
    return outs, time.perf_counter() - t0


def run(smoke: bool = False):
    n = N_SMOKE if smoke else N_FULL
    requests = make_stream(n)

    engine = InferenceEngine(
        DIMS, policy=BucketPolicy(max_graphs=64), readout="mean"
    )
    params = engine.init(jax.random.PRNGKey(0))

    traces_before = repro.trace_count()
    results = engine.submit(requests)
    stats = engine.stats()
    cold_traces = repro.trace_count() - traces_before

    # steady state: re-serving the same-shaped stream must hit only cached
    # programs and take zero new traces
    warm_engine_start = time.perf_counter()
    traces_before = repro.trace_count()
    engine.submit(requests)
    warm_s = time.perf_counter() - warm_engine_start
    warm_traces = repro.trace_count() - traces_before
    if warm_traces != 0:
        raise RuntimeError(
            f"serve: warm stream took {warm_traces} new traces; the "
            f"program cache must make steady-state serving trace-free"
        )

    # naive per-graph serving on the same (cold) stream; smoke mode only
    # checks parity on a slice so the CI lane stays fast
    naive_reqs = requests[: 8 if smoke else n]
    schedule = ModelSchedule.from_policies("sp_opt", "AC", DIMS)
    naive_outs, naive_s = naive_serve(naive_reqs, params, schedule)

    diffs = [
        float(np.abs(results[i].output - naive_outs[i]).max())
        for i in range(len(naive_reqs))
    ]
    parity = max(diffs)
    if parity > 1e-5:
        raise RuntimeError(
            f"serve: engine vs per-graph outputs differ by {parity:.2e}"
        )

    engine_us = stats.wall_s / n * 1e6
    warm_us = warm_s / n * 1e6
    naive_us = naive_s / len(naive_reqs) * 1e6
    speedup = naive_us / engine_us
    rows = [
        ("serve/engine", engine_us,
         f"graphs_per_sec={stats.graphs_per_sec:.1f};p50_ms={stats.p50_ms:.1f};"
         f"p99_ms={stats.p99_ms:.1f};buckets={stats.n_buckets};"
         f"batches={stats.n_batches};traces={cold_traces}"),
        ("serve/engine_warm", warm_us,
         f"graphs_per_sec={n / warm_s:.1f};traces={warm_traces}"),
        ("serve/naive", naive_us,
         f"graphs_per_sec={1e6 / naive_us:.1f};n={len(naive_reqs)}"),
        ("serve/speedup", 0.0, f"x{speedup:.1f};parity={parity:.1e}"),
    ]

    if not smoke:
        save_json("serve_gnn", {
            "stream": {
                "n_requests": n,
                "mix": list(MIX),
                "dims": [list(d) for d in DIMS],
                "seed": SEED,
            },
            "engine": {
                **stats.as_dict(),
                "us_per_request": engine_us,
                "cold_traces": cold_traces,
                "warm_wall_s": warm_s,
                "warm_us_per_request": warm_us,
                "warm_traces": warm_traces,
                "warm_graphs_per_sec": n / warm_s,
            },
            "naive": {
                "n_requests": len(naive_reqs),
                "wall_s": naive_s,
                "us_per_request": naive_us,
                "graphs_per_sec": 1e6 / naive_us,
            },
            "speedup": speedup,
            "parity_max_abs_diff": parity,
        })
        # the guard runs after the evidence lands, so a regression still
        # leaves the numbers behind for diagnosis
        if speedup < SPEEDUP_FLOOR:
            raise RuntimeError(
                f"serve: bucketized engine only {speedup:.1f}x faster than "
                f"naive per-graph compile+run (floor {SPEEDUP_FLOOR:.0f}x)"
            )
    return rows


def main(argv: list[str] | None = None) -> int:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="64-request stream, parity-checked, no JSON/guard")
    args = ap.parse_args(argv)
    emit(run(smoke=args.smoke))
    return 0


if __name__ == "__main__":
    sys.exit(main())
