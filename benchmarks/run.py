"""Benchmark harness entrypoint — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig9,...] [--fast]

Prints ``name,us_per_call,derived`` CSV rows (assignment format).
"""
from __future__ import annotations

import argparse
import sys
import time

from . import (
    calibrate_model,
    fig9_runtime,
    fig10_energy,
    fig11_gb_breakdown,
    fig12_pe_allocation,
    fig13_bandwidth,
    hw_codesign,
    mapper_search,
    serve_gnn,
    table3_validation,
    roofline,
)
from .common import emit

MODULES = {
    "fig9": fig9_runtime,
    "fig10": fig10_energy,
    "fig11": fig11_gb_breakdown,
    "fig12": fig12_pe_allocation,
    "fig13": fig13_bandwidth,
    "codesign": hw_codesign,
    "mapper": mapper_search,
    "serve": serve_gnn,
    "serve_chaos": serve_gnn,
    "serve_restart": serve_gnn,
    "serve_async": serve_gnn,
    "serve_giant": serve_gnn,
    "calibrate": calibrate_model,
    "table3": table3_validation,
    "roofline": roofline,
}

FAST_DATASETS = ["mutag", "collab", "citeseer"]
FAST_MAPPER_CASES = ["synth-small", "mutag", "citeseer",
                     mapper_search.MODEL_CASE]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset")
    ap.add_argument("--fast", action="store_true",
                    help="3 representative datasets for fig9/fig10")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(MODULES)
    print("name,us_per_call,derived")
    for n in names:
        mod = MODULES[n]
        t0 = time.time()
        if n in ("fig9", "fig10") and args.fast:
            rows = mod.run(FAST_DATASETS)
        elif n == "mapper" and args.fast:
            rows = mod.run(FAST_MAPPER_CASES)
        elif n == "codesign" and args.fast:
            rows = mod.run(fast=True)
        elif n == "serve" and args.fast:
            rows = mod.run(smoke=True)
        elif n == "serve_chaos":
            rows = serve_gnn.run_chaos(smoke=args.fast)
        elif n == "serve_restart":
            rows = serve_gnn.run_restart(smoke=args.fast)
        elif n == "serve_async":
            rows = serve_gnn.run_async(smoke=args.fast)
        elif n == "serve_giant":
            rows = serve_gnn.run_giant(smoke=args.fast)
        elif n == "calibrate":
            rows = calibrate_model.run(fast=args.fast)
        elif n in ("fig12", "fig13") and args.fast:
            # skip the slow scalar-loop baseline (and its speedup guard)
            rows = mod.run(with_baseline=False)
        else:
            rows = mod.run()
        emit(rows)
        print(f"# {n} done in {time.time()-t0:.1f}s", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
