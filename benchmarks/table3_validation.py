"""Paper Table 3: the simulator's runtime/buffering must match the
closed-form analytical model for every inter-phase dataflow class."""
from __future__ import annotations

import numpy as np

from repro.core import (
    AcceleratorConfig,
    GNNLayerWorkload,
    named_dataflow,
    pipelined_elements,
    simulate,
    table3_buffering,
)

from .common import emit, timed

HW = AcceleratorConfig(gb_bandwidth=10**9)  # no stalls: isolate the formulas


def run():
    rng = np.random.default_rng(0)
    wl = GNNLayerWorkload(rng.integers(1, 9, size=512), 64, 16)
    rows = []
    cases = [
        ("Seq", named_dataflow("Seq-Nt", T_V_AGG=8, T_F_AGG=16, T_V_CMB=8,
                               T_G=8, T_F_CMB=4), wl.v * wl.f_in),
        ("SP-Optimized", named_dataflow("EnGN", T_V_AGG=8, T_F_AGG=16,
                                        T_V_CMB=8, T_F_CMB=16), 0),
        ("PP-row", named_dataflow("HyGCN", T_F_AGG=16, T_V_CMB=8, T_G=8), None),
        ("PP-col", named_dataflow("AWB-GCN", T_F_AGG=8, T_V_AGG=8, T_V_CMB=8), None),
    ]
    for name, df, expect_buf in cases:
        s, us = timed(simulate, df, wl, HW)
        buf = table3_buffering(df, wl)
        if expect_buf is None:
            expect_buf = 2 * pipelined_elements(df, wl)
        ok = abs(buf - expect_buf) < 1e-6
        # Table 3 runtime checks
        if name == "Seq":
            ok &= s.cycles >= s.agg_cycles + s.cmb_cycles
        if name == "SP-Optimized":
            ok &= abs(s.cycles - (s.agg_cycles + s.cmb_cycles)) / s.cycles < 0.05
        if name.startswith("PP"):
            ok &= s.cycles < s.agg_cycles + s.cmb_cycles or s.cycles > 0
        rows.append((f"table3/{name}", us,
                     f"buffer={buf:.0f};expected={expect_buf:.0f};ok={ok}"))
        assert ok, (name, buf, expect_buf)
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
