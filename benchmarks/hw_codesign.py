"""Hardware x dataflow co-design: the joint Pareto frontier over an
`HWGrid` (objective vs the n_pes x gb_bandwidth provisioning proxy) and the
paper's "value of flexibility", quantified by `flexibility_value` on a
three-category workload suite (LEF / HE / HF).

    PYTHONPATH=src python -m benchmarks.hw_codesign [--fast]

Emits ``experiments/benchmarks/hw_codesign.json``: every grid point with
its per-workload best dataflows, the frontier, and the flexible-vs-fixed
comparison.  Guards (raised after the evidence is saved): the frontier is
non-empty and non-dominated, more hardware never hurts, and the flexible
accelerator strictly beats the best single fixed dataflow.
"""
from __future__ import annotations

from repro.core import DEFAULT_ACCEL, HWGrid, flexibility_value, search_codesign

from .common import emit, save_json, timed, workloads

#: One dataset per paper category: mutag (LEF), imdb-bin (HE), citeseer (HF).
SUITE = ["mutag", "imdb-bin", "citeseer"]
GRID = HWGrid(n_pes=(128, 256, 512, 1024), gb_bandwidth=(64, 128, 256, 512))
FAST_GRID = HWGrid(n_pes=(256, 512), gb_bandwidth=(128, 512))
OBJECTIVE = "cycles"


def run(fast: bool = False):
    grid = FAST_GRID if fast else GRID
    wls = [wl for _, _, wl in workloads(SUITE)]

    res, us = timed(search_codesign, wls, grid, objective=OBJECTIVE)
    flex, flex_us = timed(
        flexibility_value, wls, DEFAULT_ACCEL, objective=OBJECTIVE
    )

    entry = {
        "objective": OBJECTIVE,
        "suite": SUITE,
        "grid": {"n_pes": list(grid.n_pes), "gb_bandwidth": list(grid.gb_bandwidth)},
        "search_us": us,
        "points": [
            {
                "n_pes": p.hw.n_pes,
                "gb_bandwidth": p.hw.gb_bandwidth,
                "hw_cost": p.hw_cost,
                "objective_total": p.objective_total,
                "on_frontier": p.on_frontier,
                "dataflows": [df.to_string() if df else None for df in p.dataflows],
            }
            for p in res.points
        ],
        "frontier": [
            {"n_pes": p.hw.n_pes, "gb_bandwidth": p.hw.gb_bandwidth,
             "hw_cost": p.hw_cost, "objective_total": p.objective_total}
            for p in res.frontier
        ],
        "flexibility": {
            "us": flex_us,
            "fixed_dataflow": flex.fixed_dataflow.to_string(),
            "per_workload": [
                {"name": wl.name, "flexible": m.dataflow.to_string(),
                 "flexible_obj": m.objective(OBJECTIVE),
                 "fixed_obj": f.objective(OBJECTIVE)}
                for wl, m, f in zip(wls, flex.per_workload, flex.fixed)
            ],
            "flexible_total": flex.flexible_total,
            "fixed_total": flex.fixed_total,
            "value": flex.value,
            "win_pct": flex.win_pct,
        },
    }
    rows = [
        ("codesign/search", us,
         f"points={len(res.points)};frontier={len(res.frontier)};"
         f"best_hw={res.best.hw.n_pes}x{res.best.hw.gb_bandwidth}"),
        ("codesign/flexibility", flex_us,
         f"value={flex.value:.3f};win={flex.win_pct:.1f}%;"
         f"fixed={flex.fixed[0].skeleton or 'pool'}"),
    ]
    if not fast:
        save_json("hw_codesign", entry)

    # correctness guards (after the evidence is saved)
    errors = []
    if not res.frontier:
        errors.append("codesign: empty Pareto frontier")
    by_hw = {(p.hw.n_pes, p.hw.gb_bandwidth): p.objective_total
             for p in res.points}
    biggest = by_hw[(max(grid.n_pes), max(grid.gb_bandwidth))]
    # 2% slack: per-n_pes candidate grids are linspace-subsampled to
    # max_evals, so a bigger PE budget's subsample can narrowly miss a
    # smaller budget's exact winner — search incompleteness, not a bug
    if any(biggest > v * 1.02 for v in by_hw.values()):
        errors.append("codesign: a smaller hw point beats the largest one")
    # 1e-6 slack: flexible/fixed totals are re-priced through the scalar
    # oracle, which matches the batch argmin scores to 1e-6 rel
    if flex.value < 1.0 - 1e-6:
        errors.append(
            f"codesign: flexibility value {flex.value:.4f} < 1 "
            "(per-workload best lost to a fixed dataflow)"
        )
    if not fast and flex.value <= 1.0 + 1e-9:
        errors.append(
            "codesign: zero flexibility win on the full suite — "
            "per-workload-best must strictly beat the best fixed dataflow"
        )
    if errors:
        raise RuntimeError("; ".join(errors))
    return rows


def main(argv: list[str] | None = None):
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small 2x2 grid, no evidence JSON (CI smoke)")
    args = ap.parse_args(argv)
    emit(run(fast=args.fast))


if __name__ == "__main__":
    main()
